//! Fleet-scale simulation walkthrough: a 50 000-client population served
//! by per-round cohorts of 128, with heavy-tailed stragglers, dropouts, a
//! round deadline, and the framed uplink — contrasted against the same
//! model trained with the paper's full-participation setup.
//!
//! Run: `cargo run --release --example fleet_scale`

use uveqfed::data::{partition, PartitionScheme, SynthMnist};
use uveqfed::fl::{NativeTrainer, Trainer};
use uveqfed::fleet::{
    ClientRecords, FleetDriver, RoundRobinPool, RoundSpec, Scenario, VirtualClock,
};
use uveqfed::models::LogReg;
use uveqfed::quantizer;

fn main() {
    let seed = 7u64;
    let population = 50_000usize;
    let cohort = 128usize;
    let rounds = 30usize;

    // 1. Population: 50k simulated clients backed by 32 template shards
    //    (round-robin), weights drawn per client — no per-client dataset
    //    materialization.
    let n_templates = 32;
    let per = 120;
    let gen = SynthMnist::new(seed);
    let ds = gen.dataset(n_templates * per);
    let test = gen.test_dataset(500);
    let templates = partition(&ds, n_templates, per, PartitionScheme::Iid, seed);
    let pool = RoundRobinPool::synthetic(population, templates, seed);
    let trainer = NativeTrainer::new(LogReg::new(ds.features, ds.classes, 1e-3));

    // 2. Scenario: log-normal stragglers, 2% dropout, 3 s (virtual)
    //    deadline, 25% over-selection so the quota still fills.
    let scenario = Scenario::stragglers(cohort, 3.0);
    let codec = quantizer::make("uveqfed-l2").expect("codec spec");
    let driver = FleetDriver::new(seed, 2.0, 8, scenario);
    let mut clock = VirtualClock::new();
    let mut w = trainer.init_params(seed);

    println!("fleet_scale — population {population}, cohort {cohort}, UVeQFed L=2 @ R=2\n");
    println!(
        "{:>5} {:>9} {:>6} {:>6} {:>6} {:>8} {:>8} {:>9} {:>9}",
        "round", "selected", "done", "drop", "late", "compl", "αmass", "p95(s)", "wireKB"
    );
    let mut wire_total = 0usize;
    for round in 0..rounds {
        let spec = RoundSpec {
            round: round as u64,
            local_steps: 1,
            lr: 0.5,
            batch_size: 0,
            trainer: &trainer,
            codec: codec.as_ref(),
            rate_override: None,
            telemetry: None,
            client_records: ClientRecords::Full,
        };
        let rep = driver.run_round(&spec, &mut w, &pool, &mut clock);
        wire_total += rep.wire_bytes;
        if round % 5 == 0 || round + 1 == rounds {
            println!(
                "{:>5} {:>9} {:>6} {:>6} {:>6} {:>8.3} {:>8.3} {:>9.3} {:>9.1}",
                round,
                rep.selected,
                rep.aggregated,
                rep.dropped,
                rep.late,
                rep.completion_rate,
                rep.alpha_mass,
                rep.timing.p95_latency,
                rep.wire_bytes as f64 / 1e3,
            );
        }
    }
    let fleet_eval = trainer.evaluate(&w, &test);
    let fleet_time = clock.now();

    // 3. Reference: the same number of rounds with the degenerate
    //    full-participation preset over 128 real shards (the seed setup).
    let ref_shards = partition(
        &gen.dataset(cohort * 60),
        cohort,
        60,
        PartitionScheme::Iid,
        seed,
    );
    let ref_pool = uveqfed::fleet::ShardPool::new(&ref_shards);
    let ref_driver = FleetDriver::new(seed, 2.0, 8, Scenario::full());
    let mut ref_clock = VirtualClock::new();
    let mut wr = trainer.init_params(seed);
    for round in 0..rounds {
        let spec = RoundSpec {
            round: round as u64,
            local_steps: 1,
            lr: 0.5,
            batch_size: 0,
            trainer: &trainer,
            codec: codec.as_ref(),
            rate_override: None,
            telemetry: None,
            client_records: ClientRecords::Full,
        };
        ref_driver.run_round(&spec, &mut wr, &ref_pool, &mut ref_clock);
    }
    let ref_eval = trainer.evaluate(&wr, &test);

    println!("\n─ summary ─────────────────────────────────────────────");
    println!(
        "fleet (cohort {cohort}/{population}, stragglers): acc {:.4}, {:.2} virtual s, {:.2} MB wire",
        fleet_eval.accuracy,
        fleet_time,
        wire_total as f64 / 1e6
    );
    println!(
        "full participation (K={cohort}):                 acc {:.4}",
        ref_eval.accuracy
    );
    println!(
        "\nCohort sampling touches {:.2}% of the population per round yet\n\
         tracks the full-participation reference — the Theorem-2 distortion\n\
         decay survives partial participation with re-normalized α's.",
        100.0 * cohort as f64 / population as f64
    );
}
