//! Experiment harness: regenerates **every table and figure** of the
//! paper's evaluation (§V) as CSV + terminal tables.
//!
//! ```bash
//! cargo run --release --example experiments -- <fig4|fig5|table1|fig6|fig7|fig8|fig9|fig10|fig11|all>
//! ```
//!
//! Scale: by default the FL experiments run at reduced scale so the full
//! suite completes in minutes on CPU; set `UVEQFED_FULL=1` for the paper's
//! Table I scale (K=100 etc.). The *qualitative shapes* — who wins, where
//! the R=2 vs R=4 gap sits, i.i.d. vs heterogeneous — are preserved at
//! both scales; EXPERIMENTS.md records the shipped runs.
//!
//! Backend: uses the AOT/PJRT path (`model.backend=hlo`) when artifacts
//! are present for the exact shard size, the native oracle otherwise.

use uveqfed::data::{
    correlated_matrix, exp_decay_sigma, gaussian_matrix, partition, PartitionScheme,
    SynthCifar, SynthMnist,
};
use uveqfed::fl::{run_federated, FlConfig, FlHistory, LrSchedule, NativeTrainer, Trainer};
use uveqfed::metrics::CsvTable;
use uveqfed::models::{CnnLite, MlpMnist};
use uveqfed::quantizer::{self, measure_distortion};
use uveqfed::runtime;

fn full_scale() -> bool {
    std::env::var("UVEQFED_FULL").map(|v| v == "1").unwrap_or(false)
}

fn results_dir() -> std::path::PathBuf {
    uveqfed::bench::results_dir()
}

fn save(table: &CsvTable, name: &str) {
    let path = results_dir().join(format!("{name}.csv"));
    table.write_file(&path).expect("write csv");
    println!("→ {}\n{}", path.display(), table.to_pretty());
}

fn main() {
    let what = std::env::args().nth(1).unwrap_or_else(|| "all".into());
    match what.as_str() {
        "fig4" => fig45(false),
        "fig5" => fig45(true),
        "table1" => table1(),
        "fig6" => fig67(2.0),
        "fig7" => fig67(4.0),
        "fig8" => fig89(2.0),
        "fig9" => fig89(4.0),
        "fig10" => fig1011(2.0),
        "fig11" => fig1011(4.0),
        "all" => {
            fig45(false);
            fig45(true);
            table1();
            fig67(2.0);
            fig67(4.0);
            fig89(2.0);
            fig89(4.0);
            fig1011(2.0);
            fig1011(4.0);
        }
        other => {
            eprintln!("unknown experiment '{other}'");
            std::process::exit(2);
        }
    }
}

// ---------------------------------------------------------------- Fig 4/5

fn fig45(correlated: bool) {
    let name = if correlated { "fig5_distortion_corr" } else { "fig4_distortion_iid" };
    let trials = if full_scale() { 100 } else { 25 };
    println!(
        "\n### {} — quantization distortion, {} data, 128×128, {trials} realizations",
        name,
        if correlated { "correlated" } else { "i.i.d." }
    );
    let codecs =
        ["uveqfed-l2", "uveqfed-l1", "qsgd", "rotation", "subsample", "uveqfed-l4"];
    let mut header = vec!["rate"];
    header.extend(codecs);
    let mut table = CsvTable::new(&header);
    for rate in 1..=6 {
        let mut row = vec![rate as f64];
        for cname in &codecs {
            let codec = quantizer::make(cname).expect("codec spec");
            let mut mse = 0.0;
            for t in 0..trials {
                let mut h = gaussian_matrix(128, 7000 + t as u64);
                if correlated {
                    let sigma = exp_decay_sigma(128, 0.2);
                    h = correlated_matrix(&h, &sigma, 128);
                }
                mse += measure_distortion(codec.as_ref(), &h, rate as f64, 23, t as u64)
                    .mse
                    / trials as f64;
            }
            row.push(mse);
        }
        table.push(row);
    }
    save(&table, name);
}

// ---------------------------------------------------------------- Table I

fn table1() {
    println!("\n### Table I — main simulation parameters (as configured)");
    let mut t = CsvTable::new(&["experiment", "users", "samples_per_user", "local_steps", "step_size"]);
    t.push(vec![6.0, 100.0, 500.0, 1.0, 1e-2]);
    t.push(vec![8.0, 15.0, 1000.0, 1.0, 1e-2]);
    t.push(vec![10.0, 10.0, 5000.0, 17.0, 5e-3]);
    save(&t, "table1_parameters");
    println!("(rows keyed by figure number; full configs in configs/*.toml)");
}

// ------------------------------------------------------------- Figs 6–11

struct FlRun {
    label: &'static str,
    codec: &'static str,
}

const CONVERGENCE_RUNS: &[FlRun] = &[
    FlRun { label: "uveqfed_l2", codec: "uveqfed-l2" },
    FlRun { label: "uveqfed_l1", codec: "uveqfed-l1" },
    FlRun { label: "qsgd", codec: "qsgd" },
    FlRun { label: "rotation", codec: "rotation" },
    FlRun { label: "subsample", codec: "subsample" },
    FlRun { label: "unquantized", codec: "identity" },
];

fn convergence_table(histories: &[(&str, FlHistory)]) -> CsvTable {
    let mut header = vec!["round".to_string()];
    for (label, _) in histories {
        header.push(format!("acc_{label}"));
    }
    let mut t = CsvTable::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let rows = histories[0].1.rows.len();
    for i in 0..rows {
        let mut row = vec![histories[0].1.rows[i].round as f64];
        for (_, h) in histories {
            row.push(h.rows.get(i).map(|r| r.test_accuracy).unwrap_or(f64::NAN));
        }
        t.push(row);
    }
    t
}

/// MNIST trainer: HLO path when artifacts match the shard size, else
/// native oracle.
fn mnist_trainer(n_per_user: usize) -> Box<dyn Trainer> {
    if runtime::artifacts_available() {
        if let Ok(t) = runtime::HloTrainer::load("mnist", n_per_user) {
            println!("(backend: AOT HLO via PJRT, step batch {n_per_user})");
            return Box::new(t);
        }
    }
    println!("(backend: native oracle — artifacts missing or batch mismatch)");
    Box::new(NativeTrainer::new(MlpMnist::new(50)))
}

fn fig67(rate: f64) {
    let (k, n_per_user, rounds) =
        if full_scale() { (100, 500, 250) } else { (16, 150, 50) };
    let name = format!("fig{}_mnist_k{k}_r{}", if rate == 2.0 { 6 } else { 7 }, rate as u32);
    println!("\n### {name} — MNIST convergence, K={k}, R={rate}");
    let gen = SynthMnist::new(6);
    let ds = gen.dataset(k * n_per_user);
    let test = gen.test_dataset(1000);
    let shards = partition(&ds, k, n_per_user, PartitionScheme::Iid, 6);
    let trainer = mnist_trainer(n_per_user);
    let cfg = FlConfig {
        users: k,
        rounds,
        local_steps: 1,
        batch_size: 0,
        lr: LrSchedule::Const(if full_scale() { 1e-2 } else { 0.5 }),
        rate,
        seed: 6,
        workers: 8,
        eval_every: (rounds / 25).max(1),
        verbose: false,
        fleet: uveqfed::fleet::Scenario::full(),
        channel: None,
    };
    let mut histories = Vec::new();
    for run in CONVERGENCE_RUNS {
        let codec = quantizer::make(run.codec).expect("codec spec");
        let h = run_federated(&cfg, trainer.as_ref(), &shards, &test, codec.as_ref());
        println!("  {:<12} best acc {:.4}", run.label, h.best_accuracy());
        histories.push((run.label, h));
    }
    save(&convergence_table(&histories), &name);
}

fn fig89(rate: f64) {
    let (k, n_per_user, rounds) =
        if full_scale() { (15, 1000, 250) } else { (15, 150, 50) };
    let fig = if rate == 2.0 { 8 } else { 9 };
    let gen = SynthMnist::new(8);
    let ds = gen.dataset(k * n_per_user);
    let test = gen.test_dataset(1000);
    let trainer = mnist_trainer(n_per_user);
    for (split_name, scheme) in
        [("iid", PartitionScheme::Iid), ("heterogeneous", PartitionScheme::Sequential)]
    {
        let name = format!("fig{fig}_mnist_k15_r{}_{split_name}", rate as u32);
        println!("\n### {name} — MNIST K=15 {split_name}, R={rate}");
        let shards = partition(&ds, k, n_per_user, scheme, 8);
        let cfg = FlConfig {
            users: k,
            rounds,
            local_steps: 1,
            batch_size: 0,
            lr: LrSchedule::Const(if full_scale() { 1e-2 } else { 0.5 }),
            rate,
            seed: 8,
            workers: 8,
            eval_every: (rounds / 25).max(1),
            verbose: false,
            fleet: uveqfed::fleet::Scenario::full(),
            channel: None,
        };
        let mut histories = Vec::new();
        for run in CONVERGENCE_RUNS.iter().filter(|r| {
            ["uveqfed_l2", "uveqfed_l1", "qsgd", "unquantized"].contains(&r.label)
        }) {
            let codec = quantizer::make(run.codec).expect("codec spec");
            let h = run_federated(&cfg, trainer.as_ref(), &shards, &test, codec.as_ref());
            println!("  {:<12} best acc {:.4}", run.label, h.best_accuracy());
            histories.push((run.label, h));
        }
        save(&convergence_table(&histories), &name);
    }
}

fn fig1011(rate: f64) {
    let fig = if rate == 2.0 { 10 } else { 11 };
    let (k, n_per_user, rounds, tau, batch) =
        if full_scale() { (10, 5000, 60, 17, 60) } else { (8, 240, 10, 3, 60) };
    let gen = SynthCifar::new(10);
    let ds = gen.dataset(k * n_per_user);
    let test = gen.test_dataset(500);
    // CIFAR: prefer the AOT CNN (the paper's 5-layer architecture); the
    // native CnnLite oracle is the fallback.
    let trainer: Box<dyn Trainer> = if runtime::artifacts_available() {
        match runtime::HloTrainer::load("cifar", batch) {
            Ok(t) => {
                println!("(backend: AOT CIFAR CNN via PJRT)");
                Box::new(t)
            }
            Err(e) => {
                println!("(backend: native CnnLite fallback: {e})");
                Box::new(NativeTrainer::new(CnnLite::cifar()))
            }
        }
    } else {
        println!("(backend: native CnnLite fallback — artifacts missing)");
        Box::new(NativeTrainer::new(CnnLite::cifar()))
    };
    for (split_name, scheme) in [
        ("iid", PartitionScheme::Iid),
        ("heterogeneous", PartitionScheme::DominantLabel { frac: 0.25 }),
    ] {
        let name = format!("fig{fig}_cifar_r{}_{split_name}", rate as u32);
        println!("\n### {name} — CIFAR K={k} {split_name}, R={rate}");
        let shards = partition(&ds, k, n_per_user, scheme, 10);
        let cfg = FlConfig {
            users: k,
            rounds,
            local_steps: tau,
            batch_size: batch,
            lr: LrSchedule::Const(5e-3),
            rate,
            seed: 10,
            workers: 8,
            eval_every: (rounds / 12).max(1),
            verbose: false,
            fleet: uveqfed::fleet::Scenario::full(),
            channel: None,
        };
        let mut histories = Vec::new();
        for run in CONVERGENCE_RUNS.iter().filter(|r| {
            ["uveqfed_l2", "uveqfed_l1", "qsgd", "unquantized"].contains(&r.label)
        }) {
            let codec = quantizer::make(run.codec).expect("codec spec");
            let h = run_federated(&cfg, trainer.as_ref(), &shards, &test, codec.as_ref());
            println!("  {:<12} best acc {:.4}", run.label, h.best_accuracy());
            histories.push((run.label, h));
        }
        save(&convergence_table(&histories), &name);
    }
}
