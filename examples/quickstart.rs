//! Quickstart: a complete federated run with UVeQFed in ~40 lines of API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Trains the paper's MNIST MLP (784–50–10, sigmoid) across 10 simulated
//! users at R = 2 bits/parameter with the L = 2 hexagonal UVeQFed codec,
//! and prints the accuracy trajectory plus uplink accounting.

use uveqfed::data::{partition, PartitionScheme, SynthMnist};
use uveqfed::fl::{run_federated, FlConfig, LrSchedule, NativeTrainer};
use uveqfed::models::MlpMnist;
use uveqfed::quantizer;

fn main() {
    // 1. Data: 10 users × 200 samples, i.i.d. split (synthetic MNIST —
    //    this image is offline; see DESIGN.md §2 for the substitution).
    let gen = SynthMnist::new(7);
    let train = gen.dataset(2000);
    let test = gen.test_dataset(500);
    let shards = partition(&train, 10, 200, PartitionScheme::Iid, 7);

    // 2. Model + codec: the paper's MLP, UVeQFed with the hexagonal
    //    lattice (L = 2) at R = 2 bits per parameter.
    let trainer = NativeTrainer::new(MlpMnist::new(50));
    let codec = quantizer::make("uveqfed-l2").expect("codec spec");

    // 3. Federated averaging, 60 rounds of full-batch local GD.
    let cfg = FlConfig {
        users: 10,
        rounds: 60,
        local_steps: 1,
        batch_size: 0,
        lr: LrSchedule::Const(1.0),
        rate: 2.0,
        seed: 7,
        workers: 8,
        eval_every: 10,
        verbose: true,
        fleet: uveqfed::fleet::Scenario::full(),
        channel: None,
    };
    let hist = run_federated(&cfg, &trainer, &shards, &test, codec.as_ref());

    // 4. Report.
    println!("\n{}", hist.to_table().to_pretty());
    let last = hist.rows.last().unwrap();
    println!(
        "final accuracy {:.3} | total uplink {:.2} MB ({} bits) | {:.1}s",
        last.test_accuracy,
        last.uplink_bits / 8e6,
        last.uplink_bits,
        last.wall_secs
    );
    println!(
        "(an unquantized run would have used {:.2} MB — UVeQFed at R=2 is 16× smaller)",
        cfg.rounds as f64 * cfg.users as f64 * 39760.0 * 32.0 / 8e6
    );
}
