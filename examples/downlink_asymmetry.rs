//! Asymmetric-link walkthrough: the same fleet trained over two opposite
//! link budgets — a thin uplink with a fat downlink (classic consumer
//! broadband) and a fat uplink with a thin downlink (the regime arXiv
//! 2006.10672 targets, where the *global* model must be quantized too).
//!
//! One [`AsymmetricChannel`] is split into its halves: the uplink feeds
//! the rate controller's `RatePlan`, the downlink caps each client's
//! broadcast rate through `FleetDriver::with_downlink_channel`. Both
//! directions run the UVeQFed L=2 codec; the downlink codes the delta
//! `w_t − ŵ_ref(u)` against each client's stale reference with error
//! feedback, so a thin downlink costs distortion instead of resyncs.
//!
//! Prints the per-round up/down wire split and broadcast distortion of
//! each regime, then the accuracy both land on.
//!
//! Run: `cargo run --release --example downlink_asymmetry`

use uveqfed::coordinator::rate_control::controller_by_name;
use uveqfed::data::{partition, PartitionScheme, SynthMnist};
use uveqfed::fl::{NativeTrainer, Trainer};
use uveqfed::fleet::{
    AsymmetricChannel, ChannelModel, DownlinkSpec, FleetDriver, RatePlan, RoundRobinPool,
    RoundSpec, Scenario, VirtualClock,
};
use uveqfed::models::LogReg;
use uveqfed::quantizer;

fn main() {
    let seed = 23u64;
    let population = 10_000usize;
    let cohort = 64usize;
    let rounds = 12usize;
    let base_rate = 2.0;

    let n_templates = 20;
    let per = 100;
    let gen = SynthMnist::new(seed);
    let ds = gen.dataset(n_templates * per);
    let test = gen.test_dataset(500);
    let templates = partition(&ds, n_templates, per, PartitionScheme::Iid, seed);
    let pool = RoundRobinPool::synthetic(population, templates, seed);
    let trainer = NativeTrainer::new(LogReg::new(ds.features, ds.classes, 1e-3));
    let uplink_codec = quantizer::make("uveqfed-l2").expect("codec");
    let downlink_codec = quantizer::make("uveqfed-l2").expect("codec");

    // The two regimes under test: each pairs a constrained direction
    // (three capacity tiers around 0.5·R) with a generous one (4·R flat).
    let thin = || ChannelModel::Tiers {
        rates: vec![0.25 * base_rate, 0.5 * base_rate, base_rate],
    };
    let fat = || ChannelModel::Fixed { rate: 4.0 * base_rate };
    let regimes: [(&str, ChannelModel, ChannelModel); 2] = [
        ("thin-uplink", thin(), fat()),
        ("thin-downlink", fat(), thin()),
    ];

    println!(
        "downlink_asymmetry — population {population}, cohort {cohort}, {rounds} rounds, \
         UVeQFed L=2 both directions\n"
    );

    let mut finals: Vec<(&str, f64, f64, f64)> = Vec::new(); // (name, acc, upMB, downMB)
    for (name, up_model, down_model) in regimes {
        // Split one asymmetric link into its halves: uplink capacities
        // drive the water-filling allocation, downlink capacities cap
        // each client's broadcast rate.
        let (up, down) = AsymmetricChannel::new(up_model, down_model, seed).into_parts();
        let plan = RatePlan::new(up, controller_by_name("theory").expect("policy"));
        let driver = FleetDriver::new(seed, base_rate, 8, Scenario::sampled(cohort))
            .with_rate_plan(plan)
            .with_downlink_channel(down);
        let mut clock = VirtualClock::new();
        let mut w = trainer.init_params(seed);
        let (mut up_total, mut down_total) = (0usize, 0usize);

        println!("[{name}]");
        println!(
            "{:>5} {:>10} {:>10} {:>9} {:>8} {:>12}",
            "round", "up(KB)", "down(KB)", "down/up", "resyncs", "bcast dist"
        );
        for round in 0..rounds {
            // Ask for the full base rate on the downlink; the channel
            // model decides who actually gets it.
            let spec = RoundSpec::new(round as u64, 1, 0.5, 0, &trainer, uplink_codec.as_ref())
                .with_downlink(
                    DownlinkSpec::new(downlink_codec.as_ref(), base_rate).with_resync_every(8),
                );
            let rep = driver.run_round(&spec, &mut w, &pool, &mut clock);
            assert_eq!(rep.budget_violations, 0, "codec must fit every assigned budget");
            up_total += rep.wire_bytes;
            down_total += rep.downlink_bytes;
            println!(
                "{:>5} {:>10.1} {:>10.1} {:>9.2} {:>8} {:>12.3e}",
                round,
                rep.wire_bytes as f64 / 1e3,
                rep.downlink_bytes as f64 / 1e3,
                rep.downlink_bytes as f64 / rep.wire_bytes.max(1) as f64,
                rep.resyncs,
                rep.broadcast_distortion,
            );
        }
        let acc = trainer.evaluate(&w, &test).accuracy;
        println!(
            "  accuracy {:.4}; wire total up {:.2} MB, down {:.2} MB\n",
            acc,
            up_total as f64 / 1e6,
            down_total as f64 / 1e6
        );
        finals.push((name, acc, up_total as f64 / 1e6, down_total as f64 / 1e6));
    }

    let (_, acc_a, up_a, down_a) = finals[0];
    let (_, acc_b, up_b, down_b) = finals[1];
    println!(
        "thin-uplink spent {:.2} MB up / {:.2} MB down (acc {:.4});\n\
         thin-downlink spent {:.2} MB up / {:.2} MB down (acc {:.4}).\n\
         The constrained direction sets the wire bill either way — the\n\
         coded downlink turns a thin broadcast pipe into extra distortion\n\
         (absorbed by error feedback) instead of extra bytes.",
        up_a, down_a, acc_a, up_b, down_b, acc_b
    );
}
