//! CIFAR federated training with the paper's 5-layer CNN (AOT JAX graph)
//! — the Figs. 10–11 workload as a standalone driver.
//!
//! ```bash
//! make artifacts && cargo run --release --example cifar_federated -- \
//!     [--rate 2] [--rounds 30] [--codec uveqfed-l2] [--het]
//! ```

use uveqfed::data::{partition, PartitionScheme, SynthCifar};
use uveqfed::fl::{run_federated, FlConfig, LrSchedule, NativeTrainer, Trainer};
use uveqfed::models::CnnLite;
use uveqfed::quantizer;
use uveqfed::runtime;
use uveqfed::util::cli::Cli;

fn main() {
    let cli = Cli::new("cifar_federated", "CIFAR FL with the 5-layer AOT CNN")
        .opt("rate", "2", "bits per parameter")
        .opt("users", "10", "number of users K")
        .opt("samples", "1000", "samples per user")
        .opt("rounds", "30", "federated rounds (one epoch of local SGD each)")
        .opt("local-steps", "17", "τ — local mini-batch steps per round")
        .opt("codec", "uveqfed-l2", "update codec")
        .opt("out", "results/cifar_federated.csv", "history CSV")
        .flag("het", "25%-dominant-label heterogeneous split")
        .flag("native", "force the native CnnLite oracle");
    let args = cli.parse_env();
    let users = args.get_usize("users");
    let n_per_user = args.get_usize("samples");

    let gen = SynthCifar::new(20);
    let ds = gen.dataset(users * n_per_user);
    let test = gen.test_dataset(500);
    let scheme = if args.has_flag("het") {
        PartitionScheme::DominantLabel { frac: 0.25 }
    } else {
        PartitionScheme::Iid
    };
    let shards = partition(&ds, users, n_per_user, scheme, 20);

    let trainer: Box<dyn Trainer> = if args.has_flag("native") || !runtime::artifacts_available()
    {
        println!("backend: native CnnLite oracle");
        Box::new(NativeTrainer::new(CnnLite::cifar()))
    } else {
        match runtime::HloTrainer::load("cifar", 60) {
            Ok(t) => {
                println!("backend: AOT 5-layer CNN via PJRT ({} params)", t.params);
                Box::new(t)
            }
            Err(e) => {
                eprintln!("warning: {e}; using native CnnLite");
                Box::new(NativeTrainer::new(CnnLite::cifar()))
            }
        }
    };

    let codec = quantizer::make(args.get("codec")).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });
    let cfg = FlConfig {
        users,
        rounds: args.get_usize("rounds"),
        local_steps: args.get_usize("local-steps"),
        batch_size: 60,
        lr: LrSchedule::Const(5e-3),
        rate: args.get_f64("rate"),
        seed: 20,
        workers: 8,
        eval_every: 2,
        verbose: true,
        fleet: uveqfed::fleet::Scenario::full(),
        channel: None,
    };
    let hist = run_federated(&cfg, trainer.as_ref(), &shards, &test, codec.as_ref());
    let last = hist.rows.last().unwrap();
    println!(
        "\nfinal acc {:.4} | loss {:.4} | uplink {:.3} MB | {:.1}s wall",
        last.test_accuracy,
        last.test_loss,
        last.uplink_bits / 8e6,
        last.wall_secs
    );
    hist.to_table().write_file(args.get("out")).expect("write csv");
    println!("history → {}", args.get("out"));
}
