//! MNIST federated training through the FULL three-layer stack — the
//! end-to-end validation driver (DESIGN.md §6): L3 rust coordinator →
//! AOT HLO graphs (L2 JAX, with the L1 Pallas dense kernel lowered in) →
//! PJRT execution, with UVeQFed on the metered uplink.
//!
//! ```bash
//! make artifacts && cargo run --release --example mnist_federated -- \
//!     [--rate 2] [--users 15] [--rounds 100] [--codec uveqfed-l2] [--het]
//! ```
//!
//! Logs the loss/accuracy curve (recorded in EXPERIMENTS.md) and falls
//! back to the native oracle with a warning if artifacts are missing.

use uveqfed::data::{partition, PartitionScheme, SynthMnist};
use uveqfed::fl::{run_federated, FlConfig, LrSchedule, NativeTrainer, Trainer};
use uveqfed::models::MlpMnist;
use uveqfed::quantizer;
use uveqfed::runtime;
use uveqfed::util::cli::Cli;

fn main() {
    let cli = Cli::new("mnist_federated", "end-to-end MNIST FL through the AOT stack")
        .opt("rate", "2", "bits per parameter")
        .opt("users", "15", "number of users K")
        .opt("samples", "500", "samples per user (500/1000 match the AOT step graphs)")
        .opt("rounds", "100", "federated rounds")
        .opt("codec", "uveqfed-l2", "update codec")
        .opt("out", "results/mnist_federated.csv", "history CSV")
        .flag("het", "sequential heterogeneous split instead of iid")
        .flag("native", "force the native oracle backend");
    let args = cli.parse_env();
    let users = args.get_usize("users");
    let n_per_user = args.get_usize("samples");
    let rate = args.get_f64("rate");

    let gen = SynthMnist::new(15);
    let ds = gen.dataset(users * n_per_user);
    let test = gen.test_dataset(1000);
    let scheme =
        if args.has_flag("het") { PartitionScheme::Sequential } else { PartitionScheme::Iid };
    let shards = partition(&ds, users, n_per_user, scheme, 15);

    let trainer: Box<dyn Trainer> = if args.has_flag("native") {
        Box::new(NativeTrainer::new(MlpMnist::new(50)))
    } else if runtime::artifacts_available() {
        match runtime::HloTrainer::load("mnist", n_per_user) {
            Ok(t) => {
                println!("backend: AOT HLO via PJRT ({} params, platform {})", t.params, t.platform());
                Box::new(t)
            }
            Err(e) => {
                eprintln!("warning: HLO trainer unavailable ({e}); using native oracle");
                Box::new(NativeTrainer::new(MlpMnist::new(50)))
            }
        }
    } else {
        eprintln!("warning: artifacts not built (make artifacts); using native oracle");
        Box::new(NativeTrainer::new(MlpMnist::new(50)))
    };

    let codec = quantizer::make(args.get("codec")).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });
    let cfg = FlConfig {
        users,
        rounds: args.get_usize("rounds"),
        local_steps: 1,
        batch_size: 0,
        lr: LrSchedule::Const(1e-1),
        rate,
        seed: 15,
        workers: 8,
        eval_every: 5,
        verbose: true,
        fleet: uveqfed::fleet::Scenario::full(),
        channel: None,
    };
    let hist = run_federated(&cfg, trainer.as_ref(), &shards, &test, codec.as_ref());
    let last = hist.rows.last().unwrap();
    println!(
        "\nfinal acc {:.4} | loss {:.4} | uplink {:.3} MB | {:.1}s wall",
        last.test_accuracy,
        last.test_loss,
        last.uplink_bits / 8e6,
        last.wall_secs
    );
    hist.to_table().write_file(args.get("out")).expect("write csv");
    println!("history → {}", args.get("out"));
}
