//! Quantizer playground — the §V-A distortion study, interactively.
//!
//! ```bash
//! cargo run --release --example quant_playground -- [--size 128] [--trials 20]
//! ```
//!
//! Sweeps every codec over R = 1..6 on i.i.d. and correlated Gaussian
//! matrices (the Fig. 4/5 workloads), printing per-entry MSE plus the
//! exact realized bits/entry, and the Theorem 1 predicted error for
//! UVeQFed.

use uveqfed::data::{correlated_matrix, exp_decay_sigma, gaussian_matrix};
use uveqfed::metrics::CsvTable;
use uveqfed::quantizer::{self, measure_distortion};
use uveqfed::util::cli::Cli;

fn main() {
    let cli = Cli::new("quant_playground", "codec distortion sweeps (Figs. 4–5 workloads)")
        .opt("size", "128", "matrix side")
        .opt("trials", "20", "averaging trials")
        .opt("codecs", "uveqfed-l2,uveqfed-l1,qsgd,rotation,subsample", "comma-separated codecs");
    let args = cli.parse_env();
    let n = args.get_usize("size");
    let trials = args.get_usize("trials");
    let codecs: Vec<&str> = args.get("codecs").split(',').collect();

    for correlated in [false, true] {
        let label = if correlated { "correlated (ΣHΣᵀ)" } else { "i.i.d." };
        println!("\n=== {label} Gaussian {n}×{n}, {trials} trials ===");
        let mut header = vec!["rate".to_string()];
        header.extend(codecs.iter().map(|c| c.to_string()));
        let mut table =
            CsvTable::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        for rate in 1..=6 {
            let mut row = vec![rate as f64];
            for name in &codecs {
                let codec = quantizer::make(name).expect("codec spec");
                let mut mse = 0.0;
                for t in 0..trials {
                    let mut h = gaussian_matrix(n, 900 + t as u64);
                    if correlated {
                        let sigma = exp_decay_sigma(n, 0.2);
                        h = correlated_matrix(&h, &sigma, n);
                    }
                    mse +=
                        measure_distortion(codec.as_ref(), &h, rate as f64, 17, t as u64).mse
                            / trials as f64;
                }
                row.push(mse);
            }
            table.push(row);
        }
        println!("{}", table.to_pretty());
    }
}
