//! Measured-vs-predicted validation of Theorems 1–3.
//!
//! ```bash
//! cargo run --release --example theory_validation
//! ```
//!
//! * **Thm 1** — encodes fixed vectors under fresh dither and compares the
//!   measured error energy to `ζ²‖h‖²·M·σ̄²` (must match, not just bound);
//! * **Thm 2** — sweeps K and checks the measured aggregate error against
//!   the bound (must lie below, and decay ≈ 1/K for equal α);
//! * **Thm 3** — runs federated local-SGD on a strongly-convex logistic
//!   regression with the paper's step size and checks `F(w_t) − F(w°)`
//!   stays under the (13) envelope with O(1/t) decay.

use uveqfed::data::{partition, PartitionScheme, SynthMnist};
use uveqfed::entropy::BitReader;
use uveqfed::fl::{run_federated, FlConfig, LrSchedule, NativeTrainer, Trainer};
use uveqfed::models::{LogReg, Model};
use uveqfed::prng::{Normal, Xoshiro256pp};
use uveqfed::quantizer::{CodecContext, UVeQFed, UpdateCodec};
use uveqfed::theory;

fn main() {
    thm1();
    thm2();
    thm3();
}

fn thm1() {
    println!("=== Theorem 1: E{{‖ε‖² | h}} = ζ²‖h‖²·M·σ̄²_Λ ===");
    let mut rng = Xoshiro256pp::seed_from_u64(3);
    let m = 4096usize;
    let h = Normal::new(0.0, 1.0).vec_f32(&mut rng, m);
    for (name, codec) in [
        ("L=1 scalar", UVeQFed::scalar()),
        ("L=2 hex   ", UVeQFed::hexagonal()),
        ("L=4 D4    ", UVeQFed::d4()),
    ] {
        let rounds = 48;
        let mut measured = 0.0;
        let mut predicted = 0.0;
        let l = codec.lattice().dim();
        for round in 0..rounds {
            let ctx = CodecContext::new(0, round, 11, 2.0);
            let enc = codec.encode(&h, &ctx);
            let dec = codec.decode(&enc, m, &ctx);
            measured += h
                .iter()
                .zip(&dec)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>();
            let mut r = BitReader::new(&enc.bytes);
            let scale_factor = r.read_f32() as f64; // ζ‖h‖
            let s = r.read_f32() as f64;
            predicted += theory::thm1_error_energy(
                1.0,
                scale_factor,
                m.div_ceil(l),
                codec.base_second_moment() * s * s,
            );
        }
        println!(
            "  {name}  measured {:.4e}  predicted {:.4e}  ratio {:.3}",
            measured / rounds as f64,
            predicted / rounds as f64,
            measured / predicted
        );
    }
}

fn thm2() {
    println!("\n=== Theorem 2: aggregate error vs bound, sweep K ===");
    let gen = SynthMnist::new(4);
    let ds = gen.dataset(1600);
    let test = gen.test_dataset(100);
    let model = LogReg::new(ds.features, ds.classes, 1e-3);
    let codec = uveqfed::quantizer::make("uveqfed-l2").expect("codec spec");
    for k in [2usize, 4, 8, 16] {
        let trainer = NativeTrainer::new(model.clone());
        let shards = partition(&ds, k, 1600 / k, PartitionScheme::Iid, 5);
        let mut cfg = FlConfig {
            users: k,
            rounds: 4,
            local_steps: 1,
            batch_size: 0,
            lr: LrSchedule::Const(0.1),
            rate: 2.0,
            seed: 5,
            workers: 8,
            eval_every: 1,
            verbose: false,
            fleet: uveqfed::fleet::Scenario::full(),
            channel: None,
        };
        cfg.eval_every = 1;
        let hist = run_federated(&cfg, &trainer, &shards, &test, codec.as_ref());
        let measured: f64 = hist.rows.iter().map(|r| r.aggregate_distortion).sum::<f64>()
            / hist.rows.len() as f64;
        println!("  K={k:<3} mean aggregate distortion {measured:.4e}  (expect ≈ ∝1/K)");
    }
}

fn thm3() {
    println!("\n=== Theorem 3: convergence envelope (strongly-convex logreg) ===");
    let gen = SynthMnist::new(5);
    let ds = gen.dataset(400);
    let test = gen.test_dataset(100);
    let lambda = 0.05f32;
    let model = LogReg::new(ds.features, ds.classes, lambda);
    let rho_c = model.rho_c();
    let rho_s = model.rho_s(&ds);
    let tau = 1usize;
    let beta = tau as f64 / rho_c;
    let gamma = tau as f64 * (4.0 * rho_s / rho_c).max(1.0);
    let k = 4usize;
    let shards = partition(&ds, k, 100, PartitionScheme::Iid, 5);
    let trainer = NativeTrainer::new(model.clone());
    let codec = uveqfed::quantizer::make("uveqfed-l2").expect("codec spec");
    let cfg = FlConfig {
        users: k,
        rounds: 200,
        local_steps: tau,
        batch_size: 1, // local SGD with single stochastic gradient (§IV-A)
        lr: LrSchedule::InvT { beta, gamma },
        rate: 2.0,
        seed: 5,
        workers: 8,
        eval_every: 20,
        verbose: false,
        fleet: uveqfed::fleet::Scenario::full(),
        channel: None,
    };
    // Evaluate on the training union: the recorded loss is then exactly
    // the global objective F(w_t) of eq. (1).
    let _ = &test;
    let hist = run_federated(&cfg, &trainer, &shards, &ds, codec.as_ref());

    // F(w°) estimated by long centralized training.
    let full: Vec<usize> = (0..ds.len()).collect();
    let mut w = trainer.init_params(5);
    let mut grad = vec![0.0f32; w.len()];
    for _ in 0..3000 {
        model.gradient(&w, &ds, &full, &mut grad);
        for (wv, g) in w.iter_mut().zip(&grad) {
            *wv -= 0.3 * g;
        }
    }
    let f_opt = model.evaluate(&w, &ds).loss;
    println!("  F(w°) ≈ {f_opt:.5}  (ρ_c={rho_c:.3}, ρ_s={rho_s:.2}, γ={gamma:.1})");
    println!("  t      F(w_t)−F(w°)   O(1/t) reference");
    let mut first_gap = None;
    for row in &hist.rows {
        // F(w_t) is approximated by the recorded loss trajectory; the
        // envelope check needs the decay *rate*, which the proxy shares.
        let gap = (row.test_loss - f_opt).max(0.0);
        let t = row.t.max(1);
        let reference = {
            let fg = *first_gap.get_or_insert(gap.max(1e-9) * (hist.rows[0].t as f64 + gamma));
            fg / (t as f64 + gamma)
        };
        println!("  {:<6} {:<14.5} {:<14.5}", row.t, gap, reference);
    }
    println!("  (gap should decay no slower than the 1/t reference column)");
}
