//! Heterogeneous uplinks walkthrough: a cohort-sampled fleet whose
//! clients sit on three capacity tiers (0.5×, 1×, 2× the base rate),
//! trained under each rate-allocation policy — uniform, capacity-
//! proportional, and theory-guided (Theorem-2 reverse water-filling).
//!
//! Prints per-policy accuracy, realized rate spread, and the Thm-2
//! aggregate-distortion bound of each round-0 allocation at equal total
//! bits — the comparison the rate controller exists to win.
//!
//! Run: `cargo run --release --example hetero_channel`

use uveqfed::coordinator::rate_control::{
    controller_by_name, thm2_bound_for_allocation, AllocRequest, RateController, TheoryGuided,
};
use uveqfed::data::{partition, PartitionScheme, SynthMnist};
use uveqfed::fl::{NativeTrainer, Trainer};
use uveqfed::fleet::{
    Channel, ChannelModel, FleetDriver, RatePlan, RoundRobinPool, RoundSpec, Scenario,
    VirtualClock,
};
use uveqfed::fleet::ClientPool;
use uveqfed::models::LogReg;
use uveqfed::quantizer;

fn main() {
    let seed = 11u64;
    let population = 20_000usize;
    let cohort = 96usize;
    let rounds = 25usize;
    let base_rate = 2.0;

    let n_templates = 24;
    let per = 100;
    let gen = SynthMnist::new(seed);
    let ds = gen.dataset(n_templates * per);
    let test = gen.test_dataset(500);
    let templates = partition(&ds, n_templates, per, PartitionScheme::Iid, seed);
    let pool = RoundRobinPool::synthetic(population, templates, seed);
    let trainer = NativeTrainer::new(LogReg::new(ds.features, ds.classes, 1e-3));
    let codec = quantizer::make("uveqfed-l2").expect("codec");

    println!(
        "hetero_channel — population {population}, cohort {cohort}, tiers \
         [{:.1}, {:.1}, {:.1}] b/entry, UVeQFed L=2\n",
        0.5 * base_rate,
        base_rate,
        2.0 * base_rate
    );
    println!(
        "{:<14} {:>8} {:>10} {:>22} {:>12}",
        "policy", "acc", "bits(MB)", "rate min/avg/max", "thm2 bound"
    );

    let mut bounds: Vec<(String, f64)> = Vec::new();
    // Round-0 allocation inputs of the uniform run, for the equal-bits
    // comparison below (same seed ⇒ every policy sees the same cohort
    // and capacities in round 0).
    let mut round0: Option<(Vec<f64>, Vec<f64>, f64)> = None; // (caps, alphas, uniform spend)
    for policy in ["uniform", "proportional", "theory"] {
        let plan = RatePlan::new(
            Channel::new(
                ChannelModel::by_name("tiers", base_rate).expect("preset"),
                seed,
            ),
            controller_by_name(policy).expect("policy"),
        );
        let driver = FleetDriver::new(seed, base_rate, 8, Scenario::sampled(cohort))
            .with_rate_plan(plan);
        let mut clock = VirtualClock::new();
        let mut w = trainer.init_params(seed);
        let m = w.len();
        let mut bits_total = 0usize;
        let mut spread = (f64::INFINITY, 0.0f64, 0.0f64); // (min, Σmean, max)
        let mut round0_bound = 0.0;
        for round in 0..rounds {
            let spec = RoundSpec::new(round as u64, 1, 0.5, 0, &trainer, codec.as_ref());
            let rep = driver.run_round(&spec, &mut w, &pool, &mut clock);
            assert_eq!(rep.budget_violations, 0, "codec must fit every assigned budget");
            bits_total += rep.uplink_bits;
            spread = (
                spread.0.min(rep.channel.min_rate),
                spread.1 + rep.channel.mean_rate, // averaged over rounds below
                spread.2.max(rep.channel.max_rate),
            );
            if round == 0 {
                // Thm-2 bound of this round's realized allocation: the
                // yardstick the policies compete on.
                let folded: Vec<_> =
                    rep.clients.iter().filter(|c| c.achieved_bits > 0).collect();
                let rates: Vec<f64> = folded.iter().map(|c| c.assigned_rate).collect();
                let alphas: Vec<f64> =
                    folded.iter().map(|c| pool.weight(c.user as usize)).collect();
                round0_bound = thm2_bound_for_allocation(&rates, &alphas, m);
                if policy == "uniform" {
                    let caps: Vec<f64> = folded.iter().map(|c| c.capacity).collect();
                    round0 = Some((caps, alphas, rates.iter().sum()));
                }
                assert!(
                    rep.channel.distinct_budgets >= 3 || policy == "uniform",
                    "tiers must produce ≥3 budgets under capacity-aware policies"
                );
            }
        }
        let eval = trainer.evaluate(&w, &test);
        println!(
            "{:<14} {:>8.4} {:>10.2} {:>10.2}/{:>4.2}/{:>4.2} {:>12.3e}",
            policy,
            eval.accuracy,
            bits_total as f64 / 8e6,
            spread.0,
            spread.1 / rounds as f64,
            spread.2,
            round0_bound,
        );
        bounds.push((policy.to_string(), round0_bound));
    }

    // Equal-total-bits comparison: uniform strands mass behind capacity
    // caps, so re-run the water-filling at exactly the mass uniform
    // realized in round 0 (not each policy's own spend).
    let uni_bound = bounds.iter().find(|(p, _)| p == "uniform").unwrap().1;
    let (caps, alphas, spent_uni) = round0.expect("uniform run records round 0");
    let m = trainer.init_params(seed).len();
    let eq = TheoryGuided.allocate(&AllocRequest {
        capacities: &caps,
        alphas: &alphas,
        total_rate: spent_uni,
    });
    let eq_bound = thm2_bound_for_allocation(&eq, &alphas, m);
    println!(
        "\nTheorem-2 aggregate bound at equal total bits ({spent_uni:.1} b/entry):\n\
         theory {eq_bound:.3e} vs uniform {uni_bound:.3e} ({}x tighter)\n\
         Water-filling spends bits where α²-weighted distortion hurts the\n\
         aggregate most; uniform strands budget behind slow uplinks.",
        (uni_bound / eq_bound).max(1.0) as u32
    );
}
