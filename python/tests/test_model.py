"""L2 correctness: model shapes, parameter layout, and training steps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def _batch(n, d, classes, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32) * 0.5
    y = np.zeros((n, classes), np.float32)
    y[np.arange(n), rng.integers(0, classes, n)] = 1.0
    return jnp.asarray(x), jnp.asarray(y)


class TestMlp:
    def test_param_count_matches_paper(self):
        spec = M.MlpSpec(hidden=50)
        assert spec.num_params == 39_760

    def test_flatten_unflatten_roundtrip(self):
        spec = M.MlpSpec(hidden=13)
        w = spec.init(0)
        w1, b1, w2, b2 = spec.unflatten(w)
        re = jnp.concatenate([w1.reshape(-1), b1, w2.reshape(-1), b2])
        np.testing.assert_array_equal(np.array(w), np.array(re))

    def test_step_reduces_loss(self):
        spec = M.MlpSpec(hidden=16)
        w = spec.init(1)
        x, y = _batch(64, 784, 10)
        l0 = float(M.mlp_loss(spec, w, x, y, use_pallas=False))
        for _ in range(10):
            (w,) = M.mlp_step(spec, w, x, y, jnp.float32(0.5), use_pallas=False)
        l1 = float(M.mlp_loss(spec, w, x, y, use_pallas=False))
        assert l1 < l0

    def test_pallas_and_jnp_paths_agree(self):
        spec = M.MlpSpec(hidden=16)
        w = spec.init(2)
        x, y = _batch(96, 784, 10, seed=3)
        lp = float(M.mlp_loss(spec, w, x, y, use_pallas=True))
        lr = float(M.mlp_loss(spec, w, x, y, use_pallas=False))
        assert abs(lp - lr) < 1e-5
        (wp,) = M.mlp_step(spec, w, x, y, jnp.float32(0.1), use_pallas=True)
        (wr,) = M.mlp_step(spec, w, x, y, jnp.float32(0.1), use_pallas=False)
        np.testing.assert_allclose(np.array(wp), np.array(wr), rtol=1e-4, atol=1e-6)

    def test_eval_shapes(self):
        spec = M.MlpSpec(hidden=8)
        w = spec.init(0)
        x, _ = _batch(32, 784, 10)
        (logits,) = M.mlp_eval(spec, w, x, use_pallas=False)
        assert logits.shape == (32, 10)


class TestCnn:
    def test_param_count(self):
        spec = M.CnnSpec()
        # conv1 32·3·25+32, conv2 32·32·25+32, conv3 64·32·25+64,
        # fc1 1024·64+64, fc2 64·10+10
        expect = (32 * 3 * 25 + 32) + (32 * 32 * 25 + 32) + (64 * 32 * 25 + 64) \
            + (1024 * 64 + 64) + (64 * 10 + 10)
        assert spec.num_params == expect

    def test_logits_shape(self):
        spec = M.CnnSpec()
        w = spec.init(0)
        x = jnp.zeros((4, 3, 32, 32), jnp.float32)
        (logits,) = M.cnn_eval(spec, w, x)
        assert logits.shape == (4, 10)

    def test_step_reduces_loss(self):
        spec = M.CnnSpec()
        w = spec.init(1)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.uniform(size=(16, 3, 32, 32)).astype(np.float32))
        y = np.zeros((16, 10), np.float32)
        y[np.arange(16), rng.integers(0, 10, 16)] = 1.0
        y = jnp.asarray(y)
        l0 = float(M.cnn_loss(spec, w, x, y))
        for _ in range(5):
            (w,) = M.cnn_step(spec, w, x, y, jnp.float32(0.05))
        assert float(M.cnn_loss(spec, w, x, y)) < l0

    def test_init_deterministic(self):
        spec = M.CnnSpec()
        np.testing.assert_array_equal(np.array(spec.init(3)), np.array(spec.init(3)))


class TestEntryPoints:
    def test_mnist_entries_cover_batches(self):
        spec, entries = M.mnist_entry_points(step_batches=(100, 200), eval_batch=50)
        names = [e[0] for e in entries]
        assert names == ["mnist_step_b100", "mnist_step_b200", "mnist_eval"]
        for _, _, args, meta in entries:
            assert meta["params"] == spec.num_params

    def test_cifar_entries(self):
        spec, entries = M.cifar_entry_points(step_batch=30, eval_batch=40)
        assert entries[0][3]["batch"] == 30
        assert entries[1][3]["batch"] == 40


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
