"""AOT pipeline: HLO text emission sanity (fast subset; the full artifact
build runs via `make artifacts`)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile.kernels import lattice_quant as LQ


def test_hlo_text_emitted_for_small_step():
    spec = M.MlpSpec(inp=16, hidden=4, out=3)
    args = (
        jax.ShapeDtypeStruct((spec.num_params,), jnp.float32),
        jax.ShapeDtypeStruct((8, 16), jnp.float32),
        jax.ShapeDtypeStruct((8, 3), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    text = aot.to_hlo_text(
        lambda w, x, y, lr: M.mlp_step(spec, w, x, y, lr, use_pallas=False), args
    )
    assert "HloModule" in text
    assert "f32[" in text


def test_hlo_text_for_pallas_kernel():
    m = LQ.TILE
    args = (
        jax.ShapeDtypeStruct((m, 2), jnp.float32),
        jax.ShapeDtypeStruct((m, 2), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.float32),
    )
    text = aot.to_hlo_text(lambda h, d, s: (LQ.quantize_hex(h, d, s),), args)
    assert "HloModule" in text
    # interpret-mode pallas must lower to plain HLO, no mosaic custom-call
    assert "mosaic" not in text.lower()


def test_manifest_format(tmp_path):
    lines = []
    spec = M.MlpSpec(inp=16, hidden=4, out=3)
    args = (
        jax.ShapeDtypeStruct((spec.num_params,), jnp.float32),
        jax.ShapeDtypeStruct((4, 16), jnp.float32),
    )
    aot.write_artifact(
        str(tmp_path), "tiny_eval",
        lambda w, x: M.mlp_eval(spec, w, x, use_pallas=False), args,
        dict(kind="eval", model="tiny", batch=4, params=spec.num_params),
        lines,
    )
    assert (tmp_path / "tiny_eval.hlo.txt").exists()
    assert lines[0].startswith("tiny_eval kind=eval model=tiny batch=4")
    assert lines[0].endswith("file=tiny_eval.hlo.txt")


def test_init_blob_roundtrip(tmp_path):
    spec = M.MlpSpec(inp=8, hidden=3, out=2)
    init = np.asarray(spec.init(7), dtype=np.float32)
    p = tmp_path / "x_init.f32"
    init.tofile(p)
    back = np.fromfile(p, dtype=np.float32)
    np.testing.assert_array_equal(init, back)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
