"""L1 correctness: Pallas kernels vs pure-jnp/numpy oracles.

Hypothesis sweeps shapes/scales; allclose against ref.py is the core
correctness signal for the kernels that get lowered into the artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense as D
from compile.kernels import lattice_quant as LQ
from compile.kernels import ref


def _mk_inputs(m, seed, scale=0.37, spread=1.0):
    rng = np.random.default_rng(seed)
    hbar = (rng.normal(size=(m, 2)) * spread).astype(np.float32)
    # dither within the basic cell scale: fold uniform parallelepiped noise
    dither = (rng.uniform(size=(m, 2)).astype(np.float32) - 0.5) * 0.5
    return hbar, dither, np.float32(scale)


class TestLatticeQuant:
    def test_matches_jnp_ref_exactly(self):
        hbar, dither, s = _mk_inputs(LQ.TILE * 4, 0)
        out = np.array(LQ.quantize_hex(hbar, dither, jnp.array([s])))
        r = np.array(ref.quantize_hex_ref(hbar, dither, s))
        np.testing.assert_allclose(out, r, rtol=0, atol=0)

    def test_matches_float64_numpy_oracle(self):
        hbar, dither, s = _mk_inputs(LQ.TILE, 1)
        out = np.array(LQ.quantize_hex(hbar, dither, jnp.array([s])))
        npy = ref.quantize_hex_numpy(hbar, dither, float(s))
        # f32 vs f64 boundary flips are measure-zero on random data
        mismatch = (np.abs(out - npy).max(axis=1) > 1e-4).mean()
        assert mismatch < 1e-3, f"mismatch fraction {mismatch}"

    def test_quantization_error_bounded_by_covering_radius(self):
        hbar, dither, s = _mk_inputs(LQ.TILE, 2)
        out = np.array(LQ.quantize_hex(hbar, dither, jnp.array([s])))
        # ||Q(y) - y|| <= covering radius of s·Λ; bound loosely by s·||G||.
        err = np.linalg.norm(out - hbar, axis=1)
        bound = float(s) * np.linalg.norm(LQ.HEX_G, 2)
        assert err.max() <= bound, (err.max(), bound)

    def test_lattice_points_are_fixed_points(self):
        # If hbar/s + z is itself a lattice point, output = hbar exactly.
        rng = np.random.default_rng(3)
        l = rng.integers(-5, 6, size=(LQ.TILE, 2)).astype(np.float32)
        pts = l @ LQ.HEX_G.T  # lattice points
        s = np.float32(0.25)
        hbar = (pts * s).astype(np.float32)
        dither = np.zeros_like(hbar)
        out = np.array(LQ.quantize_hex(hbar, dither, jnp.array([s])))
        np.testing.assert_allclose(out, hbar, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        tiles=st.integers(min_value=1, max_value=4),
        seed=st.integers(min_value=0, max_value=2**31),
        scale=st.floats(min_value=0.01, max_value=4.0),
        spread=st.floats(min_value=0.1, max_value=10.0),
    )
    def test_hypothesis_sweep_matches_ref(self, tiles, seed, scale, spread):
        hbar, dither, s = _mk_inputs(LQ.TILE * tiles, seed, scale, spread)
        out = np.array(LQ.quantize_hex(hbar, dither, jnp.array([s])))
        r = np.array(ref.quantize_hex_ref(hbar, dither, s))
        np.testing.assert_allclose(out, r, rtol=0, atol=0)

    def test_subtractive_dither_error_uniformity(self):
        # ε = Q(h̄+z) − z − h̄ must be zero-mean with energy σ̄²·s² per
        # sub-vector, independent of the input distribution (Thm 1 driver).
        m = LQ.TILE * 8
        rng = np.random.default_rng(5)
        hbar = (rng.exponential(size=(m, 2)) - 1.0).astype(np.float32)  # non-Gaussian!
        # proper Unif(P0) dither via mod-Λ folding
        u = rng.uniform(size=(m, 2)).astype(np.float32) @ LQ.HEX_G.T.astype(np.float32)
        z = u - np.array(ref.quantize_hex_ref(u, np.zeros_like(u), 1.0))
        s = np.float32(0.5)
        out = np.array(LQ.quantize_hex(hbar, (z / s).astype(np.float32), jnp.array([s])))
        eps = out - hbar
        assert abs(eps.mean()) < 0.01
        # per-subvector error energy ≈ s²·σ̄²(hex). σ̄²(hex-paper) ≈ computed
        # by the rust side; here just check scale-invariance structure:
        energy = (eps ** 2).sum(axis=1).mean()
        assert 0.0 < energy < (float(s) ** 2) * np.linalg.norm(LQ.HEX_G, 2) ** 2


class TestDense:
    def test_matches_ref(self):
        rng = np.random.default_rng(0)
        x = rng.normal(size=(300, 64)).astype(np.float32)
        w = rng.normal(size=(64, 50)).astype(np.float32) * 0.1
        b = rng.normal(size=(50,)).astype(np.float32)
        out = np.array(D.dense_sigmoid(x, w, b))
        r = np.array(ref.dense_sigmoid_ref(x, w, b))
        np.testing.assert_allclose(out, r, rtol=1e-5, atol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=300),
        d=st.integers(min_value=1, max_value=96),
        h=st.integers(min_value=1, max_value=64),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_shapes(self, n, d, h, seed):
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(n, d)).astype(np.float32)
        w = (rng.normal(size=(d, h)) * 0.2).astype(np.float32)
        b = rng.normal(size=(h,)).astype(np.float32)
        out = np.array(D.dense_sigmoid(x, w, b))
        r = np.array(ref.dense_sigmoid_ref(x, w, b))
        assert out.shape == (n, h)
        np.testing.assert_allclose(out, r, rtol=1e-5, atol=1e-6)

    def test_gradient_matches_plain_jnp(self):
        rng = np.random.default_rng(1)
        x = rng.normal(size=(64, 32)).astype(np.float32)
        w = (rng.normal(size=(32, 20)) * 0.2).astype(np.float32)
        b = rng.normal(size=(20,)).astype(np.float32)

        def loss_pallas(w, b):
            return jnp.sum(D.dense_sigmoid(x, w, b) ** 2)

        def loss_ref(w, b):
            return jnp.sum(ref.dense_sigmoid_ref(x, w, b) ** 2)

        gw_p, gb_p = jax.grad(loss_pallas, argnums=(0, 1))(w, b)
        gw_r, gb_r = jax.grad(loss_ref, argnums=(0, 1))(w, b)
        np.testing.assert_allclose(np.array(gw_p), np.array(gw_r), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.array(gb_p), np.array(gb_r), rtol=1e-4, atol=1e-5)

    def test_saturation_is_stable(self):
        x = np.full((4, 4), 100.0, np.float32)
        w = np.eye(4, dtype=np.float32)
        b = np.zeros(4, np.float32)
        out = np.array(D.dense_sigmoid(x, w, b))
        assert np.all(np.isfinite(out))
        assert np.all(out > 0.999)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
