"""Dithered lattice quantization as a Pallas kernel (UVeQFed steps E2–E3).

The hot spot of UVeQFed's encoder is the per-sub-vector nearest-lattice-
point search. It is embarrassingly parallel over the M = m/L sub-vectors,
so the kernel tiles M into VMEM-sized blocks and vectorizes the candidate
scan across the tile:

    y      = hbar / s + dither              # dithered, scale-normalized
    l0     = round(y @ Ginv^T)              # Babai rounding
    l*     = argmin_{o in offsets} ||y - (l0+o) @ G^T||   # exact NN
    recon  = (l* @ G^T - dither) * s        # subtractive-dither decode

TPU mapping (DESIGN.md §Hardware-Adaptation): the 2×2 basis transforms are
expressed as tile-wide matmuls (MXU-eligible), the offset scan is
vectorized elementwise work on the VPU, and BlockSpec streams HBM→VMEM in
`TILE`-row blocks. interpret=True for CPU execution.

The offset search radius is 2 (25 candidates for L=2), matching the Rust
coordinator's `GenericLattice` so the two implementations are
interchangeable — `rust/tests/integration_parity.rs` checks agreement on
the same inputs through the AOT artifact.
"""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Paper §V-A hexagonal lattice, G = [2, 0; 1, 1/sqrt(3)] in MATLAB
# row-basis notation. We generate the SAME lattice through its
# Lagrange-reduced basis (1, 1/√3), (1, −1/√3) stored as columns — must
# match rust lattice::paper_hexagonal (see its doc comment for why).
HEX_G = np.array(
    [[1.0, 1.0], [1.0 / np.sqrt(3.0), -1.0 / np.sqrt(3.0)]], dtype=np.float32
)
HEX_GINV = np.linalg.inv(HEX_G).astype(np.float32)

# Offset cube {-2..2}^2, fixed order (row-major) — must match the search
# the reference uses. 25 candidates.
RADIUS = 2
OFFSETS = np.array(
    [[dx, dy] for dx in range(-RADIUS, RADIUS + 1) for dy in range(-RADIUS, RADIUS + 1)],
    dtype=np.float32,
)  # [25, 2]

TILE = 512  # rows per VMEM block: 512×2 f32 ≈ 4 KiB per operand


def _quant_kernel(hbar_ref, dither_ref, s_ref, g_ref, ginv_ref, off_ref, out_ref):
    """One TILE×L block: dither, Babai + offset scan, reconstruct."""
    s = s_ref[0]
    g = g_ref[...]
    ginv = ginv_ref[...]
    offsets = off_ref[...]
    y = hbar_ref[...] / s + dither_ref[...]          # [T, 2]
    # Babai rounding in basis coordinates: l0 = round(y @ Ginv^T).
    l0 = jnp.round(y @ ginv.T)                       # [T, 2]  (MXU 2x2)
    base_p = l0 @ g.T                                # Babai point
    # Unrolled masked min-scan over the 25 candidate offsets. Deliberately
    # NOT argmin + take_along_axis: xla_extension 0.5.1 (the AOT runtime)
    # miscompiles that gather pattern (~17% wrong lanes); elementwise
    # selects lower identically everywhere.
    n_off = offsets.shape[0]
    best_d = jnp.full(y.shape[:1], jnp.inf, y.dtype)
    best_p = base_p
    for k in range(n_off):
        cand = base_p + (offsets[k] @ g.T)[None, :]  # [T, 2]
        d = jnp.sum((y - cand) ** 2, axis=-1)        # [T]
        mask = d < best_d
        best_d = jnp.where(mask, d, best_d)
        best_p = jnp.where(mask[:, None], cand, best_p)
    # Subtractive-dither decode, back to the caller's scale.
    out_ref[...] = (best_p - dither_ref[...]) * s


@partial(jax.jit, static_argnames=("interpret",))
def quantize_hex(hbar, dither, s, interpret=True):
    """Dithered hex-lattice quantize-and-decode of `[M, 2]` sub-vectors.

    Returns the reconstructed sub-vectors `(Q(hbar/s + z) - z) * s` — i.e.
    the decoder output *before* the ζ‖h‖ rescale. `M` must be a multiple
    of TILE for the block grid; aot.py pads.
    """
    m = hbar.shape[0]
    assert hbar.shape == (m, 2) and dither.shape == (m, 2)
    assert m % TILE == 0, f"M={m} must be a multiple of {TILE}"
    g = jnp.asarray(HEX_G)
    ginv = jnp.asarray(HEX_GINV)
    offsets = jnp.asarray(OFFSETS)
    n_off = offsets.shape[0]
    return pl.pallas_call(
        _quant_kernel,
        grid=(m // TILE,),
        in_specs=[
            pl.BlockSpec((TILE, 2), lambda i: (i, 0)),
            pl.BlockSpec((TILE, 2), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),       # scale, broadcast
            pl.BlockSpec((2, 2), lambda i: (0, 0)),   # G
            pl.BlockSpec((2, 2), lambda i: (0, 0)),   # G^-1
            pl.BlockSpec((n_off, 2), lambda i: (0, 0)),  # offset table
        ],
        out_specs=pl.BlockSpec((TILE, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 2), jnp.float32),
        interpret=interpret,
    )(hbar, dither, s, g, ginv, offsets)
