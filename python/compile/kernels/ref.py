"""Pure-jnp / numpy oracles for the Pallas kernels.

These are the CORE correctness references: `python/tests/test_kernels.py`
asserts the Pallas kernels match them across shapes and dtypes, and the
Rust coordinator's native implementations are cross-checked against the
same math through the AOT parity artifact.
"""

import jax.numpy as jnp
import numpy as np

from .lattice_quant import HEX_G, HEX_GINV, OFFSETS


def quantize_hex_ref(hbar, dither, s):
    """Reference dithered hex-lattice quantization (vectorized jnp).

    Same contract as `lattice_quant.quantize_hex`, no tiling constraint.
    """
    hbar = jnp.asarray(hbar, jnp.float32)
    dither = jnp.asarray(dither, jnp.float32)
    g = jnp.asarray(HEX_G)
    ginv = jnp.asarray(HEX_GINV)
    offsets = jnp.asarray(OFFSETS)
    y = hbar / s + dither
    l0 = jnp.round(y @ ginv.T)
    base_p = l0 @ g.T
    # Same masked min-scan arithmetic as the kernel (bit-identical fp
    # operation order), so the "matches exactly" test is meaningful.
    best_d = jnp.full(y.shape[:1], jnp.inf, y.dtype)
    best_p = base_p
    for k in range(offsets.shape[0]):
        cand = base_p + (offsets[k] @ g.T)[None, :]
        d = jnp.sum((y - cand) ** 2, axis=-1)
        mask = d < best_d
        best_d = jnp.where(mask, d, best_d)
        best_p = jnp.where(mask[:, None], cand, best_p)
    return (best_p - dither) * s


def quantize_hex_numpy(hbar, dither, s):
    """Double-precision numpy oracle with exhaustive neighbor search —
    independent of jax entirely (guards against shared bugs)."""
    g = HEX_G.astype(np.float64)
    ginv = HEX_GINV.astype(np.float64)
    y = hbar.astype(np.float64) / s + dither.astype(np.float64)
    out = np.zeros_like(y)
    r = 3  # wider than the kernel: certifies radius-2 is sufficient
    for i in range(y.shape[0]):
        l0 = np.round(ginv @ y[i])
        best, best_d = None, np.inf
        for dx in range(-r, r + 1):
            for dy in range(-r, r + 1):
                l = l0 + np.array([dx, dy])
                p = g @ l
                d = np.sum((y[i] - p) ** 2)
                if d < best_d:
                    best_d, best = d, p
        out[i] = (best - dither[i].astype(np.float64)) * s
    return out.astype(np.float32)


def dense_sigmoid_ref(x, w, b):
    """Reference for the fused dense layer: sigmoid(x @ w + b)."""
    return 1.0 / (1.0 + jnp.exp(-(x @ w + b)))
