"""L1 — Pallas kernels (build-time only; lowered into the L2 HLO graphs).

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode is both the correctness path and the
only executable lowering in this image. Real-TPU performance is estimated
structurally (VMEM footprint / op counts) in DESIGN.md §Perf.
"""
