"""Fused dense layer (matmul + bias + sigmoid) as a Pallas kernel.

Used for the hidden layer of the MNIST MLP (§V-B architecture). The fusion
expresses, at kernel level, what XLA would fuse anyway on CPU — but on TPU
it pins the schedule: x-tile and the full W panel live in VMEM, the matmul
hits the MXU in bf16-eligible shape, and the sigmoid epilogue runs on the
VPU before the result ever leaves VMEM.

Grid: 1-D over batch tiles (the paper's layer is 784×50 — W is only 157 KiB
f32, fitting VMEM whole, so only the batch dimension is tiled).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BATCH_TILE = 128


def _dense_kernel(x_ref, w_ref, b_ref, o_ref):
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    z = x @ w + b[None, :]
    o_ref[...] = 1.0 / (1.0 + jnp.exp(-z))


def _sigmoid_bwd_kernel(da_ref, a_ref, dz_ref):
    """Fused sigmoid-gradient epilogue: dz = da · a · (1 − a)."""
    a = a_ref[...]
    dz_ref[...] = da_ref[...] * a * (1.0 - a)


def _pallas_forward(x, w, b, interpret):
    n, d = x.shape
    dh = w.shape[1]
    pad = (-n) % BATCH_TILE
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, d), x.dtype)], axis=0)
    npad = x.shape[0]
    out = pl.pallas_call(
        _dense_kernel,
        grid=(npad // BATCH_TILE,),
        in_specs=[
            pl.BlockSpec((BATCH_TILE, d), lambda i: (i, 0)),
            pl.BlockSpec((d, dh), lambda i: (0, 0)),
            pl.BlockSpec((dh,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BATCH_TILE, dh), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npad, dh), jnp.float32),
        interpret=interpret,
    )(x, w, b)
    return out[:n]


def _pallas_sigmoid_bwd(da, a, interpret):
    n, dh = da.shape
    pad = (-n) % BATCH_TILE
    if pad:
        z = jnp.zeros((pad, dh), da.dtype)
        da = jnp.concatenate([da, z], axis=0)
        a = jnp.concatenate([a, z], axis=0)
    npad = da.shape[0]
    dz = pl.pallas_call(
        _sigmoid_bwd_kernel,
        grid=(npad // BATCH_TILE,),
        in_specs=[
            pl.BlockSpec((BATCH_TILE, dh), lambda i: (i, 0)),
            pl.BlockSpec((BATCH_TILE, dh), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BATCH_TILE, dh), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npad, dh), jnp.float32),
        interpret=interpret,
    )(da, a)
    return dz[:n]


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense_sigmoid(x, w, b, interpret=True):
    """`sigmoid(x @ w + b)` with batch-tiled Pallas execution.

    Pads the batch to a BATCH_TILE multiple internally; output shape
    matches the input batch. Differentiable via a custom VJP (Pallas
    interpret-mode calls have no built-in reverse rule): the backward pass
    fuses the sigmoid gradient in a second Pallas kernel and leaves the
    two transport matmuls to XLA.
    """
    return _pallas_forward(x, w, b, interpret)


def _dense_fwd(x, w, b, interpret):
    a = _pallas_forward(x, w, b, interpret)
    return a, (x, w, a)


def _dense_bwd(interpret, res, da):
    x, w, a = res
    dz = _pallas_sigmoid_bwd(da, a, interpret)
    dx = dz @ w.T
    dw = x.T @ dz
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


dense_sigmoid.defvjp(_dense_fwd, _dense_bwd)
