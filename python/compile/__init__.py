"""L2 — build-time JAX model definitions + AOT lowering for UVeQFed.

Never imported at runtime: `make artifacts` runs `python -m compile.aot`
once, producing HLO-text artifacts the Rust coordinator loads via PJRT.
"""
