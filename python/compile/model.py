"""L2 — the paper's models in JAX, calling the L1 Pallas kernels.

Two architectures (Table I):
* MNIST — fully-connected 784–50–10, sigmoid hidden (Pallas fused dense
  kernel), softmax cross-entropy, full-batch GD;
* CIFAR — the 5-layer conv net of [56]: 3 conv (5×5) + 2 FC, ReLU +
  2×2 maxpool, mini-batch SGD.

Everything operates on FLAT parameter vectors — the exact layout the Rust
coordinator quantizes (`models/mlp.rs` documents the same order).
"""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.dense import dense_sigmoid


# --------------------------------------------------------------------------
# MNIST MLP (784-H-10, sigmoid)
# --------------------------------------------------------------------------

class MlpSpec:
    def __init__(self, inp=784, hidden=50, out=10):
        self.inp, self.hidden, self.out = inp, hidden, out

    @property
    def sizes(self):
        i, h, o = self.inp, self.hidden, self.out
        return [(i * h), h, (h * o), o]

    @property
    def num_params(self):
        return sum(self.sizes)

    def unflatten(self, w):
        i, h, o = self.inp, self.hidden, self.out
        s = self.sizes
        ofs = np.cumsum([0] + s)
        w1 = w[ofs[0]:ofs[1]].reshape(i, h)
        b1 = w[ofs[1]:ofs[2]]
        w2 = w[ofs[2]:ofs[3]].reshape(h, o)
        b2 = w[ofs[3]:ofs[4]]
        return w1, b1, w2, b2

    def init(self, seed):
        """Glorot init matching rust/src/models/mlp.rs (same structure; the
        artifact init blob is authoritative for cross-language runs)."""
        i, h, o = self.inp, self.hidden, self.out
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        w1 = jax.random.normal(k1, (i, h)) * np.sqrt(2.0 / (i + h))
        w2 = jax.random.normal(k2, (h, o)) * np.sqrt(2.0 / (h + o))
        return jnp.concatenate(
            [w1.reshape(-1), jnp.zeros(h), w2.reshape(-1), jnp.zeros(o)]
        ).astype(jnp.float32)


def mlp_logits(spec: MlpSpec, w, x, *, use_pallas=True, interpret=True):
    w1, b1, w2, b2 = spec.unflatten(w)
    if use_pallas:
        a1 = dense_sigmoid(x, w1, b1, interpret=interpret)
    else:
        a1 = jax.nn.sigmoid(x @ w1 + b1)
    return a1 @ w2 + b2


def mlp_loss(spec: MlpSpec, w, x, y_onehot, **kw):
    logits = mlp_logits(spec, w, x, **kw)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


def mlp_step(spec: MlpSpec, w, x, y_onehot, lr, **kw):
    """One full-batch GD step: w ← w − lr·∇F(w). AOT entry point."""
    g = jax.grad(lambda ww: mlp_loss(spec, ww, x, y_onehot, **kw))(w)
    return (w - lr * g,)


def mlp_eval(spec: MlpSpec, w, x, **kw):
    return (mlp_logits(spec, w, x, **kw),)


# --------------------------------------------------------------------------
# CIFAR 5-layer CNN ([56]: conv32-conv32-conv64 + fc64 + fc10)
# --------------------------------------------------------------------------

class CnnSpec:
    """3 conv layers (5×5, SAME) with 2×2 maxpool after each, then
    fc(1024→64), fc(64→10). Input NCHW [n, 3, 32, 32]."""

    LAYERS = [
        ("conv", 3, 32, 5),
        ("conv", 32, 32, 5),
        ("conv", 32, 64, 5),
        ("fc", 64 * 4 * 4, 64),
        ("fc", 64, 10),
    ]

    @property
    def shapes(self):
        out = []
        for l in self.LAYERS:
            if l[0] == "conv":
                _, cin, cout, k = l
                out.append(((cout, cin, k, k), (cout,)))
            else:
                _, din, dout = l
                out.append(((din, dout), (dout,)))
        return out

    @property
    def num_params(self):
        return sum(int(np.prod(ws)) + int(np.prod(bs)) for ws, bs in self.shapes)

    def unflatten(self, w):
        parts = []
        ofs = 0
        for ws, bs in self.shapes:
            nw = int(np.prod(ws))
            nb = int(np.prod(bs))
            parts.append((w[ofs:ofs + nw].reshape(ws), w[ofs + nw:ofs + nw + nb]))
            ofs += nw + nb
        return parts

    def init(self, seed):
        key = jax.random.PRNGKey(seed)
        chunks = []
        for ws, bs in self.shapes:
            key, sub = jax.random.split(key)
            fan_in = int(np.prod(ws[1:])) if len(ws) == 4 else ws[0]
            wv = jax.random.normal(sub, ws) * np.sqrt(2.0 / fan_in)
            chunks.append(wv.reshape(-1))
            chunks.append(jnp.zeros(int(np.prod(bs))))
        return jnp.concatenate(chunks).astype(jnp.float32)


def cnn_logits(spec: CnnSpec, w, x):
    """x: [n, 3, 32, 32] NCHW."""
    parts = spec.unflatten(w)
    h = x
    for (wv, bv), layer in zip(parts, spec.LAYERS):
        if layer[0] == "conv":
            h = jax.lax.conv_general_dilated(
                h, wv, window_strides=(1, 1), padding="SAME",
                dimension_numbers=("NCHW", "OIHW", "NCHW"),
            ) + bv[None, :, None, None]
            h = jax.nn.relu(h)
            h = jax.lax.reduce_window(
                h, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
            )
        else:
            if h.ndim == 4:
                h = h.reshape(h.shape[0], -1)
            h = h @ wv + bv
            if layer[2] != 10:
                h = jax.nn.relu(h)
    return h


def cnn_loss(spec: CnnSpec, w, x, y_onehot):
    logits = cnn_logits(spec, w, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


def cnn_step(spec: CnnSpec, w, x, y_onehot, lr):
    g = jax.grad(lambda ww: cnn_loss(spec, ww, x, y_onehot))(w)
    return (w - lr * g,)


def cnn_eval(spec: CnnSpec, w, x):
    return (cnn_logits(spec, w, x),)


# --------------------------------------------------------------------------
# Entry-point factories used by aot.py (fixed shapes per artifact)
# --------------------------------------------------------------------------

def mnist_entry_points(hidden=50, step_batches=(500, 1000), eval_batch=500,
                       use_pallas=True):
    spec = MlpSpec(hidden=hidden)
    kw = dict(use_pallas=use_pallas, interpret=True)
    entries = []
    for b in step_batches:
        fn = partial(mlp_step, spec, **kw)
        args = (
            jax.ShapeDtypeStruct((spec.num_params,), jnp.float32),
            jax.ShapeDtypeStruct((b, spec.inp), jnp.float32),
            jax.ShapeDtypeStruct((b, spec.out), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
        )
        meta = dict(kind="step", model="mnist", batch=b,
                    features=spec.inp, classes=spec.out, params=spec.num_params)
        entries.append((f"mnist_step_b{b}", fn, args, meta))
    fn = partial(mlp_eval, spec, **kw)
    args = (
        jax.ShapeDtypeStruct((spec.num_params,), jnp.float32),
        jax.ShapeDtypeStruct((eval_batch, spec.inp), jnp.float32),
    )
    meta = dict(kind="eval", model="mnist", batch=eval_batch,
                features=spec.inp, classes=spec.out, params=spec.num_params)
    entries.append(("mnist_eval", fn, args, meta))
    return spec, entries


def cifar_entry_points(step_batch=60, eval_batch=200):
    spec = CnnSpec()
    entries = []
    fn = partial(cnn_step, spec)
    args = (
        jax.ShapeDtypeStruct((spec.num_params,), jnp.float32),
        jax.ShapeDtypeStruct((step_batch, 3, 32, 32), jnp.float32),
        jax.ShapeDtypeStruct((step_batch, 10), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    meta = dict(kind="step", model="cifar", batch=step_batch,
                features=3 * 32 * 32, classes=10, params=spec.num_params)
    entries.append((f"cifar_step_b{step_batch}", fn, args, meta))
    fn = partial(cnn_eval, spec)
    args = (
        jax.ShapeDtypeStruct((spec.num_params,), jnp.float32),
        jax.ShapeDtypeStruct((eval_batch, 3, 32, 32), jnp.float32),
    )
    meta = dict(kind="eval", model="cifar", batch=eval_batch,
                features=3 * 32 * 32, classes=10, params=spec.num_params)
    entries.append(("cifar_eval", fn, args, meta))
    return spec, entries
