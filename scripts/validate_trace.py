#!/usr/bin/env python3
"""Schema + reconciliation validator for uveqfed JSONL traces (schema 1).

Usage: validate_trace.py TRACE.jsonl

Checks, exiting non-zero on the first violation:

* line 1 is the meta line (``type: meta``, ``schema: 1``,
  ``source: uveqfed-trace``); every later line is a ``span`` or ``round``
  object that parses as JSON;
* every span has a known ``kind``, integer ``round``, ``user`` (integer,
  or null only for the round-scoped ``rate_alloc`` / ``shard_fold``
  kinds), numeric ``wall_start_s`` / ``wall_dur_s`` / ``virt_s`` and the
  per-kind ``data`` fields;
* per (round, user): a ``fold`` span implies the full lifecycle
  (``client_train``, ``encode``, ``transmit``, ``decode``) is present,
  and every encode — uplink ``encode`` and downlink ``broadcast`` alike —
  satisfies ``achieved_bits <= assigned_bits``;
* per round line: the aggregates reconcile exactly with the span lines of
  that round (clients / aggregated / rejected counts; assigned, achieved,
  uplink and wire sums — rejected transmits cost wire bytes but are never
  metered as uplink bits; ``solver_iters`` equal to the sum over the
  round's accepted decode spans — a budget-rejected decode records no
  decode span, so its burned iterations never count; alpha_sum within
  1e-9 of the fold-span sum);
* the hostile-wire machinery reconciles two ways: the round line's
  ``retries`` equals the ``retry``-span count and ``quarantined`` equals
  the ``reject``-span count; every retry/reject span carries a non-empty
  reason and an attempt count ≥ 1 (a clean round must report zero for
  both and own no such spans);
* the downlink reconciles two ways: the round line's ``downlink_bytes`` /
  ``downlink_bits`` / ``resyncs`` equal the sums over that round's
  ``broadcast`` + ``stale_sync`` spans, and every downlink span lands in
  a round whose line carries matching totals (a downlink-off round must
  report all-zero downlink fields and own no downlink spans);
* per (round, shard): at most one ``shard_fold`` span, the round line's
  ``shards`` field equals the shard-span count, and the per-shard
  folds / chunks / entries totals reconcile exactly — in both directions —
  with the shard-tagged client ``fold`` spans, with the shard fold total
  equal to the round's ``aggregated`` count.
"""

import json
import sys

SCHEMA = 1
SPAN_FIELDS = ("kind", "round", "user", "wall_start_s", "wall_dur_s", "virt_s", "data")
DATA_FIELDS = {
    "client_train": ("local_steps", "m"),
    "encode": (
        "assigned_bits",
        "achieved_bits",
        "chunks",
        "scale_probes_est",
        "scale_probes_exact",
        "symbols",
        "escapes",
    ),
    "transmit": ("wire_bytes", "payload_bits", "accepted"),
    "decode": ("chunks", "entries", "shard", "solver_iters"),
    "fold": ("chunks", "entries", "alpha", "shard"),
    "rate_alloc": ("clients", "capacity_mass", "assigned_mass"),
    "shard_fold": ("shard", "folds", "chunks", "entries", "decode_secs", "fold_secs"),
    "broadcast": ("assigned_bits", "achieved_bits", "wire_bytes", "ref_round"),
    "stale_sync": ("staleness", "bits", "wire_bytes"),
    "retry": ("attempt", "wire_bytes", "reason"),
    "reject": ("attempts", "reason"),
}
ROUND_SCOPED = ("rate_alloc", "shard_fold")
LIFECYCLE = ("client_train", "encode", "transmit", "decode", "fold")


def fail(lineno, msg):
    print(f"validate_trace: line {lineno}: {msg}", file=sys.stderr)
    sys.exit(1)


def require(cond, lineno, msg):
    if not cond:
        fail(lineno, msg)


def blank_round_tally():
    return {
        "clients": 0,
        "aggregated": 0,
        "rejected": 0,
        "retries": 0,
        "quarantined": 0,
        "assigned_bits": 0,
        "achieved_bits": 0,
        "uplink_bits": 0,
        "wire_bytes": 0,
        "solver_iters": 0,
        "alpha_sum": 0.0,
        "downlink_bytes": 0,
        "downlink_bits": 0,
        "resyncs": 0,
        "kinds_by_user": {},
        "fold_by_shard": {},
        "shard_lines": {},
    }


def check_span(obj, lineno, tally):
    for field in SPAN_FIELDS:
        require(field in obj, lineno, f"span missing field '{field}'")
    kind = obj["kind"]
    require(kind in DATA_FIELDS, lineno, f"unknown span kind '{kind}'")
    user = obj["user"]
    if user is None:
        require(kind in ROUND_SCOPED, lineno, f"null user on non-round-scoped '{kind}' span")
    else:
        require(user == int(user) >= 0, lineno, f"bad user {user!r}")
    for field in ("wall_start_s", "wall_dur_s", "virt_s"):
        v = obj[field]
        require(isinstance(v, (int, float)) and v >= 0, lineno, f"bad {field}: {v!r}")
    data = obj["data"]
    for field in DATA_FIELDS[kind]:
        require(field in data, lineno, f"'{kind}' data missing '{field}'")

    r = tally.setdefault(obj["round"], blank_round_tally())
    if user is not None:
        r["kinds_by_user"].setdefault(user, set()).add(kind)
    if kind == "client_train":
        r["clients"] += 1
    elif kind == "encode":
        require(
            data["achieved_bits"] <= data["assigned_bits"],
            lineno,
            f"user {user}: achieved {data['achieved_bits']} > assigned {data['assigned_bits']}",
        )
        r["assigned_bits"] += data["assigned_bits"]
        r["achieved_bits"] += data["achieved_bits"]
    elif kind == "transmit":
        r["wire_bytes"] += data["wire_bytes"]
        if data["accepted"]:
            r["uplink_bits"] += data["payload_bits"]
        else:
            r["rejected"] += 1
    elif kind == "decode":
        require(
            data["solver_iters"] >= 0,
            lineno,
            f"user {user}: negative solver_iters {data['solver_iters']}",
        )
        r["solver_iters"] += data["solver_iters"]
    elif kind == "fold":
        r["aggregated"] += 1
        r["alpha_sum"] += data["alpha"]
        by = r["fold_by_shard"].setdefault(
            data["shard"], {"folds": 0, "chunks": 0, "entries": 0}
        )
        by["folds"] += 1
        by["chunks"] += data["chunks"]
        by["entries"] += data["entries"]
    elif kind == "broadcast":
        require(
            data["achieved_bits"] <= data["assigned_bits"],
            lineno,
            f"user {user}: broadcast achieved {data['achieved_bits']} > "
            f"assigned {data['assigned_bits']}",
        )
        require(
            data["ref_round"] <= obj["round"],
            lineno,
            f"user {user}: broadcast references future round {data['ref_round']}",
        )
        r["downlink_bytes"] += data["wire_bytes"]
        r["downlink_bits"] += data["achieved_bits"]
    elif kind == "stale_sync":
        require(data["staleness"] > 0, lineno, f"user {user}: resync with zero staleness")
        r["downlink_bytes"] += data["wire_bytes"]
        r["downlink_bits"] += data["bits"]
        r["resyncs"] += 1
    elif kind == "retry":
        require(
            data["attempt"] >= 1,
            lineno,
            f"user {user}: retry span with attempt {data['attempt']}",
        )
        require(
            isinstance(data["reason"], str) and data["reason"],
            lineno,
            f"user {user}: retry span with empty reason",
        )
        r["retries"] += 1
    elif kind == "reject":
        require(
            data["attempts"] >= 1,
            lineno,
            f"user {user}: reject span with {data['attempts']} attempts",
        )
        require(
            isinstance(data["reason"], str) and data["reason"],
            lineno,
            f"user {user}: reject span with empty reason",
        )
        r["quarantined"] += 1
    elif kind == "shard_fold":
        shard = data["shard"]
        require(
            shard not in r["shard_lines"],
            lineno,
            f"duplicate shard_fold span for shard {shard}",
        )
        r["shard_lines"][shard] = {
            "folds": data["folds"],
            "chunks": data["chunks"],
            "entries": data["entries"],
        }


def check_round_line(obj, lineno, tally):
    rnd = obj["round"]
    require(rnd in tally, lineno, f"round line {rnd} has no preceding spans")
    r = tally[rnd]
    for field in (
        "clients",
        "aggregated",
        "rejected",
        "retries",
        "quarantined",
        "assigned_bits",
        "achieved_bits",
        "uplink_bits",
        "wire_bytes",
        "solver_iters",
        "downlink_bytes",
        "downlink_bits",
        "resyncs",
    ):
        require(field in obj, lineno, f"round line missing '{field}'")
        require(
            obj[field] == r[field],
            lineno,
            f"round {rnd}: {field} = {obj[field]} but spans sum to {r[field]}",
        )
    require("dropped_events" in obj, lineno, "round line missing 'dropped_events'")
    require("shards" in obj, lineno, "round line missing 'shards'")
    require(
        obj["shards"] == len(r["shard_lines"]),
        lineno,
        f"round {rnd}: shards = {obj['shards']} but {len(r['shard_lines'])} shard_fold spans",
    )
    # Two-way reconciliation: every shard that client fold spans name must
    # have a shard_fold span with the same totals, and every shard_fold
    # span claiming work must be backed by client fold spans.
    for shard, got in sorted(r["fold_by_shard"].items()):
        require(
            shard in r["shard_lines"],
            lineno,
            f"round {rnd}: client folds name shard {shard} but no shard_fold span",
        )
        require(
            r["shard_lines"][shard] == got,
            lineno,
            f"round {rnd} shard {shard}: shard_fold {r['shard_lines'][shard]} "
            f"!= client-fold sums {got}",
        )
    for shard, claimed in sorted(r["shard_lines"].items()):
        require(
            claimed["folds"] == 0 or shard in r["fold_by_shard"],
            lineno,
            f"round {rnd} shard {shard}: claims {claimed['folds']} folds, no client spans",
        )
    require(
        sum(s["folds"] for s in r["shard_lines"].values()) == r["aggregated"],
        lineno,
        f"round {rnd}: shard folds don't partition the {r['aggregated']} aggregated clients",
    )
    require(
        abs(obj["alpha_sum"] - r["alpha_sum"]) < 1e-9,
        lineno,
        f"round {rnd}: alpha_sum {obj['alpha_sum']} != fold-span sum {r['alpha_sum']}",
    )
    for user, kinds in sorted(r["kinds_by_user"].items()):
        if "fold" in kinds:
            missing = [k for k in LIFECYCLE if k not in kinds]
            require(
                not missing,
                lineno,
                f"round {rnd} user {user}: folded but missing spans {missing}",
            )


def main(path):
    tally = {}
    spans = rounds = 0
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, start=1):
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
            except json.JSONDecodeError as e:
                fail(lineno, f"not valid JSON: {e}")
            if lineno == 1:
                require(obj.get("type") == "meta", 1, "first line must be the meta line")
                require(obj.get("schema") == SCHEMA, 1, f"schema {obj.get('schema')} != {SCHEMA}")
                require(obj.get("source") == "uveqfed-trace", 1, "bad meta source")
                continue
            kind = obj.get("type")
            if kind == "span":
                spans += 1
                check_span(obj, lineno, tally)
            elif kind == "round":
                rounds += 1
                check_round_line(obj, lineno, tally)
            else:
                fail(lineno, f"unknown line type {kind!r}")
    if spans == 0 or rounds == 0:
        print(f"validate_trace: {path}: empty trace ({spans} spans, {rounds} rounds)",
              file=sys.stderr)
        sys.exit(1)
    folded = sum(r["aggregated"] for r in tally.values())
    print(f"validate_trace: OK — {spans} spans, {rounds} round(s), {folded} folds, "
          f"{len(tally)} round group(s)")


if __name__ == "__main__":
    if len(sys.argv) != 2:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    main(sys.argv[1])
