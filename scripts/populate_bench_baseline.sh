#!/usr/bin/env bash
# Populate BENCH_baseline.json with real measured numbers (DESIGN.md §9.4).
#
# Labels:
#   post-pr4 — the three perf benches on the CURRENT tree (always runs);
#   pre-<n>  — optionally, the same benches at an earlier ref that already
#              contains the Recorder harness (PRE_REF=<ref> env var).
#
# NOTE on the PR-4 comparison specifically: the Recorder harness was
# introduced BY the hot-path-overhaul commit, so its parent cannot record
# snapshots at all — there is no mechanical pre-pr4 leg. That comparison
# is instead self-contained in every post-pr4 run: `lattice_micro`
# measures the legacy per-block path (nearest-scalar/*) next to the
# batched kernels (nearest-batch/*). PRE_REF exists for FUTURE perf PRs,
# where both refs carry the harness.
#
# Run from the workspace root on a quiet machine:
#
#   [PRE_REF=<ref>] scripts/populate_bench_baseline.sh
#
# Never run these with --smoke / BENCH_QUICK=1: smoke numbers are not a
# perf trajectory, and Recorder refuses to overwrite real snapshots with
# smoke ones anyway.
set -euo pipefail

if ! command -v cargo >/dev/null; then
    echo "error: cargo not found — this procedure needs the Rust toolchain" >&2
    exit 1
fi
if [ -n "$(git status --porcelain)" ]; then
    echo "error: working tree is dirty; commit or stash first" >&2
    exit 1
fi

# Work against a temp copy so checking out refs that also track
# BENCH_baseline.json can neither clobber fresh snapshots nor abort the
# checkout on a dirty tracked file; merged back at the end.
BASELINE_FINAL="$(pwd)/BENCH_baseline.json"
BASELINE="$(mktemp --suffix=.json)"
cp "$BASELINE_FINAL" "$BASELINE" 2>/dev/null || true
# On a detached HEAD `--abbrev-ref` would be the literal string "HEAD";
# pin the branch name when there is one, the commit sha otherwise, and
# always restore it — even when a bench run fails mid-way.
CUR_REF="$(git symbolic-ref --quiet --short HEAD || git rev-parse HEAD)"
trap 'git checkout --quiet "$CUR_REF"' EXIT
BENCHES=(lattice_micro codec_micro fleet_scale)

run_label() {
    local label="$1"
    for b in "${BENCHES[@]}"; do
        UVEQFED_BENCH_LABEL="$label" UVEQFED_BENCH_BASELINE="$BASELINE" \
            cargo bench --bench "$b"
    done
}

if [ -n "${PRE_REF:-}" ]; then
    echo "== pre run at $PRE_REF"
    git checkout --quiet "$PRE_REF"
    if grep -q "pub struct Recorder" rust/src/bench/mod.rs 2>/dev/null; then
        run_label "pre-$(git rev-parse --short "$PRE_REF")"
    else
        echo "error: $PRE_REF has no Recorder harness — it cannot record" >&2
        echo "       snapshots (see the header note about the PR-4 case)" >&2
        exit 1
    fi
    git checkout --quiet "$CUR_REF"
fi

echo "== post-pr4 run at $CUR_REF"
run_label post-pr4

cp "$BASELINE" "$BASELINE_FINAL"
echo "baseline written to $BASELINE_FINAL:"
python3 -c "import json; d=json.load(open('$BASELINE_FINAL')); print(*[(s['label'], s['bench'], len(s['entries'])) for s in d['snapshots']], sep='\n')"
