//! Codec micro-benchmarks: encode/decode throughput for every update
//! codec at R ∈ {2, 4} on a 39,760-entry update (the MNIST MLP size).
//! This is the §Perf L3 hot-path baseline; the UVeQFed encode rows are
//! the acceptance gauge for the single-pass scale search + batched
//! lattice kernels + table-driven range coder.
//!
//! Results merge into `BENCH_baseline.json` (label via
//! `UVEQFED_BENCH_LABEL`, so a pre/post comparison is two runs of the two
//! builds with different labels); `--smoke` shrinks the update for CI.

use uveqfed::bench::{run, smoke_mode, BenchConfig, Recorder};
use uveqfed::prng::{Normal, Xoshiro256pp};
use uveqfed::quantizer::{self, CodecContext};

fn main() {
    let cfg = BenchConfig::from_env();
    let m = if smoke_mode() { 4_096usize } else { 39_760 };
    let mut rng = Xoshiro256pp::seed_from_u64(1);
    let h = Normal::new(0.0, 0.02).vec_f32(&mut rng, m);
    let mb = m as f64 * 4.0 / 1e6;
    let mut rec = Recorder::new("codec_micro");

    println!("# codec_micro — {m}-entry update ({mb:.2} MB f32)");
    for name in [
        "uveqfed-l1",
        "uveqfed-l2",
        "uveqfed-l4",
        "uveqfed-l8",
        "qsgd",
        "rotation",
        "subsample",
        "terngrad",
        "signsgd",
        "topk",
    ] {
        for rate in [2.0, 4.0] {
            let codec = quantizer::make(name).expect("codec spec");
            let ctx = CodecContext::new(0, 0, 5, rate);
            // warm the rate-controller hint before timing
            let enc0 = codec.encode(&h, &ctx);
            let r = run(&format!("encode/{name}/r{rate}"), cfg, || {
                let ctx = CodecContext::new(0, 0, 5, rate);
                std::hint::black_box(codec.encode(&h, &ctx));
            });
            rec.add_with_items(&r, m as f64);
            println!(
                "    ↳ {:.1} MB/s encode, {:.3} bits/entry realized",
                mb / r.median_secs,
                enc0.bits as f64 / m as f64
            );
            let r = run(&format!("decode/{name}/r{rate}"), cfg, || {
                let ctx = CodecContext::new(0, 0, 5, rate);
                std::hint::black_box(codec.decode(&enc0, m, &ctx));
            });
            rec.add_with_items(&r, m as f64);
            println!("    ↳ {:.1} MB/s decode", mb / r.median_secs);
        }
    }
    rec.save_or_warn();
}
