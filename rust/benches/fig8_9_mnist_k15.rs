//! Figs. 8–9 regenerator benchmark: MNIST K=15, i.i.d. vs sequential
//! heterogeneous splits, R ∈ {2, 4}. Emits CSVs; checks heterogeneity
//! degrades accuracy and UVeQFed stays competitive.

use uveqfed::bench::{run, BenchConfig};
use uveqfed::data::{partition, PartitionScheme, SynthMnist};
use uveqfed::fl::{run_federated, FlConfig, LrSchedule, NativeTrainer};
use uveqfed::metrics::CsvTable;
use uveqfed::models::MlpMnist;
use uveqfed::quantizer;

fn main() {
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let full = std::env::var("UVEQFED_FULL").map(|v| v == "1").unwrap_or(false);
    let (n_per_user, rounds) = if full { (1000, 200) } else if quick { (100, 25) } else { (200, 60) };
    let k = 15;
    let cfg_bench = BenchConfig { warmup_iters: 0, measure_iters: 1, max_secs: 1800.0 };

    let gen = SynthMnist::new(8);
    let ds = gen.dataset(k * n_per_user);
    let test = gen.test_dataset(500);
    let trainer = NativeTrainer::new(MlpMnist::new(50));

    for rate in [2.0f64, 4.0] {
        let fig = if rate == 2.0 { 8 } else { 9 };
        let mut summary: Vec<(String, f64)> = Vec::new();
        for (split, scheme) in
            [("iid", PartitionScheme::Iid), ("het", PartitionScheme::Sequential)]
        {
            let shards = partition(&ds, k, n_per_user, scheme, 8);
            let mut header = vec!["eval_idx".to_string()];
            let mut curves: Vec<Vec<f64>> = Vec::new();
            for name in ["uveqfed-l2", "uveqfed-l1", "qsgd", "identity"] {
                let codec = quantizer::make(name).expect("codec spec");
                let cfg = FlConfig {
                    users: k,
                    rounds,
                    local_steps: 1,
                    batch_size: 0,
                    lr: LrSchedule::Const(0.5),
                    rate,
                    seed: 8,
                    workers: 8,
                    eval_every: (rounds / 20).max(1),
                    verbose: false,
                    fleet: uveqfed::fleet::Scenario::full(),
                    channel: None,
                };
                let mut best = 0.0;
                let mut curve = Vec::new();
                run(&format!("fig{fig}/{split}/{name}"), cfg_bench, || {
                    let h = run_federated(&cfg, &trainer, &shards, &test, codec.as_ref());
                    best = h.best_accuracy();
                    curve = h.rows.iter().map(|r| r.test_accuracy).collect();
                });
                println!("    ↳ best accuracy {best:.4}");
                summary.push((format!("{split}/{name}"), best));
                header.push(format!("acc_{name}"));
                curves.push(curve);
            }
            let mut t =
                CsvTable::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
            for i in 0..curves[0].len() {
                let mut row = vec![i as f64];
                for c in &curves {
                    row.push(c.get(i).copied().unwrap_or(f64::NAN));
                }
                t.push(row);
            }
            header.truncate(1);
            let path = uveqfed::bench::results_dir()
                .join(format!("fig{fig}_mnist_k15_r{rate}_{split}.csv"));
            t.write_file(&path).expect("write");
            println!("→ {}", path.display());
        }
        // Shape: het ≤ iid for UVeQFed (the paper's observation).
        let get = |key: &str| summary.iter().find(|(k, _)| k == key).unwrap().1;
        let iid = get("iid/uveqfed-l2");
        let het = get("het/uveqfed-l2");
        assert!(
            het <= iid + 0.03,
            "fig{fig}: heterogeneous ({het}) should not beat iid ({iid})"
        );
        println!("shape check fig{fig}: het ≤ iid for UVeQFed ✓");
    }
}
