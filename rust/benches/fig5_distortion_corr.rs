//! Fig. 5 regenerator benchmark: distortion vs rate on **correlated**
//! data ΣHΣᵀ with Σ_ij = e^{−0.2|i−j|} — emits the figure CSV and checks
//! the vector-quantization gain grows versus the i.i.d. case.

use uveqfed::bench::{run, BenchConfig};
use uveqfed::data::{correlated_matrix, exp_decay_sigma, gaussian_matrix};
use uveqfed::metrics::CsvTable;
use uveqfed::quantizer::{self, measure_distortion};

fn main() {
    let cfg = BenchConfig { warmup_iters: 0, measure_iters: 1, max_secs: 600.0 };
    let trials = if std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false) {
        5
    } else {
        25
    };
    let codecs = ["uveqfed-l2", "uveqfed-l1", "qsgd", "rotation", "subsample"];
    let mut header = vec!["rate"];
    header.extend(codecs);
    let mut table = CsvTable::new(&header);
    let sigma = exp_decay_sigma(128, 0.2);

    run("fig5/full-sweep", cfg, || {
        table.rows.clear();
        for rate in 1..=6 {
            let mut row = vec![rate as f64];
            for name in &codecs {
                let codec = quantizer::make(name).expect("codec spec");
                let mut mse = 0.0;
                for t in 0..trials {
                    let h0 = gaussian_matrix(128, 5000 + t as u64);
                    let h = correlated_matrix(&h0, &sigma, 128);
                    mse += measure_distortion(codec.as_ref(), &h, rate as f64, 3, t as u64)
                        .mse
                        / trials as f64;
                }
                row.push(mse);
            }
            table.push(row);
        }
    });
    let path = uveqfed::bench::results_dir().join("fig5_distortion_corr.csv");
    table.write_file(&path).expect("write");
    println!("{}", table.to_pretty());
    println!("→ {}", path.display());
    for row in &table.rows {
        // R=1 sits below the adaptive coder's per-symbol floor for L=2
        // sub-vectors (EXPERIMENTS.md §V-A); the vector gain is asserted
        // from R=2 upward, where the paper's comparison lives.
        if row[0] >= 2.0 {
            assert!(
                row[1] < row[2],
                "vector (L=2) must beat scalar (L=1) on correlated data at R={}",
                row[0]
            );
        }
    }
    println!("shape check: L=2 < L=1 on correlated data at every rate ≥ 2 ✓");
}
