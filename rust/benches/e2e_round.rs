//! End-to-end federated round benchmark: isolates coordinator cost
//! (fan-out + codec + uplink + aggregation) from model compute, and
//! measures the full round with the real MLP — the §Perf L3 target
//! ("the coordinator must never dominate a round").

use uveqfed::bench::{run, BenchConfig};
use uveqfed::coordinator::{RoundDriver, RoundSpec};
use uveqfed::data::{partition, Dataset, PartitionScheme, SynthMnist};
use uveqfed::fl::{NativeTrainer, Trainer};
use uveqfed::fleet::ClientRecords;
use uveqfed::models::{EvalReport, MlpMnist};
use uveqfed::quantizer;

/// Trainer that does no compute: isolates coordinator + codec cost.
struct NoopTrainer {
    m: usize,
}

impl Trainer for NoopTrainer {
    fn num_params(&self) -> usize {
        self.m
    }
    fn init_params(&self, seed: u64) -> Vec<f32> {
        use uveqfed::prng::{Normal, Xoshiro256pp};
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        Normal::new(0.0, 0.02).vec_f32(&mut rng, self.m)
    }
    fn local_update(
        &self,
        w0: &[f32],
        _shard: &Dataset,
        _tau: usize,
        lr: f32,
        _batch: usize,
        seed: u64,
    ) -> Vec<f32> {
        // pretend-update: deterministic pseudo-gradient
        use uveqfed::prng::{Normal, Xoshiro256pp};
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let g = Normal::new(0.0, 0.01).vec_f32(&mut rng, self.m);
        w0.iter().zip(g).map(|(&w, gv)| w - lr * gv).collect()
    }
    fn evaluate(&self, _w: &[f32], _ds: &Dataset) -> EvalReport {
        EvalReport { loss: 0.0, accuracy: 0.0 }
    }
}

fn main() {
    let cfg = BenchConfig::from_env();
    let k = 10usize;
    let m = 39_760usize;
    let gen = SynthMnist::new(1);
    let ds = gen.dataset(k * 100);
    let shards = partition(&ds, k, 100, PartitionScheme::Iid, 1);
    let alphas = vec![1.0 / k as f64; k];

    println!("# e2e_round — K={k}, m={m}");
    for name in ["uveqfed-l2", "qsgd", "identity"] {
        let codec = quantizer::make(name).expect("codec spec");
        // Coordinator-only (noop trainer).
        let noop = NoopTrainer { m };
        let mut w = noop.init_params(1);
        let driver = RoundDriver::new(1, 2.0, 8);
        let mut round = 0u64;
        let r = run(&format!("round-coordinator-only/{name}"), cfg, || {
            let spec = RoundSpec {
                round,
                local_steps: 1,
                lr: 0.1,
                batch_size: 0,
                trainer: &noop,
                codec: codec.as_ref(),
                rate_override: None,
                telemetry: None,
                client_records: ClientRecords::Full,
            };
            driver.run_round(&spec, &mut w, &shards, &alphas);
            round += 1;
        });
        println!(
            "    ↳ {:.2} ms/round coordinator+codec ({:.1} MB/s codec throughput)",
            r.median_secs * 1e3,
            k as f64 * m as f64 * 4.0 / 1e6 / r.median_secs
        );
    }
    // Full round with real model compute.
    let trainer = NativeTrainer::new(MlpMnist::new(50));
    let codec = quantizer::make("uveqfed-l2").expect("codec spec");
    let mut w = trainer.init_params(1);
    let driver = RoundDriver::new(1, 2.0, 8);
    let mut round = 0u64;
    let r = run("round-full-mlp/uveqfed-l2", cfg, || {
        let spec = RoundSpec {
            round,
            local_steps: 1,
            lr: 0.1,
            batch_size: 0,
            trainer: &trainer,
            codec: codec.as_ref(),
            rate_override: None,
            telemetry: None,
            client_records: ClientRecords::Full,
        };
        driver.run_round(&spec, &mut w, &shards, &alphas);
        round += 1;
    });
    println!("    ↳ {:.2} ms/round with MLP local training", r.median_secs * 1e3);
}
