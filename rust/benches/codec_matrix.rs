//! Codec comparison matrix: UVeQFed (lattice VQ) vs FedVQCS
//! (sketch + top-k + lattice VQ, IHT reconstruction) vs QSGD, across the
//! heterogeneous-channel presets the rate controller supports.
//!
//! Two sections:
//!   A. rate–distortion roundtrips on a synthetic Gaussian update — the
//!      per-codec mse / realized-rate trade at R ∈ {2, 4};
//!   B. end-to-end fleet rounds under each channel preset (uniform,
//!      tiers, lognormal, markov) with the theory-guided rate controller
//!      assigning per-client budgets — wall time per round plus the
//!      aggregate-distortion and uplink-bit figures the round report
//!      already carries.
//!
//! Timings merge into `BENCH_baseline.json` via [`Recorder`]; the
//! distortion/bit figures ride the printed report (they are comparisons,
//! not perf trajectories). `--smoke` shrinks sizes and swaps fedvqcs to a
//! cheap solver configuration so CI can execute every cell.

use uveqfed::bench::{run, smoke_mode, BenchConfig, Recorder};
use uveqfed::coordinator::rate_control::TheoryGuided;
use uveqfed::data::{gaussian_matrix, partition, PartitionScheme, SynthMnist};
use uveqfed::fl::{NativeTrainer, Trainer};
use uveqfed::fleet::{
    Channel, ChannelModel, FleetDriver, RatePlan, RoundSpec, Scenario, ShardPool, VirtualClock,
};
use uveqfed::models::LogReg;
use uveqfed::quantizer::{self, measure_distortion};

/// Registry base name of a codec spec (`"fedvqcs:ratio=…"` → `"fedvqcs"`).
fn short(name: &str) -> &str {
    name.split(':').next().unwrap_or(name)
}

fn main() {
    let cfg = BenchConfig::from_env();
    let smoke = smoke_mode();
    let mut rec = Recorder::new("codec_matrix");

    // The sketch matrix is d×m (regenerated, never stored on the wire,
    // but materialized per decode), so the solver configuration scales
    // with the update size under test.
    let fedvqcs = if smoke {
        "fedvqcs:ratio=0.01,sparsity=0.05,solver_iters=5"
    } else {
        "fedvqcs:ratio=0.05,sparsity=0.05,solver_iters=20"
    };
    let codecs = ["uveqfed-l2", fedvqcs, "qsgd"];

    // ── A. rate–distortion on a synthetic Gaussian update ──────────────
    let h = gaussian_matrix(if smoke { 32 } else { 64 }, 5);
    let m = h.len();
    println!("# codec_matrix — A: rate–distortion, {m}-entry update");
    for name in codecs {
        for rate in [2.0f64, 4.0] {
            let probe = quantizer::make(name).expect("codec spec");
            let d = measure_distortion(probe.as_ref(), &h, rate, 7, 0);
            let r = run(&format!("roundtrip/{}/r{rate}", short(name)), cfg, || {
                // Fresh instance per iteration: warm-start hints must not
                // leak between timed encodes.
                let codec = quantizer::make(name).expect("codec spec");
                std::hint::black_box(measure_distortion(codec.as_ref(), &h, rate, 7, 0));
            });
            rec.add_with_items(&r, m as f64);
            println!(
                "    ↳ mse {:.4e}, {:.3} bits/entry realized",
                d.mse, d.bits_per_entry
            );
        }
    }

    // ── B. fleet rounds across heterogeneous-channel presets ───────────
    let presets = ["uniform", "tiers", "lognormal", "markov"];
    let (k, per, rounds) = if smoke { (6usize, 10usize, 1u64) } else { (12, 20, 2) };
    let gen = SynthMnist::new(11);
    let ds = gen.dataset(k * per);
    let shards = partition(&ds, k, per, PartitionScheme::Iid, 11);
    let trainer = NativeTrainer::new(LogReg::new(ds.features, ds.classes, 1e-3));
    let pool = ShardPool::new(&shards);
    println!("# codec_matrix — B: {k}-client fleet, {rounds} round(s) per preset");
    for name in codecs {
        for preset in presets {
            let codec = quantizer::make(name).expect("codec spec");
            let run_fleet = || {
                let plan = RatePlan::new(
                    Channel::new(ChannelModel::by_name(preset, 2.0).unwrap(), 9),
                    Box::new(TheoryGuided),
                );
                let driver =
                    FleetDriver::new(9, 2.0, 2, Scenario::full()).with_rate_plan(plan);
                let mut clock = VirtualClock::new();
                let mut w = trainer.init_params(3);
                let mut last = None;
                for round in 0..rounds {
                    let spec = RoundSpec::new(round, 1, 0.5, 0, &trainer, codec.as_ref());
                    last = Some(driver.run_round(&spec, &mut w, &pool, &mut clock));
                }
                last.expect("at least one round")
            };
            let rep = run_fleet(); // warm + the comparison figures
            let r = run(&format!("fleet/{}/{preset}", short(name)), cfg, || {
                std::hint::black_box(run_fleet());
            });
            rec.add_with_items(&r, rounds as f64 * rep.aggregated as f64);
            println!(
                "    ↳ {} folded, aggregate distortion {:.4e}, {} uplink bits",
                rep.aggregated, rep.aggregate_distortion, rep.uplink_bits
            );
        }
    }
    rec.save_or_warn();
}
