//! Figs. 6–7 regenerator benchmark: MNIST convergence at K=100 (reduced
//! to K=20 here unless UVEQFED_FULL=1; BENCH_QUICK=1 shrinks further).
//! Emits the accuracy-vs-round CSVs and checks the headline ordering:
//! UVeQFed L=2 converges at least as well as QSGD at both rates.

use uveqfed::bench::{run, BenchConfig};
use uveqfed::data::{partition, PartitionScheme, SynthMnist};
use uveqfed::fl::{run_federated, FlConfig, LrSchedule, NativeTrainer};
use uveqfed::metrics::CsvTable;
use uveqfed::models::MlpMnist;
use uveqfed::quantizer;

fn main() {
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let full = std::env::var("UVEQFED_FULL").map(|v| v == "1").unwrap_or(false);
    let (k, n_per_user, rounds) = if full {
        (100, 500, 200)
    } else if quick {
        (8, 100, 25)
    } else {
        (20, 200, 60)
    };
    let cfg_bench = BenchConfig { warmup_iters: 0, measure_iters: 1, max_secs: 1800.0 };

    let gen = SynthMnist::new(6);
    let ds = gen.dataset(k * n_per_user);
    let test = gen.test_dataset(500);
    let shards = partition(&ds, k, n_per_user, PartitionScheme::Iid, 6);
    let trainer = NativeTrainer::new(MlpMnist::new(50));

    for rate in [2.0f64, 4.0] {
        let fig = if rate == 2.0 { 6 } else { 7 };
        let mut results: Vec<(&str, f64, Vec<f64>)> = Vec::new();
        for name in ["uveqfed-l2", "uveqfed-l1", "qsgd", "subsample", "identity"] {
            let codec = quantizer::make(name).expect("codec spec");
            let cfg = FlConfig {
                users: k,
                rounds,
                local_steps: 1,
                batch_size: 0,
                lr: LrSchedule::Const(0.5),
                rate,
                seed: 6,
                workers: 8,
                eval_every: (rounds / 20).max(1),
                verbose: false,
                fleet: uveqfed::fleet::Scenario::full(),
                channel: None,
            };
            let mut best = 0.0;
            let mut curve = Vec::new();
            run(&format!("fig{fig}/{name}"), cfg_bench, || {
                let h = run_federated(&cfg, &trainer, &shards, &test, codec.as_ref());
                best = h.best_accuracy();
                curve = h.rows.iter().map(|r| r.test_accuracy).collect();
            });
            println!("    ↳ best accuracy {best:.4}");
            results.push((name, best, curve));
        }
        // CSV
        let mut header = vec!["eval_idx".to_string()];
        header.extend(results.iter().map(|(n, _, _)| format!("acc_{n}")));
        let mut t = CsvTable::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        for i in 0..results[0].2.len() {
            let mut row = vec![i as f64];
            for (_, _, c) in &results {
                row.push(c.get(i).copied().unwrap_or(f64::NAN));
            }
            t.push(row);
        }
        let path = uveqfed::bench::results_dir().join(format!("fig{fig}_mnist_k{k}_r{rate}.csv"));
        t.write_file(&path).expect("write");
        println!("→ {}", path.display());
        // Shape check: UVeQFed-L2 within noise of the best quantized run.
        let uv = results[0].1;
        let qsgd = results[2].1;
        let sub = results[3].1;
        assert!(uv + 0.03 >= qsgd, "fig{fig}: uveqfed {uv} far below qsgd {qsgd}");
        assert!(uv + 0.03 >= sub, "fig{fig}: uveqfed {uv} far below subsample {sub}");
        println!("shape check fig{fig}: UVeQFed-L2 ≥ baselines (±3pts) ✓");
    }
}
