//! Fig. 4 regenerator benchmark: distortion vs rate on i.i.d. Gaussian
//! 128×128 data — times the full sweep and emits the figure CSV.

use uveqfed::bench::{run, BenchConfig};
use uveqfed::data::gaussian_matrix;
use uveqfed::metrics::CsvTable;
use uveqfed::quantizer::{self, measure_distortion};

fn main() {
    let cfg = BenchConfig { warmup_iters: 0, measure_iters: 1, max_secs: 600.0 };
    let _ = BenchConfig::from_env();
    let trials = if std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false) {
        5
    } else {
        25
    };
    let codecs = ["uveqfed-l2", "uveqfed-l1", "qsgd", "rotation", "subsample"];
    let mut header = vec!["rate"];
    header.extend(codecs);
    let mut table = CsvTable::new(&header);

    run("fig4/full-sweep", cfg, || {
        table.rows.clear();
        for rate in 1..=6 {
            let mut row = vec![rate as f64];
            for name in &codecs {
                let codec = quantizer::make(name).expect("codec spec");
                let mut mse = 0.0;
                for t in 0..trials {
                    let h = gaussian_matrix(128, 4000 + t as u64);
                    mse += measure_distortion(codec.as_ref(), &h, rate as f64, 3, t as u64)
                        .mse
                        / trials as f64;
                }
                row.push(mse);
            }
            table.push(row);
        }
    });
    let path = uveqfed::bench::results_dir().join("fig4_distortion_iid.csv");
    table.write_file(&path).expect("write");
    println!("{}", table.to_pretty());
    println!("→ {}", path.display());
    // Shape assertions (the paper's ordering must hold or the bench FAILS).
    for row in &table.rows {
        assert!(row[1] < row[3], "UVeQFed L=2 must beat QSGD at R={}", row[0]);
        assert!(row[1] < row[5], "UVeQFed L=2 must beat subsampling at R={}", row[0]);
    }
    println!("shape check: UVeQFed-L2 < QSGD and < subsample at every rate ✓");
}
