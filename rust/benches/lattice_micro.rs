//! Lattice micro-benchmarks: nearest-point throughput and dither sampling
//! for every lattice — the innermost loop of UVeQFed's encoder (§Perf L3).

use uveqfed::bench::{run, BenchConfig};
use uveqfed::lattice::{self, dither};
use uveqfed::prng::{Rng, Xoshiro256pp};

fn main() {
    let cfg = BenchConfig::from_env();
    let n_points = 100_000usize;

    for name in ["scalar", "hex", "hex-a2", "cubic4", "d4", "e8"] {
        let lat = lattice::by_name(name).expect("lattice");
        let l = lat.dim();
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let pts: Vec<f64> = (0..n_points * l).map(|_| rng.normal() * 3.0).collect();
        let r = run(&format!("nearest/{name}"), cfg, || {
            let mut acc = 0i64;
            for i in 0..n_points {
                let c = lat.nearest(&pts[i * l..(i + 1) * l]);
                acc = acc.wrapping_add(c[0]);
            }
            std::hint::black_box(acc);
        });
        println!(
            "    ↳ {:.2} M nearest-point ops/s ({:.1} M scalars/s)",
            n_points as f64 / r.median_secs / 1e6,
            (n_points * l) as f64 / r.median_secs / 1e6
        );
        let r = run(&format!("dither/{name}"), cfg, || {
            let mut rng = Xoshiro256pp::seed_from_u64(3);
            std::hint::black_box(dither::sample_dither_block(lat.as_ref(), &mut rng, 10_000));
        });
        println!("    ↳ {:.2} M dither vectors/s", 10_000.0 / r.median_secs / 1e6);
    }
}
