//! Lattice micro-benchmarks: nearest-point throughput and dither sampling
//! for every lattice — the innermost loop of UVeQFed's encoder (§Perf L3).
//!
//! Measures BOTH paths per lattice so the batch-kernel speedup is recorded
//! in one run:
//! * `nearest-scalar/*` — the legacy per-block `Lattice::nearest` call
//!   (allocating, per-call dispatch) — the pre-overhaul hot path;
//! * `nearest-batch/*` — `Lattice::nearest_batch_into` over the same
//!   points with caller-owned scratch (the current encoder hot path);
//! * `dither-fill/*` — the reused-buffer per-round dither fill.
//!
//! Results merge into `BENCH_baseline.json` (label via
//! `UVEQFED_BENCH_LABEL`); `--smoke` shrinks sizes for the CI smoke step.

use uveqfed::bench::{run, smoke_mode, BenchConfig, Recorder};
use uveqfed::lattice::{self, dither, Scratch};
use uveqfed::prng::{Rng, Xoshiro256pp};

fn main() {
    let cfg = BenchConfig::from_env();
    let n_points = if smoke_mode() { 2_000usize } else { 100_000 };
    let n_dither = if smoke_mode() { 1_000usize } else { 10_000 };
    let mut rec = Recorder::new("lattice_micro");

    for name in ["scalar", "hex", "hex-a2", "cubic4", "d4", "e8"] {
        let lat = lattice::by_name(name).expect("lattice");
        let l = lat.dim();
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let pts: Vec<f64> = (0..n_points * l).map(|_| rng.normal() * 3.0).collect();

        let r_scalar = run(&format!("nearest-scalar/{name}"), cfg, || {
            let mut acc = 0i64;
            for i in 0..n_points {
                let c = lat.nearest(&pts[i * l..(i + 1) * l]);
                acc = acc.wrapping_add(c[0]);
            }
            std::hint::black_box(acc);
        });
        rec.add_with_items(&r_scalar, n_points as f64);
        println!(
            "    ↳ {:.2} M nearest-point ops/s ({:.1} M scalars/s) — legacy per-block path",
            n_points as f64 / r_scalar.median_secs / 1e6,
            (n_points * l) as f64 / r_scalar.median_secs / 1e6
        );

        let mut out = vec![0i64; n_points * l];
        let mut scratch = Scratch::new();
        let r_batch = run(&format!("nearest-batch/{name}"), cfg, || {
            lat.nearest_batch_into(&pts, &mut out, &mut scratch);
            std::hint::black_box(out[0]);
        });
        rec.add_with_items(&r_batch, n_points as f64);
        println!(
            "    ↳ {:.2} M nearest-point ops/s (batched) — {:.2}x vs per-block path",
            n_points as f64 / r_batch.median_secs / 1e6,
            r_scalar.median_secs / r_batch.median_secs
        );

        let mut dbuf = vec![0.0f64; n_dither * l];
        let r_dither = run(&format!("dither-fill/{name}"), cfg, || {
            let mut drng = Xoshiro256pp::seed_from_u64(3);
            dither::fill_dither(lat.as_ref(), &mut drng, &mut dbuf, &mut scratch);
            std::hint::black_box(dbuf[0]);
        });
        rec.add_with_items(&r_dither, n_dither as f64);
        println!(
            "    ↳ {:.2} M dither vectors/s into a reused buffer",
            n_dither as f64 / r_dither.median_secs / 1e6
        );
    }
    rec.save_or_warn();
}
