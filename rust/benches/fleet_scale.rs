//! Fleet-scale round throughput: ≥10 000 simulated clients per round with
//! a mock (no-compute) trainer, isolating cohort sampling + codec +
//! wire framing + streaming aggregation cost from model compute — and
//! demonstrating the O(m) server-side accumulator memory (the seed
//! buffered all K decoded updates: O(K·m)).
//!
//! Section C meters the **encode sessions** (Codec API v2): for a large
//! update pushed through `UpdateCodec::encoder` in varying chunk sizes,
//! it records per-round encode throughput and the peak client-side sink
//! state (`EncodeSink::state_bytes`) — so each codec's memory profile
//! (streaming vs two-pass buffered) is measured, not asserted.
//!
//! Run: `cargo bench --bench fleet_scale` (BENCH_QUICK=1 for a smoke run).

use uveqfed::bench::{run, smoke_mode, BenchConfig, Recorder};
use uveqfed::coordinator::rate_control::{controller_by_name, TheoryGuided};
use uveqfed::data::Dataset;
use uveqfed::fl::Trainer;
use uveqfed::fleet::{
    Channel, ChannelModel, ClientRecords, DownlinkSpec, FleetDriver, RatePlan, RoundRobinPool,
    RoundSpec, Scenario, StreamingAggregator, VirtualClock,
};
use uveqfed::models::EvalReport;
use uveqfed::prng::{Normal, Xoshiro256pp};
use uveqfed::quantizer::{self, CodecContext};
use uveqfed::telemetry::Collector;

/// Trainer that fabricates a deterministic pseudo-update without touching
/// data: the round cost is purely coordinator + codec + aggregation.
struct MockTrainer {
    m: usize,
}

impl Trainer for MockTrainer {
    fn num_params(&self) -> usize {
        self.m
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        Normal::new(0.0, 0.02).vec_f32(&mut rng, self.m)
    }

    fn local_update(
        &self,
        w0: &[f32],
        _shard: &Dataset,
        _tau: usize,
        lr: f32,
        _batch: usize,
        seed: u64,
    ) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let g = Normal::new(0.0, 0.01).vec_f32(&mut rng, self.m);
        w0.iter().zip(g).map(|(&w, gv)| w - lr * gv).collect()
    }

    fn evaluate(&self, _w: &[f32], _ds: &Dataset) -> EvalReport {
        EvalReport { loss: 0.0, accuracy: 0.0 }
    }
}

fn tiny_template() -> Dataset {
    Dataset { x: vec![0.0; 10], y: vec![0; 10], features: 1, classes: 2 }
}

fn main() {
    let cfg = BenchConfig::from_env();
    let smoke = smoke_mode();
    let m = if smoke { 256usize } else { 2_048 };
    let workers = 8usize;
    let mut rec = Recorder::new("fleet_scale");

    // ── A: one full round over a 10k-client population (everyone
    //      participates — 10 000 encoded, framed, unframed, decoded,
    //      folded updates per iteration).
    let population = if smoke { 400usize } else { 10_000 };
    let pool = RoundRobinPool::synthetic(population, vec![tiny_template()], 1);
    let trainer = MockTrainer { m };
    println!("# fleet_scale — population={population}, m={m}, workers={workers}");
    let agg_mem = StreamingAggregator::new(m).mem_bytes();
    println!(
        "server accumulator memory: {} KB (O(m)); naive O(K·m) buffering would hold {} MB",
        2 * agg_mem / 1024, // aggregate + desired-metering accumulator
        population * m * 4 / 1_000_000
    );
    for name in ["uveqfed-l2", "qsgd", "identity"] {
        let codec = quantizer::make(name).expect("codec spec");
        let driver = FleetDriver::new(1, 2.0, workers, Scenario::full());
        let mut clock = VirtualClock::new();
        let mut w = trainer.init_params(1);
        let mut round = 0u64;
        let mut aggregated = 0usize;
        let r = run(&format!("full-10k-round/{name}"), cfg, || {
            let spec = RoundSpec {
                round,
                local_steps: 1,
                lr: 0.1,
                batch_size: 0,
                trainer: &trainer,
                codec: codec.as_ref(),
                rate_override: None,
                telemetry: None,
                client_records: ClientRecords::Full,
                downlink: None,
            };
            let rep = driver.run_round(&spec, &mut w, &pool, &mut clock);
            aggregated = rep.aggregated;
            round += 1;
        });
        rec.add_with_items(&r, population as f64);
        assert_eq!(aggregated, population, "bench must aggregate the whole population");
        println!(
            "    ↳ {:.1} ms/round, {:.2}k client-updates/s, {:.1} MB/s through the codec",
            r.median_secs * 1e3,
            population as f64 / r.median_secs / 1e3,
            population as f64 * m as f64 * 4.0 / 1e6 / r.median_secs
        );
    }

    // ── B: sampled cohorts from a 1M-client population with stragglers —
    //      selection cost must stay O(cohort), not O(population).
    let big = if smoke { 20_000usize } else { 1_000_000 };
    let big_pool = RoundRobinPool::synthetic(big, vec![tiny_template()], 2);
    let codec = quantizer::make("uveqfed-l2").expect("codec spec");
    let cohorts: &[usize] = if smoke { &[64] } else { &[256, 4096] };
    for &cohort in cohorts {
        let driver = FleetDriver::new(3, 2.0, workers, Scenario::stragglers(cohort, 3.0));
        let mut clock = VirtualClock::new();
        let mut w = trainer.init_params(1);
        let mut round = 0u64;
        let r = run(&format!("sampled-1M/cohort-{cohort}"), cfg, || {
            let spec = RoundSpec {
                round,
                local_steps: 1,
                lr: 0.1,
                batch_size: 0,
                trainer: &trainer,
                codec: codec.as_ref(),
                rate_override: None,
                telemetry: None,
                client_records: ClientRecords::Full,
                downlink: None,
            };
            driver.run_round(&spec, &mut w, &big_pool, &mut clock);
            round += 1;
        });
        rec.add_with_items(&r, cohort as f64);
        println!(
            "    ↳ {:.2} ms/round at cohort {cohort} from {big} clients",
            r.median_secs * 1e3
        );
    }

    // ── C: streaming encode sessions — per-codec encode throughput and
    //      peak client-side sink state across chunk sizes. A streaming
    //      codec (identity, signsgd) holds far less than the 4·m bytes a
    //      two-pass codec must buffer; the buffered transform codecs
    //      (rotation, topk, subsample) now report honest `state_bytes`,
    //      so their full-update footprint shows up here instead of
    //      pretending to be zero. The numbers below measure all of that.
    let m_big = if smoke { 1usize << 14 } else { 1 << 20 }; // 1M parameters
    let mut rng = Xoshiro256pp::seed_from_u64(7);
    let h_big = Normal::new(0.0, 0.02).vec_f32(&mut rng, m_big);
    println!(
        "# stream-encode — m={m_big} ({} MB update); legacy whole-buffer input = {} KB",
        m_big * 4 / 1_000_000,
        m_big * 4 / 1024
    );
    for name in
        ["uveqfed-l2", "qsgd", "signsgd", "identity", "rotation", "topk", "subsample"]
    {
        let codec = quantizer::make(name).expect("codec spec");
        let ctx = CodecContext::new(1, 1, 7, 2.0);
        let chunk_sizes: &[usize] =
            if smoke { &[4_096] } else { &[4_096, 65_536, 1 << 20] };
        for &chunk in chunk_sizes {
            let mut peak_state = 0usize;
            let mut out_bits = 0usize;
            let r = run(&format!("stream-encode/{name}/chunk-{chunk}"), cfg, || {
                let mut sink = codec.encoder(&ctx, m_big);
                let mut peak = 0usize;
                for c in h_big.chunks(chunk) {
                    sink.push(c);
                    peak = peak.max(sink.state_bytes());
                }
                let enc = sink.finish();
                out_bits = enc.bits;
                peak_state = peak;
            });
            rec.add_with_items(&r, m_big as f64);
            println!(
                "    ↳ chunk {:>8}: {:>7.1} MB/s encode, peak sink state {:>6} KB, output {:>8.0} KB",
                chunk,
                m_big as f64 * 4.0 / 1e6 / r.median_secs,
                peak_state / 1024,
                out_bits as f64 / 8.0 / 1024.0
            );
        }
    }

    // ── D: heterogeneous uplinks — the rate-diverse scenario engine.
    //      Per-round cost of drawing channel capacities + running the
    //      rate controller + encoding every client at its own budget,
    //      vs the same-pipe baseline from section A. The theory-guided
    //      water-filling runs on the coordinator thread, so this also
    //      bounds the allocation's serial overhead.
    let hetero_pop = if smoke { 400usize } else { 10_000 };
    let hetero_pool = RoundRobinPool::synthetic(hetero_pop, vec![tiny_template()], 4);
    println!("# hetero-channel rounds — population={hetero_pop}, m={m}");
    for (channel_name, policy) in
        [("tiers", "theory"), ("tiers", "proportional"), ("markov", "theory"), ("lognormal", "uniform")]
    {
        let codec = quantizer::make("uveqfed-l2").expect("codec spec");
        let plan = RatePlan::new(
            Channel::new(ChannelModel::by_name(channel_name, 2.0).expect("preset"), 4),
            controller_by_name(policy).expect("policy"),
        );
        let driver =
            FleetDriver::new(4, 2.0, workers, Scenario::full()).with_rate_plan(plan);
        let mut clock = VirtualClock::new();
        let mut w = trainer.init_params(1);
        let mut round = 0u64;
        let mut distinct = 0usize;
        let mut violations = 0usize;
        let r = run(&format!("hetero-round/{channel_name}/{policy}"), cfg, || {
            let spec = RoundSpec {
                round,
                local_steps: 1,
                lr: 0.1,
                batch_size: 0,
                trainer: &trainer,
                codec: codec.as_ref(),
                rate_override: None,
                telemetry: None,
                client_records: ClientRecords::Full,
                downlink: None,
            };
            let rep = driver.run_round(&spec, &mut w, &hetero_pool, &mut clock);
            distinct = rep.channel.distinct_budgets;
            violations += rep.budget_violations;
            round += 1;
        });
        rec.add_with_items(&r, hetero_pop as f64);
        assert_eq!(violations, 0, "every encode must fit its assigned budget");
        println!(
            "    ↳ {:.1} ms/round, {} distinct budgets, {:.2}k client-updates/s",
            r.median_secs * 1e3,
            distinct,
            hetero_pop as f64 / r.median_secs / 1e3
        );
    }
    // Pure allocation cost at fleet cohort sizes (no training/codec):
    // the controller must stay negligible against the round itself.
    let k_alloc = if smoke { 1_000usize } else { 100_000 };
    let caps: Vec<f64> = (0..k_alloc).map(|i| [0.5, 2.0, 4.0][i % 3]).collect();
    let alphas: Vec<f64> = (0..k_alloc).map(|i| 1.0 + (i % 7) as f64).collect();
    let r = run(&format!("rate-alloc/theory/{k_alloc}"), cfg, || {
        use uveqfed::coordinator::rate_control::{AllocRequest, RateController};
        let req = AllocRequest {
            capacities: &caps,
            alphas: &alphas,
            total_rate: 2.0 * k_alloc as f64,
        };
        std::hint::black_box(TheoryGuided.allocate(&req));
    });
    rec.add_with_items(&r, k_alloc as f64);
    println!(
        "    ↳ theory-guided allocation over {k_alloc} clients: {:.2} ms",
        r.median_secs * 1e3
    );

    // ── E: telemetry overhead — the section-A round re-run with a live
    //      collector (spans + histograms + per-chunk fold timing, drained
    //      each iteration) vs `telemetry: None` above. The delta is the
    //      full observability tax; the README quotes this number.
    println!("# traced rounds — population={population}, m={m}");
    let codec = quantizer::make("uveqfed-l2").expect("codec spec");
    let collector = Collector::for_cohort(population);
    let driver = FleetDriver::new(1, 2.0, workers, Scenario::full());
    let mut clock = VirtualClock::new();
    let mut w = trainer.init_params(1);
    let mut round = 0u64;
    let mut events = 0usize;
    let mut dropped = 0u64;
    let r = run("traced-10k-round/uveqfed-l2", cfg, || {
        let spec = RoundSpec {
            round,
            local_steps: 1,
            lr: 0.1,
            batch_size: 0,
            trainer: &trainer,
            codec: codec.as_ref(),
            rate_override: None,
            telemetry: Some(&collector),
            client_records: ClientRecords::Full,
            downlink: None,
        };
        driver.run_round(&spec, &mut w, &pool, &mut clock);
        events = collector.drain().len();
        dropped += collector.take_dropped();
        round += 1;
    });
    rec.add_with_items(&r, population as f64);
    assert_eq!(dropped, 0, "cohort-sized ring must not drop events");
    assert_eq!(
        events,
        population * 5 + 2,
        "5 spans per client + rate_alloc + shard_fold (single default shard)"
    );
    println!(
        "    ↳ {:.1} ms/round traced ({} spans/round), {:.2}k client-updates/s",
        r.median_secs * 1e3,
        events,
        population as f64 / r.median_secs / 1e3
    );

    // ── F: the headline scale round — every one of 1M heterogeneous
    //      clients trains, encodes at its tier's budget, and is folded
    //      through 8 aggregation shards in one round. The per-shard
    //      decode/fold stage timing is always on (no trace ring needed at
    //      this scale), so the run reports how much decode overlapped
    //      aggregation. Client records are capped: the report must stay
    //      O(cap), not O(population).
    let n_shards = 8usize;
    let scale_pop = if smoke { 20_000usize } else { 1_000_000 };
    let scale_m = if smoke { 256usize } else { 1_024 };
    let scale_cfg = if smoke {
        BenchConfig::smoke()
    } else {
        // One measured pass: a 1M-client round is minutes, not millis.
        BenchConfig { warmup_iters: 0, measure_iters: 1, max_secs: 600.0 }
    };
    println!("# scale round — population={scale_pop}, m={scale_m}, shards={n_shards}");
    let scale_trainer = MockTrainer { m: scale_m };
    let scale_pool = RoundRobinPool::synthetic(scale_pop, vec![tiny_template()], 6);
    let codec = quantizer::make("uveqfed-l2").expect("codec spec");
    let plan = RatePlan::new(
        Channel::new(ChannelModel::by_name("tiers", 2.0).expect("preset"), 6),
        controller_by_name("theory").expect("policy"),
    );
    let driver = FleetDriver::new(6, 2.0, workers, Scenario::full())
        .with_rate_plan(plan)
        .with_shards(n_shards);
    let mut clock = VirtualClock::new();
    let mut w = scale_trainer.init_params(1);
    let mut round = 0u64;
    let mut decode_secs = 0.0f64;
    let mut fold_secs = 0.0f64;
    let mut busy_secs = 0.0f64;
    let r = run(&format!("scale-round/{scale_pop}-clients"), scale_cfg, || {
        let spec = RoundSpec {
            round,
            local_steps: 1,
            lr: 0.1,
            batch_size: 0,
            trainer: &scale_trainer,
            codec: codec.as_ref(),
            rate_override: None,
            telemetry: None,
            client_records: ClientRecords::Capped(1_000),
            downlink: None,
        };
        let rep = driver.run_round(&spec, &mut w, &scale_pool, &mut clock);
        assert_eq!(rep.aggregated, scale_pop, "full participation at scale");
        assert_eq!(rep.clients_total, scale_pop, "exact count survives the cap");
        assert!(rep.clients.len() <= 1_000, "capped records must stay O(cap)");
        assert_eq!(rep.shards.len(), n_shards);
        decode_secs = rep.shards.iter().map(|s| s.decode_secs).sum();
        fold_secs = rep.shards.iter().map(|s| s.fold_secs).sum();
        busy_secs = rep.shards.iter().map(|s| s.busy_secs).sum();
        round += 1;
    });
    rec.add_with_items(&r, scale_pop as f64);
    println!(
        "    ↳ {:.2} s/round wall; shard work: decode {:.2} s + fold {:.2} s \
         (overlap factor {:.2}× — shard-seconds per wall-second)",
        r.median_secs, decode_secs, fold_secs, busy_secs / r.median_secs
    );

    // ── F (theory): distortion vs cohort size K — Theorems 2 & 3 say the
    //      aggregate distortion ‖Σα(ĥ−h)‖²/m vanishes as K grows (α=1/K
    //      averaging beats down per-client quantization noise). One full
    //      round per K through the sharded server; traced at the sizes
    //      where a ring is affordable, proving shard spans never drop.
    let sweep_m = if smoke { 256usize } else { 512 };
    let sweep_ks: &[usize] =
        if smoke { &[100, 1_000, 10_000] } else { &[100, 1_000, 10_000, 100_000, 1_000_000] };
    let sweep_trainer = MockTrainer { m: sweep_m };
    println!("# thm2-distortion sweep — m={sweep_m}, shards={n_shards}, K={sweep_ks:?}");
    let mut curve: Vec<(usize, f64)> = Vec::new();
    for &k in sweep_ks {
        let sweep_cfg = if smoke || k <= 10_000 { cfg } else { scale_cfg };
        let sweep_pool = RoundRobinPool::synthetic(k, vec![tiny_template()], 7);
        let collector = if k <= 10_000 {
            Collector::for_cohort(k)
        } else {
            Collector::disabled()
        };
        let driver =
            FleetDriver::new(7, 2.0, workers, Scenario::full()).with_shards(n_shards);
        let mut clock = VirtualClock::new();
        let mut w = sweep_trainer.init_params(1);
        let mut round = 0u64;
        let mut distortion = f64::NAN;
        let r = run(&format!("thm2-distortion/K-{k}"), sweep_cfg, || {
            let spec = RoundSpec {
                round,
                local_steps: 1,
                lr: 0.1,
                batch_size: 0,
                trainer: &sweep_trainer,
                codec: codec.as_ref(),
                rate_override: None,
                telemetry: Some(&collector),
                client_records: ClientRecords::Capped(0),
                downlink: None,
            };
            let rep = driver.run_round(&spec, &mut w, &sweep_pool, &mut clock);
            assert_eq!(rep.aggregated, k);
            assert!(rep.clients.is_empty(), "Capped(0) must keep no records");
            distortion = rep.aggregate_distortion;
            if collector.is_enabled() {
                let events = collector.drain().len();
                assert_eq!(collector.take_dropped(), 0, "ring must absorb shard spans");
                assert_eq!(events, k * 5 + 1 + n_shards, "lifecycle + rate_alloc + shard_fold");
            }
            round += 1;
        });
        rec.add_with_items(&r, k as f64);
        println!("    ↳ K={k:>8}: aggregate distortion {distortion:.3e}");
        curve.push((k, distortion));
    }
    for pair in curve.windows(2) {
        assert!(
            pair[1].1 < pair[0].1,
            "Thm 2/3: distortion must vanish with K, got {:?} -> {:?}",
            pair[0],
            pair[1]
        );
    }

    // ── G: coded downlink — the section-A round re-run bidirectionally.
    //      Every arrival's broadcast delta is encoded sequentially on the
    //      coordinator thread (the determinism contract), so this meters
    //      the serial downlink tax on a 10k-client round plus the total
    //      up+down wire split the asymmetric-link experiments care about.
    println!("# downlink rounds — population={population}, m={m}");
    let codec = quantizer::make("uveqfed-l2").expect("codec spec");
    let dl_codec = quantizer::make("uveqfed-l2").expect("codec spec");
    let driver = FleetDriver::new(8, 2.0, workers, Scenario::full());
    let mut clock = VirtualClock::new();
    let mut w = trainer.init_params(1);
    let mut round = 0u64;
    let (mut up_bytes, mut down_bytes, mut down_bits) = (0usize, 0usize, 0usize);
    let mut resyncs = 0usize;
    let r = run("downlink-10k-round/uveqfed-l2", cfg, || {
        let spec = RoundSpec {
            round,
            local_steps: 1,
            lr: 0.1,
            batch_size: 0,
            trainer: &trainer,
            codec: codec.as_ref(),
            rate_override: None,
            telemetry: None,
            client_records: ClientRecords::Full,
            downlink: None,
        }
        .with_downlink(DownlinkSpec::new(dl_codec.as_ref(), 2.0));
        let rep = driver.run_round(&spec, &mut w, &pool, &mut clock);
        up_bytes = rep.wire_bytes;
        down_bytes = rep.downlink_bytes;
        down_bits = rep.downlink_bits;
        resyncs = rep.resyncs;
        round += 1;
    });
    rec.add_with_items(&r, population as f64);
    assert!(down_bytes > 0, "downlink rounds must put bytes on the wire");
    assert!(
        round <= 1 || resyncs == 0,
        "steady-state full participation must broadcast deltas, not resyncs"
    );
    println!(
        "    ↳ {:.1} ms/round bidirectional; downlink encode {:.1} MB/s of model volume; \
         wire split up {:.2} MB / down {:.2} MB ({:.0} down bits/entry·client)",
        r.median_secs * 1e3,
        population as f64 * m as f64 * 4.0 / 1e6 / r.median_secs,
        up_bytes as f64 / 1e6,
        down_bytes as f64 / 1e6,
        down_bits as f64 / (population as f64 * m as f64)
    );

    rec.save_or_warn();
}
