//! Figs. 10–11 regenerator benchmark: CIFAR conv-net convergence, i.i.d.
//! and 25%-dominant-label splits, R ∈ {2, 4}. Uses the AOT 5-layer CNN
//! when artifacts are present, the native CnnLite oracle otherwise.

use uveqfed::bench::{run, BenchConfig};
use uveqfed::data::{partition, PartitionScheme, SynthCifar};
use uveqfed::fl::{run_federated, FlConfig, LrSchedule, NativeTrainer, Trainer};
use uveqfed::metrics::CsvTable;
use uveqfed::models::CnnLite;
use uveqfed::quantizer;
use uveqfed::runtime;

fn main() {
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let full = std::env::var("UVEQFED_FULL").map(|v| v == "1").unwrap_or(false);
    let (k, n_per_user, rounds, tau) = if full {
        (10, 5000, 40, 17)
    } else if quick {
        (6, 120, 6, 2)
    } else {
        (10, 300, 12, 4)
    };
    let cfg_bench = BenchConfig { warmup_iters: 0, measure_iters: 1, max_secs: 3600.0 };

    let gen = SynthCifar::new(10);
    let ds = gen.dataset(k * n_per_user);
    let test = gen.test_dataset(300);
    let trainer: Box<dyn Trainer> = if runtime::artifacts_available() && !quick {
        match runtime::HloTrainer::load("cifar", 60) {
            Ok(t) => {
                println!("# backend: AOT 5-layer CNN via PJRT");
                Box::new(t)
            }
            Err(_) => Box::new(NativeTrainer::new(CnnLite::cifar())),
        }
    } else {
        println!("# backend: native CnnLite oracle");
        Box::new(NativeTrainer::new(CnnLite::cifar()))
    };

    for rate in [2.0f64, 4.0] {
        let fig = if rate == 2.0 { 10 } else { 11 };
        for (split, scheme) in [
            ("iid", PartitionScheme::Iid),
            ("het", PartitionScheme::DominantLabel { frac: 0.25 }),
        ] {
            let shards = partition(&ds, k, n_per_user, scheme, 10);
            let mut header = vec!["eval_idx".to_string()];
            let mut curves: Vec<Vec<f64>> = Vec::new();
            let mut bests = Vec::new();
            for name in ["uveqfed-l2", "qsgd", "identity"] {
                let codec = quantizer::make(name).expect("codec spec");
                let cfg = FlConfig {
                    users: k,
                    rounds,
                    local_steps: tau,
                    batch_size: 60,
                    lr: LrSchedule::Const(5e-3),
                    rate,
                    seed: 10,
                    workers: 8,
                    eval_every: (rounds / 8).max(1),
                    verbose: false,
                    fleet: uveqfed::fleet::Scenario::full(),
                    channel: None,
                };
                let mut best = 0.0;
                let mut curve = Vec::new();
                run(&format!("fig{fig}/{split}/{name}"), cfg_bench, || {
                    let h =
                        run_federated(&cfg, trainer.as_ref(), &shards, &test, codec.as_ref());
                    best = h.best_accuracy();
                    curve = h.rows.iter().map(|r| r.test_accuracy).collect();
                });
                println!("    ↳ best accuracy {best:.4}");
                header.push(format!("acc_{name}"));
                curves.push(curve);
                bests.push(best);
            }
            let mut t =
                CsvTable::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
            for i in 0..curves[0].len() {
                let mut row = vec![i as f64];
                for c in &curves {
                    row.push(c.get(i).copied().unwrap_or(f64::NAN));
                }
                t.push(row);
            }
            let path = uveqfed::bench::results_dir()
                .join(format!("fig{fig}_cifar_r{rate}_{split}.csv"));
            t.write_file(&path).expect("write");
            println!("→ {}", path.display());
            // Shape: all runs must actually learn (beat 10% chance).
            for (b, name) in bests.iter().zip(["uveqfed-l2", "qsgd", "identity"]) {
                assert!(*b > 0.12, "fig{fig} {split} {name}: accuracy {b} ≈ chance");
            }
        }
        println!("shape check fig{fig}: all codecs above chance ✓");
    }
}
