//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **Dither subtraction** (the paper's §III-B claim vs QSGD): measure
//!    distortion with/without subtracting the dither at the decoder.
//! 2. **Lattice dimension** L ∈ {1, 2, 4, 8} at fixed rate on correlated
//!    data (where vector quantization pays).
//! 3. **Entropy coder choice** for UVeQFed's index stream: adaptive range
//!    coder vs Elias-γ vs two-pass Huffman (bits/entry at equal content).
//! 4. **Coordinate decorrelation** on/off: what the residual-prediction
//!    transform buys the order-0 coder.

use uveqfed::bench::{run, BenchConfig};
use uveqfed::data::{correlated_matrix, exp_decay_sigma, gaussian_matrix};
use uveqfed::entropy::elias::EliasGamma;
use uveqfed::entropy::huffman::HuffmanCoder;
use uveqfed::entropy::range::AdaptiveRangeCoder;
use uveqfed::entropy::{BitWriter, IntCoder};
use uveqfed::prng::{Rng, Xoshiro256pp};
use uveqfed::quantizer::{measure_distortion, UVeQFed};

fn main() {
    let cfg = BenchConfig { warmup_iters: 0, measure_iters: 1, max_secs: 300.0 };
    let trials = 10;

    // --- 1. dither subtraction ---------------------------------------
    println!("# ablation 1: subtractive vs non-subtractive dither (R=2, iid)");
    let mut sub = 0.0;
    let mut nosub = 0.0;
    run("ablation/dither-subtraction", cfg, || {
        sub = 0.0;
        nosub = 0.0;
        for t in 0..trials {
            let h = gaussian_matrix(64, 800 + t as u64);
            sub += measure_distortion(&UVeQFed::hexagonal(), &h, 2.0, t as u64, 0).mse
                / trials as f64;
            nosub += measure_distortion(
                &UVeQFed::hexagonal().non_subtractive(),
                &h,
                2.0,
                t as u64,
                0,
            )
            .mse
                / trials as f64;
        }
    });
    println!(
        "    subtractive {sub:.5}  non-subtractive {nosub:.5}  gain ×{:.2}",
        nosub / sub
    );
    assert!(sub < nosub, "dither subtraction must reduce distortion");

    // --- 2. lattice dimension ----------------------------------------
    println!("\n# ablation 2: lattice dimension at R=3, correlated data");
    let sigma = exp_decay_sigma(64, 0.2);
    for (name, codec) in [
        ("L=1 scalar", UVeQFed::scalar()),
        ("L=2 hex", UVeQFed::hexagonal()),
        ("L=4 D4", UVeQFed::d4()),
        ("L=8 E8", UVeQFed::e8()),
    ] {
        let mut mse = 0.0;
        run(&format!("ablation/lattice-dim/{name}"), cfg, || {
            mse = 0.0;
            for t in 0..trials {
                let h0 = gaussian_matrix(64, 900 + t as u64);
                let h = correlated_matrix(&h0, &sigma, 64);
                mse += measure_distortion(&codec, &h, 3.0, t as u64, 0).mse / trials as f64;
            }
        });
        println!("    {name}: {mse:.5}");
    }

    // --- 3. entropy coder choice -------------------------------------
    println!("\n# ablation 3: index-stream coder (bits/symbol on a lattice-coord stream)");
    let mut rng = Xoshiro256pp::seed_from_u64(5);
    let stream: Vec<i64> =
        (0..100_000).map(|_| (rng.normal() * 1.2).round() as i64).collect();
    let h_emp = uveqfed::entropy::empirical_entropy(&stream);
    for coder in
        [&AdaptiveRangeCoder::with_dims(2) as &dyn IntCoder, &EliasGamma, &HuffmanCoder]
    {
        let mut bits = 0usize;
        run(&format!("ablation/coder/{}", coder.name()), cfg, || {
            let mut w = BitWriter::new();
            coder.encode(&stream, &mut w);
            bits = w.bit_len();
        });
        println!(
            "    {}: {:.4} bits/sym (empirical entropy {h_emp:.4})",
            coder.name(),
            bits as f64 / stream.len() as f64
        );
    }

    // --- 4. coordinate decorrelation ---------------------------------
    println!("\n# ablation 4: coordinate decorrelation (hex, R=2, iid)");
    // with: the default codec; without: measured via the D4 pathway is not
    // switchable at runtime, so emulate by comparing coded size of raw vs
    // decorrelated coordinate streams from the same lattice.
    use uveqfed::lattice::{self, Lattice};
    let lat = lattice::paper_hexagonal();
    let mut rng = Xoshiro256pp::seed_from_u64(6);
    let mut raw = Vec::new();
    let mut dec = Vec::new();
    for _ in 0..50_000 {
        let y = [rng.normal() * 1.5, rng.normal() * 1.5];
        let mut c = lat.nearest(&y);
        raw.extend_from_slice(&c);
        lat.decorrelate(&mut c);
        dec.extend_from_slice(&c);
    }
    let coder = AdaptiveRangeCoder::with_dims(2);
    let bits_of = |xs: &[i64]| {
        let mut w = BitWriter::new();
        coder.encode(xs, &mut w);
        w.bit_len() as f64 / (xs.len() / 2) as f64
    };
    let b_raw = bits_of(&raw);
    let b_dec = bits_of(&dec);
    println!("    raw coords {b_raw:.4} bits/subvec  decorrelated {b_dec:.4} bits/subvec  saved {:.4}", b_raw - b_dec);
    assert!(b_dec <= b_raw + 1e-9, "decorrelation must not inflate the stream");
}
