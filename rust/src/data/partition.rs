//! Data partitioners — the i.i.d. and heterogeneous splits of §V-B.

use super::Dataset;
use crate::prng::{Rng, Xoshiro256pp};

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PartitionScheme {
    /// Shuffle globally, deal evenly: every user sees every label equally
    /// often in expectation (the paper's "i.i.d. division").
    Iid,
    /// Deal the dataset *in order*: user k gets samples
    /// `[k·n_k, (k+1)·n_k)`. Our generators emit label-major order, so
    /// this reproduces the paper's "first user has the first 1000
    /// samples" uneven label split.
    Sequential,
    /// At least `frac` of each user's samples come from one distinct
    /// dominant label (the paper's CIFAR heterogeneous split, frac=0.25).
    DominantLabel { frac: f64 },
    /// Dirichlet(α) label distribution per user (standard FL benchmark
    /// heterogeneity knob; extension beyond the paper).
    Dirichlet { alpha: f64 },
}

/// Split `ds` into `k` user shards of `n_per_user` samples each.
pub fn partition(
    ds: &Dataset,
    k: usize,
    n_per_user: usize,
    scheme: PartitionScheme,
    seed: u64,
) -> Vec<Dataset> {
    assert!(k * n_per_user <= ds.len(), "not enough samples: {} < {}", ds.len(), k * n_per_user);
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0x9A87_17B3);
    match scheme {
        PartitionScheme::Iid => {
            let mut idx: Vec<usize> = (0..ds.len()).collect();
            rng.shuffle(&mut idx);
            (0..k)
                .map(|u| ds.subset(&idx[u * n_per_user..(u + 1) * n_per_user]))
                .collect()
        }
        PartitionScheme::Sequential => (0..k)
            .map(|u| {
                let idx: Vec<usize> = (u * n_per_user..(u + 1) * n_per_user).collect();
                ds.subset(&idx)
            })
            .collect(),
        PartitionScheme::DominantLabel { frac } => {
            // indices by class
            let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); ds.classes];
            for (i, &y) in ds.y.iter().enumerate() {
                by_class[y as usize].push(i);
            }
            for v in by_class.iter_mut() {
                rng.shuffle(v);
            }
            let n_dom = (n_per_user as f64 * frac).ceil() as usize;
            let mut cursors = vec![0usize; ds.classes];
            let mut shards = Vec::with_capacity(k);
            // remaining pool after dominant assignment, refilled lazily
            let mut pool: Vec<usize> = Vec::new();
            // First pass: take dominant blocks.
            let mut dominant_take: Vec<Vec<usize>> = Vec::with_capacity(k);
            for u in 0..k {
                let c = u % ds.classes;
                let take: Vec<usize> = by_class[c]
                    [cursors[c]..(cursors[c] + n_dom).min(by_class[c].len())]
                    .to_vec();
                cursors[c] += take.len();
                dominant_take.push(take);
            }
            // Pool = everything not consumed as dominant.
            for (c, v) in by_class.iter().enumerate() {
                pool.extend_from_slice(&v[cursors[c]..]);
            }
            rng.shuffle(&mut pool);
            let mut pc = 0usize;
            for dom in dominant_take.iter_mut() {
                let need = n_per_user - dom.len();
                dom.extend_from_slice(&pool[pc..pc + need]);
                pc += need;
                shards.push(ds.subset(dom));
            }
            shards
        }
        PartitionScheme::Dirichlet { alpha } => {
            let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); ds.classes];
            for (i, &y) in ds.y.iter().enumerate() {
                by_class[y as usize].push(i);
            }
            for v in by_class.iter_mut() {
                rng.shuffle(v);
            }
            let mut cursors = vec![0usize; ds.classes];
            let mut shards = Vec::with_capacity(k);
            for _ in 0..k {
                let probs = dirichlet(ds.classes, alpha, &mut rng);
                let mut idx = Vec::with_capacity(n_per_user);
                for _ in 0..n_per_user {
                    // sample a class, fall back to whichever still has data
                    let mut c = sample_categorical(&probs, &mut rng);
                    let mut tries = 0;
                    while cursors[c] >= by_class[c].len() && tries < ds.classes {
                        c = (c + 1) % ds.classes;
                        tries += 1;
                    }
                    if cursors[c] >= by_class[c].len() {
                        break;
                    }
                    idx.push(by_class[c][cursors[c]]);
                    cursors[c] += 1;
                }
                shards.push(ds.subset(&idx));
            }
            shards
        }
    }
}

/// Marsaglia–Tsang gamma sampler (shape ≥ 0), for Dirichlet draws.
fn gamma_sample(shape: f64, rng: &mut Xoshiro256pp) -> f64 {
    if shape < 1.0 {
        // boost: Gamma(a) = Gamma(a+1)·U^{1/a}
        let u: f64 = rng.uniform().max(1e-300);
        return gamma_sample(shape + 1.0, rng) * u.powf(1.0 / shape);
    }
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = rng.normal();
        let v = (1.0 + c * x).powi(3);
        if v <= 0.0 {
            continue;
        }
        let u: f64 = rng.uniform().max(1e-300);
        if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
            return d * v;
        }
    }
}

fn dirichlet(n: usize, alpha: f64, rng: &mut Xoshiro256pp) -> Vec<f64> {
    let g: Vec<f64> = (0..n).map(|_| gamma_sample(alpha, rng)).collect();
    let sum: f64 = g.iter().sum::<f64>().max(1e-300);
    g.into_iter().map(|v| v / sum).collect()
}

fn sample_categorical(probs: &[f64], rng: &mut Xoshiro256pp) -> usize {
    let u = rng.uniform();
    let mut acc = 0.0;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if u < acc {
            return i;
        }
    }
    probs.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthMnist;

    fn dataset() -> Dataset {
        SynthMnist::new(5).dataset(1000)
    }

    #[test]
    fn iid_split_is_balanced() {
        let ds = dataset();
        let shards = partition(&ds, 10, 100, PartitionScheme::Iid, 1);
        assert_eq!(shards.len(), 10);
        for s in &shards {
            assert_eq!(s.len(), 100);
            // every class present with roughly 10 samples
            for &c in &s.label_histogram() {
                assert!(c >= 2 && c <= 25, "unbalanced iid: {:?}", s.label_histogram());
            }
        }
    }

    #[test]
    fn sequential_split_is_heterogeneous() {
        let ds = dataset(); // label-major order
        let shards = partition(&ds, 10, 100, PartitionScheme::Sequential, 1);
        // each shard should be dominated by one class (label-major blocks)
        for s in &shards {
            let h = s.label_histogram();
            let max = *h.iter().max().unwrap();
            assert!(max == 100, "expected pure-class shard, got {h:?}");
        }
    }

    #[test]
    fn dominant_label_fraction_enforced() {
        let ds = dataset();
        let shards =
            partition(&ds, 10, 80, PartitionScheme::DominantLabel { frac: 0.25 }, 1);
        for (u, s) in shards.iter().enumerate() {
            let h = s.label_histogram();
            assert!(
                h[u % 10] >= 20,
                "user {u}: dominant class {} has {} < 25%",
                u % 10,
                h[u % 10]
            );
        }
    }

    #[test]
    fn dirichlet_concentrates_for_small_alpha() {
        let ds = dataset();
        let sharp = partition(&ds, 5, 100, PartitionScheme::Dirichlet { alpha: 0.05 }, 2);
        let flat = partition(&ds, 5, 100, PartitionScheme::Dirichlet { alpha: 100.0 }, 2);
        let peak = |shards: &[Dataset]| {
            shards
                .iter()
                .map(|s| *s.label_histogram().iter().max().unwrap() as f64 / s.len() as f64)
                .sum::<f64>()
                / shards.len() as f64
        };
        assert!(peak(&sharp) > peak(&flat) + 0.2, "{} vs {}", peak(&sharp), peak(&flat));
    }

    #[test]
    #[should_panic]
    fn oversubscription_panics() {
        let ds = dataset();
        let _ = partition(&ds, 20, 100, PartitionScheme::Iid, 1);
    }
}
