//! Synthetic matrices for the §V-A distortion experiments (Figs. 4–5).

use crate::prng::{Normal, Xoshiro256pp};

/// `n × n` matrix with i.i.d. N(0,1) entries, row-major (the `H` of
/// Fig. 4).
pub fn gaussian_matrix(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    Normal::new(0.0, 1.0).vec_f32(&mut rng, n * n)
}

/// The exponentially-decaying correlation matrix of Fig. 5:
/// `Σ_{i,j} = e^{−0.2·|i−j|}`, row-major.
pub fn exp_decay_sigma(n: usize, decay: f64) -> Vec<f64> {
    let mut s = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            s[i * n + j] = (-decay * (i as f64 - j as f64).abs()).exp();
        }
    }
    s
}

/// `Σ · H · Σᵀ` for square `H` (f32) and `Σ` (f64), producing the
/// correlated test data of Fig. 5.
pub fn correlated_matrix(h: &[f32], sigma: &[f64], n: usize) -> Vec<f32> {
    assert_eq!(h.len(), n * n);
    assert_eq!(sigma.len(), n * n);
    // t = Σ·H
    let mut t = vec![0.0f64; n * n];
    for i in 0..n {
        for k in 0..n {
            let s = sigma[i * n + k];
            if s == 0.0 {
                continue;
            }
            for j in 0..n {
                t[i * n + j] += s * h[k * n + j] as f64;
            }
        }
    }
    // out = t·Σᵀ  → out[i][j] = Σ_k t[i][k]·sigma[j][k]
    let mut out = vec![0.0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut acc = 0.0f64;
            for k in 0..n {
                acc += t[i * n + k] * sigma[j * n + k];
            }
            out[i * n + j] = acc as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_matrix_stats() {
        let m = gaussian_matrix(128, 7);
        let n = m.len() as f64;
        let mean: f64 = m.iter().map(|&v| v as f64).sum::<f64>() / n;
        let var: f64 = m.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sigma_structure() {
        let s = exp_decay_sigma(4, 0.2);
        assert_eq!(s[0], 1.0);
        assert!((s[1] - (-0.2f64).exp()).abs() < 1e-12);
        // symmetric
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(s[i * 4 + j], s[j * 4 + i]);
            }
        }
    }

    #[test]
    fn correlation_induces_neighbor_similarity() {
        let h = gaussian_matrix(64, 9);
        let sigma = exp_decay_sigma(64, 0.2);
        let c = correlated_matrix(&h, &sigma, 64);
        // Neighboring entries of ΣHΣᵀ must correlate more than in H.
        let corr = |m: &[f32]| {
            let pairs: Vec<(f64, f64)> = (0..64)
                .flat_map(|i| (0..63).map(move |j| (i, j)))
                .map(|(i, j)| (m[i * 64 + j] as f64, m[i * 64 + j + 1] as f64))
                .collect();
            let n = pairs.len() as f64;
            let (ma, mb) = (
                pairs.iter().map(|p| p.0).sum::<f64>() / n,
                pairs.iter().map(|p| p.1).sum::<f64>() / n,
            );
            let cov: f64 =
                pairs.iter().map(|p| (p.0 - ma) * (p.1 - mb)).sum::<f64>() / n;
            let (va, vb) = (
                pairs.iter().map(|p| (p.0 - ma).powi(2)).sum::<f64>() / n,
                pairs.iter().map(|p| (p.1 - mb).powi(2)).sum::<f64>() / n,
            );
            cov / (va * vb).sqrt()
        };
        assert!(corr(&c) > 0.5, "correlated corr {}", corr(&c));
        assert!(corr(&h).abs() < 0.1, "iid corr {}", corr(&h));
    }
}
