//! Procedural MNIST stand-in: 28×28 grayscale "digits".
//!
//! Each class is a fixed stroke skeleton (a polyline of control points
//! drawn from a per-class seeded RNG); samples render the skeleton with
//! per-sample translation, control-point jitter, stroke-width variation
//! and pixel noise. This yields a 10-class problem with the properties the
//! FL experiments need: strong intra-class structure, inter-class
//! separation, and enough sample variation that generalization is
//! non-trivial. Fully deterministic given (seed, index).

use super::Dataset;
use crate::prng::{Rng, SplitMix64, Xoshiro256pp};

pub const SIDE: usize = 28;
pub const FEATURES: usize = SIDE * SIDE;
pub const CLASSES: usize = 10;

#[derive(Debug, Clone)]
pub struct SynthMnist {
    seed: u64,
    /// Per-class skeletons: control points in image coordinates.
    skeletons: Vec<Vec<(f32, f32)>>,
}

impl SynthMnist {
    pub fn new(seed: u64) -> Self {
        let mut skeletons = Vec::with_capacity(CLASSES);
        for c in 0..CLASSES {
            let mut sm = SplitMix64::new(seed ^ 0x5EED_0000 ^ (c as u64) << 32);
            let mut rng = Xoshiro256pp::seed_from_u64(sm.next());
            // 5–7 control points inside the central region.
            let n_pts = 5 + rng.gen_index(3);
            let pts: Vec<(f32, f32)> = (0..n_pts)
                .map(|_| {
                    (
                        rng.uniform_range(6.0, 22.0) as f32,
                        rng.uniform_range(6.0, 22.0) as f32,
                    )
                })
                .collect();
            skeletons.push(pts);
        }
        Self { seed, skeletons }
    }

    /// Render sample `index` of class `class` into a FEATURES-length
    /// buffer in [0, 1].
    pub fn render(&self, class: usize, index: u64) -> Vec<f32> {
        let mut sm = SplitMix64::new(self.seed ^ index.wrapping_mul(0x9E3779B97F4A7C15) ^ ((class as u64) << 48));
        let mut rng = Xoshiro256pp::seed_from_u64(sm.next());
        let skel = &self.skeletons[class];

        // per-sample transform
        let dx = rng.uniform_range(-2.0, 2.0) as f32;
        let dy = rng.uniform_range(-2.0, 2.0) as f32;
        let width = rng.uniform_range(0.9, 1.6) as f32; // stroke sigma
        let jitter = 0.8f32;

        let pts: Vec<(f32, f32)> = skel
            .iter()
            .map(|&(x, y)| {
                (
                    x + dx + rng.normal_f32() * jitter,
                    y + dy + rng.normal_f32() * jitter,
                )
            })
            .collect();

        let mut img = vec![0.0f32; FEATURES];
        // march along segments, stamping gaussian blobs
        for w in pts.windows(2) {
            let (x0, y0) = w[0];
            let (x1, y1) = w[1];
            let len = ((x1 - x0).powi(2) + (y1 - y0).powi(2)).sqrt().max(1e-3);
            let steps = (len * 2.0).ceil() as usize;
            for s in 0..=steps {
                let t = s as f32 / steps as f32;
                let cx = x0 + t * (x1 - x0);
                let cy = y0 + t * (y1 - y0);
                stamp(&mut img, cx, cy, width);
            }
        }
        // pixel noise + clamp
        for v in img.iter_mut() {
            *v += rng.normal_f32() * 0.08;
            *v = v.clamp(0.0, 1.0);
        }
        img
    }

    /// Generate a dataset of `n` samples, grouped label-major (all class-0
    /// samples first, then class-1, …) — the "sequential" heterogeneous
    /// split of §V-B reads this order directly.
    pub fn dataset(&self, n: usize) -> Dataset {
        let per = n / CLASSES;
        let mut x = Vec::with_capacity(n * FEATURES);
        let mut y = Vec::with_capacity(n);
        for c in 0..CLASSES {
            let count = if c == CLASSES - 1 { n - per * (CLASSES - 1) } else { per };
            for i in 0..count {
                x.extend(self.render(c, i as u64));
                y.push(c as u8);
            }
        }
        Dataset { x, y, features: FEATURES, classes: CLASSES }
    }

    /// Held-out test set (disjoint sample indices).
    pub fn test_dataset(&self, n: usize) -> Dataset {
        let per = n / CLASSES;
        let mut x = Vec::with_capacity(n * FEATURES);
        let mut y = Vec::with_capacity(n);
        for c in 0..CLASSES {
            let count = if c == CLASSES - 1 { n - per * (CLASSES - 1) } else { per };
            for i in 0..count {
                x.extend(self.render(c, 1_000_000 + i as u64));
                y.push(c as u8);
            }
        }
        Dataset { x, y, features: FEATURES, classes: CLASSES }
    }
}

fn stamp(img: &mut [f32], cx: f32, cy: f32, sigma: f32) {
    let r = (2.5 * sigma).ceil() as i64;
    let x0 = (cx.round() as i64 - r).max(0);
    let x1 = (cx.round() as i64 + r).min(SIDE as i64 - 1);
    let y0 = (cy.round() as i64 - r).max(0);
    let y1 = (cy.round() as i64 + r).min(SIDE as i64 - 1);
    let inv = 1.0 / (2.0 * sigma * sigma);
    for yy in y0..=y1 {
        for xx in x0..=x1 {
            let d2 = (xx as f32 - cx).powi(2) + (yy as f32 - cy).powi(2);
            let v = (-d2 * inv).exp() * 0.8;
            let p = &mut img[yy as usize * SIDE + xx as usize];
            *p = (*p + v).min(1.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_rendering() {
        let g1 = SynthMnist::new(7);
        let g2 = SynthMnist::new(7);
        assert_eq!(g1.render(3, 11), g2.render(3, 11));
    }

    #[test]
    fn different_samples_differ() {
        let g = SynthMnist::new(7);
        assert_ne!(g.render(3, 0), g.render(3, 1));
        assert_ne!(g.render(3, 0), g.render(4, 0));
    }

    #[test]
    fn values_in_unit_range() {
        let g = SynthMnist::new(7);
        let img = g.render(0, 0);
        assert_eq!(img.len(), FEATURES);
        assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
        // non-trivial content
        let mass: f32 = img.iter().sum();
        assert!(mass > 5.0, "image nearly empty: {mass}");
    }

    #[test]
    fn dataset_label_major_order() {
        let g = SynthMnist::new(7);
        let ds = g.dataset(100);
        assert_eq!(ds.len(), 100);
        assert_eq!(ds.label_histogram(), vec![10; 10]);
        // label-major: first 10 are class 0
        assert!(ds.y[..10].iter().all(|&y| y == 0));
        assert!(ds.y[10..20].iter().all(|&y| y == 1));
    }

    #[test]
    fn classes_are_separable_by_nearest_prototype() {
        // Nearest-class-mean classification on held-out samples must beat
        // chance by a wide margin — the datasets must be learnable.
        let g = SynthMnist::new(7);
        let train = g.dataset(500);
        let test = g.test_dataset(100);
        // class means
        let mut means = vec![vec![0.0f32; FEATURES]; CLASSES];
        let mut counts = vec![0usize; CLASSES];
        for i in 0..train.len() {
            let (x, y) = train.sample(i);
            counts[y as usize] += 1;
            for (m, &v) in means[y as usize].iter_mut().zip(x) {
                *m += v;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c as f32;
            }
        }
        let mut correct = 0;
        for i in 0..test.len() {
            let (x, y) = test.sample(i);
            let pred = (0..CLASSES)
                .min_by(|&a, &b| {
                    let da: f32 =
                        x.iter().zip(&means[a]).map(|(u, v)| (u - v) * (u - v)).sum();
                    let db: f32 =
                        x.iter().zip(&means[b]).map(|(u, v)| (u - v) * (u - v)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if pred == y as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.6, "nearest-mean accuracy {acc} too low");
    }
}
