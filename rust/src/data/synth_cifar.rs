//! Procedural CIFAR-10 stand-in: 32×32×3 textured-object classes.
//!
//! Each class combines (i) a base color palette, (ii) a sinusoidal texture
//! with class-specific frequency/orientation, and (iii) a parametric shape
//! mask (super-ellipse exponent per class). Samples jitter phase, position,
//! scale and color, plus pixel noise — a 10-class RGB problem hard enough
//! that a linear model underfits and the conv net of §V-B pays off.

use super::Dataset;
use crate::prng::{Rng, SplitMix64, Xoshiro256pp};

pub const SIDE: usize = 32;
pub const CHANNELS: usize = 3;
pub const FEATURES: usize = SIDE * SIDE * CHANNELS;
pub const CLASSES: usize = 10;

#[derive(Debug, Clone, Copy)]
struct ClassSpec {
    color: [f32; 3],
    freq: f32,
    orient: f32,
    shape_exp: f32,
    shape_radius: f32,
}

#[derive(Debug, Clone)]
pub struct SynthCifar {
    seed: u64,
    specs: Vec<ClassSpec>,
}

impl SynthCifar {
    pub fn new(seed: u64) -> Self {
        let mut specs = Vec::with_capacity(CLASSES);
        for c in 0..CLASSES {
            let mut sm = SplitMix64::new(seed ^ 0xC1FA_0000 ^ ((c as u64) << 32));
            let mut rng = Xoshiro256pp::seed_from_u64(sm.next());
            specs.push(ClassSpec {
                color: [
                    rng.uniform_f32() * 0.8 + 0.1,
                    rng.uniform_f32() * 0.8 + 0.1,
                    rng.uniform_f32() * 0.8 + 0.1,
                ],
                freq: 0.3 + 0.25 * c as f32 / CLASSES as f32 + rng.uniform_f32() * 0.15,
                orient: rng.uniform_f32() * std::f32::consts::PI,
                shape_exp: 1.0 + (c % 5) as f32 * 0.8,
                shape_radius: 8.0 + rng.uniform_f32() * 5.0,
            });
        }
        Self { seed, specs }
    }

    /// Render sample `index` of `class` as CHW-flattened RGB in [0, 1].
    pub fn render(&self, class: usize, index: u64) -> Vec<f32> {
        let mut sm = SplitMix64::new(
            self.seed ^ index.wrapping_mul(0xC2B2AE3D27D4EB4F) ^ ((class as u64) << 48),
        );
        let mut rng = Xoshiro256pp::seed_from_u64(sm.next());
        let spec = self.specs[class];

        let phase = rng.uniform_f32() * std::f32::consts::TAU;
        let cx = 16.0 + rng.uniform_range(-4.0, 4.0) as f32;
        let cy = 16.0 + rng.uniform_range(-4.0, 4.0) as f32;
        let radius = spec.shape_radius * (0.85 + 0.3 * rng.uniform_f32());
        let orient = spec.orient + rng.normal_f32() * 0.15;
        let color_jit: [f32; 3] = [
            rng.normal_f32() * 0.06,
            rng.normal_f32() * 0.06,
            rng.normal_f32() * 0.06,
        ];
        let (s, c) = orient.sin_cos();

        let mut img = vec![0.0f32; FEATURES];
        for y in 0..SIDE {
            for x in 0..SIDE {
                let fx = x as f32 - cx;
                let fy = y as f32 - cy;
                // super-ellipse mask
                let e = spec.shape_exp;
                let d = (fx.abs() / radius).powf(e) + (fy.abs() / radius).powf(e);
                let mask = if d <= 1.0 { 1.0 } else { 0.25 };
                // oriented sinusoidal texture
                let u = fx * c + fy * s;
                let tex = 0.5 + 0.5 * (spec.freq * u + phase).sin();
                for ch in 0..CHANNELS {
                    let base = (spec.color[ch] + color_jit[ch]).clamp(0.05, 0.95);
                    let v = (base * mask * (0.55 + 0.45 * tex)
                        + rng.normal_f32() * 0.05)
                        .clamp(0.0, 1.0);
                    img[ch * SIDE * SIDE + y * SIDE + x] = v;
                }
            }
        }
        img
    }

    /// Label-major dataset of `n` samples (see `SynthMnist::dataset`).
    pub fn dataset(&self, n: usize) -> Dataset {
        self.make(n, 0)
    }

    pub fn test_dataset(&self, n: usize) -> Dataset {
        self.make(n, 1_000_000)
    }

    fn make(&self, n: usize, offset: u64) -> Dataset {
        let per = n / CLASSES;
        let mut x = Vec::with_capacity(n * FEATURES);
        let mut y = Vec::with_capacity(n);
        for cl in 0..CLASSES {
            let count = if cl == CLASSES - 1 { n - per * (CLASSES - 1) } else { per };
            for i in 0..count {
                x.extend(self.render(cl, offset + i as u64));
                y.push(cl as u8);
            }
        }
        Dataset { x, y, features: FEATURES, classes: CLASSES }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_distinct() {
        let g = SynthCifar::new(3);
        assert_eq!(g.render(0, 0), SynthCifar::new(3).render(0, 0));
        assert_ne!(g.render(0, 0), g.render(0, 1));
        assert_ne!(g.render(0, 0), g.render(1, 0));
    }

    #[test]
    fn shape_and_range() {
        let g = SynthCifar::new(3);
        let img = g.render(5, 2);
        assert_eq!(img.len(), FEATURES);
        assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn nearest_mean_beats_chance() {
        let g = SynthCifar::new(3);
        let train = g.dataset(300);
        let test = g.test_dataset(100);
        let mut means = vec![vec![0.0f32; FEATURES]; CLASSES];
        let mut counts = vec![0usize; CLASSES];
        for i in 0..train.len() {
            let (x, y) = train.sample(i);
            counts[y as usize] += 1;
            for (m, &v) in means[y as usize].iter_mut().zip(x) {
                *m += v;
            }
        }
        for (m, &cnt) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= cnt as f32;
            }
        }
        let mut correct = 0;
        for i in 0..test.len() {
            let (x, y) = test.sample(i);
            let pred = (0..CLASSES)
                .min_by(|&a, &b| {
                    let da: f32 =
                        x.iter().zip(&means[a]).map(|(u, v)| (u - v) * (u - v)).sum();
                    let db: f32 =
                        x.iter().zip(&means[b]).map(|(u, v)| (u - v) * (u - v)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if pred == y as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.5, "nearest-mean accuracy {acc}");
    }
}
