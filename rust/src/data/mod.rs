//! Dataset substrate: synthetic stand-ins for MNIST / CIFAR-10 plus the
//! Gaussian matrices of the §V-A distortion study, and the data
//! partitioners of §V-B.
//!
//! The image has no network access, so the real IDX/CIFAR archives cannot
//! be fetched. The experiments in the paper measure *relative* behavior of
//! update codecs under FedAvg; the procedural datasets below preserve what
//! matters for that comparison — 10 classes, intra-class structure +
//! noise, inter-class separation, same sample counts and image geometry —
//! and are fully deterministic given a seed (see DESIGN.md §2 for the
//! substitution argument).

mod gaussian;
mod partition;
mod synth_cifar;
mod synth_mnist;

pub use gaussian::{correlated_matrix, exp_decay_sigma, gaussian_matrix};
pub use partition::{partition, PartitionScheme};
pub use synth_cifar::SynthCifar;
pub use synth_mnist::SynthMnist;

/// A labeled classification dataset in flattened row-major form.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// `n × d` features, row-major.
    pub x: Vec<f32>,
    /// `n` labels in `0..classes`.
    pub y: Vec<u8>,
    pub features: usize,
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn sample(&self, i: usize) -> (&[f32], u8) {
        (&self.x[i * self.features..(i + 1) * self.features], self.y[i])
    }

    /// Extract the subset at `indices` (copying).
    pub fn subset(&self, indices: &[usize]) -> Dataset {
        let mut x = Vec::with_capacity(indices.len() * self.features);
        let mut y = Vec::with_capacity(indices.len());
        for &i in indices {
            x.extend_from_slice(&self.x[i * self.features..(i + 1) * self.features]);
            y.push(self.y[i]);
        }
        Dataset { x, y, features: self.features, classes: self.classes }
    }

    /// Per-class sample counts (label histogram).
    pub fn label_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.classes];
        for &y in &self.y {
            h[y as usize] += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subset_preserves_rows() {
        let ds = Dataset {
            x: (0..12).map(|v| v as f32).collect(),
            y: vec![0, 1, 2, 0],
            features: 3,
            classes: 3,
        };
        let s = ds.subset(&[1, 3]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.sample(0), (&[3.0f32, 4.0, 5.0][..], 1));
        assert_eq!(s.sample(1), (&[9.0f32, 10.0, 11.0][..], 0));
    }

    #[test]
    fn histogram_counts() {
        let ds = Dataset { x: vec![0.0; 5], y: vec![0, 1, 1, 2, 1], features: 1, classes: 3 };
        assert_eq!(ds.label_histogram(), vec![1, 3, 1]);
    }
}
