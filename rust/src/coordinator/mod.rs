//! Systems layer of the federated runtime: client fan-out, the metered
//! rate-constrained uplink, and aggregation — the Fig. 1 pipeline.
//!
//! Separated from `fl::` so the benches can exercise the coordinator with
//! mock trainers (isolating codec + aggregation cost from model compute),
//! and so the uplink budget enforcement lives in exactly one place.

mod uplink;

pub use uplink::{UplinkChannel, UplinkStats};

use crate::data::Dataset;
use crate::fl::Trainer;
use crate::prng::SplitMix64;
use crate::quantizer::{CodecContext, UpdateCodec};
use crate::util::threadpool::parallel_map;

/// Per-round statistics surfaced into `fl::HistoryRow`.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundStats {
    /// Total uplink payload this round (bits, all users).
    pub uplink_bits: usize,
    /// ‖ĥ − Σ α_k h_k‖²/m — the Theorem 2 quantity, measured.
    pub aggregate_distortion: f64,
    /// Wall time spent inside client jobs (sum over users, seconds).
    pub client_secs: f64,
}

/// Drives one federated round: fan out local training, collect encoded
/// updates through the uplink, decode, aggregate, apply.
pub struct RoundDriver {
    seed: u64,
    rate: f64,
    workers: usize,
}

impl RoundDriver {
    pub fn new(seed: u64, rate: f64, workers: usize) -> Self {
        Self { seed, rate, workers: workers.max(1) }
    }

    /// Execute round `round`, updating `w` in place. Returns stats.
    #[allow(clippy::too_many_arguments)]
    pub fn run_round(
        &self,
        round: u64,
        w: &mut [f32],
        shards: &[Dataset],
        trainer: &dyn Trainer,
        codec: &dyn UpdateCodec,
        alphas: &[f64],
        tau: usize,
        lr: f32,
        batch_size: usize,
    ) -> RoundStats {
        let m = w.len();
        let k = shards.len();
        let uplink = UplinkChannel::new(self.rate, codec.rate_constrained());
        let w_snapshot: &[f32] = w;

        // Fan out: each client trains locally and uploads an encoded
        // update. The closure returns (encoded, true update) — the latter
        // only for distortion metering (a real deployment obviously cannot
        // observe it; it never influences the aggregate).
        let results = parallel_map(k, self.workers, |u| {
            let t = crate::metrics::Timer::start();
            // derive per-(user, round) batch-sampling seed
            let local_seed =
                SplitMix64::new(self.seed ^ (u as u64) << 32 ^ round.wrapping_mul(0x9E37)).next();
            let w_new =
                trainer.local_update(w_snapshot, &shards[u], tau, lr, batch_size, local_seed);
            let mut h = w_new;
            for (hv, &wv) in h.iter_mut().zip(w_snapshot.iter()) {
                *hv -= wv;
            }
            let ctx = CodecContext::new(u as u64, round, self.seed, self.rate);
            let enc = codec.encode(&h, &ctx);
            (enc, h, t.elapsed_secs())
        });

        // Uplink + decode + aggregate.
        let mut agg = vec![0.0f64; m];
        let mut desired = vec![0.0f64; m];
        let mut client_secs = 0.0;
        for (u, (enc, h, secs)) in results.into_iter().enumerate() {
            client_secs += secs;
            uplink.transmit(u as u64, &enc, m);
            let ctx = CodecContext::new(u as u64, round, self.seed, self.rate);
            let dec = codec.decode(&enc, m, &ctx);
            let a = alphas[u];
            for i in 0..m {
                agg[i] += a * dec[i] as f64;
                desired[i] += a * h[i] as f64;
            }
        }

        // Apply the aggregated update: w ← w + Σ α_k ĥ_k (eq. 8).
        let mut dist = 0.0f64;
        for i in 0..m {
            let d = agg[i] - desired[i];
            dist += d * d;
            w[i] += agg[i] as f32;
        }

        RoundStats {
            uplink_bits: uplink.stats().total_bits,
            aggregate_distortion: dist / m as f64,
            client_secs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthMnist;
    use crate::fl::NativeTrainer;
    use crate::models::LogReg;
    use crate::quantizer;

    #[test]
    fn round_applies_aggregate_and_meters_bits() {
        let ds = SynthMnist::new(31).dataset(100);
        let shards = vec![ds.subset(&(0..50).collect::<Vec<_>>()), ds.subset(&(50..100).collect::<Vec<_>>())];
        let model = LogReg::new(ds.features, ds.classes, 1e-3);
        let trainer = NativeTrainer::new(model);
        let codec = quantizer::by_name("uveqfed-l2");
        let mut w = trainer.init_params(3);
        let w0 = w.clone();
        let driver = RoundDriver::new(5, 4.0, 2);
        let stats = driver.run_round(
            0,
            &mut w,
            &shards,
            &trainer,
            codec.as_ref(),
            &[0.5, 0.5],
            1,
            0.5,
            0,
        );
        assert_ne!(w, w0, "weights unchanged");
        assert!(stats.uplink_bits > 0);
        assert!(stats.uplink_bits <= 2 * (4.0 * w.len() as f64) as usize);
        assert!(stats.aggregate_distortion.is_finite());
    }

    #[test]
    fn identity_codec_zero_distortion() {
        let ds = SynthMnist::new(32).dataset(60);
        let shards = vec![ds.subset(&(0..30).collect::<Vec<_>>()), ds.subset(&(30..60).collect::<Vec<_>>())];
        let model = LogReg::new(ds.features, ds.classes, 1e-3);
        let trainer = NativeTrainer::new(model);
        let codec = quantizer::by_name("identity");
        let mut w = trainer.init_params(3);
        let driver = RoundDriver::new(5, 2.0, 2);
        let stats = driver.run_round(
            0,
            &mut w,
            &shards,
            &trainer,
            codec.as_ref(),
            &[0.5, 0.5],
            1,
            0.5,
            0,
        );
        assert!(stats.aggregate_distortion < 1e-12);
    }

    #[test]
    fn parallel_and_serial_rounds_agree() {
        // Determinism: worker count must not change the result.
        let ds = SynthMnist::new(33).dataset(120);
        let shards: Vec<_> =
            (0..4).map(|u| ds.subset(&(u * 30..(u + 1) * 30).collect::<Vec<_>>())).collect();
        let model = LogReg::new(ds.features, ds.classes, 1e-3);
        let trainer = NativeTrainer::new(model);
        let codec = quantizer::by_name("qsgd");
        let alphas = [0.25; 4];
        let run = |workers: usize| {
            let mut w = trainer.init_params(3);
            let driver = RoundDriver::new(5, 2.0, workers);
            driver.run_round(0, &mut w, &shards, &trainer, codec.as_ref(), &alphas, 1, 0.5, 0);
            w
        };
        assert_eq!(run(1), run(4));
    }
}
