//! Systems layer of the federated runtime: client fan-out, the metered
//! rate-constrained uplink, and aggregation — the Fig. 1 pipeline.
//!
//! Since the fleet refactor, `RoundDriver` is a thin preset over
//! [`crate::fleet::FleetDriver`]: full participation, no faults, every
//! update framed through the wire format and stream-folded into the O(m)
//! fixed-point aggregate as it arrives (the old driver buffered all K
//! decoded updates — O(K·m) — before aggregating). The uplink budget
//! enforcement still lives in exactly one place: [`UplinkChannel`].

pub mod broadcast;
pub mod rate_control;
mod uplink;

pub use broadcast::BroadcastPlanner;
pub use rate_control::{
    controller_by_name, thm2_bound_for_allocation, AllocRequest, CapacityProportional,
    RateController, TheoryGuided, UniformRate,
};
pub use uplink::{UplinkChannel, UplinkError, UplinkStats};

pub use crate::fleet::RoundSpec;

use crate::data::Dataset;
use crate::fleet::{FleetDriver, Scenario, ShardPool, VirtualClock};

/// Per-round statistics surfaced into `fl::HistoryRow`.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundStats {
    /// Total uplink payload this round (bits, all users).
    pub uplink_bits: usize,
    /// ‖ĥ − Σ α_k h_k‖²/m — the Theorem 2 quantity, measured.
    pub aggregate_distortion: f64,
    /// Wall time spent inside client jobs (sum over users, seconds).
    pub client_secs: f64,
}

/// Drives one federated round with every user participating: fan out
/// local training, stream encoded updates through the framed uplink,
/// decode, fold, apply.
pub struct RoundDriver {
    driver: FleetDriver,
}

impl RoundDriver {
    pub fn new(seed: u64, rate: f64, workers: usize) -> Self {
        Self { driver: FleetDriver::new(seed, rate, workers, Scenario::full()) }
    }

    /// Split the server fold across `n` aggregation shards (pass-through
    /// to [`FleetDriver::with_shards`]; bit-identical for any `n`).
    pub fn with_shards(mut self, n: usize) -> Self {
        self.driver = self.driver.with_shards(n);
        self
    }

    /// Execute the round described by `spec` over `shards` with
    /// per-client weights `alphas`, updating `w` in place. Returns stats.
    pub fn run_round(
        &self,
        spec: &RoundSpec<'_>,
        w: &mut [f32],
        shards: &[Dataset],
        alphas: &[f64],
    ) -> RoundStats {
        let pool = ShardPool::with_weights(shards, alphas);
        let mut clock = VirtualClock::new();
        let report = self.driver.run_round(spec, w, &pool, &mut clock);
        // The paper experiments' honesty depends on every update landing
        // and none cheating the rate budget (the seed panicked here too).
        assert_eq!(
            report.budget_violations, 0,
            "round {}: {} uplink budget violation(s) — codec bug",
            spec.round, report.budget_violations
        );
        assert_eq!(report.aggregated, shards.len(), "full participation");
        RoundStats {
            uplink_bits: report.uplink_bits,
            aggregate_distortion: report.aggregate_distortion,
            client_secs: report.client_secs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthMnist;
    use crate::fl::NativeTrainer;
    use crate::models::LogReg;
    use crate::quantizer;

    fn spec<'a>(
        trainer: &'a dyn crate::fl::Trainer,
        codec: &'a dyn crate::quantizer::UpdateCodec,
    ) -> RoundSpec<'a> {
        RoundSpec::new(0, 1, 0.5, 0, trainer, codec)
    }

    #[test]
    fn round_applies_aggregate_and_meters_bits() {
        let ds = SynthMnist::new(31).dataset(100);
        let shards = vec![ds.subset(&(0..50).collect::<Vec<_>>()), ds.subset(&(50..100).collect::<Vec<_>>())];
        let model = LogReg::new(ds.features, ds.classes, 1e-3);
        let trainer = NativeTrainer::new(model);
        let codec = quantizer::make("uveqfed-l2").unwrap();
        let mut w = trainer.init_params(3);
        let w0 = w.clone();
        let driver = RoundDriver::new(5, 4.0, 2);
        let stats =
            driver.run_round(&spec(&trainer, codec.as_ref()), &mut w, &shards, &[0.5, 0.5]);
        assert_ne!(w, w0, "weights unchanged");
        assert!(stats.uplink_bits > 0);
        assert!(stats.uplink_bits <= 2 * (4.0 * w.len() as f64) as usize);
        assert!(stats.aggregate_distortion.is_finite());
    }

    #[test]
    fn identity_codec_zero_distortion() {
        let ds = SynthMnist::new(32).dataset(60);
        let shards = vec![ds.subset(&(0..30).collect::<Vec<_>>()), ds.subset(&(30..60).collect::<Vec<_>>())];
        let model = LogReg::new(ds.features, ds.classes, 1e-3);
        let trainer = NativeTrainer::new(model);
        let codec = quantizer::make("identity").unwrap();
        let mut w = trainer.init_params(3);
        let driver = RoundDriver::new(5, 2.0, 2);
        let stats =
            driver.run_round(&spec(&trainer, codec.as_ref()), &mut w, &shards, &[0.5, 0.5]);
        assert!(stats.aggregate_distortion < 1e-12);
    }

    #[test]
    fn parallel_and_serial_rounds_agree() {
        // Determinism: worker count must not change the result.
        let ds = SynthMnist::new(33).dataset(120);
        let shards: Vec<_> =
            (0..4).map(|u| ds.subset(&(u * 30..(u + 1) * 30).collect::<Vec<_>>())).collect();
        let model = LogReg::new(ds.features, ds.classes, 1e-3);
        let trainer = NativeTrainer::new(model);
        let codec = quantizer::make("qsgd").unwrap();
        let alphas = [0.25; 4];
        let run = |workers: usize, agg_shards: usize| {
            let mut w = trainer.init_params(3);
            let driver = RoundDriver::new(5, 2.0, workers).with_shards(agg_shards);
            driver.run_round(&spec(&trainer, codec.as_ref()), &mut w, &shards, &alphas);
            w
        };
        assert_eq!(run(1, 1), run(4, 1));
        assert_eq!(run(1, 1), run(4, 3), "sharded fold must agree with the serial one");
    }
}
