//! Per-client rate allocation over heterogeneous uplinks.
//!
//! Given a round's arrivals, their channel capacities (bits per model
//! entry, from [`crate::fleet::channel`]) and a total rate mass to spend,
//! a [`RateController`] decides each client's quantization rate `R_u`.
//! Allocation works purely in bits-per-entry; the driver applies the
//! model size downstream, enforcing `⌊R_u·m⌋` bits per message via
//! [`crate::coordinator::UplinkChannel`].
//!
//! ## Policy contract
//!
//! Every policy must return one rate per request entry with
//!
//! * **capacity feasibility** — `R_u ≤ capacity_u` for every client, and
//! * **budget feasibility** — `Σ R_u ≤ total_rate` (+ f64 slack),
//!
//! both property-tested in `tests/integration_channel.rs` for arbitrary
//! inputs. Policies may *under*-spend (e.g. uniform cannot redistribute
//! mass stranded behind a slow client's capacity cap).
//!
//! ## Theory-guided allocation
//!
//! Under ECDQ the per-entry distortion of a rate-`R` UVeQFed encode
//! scales like `σ̄²(s(R)) ∝ 2^{−2R}` (the high-rate entropy-coded dither
//! quantization slope), and Theorem 2 weighs client `k`'s error energy by
//! `α_k²` in the aggregate bound. [`TheoryGuided`] therefore minimizes
//! `Σ_k α_k²·2^{−2R_k}` subject to the two feasibility constraints —
//! classic reverse water-filling, solved by bisection on the water level —
//! and [`thm2_bound_for_allocation`] evaluates any allocation through
//! [`crate::theory::thm2_aggregate_bound`] so policies can be compared on
//! the paper's own yardstick (the acceptance test does exactly that).

use crate::theory::thm2_aggregate_bound;

/// One round's allocation problem: parallel slices describe the arrivals.
#[derive(Debug, Clone, Copy)]
pub struct AllocRequest<'a> {
    /// Per-client uplink capacity, bits per model entry.
    pub capacities: &'a [f64],
    /// Per-client aggregation weights α (unnormalized is fine — policies
    /// only use relative magnitudes).
    pub alphas: &'a [f64],
    /// Total rate mass to spend this round: `Σ R_u ≤ total_rate`
    /// (bits per entry, summed over clients).
    pub total_rate: f64,
}

impl AllocRequest<'_> {
    fn check(&self) {
        assert_eq!(
            self.capacities.len(),
            self.alphas.len(),
            "capacities/alphas length mismatch"
        );
        assert!(
            self.total_rate.is_finite() && self.total_rate >= 0.0,
            "total_rate must be finite and ≥ 0"
        );
    }
}

/// A per-round rate allocation policy. See the module docs for the
/// contract every implementation must satisfy.
pub trait RateController: Send + Sync {
    fn name(&self) -> &'static str;

    /// Assign one rate (bits/entry) per request entry.
    fn allocate(&self, req: &AllocRequest<'_>) -> Vec<f64>;
}

/// Everyone gets the same rate `total/K`, clamped to their capacity.
/// Mass stranded behind a capacity cap is *not* redistributed — this is
/// the legacy fixed-`R` behavior made capacity-aware, and the baseline
/// the other policies are measured against.
#[derive(Debug, Clone, Copy, Default)]
pub struct UniformRate;

impl RateController for UniformRate {
    fn name(&self) -> &'static str {
        "uniform"
    }

    fn allocate(&self, req: &AllocRequest<'_>) -> Vec<f64> {
        req.check();
        let k = req.capacities.len();
        if k == 0 {
            return Vec::new();
        }
        let share = req.total_rate / k as f64;
        req.capacities.iter().map(|&c| share.min(c.max(0.0))).collect()
    }
}

/// Rates proportional to capacity: `R_u = total · cap_u / Σcap`, clamped
/// to each capacity. Spends the budget where the pipe is wide — the
/// throughput-maximizing heuristic real fleets deploy first.
#[derive(Debug, Clone, Copy, Default)]
pub struct CapacityProportional;

impl RateController for CapacityProportional {
    fn name(&self) -> &'static str {
        "proportional"
    }

    fn allocate(&self, req: &AllocRequest<'_>) -> Vec<f64> {
        req.check();
        let caps: Vec<f64> = req.capacities.iter().map(|&c| c.max(0.0)).collect();
        let total_cap: f64 = caps.iter().sum();
        if total_cap <= 0.0 {
            return vec![0.0; caps.len()];
        }
        // scale ≤ 1 keeps every rate under its own capacity AND the sum
        // under the budget in one step.
        let scale = (req.total_rate / total_cap).min(1.0);
        caps.iter().map(|&c| c * scale).collect()
    }
}

/// Reverse water-filling on the Theorem-2 objective: minimize
/// `Σ_k α_k²·2^{−2R_k}` s.t. `Σ R_k ≤ total` and `0 ≤ R_k ≤ cap_k`.
///
/// The unconstrained stationary point is `R_k = c + ½·log₂(α_k²)` for a
/// common water level `c`; clamping to `[0, cap_k]` and bisecting on `c`
/// until the rate mass is spent gives the exact constrained optimum
/// (the objective is convex and separable).
#[derive(Debug, Clone, Copy, Default)]
pub struct TheoryGuided;

impl TheoryGuided {
    #[inline]
    fn rate_at(level: f64, w: f64, cap: f64) -> f64 {
        if w <= 0.0 || cap <= 0.0 {
            return 0.0;
        }
        (level + 0.5 * w.log2()).clamp(0.0, cap)
    }

    fn rates_at(level: f64, weights: &[f64], caps: &[f64]) -> Vec<f64> {
        weights.iter().zip(caps).map(|(&w, &cap)| Self::rate_at(level, w, cap)).collect()
    }

    /// Σ of [`Self::rates_at`] without materializing the vector — the
    /// bisection calls this 64 times per allocation (100k-client fleets
    /// would otherwise churn an O(K) buffer per probe).
    fn sum_at(level: f64, weights: &[f64], caps: &[f64]) -> f64 {
        weights.iter().zip(caps).map(|(&w, &cap)| Self::rate_at(level, w, cap)).sum()
    }
}

impl RateController for TheoryGuided {
    fn name(&self) -> &'static str {
        "theory"
    }

    fn allocate(&self, req: &AllocRequest<'_>) -> Vec<f64> {
        req.check();
        let caps: Vec<f64> = req.capacities.iter().map(|&c| c.max(0.0)).collect();
        if caps.is_empty() {
            return Vec::new();
        }
        // Weights α_k², normalized for numeric stability (the optimum is
        // invariant to a common weight scale — it shifts the level only).
        let max_a = req.alphas.iter().cloned().fold(0.0f64, |a, b| a.max(b.abs()));
        let weights: Vec<f64> = if max_a > 0.0 {
            req.alphas.iter().map(|&a| (a / max_a) * (a / max_a)).collect()
        } else {
            vec![1.0; caps.len()]
        };
        let spendable: f64 = req.total_rate.min(caps.iter().sum());
        if spendable <= 0.0 {
            return vec![0.0; caps.len()];
        }
        // Bisect the water level: Σ rates(level) is non-decreasing in the
        // level, 0 at lo and ≥ spendable at hi.
        let max_cap = caps.iter().cloned().fold(0.0f64, f64::max);
        let mut lo = -64.0; // level where every clamped rate is 0
        let mut hi = max_cap + 64.0; // level where every rate sits at its cap
        for _ in 0..64 {
            let mid = 0.5 * (lo + hi);
            let sum = Self::sum_at(mid, &weights, &caps);
            if sum > spendable {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        // `lo` is the highest probed level that did not overshoot.
        Self::rates_at(lo, &weights, &caps)
    }
}

/// Controller by config/CLI name.
pub fn controller_by_name(name: &str) -> crate::Result<Box<dyn RateController>> {
    Ok(match name {
        "uniform" => Box::new(UniformRate),
        "proportional" | "capacity" => Box::new(CapacityProportional),
        "theory" | "thm2" => Box::new(TheoryGuided),
        other => {
            crate::bail!("unknown rate policy '{other}' (uniform|proportional|theory)")
        }
    })
}

/// Evaluate an allocation on the Theorem-2 yardstick: the predicted
/// aggregate-distortion bound `Σ_k thm2(M, ζ, σ̄²·2^{−2R_k}, τ, Ση², α_k²)`
/// with the paper's ζ = 2/√M convention, τ = 1, unit step mass and the
/// scalar-lattice base moment — a *comparison* metric (common constants
/// cancel between policies), not an absolute distortion prediction.
pub fn thm2_bound_for_allocation(rates: &[f64], alphas: &[f64], m: usize) -> f64 {
    assert_eq!(rates.len(), alphas.len());
    let m_sub = m.max(1);
    let zeta = 2.0 / (m_sub as f64).sqrt();
    let alpha_total: f64 = alphas.iter().sum();
    let norm = if alpha_total > 0.0 { alpha_total } else { 1.0 };
    rates
        .iter()
        .zip(alphas)
        .map(|(&r, &a)| {
            let an = a / norm;
            // σ̄² of the rate-R ECDQ lattice, relative units: 2^{−2R}/12.
            let sigma2 = (-2.0 * r).exp2() / 12.0;
            thm2_aggregate_bound(m_sub, zeta, sigma2, 1, 1.0, an * an)
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req<'a>(caps: &'a [f64], alphas: &'a [f64], total: f64) -> AllocRequest<'a> {
        AllocRequest { capacities: caps, alphas, total_rate: total }
    }

    fn assert_feasible(rates: &[f64], caps: &[f64], total: f64, tag: &str) {
        assert_eq!(rates.len(), caps.len(), "{tag}");
        let sum: f64 = rates.iter().sum();
        assert!(sum <= total + 1e-9, "{tag}: Σ rates {sum} > total {total}");
        for (i, (&r, &c)) in rates.iter().zip(caps).enumerate() {
            assert!(r >= 0.0, "{tag}: negative rate {r} at {i}");
            assert!(r <= c.max(0.0) + 1e-9, "{tag}: rate {r} > capacity {c} at {i}");
        }
    }

    #[test]
    fn uniform_clamps_to_capacity_without_redistribution() {
        let caps = [4.0, 4.0, 0.5];
        let alphas = [1.0, 1.0, 1.0];
        let rates = UniformRate.allocate(&req(&caps, &alphas, 6.0));
        assert_feasible(&rates, &caps, 6.0, "uniform");
        assert_eq!(rates[0], 2.0);
        assert_eq!(rates[1], 2.0);
        assert_eq!(rates[2], 0.5, "capped client keeps its capacity, mass is stranded");
    }

    #[test]
    fn proportional_spends_where_the_pipe_is_wide() {
        let caps = [1.0, 2.0, 4.0];
        let alphas = [1.0, 1.0, 1.0];
        let rates = CapacityProportional.allocate(&req(&caps, &alphas, 3.5));
        assert_feasible(&rates, &caps, 3.5, "proportional");
        assert!((rates[2] / rates[0] - 4.0).abs() < 1e-9, "{rates:?}");
        let sum: f64 = rates.iter().sum();
        assert!((sum - 3.5).abs() < 1e-9, "budget under capacity must be fully spent");
        // Budget above total capacity: everyone at their cap.
        let rates = CapacityProportional.allocate(&req(&caps, &alphas, 100.0));
        assert_eq!(rates, caps.to_vec());
    }

    #[test]
    fn theory_guided_spends_the_budget_and_respects_caps() {
        let caps = [8.0, 8.0, 8.0, 1.0];
        let alphas = [4.0, 2.0, 1.0, 4.0];
        let total = 10.0;
        let rates = TheoryGuided.allocate(&req(&caps, &alphas, total));
        assert_feasible(&rates, &caps, total, "theory");
        let sum: f64 = rates.iter().sum();
        assert!((sum - total).abs() < 1e-6, "water-filling must spend the mass: {sum}");
        // Heavier α ⇒ more rate (caps permitting).
        assert!(rates[0] > rates[1] && rates[1] > rates[2], "{rates:?}");
        // The capped heavy client saturates its capacity.
        assert!((rates[3] - 1.0).abs() < 1e-6, "{rates:?}");
    }

    #[test]
    fn theory_beats_uniform_on_the_thm2_bound_at_equal_total_bits() {
        // Heterogeneous weights + 3 capacity tiers: the acceptance-
        // criterion comparison in unit form.
        let caps = [1.0, 2.0, 4.0, 1.0, 2.0, 4.0, 1.0, 2.0, 4.0];
        let alphas = [3.0, 1.0, 2.0, 1.0, 3.0, 1.0, 2.0, 1.0, 3.0];
        let total = 12.0;
        let r = req(&caps, &alphas, total);
        let uni = UniformRate.allocate(&r);
        let thy = TheoryGuided.allocate(&r);
        // Equal total bits: compare at the mass the weaker spender used.
        let spent_uni: f64 = uni.iter().sum();
        let thy_eq = TheoryGuided.allocate(&req(&caps, &alphas, spent_uni));
        let spent_thy: f64 = thy_eq.iter().sum();
        assert!(
            (spent_thy - spent_uni).abs() < 1e-6,
            "equal-bits comparison: {spent_thy} vs {spent_uni}"
        );
        let b_uni = thm2_bound_for_allocation(&uni, &alphas, 1000);
        let b_thy = thm2_bound_for_allocation(&thy_eq, &alphas, 1000);
        assert!(
            b_thy < b_uni,
            "theory-guided bound {b_thy} must beat uniform {b_uni} at equal bits"
        );
        // And the full-budget allocation is no worse still.
        let b_full = thm2_bound_for_allocation(&thy, &alphas, 1000);
        assert!(b_full <= b_thy + 1e-12);
    }

    #[test]
    fn degenerate_requests_are_safe() {
        for ctl in [
            &UniformRate as &dyn RateController,
            &CapacityProportional,
            &TheoryGuided,
        ] {
            assert!(ctl.allocate(&req(&[], &[], 5.0)).is_empty(), "{}", ctl.name());
            let rates = ctl.allocate(&req(&[0.0, 0.0], &[1.0, 1.0], 5.0));
            assert_feasible(&rates, &[0.0, 0.0], 5.0, ctl.name());
            let rates = ctl.allocate(&req(&[2.0, 2.0], &[0.0, 0.0], 0.0));
            assert!(rates.iter().all(|&r| r == 0.0), "{}: {rates:?}", ctl.name());
        }
    }

    #[test]
    fn controller_registry_resolves_and_errors() {
        for (name, want) in
            [("uniform", "uniform"), ("proportional", "proportional"), ("thm2", "theory")]
        {
            assert_eq!(controller_by_name(name).unwrap().name(), want);
        }
        let err = controller_by_name("nope").unwrap_err().to_string();
        assert!(err.contains("unknown rate policy"), "{err}");
    }
}
