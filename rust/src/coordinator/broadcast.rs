//! Coordinator-side broadcast planner: the server half of the downlink.
//!
//! [`crate::fleet::downlink::SyncTable`] owns the per-client mechanics
//! (stale references, error feedback, frame encode); this planner owns
//! the *policy*: which rate each client's broadcast gets — the round's
//! [`crate::fleet::DownlinkSpec`] rate, capped by the downlink capacity
//! when an asymmetric link is modeled — and the serialized access to the
//! table from `FleetDriver::run_round`. Broadcasts happen on the
//! coordinator thread in ascending arrival order, so the planner's lock
//! is uncontended; it exists only so `run_round(&self)` can mutate
//! cross-round downlink state, mirroring the `Channel` Markov cache.
//!
//! Lock-poisoning policy (DESIGN.md §13): unlike the telemetry collector
//! — which *recovers* a poisoned lock because observability state is
//! droppable — this table **propagates** poisoning. A panic mid-broadcast
//! can leave a client's stale reference or error-feedback vector half
//! updated; silently recovering would desynchronize the server's idea of
//! what the client holds and corrupt every later delta against it. The
//! `expect`s below are therefore deliberate: cross-round protocol state
//! is only trustworthy if no writer ever died holding the lock.

use crate::fleet::channel::Channel;
use crate::fleet::downlink::{BroadcastOutcome, DownlinkSpec, SyncTable};
use std::sync::Mutex;

/// Per-driver downlink state: the stale-model table plus an optional
/// downlink capacity model for asymmetric up/down links.
#[derive(Debug, Default)]
pub struct BroadcastPlanner {
    table: Mutex<SyncTable>,
    channel: Option<Channel>,
}

impl BroadcastPlanner {
    /// Empty planner: no clients tracked, no downlink capacity model.
    pub fn new() -> Self {
        Self::default()
    }

    /// Model per-client downlink capacity; each broadcast's rate becomes
    /// `min(spec.rate, capacity(user, round))`.
    pub fn with_channel(mut self, channel: Channel) -> Self {
        self.channel = Some(channel);
        self
    }

    /// The downlink capacity model, when one is set.
    pub fn channel(&self) -> Option<&Channel> {
        self.channel.as_ref()
    }

    /// Effective downlink rate for one client's broadcast.
    pub fn rate_for(&self, spec: &DownlinkSpec<'_>, user: u64, round: u64) -> f64 {
        match &self.channel {
            Some(ch) => spec.rate.min(ch.capacity(user, round)),
            None => spec.rate,
        }
    }

    /// Broadcast the global model `w` to `user`, updating the table.
    pub fn broadcast(
        &self,
        spec: &DownlinkSpec<'_>,
        seed: u64,
        round: u64,
        user: u64,
        w: &[f32],
    ) -> BroadcastOutcome {
        let rate = self.rate_for(spec, user, round);
        self.table
            .lock()
            .expect("downlink sync table poisoned mid-broadcast (DESIGN.md §13)")
            .broadcast(spec.codec, rate, spec.resync_every, seed, round, user, w)
    }

    /// Number of clients with tracked downlink state.
    pub fn tracked_clients(&self) -> usize {
        self.table.lock().expect("downlink sync table poisoned").len()
    }

    /// The round `user` was last synced at, if ever contacted.
    pub fn ref_round(&self, user: u64) -> Option<u64> {
        self.table.lock().expect("downlink sync table poisoned").ref_round(user)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::channel::ChannelModel;
    use crate::quantizer;

    #[test]
    fn downlink_channel_caps_the_broadcast_rate() {
        let codec = quantizer::make("uveqfed-l2").unwrap();
        let spec = DownlinkSpec::new(codec.as_ref(), 4.0);
        let free = BroadcastPlanner::new();
        assert_eq!(free.rate_for(&spec, 3, 0), 4.0);
        let capped = BroadcastPlanner::new()
            .with_channel(Channel::new(ChannelModel::Fixed { rate: 1.5 }, 9));
        assert_eq!(capped.rate_for(&spec, 3, 0), 1.5);
    }

    #[test]
    fn planner_tracks_clients_across_broadcasts() {
        let codec = quantizer::make("uveqfed-l2").unwrap();
        let spec = DownlinkSpec::new(codec.as_ref(), 2.0);
        let planner = BroadcastPlanner::new();
        assert_eq!(planner.tracked_clients(), 0);
        let w = vec![0.25f32; 64];
        let out = planner.broadcast(&spec, 7, 0, 11, &w);
        assert!(out.resync);
        assert_eq!(planner.tracked_clients(), 1);
        assert_eq!(planner.ref_round(11), Some(0));
        assert_eq!(planner.ref_round(12), None);
    }
}
