//! The rate-constrained uplink of Fig. 1: a bit-metered channel that
//! enforces the per-message budget `R·m` for rate-constrained codecs and
//! tallies exact usage for the experiment reports.

use crate::quantizer::Encoded;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};

#[derive(Debug, Default, Clone, Copy)]
pub struct UplinkStats {
    pub messages: usize,
    pub total_bits: usize,
    pub max_message_bits: usize,
}

/// Why a message was refused by [`UplinkChannel::try_transmit`]. Rejected
/// messages are not metered — they never entered the channel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UplinkError {
    /// A rate-constrained codec exceeded its `R·m` budget — a codec bug;
    /// the experiments' honesty depends on catching it.
    OverBudget { user: u64, bits: usize, budget: usize },
    /// Claimed bit count exceeds the physical payload (corrupt
    /// accounting).
    PhantomBits { user: u64, bits: usize, capacity: usize },
}

impl fmt::Display for UplinkError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            UplinkError::OverBudget { user, bits, budget } => {
                write!(f, "user {user}: uplink over budget ({bits} > {budget} bits)")
            }
            UplinkError::PhantomBits { user, bits, capacity } => {
                write!(
                    f,
                    "user {user}: bit accounting exceeds physical payload ({bits} > {capacity} bits)"
                )
            }
        }
    }
}

impl std::error::Error for UplinkError {}

/// Thread-safe uplink meter (clients transmit concurrently).
#[derive(Debug)]
pub struct UplinkChannel {
    rate: f64,
    enforce: bool,
    messages: AtomicUsize,
    total_bits: AtomicUsize,
    max_bits: AtomicUsize,
}

impl UplinkChannel {
    pub fn new(rate: f64, enforce: bool) -> Self {
        Self {
            rate,
            enforce,
            messages: AtomicUsize::new(0),
            total_bits: AtomicUsize::new(0),
            max_bits: AtomicUsize::new(0),
        }
    }

    /// Account one uplink message of an `m`-parameter update, refusing it
    /// with a typed error when the budget or physical-payload invariants
    /// are violated — so fleet fault-injection can observe and count
    /// violations instead of aborting the whole simulation.
    pub fn try_transmit(&self, user: u64, enc: &Encoded, m: usize) -> Result<(), UplinkError> {
        self.try_transmit_rate(user, enc, m, self.rate)
    }

    /// [`Self::try_transmit`] with a per-message rate override — the
    /// heterogeneous-uplink path, where the coordinator's rate controller
    /// assigns each client its own budget (`fleet::RatePlan`).
    pub fn try_transmit_rate(
        &self,
        user: u64,
        enc: &Encoded,
        m: usize,
        rate: f64,
    ) -> Result<(), UplinkError> {
        let budget = (rate * m as f64).floor() as usize;
        if self.enforce && enc.bits > budget {
            return Err(UplinkError::OverBudget { user, bits: enc.bits, budget });
        }
        let capacity = enc.bytes.len() * 8;
        if enc.bits > capacity {
            return Err(UplinkError::PhantomBits { user, bits: enc.bits, capacity });
        }
        self.messages.fetch_add(1, Ordering::Relaxed);
        self.total_bits.fetch_add(enc.bits, Ordering::Relaxed);
        self.max_bits.fetch_max(enc.bits, Ordering::Relaxed);
        Ok(())
    }

    /// Panicking wrapper over [`Self::try_transmit`] for callers that
    /// treat any violation as a hard bug (the paper-experiment paths
    /// assert the same invariant on the round report).
    pub fn transmit(&self, user: u64, enc: &Encoded, m: usize) {
        if let Err(e) = self.try_transmit(user, enc, m) {
            panic!("{e}");
        }
    }

    pub fn stats(&self) -> UplinkStats {
        UplinkStats {
            messages: self.messages.load(Ordering::Relaxed),
            total_bits: self.total_bits.load(Ordering::Relaxed),
            max_message_bits: self.max_bits.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enc(bits: usize) -> Encoded {
        Encoded { bytes: vec![0; bits.div_ceil(8)], bits }
    }

    #[test]
    fn accounting_accumulates() {
        let ch = UplinkChannel::new(2.0, true);
        ch.transmit(0, &enc(100), 100);
        ch.transmit(1, &enc(150), 100);
        let s = ch.stats();
        assert_eq!(s.messages, 2);
        assert_eq!(s.total_bits, 250);
        assert_eq!(s.max_message_bits, 150);
    }

    #[test]
    fn over_budget_is_a_typed_error_and_not_metered() {
        let ch = UplinkChannel::new(1.0, true);
        let err = ch.try_transmit(3, &enc(101), 100).unwrap_err();
        assert_eq!(err, UplinkError::OverBudget { user: 3, bits: 101, budget: 100 });
        assert_eq!(ch.stats().messages, 0, "rejected messages must not be metered");
        assert_eq!(ch.stats().total_bits, 0);
    }

    #[test]
    fn phantom_bits_is_a_typed_error() {
        let ch = UplinkChannel::new(8.0, true);
        let bad = Encoded { bytes: vec![0; 1], bits: 100 };
        let err = ch.try_transmit(7, &bad, 100).unwrap_err();
        assert_eq!(err, UplinkError::PhantomBits { user: 7, bits: 100, capacity: 8 });
    }

    #[test]
    #[should_panic(expected = "over budget")]
    fn over_budget_panics_when_enforced() {
        let ch = UplinkChannel::new(1.0, true);
        ch.transmit(0, &enc(101), 100);
    }

    #[test]
    fn per_message_rate_override_sets_the_budget() {
        // Channel rate 1.0, but this client was assigned 2.0 bits/entry.
        let ch = UplinkChannel::new(1.0, true);
        ch.try_transmit_rate(4, &enc(150), 100, 2.0).unwrap();
        let err = ch.try_transmit_rate(4, &enc(150), 100, 1.0).unwrap_err();
        assert_eq!(err, UplinkError::OverBudget { user: 4, bits: 150, budget: 100 });
        assert_eq!(ch.stats().messages, 1);
    }

    #[test]
    fn unconstrained_codec_not_enforced() {
        let ch = UplinkChannel::new(1.0, false);
        ch.transmit(0, &enc(100_000), 100);
        assert_eq!(ch.stats().total_bits, 100_000);
    }

    #[test]
    #[should_panic(expected = "physical payload")]
    fn phantom_bits_rejected() {
        let ch = UplinkChannel::new(8.0, true);
        let bad = Encoded { bytes: vec![0; 1], bits: 100 };
        ch.transmit(0, &bad, 100);
    }
}
