//! The rate-constrained uplink of Fig. 1: a bit-metered channel that
//! enforces the per-message budget `R·m` for rate-constrained codecs and
//! tallies exact usage for the experiment reports.

use crate::quantizer::Encoded;
use std::sync::atomic::{AtomicUsize, Ordering};

#[derive(Debug, Default, Clone, Copy)]
pub struct UplinkStats {
    pub messages: usize,
    pub total_bits: usize,
    pub max_message_bits: usize,
}

/// Thread-safe uplink meter (clients transmit concurrently).
#[derive(Debug)]
pub struct UplinkChannel {
    rate: f64,
    enforce: bool,
    messages: AtomicUsize,
    total_bits: AtomicUsize,
    max_bits: AtomicUsize,
}

impl UplinkChannel {
    pub fn new(rate: f64, enforce: bool) -> Self {
        Self {
            rate,
            enforce,
            messages: AtomicUsize::new(0),
            total_bits: AtomicUsize::new(0),
            max_bits: AtomicUsize::new(0),
        }
    }

    /// Account one uplink message of an `m`-parameter update. Panics if a
    /// rate-constrained codec exceeded its budget — that is a codec bug,
    /// and the experiments' honesty depends on catching it.
    pub fn transmit(&self, user: u64, enc: &Encoded, m: usize) {
        let budget = (self.rate * m as f64).floor() as usize;
        if self.enforce {
            assert!(
                enc.bits <= budget,
                "user {user}: uplink over budget ({} > {budget} bits)",
                enc.bits
            );
        }
        assert!(
            enc.bits <= enc.bytes.len() * 8,
            "bit accounting exceeds physical payload"
        );
        self.messages.fetch_add(1, Ordering::Relaxed);
        self.total_bits.fetch_add(enc.bits, Ordering::Relaxed);
        self.max_bits.fetch_max(enc.bits, Ordering::Relaxed);
    }

    pub fn stats(&self) -> UplinkStats {
        UplinkStats {
            messages: self.messages.load(Ordering::Relaxed),
            total_bits: self.total_bits.load(Ordering::Relaxed),
            max_message_bits: self.max_bits.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enc(bits: usize) -> Encoded {
        Encoded { bytes: vec![0; bits.div_ceil(8)], bits }
    }

    #[test]
    fn accounting_accumulates() {
        let ch = UplinkChannel::new(2.0, true);
        ch.transmit(0, &enc(100), 100);
        ch.transmit(1, &enc(150), 100);
        let s = ch.stats();
        assert_eq!(s.messages, 2);
        assert_eq!(s.total_bits, 250);
        assert_eq!(s.max_message_bits, 150);
    }

    #[test]
    #[should_panic(expected = "over budget")]
    fn over_budget_panics_when_enforced() {
        let ch = UplinkChannel::new(1.0, true);
        ch.transmit(0, &enc(101), 100);
    }

    #[test]
    fn unconstrained_codec_not_enforced() {
        let ch = UplinkChannel::new(1.0, false);
        ch.transmit(0, &enc(100_000), 100);
        assert_eq!(ch.stats().total_bits, 100_000);
    }

    #[test]
    #[should_panic(expected = "physical payload")]
    fn phantom_bits_rejected() {
        let ch = UplinkChannel::new(8.0, true);
        let bad = Encoded { bytes: vec![0; 1], bits: 100 };
        ch.transmit(0, &bad, 100);
    }
}
