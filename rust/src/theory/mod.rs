//! Evaluators for the paper's theoretical bounds (Theorems 1–3), used by
//! `examples/theory_validation` to print measured-vs-predicted tables.

use crate::lattice::Lattice;

/// Theorem 1 (eq. 10): conditional quantization-error energy
/// `E{‖ε‖² | h} = ζ²‖h‖²·M·σ̄²_Λ`.
///
/// `sigma2` must be the second moment of the *scaled* lattice actually
/// used for encoding (`s²·σ̄²` when the rate controller picked scale `s`).
pub fn thm1_error_energy(zeta: f64, h_norm: f64, m_subvectors: usize, sigma2: f64) -> f64 {
    zeta * zeta * h_norm * h_norm * m_subvectors as f64 * sigma2
}

/// Theorem 2 (eq. 11): bound on `E‖w_{t+τ} − w^des‖²`.
///
/// * `eta_sq_sum` — `Σ_{t'=t}^{t+τ-1} η_{t'}²`
/// * `alpha_sq_xi_sq` — `Σ_k α_k²·ξ_k²`
pub fn thm2_aggregate_bound(
    m_subvectors: usize,
    zeta: f64,
    sigma2: f64,
    tau: usize,
    eta_sq_sum: f64,
    alpha_sq_xi_sq: f64,
) -> f64 {
    m_subvectors as f64 * zeta * zeta * sigma2 * tau as f64 * eta_sq_sum * alpha_sq_xi_sq
}

/// Inputs for the Theorem 3 convergence envelope.
#[derive(Debug, Clone)]
pub struct Thm3Params {
    pub rho_s: f64,
    pub rho_c: f64,
    pub tau: usize,
    /// `Σ_k α_k²·ξ_k²`.
    pub alpha_sq_xi_sq: f64,
    /// `Σ_k α_k·ξ_k²`.
    pub alpha_xi_sq: f64,
    /// Heterogeneity gap ψ (eq. 12).
    pub psi: f64,
    /// `M·ζ²·σ̄²_Λ` for the deployed quantizer (0 ⇒ unquantized FedAvg).
    pub m_zeta_sq_sigma2: f64,
    /// `‖w₀ − w°‖²`.
    pub init_dist_sq: f64,
}

impl Thm3Params {
    /// The constant `b` of Theorem 3.
    pub fn b(&self) -> f64 {
        let tau = self.tau as f64;
        (1.0 + 4.0 * self.m_zeta_sq_sigma2 * tau * tau) * self.alpha_sq_xi_sq
            + 6.0 * self.rho_s * self.psi
            + 8.0 * (tau - 1.0) * (tau - 1.0) * self.alpha_xi_sq
    }

    /// `γ = τ·max(1, 4ρ_s/ρ_c)`.
    pub fn gamma(&self) -> f64 {
        self.tau as f64 * (4.0 * self.rho_s / self.rho_c).max(1.0)
    }

    /// The step size schedule of Theorem 3: `η_t = τ/(ρ_c(t+γ))`.
    pub fn eta(&self, t: usize) -> f64 {
        self.tau as f64 / (self.rho_c * (t as f64 + self.gamma()))
    }

    /// The bound (13) on `E{F(w_t)} − F(w°)`.
    pub fn bound(&self, t: usize) -> f64 {
        let gamma = self.gamma();
        let tau = self.tau as f64;
        let nu = ((self.rho_c * self.rho_c + tau * tau * self.b()) / (tau * self.rho_c))
            .max(gamma * self.init_dist_sq);
        self.rho_s / (2.0 * (t as f64 + gamma)) * nu
    }
}

/// Convenience: σ̄² of a lattice scaled by `s`.
pub fn scaled_sigma2(lat: &dyn Lattice, s: f64) -> f64 {
    lat.second_moment() * s * s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> Thm3Params {
        Thm3Params {
            rho_s: 4.0,
            rho_c: 0.1,
            tau: 2,
            alpha_sq_xi_sq: 0.5,
            alpha_xi_sq: 1.0,
            psi: 0.05,
            m_zeta_sq_sigma2: 0.01,
            init_dist_sq: 1.0,
        }
    }

    #[test]
    fn bound_decays_like_one_over_t() {
        let p = params();
        let b1 = p.bound(10);
        let b2 = p.bound(1000);
        // ratio ≈ (1000+γ)/(10+γ)
        let g = p.gamma();
        let expect = (1000.0 + g) / (10.0 + g);
        assert!((b1 / b2 - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn quantization_inflates_b() {
        let mut p = params();
        let b_q = p.b();
        p.m_zeta_sq_sigma2 = 0.0;
        let b_unq = p.b();
        assert!(b_q > b_unq);
    }

    #[test]
    fn eta_matches_schedule() {
        let p = params();
        let g = p.gamma();
        assert!((p.eta(0) - 2.0 / (0.1 * g)).abs() < 1e-12);
        assert!(p.eta(10) < p.eta(0));
    }

    #[test]
    fn thm1_linear_in_everything() {
        let base = thm1_error_energy(0.1, 2.0, 100, 0.05);
        assert!((thm1_error_energy(0.2, 2.0, 100, 0.05) / base - 4.0).abs() < 1e-12);
        assert!((thm1_error_energy(0.1, 4.0, 100, 0.05) / base - 4.0).abs() < 1e-12);
        assert!((thm1_error_energy(0.1, 2.0, 200, 0.05) / base - 2.0).abs() < 1e-12);
    }
}
