//! Uniform quantization with structured random rotation [12].
//!
//! The update is rotated by a randomized Hadamard transform `(1/√n)·H·D`
//! (sign-flip diagonal `D` drawn from the shared-seed stream — a shared
//! rotation needs no extra uplink bits), then quantized with a fixed-width
//! uniform scalar quantizer over the rotated dynamic range. The rotation
//! flattens the coordinate distribution, shrinking the range a uniform
//! quantizer must cover — this is the "random rotation" baseline of
//! Konečný et al. the paper compares against in Figs. 4–7.
//!
//! Sessions are buffered on both sides: the FWHT is a global transform of
//! the whole (power-of-two padded) vector, on encode and on decode.

use super::{
    BufferedSink, CodecContext, DecodeStream, Encoded, EncodeSink, SliceStream, UpdateCodec,
};
use crate::entropy::{BitReader, BitWriter};
use crate::prng::{Rng, StreamKind};

#[derive(Debug, Clone, Copy, Default)]
pub struct RotationUniform;

/// In-place fast Walsh–Hadamard transform (unnormalized). Length must be a
/// power of two.
pub fn fwht(x: &mut [f64]) {
    let n = x.len();
    debug_assert!(n.is_power_of_two());
    let mut h = 1;
    while h < n {
        for i in (0..n).step_by(2 * h) {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
        }
        h *= 2;
    }
}

fn sign_diag(n: usize, ctx: &CodecContext) -> Vec<f64> {
    let mut rng = ctx.crand.stream(ctx.user, ctx.round, StreamKind::Rotation);
    (0..n).map(|_| rng.sign() as f64).collect()
}

impl RotationUniform {
    /// Whole-buffer encoder (runs at `EncodeSink::finish`).
    fn encode_whole(&self, h: &[f32], ctx: &CodecContext) -> Encoded {
        let m = h.len();
        let n2 = m.next_power_of_two();
        let budget = ctx.budget_bits(m);
        // Fixed-width bits per transmitted rotated coordinate; when the
        // budget cannot cover all n2 coordinates at 1 bit (sub-1-bit rates
        // or heavy padding), only the first n_tx coordinates travel — the
        // rotation spreads energy uniformly, so a prefix is an unbiased
        // 1/p-scaled sketch (same common-randomness trick as subsampling).
        let header = 64 + 8;
        let payload = budget.saturating_sub(header);
        let b = ((payload / n2).clamp(1, 16)) as u32;
        let n_tx = (payload / b as usize).min(n2);
        if n_tx == 0 {
            // Budget below the header: empty zero message (the decoder
            // recomputes n_tx == 0 from the same budget and never reads).
            return Encoded { bytes: Vec::new(), bits: 0 };
        }

        // rotate: y = (1/√n2) H D x
        let mut y = vec![0.0f64; n2];
        let d = sign_diag(n2, ctx);
        for i in 0..m {
            y[i] = h[i] as f64 * d[i];
        }
        fwht(&mut y);
        let scale = 1.0 / (n2 as f64).sqrt();
        for v in y.iter_mut() {
            *v *= scale;
        }

        let lo = y[..n_tx].iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = y[..n_tx].iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut w = BitWriter::with_capacity(budget / 8 + 16);
        w.push_f32(lo as f32);
        w.push_f32(hi as f32);
        w.push_bits(b as u64, 8);
        let levels = (1u64 << b) - 1;
        let span = (hi - lo).max(1e-30);
        for &v in &y[..n_tx] {
            let q = (((v - lo) / span) * levels as f64).round() as u64;
            w.push_bits(q.min(levels), b);
        }
        let bits = w.bit_len();
        debug_assert!(bits <= budget, "rotation over budget: {bits} > {budget}");
        Encoded { bytes: w.into_bytes(), bits }
    }

    /// Whole-buffer decoder (inverse FWHT over the full padded vector).
    fn decode_whole(&self, msg: &Encoded, m: usize, ctx: &CodecContext) -> Vec<f32> {
        let n2 = m.next_power_of_two();
        let budget = ctx.budget_bits(m);
        let header = 64 + 8;
        let payload = budget.saturating_sub(header);
        let b = ((payload / n2).clamp(1, 16)) as u32;
        let n_tx = (payload / b as usize).min(n2);
        if n_tx == 0 {
            return vec![0.0; m];
        }
        let mut r = BitReader::new(&msg.bytes);
        let lo = r.read_f32() as f64;
        let hi = r.read_f32() as f64;
        let b_hdr = r.read_bits(8) as u32;
        if b_hdr != b {
            // Header width disagrees with the width this budget implies:
            // either an empty message (b_hdr == 0) or a tampered payload
            // that survived the outer CRC. Reconstruct as zeros rather
            // than misparse the bit stream.
            return vec![0.0; m];
        }
        let levels = (1u64 << b) - 1;
        let span = (hi - lo).max(1e-30);
        let mut y = vec![0.0f64; n2];
        // unbiased inverse-probability scaling for the untransmitted tail
        let inv_p = n2 as f64 / n_tx as f64;
        for v in y.iter_mut().take(n_tx) {
            let q = r.read_bits(b);
            *v = (lo + q as f64 / levels as f64 * span) * inv_p;
        }
        // inverse: x = D Hᵀ y/√n2 (H symmetric, H² = n2·I)
        fwht(&mut y);
        let scale = 1.0 / (n2 as f64).sqrt();
        let d = sign_diag(n2, ctx);
        (0..m).map(|i| (y[i] * scale * d[i]) as f32).collect()
    }
}

impl UpdateCodec for RotationUniform {
    fn name(&self) -> String {
        "rotation".into()
    }

    fn encoder(&self, ctx: &CodecContext, m: usize) -> Box<dyn EncodeSink + '_> {
        let ctx = *ctx;
        Box::new(BufferedSink::new(m, move |h: &[f32]| self.encode_whole(h, &ctx)))
    }

    fn decoder<'a>(
        &'a self,
        msg: &'a Encoded,
        m: usize,
        ctx: &CodecContext,
    ) -> Box<dyn DecodeStream + 'a> {
        Box::new(SliceStream::new(self.decode_whole(msg, m, ctx)))
    }

    /// Skip the session buffers for the whole-buffer entry points.
    fn encode(&self, h: &[f32], ctx: &CodecContext) -> Encoded {
        self.encode_whole(h, ctx)
    }

    fn decode(&self, msg: &Encoded, m: usize, ctx: &CodecContext) -> Vec<f32> {
        self.decode_whole(msg, m, ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Normal, Xoshiro256pp};
    use crate::quantizer::measure_distortion;

    fn gaussian(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        Normal::new(0.0, 1.0).vec_f32(&mut rng, n)
    }

    #[test]
    fn fwht_is_self_inverse() {
        let mut x = vec![1.0, -2.0, 3.0, 0.5, 0.0, 7.0, -1.0, 2.0];
        let orig = x.clone();
        fwht(&mut x);
        fwht(&mut x);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a / 8.0 - b).abs() < 1e-12);
        }
    }

    #[test]
    fn fwht_preserves_energy() {
        let x = gaussian(256, 91).iter().map(|&v| v as f64).collect::<Vec<_>>();
        let mut y = x.clone();
        fwht(&mut y);
        let ex: f64 = x.iter().map(|v| v * v).sum();
        let ey: f64 = y.iter().map(|v| v * v).sum::<f64>() / 256.0;
        assert!((ex - ey).abs() / ex < 1e-10);
    }

    #[test]
    fn roundtrip_within_budget() {
        let h = gaussian(1000, 92); // non-power-of-two on purpose
        for rate in [2.0, 4.0] {
            let rep = measure_distortion(&RotationUniform, &h, rate, 3, 0);
            assert!(rep.bits_per_entry <= rate + 1e-9, "{}", rep.bits_per_entry);
            assert!(rep.mse.is_finite() && rep.mse > 0.0);
        }
    }

    #[test]
    fn rotation_beats_no_rotation_uniform_on_heavy_tails() {
        // The baseline's rationale: rotating flattens heavy-tailed DENSE
        // coordinate distributions, shrinking the span a uniform quantizer
        // must cover. Compare against direct uniform quantization with the
        // SAME bit width on Laplacian data (heavier tails than Gaussian).
        let mut rng = Xoshiro256pp::seed_from_u64(93);
        let h: Vec<f32> = (0..4096)
            .map(|_| {
                // Laplace via difference of exponentials
                let u: f64 = rng.uniform().max(1e-12);
                let e = -u.ln();
                (e * rng.sign() as f64) as f32
            })
            .collect();
        // rate 4.2 so the codec's realized width is exactly 4 bits after
        // its 72-bit header — matching the direct comparator's width.
        let rate = 4.2;
        let rot = measure_distortion(&RotationUniform, &h, rate, 3, 0).mse;
        // direct uniform at the same bit width (4 bits/entry, same span rule)
        let lo = h.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
        let hi = h.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let levels = ((1u64 << 4) - 1) as f64;
        let span = hi - lo;
        let direct: f64 = h
            .iter()
            .map(|&v| {
                let q = (((v as f64 - lo) / span) * levels).round() / levels * span + lo;
                (v as f64 - q).powi(2)
            })
            .sum::<f64>()
            / h.len() as f64;
        assert!(rot < direct, "rotated {rot} !< direct {direct}");
    }

    #[test]
    fn decode_requires_matching_rotation_stream() {
        let h = gaussian(512, 94);
        let enc_ctx = CodecContext::new(2, 3, 7, 4.0);
        let bad_ctx = CodecContext::new(2, 4, 7, 4.0);
        let enc = RotationUniform.encode(&h, &enc_ctx);
        let good = RotationUniform.decode(&enc, h.len(), &enc_ctx);
        let bad = RotationUniform.decode(&enc, h.len(), &bad_ctx);
        let mg = crate::util::stats::mse(&h, &good);
        let mb = crate::util::stats::mse(&h, &bad);
        assert!(mg < mb);
    }
}
