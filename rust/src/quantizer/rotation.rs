//! Uniform quantization with structured random rotation [12].
//!
//! The update is rotated by a randomized Hadamard transform `(1/√n)·H·D`
//! (sign-flip diagonal `D` drawn from the shared-seed stream — a shared
//! rotation needs no extra uplink bits), then quantized with a fixed-width
//! uniform scalar quantizer over the rotated dynamic range. The rotation
//! flattens the coordinate distribution, shrinking the range a uniform
//! quantizer must cover — this is the "random rotation" baseline of
//! Konečný et al. the paper compares against in Figs. 4–7.
//!
//! Sessions are buffered on both sides: the FWHT is a global transform of
//! the whole (power-of-two padded) vector, on encode and on decode.
//!
//! Since Codec API v3 the registry builds the **pipeline port**
//! ([`RotationUniform::pipeline`]): a [`RotationStage`] (pad → sign flip →
//! FWHT → 1/√n₂) in front of a [`UniformPrefixCoder`] terminal. The
//! monolithic [`RotationUniform`] implementation below is retained
//! verbatim as the bit-parity oracle — `pipeline_matches_legacy_oracle`
//! asserts byte-identical wire output and identical decodes.

use super::pipeline::{
    dequantize_uniform, quantize_uniform, PipelineCodec, TerminalCoder, TransformStage,
};
use super::{
    BufferedSink, CodecContext, DecodeBudget, DecodeError, DecodeStream, Encoded, EncodeSink,
    SliceStream, UpdateCodec,
};
use crate::entropy::{BitReader, BitWriter};
use crate::prng::{Rng, StreamKind};

#[derive(Debug, Clone, Copy, Default)]
pub struct RotationUniform;

/// In-place fast Walsh–Hadamard transform (unnormalized). Length must be a
/// power of two.
pub fn fwht(x: &mut [f64]) {
    let n = x.len();
    debug_assert!(n.is_power_of_two());
    let mut h = 1;
    while h < n {
        for i in (0..n).step_by(2 * h) {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
        }
        h *= 2;
    }
}

fn sign_diag(n: usize, ctx: &CodecContext) -> Vec<f64> {
    let mut rng = ctx.crand.stream(ctx.user, ctx.round, StreamKind::Rotation);
    (0..n).map(|_| rng.sign() as f64).collect()
}

impl RotationUniform {
    /// Whole-buffer encoder (runs at `EncodeSink::finish`).
    fn encode_whole(&self, h: &[f32], ctx: &CodecContext) -> Encoded {
        let m = h.len();
        let n2 = m.next_power_of_two();
        let budget = ctx.budget_bits(m);
        // Fixed-width bits per transmitted rotated coordinate; when the
        // budget cannot cover all n2 coordinates at 1 bit (sub-1-bit rates
        // or heavy padding), only the first n_tx coordinates travel — the
        // rotation spreads energy uniformly, so a prefix is an unbiased
        // 1/p-scaled sketch (same common-randomness trick as subsampling).
        let (b, n_tx) = prefix_geometry(budget, n2);
        if n_tx == 0 {
            // Budget below the header: empty zero message (the decoder
            // recomputes n_tx == 0 from the same budget and never reads).
            return Encoded { bytes: Vec::new(), bits: 0 };
        }

        // rotate: y = (1/√n2) H D x
        let mut y = vec![0.0f64; n2];
        let d = sign_diag(n2, ctx);
        for i in 0..m {
            y[i] = h[i] as f64 * d[i];
        }
        fwht(&mut y);
        let scale = 1.0 / (n2 as f64).sqrt();
        for v in y.iter_mut() {
            *v *= scale;
        }

        let lo = y[..n_tx].iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = y[..n_tx].iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut w = BitWriter::with_capacity(budget / 8 + 16);
        w.push_f32(lo as f32);
        w.push_f32(hi as f32);
        w.push_bits(b as u64, 8);
        let levels = (1u64 << b) - 1;
        let span = (hi - lo).max(1e-30);
        for &v in &y[..n_tx] {
            let q = (((v - lo) / span) * levels as f64).round() as u64;
            w.push_bits(q.min(levels), b);
        }
        let bits = w.bit_len();
        debug_assert!(bits <= budget, "rotation over budget: {bits} > {budget}");
        Encoded { bytes: w.into_bytes(), bits }
    }

    /// Whole-buffer decoder (inverse FWHT over the full padded vector).
    fn decode_whole(&self, msg: &Encoded, m: usize, ctx: &CodecContext) -> Vec<f32> {
        let n2 = m.next_power_of_two();
        let budget = ctx.budget_bits(m);
        let (b, n_tx) = prefix_geometry(budget, n2);
        if n_tx == 0 {
            return vec![0.0; m];
        }
        let mut r = BitReader::new(&msg.bytes);
        let lo = r.read_f32() as f64;
        let hi = r.read_f32() as f64;
        let b_hdr = r.read_bits(8) as u32;
        if b_hdr != b {
            // Header width disagrees with the width this budget implies:
            // either an empty message (b_hdr == 0) or a tampered payload
            // that survived the outer CRC. Reconstruct as zeros rather
            // than misparse the bit stream.
            return vec![0.0; m];
        }
        let levels = (1u64 << b) - 1;
        let span = (hi - lo).max(1e-30);
        let mut y = vec![0.0f64; n2];
        // unbiased inverse-probability scaling for the untransmitted tail
        let inv_p = n2 as f64 / n_tx as f64;
        for v in y.iter_mut().take(n_tx) {
            let q = r.read_bits(b);
            *v = (lo + q as f64 / levels as f64 * span) * inv_p;
        }
        // inverse: x = D Hᵀ y/√n2 (H symmetric, H² = n2·I)
        fwht(&mut y);
        let scale = 1.0 / (n2 as f64).sqrt();
        let d = sign_diag(n2, ctx);
        (0..m).map(|i| (y[i] * scale * d[i]) as f32).collect()
    }
}

/// Fixed-width bits per coded coordinate and the transmitted prefix
/// length for an n₂-point rotated vector under `budget` total bits.
/// Shared by the legacy oracle and the pipeline terminal so the wire
/// geometry cannot drift between them.
fn prefix_geometry(budget: usize, n2: usize) -> (u32, usize) {
    let header = 64 + 8;
    let payload = budget.saturating_sub(header);
    let b = ((payload / n2).clamp(1, 16)) as u32;
    let n_tx = (payload / b as usize).min(n2);
    (b, n_tx)
}

/// Pipeline stage: pad to the next power of two, apply the shared-seed
/// sign diagonal `D`, FWHT, and the 1/√n₂ normalization. The inverse
/// (H symmetric, H² = n₂·I) is the same transform followed by the sign
/// flip and truncation back to `m_in` entries.
#[derive(Debug, Clone, Copy, Default)]
pub struct RotationStage;

impl TransformStage for RotationStage {
    fn name(&self) -> &'static str {
        "rotation"
    }

    fn out_len(&self, m_in: usize, _ctx: &CodecContext) -> usize {
        m_in.next_power_of_two()
    }

    fn forward(&self, x: Vec<f64>, ctx: &CodecContext) -> Vec<f64> {
        let m = x.len();
        let n2 = m.next_power_of_two();
        let d = sign_diag(n2, ctx);
        let mut y = vec![0.0f64; n2];
        for i in 0..m {
            y[i] = x[i] * d[i];
        }
        fwht(&mut y);
        let scale = 1.0 / (n2 as f64).sqrt();
        for v in y.iter_mut() {
            *v *= scale;
        }
        y
    }

    fn inverse(
        &self,
        mut y: Vec<f64>,
        m_in: usize,
        ctx: &CodecContext,
        budget: &mut DecodeBudget,
    ) -> Result<Vec<f64>, DecodeError> {
        budget.charge(1)?;
        let n2 = y.len();
        fwht(&mut y);
        let scale = 1.0 / (n2 as f64).sqrt();
        let d = sign_diag(n2, ctx);
        Ok((0..m_in).map(|i| y[i] * scale * d[i]).collect())
    }
}

/// Pipeline terminal: fixed-width uniform quantization of the prefix the
/// budget can afford, with the unbiased 1/p tail scaling applied on
/// decode — byte-identical to the legacy monolith's wire format.
#[derive(Debug, Clone, Copy, Default)]
pub struct UniformPrefixCoder;

impl TerminalCoder for UniformPrefixCoder {
    fn name(&self) -> &'static str {
        "uniform-prefix"
    }

    fn encode(&self, y: &[f64], budget_bits: usize, _ctx: &CodecContext) -> Encoded {
        let n2 = y.len();
        let (b, n_tx) = prefix_geometry(budget_bits, n2);
        if n_tx == 0 {
            return Encoded { bytes: Vec::new(), bits: 0 };
        }
        let lo = y[..n_tx].iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = y[..n_tx].iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut w = BitWriter::with_capacity(budget_bits / 8 + 16);
        w.push_f32(lo as f32);
        w.push_f32(hi as f32);
        w.push_bits(b as u64, 8);
        for &v in &y[..n_tx] {
            w.push_bits(quantize_uniform(v, lo, hi, b), b);
        }
        let bits = w.bit_len();
        debug_assert!(bits <= budget_bits, "rotation over budget: {bits} > {budget_bits}");
        Encoded { bytes: w.into_bytes(), bits }
    }

    fn decode(
        &self,
        msg: &Encoded,
        y_len: usize,
        budget_bits: usize,
        _ctx: &CodecContext,
    ) -> Result<Vec<f64>, DecodeError> {
        let (b, n_tx) = prefix_geometry(budget_bits, y_len);
        let mut y = vec![0.0f64; y_len];
        if n_tx == 0 {
            return Ok(y);
        }
        let mut r = BitReader::new(&msg.bytes);
        let lo = r.read_f32() as f64;
        let hi = r.read_f32() as f64;
        let b_hdr = r.read_bits(8) as u32;
        if b_hdr != b {
            // Same policy as the oracle: zeros rather than a misparse.
            return Ok(y);
        }
        let inv_p = y_len as f64 / n_tx as f64;
        for v in y.iter_mut().take(n_tx) {
            let q = r.read_bits(b);
            *v = dequantize_uniform(q, lo, hi, b) * inv_p;
        }
        Ok(y)
    }
}

impl RotationUniform {
    /// The staged pipeline port — what `quantizer::make("rotation")`
    /// builds since Codec API v3. Byte-identical to the legacy monolith.
    pub fn pipeline() -> PipelineCodec {
        PipelineCodec::new("rotation", vec![Box::new(RotationStage)], Box::new(UniformPrefixCoder))
    }
}

impl UpdateCodec for RotationUniform {
    fn name(&self) -> String {
        "rotation".into()
    }

    fn encoder(&self, ctx: &CodecContext, m: usize) -> Box<dyn EncodeSink + '_> {
        let ctx = *ctx;
        Box::new(BufferedSink::new(m, move |h: &[f32]| self.encode_whole(h, &ctx)))
    }

    fn decoder<'a>(
        &'a self,
        msg: &'a Encoded,
        m: usize,
        ctx: &CodecContext,
    ) -> Box<dyn DecodeStream + 'a> {
        Box::new(SliceStream::new(self.decode_whole(msg, m, ctx)))
    }

    /// Skip the session buffers for the whole-buffer entry points.
    fn encode(&self, h: &[f32], ctx: &CodecContext) -> Encoded {
        self.encode_whole(h, ctx)
    }

    fn decode(&self, msg: &Encoded, m: usize, ctx: &CodecContext) -> Vec<f32> {
        self.decode_whole(msg, m, ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Normal, Xoshiro256pp};
    use crate::quantizer::measure_distortion;

    fn gaussian(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        Normal::new(0.0, 1.0).vec_f32(&mut rng, n)
    }

    #[test]
    fn fwht_is_self_inverse() {
        let mut x = vec![1.0, -2.0, 3.0, 0.5, 0.0, 7.0, -1.0, 2.0];
        let orig = x.clone();
        fwht(&mut x);
        fwht(&mut x);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a / 8.0 - b).abs() < 1e-12);
        }
    }

    #[test]
    fn fwht_preserves_energy() {
        let x = gaussian(256, 91).iter().map(|&v| v as f64).collect::<Vec<_>>();
        let mut y = x.clone();
        fwht(&mut y);
        let ex: f64 = x.iter().map(|v| v * v).sum();
        let ey: f64 = y.iter().map(|v| v * v).sum::<f64>() / 256.0;
        assert!((ex - ey).abs() / ex < 1e-10);
    }

    #[test]
    fn roundtrip_within_budget() {
        let h = gaussian(1000, 92); // non-power-of-two on purpose
        for rate in [2.0, 4.0] {
            let rep = measure_distortion(&RotationUniform, &h, rate, 3, 0);
            assert!(rep.bits_per_entry <= rate + 1e-9, "{}", rep.bits_per_entry);
            assert!(rep.mse.is_finite() && rep.mse > 0.0);
        }
    }

    #[test]
    fn rotation_beats_no_rotation_uniform_on_heavy_tails() {
        // The baseline's rationale: rotating flattens heavy-tailed DENSE
        // coordinate distributions, shrinking the span a uniform quantizer
        // must cover. Compare against direct uniform quantization with the
        // SAME bit width on Laplacian data (heavier tails than Gaussian).
        let mut rng = Xoshiro256pp::seed_from_u64(93);
        let h: Vec<f32> = (0..4096)
            .map(|_| {
                // Laplace via difference of exponentials
                let u: f64 = rng.uniform().max(1e-12);
                let e = -u.ln();
                (e * rng.sign() as f64) as f32
            })
            .collect();
        // rate 4.2 so the codec's realized width is exactly 4 bits after
        // its 72-bit header — matching the direct comparator's width.
        let rate = 4.2;
        let rot = measure_distortion(&RotationUniform, &h, rate, 3, 0).mse;
        // direct uniform at the same bit width (4 bits/entry, same span rule)
        let lo = h.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
        let hi = h.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let levels = ((1u64 << 4) - 1) as f64;
        let span = hi - lo;
        let direct: f64 = h
            .iter()
            .map(|&v| {
                let q = (((v as f64 - lo) / span) * levels).round() / levels * span + lo;
                (v as f64 - q).powi(2)
            })
            .sum::<f64>()
            / h.len() as f64;
        assert!(rot < direct, "rotated {rot} !< direct {direct}");
    }

    #[test]
    fn pipeline_matches_legacy_oracle() {
        // The registry's pipeline port must be indistinguishable from the
        // retained monolith: byte-identical wire output, identical exact
        // bit counts, and bitwise-equal decodes — across sizes (including
        // non-power-of-two and sub-header budgets), rates, and contexts.
        for (m, seed) in [(1000usize, 3u64), (512, 7), (300, 11), (7, 5)] {
            let h = gaussian(m, seed);
            for rate in [0.05, 2.0, 4.0] {
                for (user, round) in [(0u64, 0u64), (42, 17)] {
                    let ctx = CodecContext::new(user, round, seed, rate);
                    let pipe = RotationUniform::pipeline();
                    let legacy_enc = RotationUniform.encode(&h, &ctx);
                    let pipe_enc = pipe.encode(&h, &ctx);
                    assert_eq!(pipe_enc, legacy_enc, "m={m} rate={rate}");
                    let legacy_dec = RotationUniform.decode(&legacy_enc, m, &ctx);
                    let pipe_dec = pipe.decode(&pipe_enc, m, &ctx);
                    assert_eq!(pipe_dec, legacy_dec, "m={m} rate={rate}");
                }
            }
        }
    }

    #[test]
    fn pipeline_decode_budget_exhaustion_is_typed() {
        use crate::quantizer::{DecodeBudget, DecodeError};
        let h = gaussian(256, 21);
        let pipe = RotationUniform::pipeline();
        let ctx = CodecContext::new(1, 1, 9, 4.0);
        let enc = pipe.encode(&h, &ctx);
        let starved = ctx.with_decode_budget(DecodeBudget::units(0));
        assert_eq!(pipe.try_decode(&enc, h.len(), &starved), Err(DecodeError::Budget));
        let fed = ctx.with_decode_budget(DecodeBudget::units(1));
        assert_eq!(pipe.try_decode(&enc, h.len(), &fed).unwrap(), pipe.decode(&enc, h.len(), &ctx));
    }

    #[test]
    fn decode_requires_matching_rotation_stream() {
        let h = gaussian(512, 94);
        let enc_ctx = CodecContext::new(2, 3, 7, 4.0);
        let bad_ctx = CodecContext::new(2, 4, 7, 4.0);
        let enc = RotationUniform.encode(&h, &enc_ctx);
        let good = RotationUniform.decode(&enc, h.len(), &enc_ctx);
        let bad = RotationUniform.decode(&enc, h.len(), &bad_ctx);
        let mg = crate::util::stats::mse(&h, &good);
        let mb = crate::util::stats::mse(&h, &bad);
        assert!(mg < mb);
    }
}
