//! Random subsampling followed by 3-bit uniform quantization [12].
//!
//! A pseudo-random subset of coordinates (mask drawn from the shared-seed
//! stream, so it costs no uplink bits) is kept, 3-bit uniform-quantized
//! over its dynamic range, and scaled by `1/p` at the decoder for
//! unbiasedness. The keep-fraction `p` is set so the message exactly fills
//! the bit budget — the rate "determines the subsampling ratio" (§V-A).
//!
//! Sessions are buffered on both sides: the encoder quantizes over the
//! kept subset's global dynamic range, and the decoder scatter-writes the
//! kept coordinates into their (unsorted-in-stream-order) positions.
//!
//! **Pipeline-v3 stage mapping**: subsampling is `mask-project →
//! uniform-quantize`, i.e. a subsampling
//! [`TransformStage`](super::pipeline::TransformStage) fused into its
//! terminal coder — the mask comes from common randomness (no in-band
//! index list), but `k` and the scatter positions depend on the *outer*
//! budget and `m`, so cutting a stage boundary here would re-derive them
//! from a stage-local length and change bytes. The value quantization is
//! the shared [`pipeline::quantize_uniform`](super::pipeline::quantize_uniform)
//! arithmetic, keeping the wire format bit-identical to the pre-pipeline
//! implementation.

use super::pipeline::{dequantize_uniform, quantize_uniform};
use super::{
    BufferedSink, CodecContext, DecodeStream, Encoded, EncodeSink, SliceStream, UpdateCodec,
};
use crate::entropy::{BitReader, BitWriter};
use crate::prng::{Rng, StreamKind};

#[derive(Debug, Clone, Copy)]
pub struct SubsampleUniform {
    /// Bits per kept coordinate (the paper uses 3).
    pub value_bits: u32,
}

impl Default for SubsampleUniform {
    fn default() -> Self {
        Self { value_bits: 3 }
    }
}

impl SubsampleUniform {
    fn kept_indices(&self, m: usize, k: usize, ctx: &CodecContext) -> Vec<usize> {
        let mut rng = ctx.crand.stream(ctx.user, ctx.round, StreamKind::Mask);
        let mut idx = rng.sample_indices(m, k);
        idx.sort_unstable();
        idx
    }

    /// Whole-buffer encoder (runs at `EncodeSink::finish`).
    fn encode_whole(&self, h: &[f32], ctx: &CodecContext) -> Encoded {
        let m = h.len();
        let budget = ctx.budget_bits(m);
        let header = 64;
        let k = if budget > header {
            ((budget - header) / self.value_bits as usize).min(m)
        } else {
            0
        };
        if k == 0 {
            // Budget below the header: empty zero message (the decoder
            // recomputes k == 0 from the same budget and returns zeros).
            return Encoded { bytes: Vec::new(), bits: 0 };
        }
        let mut w = BitWriter::with_capacity(budget / 8 + 16);
        let idx = self.kept_indices(m, k, ctx);
        let vals: Vec<f64> = idx.iter().map(|&i| h[i] as f64).collect();
        let lo = vals.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        w.push_f32(lo as f32);
        w.push_f32(hi as f32);
        for &v in &vals {
            w.push_bits(quantize_uniform(v, lo, hi, self.value_bits), self.value_bits);
        }
        let bits = w.bit_len();
        debug_assert!(bits <= budget);
        Encoded { bytes: w.into_bytes(), bits }
    }

    /// Whole-buffer decoder (scatter reconstruction over the shared mask).
    fn decode_whole(&self, msg: &Encoded, m: usize, ctx: &CodecContext) -> Vec<f32> {
        let budget = ctx.budget_bits(m);
        let header = 64;
        let k = if budget > header {
            ((budget - header) / self.value_bits as usize).min(m)
        } else {
            0
        };
        let mut out = vec![0.0f32; m];
        if k == 0 {
            return out;
        }
        let mut r = BitReader::new(&msg.bytes);
        let lo = r.read_f32() as f64;
        let hi = r.read_f32() as f64;
        if lo == 0.0 && hi == 0.0 {
            return out;
        }
        let idx = self.kept_indices(m, k, ctx);
        // unbiased inverse-probability scaling
        let inv_p = m as f64 / k as f64;
        for &i in &idx {
            let q = r.read_bits(self.value_bits);
            out[i] = (dequantize_uniform(q, lo, hi, self.value_bits) * inv_p) as f32;
        }
        out
    }
}

impl UpdateCodec for SubsampleUniform {
    fn name(&self) -> String {
        "subsample".into()
    }

    fn encoder(&self, ctx: &CodecContext, m: usize) -> Box<dyn EncodeSink + '_> {
        let ctx = *ctx;
        Box::new(BufferedSink::new(m, move |h: &[f32]| self.encode_whole(h, &ctx)))
    }

    fn decoder<'a>(
        &'a self,
        msg: &'a Encoded,
        m: usize,
        ctx: &CodecContext,
    ) -> Box<dyn DecodeStream + 'a> {
        Box::new(SliceStream::new(self.decode_whole(msg, m, ctx)))
    }

    /// Skip the session buffers for the whole-buffer entry points.
    fn encode(&self, h: &[f32], ctx: &CodecContext) -> Encoded {
        self.encode_whole(h, ctx)
    }

    fn decode(&self, msg: &Encoded, m: usize, ctx: &CodecContext) -> Vec<f32> {
        self.decode_whole(msg, m, ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Normal, Xoshiro256pp};
    use crate::quantizer::measure_distortion;

    fn gaussian(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        Normal::new(0.0, 1.0).vec_f32(&mut rng, n)
    }

    #[test]
    fn within_budget() {
        let h = gaussian(4096, 95);
        for rate in [1.0, 2.0, 4.0] {
            let rep = measure_distortion(&SubsampleUniform::default(), &h, rate, 3, 0);
            assert!(rep.bits_per_entry <= rate + 1e-9);
        }
    }

    #[test]
    fn keeps_expected_fraction() {
        let h = gaussian(3000, 96);
        let ctx = CodecContext::new(0, 0, 5, 2.0);
        let enc = SubsampleUniform::default().encode(&h, &ctx);
        let dec = SubsampleUniform::default().decode(&enc, h.len(), &ctx);
        let nonzero = dec.iter().filter(|&&v| v != 0.0).count();
        // k = (2·3000 − 64)/3 ≈ 1978
        assert!((nonzero as i64 - 1978).abs() < 30, "nonzero {nonzero}");
    }

    #[test]
    fn distortion_worse_than_uveqfed() {
        // The paper's Fig. 4 ordering: subsampling is the weakest scheme.
        let mut ds = 0.0;
        let mut du = 0.0;
        for seed in 0..6 {
            let h = gaussian(8192, 400 + seed);
            ds += measure_distortion(&SubsampleUniform::default(), &h, 2.0, seed, 0).mse;
            du += measure_distortion(&crate::quantizer::UVeQFed::hexagonal(), &h, 2.0, seed, 0)
                .mse;
        }
        assert!(du < ds, "uveqfed {du} !< subsample {ds}");
    }

    #[test]
    fn mask_shared_between_encode_decode() {
        let h = gaussian(512, 97);
        let ctx = CodecContext::new(1, 2, 5, 3.0);
        let codec = SubsampleUniform::default();
        let enc = codec.encode(&h, &ctx);
        let dec = codec.decode(&enc, h.len(), &ctx);
        // kept positions must match actual large reconstructed entries;
        // verify determinism by re-decoding.
        let dec2 = codec.decode(&enc, h.len(), &ctx);
        assert_eq!(dec, dec2);
    }
}
