//! **UVeQFed** — the paper's codec (§III): subtractive dithered lattice
//! quantization with entropy coding.
//!
//! Encoder (steps E1–E4):
//! 1. *Normalize & partition* — scale `h` by `1/(ζ‖h‖)` and split into
//!    `M = ⌈m/L⌉` sub-vectors (zero-padded tail). `ζ‖h‖` itself travels in
//!    the header as an f32 — the "fine-resolution scalar quantizer" of E1
//!    (error ~2⁻²⁴, matching the paper's negligibility assumption).
//! 2. *Dither* — draw `z_i ~ Unif(P₀)` from the shared-seed stream
//!    `(user, round, Dither)`; both sides regenerate it identically.
//! 3. *Quantize* — `Q_{sΛ}(h̄_i + s·z_i) = s·G·NN_Λ(h̄_i/s + z_i)` where the
//!    scale `s` is chosen by the rate controller so the coded stream fits
//!    the `R·m`-bit budget (the paper's "scale `G`" procedure, §V-A).
//! 4. *Entropy-code* — adaptive table-driven range coder over the integer
//!    lattice coordinates (one model per lattice dimension).
//!
//! Decoder (D1–D3): entropy-decode, **subtract the dither**, rescale by
//! `ζ‖h‖` and reassemble. The dither subtraction is what makes the error
//! `ε = Q(h̄+z) − z − h̄` uniform over `P₀` and independent of `h̄` (Thm 1)
//! — and is the concrete difference from QSGD-style probabilistic
//! quantizers.
//!
//! Sessions: the encode sink is buffered — E1's normalization needs `‖h‖`
//! before the first sub-vector can be coded, and the rate controller's
//! scale search re-reads every coordinate, so a one-pass encoder cannot
//! be bit-identical. The **decode stream is genuinely single-pass**: it
//! pulls lattice coordinates one sub-vector at a time from the
//! incremental range decoder, regenerates the matching dither blocks on
//! the fly, and yields chunks on lattice-block boundaries — O(chunk)
//! server memory for the paper's codec.

use super::rate::{search_scale, ScaleHintMap};
use super::session::DEFAULT_CHUNK;
use super::{
    BufferedSink, CodecContext, DecodeStream, Encoded, EncodeSink, EntryStream, UpdateCodec,
};
use crate::entropy::range::{AdaptiveRangeCoder, SymbolDecoder};
use crate::entropy::{BitReader, BitWriter, IntCoder};
use crate::lattice::dither::fill_dither;
use crate::lattice::{self, Lattice, Scratch};
use crate::prng::{StreamKind, Xoshiro256pp};
use crate::util::stats::l2_norm;
use crate::util::threadpool::with_scratch;
use std::sync::Arc;

/// Per-thread encode arena: every buffer the whole-buffer encoder needs,
/// reused across clients on the same worker thread via
/// [`with_scratch`] so steady-state encodes stop allocating
/// (`FleetDriver` fans thousands of client encodes per round through each
/// worker).
#[derive(Default)]
struct EncodeArena {
    /// Normalized update h̄ (zero-padded to whole lattice blocks).
    hbar: Vec<f64>,
    /// Per-round dither, one block per sub-vector.
    dither: Vec<f64>,
    /// Cached real-valued Babai coordinates `G⁻¹h̄` (per coordinate).
    babai: Vec<f64>,
    /// Cached `G⁻¹z` for the dither.
    dbabai: Vec<f64>,
    /// Integer coordinates (scale probes and the final encode).
    coords: Vec<i64>,
    /// `h̄/s + z` staging buffer for exact quantization passes.
    y: Vec<f64>,
    /// One-block coordinate buffer for the estimate pass.
    cbuf: Vec<i64>,
    /// Lattice batch-kernel scratch.
    scratch: Scratch,
}

/// ζ selection. The paper uses `ζ = (2 + R/5)/√M` in the §V experiments
/// (rate-adaptive spread) and motivates `3/√M` from Chebyshev in §III-B.
#[derive(Debug, Clone, Copy)]
pub enum ZetaMode {
    /// `ζ = (2 + R/5) / √M` (paper §V-A).
    PaperRateAdaptive,
    /// `ζ = c / √M`.
    FixedOverSqrtM(f64),
}

impl ZetaMode {
    pub fn zeta(&self, rate: f64, m_subvectors: usize) -> f64 {
        let sqrt_m = (m_subvectors as f64).sqrt();
        match self {
            ZetaMode::PaperRateAdaptive => (2.0 + rate / 5.0) / sqrt_m,
            ZetaMode::FixedOverSqrtM(c) => c / sqrt_m,
        }
    }
}

/// The UVeQFed codec. Cheap to clone (the base lattice is shared).
pub struct UVeQFed {
    base: Arc<dyn Lattice>,
    pub zeta_mode: ZetaMode,
    /// Optional: subtract the dither at the decoder (true = the paper's
    /// scheme; false degrades to a QSGD-like non-subtractive decoder —
    /// used by the ablation bench to quantify the dither-subtraction gain).
    pub subtractive: bool,
    /// Cross-round warm-start for the rate search, keyed by quarter-bit
    /// rate tier: heterogeneous uplinks mean one codec instance serves
    /// clients at very different budgets, and a single shared hint would
    /// thrash between tiers. Round-frozen with a deterministic
    /// within-round winner, so every encode stays a pure function of
    /// `(h, ctx)` — worker interleaving cannot leak into the accepted
    /// scale (see [`ScaleHintMap`]).
    hint: ScaleHintMap,
}

impl UVeQFed {
    pub fn new(base: Arc<dyn Lattice>) -> Self {
        Self {
            base,
            zeta_mode: ZetaMode::PaperRateAdaptive,
            subtractive: true,
            hint: ScaleHintMap::new(),
        }
    }

    /// L = 1 scalar configuration (paper's "UVeQFed L=1").
    pub fn scalar() -> Self {
        Self::new(Arc::new(lattice::scalar(1.0)))
    }

    /// L = 2 hexagonal configuration with the paper's generator.
    pub fn hexagonal() -> Self {
        Self::new(Arc::new(lattice::paper_hexagonal()))
    }

    /// L = 4 checkerboard lattice (extension).
    pub fn d4() -> Self {
        Self::new(Arc::new(lattice::DnLattice::new(4, 1.0)))
    }

    /// L = 8 Gosset lattice (extension).
    pub fn e8() -> Self {
        Self::new(Arc::new(lattice::E8Lattice::new(1.0)))
    }

    pub fn with_zeta(mut self, mode: ZetaMode) -> Self {
        self.zeta_mode = mode;
        self
    }

    pub fn non_subtractive(mut self) -> Self {
        self.subtractive = false;
        self
    }

    pub fn lattice(&self) -> &dyn Lattice {
        self.base.as_ref()
    }

    /// σ̄²_Λ of the *base* lattice — callers combine with the header scale
    /// to evaluate the Thm 1 prediction.
    pub fn base_second_moment(&self) -> f64 {
        self.base.second_moment()
    }

    /// Header bits: ζ‖h‖ (f32) + lattice scale (f32).
    const HEADER_BITS: usize = 64;

    /// Whole-buffer encoder — runs at `EncodeSink::finish` (E1 needs ‖h‖
    /// and the rate search re-reads every coordinate; see module docs).
    /// All working memory comes from the worker thread's [`EncodeArena`].
    fn encode_whole(&self, h: &[f32], ctx: &CodecContext) -> Encoded {
        with_scratch::<EncodeArena, _>(|arena| self.encode_in_arena(h, ctx, arena))
    }

    fn encode_in_arena(&self, h: &[f32], ctx: &CodecContext, arena: &mut EncodeArena) -> Encoded {
        let m = h.len();
        let l = self.base.dim();
        let n_sub = m.div_ceil(l);
        let padded = n_sub * l;
        let budget = ctx.budget_bits(m);

        let norm = l2_norm(h);
        let zeta = self.zeta_mode.zeta(ctx.rate, n_sub);
        let scale_factor = zeta * norm; // the ζ‖h‖ of step E1

        let mut w = BitWriter::with_capacity(budget / 8 + 16);
        if norm == 0.0 || budget <= Self::HEADER_BITS {
            // Degenerate: all-zero update or no budget for payload. The
            // empty message decodes as zeros (the reader zero-fills) and —
            // unlike a zeroed header — fits ANY budget, including the
            // near-zero allocations a rate controller hands to dead
            // uplinks.
            return Encoded { bytes: Vec::new(), bits: 0 };
        }

        let base = self.base.as_ref();
        let EncodeArena { hbar, dither, babai, dbabai, coords, y, cbuf, scratch } = arena;

        // E1: normalize & partition (f64 internally for exactness).
        hbar.clear();
        hbar.resize(padded, 0.0);
        for (i, &v) in h.iter().enumerate() {
            hbar[i] = v as f64 / scale_factor;
        }

        // E2: dither from common randomness (base-lattice cell; scaled by
        // the rate controller's `s` implicitly via the identity
        // Unif(P₀(sΛ)) = s·Unif(P₀(Λ))), filled into the reused buffer.
        let mut rng = ctx.crand.stream(ctx.user, ctx.round, StreamKind::Dither);
        dither.clear();
        dither.resize(padded, 0.0);
        fill_dither(base, &mut rng, dither, scratch);

        // Single coordinate pass for the scale search: cache the real
        // Babai coordinates a = G⁻¹h̄ and b = G⁻¹z once, then every
        // candidate scale probes `round(a/s + b)` — a multiply/round per
        // coordinate instead of a full re-quantization of the update.
        // Exact for diagonal generators; for the others a tight statistical
        // proxy, and the accepted scale is always verified (and the final
        // payload encoded) through the exact batched nearest-point kernel.
        babai.clear();
        babai.resize(padded, 0.0);
        dbabai.clear();
        dbabai.resize(padded, 0.0);
        for b in 0..n_sub {
            base.coords_real_into(&hbar[b * l..(b + 1) * l], &mut babai[b * l..(b + 1) * l]);
            base.coords_real_into(&dither[b * l..(b + 1) * l], &mut dbabai[b * l..(b + 1) * l]);
        }

        // E3 + E4 with rate targeting.
        let payload_budget = budget - Self::HEADER_BITS;
        let coder = AdaptiveRangeCoder::with_dims(l);
        // Initial scale: per-entry RMS of h̄ (≈ 1/(ζ√m) by construction),
        // warm-started from the previous accepted scale.
        let rms = (hbar.iter().map(|v| v * v).sum::<f64>() / padded as f64).sqrt();

        // Cheap size estimate for the scale search: entropy of the cached
        // rescaled-Babai coordinates over a strided ~25% sample of
        // sub-vectors via an array-indexed histogram; the exact-encode
        // verification below absorbs estimation error.
        let stride = if n_sub >= 512 { 4 } else { 1 };
        cbuf.clear();
        cbuf.resize(l, 0);
        let babai_ref: &[f64] = babai;
        let dbabai_ref: &[f64] = dbabai;
        let mut est = |s: f64| {
            crate::telemetry::probe::add_scale_est(1);
            let inv_s = 1.0 / s;
            let mut hist = [0u32; 257]; // [-128,127] + overflow bucket
            let mut total = 0usize;
            let mut i = 0;
            while i < n_sub {
                let off = i * l;
                for j in 0..l {
                    let v = babai_ref[off + j] * inv_s + dbabai_ref[off + j];
                    cbuf[j] = if v.is_finite() { v.round() as i64 } else { 0 };
                }
                base.decorrelate(cbuf);
                for &v in cbuf.iter() {
                    let idx =
                        if (-128..128).contains(&v) { (v + 128) as usize } else { 256 };
                    hist[idx] += 1;
                    total += 1;
                }
                i += stride;
            }
            let n = total as f64;
            let hbits: f64 = hist
                .iter()
                .filter(|&&cnt| cnt > 0)
                .map(|&cnt| {
                    let p = cnt as f64 / n;
                    -p * p.log2()
                })
                .sum();
            // overflow bucket symbols are long; charge them 24 bits each
            let overflow_penalty = hist[256] as f64 * 24.0 * stride as f64;
            ((hbits * (n_sub * l) as f64) + overflow_penalty).ceil() as usize + 64
        };
        // Exact coded size at scale `s`, batched through the lattice
        // kernels; memoizes the encoded payload so the accepted scale's
        // stream is stitched into the message without re-encoding.
        let hbar_ref: &[f64] = hbar;
        let dither_ref: &[f64] = dither;
        let mut cache: Option<(f64, BitWriter)> = None;
        let mut exact = |s: f64| {
            crate::telemetry::probe::add_scale_exact(1);
            let inv_s = 1.0 / s;
            y.clear();
            y.resize(padded, 0.0);
            for i in 0..padded {
                y[i] = hbar_ref[i] * inv_s + dither_ref[i];
            }
            coords.clear();
            coords.resize(padded, 0);
            base.nearest_batch_into(y, coords, scratch);
            // residual-predict coordinates: order-0 coder then operates on
            // (near-)decorrelated integers (see Lattice::decorrelate).
            for blk in coords.chunks_exact_mut(l) {
                base.decorrelate(blk);
            }
            let mut tw = BitWriter::new();
            coder.encode(coords, &mut tw);
            let bits = tw.bit_len();
            cache = Some((s, tw));
            bits
        };
        // Feasibility floor: tiny messages can't cover even the coder's
        // fixed overhead (length prefix) — fall back to the empty zero
        // message (0 bits, decodes as zeros).
        if exact(rms.max(1e-12) * 1e9) > payload_budget {
            return Encoded { bytes: Vec::new(), bits: 0 };
        }
        let init = self.hint.get(ctx.rate, ctx.round).unwrap_or(rms.max(1e-12));
        let s = search_scale(payload_budget, init, &mut est, &mut exact);
        self.hint.set(ctx.rate, ctx.round, ctx.user, s);

        // Commit: header, then the memoized exact payload. `search_scale`
        // only returns after a successful `exact(s)` probe at the accepted
        // scale, so the cache is guaranteed to hold precisely that stream —
        // the single copy of the final-encode logic lives in the closure.
        w.push_f32(scale_factor as f32);
        w.push_f32(s as f32);
        let (cached_s, tw) = cache.expect("exact() memoizes every probe");
        assert!(
            cached_s == s,
            "scale search returned {s} but last exact probe was {cached_s}"
        );
        w.append(&tw);
        let bits = w.bit_len();
        debug_assert!(bits <= budget, "UVeQFed exceeded budget: {bits} > {budget}");
        Encoded { bytes: w.into_bytes(), bits }
    }
}

/// Single-pass UVeQFed decode (D1–D3), one lattice block at a time:
/// chunks are yielded on lattice-block boundaries, and the dither blocks
/// are regenerated incrementally from the shared stream — the server
/// holds O(chunk) state, never the m-entry update.
struct UveqfedStream<'a> {
    base: &'a dyn Lattice,
    subtractive: bool,
    sym: SymbolDecoder<'a>,
    rng: Xoshiro256pp,
    scale_factor: f64,
    s: f64,
    l: usize,
    n_sub: usize,
    next_block: usize,
    m: usize,
    blocks_per_chunk: usize,
    /// Per-session scratch (preallocated at `decoder()`): one block of
    /// coordinates, the lattice point, the regenerated dither, the lattice
    /// kernels' scratch, and the yielded f32 chunk. Steady-state
    /// `next_chunk` performs zero heap allocation (asserted by the
    /// counting-allocator test).
    coords: Vec<i64>,
    point: Vec<f64>,
    zbuf: Vec<f64>,
    lat_scratch: Scratch,
    scratch: Vec<f32>,
}

impl DecodeStream for UveqfedStream<'_> {
    fn next_chunk(&mut self) -> Result<Option<&[f32]>, super::DecodeError> {
        if self.next_block >= self.n_sub {
            return Ok(None);
        }
        self.scratch.clear();
        let blocks = (self.n_sub - self.next_block).min(self.blocks_per_chunk);
        for _ in 0..blocks {
            // D1: entropy-decode one sub-vector's coordinates (batched
            // symbol pull). A corrupt range stream surfaces here as a
            // typed error; the partial chunk is discarded.
            self.sym.decode_into(&mut self.coords)?;
            self.base.recorrelate(&mut self.coords);
            // lattice point at base scale
            self.base.point_into(&self.coords, &mut self.point);
            // D2: regenerate this block's dither and subtract;
            // D3: rescale and reassemble.
            fill_dither(self.base, &mut self.rng, &mut self.zbuf, &mut self.lat_scratch);
            for j in 0..self.l {
                let idx = self.next_block * self.l + j;
                if idx >= self.m {
                    break;
                }
                // Q_{sΛ}(h̄+sz) = s·p; subtract dither s·z; rescale.
                let v = if self.subtractive {
                    self.s * (self.point[j] - self.zbuf[j])
                } else {
                    self.s * self.point[j]
                };
                self.scratch.push((v * self.scale_factor) as f32);
            }
            self.next_block += 1;
        }
        Ok(Some(&self.scratch))
    }
}

impl UpdateCodec for UVeQFed {
    fn name(&self) -> String {
        let sub = if self.subtractive { "" } else { "-nosub" };
        format!("uveqfed-{}{sub}", self.base.name())
    }

    fn encoder(&self, ctx: &CodecContext, m: usize) -> Box<dyn EncodeSink + '_> {
        let ctx = *ctx;
        Box::new(BufferedSink::new(m, move |h: &[f32]| self.encode_whole(h, &ctx)))
    }

    /// Skip the session input buffer for the whole-buffer entry point.
    fn encode(&self, h: &[f32], ctx: &CodecContext) -> Encoded {
        self.encode_whole(h, ctx)
    }

    fn decoder<'a>(
        &'a self,
        msg: &'a Encoded,
        m: usize,
        ctx: &CodecContext,
    ) -> Box<dyn DecodeStream + 'a> {
        let l = self.base.dim();
        let n_sub = m.div_ceil(l);
        let mut r = BitReader::new(&msg.bytes);
        let scale_factor = r.read_f32() as f64;
        let s = r.read_f32() as f64;
        if scale_factor == 0.0 || s == 0.0 {
            return Box::new(EntryStream::new(m, || Ok(0.0)));
        }
        let sym = SymbolDecoder::from_embedded(&msg.bytes, &mut r, l);
        let rng = ctx.crand.stream(ctx.user, ctx.round, StreamKind::Dither);
        let blocks_per_chunk = (DEFAULT_CHUNK / l).max(1);
        Box::new(UveqfedStream {
            base: self.base.as_ref(),
            subtractive: self.subtractive,
            sym,
            rng,
            scale_factor,
            s,
            l,
            n_sub,
            next_block: 0,
            m,
            blocks_per_chunk,
            coords: vec![0i64; l],
            point: vec![0.0; l],
            zbuf: vec![0.0; l],
            lat_scratch: Scratch::new(),
            scratch: Vec::with_capacity(blocks_per_chunk * l),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Normal, Rng, Xoshiro256pp};

    fn gaussian(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        Normal::new(0.0, 1.0).vec_f32(&mut rng, n)
    }

    #[test]
    fn roundtrip_within_budget_all_lattices() {
        let h = gaussian(1024, 71);
        for (codec, rate) in [
            (UVeQFed::scalar(), 2.0),
            (UVeQFed::hexagonal(), 2.0),
            (UVeQFed::d4(), 2.0),
            (UVeQFed::e8(), 4.0),
        ] {
            let ctx = CodecContext::new(3, 5, 42, rate);
            let enc = codec.encode(&h, &ctx);
            assert!(
                enc.bits <= ctx.budget_bits(h.len()),
                "{}: {} > {}",
                codec.name(),
                enc.bits,
                ctx.budget_bits(h.len())
            );
            let dec = codec.decode(&enc, h.len(), &ctx);
            assert_eq!(dec.len(), h.len());
            // sanity: decoded vector correlates with input
            let dot: f64 = h.iter().zip(&dec).map(|(&a, &b)| (a as f64) * (b as f64)).sum();
            assert!(dot > 0.0, "{}: no correlation", codec.name());
        }
    }

    #[test]
    fn stream_chunks_on_lattice_block_boundaries() {
        let h = gaussian(2050, 70); // not a multiple of DEFAULT_CHUNK or L
        let codec = UVeQFed::hexagonal();
        let ctx = CodecContext::new(0, 0, 9, 4.0);
        let enc = codec.encode(&h, &ctx);
        let mut stream = codec.decoder(&enc, h.len(), &ctx);
        let mut total = 0usize;
        let mut chunks = 0usize;
        while let Some(c) = stream.next_chunk().unwrap() {
            total += c.len();
            chunks += 1;
            if total < h.len() {
                assert_eq!(c.len() % 2, 0, "chunk not on L=2 block boundary");
            }
        }
        assert_eq!(total, h.len());
        assert!(chunks > 1, "expected multiple chunks for m=2050");
    }

    #[test]
    fn higher_rate_lower_distortion() {
        let h = gaussian(4096, 72);
        let codec = UVeQFed::hexagonal();
        let mut last = f64::INFINITY;
        for rate in [1.0, 2.0, 4.0, 6.0] {
            let rep = super::super::measure_distortion(&codec, &h, rate, 7, 0);
            assert!(rep.mse < last, "rate {rate}: {} !< {last}", rep.mse);
            last = rep.mse;
        }
    }

    #[test]
    fn vector_vs_scalar_at_equal_rate() {
        // The paper's Fig. 4/5 claim. Under entropy-coded dithered
        // quantization (ECDQ) the i.i.d. high-rate gain of A2 over Z is
        // only G(Z)/G(A2) ≈ 3.7% — we assert parity-or-better there — while
        // on *correlated* data the vector quantizer's joint encoding wins
        // clearly (the gain the paper highlights for Fig. 5).
        let (mut d1, mut d2) = (0.0, 0.0);
        for seed in 0..8 {
            let h = gaussian(8192, 100 + seed);
            d1 += super::super::measure_distortion(&UVeQFed::scalar(), &h, 3.0, seed, 0).mse;
            d2 += super::super::measure_distortion(&UVeQFed::hexagonal(), &h, 3.0, seed, 0).mse;
        }
        assert!(d2 < d1 * 1.05, "iid: hex {d2} !<~ scalar {d1}");

        let (mut c1, mut c2) = (0.0, 0.0);
        for seed in 0..8 {
            let mut h = crate::data::gaussian_matrix(64, 500 + seed);
            let sigma = crate::data::exp_decay_sigma(64, 0.2);
            h = crate::data::correlated_matrix(&h, &sigma, 64);
            c1 += super::super::measure_distortion(&UVeQFed::scalar(), &h, 3.0, seed, 0).mse;
            c2 += super::super::measure_distortion(&UVeQFed::hexagonal(), &h, 3.0, seed, 0).mse;
        }
        assert!(c2 < c1, "correlated: hex {c2} !< scalar {c1}");
    }

    #[test]
    fn subtractive_beats_non_subtractive() {
        let mut ds = 0.0;
        let mut dn = 0.0;
        for seed in 0..8 {
            let h = gaussian(8192, 200 + seed);
            ds += super::super::measure_distortion(&UVeQFed::hexagonal(), &h, 2.0, seed, 0).mse;
            dn += super::super::measure_distortion(
                &UVeQFed::hexagonal().non_subtractive(),
                &h,
                2.0,
                seed,
                0,
            )
            .mse;
        }
        assert!(ds < dn, "subtractive {ds} !< non-subtractive {dn}");
    }

    #[test]
    fn zero_update_roundtrips() {
        let h = vec![0.0f32; 100];
        let codec = UVeQFed::hexagonal();
        let ctx = CodecContext::new(0, 0, 1, 2.0);
        let enc = codec.encode(&h, &ctx);
        let dec = codec.decode(&enc, 100, &ctx);
        assert_eq!(dec, h);
    }

    #[test]
    fn non_multiple_of_l_length() {
        let h = gaussian(1001, 73); // 1001 = odd, not multiple of 2
        let codec = UVeQFed::hexagonal();
        let ctx = CodecContext::new(0, 0, 1, 4.0);
        let enc = codec.encode(&h, &ctx);
        let dec = codec.decode(&enc, h.len(), &ctx);
        assert_eq!(dec.len(), 1001);
        assert!(enc.bits <= ctx.budget_bits(1001));
    }

    #[test]
    fn encoder_decoder_dither_agreement_across_users_rounds() {
        // Different (user, round) → different dither, but decode always
        // matches its own encode context.
        let h = gaussian(512, 74);
        let codec = UVeQFed::hexagonal();
        for (user, round) in [(0, 0), (1, 0), (0, 1), (7, 13)] {
            let ctx = CodecContext::new(user, round, 99, 4.0);
            let enc = codec.encode(&h, &ctx);
            let dec = codec.decode(&enc, h.len(), &ctx);
            let mse = crate::util::stats::mse(&h, &dec);
            assert!(mse < 0.1, "user {user} round {round}: mse {mse}");
        }
    }

    #[test]
    fn wrong_round_context_decodes_garbage() {
        // Using the wrong dither stream must hurt: this is evidence the
        // dither subtraction is real, not a no-op.
        let h = gaussian(2048, 75);
        let codec = UVeQFed::hexagonal();
        let ctx_enc = CodecContext::new(0, 0, 99, 2.0);
        let ctx_wrong = CodecContext::new(0, 1, 99, 2.0);
        let enc = codec.encode(&h, &ctx_enc);
        let good = codec.decode(&enc, h.len(), &ctx_enc);
        let bad = codec.decode(&enc, h.len(), &ctx_wrong);
        let mse_good = crate::util::stats::mse(&h, &good);
        let mse_bad = crate::util::stats::mse(&h, &bad);
        assert!(mse_bad > mse_good, "wrong dither should decode worse");
    }

    #[test]
    fn theorem1_error_energy_matches_prediction() {
        // E{‖ε‖² | h} = ζ²‖h‖²·M·σ̄²_Λ(s·Λ) with σ̄²(sΛ) = s²σ̄²(Λ).
        // Measure over many rounds (fresh dither each) on one h.
        let h = gaussian(2048, 76);
        let codec = UVeQFed::hexagonal();
        let mut total = 0.0;
        let rounds = 64;
        let mut predicted = 0.0;
        for round in 0..rounds {
            let ctx = CodecContext::new(0, round, 5, 2.0);
            let enc = codec.encode(&h, &ctx);
            let dec = codec.decode(&enc, h.len(), &ctx);
            let err_sq: f64 = h
                .iter()
                .zip(&dec)
                .map(|(&a, &b)| ((a as f64) - (b as f64)).powi(2))
                .sum();
            total += err_sq;
            // read header back for ζ‖h‖ and s
            let mut r = BitReader::new(&enc.bytes);
            let scale_factor = r.read_f32() as f64;
            let s = r.read_f32() as f64;
            let m_sub = h.len() / 2;
            predicted +=
                scale_factor * scale_factor * m_sub as f64 * codec.base_second_moment() * s * s;
        }
        let ratio = total / predicted;
        assert!(
            (0.85..1.15).contains(&ratio),
            "measured/predicted = {ratio} (measured {total}, predicted {predicted})"
        );
    }

    #[test]
    fn error_is_independent_zero_mean_across_users() {
        // Average of per-user errors should shrink like 1/K (Thm 2 spirit).
        let h = gaussian(4096, 77);
        let codec = UVeQFed::hexagonal();
        let k = 32;
        let mut avg_err = vec![0.0f64; h.len()];
        for user in 0..k {
            let ctx = CodecContext::new(user, 0, 5, 2.0);
            let enc = codec.encode(&h, &ctx);
            let dec = codec.decode(&enc, h.len(), &ctx);
            for (a, (&orig, &d)) in avg_err.iter_mut().zip(h.iter().zip(&dec)) {
                *a += (d as f64 - orig as f64) / k as f64;
            }
        }
        // single-user error energy
        let ctx = CodecContext::new(0, 0, 5, 2.0);
        let enc = codec.encode(&h, &ctx);
        let dec = codec.decode(&enc, h.len(), &ctx);
        let single: f64 =
            h.iter().zip(&dec).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
        let averaged: f64 = avg_err.iter().map(|e| e * e).sum();
        // Expect ≈ single/K; allow generous slack.
        assert!(
            averaged < single / (k as f64) * 3.0,
            "averaged {averaged} vs single {single} (K={k})"
        );
    }

    #[test]
    fn warm_start_reuses_scale() {
        let codec = UVeQFed::hexagonal();
        let h = gaussian(2048, 78);
        let _ = codec.encode(&h, &CodecContext::new(0, 0, 7, 2.0));
        let s1 = codec.hint.peek(2.0).unwrap();
        // The next round warm-starts from round 0's accepted scale and
        // must land in the same neighborhood on the same data.
        let _ = codec.encode(&h, &CodecContext::new(0, 1, 7, 2.0));
        let s2 = codec.hint.peek(2.0).unwrap();
        assert!((s1 - s2).abs() / s1 < 0.25, "hint unstable: {s1} vs {s2}");
    }

    #[test]
    fn warm_start_is_round_frozen_and_deterministic() {
        // Concurrent clients of one round must not see each other's
        // accepted scales: encoding (user 0, round 1) then (user 1,
        // round 1) must produce exactly the bytes of encoding them in
        // the opposite order — the fleet's worker-count-independence
        // contract at the codec level.
        let h = gaussian(2048, 80);
        let encode_pair = |first: u64, second: u64| {
            let codec = UVeQFed::hexagonal();
            let _ = codec.encode(&h, &CodecContext::new(0, 0, 7, 2.0)); // warm round 0
            let a = codec.encode(&h, &CodecContext::new(first, 1, 7, 2.0));
            let b = codec.encode(&h, &CodecContext::new(second, 1, 7, 2.0));
            (a, b)
        };
        let (a01, b01) = encode_pair(0, 1);
        let (b10, a10) = encode_pair(1, 0);
        assert_eq!(a01, a10, "user 0's encode must not depend on encode order");
        assert_eq!(b01, b10, "user 1's encode must not depend on encode order");
    }

    #[test]
    fn warm_start_rewinds_for_a_fresh_run() {
        // Re-running a schedule on the same instance (round counter back
        // to 0) must reproduce the first run bit-for-bit — the
        // RoundDriver-vs-FleetDriver parity test reuses one codec.
        let codec = UVeQFed::hexagonal();
        let h = gaussian(1024, 81);
        let run = |codec: &UVeQFed| {
            (0..3)
                .map(|round| codec.encode(&h, &CodecContext::new(0, round, 7, 2.0)))
                .collect::<Vec<_>>()
        };
        let first = run(&codec);
        let second = run(&codec);
        assert_eq!(first, second, "instance reuse must not leak warm-start state");
    }

    #[test]
    fn warm_start_tiers_do_not_cross_contaminate() {
        // One codec instance serving two very different budgets must keep
        // one warm-start scale per tier: the R=8 scale is far finer than
        // the R=1 scale, and each tier's hint must retain its own value
        // after interleaved encodes (the heterogeneous-uplink regime).
        let codec = UVeQFed::hexagonal();
        let h = gaussian(4096, 79);
        for round in 0..3 {
            let _ = codec.encode(&h, &CodecContext::new(0, round, 7, 1.0));
            let _ = codec.encode(&h, &CodecContext::new(1, round, 7, 8.0));
        }
        let coarse = codec.hint.peek(1.0).unwrap();
        let fine = codec.hint.peek(8.0).unwrap();
        assert!(
            fine < coarse,
            "R=8 must warm-start at a finer scale than R=1: {fine} !< {coarse}"
        );
        // Encodes at either tier still fit their budgets.
        for rate in [1.0, 8.0] {
            let ctx = CodecContext::new(2, 9, 7, rate);
            let enc = codec.encode(&h, &ctx);
            assert!(enc.bits <= ctx.budget_bits(h.len()), "rate {rate}");
        }
    }

    #[test]
    fn mostly_sparse_update_compresses_fine() {
        let mut rng = Xoshiro256pp::seed_from_u64(79);
        let h: Vec<f32> = (0..4096)
            .map(|_| if rng.uniform() < 0.01 { rng.normal_f32() } else { 0.0 })
            .collect();
        let codec = UVeQFed::hexagonal();
        let ctx = CodecContext::new(0, 0, 7, 1.0);
        let enc = codec.encode(&h, &ctx);
        assert!(enc.bits <= ctx.budget_bits(h.len()));
        let dec = codec.decode(&enc, h.len(), &ctx);
        let mse = crate::util::stats::mse(&h, &dec);
        let var: f64 =
            h.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / h.len() as f64;
        assert!(mse < var, "mse {mse} should beat signal power {var}");
    }
}
