//! Reusable building blocks for codec **sessions** (the streaming
//! [`EncodeSink`] / [`DecodeStream`] API of [`UpdateCodec`](super::UpdateCodec)).
//!
//! Two session shapes cover every codec in the registry:
//!
//! * **single-pass** codecs (identity, sign-SGD, and every decoder that
//!   reconstructs entries in order) keep O(chunk) state — their decoders
//!   supply a per-entry closure to [`EntryStream`], which owns the shared
//!   chunking skeleton;
//! * **two-pass** codecs — those whose first coded bit depends on a
//!   global statistic of the update (UVeQFed's ‖h‖, QSGD's level search,
//!   top-k's global sort, the rotation's full-vector transform) — use
//!   [`BufferedSink`], which accumulates pushed chunks and runs the
//!   codec's whole-buffer encoder at [`EncodeSink::finish`], and
//!   [`SliceStream`], which serves a fully-materialized decode in fixed
//!   chunks.
//!
//! The buffered fallbacks keep the *API* uniform (callers always push
//! chunks and drain streams) while being honest about memory:
//! [`EncodeSink::state_bytes`] reports what the sink actually holds, and
//! the `fleet_scale` bench meters it.

use super::{DecodeError, DecodeStream, Encoded, EncodeSink};
use crate::entropy::range::SymbolDecoder;

/// Entries per chunk yielded by buffered decode streams and used by the
/// fleet driver when pushing client updates through an [`EncodeSink`].
pub const DEFAULT_CHUNK: usize = 1024;

/// [`EncodeSink`] for two-pass codecs: buffers every pushed chunk and
/// invokes the codec's whole-buffer encoder once at `finish`.
///
/// Bit-exactness is inherited: any partition of the input produces the
/// same buffered vector, hence the same encoding.
pub struct BufferedSink<F> {
    buf: Vec<f32>,
    expected: usize,
    encode: F,
}

impl<F: FnOnce(&[f32]) -> Encoded> BufferedSink<F> {
    /// `expected` is the update length `m` the session was opened for;
    /// `encode` is the codec's whole-buffer encoder.
    pub fn new(expected: usize, encode: F) -> Self {
        Self { buf: Vec::with_capacity(expected), expected, encode }
    }
}

impl<F: FnOnce(&[f32]) -> Encoded> EncodeSink for BufferedSink<F> {
    fn push(&mut self, chunk: &[f32]) {
        self.buf.extend_from_slice(chunk);
    }

    fn state_bytes(&self) -> usize {
        self.buf.capacity() * std::mem::size_of::<f32>()
    }

    fn finish(self: Box<Self>) -> Encoded {
        let BufferedSink { buf, expected, encode } = *self;
        assert_eq!(
            buf.len(),
            expected,
            "EncodeSink fed {} entries, session opened for {expected}",
            buf.len()
        );
        encode(&buf)
    }
}

/// [`DecodeStream`] over a fully-materialized update, served in
/// [`DEFAULT_CHUNK`]-entry chunks — the fallback for scatter/transform
/// decoders (top-k, subsampling, rotation) that cannot reconstruct
/// entries in stream order.
pub struct SliceStream {
    buf: Vec<f32>,
    pos: usize,
}

impl SliceStream {
    pub fn new(buf: Vec<f32>) -> Self {
        Self { buf, pos: 0 }
    }
}

impl DecodeStream for SliceStream {
    fn next_chunk(&mut self) -> Result<Option<&[f32]>, DecodeError> {
        if self.pos >= self.buf.len() {
            return Ok(None);
        }
        let end = (self.pos + DEFAULT_CHUNK).min(self.buf.len());
        let chunk = &self.buf[self.pos..end];
        self.pos = end;
        Ok(Some(chunk))
    }

    fn state_bytes(&self) -> usize {
        self.buf.capacity() * std::mem::size_of::<f32>()
    }
}

/// [`DecodeStream`] adapter for per-entry decoders: pulls one entry at a
/// time from `next_entry` and yields [`DEFAULT_CHUNK`]-sized chunks.
///
/// This is the shared chunking skeleton behind the single-pass streams
/// (identity, sign-SGD, QSGD, TernGrad, and the degenerate all-zero
/// message `EntryStream::new(m, || Ok(0.0))`) — the per-codec decoders
/// supply only the per-entry closure. A closure `Err` (corrupt entropy
/// stream) propagates out of `next_chunk` without yielding the partial
/// chunk.
pub struct EntryStream<F> {
    remaining: usize,
    scratch: Vec<f32>,
    next_entry: F,
}

impl<F: FnMut() -> Result<f32, DecodeError>> EntryStream<F> {
    /// Stream of exactly `m` entries drawn from `next_entry`. The chunk
    /// buffer is preallocated here so steady-state `next_chunk` never
    /// allocates.
    pub fn new(m: usize, next_entry: F) -> Self {
        Self { remaining: m, scratch: Vec::with_capacity(m.min(DEFAULT_CHUNK)), next_entry }
    }
}

impl<F: FnMut() -> Result<f32, DecodeError>> DecodeStream for EntryStream<F> {
    fn next_chunk(&mut self) -> Result<Option<&[f32]>, DecodeError> {
        if self.remaining == 0 {
            return Ok(None);
        }
        let n = self.remaining.min(DEFAULT_CHUNK);
        self.scratch.clear();
        for _ in 0..n {
            let v = (self.next_entry)()?;
            self.scratch.push(v);
        }
        self.remaining -= n;
        Ok(Some(&self.scratch))
    }

    fn state_bytes(&self) -> usize {
        self.scratch.capacity() * std::mem::size_of::<f32>()
    }
}

/// [`DecodeStream`] over a range-coded symbol payload: pulls
/// [`DEFAULT_CHUNK`] symbols per chunk through the **batched**
/// [`SymbolDecoder::decode_into`] and maps each to an f32. This is the
/// shared single-pass skeleton for the range-coded codecs (QSGD's
/// sub-1-bit fallback, TernGrad); buffers are preallocated so
/// steady-state `next_chunk` performs zero heap allocation.
pub struct SymbolMapStream<'a, F> {
    sym: SymbolDecoder<'a>,
    remaining: usize,
    ibuf: Vec<i64>,
    scratch: Vec<f32>,
    map: F,
}

impl<'a, F: FnMut(i64) -> f32> SymbolMapStream<'a, F> {
    /// Stream of exactly `m` entries: symbol `i` decodes via `sym` and
    /// reconstructs as `map(symbol)`.
    pub fn new(sym: SymbolDecoder<'a>, m: usize, map: F) -> Self {
        let cap = m.min(DEFAULT_CHUNK);
        Self {
            sym,
            remaining: m,
            ibuf: Vec::with_capacity(cap),
            scratch: Vec::with_capacity(cap),
            map,
        }
    }
}

impl<F: FnMut(i64) -> f32> DecodeStream for SymbolMapStream<'_, F> {
    fn next_chunk(&mut self) -> Result<Option<&[f32]>, DecodeError> {
        if self.remaining == 0 {
            return Ok(None);
        }
        let n = self.remaining.min(DEFAULT_CHUNK);
        self.ibuf.clear();
        self.ibuf.resize(n, 0);
        self.sym.decode_into(&mut self.ibuf)?;
        self.scratch.clear();
        for &v in self.ibuf.iter() {
            let f = (self.map)(v);
            self.scratch.push(f);
        }
        self.remaining -= n;
        Ok(Some(&self.scratch))
    }

    fn state_bytes(&self) -> usize {
        self.ibuf.capacity() * std::mem::size_of::<i64>()
            + self.scratch.capacity() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffered_sink_runs_encoder_over_concatenation() {
        let sink = BufferedSink::new(5, |h: &[f32]| Encoded {
            bytes: h.iter().map(|&v| v as u8).collect(),
            bits: h.len() * 8,
        });
        let mut sink: Box<dyn EncodeSink> = Box::new(sink);
        sink.push(&[1.0, 2.0]);
        sink.push(&[]);
        sink.push(&[3.0, 4.0, 5.0]);
        assert!(sink.state_bytes() >= 5 * 4);
        let enc = sink.finish();
        assert_eq!(enc.bytes, vec![1, 2, 3, 4, 5]);
        assert_eq!(enc.bits, 40);
    }

    #[test]
    #[should_panic(expected = "session opened for")]
    fn buffered_sink_rejects_wrong_length() {
        let sink = BufferedSink::new(3, |_: &[f32]| Encoded { bytes: vec![], bits: 0 });
        let mut sink: Box<dyn EncodeSink> = Box::new(sink);
        sink.push(&[1.0]);
        let _ = sink.finish();
    }

    #[test]
    fn slice_stream_chunks_concatenate_to_buffer() {
        let data: Vec<f32> = (0..2500).map(|i| i as f32).collect();
        let mut s = SliceStream::new(data.clone());
        let mut out = Vec::new();
        let mut chunks = 0;
        while let Some(c) = s.next_chunk().unwrap() {
            assert!(c.len() <= DEFAULT_CHUNK);
            out.extend_from_slice(c);
            chunks += 1;
        }
        assert_eq!(out, data);
        assert_eq!(chunks, 3);
    }

    #[test]
    fn slice_stream_empty() {
        let mut s = SliceStream::new(Vec::new());
        assert!(s.next_chunk().unwrap().is_none());
    }

    #[test]
    fn entry_stream_yields_exactly_m_entries_in_order() {
        for m in [0usize, 1, DEFAULT_CHUNK, DEFAULT_CHUNK + 7] {
            let mut i = 0u32;
            let mut s = EntryStream::new(m, move || {
                i += 1;
                Ok(i as f32)
            });
            let mut drained = Vec::new();
            while let Some(c) = s.next_chunk().unwrap() {
                assert!(c.len() <= DEFAULT_CHUNK && !c.is_empty());
                drained.extend_from_slice(c);
            }
            let want: Vec<f32> = (1..=m as u32).map(|v| v as f32).collect();
            assert_eq!(drained, want);
        }
    }

    #[test]
    fn entry_stream_propagates_decode_error() {
        let mut i = 0u32;
        let mut s = EntryStream::new(DEFAULT_CHUNK + 5, move || {
            i += 1;
            if i > 3 {
                Err(DecodeError::Header("synthetic corruption"))
            } else {
                Ok(i as f32)
            }
        });
        assert!(s.next_chunk().is_err());
    }
}
