//! Rate-targeting search shared by the variable-resolution codecs.
//!
//! The paper meets the bit constraint by "scaling G such that the
//! resulting codewords use less than R·m bits" (§V-A). We implement that
//! as a monotone search over the lattice scale `s`: coarser lattices
//! (larger `s`) produce lower-entropy index streams and fewer coded bits,
//! so the feasible set `{s : bits(s) ≤ budget}` is an interval `[s*, ∞)`
//! and we want its left edge (finest feasible lattice).
//!
//! The search uses a cheap entropy-based size estimate for bracketing and
//! bisection, then verifies with the exact coder, nudging coarser until the
//! exact encoding fits. A cross-round warm-start hint (atomic, shared
//! across clients of the same codec instance) collapses the search to a
//! couple of probes in steady state because update statistics drift slowly
//! between FL rounds.

use std::sync::atomic::{AtomicU64, Ordering};

/// Warm-start cell: stores the last accepted scale as f64 bits.
#[derive(Debug, Default)]
pub struct ScaleHint {
    bits: AtomicU64,
}

impl ScaleHint {
    pub fn new() -> Self {
        Self { bits: AtomicU64::new(0) }
    }

    pub fn get(&self) -> Option<f64> {
        let b = self.bits.load(Ordering::Relaxed);
        if b == 0 {
            None
        } else {
            Some(f64::from_bits(b))
        }
    }

    pub fn set(&self, s: f64) {
        self.bits.store(s.to_bits(), Ordering::Relaxed);
    }
}

/// Find the (approximately) smallest `s` in `[lo_bound, ∞)` with
/// `cost(s) ≤ budget`, where `cost` is non-increasing in `s`.
///
/// `cost` is the *estimated* bit count; `exact` the exact one. Both are
/// `FnMut` so callers can thread scratch buffers and memoize the last
/// exact encoding (UVeQFed reuses it verbatim at commit). Returns the
/// accepted scale; the final accepted value is always probed through
/// `exact` last. Panics only if no scale up to `lo_bound · 2^60` fits —
/// which cannot happen for entropy-coded streams (all-zero indices cost
/// O(M) bits).
pub fn search_scale(
    budget: usize,
    init: f64,
    mut cost: impl FnMut(f64) -> usize,
    mut exact: impl FnMut(f64) -> usize,
) -> f64 {
    assert!(init > 0.0 && init.is_finite());
    // Bracket: grow/shrink geometrically until we straddle the budget.
    let mut lo = init; // may be infeasible (too fine)
    let mut hi = init; // will be feasible (coarse enough)
    if cost(hi) > budget {
        let mut iters = 0;
        while cost(hi) > budget {
            hi *= 2.0;
            iters += 1;
            assert!(iters < 64, "rate search diverged (budget {budget})");
        }
        lo = hi / 2.0;
    } else {
        // Shrinking is bounded: past ~20 halvings the added resolution is
        // below f32 reconstruction noise, and sparse inputs (whose index
        // entropy barely grows as s → 0) would otherwise drive s to a
        // subnormal and blow up the coordinate magnitudes.
        let mut iters = 0;
        loop {
            let cand = lo / 2.0;
            if cost(cand) > budget || iters >= 20 {
                break;
            }
            lo = cand;
            iters += 1;
        }
        // lo is feasible; make it the hi edge and probe below.
        hi = lo;
        lo /= 2.0;
    }
    // Bisect on log-scale: hi stays feasible, lo infeasible. 12 steps
    // give a 2^(1/2^12)≈1.0002 bracket on s — far below the precision
    // that matters for the coded size (§Perf: halved from 24, <0.1%
    // rate-utilization change, 2× fewer estimate passes).
    for _ in 0..12 {
        let mid = (lo * hi).sqrt();
        if cost(mid) <= budget {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    // Exact verification: entropy estimates can undershoot the true coded
    // size; coarsen gently first (the common off-by-a-few-percent case),
    // then geometrically (degenerate estimates, e.g. ultra-sparse inputs),
    // so termination is guaranteed for any monotone `exact`.
    let mut s = hi;
    let mut iters = 0;
    while exact(s) > budget {
        s *= if iters < 40 { 1.07 } else { 2.0 };
        iters += 1;
        assert!(iters < 200, "exact rate verification diverged");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_left_edge_of_feasible_set() {
        // cost(s) = ceil(1000 / s); budget 100 → s* = 10.
        let cost = |s: f64| (1000.0 / s).ceil() as usize;
        let s = search_scale(100, 1.0, cost, cost);
        assert!(cost(s) <= 100);
        assert!(s < 10.6, "s={s} too coarse");
    }

    #[test]
    fn warm_start_from_feasible_side() {
        let cost = |s: f64| (1000.0 / s).ceil() as usize;
        let s = search_scale(100, 500.0, cost, cost);
        assert!(cost(s) <= 100);
        assert!(s < 10.6, "s={s}");
    }

    #[test]
    fn exact_coarsening_applied() {
        // Estimated cost says everything fits; exact disagrees below 5.
        let est = |_s: f64| 0usize;
        let exact = |s: f64| if s < 5.0 { 1000 } else { 10 };
        let s = search_scale(100, 1.0, est, exact);
        assert!(exact(s) <= 100);
    }

    #[test]
    fn hint_roundtrip() {
        let h = ScaleHint::new();
        assert!(h.get().is_none());
        h.set(0.125);
        assert_eq!(h.get(), Some(0.125));
    }
}
