//! Rate-targeting search shared by the variable-resolution codecs.
//!
//! The paper meets the bit constraint by "scaling G such that the
//! resulting codewords use less than R·m bits" (§V-A). We implement that
//! as a monotone search over the lattice scale `s`: coarser lattices
//! (larger `s`) produce lower-entropy index streams and fewer coded bits,
//! so the feasible set `{s : bits(s) ≤ budget}` is an interval `[s*, ∞)`
//! and we want its left edge (finest feasible lattice).
//!
//! The search uses a cheap entropy-based size estimate for bracketing and
//! bisection, then verifies with the exact coder, nudging coarser until the
//! exact encoding fits. A cross-round warm-start ([`ScaleHintMap`], keyed
//! by quarter-bit rate tier) shortens the bracketing in steady state
//! because update statistics drift slowly between FL rounds — but reads
//! are **round-frozen** and writes pick a deterministic winner, so the
//! warm start can never leak scheduling order into the accepted scale
//! (sharing a plain mutable cell across concurrently-encoding clients
//! did exactly that before the heterogeneous-uplink rework, and is the
//! pattern to avoid).

/// Number of warm-start tiers in a [`ScaleHintMap`]: rates `0..16`
/// bits/entry at quarter-bit resolution.
const HINT_BUCKETS: usize = 64;

/// One rate tier's warm-start state. The committed value is what readers
/// see; the pending value is this round's candidate, promoted the first
/// time a *later* round touches the cell.
#[derive(Debug, Clone, Copy, Default)]
struct HintCell {
    committed: Option<f64>,
    /// Round whose winner produced `pending` (and, implicitly, an upper
    /// bound on the rounds folded into `committed`).
    pending_round: u64,
    pending_user: u64,
    pending: Option<f64>,
}

impl HintCell {
    /// Fold `pending` into `committed` when `round` has moved past it.
    fn promote(&mut self, round: u64) {
        if self.pending.is_some() && self.pending_round < round {
            self.committed = self.pending;
        }
    }
}

/// Rate-keyed, **round-frozen** warm-start map: one cell per quarter-bit
/// rate tier.
///
/// Two problems with the old single shared-atomic cell, both fixed here:
///
/// * **tier thrash** — with heterogeneous uplinks one codec instance
///   serves clients whose budgets differ by an order of magnitude, and a
///   shared cell degrades every tier's warm start back to a cold search.
///   Rates within the same quarter-bit share a cell; their accepted
///   scales are within the search's own bracket tolerance of each other.
/// * **nondeterminism** — the old cell was read/written mid-round by
///   concurrently-encoding clients, so a client's search *init* — and
///   with it the accepted scale serialized into its message — depended
///   on worker interleaving, breaking the fleet's worker-count-
///   independence contract. Here reads at round `r` only ever observe the
///   value committed by a round `< r`, and the within-round writer is
///   chosen deterministically (smallest user id), so every client's
///   encode is a pure function of `(h, ctx)` again.
#[derive(Debug)]
pub struct ScaleHintMap {
    cells: [std::sync::Mutex<HintCell>; HINT_BUCKETS],
}

impl Default for ScaleHintMap {
    fn default() -> Self {
        Self::new()
    }
}

impl ScaleHintMap {
    pub fn new() -> Self {
        Self { cells: std::array::from_fn(|_| std::sync::Mutex::new(HintCell::default())) }
    }

    /// Quarter-bit tier index for a rate (bits/entry), clamped to the
    /// table. Non-finite / negative rates share bucket 0.
    fn bucket(rate: f64) -> usize {
        if !rate.is_finite() || rate <= 0.0 {
            return 0;
        }
        ((rate * 4.0).round() as usize).min(HINT_BUCKETS - 1)
    }

    /// A round counter moving backwards means a new run is reusing this
    /// codec instance — reset the cell so the rerun behaves exactly like
    /// a fresh instance (`RoundDriver`-vs-`FleetDriver` bitwise parity
    /// depends on this).
    fn rewind_check(c: &mut HintCell, round: u64) {
        if c.pending.is_some() && round < c.pending_round {
            *c = HintCell::default();
        }
    }

    /// Warm-start scale for this rate tier at `round`: the accepted scale
    /// of the most recent *earlier* round (never a same-round value — the
    /// round freeze is what makes concurrent encodes deterministic).
    pub fn get(&self, rate: f64, round: u64) -> Option<f64> {
        let mut c = self.cells[Self::bucket(rate)].lock().unwrap();
        Self::rewind_check(&mut c, round);
        c.promote(round);
        c.committed
    }

    /// Record `user`'s accepted scale for this tier at `round`. Among the
    /// writers of one round the smallest user id wins, so the value the
    /// next round warm-starts from is schedule-independent.
    pub fn set(&self, rate: f64, round: u64, user: u64, s: f64) {
        let mut c = self.cells[Self::bucket(rate)].lock().unwrap();
        Self::rewind_check(&mut c, round);
        let newer = round > c.pending_round || c.pending.is_none();
        let same_round_winner =
            round == c.pending_round && c.pending.is_some() && user < c.pending_user;
        if newer {
            c.promote(round);
        }
        if newer || same_round_winner {
            c.pending = Some(s);
            c.pending_round = round;
            c.pending_user = user;
        }
    }

    /// Latest recorded scale for a tier regardless of round (tests /
    /// diagnostics — NOT the deterministic read path).
    pub fn peek(&self, rate: f64) -> Option<f64> {
        let c = self.cells[Self::bucket(rate)].lock().unwrap();
        c.pending.or(c.committed)
    }
}

/// Find the (approximately) smallest `s` in `[lo_bound, ∞)` with
/// `cost(s) ≤ budget`, where `cost` is non-increasing in `s`.
///
/// `cost` is the *estimated* bit count; `exact` the exact one. Both are
/// `FnMut` so callers can thread scratch buffers and memoize the last
/// exact encoding (UVeQFed reuses it verbatim at commit). Returns the
/// accepted scale; the final accepted value is always probed through
/// `exact` last. Panics only if no scale up to `lo_bound · 2^60` fits —
/// which cannot happen for entropy-coded streams (all-zero indices cost
/// O(M) bits).
pub fn search_scale(
    budget: usize,
    init: f64,
    mut cost: impl FnMut(f64) -> usize,
    mut exact: impl FnMut(f64) -> usize,
) -> f64 {
    assert!(init > 0.0 && init.is_finite());
    // Bracket: grow/shrink geometrically until we straddle the budget.
    let mut lo = init; // may be infeasible (too fine)
    let mut hi = init; // will be feasible (coarse enough)
    if cost(hi) > budget {
        let mut iters = 0;
        while cost(hi) > budget {
            hi *= 2.0;
            iters += 1;
            assert!(iters < 64, "rate search diverged (budget {budget})");
        }
        lo = hi / 2.0;
    } else {
        // Shrinking is bounded: past ~20 halvings the added resolution is
        // below f32 reconstruction noise, and sparse inputs (whose index
        // entropy barely grows as s → 0) would otherwise drive s to a
        // subnormal and blow up the coordinate magnitudes.
        let mut iters = 0;
        loop {
            let cand = lo / 2.0;
            if cost(cand) > budget || iters >= 20 {
                break;
            }
            lo = cand;
            iters += 1;
        }
        // lo is feasible; make it the hi edge and probe below.
        hi = lo;
        lo /= 2.0;
    }
    // Bisect on log-scale: hi stays feasible, lo infeasible. 12 steps
    // give a 2^(1/2^12)≈1.0002 bracket on s — far below the precision
    // that matters for the coded size (§Perf: halved from 24, <0.1%
    // rate-utilization change, 2× fewer estimate passes).
    for _ in 0..12 {
        let mid = (lo * hi).sqrt();
        if cost(mid) <= budget {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    // Exact verification: entropy estimates can undershoot the true coded
    // size; coarsen gently first (the common off-by-a-few-percent case),
    // then geometrically (degenerate estimates, e.g. ultra-sparse inputs),
    // so termination is guaranteed for any monotone `exact`.
    let mut s = hi;
    let mut iters = 0;
    while exact(s) > budget {
        s *= if iters < 40 { 1.07 } else { 2.0 };
        iters += 1;
        assert!(iters < 200, "exact rate verification diverged");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_left_edge_of_feasible_set() {
        // cost(s) = ceil(1000 / s); budget 100 → s* = 10.
        let cost = |s: f64| (1000.0 / s).ceil() as usize;
        let s = search_scale(100, 1.0, cost, cost);
        assert!(cost(s) <= 100);
        assert!(s < 10.6, "s={s} too coarse");
    }

    #[test]
    fn warm_start_from_feasible_side() {
        let cost = |s: f64| (1000.0 / s).ceil() as usize;
        let s = search_scale(100, 500.0, cost, cost);
        assert!(cost(s) <= 100);
        assert!(s < 10.6, "s={s}");
    }

    #[test]
    fn exact_coarsening_applied() {
        // Estimated cost says everything fits; exact disagrees below 5.
        let est = |_s: f64| 0usize;
        let exact = |s: f64| if s < 5.0 { 1000 } else { 10 };
        let s = search_scale(100, 1.0, est, exact);
        assert!(exact(s) <= 100);
    }

    #[test]
    fn hint_map_isolates_rate_tiers() {
        let h = ScaleHintMap::new();
        assert!(h.get(2.0, 1).is_none());
        h.set(2.0, 0, 3, 0.25);
        h.set(8.0, 0, 3, 0.001);
        assert_eq!(h.get(2.0, 1), Some(0.25), "tier 2.0 must keep its own scale");
        assert_eq!(h.get(8.0, 1), Some(0.001));
        // Same quarter-bit tier shares the cell…
        assert_eq!(h.get(2.05, 1), Some(0.25));
        // …a different tier does not.
        assert!(h.get(4.0, 1).is_none());
        // Degenerate rates are safe, not panics.
        h.set(f64::NAN, 0, 0, 1.0);
        h.set(-3.0, 0, 0, 1.0);
        assert_eq!(h.get(0.0, 1), Some(1.0));
        h.set(1e9, 0, 0, 2.0);
        assert_eq!(h.get(1e9, 1), Some(2.0));
    }

    #[test]
    fn hint_map_is_round_frozen_with_deterministic_winner() {
        let h = ScaleHintMap::new();
        // Round 0 writes are invisible to round-0 readers…
        h.set(2.0, 0, 5, 0.5);
        assert!(h.get(2.0, 0).is_none(), "same-round reads must stay frozen");
        // …and visible from round 1 on.
        assert_eq!(h.get(2.0, 1), Some(0.5));
        // Within a round, the smallest user id wins regardless of order.
        h.set(2.0, 1, 9, 0.9);
        h.set(2.0, 1, 2, 0.2);
        h.set(2.0, 1, 7, 0.7);
        // Reads during round 1 still see round 0's value…
        assert_eq!(h.get(2.0, 1), Some(0.5));
        // …and round 2 sees the smallest-user winner of round 1.
        assert_eq!(h.get(2.0, 2), Some(0.2), "winner must be the smallest user");
        // A later round's write supersedes.
        h.set(2.0, 3, 8, 0.8);
        assert_eq!(h.get(2.0, 4), Some(0.8));
        // Rewinding the round counter (a fresh run) resets the cell.
        assert!(h.get(2.0, 0).is_none(), "rewound reader must reset and go cold");
        assert!(h.get(2.0, 4).is_none(), "reset is sticky until something is recorded");
        assert_eq!(h.peek(2.0), None);
    }
}
