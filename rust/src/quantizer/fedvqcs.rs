//! FedVQCS-style compressed-sensing codec (arXiv 2204.07692), the first
//! pipeline-native codec of Codec API v3.
//!
//! The encode chain is three stages behind one [`PipelineCodec`]:
//!
//! ```text
//! x ──block-topk──▶ sparse x ──sketch (A·x)──▶ y ──UVeQFed lattice VQ──▶ bits
//! ```
//!
//! * **Block top-k** keeps the `⌈sparsity·64⌉` largest-magnitude entries
//!   of every 64-entry block (at least one per block), zeroing the rest.
//!   Blockwise selection keeps the projection local and deterministic.
//! * **Sketch** multiplies by a seeded Gaussian matrix `A ∈ ℝ^{d×m}`,
//!   `d = ⌈ratio·m⌉`, entries `N(0, 1/d)`. `A` is regenerated from the
//!   common-randomness stream [`StreamKind::Sketch`] on both sides and
//!   never travels on the wire.
//! * The sketch `y` is coded by the existing UVeQFed hexagonal-lattice
//!   quantizer via [`CodecTerminal`], which hands it the *exact* outer
//!   bit budget (computed over the original `m`, not `d`).
//!
//! Reconstruction inverts the sketch with **iterative hard thresholding**
//! (IHT): `x ← P_k(x + Aᵀ(y − A·x))` with unit step — `AᵀA ≈ I` in
//! expectation for this normalization — where `P_k` is the same block
//! top-k projection (the sparsity prior; the block-topk stage's own
//! inverse is therefore the identity). Every solver iteration charges one
//! unit of the context's [`DecodeBudget`](super::DecodeBudget) and bumps
//! [`probe::add_solver_iters`]; exhaustion surfaces as the typed
//! [`DecodeError::Budget`](super::DecodeError::Budget), never a partial
//! reconstruction. Non-finite iterates (possible under hostile wire
//! bytes) reset to zero and stop — decode is panic-free by construction.

use super::pipeline::{CodecTerminal, PipelineCodec, TransformStage};
use super::{CodecContext, DecodeBudget, DecodeError, UVeQFed};
use crate::prng::{Rng, StreamKind};
use crate::telemetry::probe;

/// Block size for the top-k sparsity pattern.
const BLOCK: usize = 64;

/// Kept entries per `block_len`-entry block: `⌈sparsity·block_len⌉`,
/// clamped to `[1, block_len]`.
fn block_k(sparsity: f64, block_len: usize) -> usize {
    ((sparsity * block_len as f64).ceil() as usize).clamp(1, block_len)
}

/// Sketch dimension `d = ⌈ratio·m⌉`, clamped to `[1, max(m, 1)]`.
fn sketch_dim(ratio: f64, m: usize) -> usize {
    ((ratio * m as f64).ceil() as usize).clamp(1, m.max(1))
}

/// Zero all but the `block_k` largest-magnitude entries of each block.
/// Deterministic under ties and NaN-safe (`f64::total_cmp` on magnitude,
/// then ascending index), so hostile solver iterates cannot panic or
/// diverge between replicas.
fn block_top_k_project(x: &mut [f64], sparsity: f64) {
    let mut idx = [0usize; BLOCK];
    for start in (0..x.len()).step_by(BLOCK) {
        let len = BLOCK.min(x.len() - start);
        let k = block_k(sparsity, len);
        if k >= len {
            continue;
        }
        let block = &mut x[start..start + len];
        let ids = &mut idx[..len];
        for (j, id) in ids.iter_mut().enumerate() {
            *id = j;
        }
        ids.sort_unstable_by(|&a, &b| {
            block[b].abs().total_cmp(&block[a].abs()).then(a.cmp(&b))
        });
        for &j in &ids[k..] {
            block[j] = 0.0;
        }
    }
}

/// Encode-side sparsification stage. Its `inverse` is the identity: the
/// sparsity prior is enforced *inside* the sketch stage's IHT projection,
/// so re-projecting here would be redundant work charged to the budget.
struct BlockTopKStage {
    sparsity: f64,
}

impl TransformStage for BlockTopKStage {
    fn name(&self) -> &'static str {
        "block-topk"
    }

    fn out_len(&self, m_in: usize, _ctx: &CodecContext) -> usize {
        m_in
    }

    fn forward(&self, mut x: Vec<f64>, _ctx: &CodecContext) -> Vec<f64> {
        block_top_k_project(&mut x, self.sparsity);
        x
    }

    fn inverse(
        &self,
        y: Vec<f64>,
        _m_in: usize,
        _ctx: &CodecContext,
        _budget: &mut DecodeBudget,
    ) -> Result<Vec<f64>, DecodeError> {
        Ok(y)
    }
}

/// Seeded Gaussian sketch `y = A·x` with a budgeted IHT inverse.
struct SketchStage {
    ratio: f64,
    sparsity: f64,
    solver_iters: u32,
}

impl SketchStage {
    /// The shared-seed stream both sides draw `A` from, row-major.
    fn sketch_rng(ctx: &CodecContext) -> impl Rng {
        ctx.crand.stream(ctx.user, ctx.round, StreamKind::Sketch)
    }
}

impl TransformStage for SketchStage {
    fn name(&self) -> &'static str {
        "sketch"
    }

    fn out_len(&self, m_in: usize, _ctx: &CodecContext) -> usize {
        sketch_dim(self.ratio, m_in)
    }

    /// `y[r] = Σ_i A[r][i]·x[i]`, streaming `A` row by row — O(d·m) time,
    /// O(1) extra memory beyond the output.
    fn forward(&self, x: Vec<f64>, ctx: &CodecContext) -> Vec<f64> {
        let m = x.len();
        let d = sketch_dim(self.ratio, m);
        let inv_sqrt_d = 1.0 / (d as f64).sqrt();
        let mut rng = Self::sketch_rng(ctx);
        let mut y = vec![0.0f64; d];
        for yr in y.iter_mut() {
            let mut acc = 0.0f64;
            for &xi in &x {
                acc += rng.normal() * inv_sqrt_d * xi;
            }
            *yr = acc;
        }
        y
    }

    /// Budgeted IHT: each iteration charges one [`DecodeBudget`] unit
    /// before running. An all-zero sketch (the empty-message convention)
    /// short-circuits to zeros without charging — decoding a silent
    /// client must stay free.
    fn inverse(
        &self,
        y: Vec<f64>,
        m_in: usize,
        ctx: &CodecContext,
        budget: &mut DecodeBudget,
    ) -> Result<Vec<f64>, DecodeError> {
        let d = sketch_dim(self.ratio, m_in);
        if y.len() != d {
            return Err(DecodeError::Length { got: y.len(), want: d });
        }
        if m_in == 0 || y.iter().all(|&v| v == 0.0) {
            return Ok(vec![0.0f64; m_in]);
        }

        // Materialize A once (row-major, same draw order as `forward`):
        // the solver touches it 2·solver_iters times, so regenerating per
        // pass would dominate the decode cost.
        let inv_sqrt_d = 1.0 / (d as f64).sqrt();
        let mut rng = Self::sketch_rng(ctx);
        let a: Vec<f64> = (0..d * m_in).map(|_| rng.normal() * inv_sqrt_d).collect();

        let mut x = vec![0.0f64; m_in];
        let mut prev = vec![0.0f64; m_in];
        let mut resid = vec![0.0f64; d];
        for _ in 0..self.solver_iters {
            budget.charge(1)?;
            probe::add_solver_iters(1);
            prev.copy_from_slice(&x);
            // resid = y − A·x
            for (r, (yr, row)) in resid.iter_mut().zip(y.iter().zip(a.chunks_exact(m_in))) {
                let ax: f64 = row.iter().zip(&x).map(|(av, xv)| av * xv).sum();
                *r = yr - ax;
            }
            // x += Aᵀ·resid (unit step)
            for (row, &rr) in a.chunks_exact(m_in).zip(&resid) {
                for (xv, &av) in x.iter_mut().zip(row) {
                    *xv += av * rr;
                }
            }
            block_top_k_project(&mut x, self.sparsity);
            if x.iter().any(|v| !v.is_finite()) {
                // Hostile bytes can push the iteration to overflow; a
                // zero reconstruction is the safe, deterministic fallback.
                x.iter_mut().for_each(|v| *v = 0.0);
                break;
            }
            if x == prev {
                break; // converged exactly; further iterations are no-ops
            }
        }
        Ok(x)
    }
}

/// FedVQCS codec parameters. Build the actual codec with
/// [`FedVqcs::pipeline`]; the registry spelling is
/// `"fedvqcs:ratio=0.25,sparsity=0.05,solver_iters=50"`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FedVqcs {
    /// Sketch compression ratio `d/m`, in `(0, 1]`.
    pub ratio: f64,
    /// Kept fraction per 64-entry block, in `(0, 1]`.
    pub sparsity: f64,
    /// IHT iteration cap (each iteration costs one decode-budget unit).
    pub solver_iters: u32,
}

impl Default for FedVqcs {
    fn default() -> Self {
        Self { ratio: 0.25, sparsity: 0.05, solver_iters: 50 }
    }
}

impl FedVqcs {
    /// Assemble the staged codec: block top-k → Gaussian sketch →
    /// UVeQFed hexagonal-lattice terminal.
    pub fn pipeline(self) -> PipelineCodec {
        PipelineCodec::new(
            "fedvqcs",
            vec![
                Box::new(BlockTopKStage { sparsity: self.sparsity }),
                Box::new(SketchStage {
                    ratio: self.ratio,
                    sparsity: self.sparsity,
                    solver_iters: self.solver_iters,
                }),
            ],
            Box::new(CodecTerminal::new(UVeQFed::hexagonal())),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Normal, Xoshiro256pp};
    use crate::quantizer::{measure_distortion, UpdateCodec};

    /// A genuinely block-sparse signal: two large entries per 64-block.
    fn block_sparse(m: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut h = vec![0.0f32; m];
        for b in 0..m.div_ceil(BLOCK) {
            for j in 0..2 {
                let i = b * BLOCK + j * 17;
                if i < m {
                    h[i] = 8.0 + Normal::new(0.0, 1.0).sample(&mut rng) as f32;
                }
            }
        }
        h
    }

    fn dense(m: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        Normal::new(0.0, 1.0).vec_f32(&mut rng, m)
    }

    fn cheap() -> FedVqcs {
        FedVqcs { ratio: 0.5, sparsity: 0.05, solver_iters: 30 }
    }

    #[test]
    fn recovers_block_sparse_signal() {
        let h = block_sparse(512, 11);
        let rep = measure_distortion(&cheap().pipeline(), &h, 4.0, 3, 0);
        let power: f64 =
            h.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / h.len() as f64;
        // The sketch keeps d = m/2 measurements for ~16 nonzeros per 512
        // entries; IHT must recover most of the signal energy.
        assert!(rep.mse < 0.1 * power, "mse {} vs power {power}", rep.mse);
        assert!(rep.bits_per_entry <= 4.0 + 1e-9, "{}", rep.bits_per_entry);
    }

    #[test]
    fn within_budget_at_all_rates() {
        let h = dense(2048, 12);
        for rate in [1.0, 2.0, 4.0] {
            let rep = measure_distortion(&cheap().pipeline(), &h, rate, 3, 0);
            assert!(rep.bits_per_entry <= rate + 1e-9, "rate {rate}: {}", rep.bits_per_entry);
        }
    }

    #[test]
    fn encode_and_decode_are_deterministic() {
        // Fresh instances per encode: the UVeQFed terminal warm-starts
        // its scale search across rounds on one instance (same contract
        // as the registry-wide session-parity tests).
        let h = dense(700, 13);
        let ctx = CodecContext::new(4, 9, 77, 2.0);
        let e1 = cheap().pipeline().encode(&h, &ctx);
        let e2 = cheap().pipeline().encode(&h, &ctx);
        assert_eq!(e1, e2, "encode must be deterministic");
        let d1 = cheap().pipeline().decode(&e1, h.len(), &ctx);
        let d2 = cheap().pipeline().decode(&e1, h.len(), &ctx);
        assert_eq!(d1, d2, "decode must be deterministic");
    }

    #[test]
    fn exhausted_solver_budget_is_a_typed_error() {
        let spec = FedVqcs { ratio: 0.5, sparsity: 0.05, solver_iters: 8 };
        let h = dense(256, 14);
        let ctx = CodecContext::new(0, 0, 5, 2.0);
        let enc = spec.pipeline().encode(&h, &ctx);

        let tight = ctx.with_decode_budget(DecodeBudget::units(3));
        let err = spec.pipeline().try_decode(&enc, h.len(), &tight).unwrap_err();
        assert_eq!(err, DecodeError::Budget);

        let enough = ctx.with_decode_budget(DecodeBudget::units(8));
        assert!(spec.pipeline().try_decode(&enc, h.len(), &enough).is_ok());
    }

    #[test]
    fn zero_update_is_an_empty_message_and_decodes_for_free() {
        let h = vec![0.0f32; 300];
        let ctx = CodecContext::new(1, 1, 9, 2.0);
        let codec = cheap().pipeline();
        let enc = codec.encode(&h, &ctx);
        assert!(enc.bytes.is_empty(), "zero update must stay an empty message");
        // An empty sketch decodes to zeros without touching the solver —
        // zero budget suffices.
        let free = ctx.with_decode_budget(DecodeBudget::units(0));
        let dec = codec.try_decode(&enc, h.len(), &free).unwrap();
        assert!(dec.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn garbage_bytes_never_panic_the_solver_path() {
        use crate::quantizer::Encoded;
        use crate::prng::Rng;
        let ctx = CodecContext::new(2, 3, 4, 2.0);
        let codec = cheap().pipeline();
        let mut rng = Xoshiro256pp::seed_from_u64(0xBAD);
        for m in [1usize, 65, 256] {
            for _ in 0..8 {
                let n = rng.gen_index(64) + 1;
                let bytes: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
                let msg = Encoded { bits: bytes.len() * 8, bytes };
                // Ok or typed Err both fine; panics are not.
                let _ = codec.try_decode(&msg, m, &ctx);
            }
        }
    }

    #[test]
    fn block_projection_is_deterministic_and_nan_safe() {
        let mut x = vec![0.0f64; 130];
        x[3] = f64::NAN;
        x[70] = 5.0;
        x[128] = -2.0;
        block_top_k_project(&mut x, 0.05); // k = 1 per 64-block
        // NaN has the largest total_cmp magnitude → kept; the rest of its
        // block is zeroed. No panic, fully deterministic.
        assert!(x[3].is_nan());
        assert_eq!(x[70], 5.0);
        assert_eq!(x[128], -2.0);
        assert_eq!(x.iter().filter(|v| **v != 0.0).count(), 3);

        // Tie-break: equal magnitudes keep the smaller index.
        let mut t = vec![1.0f64; 64];
        block_top_k_project(&mut t, 0.02); // k = 2
        assert_eq!(t[0], 1.0);
        assert_eq!(t[1], 1.0);
        assert!(t[2..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn sketch_dims_are_sane() {
        assert_eq!(sketch_dim(0.25, 1000), 250);
        assert_eq!(sketch_dim(0.25, 1), 1);
        assert_eq!(sketch_dim(1.0, 7), 7);
        assert_eq!(sketch_dim(0.25, 0), 1);
        assert_eq!(block_k(0.05, 64), 4);
        assert_eq!(block_k(0.05, 3), 1);
        assert_eq!(block_k(1.0, 64), 64);
    }
}
