//! Fallible, parameterized codec registry.
//!
//! [`CodecSpec`] is the parsed form of a config/CLI codec string. The
//! grammar is
//!
//! ```text
//! spec   := base [':' param (',' param)*]
//! param  := key '=' value
//! ```
//!
//! where `base` is any canonical registry name or alias (`uveqfed-l2`,
//! `uveqfed`, `none`, …; see `quantizer::WIRE_CODECS`) and the accepted
//! keys depend on the codec:
//!
//! | base | keys |
//! |---|---|
//! | `uveqfed-l{1,2,4,8}` | `zeta=<f64 > 0>` (fixed ζ·√M spread), `subtractive=<bool>` |
//! | `qsgd` | `max_levels=<u32 ≥ 1>` |
//! | `topk` | `value_bits=<1..=16>` |
//! | `subsample` | `value_bits=<1..=16>` |
//! | `fedvqcs` | `ratio=<f64 in (0,1]>`, `sparsity=<f64 in (0,1]>`, `solver_iters=<u32 ≥ 1>` |
//! | others | *(no parameters)* |
//!
//! Examples: `uveqfed-l4`, `uveqfed-l2:zeta=3.0,subtractive=false`,
//! `qsgd:max_levels=4096`, `topk:value_bits=6`,
//! `fedvqcs:ratio=0.25,sparsity=0.05,solver_iters=50`.
//!
//! Every failure — unknown base, malformed `key=value`, unknown key, bad
//! value — is a [`crate::Result`] error naming the valid alternatives;
//! nothing in this module panics.

use super::uveqfed::ZetaMode;
use super::{
    codec_id, codec_name, registered_codec_names, FedVqcs, IdentityCodec, Qsgd,
    RotationUniform, SignSgd, SubsampleUniform, TernGrad, TopK, UVeQFed, UpdateCodec,
};

/// Lattice dimension of a UVeQFed configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LatticeDim {
    /// L = 1 scalar lattice.
    L1,
    /// L = 2 hexagonal lattice (the paper's configuration).
    L2,
    /// L = 4 checkerboard lattice D4.
    L4,
    /// L = 8 Gosset lattice E8.
    L8,
}

/// A parsed, validated codec configuration: the codec kind plus its
/// parameters. Replaces the old panicking string-only `by_name` lookup.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CodecSpec {
    /// The paper's subtractive dithered lattice quantizer.
    UVeQFed {
        dim: LatticeDim,
        /// `false` degrades to the non-subtractive ablation variant.
        subtractive: bool,
        /// Fixed `ζ = c/√M` spread; `None` = the paper's rate-adaptive ζ.
        zeta: Option<f64>,
    },
    /// QSGD probabilistic scalar quantization.
    Qsgd { max_levels: u32 },
    /// Uniform quantization under a random Hadamard rotation.
    Rotation,
    /// Random subsampling + uniform quantization.
    Subsample { value_bits: u32 },
    /// TernGrad-style ternary quantization.
    TernGrad,
    /// One sign bit per coordinate with ℓ1 magnitude.
    SignSgd,
    /// Top-k sparsification.
    TopK { value_bits: u32 },
    /// FedVQCS compressed sensing: block top-k → Gaussian sketch →
    /// UVeQFed lattice VQ, decoded by a budgeted IHT solver.
    FedVqcs { ratio: f64, sparsity: f64, solver_iters: u32 },
    /// Unquantized passthrough.
    Identity,
}

impl CodecSpec {
    /// Parse a codec spec string. See the module docs for the grammar.
    pub fn parse(spec: &str) -> crate::Result<Self> {
        let (base, params) = match spec.split_once(':') {
            Some((b, p)) => (b.trim(), Some(p)),
            None => (spec.trim(), None),
        };
        let canonical = codec_id(base).and_then(codec_name).ok_or_else(|| {
            let names: Vec<&str> = registered_codec_names().collect();
            crate::format_err!("unknown codec '{base}' (valid: {})", names.join(", "))
        })?;
        let mut out = Self::default_for(canonical).ok_or_else(|| {
            crate::format_err!("codec '{canonical}' has no spec mapping (registry bug)")
        })?;
        if let Some(params) = params {
            for kv in params.split(',') {
                let kv = kv.trim();
                if kv.is_empty() {
                    continue;
                }
                let (key, val) = kv.split_once('=').ok_or_else(|| {
                    crate::format_err!("codec param '{kv}' is not key=value (in spec '{spec}')")
                })?;
                out.apply(key.trim(), val.trim())?;
            }
        }
        Ok(out)
    }

    /// Default parameters for a canonical registry name.
    fn default_for(canonical: &str) -> Option<Self> {
        let uveq = |dim| CodecSpec::UVeQFed { dim, subtractive: true, zeta: None };
        Some(match canonical {
            "uveqfed-l1" => uveq(LatticeDim::L1),
            "uveqfed-l2" => uveq(LatticeDim::L2),
            "uveqfed-l4" => uveq(LatticeDim::L4),
            "uveqfed-l8" => uveq(LatticeDim::L8),
            "qsgd" => CodecSpec::Qsgd { max_levels: Qsgd::default().max_levels },
            "rotation" => CodecSpec::Rotation,
            "subsample" => {
                CodecSpec::Subsample { value_bits: SubsampleUniform::default().value_bits }
            }
            "terngrad" => CodecSpec::TernGrad,
            "signsgd" => CodecSpec::SignSgd,
            "topk" => CodecSpec::TopK { value_bits: TopK::default().value_bits },
            "fedvqcs" => {
                let d = FedVqcs::default();
                CodecSpec::FedVqcs {
                    ratio: d.ratio,
                    sparsity: d.sparsity,
                    solver_iters: d.solver_iters,
                }
            }
            "identity" => CodecSpec::Identity,
            _ => return None,
        })
    }

    /// Apply one `key=value` parameter.
    fn apply(&mut self, key: &str, val: &str) -> crate::Result<()> {
        let cname = self.canonical_name();
        fn bits(key: &str, val: &str) -> crate::Result<u32> {
            let b: u32 = val
                .parse()
                .map_err(|e| crate::format_err!("codec param '{key}={val}': {e}"))?;
            crate::ensure!((1..=16).contains(&b), "codec param '{key}' must be in 1..=16");
            Ok(b)
        }
        match self {
            CodecSpec::UVeQFed { subtractive, zeta, .. } => match key {
                "zeta" => {
                    let z: f64 = val
                        .parse()
                        .map_err(|e| crate::format_err!("codec param 'zeta={val}': {e}"))?;
                    crate::ensure!(z.is_finite() && z > 0.0, "codec param 'zeta' must be > 0");
                    *zeta = Some(z);
                }
                "subtractive" => {
                    *subtractive = val.parse().map_err(|e| {
                        crate::format_err!("codec param 'subtractive={val}': {e}")
                    })?;
                }
                other => crate::bail!(
                    "codec 'uveqfed' has no parameter '{other}' (valid: zeta, subtractive)"
                ),
            },
            CodecSpec::Qsgd { max_levels } => match key {
                "max_levels" => {
                    let lv: u32 = val.parse().map_err(|e| {
                        crate::format_err!("codec param 'max_levels={val}': {e}")
                    })?;
                    crate::ensure!(lv >= 1, "codec param 'max_levels' must be ≥ 1");
                    *max_levels = lv;
                }
                other => {
                    crate::bail!("codec 'qsgd' has no parameter '{other}' (valid: max_levels)")
                }
            },
            CodecSpec::Subsample { value_bits } => match key {
                "value_bits" => *value_bits = bits(key, val)?,
                other => crate::bail!(
                    "codec 'subsample' has no parameter '{other}' (valid: value_bits)"
                ),
            },
            CodecSpec::TopK { value_bits } => match key {
                "value_bits" => *value_bits = bits(key, val)?,
                other => {
                    crate::bail!("codec 'topk' has no parameter '{other}' (valid: value_bits)")
                }
            },
            CodecSpec::FedVqcs { ratio, sparsity, solver_iters } => {
                fn frac(key: &str, val: &str) -> crate::Result<f64> {
                    let f: f64 = val
                        .parse()
                        .map_err(|e| crate::format_err!("codec param '{key}={val}': {e}"))?;
                    crate::ensure!(
                        f.is_finite() && f > 0.0 && f <= 1.0,
                        "codec param '{key}' must be in (0, 1]"
                    );
                    Ok(f)
                }
                match key {
                    "ratio" => *ratio = frac(key, val)?,
                    "sparsity" => *sparsity = frac(key, val)?,
                    "solver_iters" => {
                        let it: u32 = val.parse().map_err(|e| {
                            crate::format_err!("codec param 'solver_iters={val}': {e}")
                        })?;
                        crate::ensure!(it >= 1, "codec param 'solver_iters' must be ≥ 1");
                        *solver_iters = it;
                    }
                    other => crate::bail!(
                        "codec 'fedvqcs' has no parameter '{other}' \
                         (valid: ratio, sparsity, solver_iters)"
                    ),
                }
            }
            CodecSpec::Rotation
            | CodecSpec::TernGrad
            | CodecSpec::SignSgd
            | CodecSpec::Identity => {
                crate::bail!("codec '{cname}' takes no parameters")
            }
        }
        Ok(())
    }

    /// The canonical registry name (wire-id key) of this spec.
    pub fn canonical_name(&self) -> &'static str {
        match *self {
            CodecSpec::UVeQFed { dim, .. } => match dim {
                LatticeDim::L1 => "uveqfed-l1",
                LatticeDim::L2 => "uveqfed-l2",
                LatticeDim::L4 => "uveqfed-l4",
                LatticeDim::L8 => "uveqfed-l8",
            },
            CodecSpec::Qsgd { .. } => "qsgd",
            CodecSpec::Rotation => "rotation",
            CodecSpec::Subsample { .. } => "subsample",
            CodecSpec::TernGrad => "terngrad",
            CodecSpec::SignSgd => "signsgd",
            CodecSpec::TopK { .. } => "topk",
            CodecSpec::FedVqcs { .. } => "fedvqcs",
            CodecSpec::Identity => "identity",
        }
    }

    /// Construct the codec. Infallible: every invariant was checked at
    /// parse time (or by the typed constructor of the spec).
    pub fn build(&self) -> Box<dyn UpdateCodec> {
        match *self {
            CodecSpec::UVeQFed { dim, subtractive, zeta } => {
                let mut c = match dim {
                    LatticeDim::L1 => UVeQFed::scalar(),
                    LatticeDim::L2 => UVeQFed::hexagonal(),
                    LatticeDim::L4 => UVeQFed::d4(),
                    LatticeDim::L8 => UVeQFed::e8(),
                };
                if let Some(z) = zeta {
                    c = c.with_zeta(ZetaMode::FixedOverSqrtM(z));
                }
                if !subtractive {
                    c = c.non_subtractive();
                }
                Box::new(c)
            }
            CodecSpec::Qsgd { max_levels } => Box::new(Qsgd { max_levels }),
            // Rotation builds as its pipeline port — bit-identical to the
            // legacy implementation (proved by the oracle-parity tests in
            // `quantizer::rotation`).
            CodecSpec::Rotation => Box::new(RotationUniform::pipeline()),
            CodecSpec::Subsample { value_bits } => Box::new(SubsampleUniform { value_bits }),
            CodecSpec::TernGrad => Box::new(TernGrad),
            CodecSpec::SignSgd => Box::new(SignSgd),
            CodecSpec::TopK { value_bits } => Box::new(TopK { value_bits }),
            CodecSpec::FedVqcs { ratio, sparsity, solver_iters } => {
                Box::new(FedVqcs { ratio, sparsity, solver_iters }.pipeline())
            }
            CodecSpec::Identity => Box::new(IdentityCodec),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_registered_name() {
        for name in registered_codec_names() {
            let spec = CodecSpec::parse(name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(spec.canonical_name(), name);
            assert!(!spec.build().name().is_empty());
        }
    }

    #[test]
    fn aliases_resolve() {
        assert_eq!(CodecSpec::parse("uveqfed").unwrap().canonical_name(), "uveqfed-l2");
        assert_eq!(CodecSpec::parse("none").unwrap().canonical_name(), "identity");
        assert_eq!(CodecSpec::parse("uveqfed-d4").unwrap().canonical_name(), "uveqfed-l4");
    }

    #[test]
    fn unknown_base_lists_valid_names() {
        let err = CodecSpec::parse("nope").unwrap_err().to_string();
        assert!(err.contains("unknown codec 'nope'"), "{err}");
        assert!(err.contains("uveqfed-l2"), "{err}");
        assert!(err.contains("identity"), "{err}");
    }

    #[test]
    fn params_parse_and_apply() {
        assert_eq!(
            CodecSpec::parse("qsgd:max_levels=64").unwrap(),
            CodecSpec::Qsgd { max_levels: 64 }
        );
        assert_eq!(
            CodecSpec::parse("topk:value_bits=6").unwrap(),
            CodecSpec::TopK { value_bits: 6 }
        );
        assert_eq!(
            CodecSpec::parse("uveqfed-l2:zeta=3.0,subtractive=false").unwrap(),
            CodecSpec::UVeQFed {
                dim: LatticeDim::L2,
                subtractive: false,
                zeta: Some(3.0)
            }
        );
    }

    #[test]
    fn bad_params_are_errors_not_panics() {
        for bad in [
            "qsgd:levels=4",          // unknown key
            "qsgd:max_levels=zero",   // bad value
            "qsgd:max_levels",        // not key=value
            "identity:x=1",           // parameterless codec
            "topk:value_bits=0",      // out of range
            "topk:value_bits=17",     // out of range
            "uveqfed-l2:zeta=-1",     // non-positive
        ] {
            assert!(CodecSpec::parse(bad).is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn fedvqcs_params_parse_and_apply() {
        assert_eq!(
            CodecSpec::parse("fedvqcs:ratio=0.25,sparsity=0.05,solver_iters=50").unwrap(),
            CodecSpec::FedVqcs { ratio: 0.25, sparsity: 0.05, solver_iters: 50 }
        );
        assert_eq!(CodecSpec::parse("fedvqcs").unwrap().canonical_name(), "fedvqcs");
        assert_eq!(
            CodecSpec::parse("fedvqcs:ratio=0.5").unwrap(),
            CodecSpec::FedVqcs { ratio: 0.5, sparsity: 0.05, solver_iters: 50 }
        );
    }

    #[test]
    fn fedvqcs_bad_params_are_descriptive_errors() {
        // Out-of-range / malformed values name the offending key.
        for (bad, needle) in [
            ("fedvqcs:ratio=0", "'ratio' must be in (0, 1]"),
            ("fedvqcs:ratio=1.5", "'ratio' must be in (0, 1]"),
            ("fedvqcs:ratio=nan", "'ratio' must be in (0, 1]"),
            ("fedvqcs:sparsity=-0.1", "'sparsity' must be in (0, 1]"),
            ("fedvqcs:sparsity=inf", "'sparsity' must be in (0, 1]"),
            ("fedvqcs:solver_iters=0", "'solver_iters' must be ≥ 1"),
            ("fedvqcs:solver_iters=many", "solver_iters=many"),
            ("fedvqcs:iters=5", "no parameter 'iters'"),
        ] {
            let err = CodecSpec::parse(bad).unwrap_err().to_string();
            assert!(err.contains(needle), "{bad}: {err}");
        }
        // Unknown-key errors list the valid keys.
        let err = CodecSpec::parse("fedvqcs:bogus=1").unwrap_err().to_string();
        assert!(err.contains("valid: ratio, sparsity, solver_iters"), "{err}");
        // Unknown-base errors still list every valid codec name.
        let err = CodecSpec::parse("fedvqc").unwrap_err().to_string();
        for name in registered_codec_names() {
            assert!(err.contains(name), "error should list '{name}': {err}");
        }
    }

    #[test]
    fn built_params_take_effect() {
        let spec = CodecSpec::parse("uveqfed-l2:subtractive=false").unwrap();
        assert_eq!(spec.build().name(), "uveqfed-hex-paper-nosub");
    }
}
