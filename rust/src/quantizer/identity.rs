//! Unquantized passthrough — the "federated averaging without quantization
//! constraints" reference curve in Figs. 6–11.

use super::{CodecContext, Encoded, UpdateCodec};
use crate::entropy::{BitReader, BitWriter};

#[derive(Debug, Clone, Copy, Default)]
pub struct IdentityCodec;

impl UpdateCodec for IdentityCodec {
    fn name(&self) -> String {
        "identity".into()
    }

    fn encode(&self, h: &[f32], _ctx: &CodecContext) -> Encoded {
        let mut w = BitWriter::with_capacity(h.len() * 4);
        for &v in h {
            w.push_f32(v);
        }
        let bits = w.bit_len();
        Encoded { bytes: w.into_bytes(), bits }
    }

    fn decode(&self, msg: &Encoded, m: usize, _ctx: &CodecContext) -> Vec<f32> {
        let mut r = BitReader::new(&msg.bytes);
        (0..m).map(|_| r.read_f32()).collect()
    }

    fn rate_constrained(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lossless_roundtrip() {
        let h = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE, 1e30];
        let ctx = CodecContext::new(0, 0, 1, 2.0);
        let enc = IdentityCodec.encode(&h, &ctx);
        assert_eq!(enc.bits, h.len() * 32);
        assert_eq!(IdentityCodec.decode(&enc, h.len(), &ctx), h);
    }
}
