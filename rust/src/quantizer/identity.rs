//! Unquantized passthrough — the "federated averaging without quantization
//! constraints" reference curve in Figs. 6–11.
//!
//! Both sessions are genuinely single-pass: the encode sink serializes
//! each pushed chunk straight into the output bit stream (no input
//! buffering at all), and the decode stream reads f32s chunk by chunk.

use super::{CodecContext, DecodeStream, Encoded, EncodeSink, EntryStream, UpdateCodec};
use crate::entropy::{BitReader, BitWriter};

#[derive(Debug, Clone, Copy, Default)]
pub struct IdentityCodec;

struct IdentitySink {
    w: BitWriter,
    pushed: usize,
    expected: usize,
}

impl EncodeSink for IdentitySink {
    fn push(&mut self, chunk: &[f32]) {
        for &v in chunk {
            self.w.push_f32(v);
        }
        self.pushed += chunk.len();
    }

    fn finish(self: Box<Self>) -> Encoded {
        assert_eq!(self.pushed, self.expected, "identity sink fed wrong length");
        let bits = self.w.bit_len();
        Encoded { bytes: self.w.into_bytes(), bits }
    }
}

impl UpdateCodec for IdentityCodec {
    fn name(&self) -> String {
        "identity".into()
    }

    fn encoder(&self, _ctx: &CodecContext, m: usize) -> Box<dyn EncodeSink + '_> {
        Box::new(IdentitySink {
            w: BitWriter::with_capacity(m * 4),
            pushed: 0,
            expected: m,
        })
    }

    fn decoder<'a>(
        &'a self,
        msg: &'a Encoded,
        m: usize,
        _ctx: &CodecContext,
    ) -> Box<dyn DecodeStream + 'a> {
        let mut r = BitReader::new(&msg.bytes);
        Box::new(EntryStream::new(m, move || Ok(r.read_f32())))
    }

    /// Skip the session scratch buffer for the whole-buffer entry point.
    fn decode(&self, msg: &Encoded, m: usize, _ctx: &CodecContext) -> Vec<f32> {
        let mut r = BitReader::new(&msg.bytes);
        (0..m).map(|_| r.read_f32()).collect()
    }

    fn rate_constrained(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lossless_roundtrip() {
        let h = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE, 1e30];
        let ctx = CodecContext::new(0, 0, 1, 2.0);
        let enc = IdentityCodec.encode(&h, &ctx);
        assert_eq!(enc.bits, h.len() * 32);
        assert_eq!(IdentityCodec.decode(&enc, h.len(), &ctx), h);
    }

    #[test]
    fn chunked_push_is_bit_identical() {
        let h: Vec<f32> = (0..37).map(|i| i as f32 * 0.5 - 9.0).collect();
        let ctx = CodecContext::new(0, 0, 1, 2.0);
        let whole = IdentityCodec.encode(&h, &ctx);
        let mut sink = IdentityCodec.encoder(&ctx, h.len());
        for c in h.chunks(5) {
            sink.push(c);
        }
        assert_eq!(sink.finish(), whole);
    }

    #[test]
    fn streaming_sink_holds_no_input_state() {
        let ctx = CodecContext::new(0, 0, 1, 2.0);
        let mut sink = IdentityCodec.encoder(&ctx, 8);
        sink.push(&[1.0; 8]);
        assert_eq!(sink.state_bytes(), 0, "identity buffers nothing beyond the output");
    }
}
