//! Top-k sparsification [13]–[15] (extension baseline): keep the k
//! largest-magnitude coordinates; each travels as (index, 8-bit uniform
//! value); k is set to exactly fill the bit budget.
//!
//! Sessions are buffered on both sides: the encoder needs a global sort
//! by magnitude, and the decoder scatter-writes into arbitrary positions,
//! so neither can operate on an in-order chunk stream.
//!
//! **Pipeline-v3 stage mapping**: top-k is `sparsify → uniform-quantize`
//! with the support indices coded in-band, i.e. a sparsification
//! [`TransformStage`](super::pipeline::TransformStage) fused into its
//! terminal coder (the index list *is* part of the wire format, so the
//! stage boundary cannot be cut without changing bytes). The uniform
//! value quantization is the shared
//! [`pipeline::quantize_uniform`](super::pipeline::quantize_uniform)
//! arithmetic, so the wire format stays bit-identical to the
//! pre-pipeline implementation.

use super::pipeline::{dequantize_uniform, quantize_uniform};
use super::{
    BufferedSink, CodecContext, DecodeStream, Encoded, EncodeSink, SliceStream, UpdateCodec,
};
use crate::entropy::{BitReader, BitWriter};

#[derive(Debug, Clone, Copy)]
pub struct TopK {
    pub value_bits: u32,
}

impl Default for TopK {
    fn default() -> Self {
        Self { value_bits: 8 }
    }
}

fn index_bits(m: usize) -> u32 {
    (usize::BITS - (m.max(2) - 1).leading_zeros()).max(1)
}

impl TopK {
    /// Whole-buffer encoder (runs at `EncodeSink::finish`).
    fn encode_whole(&self, h: &[f32], ctx: &CodecContext) -> Encoded {
        let m = h.len();
        let budget = ctx.budget_bits(m);
        let ib = index_bits(m);
        let per = (ib + self.value_bits) as usize;
        let header = 64 + 32;
        let k = if budget > header { ((budget - header) / per).min(m) } else { 0 };

        if k == 0 {
            // Budget below the header: empty zero message (reading the
            // empty buffer yields k = 0 → an all-zero reconstruction).
            return Encoded { bytes: Vec::new(), bits: 0 };
        }
        let mut w = BitWriter::with_capacity(budget / 8 + 16);
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&a, &b| h[b].abs().partial_cmp(&h[a].abs()).unwrap());
        let kept = &order[..k];
        let lo = kept.iter().map(|&i| h[i] as f64).fold(f64::INFINITY, f64::min);
        let hi = kept.iter().map(|&i| h[i] as f64).fold(f64::NEG_INFINITY, f64::max);
        w.push_f32(if k > 0 { lo as f32 } else { 0.0 });
        w.push_f32(if k > 0 { hi as f32 } else { 0.0 });
        w.push_u32(k as u32);
        for &i in kept {
            w.push_bits(i as u64, ib);
            w.push_bits(quantize_uniform(h[i] as f64, lo, hi, self.value_bits), self.value_bits);
        }
        let bits = w.bit_len();
        debug_assert!(bits <= budget || k == 0);
        Encoded { bytes: w.into_bytes(), bits }
    }

    /// Whole-buffer decoder (scatter reconstruction).
    fn decode_whole(&self, msg: &Encoded, m: usize) -> Vec<f32> {
        let ib = index_bits(m);
        let mut r = BitReader::new(&msg.bytes);
        let lo = r.read_f32() as f64;
        let hi = r.read_f32() as f64;
        let k = r.read_u32() as usize;
        let mut out = vec![0.0f32; m];
        for _ in 0..k {
            let i = r.read_bits(ib) as usize;
            let q = r.read_bits(self.value_bits);
            if i < m {
                out[i] = dequantize_uniform(q, lo, hi, self.value_bits) as f32;
            }
        }
        out
    }
}

impl UpdateCodec for TopK {
    fn name(&self) -> String {
        "topk".into()
    }

    fn encoder(&self, ctx: &CodecContext, m: usize) -> Box<dyn EncodeSink + '_> {
        let ctx = *ctx;
        Box::new(BufferedSink::new(m, move |h: &[f32]| self.encode_whole(h, &ctx)))
    }

    fn decoder<'a>(
        &'a self,
        msg: &'a Encoded,
        m: usize,
        _ctx: &CodecContext,
    ) -> Box<dyn DecodeStream + 'a> {
        Box::new(SliceStream::new(self.decode_whole(msg, m)))
    }

    /// Skip the session buffers for the whole-buffer entry points.
    fn encode(&self, h: &[f32], ctx: &CodecContext) -> Encoded {
        self.encode_whole(h, ctx)
    }

    fn decode(&self, msg: &Encoded, m: usize, _ctx: &CodecContext) -> Vec<f32> {
        self.decode_whole(msg, m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Normal, Rng, Xoshiro256pp};
    use crate::quantizer::measure_distortion;

    fn gaussian(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        Normal::new(0.0, 1.0).vec_f32(&mut rng, n)
    }

    #[test]
    fn keeps_largest_entries() {
        let mut h = vec![0.01f32; 256];
        h[7] = 5.0;
        h[100] = -4.0;
        let ctx = CodecContext::new(0, 0, 1, 1.0);
        let enc = TopK::default().encode(&h, &ctx);
        let dec = TopK::default().decode(&enc, h.len(), &ctx);
        assert!(dec[7] > 4.0, "{}", dec[7]);
        assert!(dec[100] < -3.0, "{}", dec[100]);
    }

    #[test]
    fn within_budget() {
        let h = gaussian(4096, 121);
        for rate in [1.0, 2.0, 4.0] {
            let rep = measure_distortion(&TopK::default(), &h, rate, 3, 0);
            assert!(rep.bits_per_entry <= rate + 1e-9);
        }
    }

    #[test]
    fn recovers_sparse_support_exactly() {
        // On a truly sparse signal, top-k must recover the full support
        // and capture (almost) all the signal energy at R = 1.
        let mut rng = Xoshiro256pp::seed_from_u64(122);
        let h: Vec<f32> = (0..4096)
            .map(|i| if i % 512 == 0 { 10.0 + rng.normal_f32() } else { 0.0 })
            .collect();
        let ctx = CodecContext::new(0, 0, 3, 1.0);
        let enc = TopK::default().encode(&h, &ctx);
        let dec = TopK::default().decode(&enc, h.len(), &ctx);
        for (i, (&a, &b)) in h.iter().zip(&dec).enumerate() {
            if a != 0.0 {
                assert!((a - b).abs() < 0.1, "support entry {i}: {a} vs {b}");
            }
        }
        let mse = crate::util::stats::mse(&h, &dec);
        let power: f64 =
            h.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / h.len() as f64;
        assert!(mse < power * 1e-3, "mse {mse} vs power {power}");
    }
}
