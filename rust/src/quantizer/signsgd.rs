//! signSGD with norm scaling [21] (extension baseline): one sign bit per
//! coordinate, reconstructed as `sign(h_i) · ‖h‖₁/m` (the ℓ1-scaled
//! variant, which is the unbiased-magnitude flavor used in FL studies).
//!
//! The encode session is single-pass with O(m/8) state: each pushed chunk
//! contributes to the running ℓ1 sum and appends sign bits to a
//! side-buffer; `finish` stitches header + signs (the header value — the
//! mean magnitude — is only known once the whole update has streamed
//! past). The decode session is single-pass.

use super::{CodecContext, DecodeStream, Encoded, EncodeSink, EntryStream, UpdateCodec};
use crate::entropy::{BitReader, BitWriter};

#[derive(Debug, Clone, Copy, Default)]
pub struct SignSgd;

struct SignSink {
    l1: f64,
    pushed: usize,
    expected: usize,
    signs: BitWriter,
}

impl EncodeSink for SignSink {
    fn push(&mut self, chunk: &[f32]) {
        for &v in chunk {
            self.l1 += v.abs() as f64;
            self.signs.push_bit(v < 0.0);
        }
        self.pushed += chunk.len();
    }

    fn state_bytes(&self) -> usize {
        self.signs.bytes().len()
    }

    fn finish(self: Box<Self>) -> Encoded {
        assert_eq!(self.pushed, self.expected, "signsgd sink fed wrong length");
        let mut w = BitWriter::with_capacity(self.expected / 8 + 8);
        w.push_f32((self.l1 / self.expected.max(1) as f64) as f32);
        w.append(&self.signs);
        let bits = w.bit_len();
        Encoded { bytes: w.into_bytes(), bits }
    }
}

impl UpdateCodec for SignSgd {
    fn name(&self) -> String {
        "signsgd".into()
    }

    fn encoder(&self, _ctx: &CodecContext, m: usize) -> Box<dyn EncodeSink + '_> {
        Box::new(SignSink {
            l1: 0.0,
            pushed: 0,
            expected: m,
            signs: BitWriter::with_capacity(m / 8 + 1),
        })
    }

    fn decoder<'a>(
        &'a self,
        msg: &'a Encoded,
        m: usize,
        _ctx: &CodecContext,
    ) -> Box<dyn DecodeStream + 'a> {
        let mut r = BitReader::new(&msg.bytes);
        let mag = r.read_f32();
        Box::new(EntryStream::new(m, move || Ok(if r.read_bit() { -mag } else { mag })))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Normal, Xoshiro256pp};

    #[test]
    fn roundtrip_signs_and_magnitude() {
        let mut rng = Xoshiro256pp::seed_from_u64(111);
        let h = Normal::new(0.0, 2.0).vec_f32(&mut rng, 1024);
        let ctx = CodecContext::new(0, 0, 1, 2.0);
        let enc = SignSgd.encode(&h, &ctx);
        assert_eq!(enc.bits, 32 + 1024);
        let dec = SignSgd.decode(&enc, h.len(), &ctx);
        for (&a, &b) in h.iter().zip(&dec) {
            assert_eq!(a < 0.0, b < 0.0);
        }
        let mag = dec[0].abs();
        let l1_mean: f32 = h.iter().map(|v| v.abs()).sum::<f32>() / 1024.0;
        assert!((mag - l1_mean).abs() < 1e-3);
    }

    #[test]
    fn preserves_descent_direction() {
        let mut rng = Xoshiro256pp::seed_from_u64(112);
        let h = Normal::new(0.0, 1.0).vec_f32(&mut rng, 4096);
        let ctx = CodecContext::new(0, 0, 1, 2.0);
        let enc = SignSgd.encode(&h, &ctx);
        let dec = SignSgd.decode(&enc, h.len(), &ctx);
        let dot: f64 = h.iter().zip(&dec).map(|(&a, &b)| (a * b) as f64).sum();
        assert!(dot > 0.0);
    }

    #[test]
    fn chunked_push_is_bit_identical_and_o_m_over_8() {
        let mut rng = Xoshiro256pp::seed_from_u64(113);
        let h = Normal::new(0.0, 1.0).vec_f32(&mut rng, 1000);
        let ctx = CodecContext::new(0, 0, 1, 2.0);
        let whole = SignSgd.encode(&h, &ctx);
        let mut sink = SignSgd.encoder(&ctx, h.len());
        for c in h.chunks(13) {
            sink.push(c);
        }
        // Side-buffer state is bits, not samples: ~m/8 bytes.
        assert!(sink.state_bytes() <= 1000 / 8 + 1, "state {}", sink.state_bytes());
        assert_eq!(sink.finish(), whole);
    }
}
