//! signSGD with norm scaling [21] (extension baseline): one sign bit per
//! coordinate, reconstructed as `sign(h_i) · ‖h‖₁/m` (the ℓ1-scaled
//! variant, which is the unbiased-magnitude flavor used in FL studies).

use super::{CodecContext, Encoded, UpdateCodec};
use crate::entropy::{BitReader, BitWriter};

#[derive(Debug, Clone, Copy, Default)]
pub struct SignSgd;

impl UpdateCodec for SignSgd {
    fn name(&self) -> String {
        "signsgd".into()
    }

    fn encode(&self, h: &[f32], _ctx: &CodecContext) -> Encoded {
        let l1: f64 = h.iter().map(|&v| v.abs() as f64).sum();
        let mut w = BitWriter::with_capacity(h.len() / 8 + 8);
        w.push_f32((l1 / h.len().max(1) as f64) as f32);
        for &v in h {
            w.push_bit(v < 0.0);
        }
        let bits = w.bit_len();
        Encoded { bytes: w.into_bytes(), bits }
    }

    fn decode(&self, msg: &Encoded, m: usize, _ctx: &CodecContext) -> Vec<f32> {
        let mut r = BitReader::new(&msg.bytes);
        let mag = r.read_f32();
        (0..m).map(|_| if r.read_bit() { -mag } else { mag }).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Normal, Xoshiro256pp};

    #[test]
    fn roundtrip_signs_and_magnitude() {
        let mut rng = Xoshiro256pp::seed_from_u64(111);
        let h = Normal::new(0.0, 2.0).vec_f32(&mut rng, 1024);
        let ctx = CodecContext::new(0, 0, 1, 2.0);
        let enc = SignSgd.encode(&h, &ctx);
        assert_eq!(enc.bits, 32 + 1024);
        let dec = SignSgd.decode(&enc, h.len(), &ctx);
        for (&a, &b) in h.iter().zip(&dec) {
            assert_eq!(a < 0.0, b < 0.0);
        }
        let mag = dec[0].abs();
        let l1_mean: f32 = h.iter().map(|v| v.abs()).sum::<f32>() / 1024.0;
        assert!((mag - l1_mean).abs() < 1e-3);
    }

    #[test]
    fn preserves_descent_direction() {
        let mut rng = Xoshiro256pp::seed_from_u64(112);
        let h = Normal::new(0.0, 1.0).vec_f32(&mut rng, 4096);
        let ctx = CodecContext::new(0, 0, 1, 2.0);
        let enc = SignSgd.encode(&h, &ctx);
        let dec = SignSgd.decode(&enc, h.len(), &ctx);
        let dot: f64 = h.iter().zip(&dec).map(|(&a, &b)| (a * b) as f64).sum();
        assert!(dot > 0.0);
    }
}
