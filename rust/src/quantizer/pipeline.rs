//! Staged codec pipelines — **Codec API v3**.
//!
//! A pipeline codec is a chain of [`TransformStage`]s in front of one
//! [`TerminalCoder`], assembled by [`PipelineCodec`] behind the unchanged
//! [`UpdateCodec`] session surface:
//!
//! ```text
//! encode:  x ──stage₀.forward──▶ … ──stageₙ.forward──▶ y ──coder.encode──▶ bits
//! decode:  bits ──coder.decode──▶ ŷ ──stageₙ.inverse──▶ … ──stage₀.inverse──▶ x̂
//! ```
//!
//! The internal stage domain is `f64`: the legacy codecs (rotation
//! foremost) do all intermediate math in doubles with a single final
//! `f32` cast, so an `f32` stage boundary would break the bit-parity
//! guarantee the pipeline ports must uphold. The `f32` casts happen
//! exactly once on each side — when the encode sink seals its buffered
//! input, and when the decode session materializes its output.
//!
//! ## Cross-chunk decode state and budgets
//!
//! Unlike v2 streams, a pipeline decode session legally **buffers**: the
//! whole reconstruction (terminal decode + inverse stages, including any
//! iterative solver) runs once, inside the first `next_chunk` call, and
//! the finished output is then served in [`DEFAULT_CHUNK`]-entry slices
//! with zero steady-state allocation. Expensive inverse work draws on the
//! context's [`DecodeBudget`]; exhaustion surfaces as the typed
//! [`DecodeError::Budget`] from `next_chunk`, never as a panic or a
//! partial output.

use std::time::Instant;

use super::session::DEFAULT_CHUNK;
use super::{
    CodecContext, DecodeBudget, DecodeError, DecodeStream, Encoded, EncodeSink, UpdateCodec,
};
use crate::telemetry::probe;

/// One composable transform in a pipeline codec. `forward` must be a pure
/// function of `(x, ctx)` and `inverse` of `(y, m_in, ctx)` — common
/// randomness comes from `ctx.crand`, never from ambient state — so a
/// pipeline codec inherits the registry-wide bit-identity guarantee
/// across worker/shard topologies.
pub trait TransformStage: Send + Sync {
    /// Stage name for diagnostics.
    fn name(&self) -> &'static str;

    /// Output length of [`Self::forward`] for an `m_in`-entry input.
    /// `inverse` receives the same `m_in` so it can undo padding or
    /// projection without in-band length headers.
    fn out_len(&self, m_in: usize, ctx: &CodecContext) -> usize;

    /// Encode-side transform.
    fn forward(&self, x: Vec<f64>, ctx: &CodecContext) -> Vec<f64>;

    /// Decode-side inverse. Expensive reconstruction (solver iterations,
    /// transform passes) must charge `budget`; an `Err` poisons the
    /// session.
    fn inverse(
        &self,
        y: Vec<f64>,
        m_in: usize,
        ctx: &CodecContext,
        budget: &mut DecodeBudget,
    ) -> Result<Vec<f64>, DecodeError>;
}

/// The quantize-and-entropy-code tail of a pipeline: turns the last
/// stage's output into wire bits and back. `budget_bits` is the exact
/// whole-message bit budget (headers included) — the pipeline computes it
/// once from the *original* input length so stage-induced length changes
/// never shift the rate accounting.
pub trait TerminalCoder: Send + Sync {
    /// Coder name for diagnostics.
    fn name(&self) -> &'static str;

    /// Code `y` into at most `budget_bits` bits.
    fn encode(&self, y: &[f64], budget_bits: usize, ctx: &CodecContext) -> Encoded;

    /// Reconstruct the `y_len`-entry stage output from `msg`. Must never
    /// panic on untrusted bytes.
    fn decode(
        &self,
        msg: &Encoded,
        y_len: usize,
        budget_bits: usize,
        ctx: &CodecContext,
    ) -> Result<Vec<f64>, DecodeError>;
}

/// Adapter running any whole-buffer [`UpdateCodec`] as a pipeline
/// terminal. The inner codec sees a context whose `budget_bits` returns
/// the pipeline's exact budget (via [`CodecContext::with_exact_budget`]),
/// so no rate·m float round trip can lose a bit; the `f64`↔`f32` casts at
/// the boundary are the adapter's price and acceptable for new codecs
/// that define their own math (fedvqcs).
pub struct CodecTerminal<C> {
    inner: C,
}

impl<C: UpdateCodec> CodecTerminal<C> {
    pub fn new(inner: C) -> Self {
        Self { inner }
    }
}

impl<C: UpdateCodec> TerminalCoder for CodecTerminal<C> {
    fn name(&self) -> &'static str {
        "codec-terminal"
    }

    fn encode(&self, y: &[f64], budget_bits: usize, ctx: &CodecContext) -> Encoded {
        let y32: Vec<f32> = y.iter().map(|&v| v as f32).collect();
        let ictx = ctx.with_exact_budget(budget_bits);
        self.inner.encode(&y32, &ictx)
    }

    fn decode(
        &self,
        msg: &Encoded,
        y_len: usize,
        budget_bits: usize,
        ctx: &CodecContext,
    ) -> Result<Vec<f64>, DecodeError> {
        let ictx = ctx.with_exact_budget(budget_bits);
        let out = self.inner.try_decode(msg, y_len, &ictx)?;
        Ok(out.iter().map(|&v| v as f64).collect())
    }
}

/// A staged codec: transform stages + terminal coder behind the
/// [`UpdateCodec`] session surface.
pub struct PipelineCodec {
    name: &'static str,
    stages: Vec<Box<dyn TransformStage>>,
    coder: Box<dyn TerminalCoder>,
}

impl PipelineCodec {
    pub fn new(
        name: &'static str,
        stages: Vec<Box<dyn TransformStage>>,
        coder: Box<dyn TerminalCoder>,
    ) -> Self {
        Self { name, stages, coder }
    }

    /// Stage names, front to back (diagnostics / tests).
    pub fn stage_names(&self) -> Vec<&'static str> {
        self.stages.iter().map(|s| s.name()).collect()
    }

    /// The per-stage input lengths `m = len₀ → len₁ → … → y_len` for an
    /// `m`-entry update: `lens[i]` is the input length of stage `i`, and
    /// the final element is the terminal coder's input length.
    fn stage_lens(&self, m: usize, ctx: &CodecContext) -> Vec<usize> {
        let mut lens = Vec::with_capacity(self.stages.len() + 1);
        let mut len = m;
        lens.push(len);
        for stage in &self.stages {
            len = stage.out_len(len, ctx);
            lens.push(len);
        }
        lens
    }

    fn encode_whole(&self, h: &[f32], ctx: &CodecContext) -> Encoded {
        let m = h.len();
        let budget = ctx.budget_bits(m);
        let mut x: Vec<f64> = h.iter().map(|&v| v as f64).collect();
        for stage in &self.stages {
            let t0 = Instant::now();
            x = stage.forward(x, ctx);
            probe::add_transform_nanos(t0.elapsed().as_nanos() as u64);
        }
        self.coder.encode(&x, budget, ctx)
    }

    fn decode_whole(
        &self,
        msg: &Encoded,
        m: usize,
        ctx: &CodecContext,
    ) -> Result<Vec<f32>, DecodeError> {
        let budget_bits = ctx.budget_bits(m);
        let lens = self.stage_lens(m, ctx);
        let y_len = *lens.last().expect("stage_lens is never empty");
        let mut budget = ctx.decode_budget;
        let mut y = self.coder.decode(msg, y_len, budget_bits, ctx)?;
        if y.len() != y_len {
            return Err(DecodeError::Length { got: y.len(), want: y_len });
        }
        for (i, stage) in self.stages.iter().enumerate().rev() {
            let t0 = Instant::now();
            let r = stage.inverse(y, lens[i], ctx, &mut budget);
            probe::add_transform_nanos(t0.elapsed().as_nanos() as u64);
            y = r?;
            if y.len() != lens[i] {
                return Err(DecodeError::Length { got: y.len(), want: lens[i] });
            }
        }
        Ok(y.iter().map(|&v| v as f32).collect())
    }
}

impl UpdateCodec for PipelineCodec {
    fn name(&self) -> String {
        self.name.into()
    }

    fn encoder(&self, ctx: &CodecContext, m: usize) -> Box<dyn EncodeSink + '_> {
        Box::new(PipelineSink { codec: self, ctx: *ctx, buf: Vec::with_capacity(m), m })
    }

    fn decoder<'a>(
        &'a self,
        msg: &'a Encoded,
        m: usize,
        ctx: &CodecContext,
    ) -> Box<dyn DecodeStream + 'a> {
        Box::new(PipelineStream {
            codec: self,
            msg,
            m,
            ctx: *ctx,
            state: StreamState::Pending,
        })
    }

    fn encode(&self, h: &[f32], ctx: &CodecContext) -> Encoded {
        self.encode_whole(h, ctx)
    }
}

/// Encode session: buffers the pushed chunks (every current pipeline's
/// first stage is a global transform) and runs the stage chain once at
/// `finish`. `state_bytes` is honest — the fleet's buffered-session
/// telemetry counter keys off it.
struct PipelineSink<'a> {
    codec: &'a PipelineCodec,
    ctx: CodecContext,
    buf: Vec<f32>,
    m: usize,
}

impl EncodeSink for PipelineSink<'_> {
    fn push(&mut self, chunk: &[f32]) {
        self.buf.extend_from_slice(chunk);
    }

    fn state_bytes(&self) -> usize {
        self.buf.capacity() * std::mem::size_of::<f32>()
    }

    fn finish(self: Box<Self>) -> Encoded {
        assert_eq!(
            self.buf.len(),
            self.m,
            "EncodeSink fed {} entries, session opened for {}",
            self.buf.len(),
            self.m
        );
        self.codec.encode_whole(&self.buf, &self.ctx)
    }
}

/// Typed cross-chunk decode state: the reconstruction runs once, then the
/// finished buffer is served chunk by chunk.
enum StreamState {
    /// Reconstruction has not run yet.
    Pending,
    /// Finished output, being served from `pos`.
    Ready { out: Vec<f32>, pos: usize },
    /// A previous call failed; the session is poisoned.
    Poisoned,
}

struct PipelineStream<'a> {
    codec: &'a PipelineCodec,
    msg: &'a Encoded,
    m: usize,
    ctx: CodecContext,
    state: StreamState,
}

impl DecodeStream for PipelineStream<'_> {
    fn next_chunk(&mut self) -> Result<Option<&[f32]>, DecodeError> {
        if let StreamState::Pending = self.state {
            match self.codec.decode_whole(self.msg, self.m, &self.ctx) {
                Ok(out) => self.state = StreamState::Ready { out, pos: 0 },
                Err(e) => {
                    self.state = StreamState::Poisoned;
                    return Err(e);
                }
            }
        }
        match &mut self.state {
            StreamState::Ready { out, pos } => {
                if *pos >= out.len() {
                    return Ok(None);
                }
                let end = (*pos + DEFAULT_CHUNK).min(out.len());
                let start = *pos;
                *pos = end;
                Ok(Some(&out[start..end]))
            }
            StreamState::Poisoned => Err(DecodeError::Header("poisoned pipeline session")),
            StreamState::Pending => unreachable!("reconstruction just ran"),
        }
    }

    fn state_bytes(&self) -> usize {
        match &self.state {
            StreamState::Ready { out, .. } => out.capacity() * std::mem::size_of::<f32>(),
            _ => 0,
        }
    }
}

/// Shared fixed-width uniform quantization arithmetic. These are the
/// *exact* expressions the rotation/top-k/subsample codecs have always
/// used — extracted here so the pipeline ports and the legacy oracles
/// provably share one implementation (bit parity by construction).
///
/// `levels = 2^b − 1`, `span = max(hi − lo, 1e-30)`:
/// quantize `v ↦ min(round((v−lo)/span · levels), levels)`,
/// dequantize `q ↦ lo + q/levels · span`.
pub fn quantize_uniform(v: f64, lo: f64, hi: f64, b: u32) -> u64 {
    let levels = (1u64 << b) - 1;
    let span = (hi - lo).max(1e-30);
    let q = (((v - lo) / span) * levels as f64).round() as u64;
    q.min(levels)
}

/// Inverse of [`quantize_uniform`] (same `lo`/`hi`/`b`).
pub fn dequantize_uniform(q: u64, lo: f64, hi: f64, b: u32) -> f64 {
    let levels = (1u64 << b) - 1;
    let span = (hi - lo).max(1e-30);
    lo + q as f64 / levels as f64 * span
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Doubles every entry; inverse halves (charging one budget unit).
    struct DoubleStage;

    impl TransformStage for DoubleStage {
        fn name(&self) -> &'static str {
            "double"
        }
        fn out_len(&self, m_in: usize, _ctx: &CodecContext) -> usize {
            m_in
        }
        fn forward(&self, mut x: Vec<f64>, _ctx: &CodecContext) -> Vec<f64> {
            for v in x.iter_mut() {
                *v *= 2.0;
            }
            x
        }
        fn inverse(
            &self,
            mut y: Vec<f64>,
            _m_in: usize,
            _ctx: &CodecContext,
            budget: &mut DecodeBudget,
        ) -> Result<Vec<f64>, DecodeError> {
            budget.charge(1)?;
            for v in y.iter_mut() {
                *v *= 0.5;
            }
            Ok(y)
        }
    }

    /// Lossless f32 terminal: 32 bits per entry, budget ignored (tests
    /// only exercise the plumbing, not the rate accounting).
    struct RawCoder;

    impl TerminalCoder for RawCoder {
        fn name(&self) -> &'static str {
            "raw"
        }
        fn encode(&self, y: &[f64], _budget_bits: usize, _ctx: &CodecContext) -> Encoded {
            let mut bytes = Vec::with_capacity(y.len() * 4);
            for &v in y {
                bytes.extend_from_slice(&(v as f32).to_le_bytes());
            }
            Encoded { bits: bytes.len() * 8, bytes }
        }
        fn decode(
            &self,
            msg: &Encoded,
            y_len: usize,
            _budget_bits: usize,
            _ctx: &CodecContext,
        ) -> Result<Vec<f64>, DecodeError> {
            if msg.bytes.len() != y_len * 4 {
                return Err(DecodeError::Length { got: msg.bytes.len() / 4, want: y_len });
            }
            Ok(msg
                .bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f64)
                .collect())
        }
    }

    fn test_codec() -> PipelineCodec {
        PipelineCodec::new("test-pipeline", vec![Box::new(DoubleStage)], Box::new(RawCoder))
    }

    #[test]
    fn pipeline_round_trips_through_sessions() {
        let codec = test_codec();
        let ctx = CodecContext::new(1, 2, 3, 32.0);
        let h: Vec<f32> = (0..2500).map(|i| (i as f32).sin()).collect();
        let enc = codec.encode(&h, &ctx);
        // Whole-buffer and chunked decode agree and recover the input
        // (the stage chain is lossless here).
        let dec = codec.try_decode(&enc, h.len(), &ctx).unwrap();
        assert_eq!(dec.len(), h.len());
        for (a, b) in dec.iter().zip(&h) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        // Chunked encode is bit-identical to whole-buffer encode.
        let mut sink = codec.encoder(&ctx, h.len());
        for c in h.chunks(700) {
            sink.push(c);
        }
        assert!(sink.state_bytes() >= h.len() * 4, "buffered sink must report its buffer");
        assert_eq!(sink.finish(), enc);
    }

    #[test]
    fn exhausted_budget_is_a_typed_error_then_poisons() {
        let codec = test_codec();
        let ctx = CodecContext::new(1, 2, 3, 32.0)
            .with_decode_budget(DecodeBudget::units(0));
        let h = vec![1.0f32; 64];
        let enc = codec.encode(&h, &ctx);
        let mut stream = codec.decoder(&enc, h.len(), &ctx);
        assert_eq!(stream.next_chunk().unwrap_err(), DecodeError::Budget);
        assert!(stream.next_chunk().is_err(), "poisoned session must keep failing");
        // With one unit of credit the same message decodes fine.
        let ok_ctx = CodecContext::new(1, 2, 3, 32.0)
            .with_decode_budget(DecodeBudget::units(1));
        assert!(codec.try_decode(&enc, h.len(), &ok_ctx).is_ok());
    }

    #[test]
    fn uniform_quant_helpers_invert() {
        for b in [1u32, 3, 8, 16] {
            let (lo, hi) = (-2.5f64, 7.25);
            for i in 0..50 {
                let v = lo + (hi - lo) * i as f64 / 49.0;
                let q = quantize_uniform(v, lo, hi, b);
                assert!(q <= (1u64 << b) - 1);
                let r = dequantize_uniform(q, lo, hi, b);
                let step = (hi - lo) / ((1u64 << b) - 1) as f64;
                assert!((r - v).abs() <= step / 2.0 + 1e-12, "b={b} v={v} r={r}");
            }
        }
        // Degenerate span must not divide by zero.
        assert_eq!(quantize_uniform(1.0, 1.0, 1.0, 4), 0);
        assert_eq!(dequantize_uniform(0, 1.0, 1.0, 4), 1.0);
    }

    #[test]
    fn codec_terminal_passes_exact_budget_through() {
        // The adapter must hand the inner codec the pipeline's exact bit
        // budget, not a rate-derived recomputation over the stage length.
        struct BudgetEcho;
        impl UpdateCodec for BudgetEcho {
            fn name(&self) -> String {
                "budget-echo".into()
            }
            fn encoder(&self, ctx: &CodecContext, m: usize) -> Box<dyn EncodeSink + '_> {
                let bits = ctx.budget_bits(m);
                Box::new(super::super::BufferedSink::new(m, move |_: &[f32]| Encoded {
                    bytes: (bits as u64).to_le_bytes().to_vec(),
                    bits: 64,
                }))
            }
            fn decoder<'a>(
                &'a self,
                _msg: &'a Encoded,
                m: usize,
                _ctx: &CodecContext,
            ) -> Box<dyn DecodeStream + 'a> {
                Box::new(super::super::EntryStream::new(m, || Ok(0.0)))
            }
        }
        let term = CodecTerminal::new(BudgetEcho);
        let ctx = CodecContext::new(0, 0, 1, 2.0);
        let enc = term.encode(&[0.0; 10], 12_345, &ctx);
        assert_eq!(u64::from_le_bytes(enc.bytes.try_into().unwrap()), 12_345);
    }
}
