//! Model-update compression codecs.
//!
//! The paper's contribution — [`UVeQFed`] (subtractive dithered lattice
//! quantization, §III) — plus every baseline it is evaluated against in
//! §V, behind one [`UpdateCodec`] interface so the federated runtime and
//! the distortion benches can swap them freely:
//!
//! | codec | paper ref | module | encode session | decode session |
//! |---|---|---|---|---|
//! | UVeQFed (L = 1, 2, 4, 8) | §III | [`uveqfed`] | buffered (needs ‖h‖) | streaming, lattice-block chunks |
//! | QSGD | [17] | [`qsgd`] | buffered (level search) | streaming |
//! | uniform + random rotation | [12] | [`rotation`] | buffered (full FWHT) | buffered |
//! | random subsampling + 3-bit uniform | [12] | [`subsample`] | buffered (range scan) | buffered (scatter) |
//! | TernGrad-style ternary (extension) | [16] | [`terngrad`] | buffered (max scan) | streaming |
//! | sign-SGD with norm scaling (extension) | [21] | [`signsgd`] | streaming (ℓ1 + sign side-buffer) | streaming |
//! | top-k sparsification (extension) | [13]–[15] | [`topk`] | buffered (global sort) | buffered (scatter) |
//! | identity (unquantized FedAvg reference) | — | [`identity`] | streaming | streaming |
//! | FedVQCS compressed sensing (arXiv 2204.07692) | PAPERS.md | [`fedvqcs`] | buffered (sketch) | buffered (budgeted IHT solver) |
//!
//! ## Sessions
//!
//! Since the Codec API v2 redesign the primary interface is **stateful
//! sessions**: [`UpdateCodec::encoder`] returns an [`EncodeSink`] that
//! accepts tensor chunks (`push` … `finish`), and [`UpdateCodec::decoder`]
//! returns a [`DecodeStream`] whose chunks fold straight into the fleet's
//! fixed-point streaming aggregator — the server never materializes a
//! per-user `Vec<f32>`. The whole-buffer [`UpdateCodec::encode`] /
//! [`UpdateCodec::decode`] remain as default-method adapters over the
//! sessions, so callers that hold complete updates keep working and are
//! bit-identical to the chunked path by construction (property-tested in
//! `tests/integration_sessions.rs`).
//!
//! Codec construction is **fallible and parameterized** via
//! [`CodecSpec`] / [`make`]; the old panicking `by_name` wrapper is gone.
//!
//! ## Staged pipelines (Codec API v3)
//!
//! [`pipeline`] decomposes codecs into composable [`TransformStage`]s in
//! front of a [`TerminalCoder`], assembled by [`PipelineCodec`] behind the
//! unchanged [`UpdateCodec`] session surface. Decode sessions carry typed
//! cross-chunk state and draw on the context's [`DecodeBudget`], so a
//! decoder may legally buffer, run a bounded iterative solver, and
//! finalize before yielding its first chunk. [`fedvqcs`] is the first
//! pipeline-native codec; [`rotation`] is ported onto the same stages with
//! its legacy implementation retained as a bit-parity oracle.
//!
//! Every encoder reports the **exact** number of bits it used; the uplink
//! accounting in `fl::` and the distortion figures consume that number, so
//! rate comparisons are honest (headers included).

pub mod fedvqcs;
pub mod identity;
pub mod pipeline;
pub mod qsgd;
pub mod rate;
pub mod rotation;
pub mod session;
pub mod signsgd;
pub mod spec;
pub mod subsample;
pub mod terngrad;
pub mod topk;
pub mod uveqfed;

pub use fedvqcs::FedVqcs;
pub use identity::IdentityCodec;
pub use pipeline::{PipelineCodec, TerminalCoder, TransformStage};
pub use qsgd::Qsgd;
pub use rotation::RotationUniform;
pub use session::{BufferedSink, EntryStream, SliceStream, SymbolMapStream, DEFAULT_CHUNK};
pub use signsgd::SignSgd;
pub use spec::{CodecSpec, LatticeDim};
pub use subsample::SubsampleUniform;
pub use terngrad::TernGrad;
pub use topk::TopK;
pub use uveqfed::UVeQFed;

use crate::entropy::CodeError;
use crate::prng::CommonRandomness;

/// Typed decode failure for a codec session. Everything reachable from
/// untrusted payload bytes surfaces here — the entropy layer's
/// [`CodeError`], a stream that yields the wrong entry count, or an
/// inconsistent in-payload header. `Copy`, so the fleet can carry it on
/// zero-alloc telemetry spans and `ClientFate` records.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeError {
    /// The entropy layer rejected the payload.
    Code(CodeError),
    /// The stream ended with the wrong number of entries.
    Length { got: usize, want: usize },
    /// A structural in-payload header was inconsistent.
    Header(&'static str),
    /// The session's [`DecodeBudget`] ran out before reconstruction
    /// finished (e.g. the fedvqcs iterative solver hit its credit limit).
    Budget,
}

impl DecodeError {
    /// Static quarantine reason for fate records and telemetry spans
    /// (which are `Copy` and carry no allocations).
    pub fn reason(self) -> &'static str {
        match self {
            DecodeError::Code(_) => "corrupt entropy stream",
            DecodeError::Length { .. } => "decoded stream length mismatch",
            DecodeError::Header(what) => what,
            DecodeError::Budget => "decode budget exhausted",
        }
    }
}

impl From<CodeError> for DecodeError {
    fn from(e: CodeError) -> Self {
        DecodeError::Code(e)
    }
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            DecodeError::Code(e) => write!(f, "{e}"),
            DecodeError::Length { got, want } => {
                write!(f, "decode stream yielded {got} of {want} entries")
            }
            DecodeError::Header(what) => write!(f, "corrupt payload header: {what}"),
            DecodeError::Budget => write!(f, "decode budget exhausted"),
        }
    }
}

impl std::error::Error for DecodeError {}

/// Compute credit for one decode session: how many units of expensive
/// reconstruction work (iterative-solver iterations, inverse-transform
/// passes) the server is willing to spend on a single message. Stages
/// draw credit via [`DecodeBudget::charge`]; exhaustion surfaces as the
/// typed [`DecodeError::Budget`], which the shard fold turns into a
/// quarantine — never a partial fold.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DecodeBudget {
    credit: u64,
}

impl DecodeBudget {
    /// Effectively unbounded credit — the default for trusted pipelines.
    pub const UNLIMITED: DecodeBudget = DecodeBudget { credit: u64::MAX };

    /// A budget of exactly `credit` work units.
    pub fn units(credit: u64) -> Self {
        Self { credit }
    }

    /// Remaining credit.
    pub fn remaining(&self) -> u64 {
        self.credit
    }

    /// Spend `n` units, or fail with [`DecodeError::Budget`] if fewer
    /// than `n` remain (the budget is left drained either way, so a
    /// poisoned session cannot keep charging).
    pub fn charge(&mut self, n: u64) -> Result<(), DecodeError> {
        if self.credit == u64::MAX {
            return Ok(());
        }
        if self.credit < n {
            self.credit = 0;
            return Err(DecodeError::Budget);
        }
        self.credit -= n;
        Ok(())
    }
}

impl Default for DecodeBudget {
    fn default() -> Self {
        Self::UNLIMITED
    }
}

/// Everything an encoder/decoder pair shares per (user, round) message:
/// the common-randomness source (assumption A3), the rate budget, and the
/// server-side decode-compute budget.
#[derive(Debug, Clone, Copy)]
pub struct CodecContext {
    pub user: u64,
    pub round: u64,
    pub crand: CommonRandomness,
    /// Bit budget per tensor entry (the paper's quantization rate `R`).
    pub rate: f64,
    /// Compute credit a decode session opened from this context may
    /// spend. Defaults to [`DecodeBudget::UNLIMITED`].
    pub decode_budget: DecodeBudget,
    /// Exact total-bit override for [`Self::budget_bits`]. Private:
    /// pipeline internals use [`Self::with_exact_budget`] to hand an
    /// inner terminal coder an exact budget without the float
    /// rate-times-m round trip losing a bit.
    budget_override: Option<usize>,
}

impl CodecContext {
    pub fn new(user: u64, round: u64, seed: u64, rate: f64) -> Self {
        Self {
            user,
            round,
            crand: CommonRandomness::new(seed),
            rate,
            decode_budget: DecodeBudget::UNLIMITED,
            budget_override: None,
        }
    }

    /// Same context with a decode-compute budget attached.
    pub fn with_decode_budget(mut self, budget: DecodeBudget) -> Self {
        self.decode_budget = budget;
        self
    }

    /// Same context whose [`Self::budget_bits`] returns exactly `bits`
    /// for any `m`. Used by pipeline codecs to pass an already-computed
    /// bit budget to an inner coder without float rounding drift.
    pub fn with_exact_budget(mut self, bits: usize) -> Self {
        self.budget_override = Some(bits);
        self
    }

    /// Total bit budget for an `m`-entry update.
    pub fn budget_bits(&self, m: usize) -> usize {
        match self.budget_override {
            Some(bits) => bits,
            None => (self.rate * m as f64).floor() as usize,
        }
    }
}

/// An encoded model update plus exact accounting.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Encoded {
    pub bytes: Vec<u8>,
    /// Exact bits used (≤ bytes.len()*8; the tail byte may be padding).
    pub bits: usize,
}

impl Encoded {
    pub fn bits_per_entry(&self, m: usize) -> f64 {
        self.bits as f64 / m as f64
    }
}

/// Client side of a codec session: accepts the update as tensor chunks
/// and produces the coded message at the end.
///
/// Chunks may have any sizes (including empty); their concatenation must
/// total exactly the `m` entries the session was opened for. The coded
/// output is independent of the chunk partition — any partition is
/// bit-identical to a single whole-buffer `push` (property-tested).
pub trait EncodeSink {
    /// Append the next chunk of the update.
    fn push(&mut self, chunk: &[f32]);

    /// Approximate bytes of encoder state currently held (input buffers,
    /// partial side-buffers), **excluding** the final coded output. The
    /// `fleet_scale` bench meters this to measure — not assert — each
    /// codec's client-side memory profile.
    fn state_bytes(&self) -> usize {
        0
    }

    /// Seal the session and return the coded message with exact bit
    /// accounting.
    fn finish(self: Box<Self>) -> Encoded;
}

/// Server side of a codec session: yields the decoded update as chunks,
/// in order. The concatenation of all chunks is exactly the `m`-entry
/// decoded update (identical to [`UpdateCodec::decode`]).
pub trait DecodeStream {
    /// The next decoded chunk, or `Ok(None)` once all `m` entries were
    /// yielded. The returned slice is only valid until the next call.
    /// Corrupt payloads surface as a typed [`DecodeError`] — sessions
    /// never panic on untrusted bytes. After an `Err` the stream is
    /// poisoned: further calls may return anything except a panic.
    fn next_chunk(&mut self) -> Result<Option<&[f32]>, DecodeError>;

    /// Approximate bytes of decoder state currently held (output
    /// buffers, solver scratch). Mirrors [`EncodeSink::state_bytes`]:
    /// metered, never asserted.
    fn state_bytes(&self) -> usize {
        0
    }
}

/// A lossy model-update codec. Encoders MUST stay within
/// `ctx.budget_bits(m)` unless the codec is explicitly exempt (identity)
/// — the runtime asserts this on every uplink message.
///
/// Implementors provide the session constructors ([`Self::encoder`] /
/// [`Self::decoder`]); the whole-buffer [`Self::encode`] /
/// [`Self::decode`] are default adapters over them.
pub trait UpdateCodec: Send + Sync {
    fn name(&self) -> String;

    /// Open an encode session for an `m`-entry update.
    fn encoder(&self, ctx: &CodecContext, m: usize) -> Box<dyn EncodeSink + '_>;

    /// Open a decode session over `msg` for an update of known length `m`
    /// (the server knows the model).
    fn decoder<'a>(
        &'a self,
        msg: &'a Encoded,
        m: usize,
        ctx: &CodecContext,
    ) -> Box<dyn DecodeStream + 'a>;

    /// Whole-buffer encode: a one-`push` session.
    fn encode(&self, h: &[f32], ctx: &CodecContext) -> Encoded {
        let mut sink = self.encoder(ctx, h.len());
        sink.push(h);
        sink.finish()
    }

    /// Whole-buffer decode for **trusted** bytes (a message this process
    /// encoded): drains the decode session into a vector, panicking on a
    /// corrupt payload. Untrusted bytes go through [`Self::try_decode`]
    /// or [`Self::decoder`] instead.
    fn decode(&self, msg: &Encoded, m: usize, ctx: &CodecContext) -> Vec<f32> {
        self.try_decode(msg, m, ctx)
            .expect("corrupt payload: decode untrusted bytes via try_decode/decoder")
    }

    /// Fallible whole-buffer decode: drains the decode session, surfacing
    /// corruption as a typed [`DecodeError`] instead of a panic.
    fn try_decode(
        &self,
        msg: &Encoded,
        m: usize,
        ctx: &CodecContext,
    ) -> Result<Vec<f32>, DecodeError> {
        let mut out = Vec::with_capacity(m);
        let mut stream = self.decoder(msg, m, ctx);
        while let Some(chunk) = stream.next_chunk()? {
            out.extend_from_slice(chunk);
            if out.len() > m {
                return Err(DecodeError::Length { got: out.len(), want: m });
            }
        }
        if out.len() != m {
            return Err(DecodeError::Length { got: out.len(), want: m });
        }
        Ok(out)
    }

    /// Whether the codec respects the bit budget (identity does not).
    fn rate_constrained(&self) -> bool {
        true
    }
}

/// Construct a codec from a spec string — the fallible registry entry
/// point. Accepts every canonical name and alias plus `key=value`
/// parameters; see [`CodecSpec`] for the grammar. Errors name the valid
/// codecs instead of panicking.
pub fn make(spec: &str) -> crate::Result<Box<dyn UpdateCodec>> {
    CodecSpec::parse(spec).map(|s| s.build())
}

/// Stable codec ids for the fleet wire format (`fleet::wire`).
///
/// Each row is `(id, canonical config name, display-name aliases)`. The
/// table is **append-only**: ids are baked into serialized frames, so
/// reordering or deleting rows breaks decode of recorded traffic.
const WIRE_CODECS: &[(u8, &str, &[&str])] = &[
    (0, "identity", &["none"]),
    (1, "uveqfed-l1", &["uveqfed-scalar"]),
    (2, "uveqfed-l2", &["uveqfed", "uveqfed-hex-paper"]),
    (3, "uveqfed-l4", &["uveqfed-d4"]),
    (4, "uveqfed-l8", &["uveqfed-e8"]),
    (5, "qsgd", &[]),
    (6, "rotation", &[]),
    (7, "subsample", &[]),
    (8, "terngrad", &[]),
    (9, "signsgd", &[]),
    (10, "topk", &[]),
    (11, "fedvqcs", &[]),
];

/// Wire id for a codec name — accepts both the registry config keys and
/// the `UpdateCodec::name()` display names. `None` for unregistered
/// variants (e.g. ablation-only `-nosub` codecs), which frames carry as
/// [`CODEC_ID_UNREGISTERED`].
pub fn codec_id(name: &str) -> Option<u8> {
    WIRE_CODECS
        .iter()
        .find(|(_, canon, aliases)| *canon == name || aliases.contains(&name))
        .map(|&(id, _, _)| id)
}

/// Canonical config name for a wire id.
pub fn codec_name(id: u8) -> Option<&'static str> {
    WIRE_CODECS.iter().find(|&&(i, _, _)| i == id).map(|&(_, canon, _)| canon)
}

/// Frame codec id for payloads whose codec is not in the registry.
pub const CODEC_ID_UNREGISTERED: u8 = u8::MAX;

/// All canonical registry names (the round-trip test surface).
pub fn registered_codec_names() -> impl Iterator<Item = &'static str> {
    WIRE_CODECS.iter().map(|&(_, canon, _)| canon)
}

/// Measure per-entry quantization MSE of `codec` on `data` at `rate` —
/// the quantity plotted in Figs. 4–5.
pub fn measure_distortion(
    codec: &dyn UpdateCodec,
    data: &[f32],
    rate: f64,
    seed: u64,
    round: u64,
) -> DistortionReport {
    let ctx = CodecContext::new(0, round, seed, rate);
    let enc = codec.encode(data, &ctx);
    let dec = codec.decode(&enc, data.len(), &ctx);
    DistortionReport {
        mse: crate::util::stats::mse(data, &dec),
        bits: enc.bits,
        bits_per_entry: enc.bits_per_entry(data.len()),
    }
}

#[derive(Debug, Clone, Copy)]
pub struct DistortionReport {
    /// Per-entry squared error.
    pub mse: f64,
    pub bits: usize,
    pub bits_per_entry: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_constructs_all() {
        for n in registered_codec_names() {
            let c = make(n).unwrap_or_else(|e| panic!("{n}: {e}"));
            assert!(!c.name().is_empty());
        }
    }

    #[test]
    fn unknown_codec_is_an_error_listing_valid_names() {
        let err = make("nope").unwrap_err().to_string();
        assert!(err.contains("unknown codec 'nope'"), "{err}");
        for n in registered_codec_names() {
            assert!(err.contains(n), "error should list '{n}': {err}");
        }
    }

    #[test]
    fn wire_ids_cover_registry_and_display_names() {
        for name in registered_codec_names() {
            let id = codec_id(name).expect(name);
            assert_eq!(codec_name(id), Some(name));
            // Display names of constructed codecs resolve to the same id.
            let codec = make(name).unwrap();
            assert_eq!(codec_id(&codec.name()), Some(id), "display name {}", codec.name());
        }
        assert_eq!(codec_id("uveqfed"), codec_id("uveqfed-l2"));
        assert_eq!(codec_id("nope-codec"), None);
    }

    #[test]
    fn budget_math() {
        let ctx = CodecContext::new(0, 0, 1, 2.0);
        assert_eq!(ctx.budget_bits(100), 200);
        let exact = ctx.with_exact_budget(137);
        assert_eq!(exact.budget_bits(100), 137, "override wins for any m");
        assert_eq!(exact.budget_bits(7), 137);
    }

    #[test]
    fn decode_budget_charges_and_exhausts() {
        let mut b = DecodeBudget::units(3);
        assert!(b.charge(2).is_ok());
        assert_eq!(b.remaining(), 1);
        assert_eq!(b.charge(2), Err(DecodeError::Budget));
        assert_eq!(b.remaining(), 0, "failed charge drains the budget");
        assert_eq!(b.charge(1), Err(DecodeError::Budget));

        let mut unlimited = DecodeBudget::UNLIMITED;
        assert!(unlimited.charge(u64::MAX).is_ok());
        assert!(unlimited.charge(u64::MAX).is_ok(), "unlimited never drains");
        assert_eq!(DecodeBudget::default(), DecodeBudget::UNLIMITED);
        assert_eq!(DecodeError::Budget.reason(), "decode budget exhausted");
    }
}
