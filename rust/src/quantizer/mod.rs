//! Model-update compression codecs.
//!
//! The paper's contribution — [`UVeQFed`] (subtractive dithered lattice
//! quantization, §III) — plus every baseline it is evaluated against in
//! §V, behind one [`UpdateCodec`] interface so the federated runtime and
//! the distortion benches can swap them freely:
//!
//! | codec | paper ref | module |
//! |---|---|---|
//! | UVeQFed (L = 1, 2, 4, 8) | §III | [`uveqfed`] |
//! | QSGD | [17] | [`qsgd`] |
//! | uniform + random rotation | [12] | [`rotation`] |
//! | random subsampling + 3-bit uniform | [12] | [`subsample`] |
//! | TernGrad-style ternary (extension) | [16] | [`terngrad`] |
//! | sign-SGD with norm scaling (extension) | [21] | [`signsgd`] |
//! | top-k sparsification (extension) | [13]–[15] | [`topk`] |
//! | identity (unquantized FedAvg reference) | — | [`identity`] |
//!
//! Every encoder reports the **exact** number of bits it used; the uplink
//! accounting in `fl::` and the distortion figures consume that number, so
//! rate comparisons are honest (headers included).

pub mod identity;
pub mod qsgd;
pub mod rate;
pub mod rotation;
pub mod signsgd;
pub mod subsample;
pub mod terngrad;
pub mod topk;
pub mod uveqfed;

pub use identity::IdentityCodec;
pub use qsgd::Qsgd;
pub use rotation::RotationUniform;
pub use signsgd::SignSgd;
pub use subsample::SubsampleUniform;
pub use terngrad::TernGrad;
pub use topk::TopK;
pub use uveqfed::UVeQFed;

use crate::prng::CommonRandomness;

/// Everything an encoder/decoder pair shares per (user, round) message:
/// the common-randomness source (assumption A3) and the rate budget.
#[derive(Debug, Clone, Copy)]
pub struct CodecContext {
    pub user: u64,
    pub round: u64,
    pub crand: CommonRandomness,
    /// Bit budget per tensor entry (the paper's quantization rate `R`).
    pub rate: f64,
}

impl CodecContext {
    pub fn new(user: u64, round: u64, seed: u64, rate: f64) -> Self {
        Self { user, round, crand: CommonRandomness::new(seed), rate }
    }

    /// Total bit budget for an `m`-entry update.
    pub fn budget_bits(&self, m: usize) -> usize {
        (self.rate * m as f64).floor() as usize
    }
}

/// An encoded model update plus exact accounting.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Encoded {
    pub bytes: Vec<u8>,
    /// Exact bits used (≤ bytes.len()*8; the tail byte may be padding).
    pub bits: usize,
}

impl Encoded {
    pub fn bits_per_entry(&self, m: usize) -> f64 {
        self.bits as f64 / m as f64
    }
}

/// A lossy model-update codec. Encoders MUST stay within
/// `ctx.budget_bits(h.len())` unless the codec is explicitly exempt
/// (identity) — the runtime asserts this on every uplink message.
pub trait UpdateCodec: Send + Sync {
    fn name(&self) -> String;

    fn encode(&self, h: &[f32], ctx: &CodecContext) -> Encoded;

    /// Decode an update of known length `m` (the server knows the model).
    fn decode(&self, msg: &Encoded, m: usize, ctx: &CodecContext) -> Vec<f32>;

    /// Whether the codec respects the bit budget (identity does not).
    fn rate_constrained(&self) -> bool {
        true
    }
}

/// Construct a codec from a config-style name. Lattice dims for UVeQFed
/// are selected by suffix: `uveqfed-l1`, `uveqfed-l2` (hex), `uveqfed-l4`
/// (D4), `uveqfed-l8` (E8).
pub fn by_name(name: &str) -> Box<dyn UpdateCodec> {
    match name {
        "uveqfed-l1" => Box::new(UVeQFed::scalar()),
        "uveqfed" | "uveqfed-l2" => Box::new(UVeQFed::hexagonal()),
        "uveqfed-l4" => Box::new(UVeQFed::d4()),
        "uveqfed-l8" => Box::new(UVeQFed::e8()),
        "qsgd" => Box::new(Qsgd::default()),
        "rotation" => Box::new(RotationUniform::default()),
        "subsample" => Box::new(SubsampleUniform::default()),
        "terngrad" => Box::new(TernGrad::default()),
        "signsgd" => Box::new(SignSgd::default()),
        "topk" => Box::new(TopK::default()),
        "identity" | "none" => Box::new(IdentityCodec),
        other => panic!("unknown codec '{other}'"),
    }
}

/// Stable codec ids for the fleet wire format (`fleet::wire`).
///
/// Each row is `(id, canonical config name, display-name aliases)`. The
/// table is **append-only**: ids are baked into serialized frames, so
/// reordering or deleting rows breaks decode of recorded traffic.
const WIRE_CODECS: &[(u8, &str, &[&str])] = &[
    (0, "identity", &["none"]),
    (1, "uveqfed-l1", &["uveqfed-scalar"]),
    (2, "uveqfed-l2", &["uveqfed", "uveqfed-hex-paper"]),
    (3, "uveqfed-l4", &["uveqfed-d4"]),
    (4, "uveqfed-l8", &["uveqfed-e8"]),
    (5, "qsgd", &[]),
    (6, "rotation", &[]),
    (7, "subsample", &[]),
    (8, "terngrad", &[]),
    (9, "signsgd", &[]),
    (10, "topk", &[]),
];

/// Wire id for a codec name — accepts both the `by_name` config keys and
/// the `UpdateCodec::name()` display names. `None` for unregistered
/// variants (e.g. ablation-only `-nosub` codecs), which frames carry as
/// [`CODEC_ID_UNREGISTERED`].
pub fn codec_id(name: &str) -> Option<u8> {
    WIRE_CODECS
        .iter()
        .find(|(_, canon, aliases)| *canon == name || aliases.contains(&name))
        .map(|&(id, _, _)| id)
}

/// Canonical config name for a wire id.
pub fn codec_name(id: u8) -> Option<&'static str> {
    WIRE_CODECS.iter().find(|&&(i, _, _)| i == id).map(|&(_, canon, _)| canon)
}

/// Frame codec id for payloads whose codec is not in the registry.
pub const CODEC_ID_UNREGISTERED: u8 = u8::MAX;

/// All canonical registry names (the round-trip test surface).
pub fn registered_codec_names() -> impl Iterator<Item = &'static str> {
    WIRE_CODECS.iter().map(|&(_, canon, _)| canon)
}

/// Measure per-entry quantization MSE of `codec` on `data` at `rate` —
/// the quantity plotted in Figs. 4–5.
pub fn measure_distortion(
    codec: &dyn UpdateCodec,
    data: &[f32],
    rate: f64,
    seed: u64,
    round: u64,
) -> DistortionReport {
    let ctx = CodecContext::new(0, round, seed, rate);
    let enc = codec.encode(data, &ctx);
    let dec = codec.decode(&enc, data.len(), &ctx);
    DistortionReport {
        mse: crate::util::stats::mse(data, &dec),
        bits: enc.bits,
        bits_per_entry: enc.bits_per_entry(data.len()),
    }
}

#[derive(Debug, Clone, Copy)]
pub struct DistortionReport {
    /// Per-entry squared error.
    pub mse: f64,
    pub bits: usize,
    pub bits_per_entry: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_constructs_all() {
        for n in [
            "uveqfed-l1",
            "uveqfed-l2",
            "uveqfed-l4",
            "uveqfed-l8",
            "qsgd",
            "rotation",
            "subsample",
            "terngrad",
            "signsgd",
            "topk",
            "identity",
        ] {
            let c = by_name(n);
            assert!(!c.name().is_empty());
        }
    }

    #[test]
    #[should_panic]
    fn unknown_codec_panics() {
        let _ = by_name("nope");
    }

    #[test]
    fn wire_ids_cover_registry_and_display_names() {
        for name in registered_codec_names() {
            let id = codec_id(name).expect(name);
            assert_eq!(codec_name(id), Some(name));
            // Display names of constructed codecs resolve to the same id.
            let codec = by_name(name);
            assert_eq!(codec_id(&codec.name()), Some(id), "display name {}", codec.name());
        }
        assert_eq!(codec_id("uveqfed"), codec_id("uveqfed-l2"));
        assert_eq!(codec_id("nope-codec"), None);
    }

    #[test]
    fn budget_math() {
        let ctx = CodecContext::new(0, 0, 1, 2.0);
        assert_eq!(ctx.budget_bits(100), 200);
    }
}
