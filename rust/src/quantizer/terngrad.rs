//! TernGrad-style ternary quantization [16] (extension baseline).
//!
//! Coordinates are mapped to `{−1, 0, +1}·max|h|` with probabilistic
//! rounding `P(±1) = |h_i|/max|h|` (unbiased). The ternary stream is
//! entropy-coded with the adaptive range coder, so the realized rate is
//! usually well below 2 bits/entry.
//!
//! Sessions: the encode sink is buffered (`max|h|` is a global statistic
//! and must precede the coded stream); the decode stream is single-pass
//! via the incremental [`SymbolDecoder`].

use super::{
    BufferedSink, CodecContext, DecodeStream, Encoded, EncodeSink, EntryStream, SymbolMapStream,
    UpdateCodec,
};
use crate::entropy::range::{AdaptiveRangeCoder, SymbolDecoder};
use crate::entropy::{BitReader, BitWriter, IntCoder};
use crate::prng::{Rng, StreamKind};

#[derive(Debug, Clone, Copy, Default)]
pub struct TernGrad;

impl TernGrad {
    /// Whole-buffer encoder (runs at `EncodeSink::finish`).
    fn encode_whole(&self, h: &[f32], ctx: &CodecContext) -> Encoded {
        let max = h.iter().fold(0.0f32, |a, &b| a.max(b.abs())) as f64;
        if max == 0.0 {
            // Empty zero message (decodes as zeros, fits any budget).
            return Encoded { bytes: Vec::new(), bits: 0 };
        }
        let mut w = BitWriter::new();
        w.push_f32(max as f32);
        let mut rng = ctx.crand.stream(ctx.user, ctx.round, StreamKind::Rounding);
        let syms: Vec<i64> = h
            .iter()
            .map(|&v| {
                let p = (v.abs() as f64) / max;
                if rng.uniform() < p {
                    if v >= 0.0 {
                        1
                    } else {
                        -1
                    }
                } else {
                    0
                }
            })
            .collect();
        AdaptiveRangeCoder::default().encode(&syms, &mut w);
        let bits = w.bit_len();
        Encoded { bytes: w.into_bytes(), bits }
    }
}

impl UpdateCodec for TernGrad {
    fn name(&self) -> String {
        "terngrad".into()
    }

    fn encoder(&self, ctx: &CodecContext, m: usize) -> Box<dyn EncodeSink + '_> {
        let ctx = *ctx;
        Box::new(BufferedSink::new(m, move |h: &[f32]| self.encode_whole(h, &ctx)))
    }

    /// Skip the session input buffer for the whole-buffer entry point.
    fn encode(&self, h: &[f32], ctx: &CodecContext) -> Encoded {
        self.encode_whole(h, ctx)
    }

    fn decoder<'a>(
        &'a self,
        msg: &'a Encoded,
        m: usize,
        _ctx: &CodecContext,
    ) -> Box<dyn DecodeStream + 'a> {
        let mut r = BitReader::new(&msg.bytes);
        let max = r.read_f32() as f64;
        if max == 0.0 {
            return Box::new(EntryStream::new(m, || Ok(0.0)));
        }
        let sd = SymbolDecoder::from_embedded(&msg.bytes, &mut r, 1);
        // Batched symbol pulls (one `decode_into` per chunk).
        Box::new(SymbolMapStream::new(sd, m, move |x| (x as f64 * max) as f32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Normal, Xoshiro256pp};

    fn gaussian(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        Normal::new(0.0, 1.0).vec_f32(&mut rng, n)
    }

    #[test]
    fn roundtrip_values_ternary() {
        let h = gaussian(2048, 101);
        let ctx = CodecContext::new(0, 0, 5, 2.0);
        let enc = TernGrad.encode(&h, &ctx);
        let dec = TernGrad.decode(&enc, h.len(), &ctx);
        let max = h.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        for &v in &dec {
            let n = v / max;
            assert!(
                (n.abs() < 1e-6) || ((n.abs() - 1.0).abs() < 1e-6),
                "non-ternary value {v}"
            );
        }
    }

    #[test]
    fn unbiased_in_expectation() {
        let h = gaussian(128, 102);
        let rounds = 600;
        let mut mean = vec![0.0f64; h.len()];
        for round in 0..rounds {
            let ctx = CodecContext::new(0, round, 5, 2.0);
            let enc = TernGrad.encode(&h, &ctx);
            let dec = TernGrad.decode(&enc, h.len(), &ctx);
            for (m, &d) in mean.iter_mut().zip(&dec) {
                *m += d as f64 / rounds as f64;
            }
        }
        let bias: f64 = h
            .iter()
            .zip(&mean)
            .map(|(&a, &b)| (a as f64 - b).powi(2))
            .sum::<f64>()
            / h.len() as f64;
        assert!(bias < 0.05, "bias^2 {bias}");
    }

    #[test]
    fn rate_under_two_bits() {
        let h = gaussian(8192, 103);
        let ctx = CodecContext::new(0, 0, 5, 2.0);
        let enc = TernGrad.encode(&h, &ctx);
        assert!(enc.bits_per_entry(h.len()) <= 2.0, "{}", enc.bits_per_entry(h.len()));
    }

    #[test]
    fn zero_update_streams_zeros() {
        let h = vec![0.0f32; 300];
        let ctx = CodecContext::new(0, 0, 5, 2.0);
        let enc = TernGrad.encode(&h, &ctx);
        assert_eq!(TernGrad.decode(&enc, 300, &ctx), h);
    }
}
