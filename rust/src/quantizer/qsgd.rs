//! QSGD [17] — probabilistic scalar quantization with Elias coding.
//!
//! For quantization level count `s`, each coordinate is encoded as
//! `sign(h_i) · ‖h‖₂ · ξ_i/s` where `ξ_i ∈ {0,…,s}` randomly rounds
//! `|h_i|/‖h‖·s` to a neighboring integer (unbiased). The integer stream
//! is compressed with Elias-gamma (the paper's integer code family), signs
//! travel only for non-zero levels.
//!
//! This is exactly UVeQFed's E1–E3 with `L = 1`, `ζ = 1` and **no dither
//! subtraction** — the comparison the paper draws in §III-B. The level
//! count is halved until the encoding fits the bit budget, mirroring how
//! the paper operates QSGD "with the same overall number of bits".
//!
//! Sessions: the encode sink is buffered (the level-count bisection needs
//! `‖h‖₂` and the whole coordinate stream); the decode stream is
//! single-pass for both wire formats (Elias directly off the bit reader,
//! range-coded via the incremental [`SymbolDecoder`]).

use super::{
    BufferedSink, CodecContext, DecodeStream, Encoded, EncodeSink, EntryStream, SymbolMapStream,
    UpdateCodec,
};
use crate::entropy::elias::EliasGamma;
use crate::entropy::range::{AdaptiveRangeCoder, SymbolDecoder};
use crate::entropy::{BitReader, BitWriter, IntCoder};
use crate::prng::{Rng, StreamKind};
use crate::util::stats::l2_norm;

#[derive(Debug, Clone, Copy)]
pub struct Qsgd {
    /// Cap on quantization levels.
    pub max_levels: u32,
}

impl Default for Qsgd {
    fn default() -> Self {
        Self { max_levels: 1 << 20 }
    }
}

/// Header flag marking the range-coded fallback (levels' high bit).
const RANGE_CODED_FLAG: u32 = 1 << 31;

impl Qsgd {
    /// Draw the probabilistic levels ξ_i (signed) for the whole update.
    fn draw_levels(&self, h: &[f32], norm: f64, levels: u32, ctx: &CodecContext) -> Vec<i64> {
        let mut rng = ctx.crand.stream(ctx.user, ctx.round, StreamKind::Rounding);
        let s = levels as f64;
        h.iter()
            .map(|&v| {
                let a = (v.abs() as f64) / norm * s;
                let lo = a.floor();
                let xi = if rng.uniform() < a - lo { lo + 1.0 } else { lo } as i64;
                if v < 0.0 {
                    -xi
                } else {
                    xi
                }
            })
            .collect()
    }

    fn encode_at_levels(
        &self,
        h: &[f32],
        norm: f64,
        levels: u32,
        ctx: &CodecContext,
        range_coded: bool,
    ) -> BitWriter {
        let mut w = BitWriter::new();
        w.push_f32(norm as f32);
        let flag = if range_coded { RANGE_CODED_FLAG } else { 0 };
        w.push_u32(levels | flag);
        let xs = self.draw_levels(h, norm, levels, ctx);
        if range_coded {
            // Adaptive range coding of the signed levels — used when the
            // Elias stream cannot meet a sub-1-bit budget (heavily-zero
            // streams compress below 1 bit/entry here).
            AdaptiveRangeCoder::default().encode(&xs, &mut w);
        } else {
            for &x in &xs {
                EliasGamma::put(&mut w, x.unsigned_abs() + 1);
                if x != 0 {
                    w.push_bit(x < 0);
                }
            }
        }
        w
    }

    /// Whole-buffer encoder (runs at `EncodeSink::finish`; the level
    /// search is a global two-pass procedure).
    fn encode_whole(&self, h: &[f32], ctx: &CodecContext) -> Encoded {
        let budget = ctx.budget_bits(h.len());
        let norm = l2_norm(h);
        if norm == 0.0 || budget < 96 {
            // Empty zero message: decodes as zeros (the reader
            // zero-fills), fits any budget — including the near-zero
            // rates a heterogeneous-uplink controller can assign.
            return Encoded { bytes: Vec::new(), bits: 0 };
        }
        // QSGD's distortion falls with the level count while the Elias
        // stream grows only logarithmically, so the fair rate-R baseline
        // uses the LARGEST level count whose encoding fits R·m bits (the
        // paper runs QSGD "with the same overall number of bits"). The
        // search — geometric bracket + bisection on the exact encoded size
        // — is a pure function of (h, ctx), keeping encoding deterministic
        // across worker interleavings.
        let bits_at = |lv: u32| self.encode_at_levels(h, norm, lv, ctx, false).bit_len();
        if bits_at(1) > budget {
            // Elias can't fit (≥1 bit/coordinate floor): range-coded
            // ternary fallback (heavily-zero streams go sub-1-bit there).
            let w = self.encode_at_levels(h, norm, 1, ctx, true);
            let bits = w.bit_len();
            if bits > budget {
                // Even the entropy-coded ternary stream overflows a
                // starvation budget — send the empty zero message rather
                // than violate the uplink contract.
                return Encoded { bytes: Vec::new(), bits: 0 };
            }
            return Encoded { bytes: w.into_bytes(), bits };
        }
        let mut lo = 1u32; // feasible
        let mut hi = 2u32;
        let mut iters = 0;
        while hi < self.max_levels && bits_at(hi) <= budget && iters < 24 {
            lo = hi;
            hi *= 2;
            iters += 1;
        }
        // bisect: lo feasible, hi infeasible (or cap)
        while hi - lo > 1 {
            let mid = lo + (hi - lo) / 2;
            if bits_at(mid) <= budget {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let w = self.encode_at_levels(h, norm, lo, ctx, false);
        let bits = w.bit_len();
        debug_assert!(bits <= budget);
        Encoded { bytes: w.into_bytes(), bits }
    }
}

impl UpdateCodec for Qsgd {
    fn name(&self) -> String {
        "qsgd".into()
    }

    fn encoder(&self, ctx: &CodecContext, m: usize) -> Box<dyn EncodeSink + '_> {
        let ctx = *ctx;
        Box::new(BufferedSink::new(m, move |h: &[f32]| self.encode_whole(h, &ctx)))
    }

    /// Skip the session input buffer for the whole-buffer entry point.
    fn encode(&self, h: &[f32], ctx: &CodecContext) -> Encoded {
        self.encode_whole(h, ctx)
    }

    fn decoder<'a>(
        &'a self,
        msg: &'a Encoded,
        m: usize,
        _ctx: &CodecContext,
    ) -> Box<dyn DecodeStream + 'a> {
        let mut r = BitReader::new(&msg.bytes);
        let norm = r.read_f32() as f64;
        let raw = r.read_u32();
        let range_coded = raw & RANGE_CODED_FLAG != 0;
        let levels = raw & !RANGE_CODED_FLAG;
        if norm == 0.0 || levels == 0 {
            return Box::new(EntryStream::new(m, || Ok(0.0)));
        }
        let s = levels as f64;
        if range_coded {
            // Batched symbol pulls over the range-coded fallback stream.
            let sd = SymbolDecoder::from_embedded(&msg.bytes, &mut r, 1);
            Box::new(SymbolMapStream::new(sd, m, move |xi| (norm * xi as f64 / s) as f32))
        } else {
            Box::new(EntryStream::new(m, move || {
                let xi = EliasGamma::get(&mut r)? - 1;
                let mut v = norm * xi as f64 / s;
                if xi > 0 && r.read_bit() {
                    v = -v;
                }
                Ok(v as f32)
            }))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Normal, Rng, Xoshiro256pp};
    use crate::quantizer::measure_distortion;

    fn gaussian(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        Normal::new(0.0, 1.0).vec_f32(&mut rng, n)
    }

    #[test]
    fn within_budget_and_reasonable() {
        let h = gaussian(4096, 81);
        for rate in [1.0, 2.0, 4.0] {
            let rep = measure_distortion(&Qsgd::default(), &h, rate, 3, 0);
            assert!(rep.bits_per_entry <= rate + 1e-9, "rate {rate}: {}", rep.bits_per_entry);
            assert!(rep.mse.is_finite());
        }
    }

    #[test]
    fn quantization_is_unbiased() {
        // E[decoded] = h coordinate-wise: average over many rounds.
        let h = gaussian(256, 82);
        let codec = Qsgd::default();
        let rounds = 400;
        let mut mean = vec![0.0f64; h.len()];
        for round in 0..rounds {
            let ctx = CodecContext::new(0, round, 11, 4.0);
            let enc = codec.encode(&h, &ctx);
            let dec = codec.decode(&enc, h.len(), &ctx);
            for (m, &d) in mean.iter_mut().zip(&dec) {
                *m += d as f64 / rounds as f64;
            }
        }
        let bias: f64 = h
            .iter()
            .zip(&mean)
            .map(|(&a, &b)| (a as f64 - b).powi(2))
            .sum::<f64>()
            / h.len() as f64;
        // Residual bias must be far below signal power (≈1.0).
        assert!(bias < 0.01, "bias^2 {bias}");
    }

    #[test]
    fn higher_rate_less_distortion() {
        let h = gaussian(8192, 83);
        let lo = measure_distortion(&Qsgd::default(), &h, 2.0, 5, 0).mse;
        let hi = measure_distortion(&Qsgd::default(), &h, 4.0, 5, 0).mse;
        assert!(hi < lo, "{hi} !< {lo}");
    }

    #[test]
    fn zero_vector_roundtrips() {
        let h = vec![0.0f32; 64];
        let codec = Qsgd::default();
        let ctx = CodecContext::new(0, 0, 1, 2.0);
        let enc = codec.encode(&h, &ctx);
        assert_eq!(codec.decode(&enc, 64, &ctx), h);
    }

    #[test]
    fn range_fallback_stream_decodes() {
        // Sub-1-bit budget on a mostly-zero vector forces the range-coded
        // wire format; the streaming decoder must read it.
        let mut rng = Xoshiro256pp::seed_from_u64(84);
        let h: Vec<f32> = (0..4096)
            .map(|_| if rng.uniform() < 0.005 { rng.normal_f32() } else { 0.0 })
            .collect();
        let codec = Qsgd::default();
        let ctx = CodecContext::new(0, 0, 7, 0.2);
        let enc = codec.encode(&h, &ctx);
        let mut r = BitReader::new(&enc.bytes);
        let _norm = r.read_f32();
        assert!(r.read_u32() & RANGE_CODED_FLAG != 0, "expected range fallback");
        let dec = codec.decode(&enc, h.len(), &ctx);
        assert_eq!(dec.len(), h.len());
        assert!(dec.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn uveqfed_l1_beats_qsgd() {
        // The paper's dither-subtraction claim: UVeQFed L=1 < QSGD
        // distortion at equal rate (§III-B, factor ≈ 2 from [30]).
        let mut dq = 0.0;
        let mut du = 0.0;
        for seed in 0..8 {
            let h = gaussian(8192, 300 + seed);
            dq += measure_distortion(&Qsgd::default(), &h, 2.0, seed, 0).mse;
            du += measure_distortion(&crate::quantizer::UVeQFed::scalar(), &h, 2.0, seed, 0).mse;
        }
        assert!(du < dq, "uveqfed-l1 {du} !< qsgd {dq}");
    }
}
