//! Straggler, dropout, and wire-corruption model.
//!
//! Every selected client gets a simulated uplink latency and a dropout
//! draw, both pure functions of `(root seed, client, round)` through the
//! shared randomness streams — fault injection is bit-reproducible and
//! independent of execution order. The server imposes a round deadline:
//! with over-selection it aggregates the first `target` arrivals and cuts
//! the rest, which is the K_a-active-devices-per-round regime the
//! partial-participation literature evaluates.
//!
//! [`WirePlan`] extends the model below the framing layer: each transmit
//! attempt may deterministically corrupt the encoded frame (bit flips,
//! truncation, trailing garbage, header tampering) with all draws taken
//! from the `(user, round, WireFault)` stream, so a corrupted round is as
//! bit-reproducible as a clean one and independent of worker/shard count.

use super::wire::{crc32, HEADER_BYTES, TRAILER_BYTES};
use crate::prng::{CommonRandomness, Rng, StreamKind};

/// Per-client latency distribution (virtual seconds — nothing sleeps).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LatencyModel {
    /// Every client at the same latency (0 = the seed's instant uplink).
    Fixed(f64),
    /// Uniform in `[lo, hi)`.
    Uniform { lo: f64, hi: f64 },
    /// `median · exp(σ·Z)` — the classic heavy-upper-tail straggler shape.
    LogNormal { median: f64, sigma: f64 },
    /// Exponential with the given mean.
    Exponential { mean: f64 },
}

impl Default for LatencyModel {
    fn default() -> Self {
        LatencyModel::Fixed(0.0)
    }
}

impl LatencyModel {
    pub fn sample<R: Rng>(&self, rng: &mut R) -> f64 {
        match *self {
            LatencyModel::Fixed(v) => v,
            LatencyModel::Uniform { lo, hi } => rng.uniform_range(lo, hi),
            LatencyModel::LogNormal { median, sigma } => median * (sigma * rng.normal()).exp(),
            LatencyModel::Exponential { mean } => {
                -mean * (1.0 - rng.uniform()).ln()
            }
        }
    }
}

/// What a selected client does this round.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ClientFate {
    /// Update lands at `latency` virtual seconds after broadcast.
    Arrives { latency: f64 },
    /// Would have landed after the deadline — the server never sees it.
    Late { latency: f64 },
    /// Crashed / lost connectivity; nothing is sent.
    Dropped,
    /// Every transmit attempt was corrupted (or the payload failed to
    /// decode); the partial contribution was discarded and the client
    /// quarantined for the round. `reason` names the terminal failure.
    Rejected { reason: &'static str },
}

/// Per-attempt wire corruption drawn from `StreamKind::WireFault`.
///
/// `corrupt_prob` gates each transmit attempt independently; a corrupted
/// attempt then draws one of five modes: single bit flip, burst of 2–8
/// bit flips, truncation, 1–4 trailing garbage bytes, or a phantom-bits
/// header tamper (the `bits` field inflated past the payload capacity and
/// the CRC restamped — exercising the post-CRC header validation).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct WirePlan {
    /// Probability each transmit attempt is corrupted, in `[0, 1]`.
    pub corrupt_prob: f64,
    /// Additional transmit attempts a rejected client may make before the
    /// server quarantines it for the round (0 = no retransmission).
    pub max_retries: u32,
}

impl WirePlan {
    /// No wire faults (the seed semantics).
    pub fn none() -> Self {
        Self::default()
    }

    /// Whether any attempt can corrupt.
    pub fn active(&self) -> bool {
        self.corrupt_prob > 0.0
    }

    /// Maybe corrupt one transmit attempt's frame in place. Draws come
    /// sequentially from `rng` (one `(user, round, WireFault)` stream per
    /// client-round, shared across that client's attempts), so the k-th
    /// attempt's corruption is a pure function of `(seed, user, round, k)`.
    /// Returns the number of frame bytes disturbed (0 = clean attempt).
    pub fn corrupt_attempt<R: Rng>(&self, rng: &mut R, frame: &mut Vec<u8>) -> usize {
        if self.corrupt_prob <= 0.0 || rng.uniform() >= self.corrupt_prob || frame.is_empty() {
            return 0;
        }
        match rng.gen_index(5) {
            0 => {
                // Single bit flip anywhere in the frame.
                let byte = rng.gen_index(frame.len());
                frame[byte] ^= 1 << rng.gen_index(8);
                1
            }
            1 => {
                // Burst: 2..=8 independent bit flips (may share a byte).
                let flips = 2 + rng.gen_index(7);
                for _ in 0..flips {
                    let byte = rng.gen_index(frame.len());
                    frame[byte] ^= 1 << rng.gen_index(8);
                }
                flips
            }
            2 => {
                // Truncation: keep a strict prefix (possibly empty).
                let keep = rng.gen_index(frame.len());
                let cut = frame.len() - keep;
                frame.truncate(keep);
                cut
            }
            3 => {
                // Trailing garbage: 1..=4 extra bytes past the trailer.
                let extra = 1 + rng.gen_index(4);
                for _ in 0..extra {
                    frame.push((rng.next_u64() & 0xFF) as u8);
                }
                extra
            }
            _ => {
                // Phantom bits: inflate the header's `bits` field past the
                // payload's capacity and restamp the CRC, so the frame
                // passes the checksum but fails semantic validation.
                if frame.len() < HEADER_BYTES + TRAILER_BYTES {
                    // Already-truncated frames can't be tampered coherently;
                    // flip a bit instead so the attempt still corrupts.
                    let byte = rng.gen_index(frame.len());
                    frame[byte] ^= 1 << rng.gen_index(8);
                    return 1;
                }
                let payload = frame.len() - HEADER_BYTES - TRAILER_BYTES;
                let phantom = 8 * payload as u64 + 1 + (rng.next_u64() & 0x3FF);
                frame[24..32].copy_from_slice(&phantom.to_le_bytes());
                let body = frame.len() - TRAILER_BYTES;
                let crc = crc32(&frame[..body]);
                frame[body..].copy_from_slice(&crc.to_le_bytes());
                8 + TRAILER_BYTES
            }
        }
    }
}

/// Fault-injection plan for a scenario.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FaultPlan {
    pub latency: LatencyModel,
    /// Per-client per-round dropout probability in `[0, 1]`.
    pub dropout: f64,
    /// Round deadline in virtual seconds (`None` = wait for everyone).
    pub deadline: Option<f64>,
    /// Frame-level corruption and retransmission policy.
    pub wire: WirePlan,
}

impl FaultPlan {
    /// No faults: everyone arrives instantly (the seed semantics).
    pub fn none() -> Self {
        Self::default()
    }

    /// Fate of `(user, round)` — deterministic given the shared seed.
    pub fn fate(&self, crand: &CommonRandomness, user: u64, round: u64) -> ClientFate {
        if self.dropout > 0.0 {
            let mut drng = crand.stream(user, round, StreamKind::Dropout);
            if drng.uniform() < self.dropout {
                return ClientFate::Dropped;
            }
        }
        let latency = match self.latency {
            LatencyModel::Fixed(v) => v,
            model => {
                let mut lrng = crand.stream(user, round, StreamKind::Latency);
                model.sample(&mut lrng)
            }
        };
        match self.deadline {
            Some(d) if latency > d => ClientFate::Late { latency },
            _ => ClientFate::Arrives { latency },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Xoshiro256pp;

    #[test]
    fn fate_is_deterministic_and_varies_by_client_and_round() {
        let cr = CommonRandomness::new(42);
        let plan = FaultPlan {
            latency: LatencyModel::LogNormal { median: 1.0, sigma: 0.8 },
            dropout: 0.3,
            deadline: Some(2.0),
            wire: WirePlan::none(),
        };
        let a = plan.fate(&cr, 5, 9);
        assert_eq!(a, plan.fate(&cr, 5, 9), "fate must be reproducible");
        let distinct = (0..200)
            .map(|u| plan.fate(&cr, u, 0))
            .collect::<Vec<_>>();
        let arrived = distinct.iter().filter(|f| matches!(f, ClientFate::Arrives { .. })).count();
        let dropped = distinct.iter().filter(|f| matches!(f, ClientFate::Dropped)).count();
        let late = distinct.iter().filter(|f| matches!(f, ClientFate::Late { .. })).count();
        assert!(arrived > 0 && dropped > 0 && late > 0, "{arrived}/{dropped}/{late}");
        assert_eq!(arrived + dropped + late, 200);
    }

    #[test]
    fn no_faults_means_everyone_arrives_instantly() {
        let cr = CommonRandomness::new(1);
        for u in 0..50 {
            assert_eq!(
                FaultPlan::none().fate(&cr, u, 3),
                ClientFate::Arrives { latency: 0.0 }
            );
        }
    }

    #[test]
    fn latency_models_are_positive_and_shaped() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let n = 20_000;
        let exp = LatencyModel::Exponential { mean: 2.0 };
        let mean: f64 = (0..n).map(|_| exp.sample(&mut rng)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "exponential mean {mean}");

        let lognormal = LatencyModel::LogNormal { median: 1.0, sigma: 0.5 };
        let mut med: Vec<f64> = (0..n).map(|_| lognormal.sample(&mut rng)).collect();
        med.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = med[n / 2];
        assert!((median - 1.0).abs() < 0.05, "lognormal median {median}");
        assert!(med.iter().all(|&v| v > 0.0));

        let uni = LatencyModel::Uniform { lo: 1.0, hi: 3.0 };
        for _ in 0..1000 {
            let v = uni.sample(&mut rng);
            assert!((1.0..3.0).contains(&v));
        }
    }

    #[test]
    fn deadline_partitions_arrivals() {
        let cr = CommonRandomness::new(9);
        let plan = FaultPlan {
            latency: LatencyModel::Uniform { lo: 0.0, hi: 10.0 },
            dropout: 0.0,
            deadline: Some(5.0),
            wire: WirePlan::none(),
        };
        for u in 0..500 {
            match plan.fate(&cr, u, 0) {
                ClientFate::Arrives { latency } => assert!(latency <= 5.0),
                ClientFate::Late { latency } => assert!(latency > 5.0),
                other => panic!("dropout and wire faults disabled: {other:?}"),
            }
        }
    }

    #[test]
    fn wire_corruption_is_deterministic_per_attempt_sequence() {
        let cr = CommonRandomness::new(77);
        let plan = WirePlan { corrupt_prob: 0.6, max_retries: 2 };
        let pristine: Vec<u8> = (0..120u8).collect();
        let run = || {
            let mut rng = cr.stream(4, 9, StreamKind::WireFault);
            (0..5)
                .map(|_| {
                    let mut f = pristine.clone();
                    let disturbed = plan.corrupt_attempt(&mut rng, &mut f);
                    (disturbed, f)
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run(), "attempt sequence must be bit-reproducible");
    }

    #[test]
    fn wire_corruption_covers_all_modes_and_respects_gate() {
        let cr = CommonRandomness::new(31);
        let always = WirePlan { corrupt_prob: 1.0, max_retries: 0 };
        let pristine: Vec<u8> = (0..80u8).collect();
        let mut shorter = false;
        let mut longer = false;
        let mut same_len_changed = false;
        for user in 0..200 {
            let mut rng = cr.stream(user, 0, StreamKind::WireFault);
            let mut f = pristine.clone();
            let disturbed = always.corrupt_attempt(&mut rng, &mut f);
            assert!(disturbed > 0, "corrupt_prob 1.0 must disturb every attempt");
            match f.len().cmp(&pristine.len()) {
                std::cmp::Ordering::Less => shorter = true,
                std::cmp::Ordering::Greater => longer = true,
                std::cmp::Ordering::Equal => {
                    assert_ne!(f, pristine, "same-length attempt must alter bytes");
                    same_len_changed = true;
                }
            }
        }
        assert!(shorter && longer && same_len_changed, "all mode families must occur");

        let never = WirePlan::none();
        let mut rng = cr.stream(0, 0, StreamKind::WireFault);
        let mut f = pristine.clone();
        assert_eq!(never.corrupt_attempt(&mut rng, &mut f), 0);
        assert_eq!(f, pristine, "inactive plan must pass frames through");
    }

    #[test]
    fn phantom_tamper_keeps_crc_valid_but_inflates_bits() {
        // Force mode 4 by scanning users until the tampered frame keeps
        // its length and has a valid restamped CRC over the body.
        let cr = CommonRandomness::new(12);
        let plan = WirePlan { corrupt_prob: 1.0, max_retries: 0 };
        let pristine = vec![0u8; HEADER_BYTES + 16 + TRAILER_BYTES];
        let mut seen_phantom = false;
        for user in 0..400 {
            let mut rng = cr.stream(user, 1, StreamKind::WireFault);
            let mut f = pristine.clone();
            plan.corrupt_attempt(&mut rng, &mut f);
            if f.len() != pristine.len() {
                continue;
            }
            let body = f.len() - TRAILER_BYTES;
            let crc = u32::from_le_bytes(f[body..].try_into().unwrap());
            if crc == crc32(&f[..body]) && f[24..32] != pristine[24..32] {
                let bits = u64::from_le_bytes(f[24..32].try_into().unwrap());
                assert!(bits > 8 * 16, "tampered bits {bits} must exceed capacity");
                seen_phantom = true;
                break;
            }
        }
        assert!(seen_phantom, "phantom-bits mode never drawn in 400 streams");
    }
}
