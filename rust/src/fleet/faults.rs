//! Straggler and dropout model.
//!
//! Every selected client gets a simulated uplink latency and a dropout
//! draw, both pure functions of `(root seed, client, round)` through the
//! shared randomness streams — fault injection is bit-reproducible and
//! independent of execution order. The server imposes a round deadline:
//! with over-selection it aggregates the first `target` arrivals and cuts
//! the rest, which is the K_a-active-devices-per-round regime the
//! partial-participation literature evaluates.

use crate::prng::{CommonRandomness, Rng, StreamKind};

/// Per-client latency distribution (virtual seconds — nothing sleeps).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LatencyModel {
    /// Every client at the same latency (0 = the seed's instant uplink).
    Fixed(f64),
    /// Uniform in `[lo, hi)`.
    Uniform { lo: f64, hi: f64 },
    /// `median · exp(σ·Z)` — the classic heavy-upper-tail straggler shape.
    LogNormal { median: f64, sigma: f64 },
    /// Exponential with the given mean.
    Exponential { mean: f64 },
}

impl Default for LatencyModel {
    fn default() -> Self {
        LatencyModel::Fixed(0.0)
    }
}

impl LatencyModel {
    pub fn sample<R: Rng>(&self, rng: &mut R) -> f64 {
        match *self {
            LatencyModel::Fixed(v) => v,
            LatencyModel::Uniform { lo, hi } => rng.uniform_range(lo, hi),
            LatencyModel::LogNormal { median, sigma } => median * (sigma * rng.normal()).exp(),
            LatencyModel::Exponential { mean } => {
                -mean * (1.0 - rng.uniform()).ln()
            }
        }
    }
}

/// What a selected client does this round.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ClientFate {
    /// Update lands at `latency` virtual seconds after broadcast.
    Arrives { latency: f64 },
    /// Would have landed after the deadline — the server never sees it.
    Late { latency: f64 },
    /// Crashed / lost connectivity; nothing is sent.
    Dropped,
}

/// Fault-injection plan for a scenario.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FaultPlan {
    pub latency: LatencyModel,
    /// Per-client per-round dropout probability in `[0, 1]`.
    pub dropout: f64,
    /// Round deadline in virtual seconds (`None` = wait for everyone).
    pub deadline: Option<f64>,
}

impl FaultPlan {
    /// No faults: everyone arrives instantly (the seed semantics).
    pub fn none() -> Self {
        Self::default()
    }

    /// Fate of `(user, round)` — deterministic given the shared seed.
    pub fn fate(&self, crand: &CommonRandomness, user: u64, round: u64) -> ClientFate {
        if self.dropout > 0.0 {
            let mut drng = crand.stream(user, round, StreamKind::Dropout);
            if drng.uniform() < self.dropout {
                return ClientFate::Dropped;
            }
        }
        let latency = match self.latency {
            LatencyModel::Fixed(v) => v,
            model => {
                let mut lrng = crand.stream(user, round, StreamKind::Latency);
                model.sample(&mut lrng)
            }
        };
        match self.deadline {
            Some(d) if latency > d => ClientFate::Late { latency },
            _ => ClientFate::Arrives { latency },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Xoshiro256pp;

    #[test]
    fn fate_is_deterministic_and_varies_by_client_and_round() {
        let cr = CommonRandomness::new(42);
        let plan = FaultPlan {
            latency: LatencyModel::LogNormal { median: 1.0, sigma: 0.8 },
            dropout: 0.3,
            deadline: Some(2.0),
        };
        let a = plan.fate(&cr, 5, 9);
        assert_eq!(a, plan.fate(&cr, 5, 9), "fate must be reproducible");
        let distinct = (0..200)
            .map(|u| plan.fate(&cr, u, 0))
            .collect::<Vec<_>>();
        let arrived = distinct.iter().filter(|f| matches!(f, ClientFate::Arrives { .. })).count();
        let dropped = distinct.iter().filter(|f| matches!(f, ClientFate::Dropped)).count();
        let late = distinct.iter().filter(|f| matches!(f, ClientFate::Late { .. })).count();
        assert!(arrived > 0 && dropped > 0 && late > 0, "{arrived}/{dropped}/{late}");
        assert_eq!(arrived + dropped + late, 200);
    }

    #[test]
    fn no_faults_means_everyone_arrives_instantly() {
        let cr = CommonRandomness::new(1);
        for u in 0..50 {
            assert_eq!(
                FaultPlan::none().fate(&cr, u, 3),
                ClientFate::Arrives { latency: 0.0 }
            );
        }
    }

    #[test]
    fn latency_models_are_positive_and_shaped() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let n = 20_000;
        let exp = LatencyModel::Exponential { mean: 2.0 };
        let mean: f64 = (0..n).map(|_| exp.sample(&mut rng)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "exponential mean {mean}");

        let lognormal = LatencyModel::LogNormal { median: 1.0, sigma: 0.5 };
        let mut med: Vec<f64> = (0..n).map(|_| lognormal.sample(&mut rng)).collect();
        med.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = med[n / 2];
        assert!((median - 1.0).abs() < 0.05, "lognormal median {median}");
        assert!(med.iter().all(|&v| v > 0.0));

        let uni = LatencyModel::Uniform { lo: 1.0, hi: 3.0 };
        for _ in 0..1000 {
            let v = uni.sample(&mut rng);
            assert!((1.0..3.0).contains(&v));
        }
    }

    #[test]
    fn deadline_partitions_arrivals() {
        let cr = CommonRandomness::new(9);
        let plan = FaultPlan {
            latency: LatencyModel::Uniform { lo: 0.0, hi: 10.0 },
            dropout: 0.0,
            deadline: Some(5.0),
        };
        for u in 0..500 {
            match plan.fate(&cr, u, 0) {
                ClientFate::Arrives { latency } => assert!(latency <= 5.0),
                ClientFate::Late { latency } => assert!(latency > 5.0),
                ClientFate::Dropped => panic!("dropout disabled"),
            }
        }
    }
}
