//! Server-side aggregation shards: the leaf level of the deterministic
//! two-level merge that [`super::FleetDriver::run_round`] runs the fold
//! on.
//!
//! Topology (client-partition, justified in DESIGN.md §11): arrival `i`
//! of a round is owned by shard `i % n_shards`. Each shard owns a full
//! pair of fixed-point [`StreamingAggregator`]s (the quantized aggregate
//! and the "desired" unquantized reference) and folds whole client
//! streams — decode and fold interleave chunk-by-chunk on the shard
//! thread, so at most one `DEFAULT_CHUNK` of decoded entries is ever
//! buffered per shard. The coordinator feeds shards through bounded
//! [`std::sync::mpsc::sync_channel`]s of depth [`QUEUE_DEPTH`]
//! (backpressure, never unbounded buffering) and, after dropping the
//! senders, joins and merges the partials **in ascending shard order**.
//! Because the accumulators are integer (i128) fixed-point, the merged
//! model is bit-identical for any shard count and any worker/channel
//! interleaving.

use std::sync::mpsc::Receiver;

use crate::metrics::Timer;
use crate::quantizer::{CodecContext, Encoded, UpdateCodec};
use crate::telemetry::{Collector, HistMetric, SpanData, SpanEvent, SpanKind};

use super::aggregate::StreamingAggregator;

/// Hard upper bound on `FleetDriver::with_shards`; also baked into the
/// `telemetry::Collector::for_cohort` ring-sizing formula so a maximally
/// sharded traced round can never drop its per-shard fold spans.
pub const MAX_SHARDS: usize = 64;

/// Bounded depth of each coordinator→shard hand-off channel. Small on
/// purpose: in-flight memory is `shards · (QUEUE_DEPTH + 1)` undecoded
/// frames (+ their reference updates), and a slow shard back-pressures
/// the coordinator — which stops draining the worker channel — instead
/// of buffering without bound.
pub const QUEUE_DEPTH: usize = 4;

/// Per-shard fold statistics for one round, always collected (tracing or
/// not) so the scale benches can report decode-vs-fold overlap at
/// populations where a traced event ring would be infeasible.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ShardRoundStats {
    /// Shard index in `0..n_shards` (also the merge position).
    pub shard: usize,
    /// Client streams folded by this shard.
    pub folds: usize,
    /// Decoded chunks folded.
    pub chunks: u64,
    /// Entries folded (`folds · m` when every stream completes).
    pub entries: u64,
    /// Seconds spent pulling chunks out of decode streams.
    pub decode_secs: f64,
    /// Seconds spent in `fold_chunk`/`commit`.
    pub fold_secs: f64,
    /// Total seconds this shard spent processing jobs (decode + fold +
    /// reference-update metering); `Σ busy_secs / round wall` is the
    /// pipeline-overlap factor the §F bench reports.
    pub busy_secs: f64,
}

/// One admitted uplink message, handed from the coordinator to its
/// owning shard. Carries everything the shard needs to rebuild the
/// decoder context deterministically plus the client's raw update `h`
/// for the "desired" (unquantized) reference aggregate.
pub(crate) struct ShardJob {
    pub user: u64,
    pub round: u64,
    /// The rate the controller assigned this client — the decoder must
    /// see the same budget the encoder did.
    pub rate: f64,
    /// Re-normalized aggregation weight.
    pub alpha: f64,
    /// Virtual-time arrival instant (stamped on decode/fold spans).
    pub virt_s: f64,
    pub payload: Encoded,
    pub h: Vec<f32>,
}

/// What a shard thread returns when its channel closes.
pub(crate) struct ShardOutcome {
    pub agg: StreamingAggregator,
    pub desired: StreamingAggregator,
    pub stats: ShardRoundStats,
    /// Wall instant the shard started (0 when untraced) — the start of
    /// its round-scoped `shard_fold` span.
    pub wall_start_s: f64,
}

/// Drain `rx` until every sender is dropped, folding each job into this
/// shard's fixed-point partials.
///
/// The chunk loop is the same `next_chunk → fold_chunk → … → commit`
/// sequence as `StreamingAggregator::fold_stream`, so the arithmetic is
/// bit-identical to the pre-shard serial fold; the per-chunk timers only
/// observe. Per-client `decode`/`fold` spans (shard-tagged) are recorded
/// only when tracing; the coarse [`ShardRoundStats`] are always kept.
pub(crate) fn run_shard(
    shard: u32,
    m: usize,
    seed: u64,
    codec: &dyn UpdateCodec,
    tel: Option<&Collector>,
    rx: Receiver<ShardJob>,
) -> ShardOutcome {
    let mut agg = StreamingAggregator::new(m);
    let mut desired = StreamingAggregator::new(m);
    let mut stats = ShardRoundStats { shard: shard as usize, ..Default::default() };
    let wall_start_s = tel.map(|c| c.wall_now()).unwrap_or(0.0);
    while let Ok(job) = rx.recv() {
        let t_job = Timer::start();
        let ctx = CodecContext::new(job.user, job.round, seed, job.rate);
        let mut stream = codec.decoder(&job.payload, m, &ctx);
        let stream = stream.as_mut();
        let dec_start = tel.map(|c| c.wall_now()).unwrap_or(0.0);
        let mut fold_start = dec_start;
        let mut dec_secs = 0.0f64;
        let mut fold_secs = 0.0f64;
        let mut offset = 0usize;
        let mut chunks = 0u32;
        loop {
            let t_dec = Timer::start();
            let Some(chunk) = stream.next_chunk() else {
                break;
            };
            dec_secs += t_dec.elapsed_secs();
            if chunks == 0 {
                if let Some(c) = tel {
                    fold_start = c.wall_now();
                }
            }
            let t_fold = Timer::start();
            agg.fold_chunk(offset, job.alpha, chunk);
            let dt = t_fold.elapsed_secs();
            fold_secs += dt;
            if let Some(c) = tel {
                c.record_hist(HistMetric::FoldChunkNanos, (dt * 1e9) as u64);
            }
            offset += chunk.len();
            chunks += 1;
        }
        assert_eq!(offset, m, "decode stream yielded {offset} of {m} entries");
        let t_commit = Timer::start();
        agg.commit(job.alpha);
        fold_secs += t_commit.elapsed_secs();
        if let Some(c) = tel {
            c.record(SpanEvent {
                kind: SpanKind::Decode,
                round: job.round,
                user: job.user,
                wall_start_s: dec_start,
                wall_dur_s: dec_secs,
                virt_s: job.virt_s,
                data: SpanData::Decode { chunks, entries: offset as u64, shard },
            });
            c.record(SpanEvent {
                kind: SpanKind::Fold,
                round: job.round,
                user: job.user,
                wall_start_s: fold_start,
                wall_dur_s: fold_secs,
                virt_s: job.virt_s,
                data: SpanData::Fold { chunks, entries: offset as u64, alpha: job.alpha, shard },
            });
        }
        desired.fold(job.alpha, &job.h);
        stats.folds += 1;
        stats.chunks += u64::from(chunks);
        stats.entries += offset as u64;
        stats.decode_secs += dec_secs;
        stats.fold_secs += fold_secs;
        stats.busy_secs += t_job.elapsed_secs();
    }
    ShardOutcome { agg, desired, stats, wall_start_s }
}
