//! Server-side aggregation shards: the leaf level of the deterministic
//! two-level merge that [`super::FleetDriver::run_round`] runs the fold
//! on.
//!
//! Topology (client-partition, justified in DESIGN.md §11): arrival `i`
//! of a round is owned by shard `i % n_shards`. Each shard owns a full
//! pair of fixed-point [`StreamingAggregator`]s (the quantized aggregate
//! and the "desired" unquantized reference) and folds whole client
//! streams — each stream is **staged** into a reusable per-shard `m`-entry
//! scratch vector and folded only after it decodes completely, so a
//! mid-stream decode failure (CRC-valid but semantically corrupt payload)
//! rejects the client without ever touching the accumulators — no
//! rollback, and the merged model stays bit-identical to the serial fold
//! because per-entry fixed-point folds are chunking-independent. The
//! staging vector (4·m bytes) is dominated by the shard's own aggregator
//! pair (32·m bytes), so per-shard memory stays O(m). Decode panics are
//! contained with `catch_unwind` and surface as rejects too — a hostile
//! payload can quarantine one client, never a shard thread.
//! The coordinator feeds shards through bounded
//! [`std::sync::mpsc::sync_channel`]s of depth [`QUEUE_DEPTH`]
//! (backpressure, never unbounded buffering) and, after dropping the
//! senders, joins and merges the partials **in ascending shard order**.
//! Because the accumulators are integer (i128) fixed-point, the merged
//! model is bit-identical for any shard count and any worker/channel
//! interleaving.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::Receiver;

use crate::metrics::Timer;
use crate::quantizer::{CodecContext, DecodeBudget, DecodeError, Encoded, UpdateCodec};
use crate::telemetry::{probe, Collector, HistMetric, SpanData, SpanEvent, SpanKind};

use super::aggregate::StreamingAggregator;

/// Hard upper bound on `FleetDriver::with_shards`; also baked into the
/// `telemetry::Collector::for_cohort` ring-sizing formula so a maximally
/// sharded traced round can never drop its per-shard fold spans.
pub const MAX_SHARDS: usize = 64;

/// Bounded depth of each coordinator→shard hand-off channel. Small on
/// purpose: in-flight memory is `shards · (QUEUE_DEPTH + 1)` undecoded
/// frames (+ their reference updates), and a slow shard back-pressures
/// the coordinator — which stops draining the worker channel — instead
/// of buffering without bound.
pub const QUEUE_DEPTH: usize = 4;

/// Per-shard fold statistics for one round, always collected (tracing or
/// not) so the scale benches can report decode-vs-fold overlap at
/// populations where a traced event ring would be infeasible.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ShardRoundStats {
    /// Shard index in `0..n_shards` (also the merge position).
    pub shard: usize,
    /// Client streams folded by this shard.
    pub folds: usize,
    /// Decoded chunks folded.
    pub chunks: u64,
    /// Entries folded (`folds · m` when every stream completes).
    pub entries: u64,
    /// Seconds spent pulling chunks out of decode streams.
    pub decode_secs: f64,
    /// Seconds spent in `fold_chunk`/`commit`.
    pub fold_secs: f64,
    /// Total seconds this shard spent processing jobs (decode + fold +
    /// reference-update metering); `Σ busy_secs / round wall` is the
    /// pipeline-overlap factor the §F bench reports.
    pub busy_secs: f64,
}

/// One admitted uplink message, handed from the coordinator to its
/// owning shard. Carries everything the shard needs to rebuild the
/// decoder context deterministically plus the client's raw update `h`
/// for the "desired" (unquantized) reference aggregate.
pub(crate) struct ShardJob {
    /// Arrival index within the round's client arrays — the coordinator
    /// uses it to patch `folded`/bit accounting if the shard rejects.
    pub arrival: usize,
    pub user: u64,
    pub round: u64,
    /// The rate the controller assigned this client — the decoder must
    /// see the same budget the encoder did.
    pub rate: f64,
    /// Re-normalized aggregation weight.
    pub alpha: f64,
    /// Virtual-time arrival instant (stamped on decode/fold spans).
    pub virt_s: f64,
    pub payload: Encoded,
    pub h: Vec<f32>,
}

/// A client whose CRC-valid payload failed to decode on the shard (or
/// whose decoder panicked). The contribution never touched the
/// accumulators; the coordinator patches round accounting from this.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct ShardReject {
    pub arrival: usize,
    pub user: u64,
    pub reason: &'static str,
}

/// What a shard thread returns when its channel closes.
pub(crate) struct ShardOutcome {
    pub agg: StreamingAggregator,
    pub desired: StreamingAggregator,
    pub stats: ShardRoundStats,
    /// Wall instant the shard started (0 when untraced) — the start of
    /// its round-scoped `shard_fold` span.
    pub wall_start_s: f64,
    /// Clients rejected at decode time, in this shard's arrival order.
    pub rejects: Vec<ShardReject>,
}

/// Decode one payload completely into `staging` (cleared first), chunk by
/// chunk. Returns the chunk count on success; a typed error on a corrupt
/// or wrong-length stream. Never touches the aggregators.
fn stage_decode(
    codec: &dyn UpdateCodec,
    payload: &Encoded,
    m: usize,
    ctx: &CodecContext,
    staging: &mut Vec<f32>,
) -> Result<u32, DecodeError> {
    staging.clear();
    let mut stream = codec.decoder(payload, m, ctx);
    let mut chunks = 0u32;
    while let Some(chunk) = stream.next_chunk()? {
        if staging.len() + chunk.len() > m {
            return Err(DecodeError::Length { got: staging.len() + chunk.len(), want: m });
        }
        staging.extend_from_slice(chunk);
        chunks += 1;
    }
    if staging.len() != m {
        return Err(DecodeError::Length { got: staging.len(), want: m });
    }
    Ok(chunks)
}

/// Drain `rx` until every sender is dropped, folding each job into this
/// shard's fixed-point partials.
///
/// Each job stages its full decode first and folds only on success, so
/// the arithmetic is bit-identical to the pre-shard serial fold (per-entry
/// fixed-point folds are chunking-independent) and a failed decode leaves
/// the partials untouched. Decode panics are contained per job. Per-client
/// `decode`/`fold` spans (shard-tagged) are recorded only when tracing;
/// the coarse [`ShardRoundStats`] are always kept.
pub(crate) fn run_shard(
    shard: u32,
    m: usize,
    seed: u64,
    codec: &dyn UpdateCodec,
    decode_budget: DecodeBudget,
    tel: Option<&Collector>,
    rx: Receiver<ShardJob>,
) -> ShardOutcome {
    let mut agg = StreamingAggregator::new(m);
    let mut desired = StreamingAggregator::new(m);
    let mut stats = ShardRoundStats { shard: shard as usize, ..Default::default() };
    let mut rejects = Vec::new();
    let mut staging: Vec<f32> = Vec::with_capacity(m);
    let wall_start_s = tel.map(|c| c.wall_now()).unwrap_or(0.0);
    while let Ok(job) = rx.recv() {
        let t_job = Timer::start();
        let ctx = CodecContext::new(job.user, job.round, seed, job.rate)
            .with_decode_budget(decode_budget);
        let dec_start = tel.map(|c| c.wall_now()).unwrap_or(0.0);
        // Bracket the decode with the thread-local probe (same contract
        // as the worker's encode bracketing) so solver iterations land on
        // this client's decode span.
        if tel.is_some() {
            probe::reset();
        }
        let t_dec = Timer::start();
        let staged = catch_unwind(AssertUnwindSafe(|| {
            stage_decode(codec, &job.payload, m, &ctx, &mut staging)
        }));
        let dec_secs = t_dec.elapsed_secs();
        let solver_iters = if tel.is_some() { probe::take().solver_iters } else { 0 };
        let chunks = match staged {
            Ok(Ok(chunks)) => chunks,
            Ok(Err(err)) => {
                rejects.push(ShardReject {
                    arrival: job.arrival,
                    user: job.user,
                    reason: err.reason(),
                });
                stats.busy_secs += t_job.elapsed_secs();
                continue;
            }
            Err(_panic) => {
                rejects.push(ShardReject {
                    arrival: job.arrival,
                    user: job.user,
                    reason: "decoder panicked",
                });
                stats.busy_secs += t_job.elapsed_secs();
                continue;
            }
        };
        let fold_start = tel.map(|c| c.wall_now()).unwrap_or(0.0);
        let t_fold = Timer::start();
        agg.fold_chunk(0, job.alpha, &staging);
        agg.commit(job.alpha);
        let fold_secs = t_fold.elapsed_secs();
        if let Some(c) = tel {
            c.record_hist(HistMetric::FoldChunkNanos, (fold_secs * 1e9) as u64);
            c.record(SpanEvent {
                kind: SpanKind::Decode,
                round: job.round,
                user: job.user,
                wall_start_s: dec_start,
                wall_dur_s: dec_secs,
                virt_s: job.virt_s,
                data: SpanData::Decode { chunks, entries: m as u64, shard, solver_iters },
            });
            c.record(SpanEvent {
                kind: SpanKind::Fold,
                round: job.round,
                user: job.user,
                wall_start_s: fold_start,
                wall_dur_s: fold_secs,
                virt_s: job.virt_s,
                data: SpanData::Fold { chunks, entries: m as u64, alpha: job.alpha, shard },
            });
        }
        desired.fold(job.alpha, &job.h);
        stats.folds += 1;
        stats.chunks += u64::from(chunks);
        stats.entries += m as u64;
        stats.decode_secs += dec_secs;
        stats.fold_secs += fold_secs;
        stats.busy_secs += t_job.elapsed_secs();
    }
    ShardOutcome { agg, desired, stats, wall_start_s, rejects }
}
