//! Fleet-scale federated simulation: partial participation, stragglers,
//! wire-framed uplink, and streaming O(m) aggregation.
//!
//! The paper's experiments (and the seed `coordinator::RoundDriver`)
//! assume all K users participate in every round and the server buffers
//! every decoded update — fine for K ≤ 100, fatal for populations in the
//! millions. This subsystem simulates rounds over an arbitrarily large
//! client population:
//!
//! * [`sampler`] — per-round cohort selection (uniform without
//!   replacement, shard-size-weighted, fixed roster, or full
//!   participation), deterministic from `(seed, round)`;
//! * [`faults`] — per-client latency + dropout with a round deadline and
//!   over-selection: the server aggregates the first `target` arrivals
//!   and reports completion rate and effective α mass; a [`WirePlan`]
//!   additionally injects deterministic frame corruption with bounded
//!   retransmission, and clients whose frames never survive the wire (or
//!   whose CRC-valid payloads fail shard decode) are quarantined as
//!   [`ClientFate::Rejected`] — partial contributions discarded, α
//!   re-normalized over the folded set (DESIGN.md §13);
//! * [`wire`] — framed binary uplink messages (header, exact bit count,
//!   CRC), so the channel meters real serialized bytes;
//! * [`aggregate`] — order-independent fixed-point streaming fold of
//!   `Σ α_k ĥ_k`, O(m) server memory regardless of cohort size;
//! * [`shard`] — N-way sharded server fold: arrivals are partitioned by
//!   `arrival_index % shards` onto dedicated decode+fold threads behind
//!   bounded channels, and the fixed-point partials merge in ascending
//!   shard order — bit-identical for any shard count (DESIGN.md §11);
//! * [`clock`] — virtual time: latency statistics without sleeping.
//!
//! `coordinator::RoundDriver` now runs on top of this layer with
//! [`Scenario::full`] (full participation is the degenerate preset), so
//! the paper experiments and the fleet simulations share one code path.
//! Rounds are described by a [`RoundSpec`] (schedule position + trainer +
//! codec + local-SGD hyperparameters); on the uplink the driver speaks
//! the codec **session** API — clients push tensor chunks through an
//! `EncodeSink`, and the server folds `DecodeStream` chunks straight into
//! the fixed-point aggregator without materializing per-user vectors.
//!
//! Aggregation weights: per round, the α of the clients whose updates are
//! actually folded are re-normalized to sum to exactly one (FedAvg over
//! the participating set); `alpha_mass` reports how much of the selected
//! cohort's weight made it before the deadline.

pub mod aggregate;
pub mod channel;
pub mod clock;
pub mod downlink;
pub mod faults;
pub mod sampler;
pub mod shard;
pub mod wire;

pub use aggregate::StreamingAggregator;
pub use channel::{AsymmetricChannel, Channel, ChannelModel};
pub use clock::{RoundTiming, VirtualClock};
pub use downlink::{BroadcastOutcome, DownlinkSpec, SyncTable};
pub use faults::{ClientFate, FaultPlan, LatencyModel, WirePlan};
pub use sampler::{CohortSampler, SamplerKind};
pub use shard::{ShardRoundStats, MAX_SHARDS};
pub use wire::{decode_frame, encode_frame, Frame, FrameKind, WireError};

use crate::coordinator::broadcast::BroadcastPlanner;
use crate::coordinator::rate_control::{AllocRequest, RateController};
use crate::coordinator::UplinkChannel;
use crate::data::Dataset;
use crate::fl::Trainer;
use crate::metrics::Timer;
use std::sync::atomic::{AtomicBool, Ordering};

use crate::prng::{CommonRandomness, SplitMix64, StreamKind};
use crate::quantizer::{self, CodecContext, DecodeBudget, UpdateCodec, DEFAULT_CHUNK};
use crate::telemetry::{probe, Collector, HistMetric, SpanData, SpanEvent, SpanKind};
use crate::util::threadpool::parallel_map_fold;

/// One-time (process-wide) latch for the "a buffered encode session held
/// more than 1 MiB" telemetry counter — the counter fires at most once
/// per process, so traced large-model runs get exactly one marker instead
/// of one per client encode.
static ENCODE_STATE_OVER_1MIB: AtomicBool = AtomicBool::new(false);

/// Everything one round needs beyond the mutable state (`w`, the pool and
/// the clock): the schedule position plus the client-side algorithm —
/// trainer, codec, and the local-SGD hyperparameters. Collapses the old
/// nine-positional-argument `run_round` plumbing shared by
/// `coordinator::RoundDriver`, [`FleetDriver`] and `fl::run_federated`.
#[derive(Clone, Copy)]
pub struct RoundSpec<'a> {
    /// Round index `t/τ` — drives cohort selection, dither and fault
    /// streams.
    pub round: u64,
    /// τ — local SGD steps per selected client.
    pub local_steps: usize,
    /// Learning rate applied during this round's local steps.
    pub lr: f32,
    /// Mini-batch size per local step (0 = full-batch GD).
    pub batch_size: usize,
    pub trainer: &'a dyn Trainer,
    pub codec: &'a dyn UpdateCodec,
    /// Per-round budget override (bits/entry): replaces the driver's base
    /// rate for this round only — every variable-rate codec sees it
    /// through `CodecContext::rate` (rate schedules, warm-up rounds). A
    /// `RatePlan` on the driver further splits this mass per client.
    pub rate_override: Option<f64>,
    /// Opt-in round-lifecycle tracing: when set (and the collector is
    /// enabled), the driver records per-client `client_train` / `encode` /
    /// `transmit` / `decode` / `fold` spans plus a round-scoped
    /// `rate_alloc` span into it. `None` (or a disabled collector) keeps
    /// the untraced hot path byte-for-byte identical.
    pub telemetry: Option<&'a Collector>,
    /// How many per-client [`ClientRoundRecord`]s the report keeps —
    /// `Full` is O(cohort) memory (~1M records at north-star scale), so
    /// million-client rounds should cap or drop them; the exact count
    /// always survives in [`FleetRoundReport::clients_total`].
    pub client_records: ClientRecords,
    /// Downlink broadcast: when set, every arrival receives a coded
    /// global-model delta (or a full resync) *before* local training and
    /// trains on its own reconstruction — see [`downlink`]. `None` keeps
    /// the classic perfect-downlink round byte-for-byte identical.
    pub downlink: Option<DownlinkSpec<'a>>,
}

/// Per-client record retention policy for [`FleetRoundReport::clients`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClientRecords {
    /// One record per selected client (the default; backward compatible).
    Full,
    /// Keep at most `n` records, chosen by a deterministic stride over
    /// the selected cohort (every `⌈selected/n⌉`-th client, ascending
    /// id) — a representative, reproducible sample. `Capped(0)` keeps
    /// none. `FleetRoundReport::clients_total` still reports the exact
    /// selected count.
    Capped(usize),
}

impl<'a> RoundSpec<'a> {
    /// Spec with the driver's base rate (no per-round override).
    pub fn new(
        round: u64,
        local_steps: usize,
        lr: f32,
        batch_size: usize,
        trainer: &'a dyn Trainer,
        codec: &'a dyn UpdateCodec,
    ) -> Self {
        Self {
            round,
            local_steps,
            lr,
            batch_size,
            trainer,
            codec,
            rate_override: None,
            telemetry: None,
            client_records: ClientRecords::Full,
            downlink: None,
        }
    }

    /// Override this round's rate budget (bits/entry).
    pub fn with_rate(mut self, rate: f64) -> Self {
        self.rate_override = Some(rate);
        self
    }

    /// Record this round's lifecycle spans into `collector`.
    pub fn with_telemetry(mut self, collector: &'a Collector) -> Self {
        self.telemetry = Some(collector);
        self
    }

    /// Choose how many per-client records the round report retains.
    pub fn with_client_records(mut self, records: ClientRecords) -> Self {
        self.client_records = records;
        self
    }

    /// Broadcast the global model to this round's arrivals through `dl`
    /// (coded downlink with per-client stale references and error
    /// feedback) instead of handing them `w` verbatim.
    pub fn with_downlink(mut self, dl: DownlinkSpec<'a>) -> Self {
        self.downlink = Some(dl);
        self
    }
}

/// A (possibly enormous) client population the fleet can draw from.
///
/// `shard` may alias (many simulated clients sharing template data);
/// `weight` is the unnormalized aggregation weight (e.g. local sample
/// count n_k).
pub trait ClientPool: Sync {
    fn population(&self) -> usize;

    fn weight(&self, user: usize) -> f64;

    fn shard(&self, user: usize) -> &Dataset;
}

/// One real dataset shard per client — the paper-scale pool backing
/// `RoundDriver` and `fl::run_federated`.
pub struct ShardPool<'a> {
    shards: &'a [Dataset],
    weights: Vec<f64>,
}

impl<'a> ShardPool<'a> {
    /// Weights proportional to shard sizes (the FedAvg default).
    pub fn new(shards: &'a [Dataset]) -> Self {
        let weights = shards.iter().map(|s| s.len() as f64).collect();
        Self { shards, weights }
    }

    /// Explicit weights (e.g. pre-computed α's from `FlConfig::alphas`).
    pub fn with_weights(shards: &'a [Dataset], weights: &[f64]) -> Self {
        assert_eq!(shards.len(), weights.len(), "weights/shards mismatch");
        Self { shards, weights: weights.to_vec() }
    }
}

impl ClientPool for ShardPool<'_> {
    fn population(&self) -> usize {
        self.shards.len()
    }

    fn weight(&self, user: usize) -> f64 {
        self.weights[user]
    }

    fn shard(&self, user: usize) -> &Dataset {
        &self.shards[user]
    }
}

/// Simulates a population far larger than the number of distinct datasets
/// by mapping client `u` onto `templates[u % templates.len()]`, with
/// deterministic per-client integer weights in `[lo, hi]`. This is how the
/// ≥10k-client benches and examples model "millions of users" without
/// materializing millions of shards.
pub struct RoundRobinPool {
    templates: Vec<Dataset>,
    weights: Vec<f64>,
}

impl RoundRobinPool {
    pub fn synthetic(population: usize, templates: Vec<Dataset>, seed: u64) -> Self {
        assert!(!templates.is_empty(), "need at least one template shard");
        assert!(population > 0, "empty population");
        let span = 101u64; // weights in [50, 150]
        let weights = (0..population)
            .map(|u| {
                let x = SplitMix64::new(seed ^ 0xF1EE7 ^ (u as u64).wrapping_mul(0x9E3779B97F4A7C15))
                    .next();
                (50 + (x % span)) as f64
            })
            .collect();
        Self { templates, weights }
    }
}

impl ClientPool for RoundRobinPool {
    fn population(&self) -> usize {
        self.weights.len()
    }

    fn weight(&self, user: usize) -> f64 {
        self.weights[user]
    }

    fn shard(&self, user: usize) -> &Dataset {
        &self.templates[user % self.templates.len()]
    }
}

/// A participation + fault scenario: who is selected and what goes wrong.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub sampler: SamplerKind,
    /// Extra selection headroom: the server selects
    /// `ceil(target·(1+over_select))` clients and aggregates the first
    /// `target` arrivals (ignored by `Full`/`Fixed` samplers).
    pub over_select: f64,
    pub faults: FaultPlan,
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario::full()
    }
}

impl Scenario {
    /// Full participation, no faults — reproduces the seed `RoundDriver`.
    pub fn full() -> Self {
        Self { sampler: SamplerKind::Full, over_select: 0.0, faults: FaultPlan::none() }
    }

    /// Uniform cohort of `cohort` clients per round, no faults.
    pub fn sampled(cohort: usize) -> Self {
        Self {
            sampler: SamplerKind::Uniform { cohort },
            over_select: 0.0,
            faults: FaultPlan::none(),
        }
    }

    /// Shard-size-weighted cohort, no faults.
    pub fn weighted(cohort: usize) -> Self {
        Self {
            sampler: SamplerKind::Weighted { cohort },
            over_select: 0.0,
            faults: FaultPlan::none(),
        }
    }

    /// Heavy-tailed client latency with a round deadline and 25%
    /// over-selection — the production straggler regime.
    pub fn stragglers(cohort: usize, deadline: f64) -> Self {
        Self {
            sampler: SamplerKind::Uniform { cohort },
            over_select: 0.25,
            faults: FaultPlan {
                latency: LatencyModel::LogNormal { median: 1.0, sigma: 0.8 },
                dropout: 0.02,
                deadline: Some(deadline),
                wire: WirePlan::none(),
            },
        }
    }

    /// Unreliable fleet: high dropout, exponential latency, 50%
    /// over-selection.
    pub fn flaky(cohort: usize, deadline: f64) -> Self {
        Self {
            sampler: SamplerKind::Uniform { cohort },
            over_select: 0.5,
            faults: FaultPlan {
                latency: LatencyModel::Exponential { mean: 1.0 },
                dropout: 0.2,
                deadline: Some(deadline),
                wire: WirePlan::none(),
            },
        }
    }

    /// Scenario preset by CLI/config name.
    pub fn by_name(name: &str, cohort: usize) -> crate::Result<Self> {
        Ok(match name {
            "full" => Self::full(),
            "sampled" | "uniform" => Self::sampled(cohort),
            "weighted" => Self::weighted(cohort),
            "stragglers" => Self::stragglers(cohort, 3.0),
            "flaky" => Self::flaky(cohort, 4.0),
            other => crate::bail!(
                "unknown fleet scenario '{other}' (full|sampled|weighted|stragglers|flaky)"
            ),
        })
    }
}

/// Per-(selected client, round) uplink outcome — the rate-diverse
/// observability the heterogeneous-channel work adds. One record per
/// *selected* client, in cohort (ascending-id) order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClientRoundRecord {
    pub user: u64,
    /// Channel capacity this round (bits/entry); the base rate when no
    /// rate plan is active.
    pub capacity: f64,
    /// Rate the controller assigned (bits/entry). 0 when the client never
    /// transmitted (dropped / late / cut by over-selection).
    pub assigned_rate: f64,
    /// Exact coded bits of the folded update (0 when not aggregated) —
    /// always ≤ ⌊assigned_rate·m⌋ for rate-constrained codecs.
    pub achieved_bits: usize,
    /// Client finished local work but missed the round deadline.
    pub deadline_miss: bool,
    /// Client dropped out (sent nothing).
    pub dropped: bool,
    /// Client was quarantined: wire corruption survived every retransmit,
    /// or its CRC-valid payload failed shard decode.
    pub rejected: bool,
    /// Retransmission attempts this client made beyond its first
    /// transmit (0 on a clean wire).
    pub retries: u32,
}

/// Round-level summary of the rate allocation (all zeros when the driver
/// has no rate plan and ran the legacy same-pipe-for-everyone uplink).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ChannelRoundStats {
    /// A rate plan was active this round.
    pub enabled: bool,
    /// Min / mean / max assigned rate over aggregated clients (bits/entry).
    pub min_rate: f64,
    pub mean_rate: f64,
    pub max_rate: f64,
    /// Distinct assigned budgets (⌊R_u·m⌋ granularity) — ≥ 3 under the
    /// tiers preset.
    pub distinct_budgets: usize,
    /// Σ channel capacity over aggregated clients (bits/entry mass).
    pub capacity_mass: f64,
    /// Σ assigned rate over aggregated clients (≤ capacity_mass).
    pub assigned_mass: f64,
}

/// Everything the server learns from one fleet round.
#[derive(Debug, Clone, Default)]
pub struct FleetRoundReport {
    pub round: u64,
    /// Clients selected (target + over-selection headroom).
    pub selected: usize,
    /// Updates actually folded into the aggregate.
    pub aggregated: usize,
    /// Selected clients that dropped out (sent nothing).
    pub dropped: usize,
    /// Selected clients whose update missed the deadline.
    pub late: usize,
    /// Arrivals beyond the target count, cut by over-selection.
    pub surplus: usize,
    /// `aggregated / target` — 1.0 when the round filled its quota.
    pub completion_rate: f64,
    /// Σ of the re-normalized α's folded (≈1 by construction).
    pub alpha_sum: f64,
    /// Aggregated weight / selected weight — how much of the intended
    /// cohort's mass made it into the round.
    pub alpha_mass: f64,
    /// Exact entropy-coded payload bits (what the budget constrains).
    pub uplink_bits: usize,
    /// Serialized bytes on the wire, frame headers + CRC included.
    pub wire_bytes: usize,
    /// Rate-budget violations observed (messages rejected, not folded).
    pub budget_violations: usize,
    /// Clients quarantined this round: wire corruption survived every
    /// retransmit attempt, or a CRC-valid payload failed shard decode.
    /// Their partial contributions never touch the aggregate; `alpha_sum`
    /// re-normalizes over the clients that actually folded.
    pub rejected: usize,
    /// Total retransmission attempts across the round (beyond each
    /// client's first transmit). Every attempt burns wire bytes and one
    /// more latency period of virtual time.
    pub retries: usize,
    /// Frame bytes disturbed by injected wire corruption (0 when the
    /// scenario's [`WirePlan`] is inactive).
    pub corrupt_wire_bytes: usize,
    /// ‖Σα(ĥ−h)‖²/m — the measured Theorem-2 quantity.
    pub aggregate_distortion: f64,
    /// Real compute seconds spent inside client jobs (sum over clients).
    pub client_secs: f64,
    /// Wall-clock seconds the whole round took on the coordinator (the
    /// virtual-time view lives in `timing`).
    pub wall_secs: f64,
    pub timing: RoundTiming,
    /// Rate-allocation summary (zeroed when no rate plan is active).
    pub channel: ChannelRoundStats,
    /// Per-selected-client uplink outcomes (capacity, assigned rate,
    /// achieved bits, deadline misses), ascending client id. Under
    /// [`ClientRecords::Capped`] this is a deterministic stride sample;
    /// `clients_total` always holds the exact count.
    pub clients: Vec<ClientRoundRecord>,
    /// Exact number of selected clients (== `selected`; kept explicit so
    /// capped-record reports stay self-describing).
    pub clients_total: usize,
    /// Per-shard fold statistics, ascending shard order — always
    /// populated (tracing or not), one entry per aggregation shard.
    pub shards: Vec<ShardRoundStats>,
    /// Serialized downlink bytes broadcast this round (delta frames +
    /// resync frames, headers and CRC included). 0 when downlink is off.
    pub downlink_bytes: usize,
    /// Downlink payload bits: entropy-coded bits for delta broadcasts
    /// plus raw `32·m` bits per full resync.
    pub downlink_bits: usize,
    /// Arrivals that received a full-model resync instead of a delta
    /// (first contact, stale beyond the resync bound, or a lossless
    /// downlink codec).
    pub resyncs: usize,
    /// Mean per-entry squared broadcast error `Σ‖d−d̂‖²/(m·arrivals)`
    /// over this round's downlink messages (resyncs contribute zero).
    pub broadcast_distortion: f64,
}

/// A heterogeneous-uplink plan: the capacity model plus the policy that
/// splits the round's rate mass across clients.
pub struct RatePlan {
    pub channel: Channel,
    pub controller: Box<dyn RateController>,
}

impl RatePlan {
    pub fn new(channel: Channel, controller: Box<dyn RateController>) -> Self {
        Self { channel, controller }
    }
}

/// Drives fleet rounds: sample cohort → fault fates → (optionally) draw
/// per-client channel capacities and allocate rates → fan out local
/// training over the arrivals → frame/unframe each update through the
/// metered uplink → stream-fold into the O(m) aggregate → apply.
pub struct FleetDriver {
    seed: u64,
    rate: f64,
    workers: usize,
    scenario: Scenario,
    sampler: CohortSampler,
    /// Heterogeneous uplink: per-client capacities + rate controller.
    /// `None` = the legacy fixed budget for everyone.
    rate_plan: Option<RatePlan>,
    /// Aggregation shards the server fold is split across (≥ 1).
    shards: usize,
    /// Compute credit each shard decode session may spend (solver
    /// iterations for fedvqcs-style codecs). Default unlimited; a bounded
    /// budget turns an over-budget decode into a typed `ShardReject`
    /// ("decode budget exhausted"), never a partial fold.
    decode_budget: DecodeBudget,
    /// Downlink broadcast state: per-client reference table + error
    /// feedback, plus an optional downlink capacity model. Only consulted
    /// when a round's spec carries a [`DownlinkSpec`].
    broadcast: BroadcastPlanner,
}

impl FleetDriver {
    pub fn new(seed: u64, rate: f64, workers: usize, scenario: Scenario) -> Self {
        Self {
            seed,
            rate,
            workers: workers.max(1),
            scenario,
            sampler: CohortSampler::new(seed),
            rate_plan: None,
            shards: 1,
            decode_budget: DecodeBudget::UNLIMITED,
            broadcast: BroadcastPlanner::new(),
        }
    }

    /// Cap the compute credit each server-side decode session may spend
    /// (one unit per reconstruction-solver iteration). Exhaustion rejects
    /// that client's update for the round — it never partially folds.
    pub fn with_decode_budget(mut self, budget: DecodeBudget) -> Self {
        self.decode_budget = budget;
        self
    }

    /// Split the server fold across `n` aggregation shards. The merged
    /// result is bit-identical for any `n` (fixed-point partials combined
    /// in ascending shard order), so this is purely a throughput knob.
    ///
    /// # Panics
    /// When `n` is outside `1..=`[`MAX_SHARDS`].
    pub fn with_shards(mut self, n: usize) -> Self {
        assert!(
            (1..=MAX_SHARDS).contains(&n),
            "shards must be in 1..={MAX_SHARDS}, got {n}"
        );
        self.shards = n;
        self
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Attach a heterogeneous-uplink rate plan: per-client capacities are
    /// drawn each round and `plan.controller` splits `rate · |cohort|`
    /// bits/entry of mass across the arrivals.
    pub fn with_rate_plan(mut self, plan: RatePlan) -> Self {
        self.rate_plan = Some(plan);
        self
    }

    pub fn rate_plan(&self) -> Option<&RatePlan> {
        self.rate_plan.as_ref()
    }

    /// Model per-client downlink capacity (asymmetric links): every
    /// broadcast's rate becomes `min(spec.rate, capacity(user, round))`.
    /// Pair with [`AsymmetricChannel::into_parts`] to split one
    /// asymmetric link into an uplink `RatePlan` and this downlink cap.
    pub fn with_downlink_channel(mut self, channel: Channel) -> Self {
        self.broadcast = BroadcastPlanner::new().with_channel(channel);
        self
    }

    /// The downlink broadcast planner (per-client reference table + error
    /// feedback state). Useful for inspecting stale-sync bookkeeping.
    pub fn broadcast_planner(&self) -> &BroadcastPlanner {
        &self.broadcast
    }

    pub fn scenario(&self) -> &Scenario {
        &self.scenario
    }

    /// Execute the round described by `spec`, updating `w` in place.
    pub fn run_round(
        &self,
        spec: &RoundSpec<'_>,
        w: &mut [f32],
        pool: &dyn ClientPool,
        clock: &mut VirtualClock,
    ) -> FleetRoundReport {
        let m = w.len();
        let round = spec.round;
        // Tracing is opt-in and observation-only: `tel` is `Some` exactly
        // when a live collector is attached, and every instrumented branch
        // performs the same arithmetic as the untraced one (the
        // determinism tests pin this).
        let tel: Option<&Collector> = spec.telemetry.filter(|c| c.is_enabled());
        let virt_start = clock.now();
        let round_timer = Timer::start();
        let population = pool.population();
        let target = self.scenario.sampler.target(population);
        let n_select = match self.scenario.sampler {
            SamplerKind::Full | SamplerKind::Fixed { .. } => target,
            _ => (((target as f64) * (1.0 + self.scenario.over_select)).ceil() as usize)
                .min(population),
        };
        let weight_of = |u: usize| pool.weight(u);
        let selected =
            self.sampler.select(&self.scenario.sampler, population, n_select, &weight_of, round);

        // Fault fates — pure functions of (seed, user, round).
        let crand = CommonRandomness::new(self.seed);
        let mut arrivals: Vec<(f64, usize)> = Vec::with_capacity(selected.len());
        let mut fates: Vec<ClientFate> = Vec::with_capacity(selected.len());
        let mut dropped = 0usize;
        let mut late = 0usize;
        for &u in &selected {
            let fate = self.scenario.faults.fate(&crand, u as u64, round);
            match fate {
                ClientFate::Arrives { latency } => arrivals.push((latency, u)),
                ClientFate::Late { .. } => late += 1,
                ClientFate::Dropped => dropped += 1,
                // `fate()` never pre-rejects — rejection is an uplink
                // outcome, patched into `fates` after the fold.
                ClientFate::Rejected { .. } => {}
            }
            fates.push(fate);
        }
        arrivals.sort_by(|a, b| {
            a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
        });
        let surplus = arrivals.len().saturating_sub(target);
        arrivals.truncate(target);

        // Per-client uplink budget: draw channel capacities and run the
        // rate controller over the aggregating set (coordinator thread —
        // allocation sees the whole cohort, workers only their own rate).
        let base_rate = spec.rate_override.unwrap_or(self.rate);
        let ra_start = tel.map(|c| c.wall_now()).unwrap_or(0.0);
        let ra_timer = Timer::start();
        let (capacities, rates) = match &self.rate_plan {
            Some(plan) => {
                let caps: Vec<f64> = arrivals
                    .iter()
                    .map(|&(_, u)| plan.channel.capacity(u as u64, round))
                    .collect();
                let cohort_alphas: Vec<f64> =
                    arrivals.iter().map(|&(_, u)| pool.weight(u)).collect();
                let req = AllocRequest {
                    capacities: &caps,
                    alphas: &cohort_alphas,
                    total_rate: base_rate * arrivals.len() as f64,
                };
                let rates = plan.controller.allocate(&req);
                debug_assert_eq!(rates.len(), arrivals.len());
                (caps, rates)
            }
            None => (vec![base_rate; arrivals.len()], vec![base_rate; arrivals.len()]),
        };
        if let Some(c) = tel {
            c.record(SpanEvent {
                kind: SpanKind::RateAlloc,
                round,
                user: SpanEvent::ROUND_SCOPED,
                wall_start_s: ra_start,
                wall_dur_s: ra_timer.elapsed_secs(),
                virt_s: virt_start,
                data: SpanData::RateAlloc {
                    clients: arrivals.len() as u32,
                    capacity_mass: capacities.iter().sum(),
                    assigned_mass: rates.iter().sum(),
                },
            });
        }

        // Downlink broadcast — before the training fan-out: the server
        // codes each arrival's global-model delta against that client's
        // last-synced reference (or sends a full resync) and the client
        // trains on its *reconstruction* of `w`, never on `w` itself.
        // Runs sequentially on the coordinator thread in ascending
        // arrival order, so the reference table, the error-feedback
        // state, and every reconstruction are bit-identical for any
        // worker or shard count, traced or not.
        let mut downlink_bytes = 0usize;
        let mut downlink_bits = 0usize;
        let mut resyncs = 0usize;
        let mut broadcast_sq_err = 0.0f64;
        let reconstructions: Option<Vec<Vec<f32>>> = spec.downlink.as_ref().map(|dl| {
            arrivals
                .iter()
                .map(|&(_, u)| {
                    let bc_start = tel.map(|c| c.wall_now()).unwrap_or(0.0);
                    let bc_timer = Timer::start();
                    let out = self.broadcast.broadcast(dl, self.seed, round, u as u64, &*w);
                    downlink_bytes += out.frame_bytes;
                    downlink_bits += out.payload_bits;
                    resyncs += out.resync as usize;
                    broadcast_sq_err += out.sq_err;
                    if let Some(c) = tel {
                        // Exactly one downlink span per arrival: a
                        // `stale_sync` when the planner fell back to a
                        // full-model resync, a `broadcast` otherwise.
                        let (kind, data) = if out.resync {
                            (
                                SpanKind::StaleSync,
                                SpanData::StaleSync {
                                    staleness: out.staleness,
                                    bits: out.payload_bits as u64,
                                    wire_bytes: out.frame_bytes as u64,
                                },
                            )
                        } else {
                            (
                                SpanKind::Broadcast,
                                SpanData::Broadcast {
                                    assigned_bits: out.assigned_bits as u64,
                                    achieved_bits: out.payload_bits as u64,
                                    wire_bytes: out.frame_bytes as u64,
                                    ref_round: out.ref_round,
                                },
                            )
                        };
                        c.record(SpanEvent {
                            kind,
                            round,
                            user: u as u64,
                            wall_start_s: bc_start,
                            wall_dur_s: bc_timer.elapsed_secs(),
                            virt_s: virt_start,
                            data,
                        });
                    }
                    out.reconstruction
                })
                .collect()
        });

        // α re-normalization over the set that actually aggregates.
        let arrived_weight: f64 = arrivals.iter().map(|&(_, u)| pool.weight(u)).sum();
        let selected_weight: f64 = selected.iter().map(|&u| pool.weight(u)).sum();
        assert!(
            arrivals.is_empty() || arrived_weight > 0.0,
            "aggregating cohort has zero total weight"
        );

        // Fan out local training over arrivals. The coordinator meters,
        // integrity-checks and admits each frame, then hands it to its
        // owning aggregation shard over a bounded channel: decode+fold
        // run on the shard threads, pipelined with the workers' local
        // training/encode. A full shard queue blocks the coordinator,
        // which stops draining the (also bounded) worker channel — so
        // backpressure reaches the producers instead of buffering
        // without bound.
        let uplink = UplinkChannel::new(base_rate, spec.codec.rate_constrained());
        let wire_codec_id =
            quantizer::codec_id(&spec.codec.name()).unwrap_or(quantizer::CODEC_ID_UNREGISTERED);
        let n_shards = self.shards;
        let wire_plan = self.scenario.faults.wire;
        let mut client_secs = 0.0f64;
        let mut wire_bytes = 0usize;
        let mut budget_violations = 0usize;
        let mut corrupt_wire_bytes = 0usize;
        let mut achieved_bits = vec![0usize; arrivals.len()];
        let mut folded = vec![false; arrivals.len()];
        // Quarantine bookkeeping, indexed by arrival so it accumulates
        // order-independently: the terminal failure reason (None = not
        // rejected), retransmissions spent, and the effective latency
        // (base latency × attempts) the virtual clock must charge.
        let mut reject_reasons: Vec<Option<&'static str>> = vec![None; arrivals.len()];
        let mut attempts_used = vec![0u32; arrivals.len()];
        let mut eff_latency: Vec<f64> = arrivals.iter().map(|&(l, _)| l).collect();
        let (agg, desired, shard_stats, shard_rejects) = {
            let w_snapshot: &[f32] = w;
            let recon_ref: Option<&[Vec<f32>]> = reconstructions.as_deref();
            let arrivals_ref: &[(f64, usize)] = &arrivals;
            let rates_ref: &[f64] = &rates;
            let achieved_ref = &mut achieved_bits;
            let folded_ref = &mut folded;
            let reject_ref = &mut reject_reasons;
            let attempts_ref = &mut attempts_used;
            let eff_latency_ref = &mut eff_latency;
            let seed = self.seed;
            let codec = spec.codec;
            let decode_budget = self.decode_budget;
            std::thread::scope(|scope| {
                // Leaf shards: arrival `i` belongs to shard `i % n_shards`.
                let mut senders = Vec::with_capacity(n_shards);
                let mut handles = Vec::with_capacity(n_shards);
                for s in 0..n_shards {
                    let (tx, rx) = std::sync::mpsc::sync_channel(shard::QUEUE_DEPTH);
                    senders.push(tx);
                    handles.push(scope.spawn(move || {
                        shard::run_shard(s as u32, m, seed, codec, decode_budget, tel, rx)
                    }));
                }
                parallel_map_fold(
                    arrivals_ref.len(),
                    self.workers,
                    |i| {
                        let u = arrivals_ref[i].1;
                        let t = Timer::start();
                        let train_start = tel.map(|c| c.wall_now()).unwrap_or(0.0);
                        // Same per-(user, round) derivation as the seed driver,
                        // so full participation reproduces it bit-for-bit.
                        let local_seed = SplitMix64::new(
                            self.seed ^ (u as u64) << 32 ^ round.wrapping_mul(0x9E37),
                        )
                        .next();
                        // Downlink-on rounds train from the client's own
                        // reconstruction of the global model (and report
                        // the update relative to it); downlink-off rounds
                        // keep the classic perfect-downlink snapshot.
                        let w_client: &[f32] = match recon_ref {
                            Some(r) => &r[i],
                            None => w_snapshot,
                        };
                        let w_new = spec.trainer.local_update(
                            w_client,
                            pool.shard(u),
                            spec.local_steps,
                            spec.lr,
                            spec.batch_size,
                            local_seed,
                        );
                        let mut h = w_new;
                        for (hv, &wv) in h.iter_mut().zip(w_client.iter()) {
                            *hv -= wv;
                        }
                        if let Some(c) = tel {
                            c.record(SpanEvent {
                                kind: SpanKind::ClientTrain,
                                round,
                                user: u as u64,
                                wall_start_s: train_start,
                                wall_dur_s: t.elapsed_secs(),
                                virt_s: virt_start,
                                data: SpanData::ClientTrain {
                                    local_steps: spec.local_steps as u32,
                                    m: m as u64,
                                },
                            });
                            // Attribute codec-internal work counters (scale
                            // probes, range symbols) to this client's encode.
                            probe::reset();
                        }
                        let enc_start = tel.map(|c| c.wall_now()).unwrap_or(0.0);
                        let enc_timer = Timer::start();
                        // Client side of the session API: the update streams
                        // through the encode sink in tensor chunks (layer-style
                        // granularity), not as one monolithic buffer. The
                        // client's assigned rate arrives via CodecContext.
                        let ctx = CodecContext::new(u as u64, round, self.seed, rates_ref[i]);
                        let mut sink = spec.codec.encoder(&ctx, m);
                        let mut enc_chunks = 0u32;
                        for chunk in h.chunks(DEFAULT_CHUNK) {
                            sink.push(chunk);
                            enc_chunks += 1;
                        }
                        if let Some(c) = tel {
                            // One-time (process-wide) flag when a buffered
                            // session holds > 1 MiB: `state_bytes` is now
                            // honest for every codec, so the §C bench's
                            // peak-state figures stop under-reporting —
                            // this counter marks runs where buffering was
                            // actually significant.
                            let state = sink.state_bytes();
                            if state > 1 << 20
                                && !ENCODE_STATE_OVER_1MIB.swap(true, Ordering::Relaxed)
                            {
                                c.add_counter("encode_state_over_1mib_bytes", state as f64);
                            }
                        }
                        let enc = sink.finish();
                        let frame = wire::encode_frame(u as u64, round, wire_codec_id, &enc);
                        if let Some(c) = tel {
                            let enc_secs = enc_timer.elapsed_secs();
                            let p = probe::take();
                            c.record(SpanEvent {
                                kind: SpanKind::Encode,
                                round,
                                user: u as u64,
                                wall_start_s: enc_start,
                                wall_dur_s: enc_secs,
                                virt_s: virt_start,
                                data: SpanData::Encode {
                                    assigned_bits: (rates_ref[i] * m as f64).floor() as u64,
                                    achieved_bits: enc.bits as u64,
                                    chunks: enc_chunks,
                                    scale_probes_est: p.scale_probes_est,
                                    scale_probes_exact: p.scale_probes_exact,
                                    symbols: p.symbols,
                                    escapes: p.escapes,
                                },
                            });
                            c.record_hist(HistMetric::EncodeNanos, (enc_secs * 1e9) as u64);
                            c.record_hist(HistMetric::MessageBytes, frame.len() as u64);
                            if p.transform_nanos > 0 {
                                // Pipeline codecs only — closed-form codecs
                                // never touch a transform stage.
                                c.record_hist(HistMetric::TransformNanos, p.transform_nanos);
                            }
                        }
                        (frame, h, t.elapsed_secs())
                    },
                    |i, (frame, h, secs)| {
                        client_secs += secs;
                        let user = arrivals_ref[i].1 as u64;
                        let base_latency = arrivals_ref[i].0;
                        // Hostile wire: every transmit attempt re-frames the
                        // pristine encoder output, re-draws deterministic
                        // corruption from the per-(user, round) WireFault
                        // stream, burns wire bytes, and costs one more
                        // latency period of virtual time. A frame that fails
                        // integrity/parse checks retransmits up to
                        // `max_retries` times while the deadline allows;
                        // exhaustion quarantines the client for the round.
                        // Every draw is a pure function of (seed, user,
                        // round, attempt), so the outcome is independent of
                        // worker count and completion order.
                        let mut wf_rng = crand.stream(user, round, StreamKind::WireFault);
                        let mut attempt = 0u32;
                        loop {
                            let mut attempt_frame = frame.clone();
                            if wire_plan.active() {
                                corrupt_wire_bytes +=
                                    wire_plan.corrupt_attempt(&mut wf_rng, &mut attempt_frame);
                            }
                            wire_bytes += attempt_frame.len();
                            // In virtual time attempt k lands after k full
                            // latency periods; transmit/decode/fold all
                            // happen at that instant.
                            eff_latency_ref[i] = base_latency * (attempt + 1) as f64;
                            let arrival_virt = virt_start + eff_latency_ref[i];
                            let tx_start = tel.map(|c| c.wall_now()).unwrap_or(0.0);
                            let tx_timer = Timer::start();
                            match wire::decode_frame(&attempt_frame) {
                                Ok(f) => {
                                    debug_assert_eq!(f.user, user);
                                    let admitted = uplink.try_transmit_rate(
                                        f.user,
                                        &f.payload,
                                        m,
                                        rates_ref[i],
                                    );
                                    if let Some(c) = tel {
                                        c.record(SpanEvent {
                                            kind: SpanKind::Transmit,
                                            round,
                                            user: f.user,
                                            wall_start_s: tx_start,
                                            wall_dur_s: tx_timer.elapsed_secs(),
                                            virt_s: arrival_virt,
                                            data: SpanData::Transmit {
                                                wire_bytes: attempt_frame.len() as u64,
                                                payload_bits: f.payload.bits as u64,
                                                accepted: admitted.is_ok(),
                                            },
                                        });
                                    }
                                    match admitted {
                                        Ok(()) => {
                                            achieved_ref[i] = f.payload.bits;
                                            folded_ref[i] = true;
                                            let alpha = pool.weight(arrivals_ref[i].1)
                                                / arrived_weight;
                                            // Hand off to the owning shard, which
                                            // rebuilds the decoder context (same
                                            // per-client rate the encoder saw) and
                                            // stage-folds the stream into its
                                            // fixed-point partial. `send` blocks
                                            // when the shard is `QUEUE_DEPTH` jobs
                                            // behind.
                                            senders[i % n_shards]
                                                .send(shard::ShardJob {
                                                    arrival: i,
                                                    user: f.user,
                                                    round: f.round,
                                                    rate: rates_ref[i],
                                                    alpha,
                                                    virt_s: arrival_virt,
                                                    payload: f.payload,
                                                    h,
                                                })
                                                .expect("aggregation shard hung up");
                                        }
                                        // A budget violation is a deterministic
                                        // function of the coded bytes — a resend
                                        // would fail identically, so it never
                                        // retries (DESIGN.md §13).
                                        Err(_) => budget_violations += 1,
                                    }
                                    break;
                                }
                                Err(werr) => {
                                    if let Some(c) = tel {
                                        // The corrupt attempt still burned wire
                                        // bytes; its payload bits are unknowable.
                                        c.record(SpanEvent {
                                            kind: SpanKind::Transmit,
                                            round,
                                            user,
                                            wall_start_s: tx_start,
                                            wall_dur_s: tx_timer.elapsed_secs(),
                                            virt_s: arrival_virt,
                                            data: SpanData::Transmit {
                                                wire_bytes: attempt_frame.len() as u64,
                                                payload_bits: 0,
                                                accepted: false,
                                            },
                                        });
                                    }
                                    let next_eff = base_latency * (attempt + 2) as f64;
                                    let deadline_ok = self
                                        .scenario
                                        .faults
                                        .deadline
                                        .map_or(true, |d| next_eff <= d);
                                    if attempt < wire_plan.max_retries && deadline_ok {
                                        attempt += 1;
                                        attempts_ref[i] = attempt;
                                        if let Some(c) = tel {
                                            c.record(SpanEvent {
                                                kind: SpanKind::Retry,
                                                round,
                                                user,
                                                wall_start_s: tx_start,
                                                wall_dur_s: 0.0,
                                                virt_s: arrival_virt,
                                                data: SpanData::Retry {
                                                    attempt,
                                                    wire_bytes: attempt_frame.len() as u64,
                                                    reason: werr.reason(),
                                                },
                                            });
                                        }
                                        continue;
                                    }
                                    // Terminal: retries exhausted, or another
                                    // attempt could not land before the round
                                    // deadline.
                                    let reason = if attempt >= wire_plan.max_retries {
                                        werr.reason()
                                    } else {
                                        "retransmit deadline exceeded"
                                    };
                                    reject_ref[i] = Some(reason);
                                    if let Some(c) = tel {
                                        c.record(SpanEvent {
                                            kind: SpanKind::Reject,
                                            round,
                                            user,
                                            wall_start_s: tx_start,
                                            wall_dur_s: 0.0,
                                            virt_s: arrival_virt,
                                            data: SpanData::Reject {
                                                attempts: attempt + 1,
                                                reason,
                                            },
                                        });
                                    }
                                    break;
                                }
                            }
                        }
                    },
                );
                // Closing the senders ends every shard's receive loop; the
                // root combiner then folds the partials in fixed (ascending)
                // shard order. Fixed-point (i128) accumulators make the merge
                // associative and commutative, so the merged model is
                // bit-identical for any shard count, worker count, or send
                // interleaving — `worker_count_does_not_change_the_model` and
                // `tests/integration_shards.rs` pin this.
                drop(senders);
                let mut agg = StreamingAggregator::new(m);
                let mut desired = StreamingAggregator::new(m);
                let mut shard_stats: Vec<ShardRoundStats> = Vec::with_capacity(n_shards);
                let mut shard_rejects: Vec<shard::ShardReject> = Vec::new();
                for handle in handles {
                    let out = handle.join().expect("aggregation shard panicked");
                    shard_rejects.extend(out.rejects.iter().copied());
                    agg.merge(&out.agg);
                    desired.merge(&out.desired);
                    if let Some(c) = tel {
                        c.record(SpanEvent {
                            kind: SpanKind::ShardFold,
                            round,
                            user: SpanEvent::ROUND_SCOPED,
                            wall_start_s: out.wall_start_s,
                            wall_dur_s: out.stats.busy_secs,
                            virt_s: virt_start,
                            data: SpanData::ShardFold {
                                shard: out.stats.shard as u32,
                                folds: out.stats.folds as u32,
                                chunks: out.stats.chunks,
                                entries: out.stats.entries,
                                decode_secs: out.stats.decode_secs,
                                fold_secs: out.stats.fold_secs,
                            },
                        });
                    }
                    shard_stats.push(out.stats);
                }
                (agg, desired, shard_stats, shard_rejects)
            })
        };

        // Shard-level rejections surface only after the join: the
        // admission path recorded these clients optimistically, so roll
        // back their `folded`/bit accounting and quarantine them. Their
        // staged contribution never touched the accumulators (the shard
        // folds only fully-decoded streams), so no arithmetic rollback is
        // needed and the merged model stays bit-identical for any
        // worker/shard topology. Their uplink bits stay metered — the
        // payload was transmitted and admitted before it failed decode.
        for r in &shard_rejects {
            folded[r.arrival] = false;
            achieved_bits[r.arrival] = 0;
            reject_reasons[r.arrival] = Some(r.reason);
            if let Some(c) = tel {
                c.record(SpanEvent {
                    kind: SpanKind::Reject,
                    round,
                    user: r.user,
                    wall_start_s: c.wall_now(),
                    wall_dur_s: 0.0,
                    virt_s: virt_start + eff_latency[r.arrival],
                    data: SpanData::Reject {
                        attempts: attempts_used[r.arrival] + 1,
                        reason: r.reason,
                    },
                });
            }
        }
        // Patch the quarantined clients' fates so per-client records (and
        // any caller inspecting them) see the terminal outcome.
        let rejected = reject_reasons.iter().flatten().count();
        if rejected > 0 {
            for (i, reason) in reject_reasons.iter().enumerate() {
                if let Some(reason) = *reason {
                    let u = arrivals[i].1;
                    if let Some(pos) = selected.iter().position(|&s| s == u) {
                        fates[pos] = ClientFate::Rejected { reason };
                    }
                }
            }
        }
        let retries: usize = attempts_used.iter().map(|&a| a as usize).sum();

        // Apply w ← w + Σ α_k ĥ_k and measure the Theorem-2 distortion.
        let aggregate_distortion = StreamingAggregator::mean_sq_diff(&agg, &desired);
        agg.apply_to(w);
        let broadcast_distortion = if spec.downlink.is_some() && !arrivals.is_empty() && m > 0 {
            broadcast_sq_err / (m as f64 * arrivals.len() as f64)
        } else {
            0.0
        };

        // Virtual time: the round closes at the slowest effective arrival
        // (retransmissions multiply a client's base latency by its
        // attempt count), or at the deadline when the quota went unmet.
        let waited = if arrivals.len() < target { self.scenario.faults.deadline } else { None };
        let timing = clock.close_round(&eff_latency, waited);

        // The folded α mass, re-summed in ascending arrival order: the
        // shard partials accumulate `alpha_sum` in completion order, so
        // their f64 running sums can differ in the last ulp across
        // worker/shard interleavings — this fixed-order recomputation is
        // what the report exposes, making every report aggregate
        // topology-independent.
        let alpha_sum: f64 = arrivals
            .iter()
            .enumerate()
            .filter(|&(i, _)| folded[i])
            .map(|(_, &(_, u))| pool.weight(u) / arrived_weight)
            .sum();

        // Per-client records (ascending client id = `selected` order) and
        // the round's rate-allocation summary. The user→arrival index is
        // a sorted side table probed by binary search — O(n log n) with
        // one small allocation, no hashing on the per-round path. Under
        // `ClientRecords::Capped(n)` only a deterministic stride sample
        // of the cohort is materialized (O(n) instead of O(cohort)).
        let clients: Vec<ClientRoundRecord> = if spec.client_records == ClientRecords::Capped(0) {
            Vec::new()
        } else {
            let mut by_user: Vec<(usize, usize)> =
                arrivals.iter().enumerate().map(|(i, &(_, u))| (u, i)).collect();
            by_user.sort_unstable();
            let record_for = |(&u, fate): (&usize, &ClientFate)| {
                let idx = by_user
                    .binary_search_by_key(&u, |&(user, _)| user)
                    .ok()
                    .map(|pos| by_user[pos].1);
                ClientRoundRecord {
                    user: u as u64,
                    capacity: match (&self.rate_plan, idx) {
                        (_, Some(i)) => capacities[i],
                        (Some(plan), None) => plan.channel.capacity(u as u64, round),
                        (None, None) => base_rate,
                    },
                    assigned_rate: idx.map(|i| rates[i]).unwrap_or(0.0),
                    achieved_bits: idx.map(|i| achieved_bits[i]).unwrap_or(0),
                    deadline_miss: matches!(fate, ClientFate::Late { .. }),
                    dropped: matches!(fate, ClientFate::Dropped),
                    rejected: matches!(fate, ClientFate::Rejected { .. }),
                    retries: idx.map(|i| attempts_used[i]).unwrap_or(0),
                }
            };
            match spec.client_records {
                ClientRecords::Full => selected.iter().zip(&fates).map(record_for).collect(),
                ClientRecords::Capped(cap) => {
                    let stride = selected.len().div_ceil(cap).max(1);
                    selected
                        .iter()
                        .zip(&fates)
                        .step_by(stride)
                        .map(record_for)
                        .collect()
                }
            }
        };
        let channel = if arrivals.is_empty() {
            ChannelRoundStats { enabled: self.rate_plan.is_some(), ..Default::default() }
        } else {
            let mut budgets: Vec<usize> =
                rates.iter().map(|&r| (r * m as f64).floor() as usize).collect();
            budgets.sort_unstable();
            budgets.dedup();
            ChannelRoundStats {
                enabled: self.rate_plan.is_some(),
                min_rate: rates.iter().cloned().fold(f64::INFINITY, f64::min),
                mean_rate: rates.iter().sum::<f64>() / rates.len() as f64,
                max_rate: rates.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
                distinct_budgets: budgets.len(),
                capacity_mass: capacities.iter().sum(),
                assigned_mass: rates.iter().sum(),
            }
        };

        FleetRoundReport {
            round,
            selected: selected.len(),
            aggregated: agg.folds(),
            dropped,
            late,
            surplus,
            completion_rate: agg.folds() as f64 / target.max(1) as f64,
            alpha_sum,
            alpha_mass: if selected_weight > 0.0 { arrived_weight / selected_weight } else { 0.0 },
            uplink_bits: uplink.stats().total_bits,
            wire_bytes,
            budget_violations,
            rejected,
            retries,
            corrupt_wire_bytes,
            aggregate_distortion,
            client_secs,
            wall_secs: round_timer.elapsed_secs(),
            timing,
            channel,
            clients,
            clients_total: selected.len(),
            shards: shard_stats,
            downlink_bytes,
            downlink_bits,
            resyncs,
            broadcast_distortion,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthMnist;
    use crate::fl::NativeTrainer;
    use crate::models::LogReg;
    use crate::quantizer;

    fn setup(k: usize, per: usize) -> (Vec<Dataset>, NativeTrainer<LogReg>) {
        let ds = SynthMnist::new(77).dataset(k * per);
        let shards: Vec<Dataset> = (0..k)
            .map(|u| ds.subset(&(u * per..(u + 1) * per).collect::<Vec<_>>()))
            .collect();
        let model = LogReg::new(ds.features, ds.classes, 1e-3);
        (shards, NativeTrainer::new(model))
    }

    fn spec<'a>(
        round: u64,
        trainer: &'a dyn Trainer,
        codec: &'a dyn UpdateCodec,
    ) -> RoundSpec<'a> {
        RoundSpec::new(round, 1, 0.5, 0, trainer, codec)
    }

    #[test]
    fn sampled_round_aggregates_the_cohort_only() {
        let (shards, trainer) = setup(8, 30);
        let pool = ShardPool::new(&shards);
        let codec = quantizer::make("qsgd").unwrap();
        let driver = FleetDriver::new(5, 2.0, 2, Scenario::sampled(3));
        let mut clock = VirtualClock::new();
        let mut w = trainer.init_params(3);
        let rep = driver.run_round(&spec(0, &trainer, codec.as_ref()), &mut w, &pool, &mut clock);
        assert_eq!(rep.selected, 3);
        assert_eq!(rep.aggregated, 3);
        assert_eq!(rep.completion_rate, 1.0);
        assert!((rep.alpha_sum - 1.0).abs() < 1e-9, "alpha_sum {}", rep.alpha_sum);
        assert!((rep.alpha_mass - 1.0).abs() < 1e-12);
        assert!(rep.uplink_bits > 0);
        assert!(rep.wire_bytes > rep.uplink_bits / 8, "frames must cost more than payloads");
    }

    #[test]
    fn worker_count_does_not_change_the_model() {
        let (shards, trainer) = setup(6, 25);
        let pool = ShardPool::new(&shards);
        let codec = quantizer::make("uveqfed-l2").unwrap();
        let scenario = Scenario::stragglers(4, 5.0);
        let run = |workers: usize, n_shards: usize, traced: bool| {
            let collector =
                if traced { Collector::with_default_capacity() } else { Collector::disabled() };
            let driver =
                FleetDriver::new(9, 2.0, workers, scenario.clone()).with_shards(n_shards);
            let mut clock = VirtualClock::new();
            let mut w = trainer.init_params(1);
            for round in 0..3 {
                let s = spec(round, &trainer, codec.as_ref()).with_telemetry(&collector);
                driver.run_round(&s, &mut w, &pool, &mut clock);
            }
            if traced {
                assert!(!collector.drain().is_empty(), "traced run must record spans");
            }
            w
        };
        let baseline = run(1, 1, false);
        assert_eq!(baseline, run(4, 1, false), "aggregation must be arrival-order independent");
        assert_eq!(baseline, run(1, 1, true), "tracing must not perturb the round");
        assert_eq!(baseline, run(4, 1, true), "tracing must not perturb parallel rounds");
        // The sharded fold extends the same guarantee: the two-level
        // merge in fixed shard order is bit-identical for any topology.
        assert_eq!(baseline, run(1, 3, false), "shard count must not change the model");
        assert_eq!(baseline, run(4, 7, true), "sharded+traced+parallel must stay bit-identical");
    }

    #[test]
    fn capped_client_records_sample_deterministically() {
        let (shards, trainer) = setup(8, 20);
        let pool = ShardPool::new(&shards);
        let codec = quantizer::make("qsgd").unwrap();
        let driver = FleetDriver::new(5, 2.0, 2, Scenario::full());
        let mut run = |records: ClientRecords| {
            let mut clock = VirtualClock::new();
            let mut w = trainer.init_params(4);
            let s = spec(0, &trainer, codec.as_ref()).with_client_records(records);
            driver.run_round(&s, &mut w, &pool, &mut clock)
        };
        let full = run(ClientRecords::Full);
        assert_eq!(full.clients.len(), 8);
        assert_eq!(full.clients_total, 8);
        let capped = run(ClientRecords::Capped(3));
        assert_eq!(capped.clients_total, 8, "exact count must survive the cap");
        assert!(capped.clients.len() <= 3, "got {}", capped.clients.len());
        // Stride sampling keeps a subset of the full records, verbatim.
        for rec in &capped.clients {
            assert!(full.clients.contains(rec), "capped record {rec:?} not in full set");
        }
        let none = run(ClientRecords::Capped(0));
        assert!(none.clients.is_empty());
        assert_eq!(none.clients_total, 8);
        // Aggregates are unaffected by the retention policy.
        assert_eq!(none.aggregated, full.aggregated);
        assert_eq!(none.uplink_bits, full.uplink_bits);
    }

    #[test]
    fn capped_records_edge_cases_and_worker_independence() {
        let (shards, trainer) = setup(8, 20);
        let pool = ShardPool::new(&shards);
        let codec = quantizer::make("qsgd").unwrap();
        // Faulty scenario: the record set mixes arrivals, lates and drops,
        // so the stride has non-trivial structure to preserve.
        let run = |workers: usize, records: ClientRecords| {
            let driver = FleetDriver::new(5, 2.0, workers, Scenario::stragglers(6, 5.0));
            let mut clock = VirtualClock::new();
            let mut w = trainer.init_params(4);
            let s = spec(0, &trainer, codec.as_ref()).with_client_records(records);
            driver.run_round(&s, &mut w, &pool, &mut clock)
        };
        let full = run(1, ClientRecords::Full);
        assert!(!full.clients.is_empty());
        // A cap at (or above) the cohort size degenerates to the full set.
        assert_eq!(run(1, ClientRecords::Capped(full.clients.len())).clients, full.clients);
        assert_eq!(run(1, ClientRecords::Capped(64)).clients, full.clients);
        // Capped(0) keeps nothing even when faults shrink the cohort.
        assert!(run(1, ClientRecords::Capped(0)).clients.is_empty());
        // The stride sample is a pure function of the selected cohort —
        // never of the worker count that happened to run the round.
        for cap in [1usize, 2, 3] {
            let a = run(1, ClientRecords::Capped(cap));
            let b = run(4, ClientRecords::Capped(cap));
            assert_eq!(a.clients, b.clients, "Capped({cap}) differed across worker counts");
            assert_eq!(a.clients_total, b.clients_total);
        }
    }

    #[test]
    fn downlink_round_reports_broadcast_accounting() {
        let (shards, trainer) = setup(5, 20);
        let pool = ShardPool::new(&shards);
        let codec = quantizer::make("uveqfed-l2").unwrap();
        let dl_codec = quantizer::make("uveqfed-l2").unwrap();
        let driver = FleetDriver::new(4, 2.0, 2, Scenario::full());
        let mut clock = VirtualClock::new();
        let mut w = trainer.init_params(2);
        let m = w.len();
        let mut reports = Vec::new();
        for round in 0..2u64 {
            let s = spec(round, &trainer, codec.as_ref())
                .with_downlink(DownlinkSpec::new(dl_codec.as_ref(), 2.0));
            reports.push(driver.run_round(&s, &mut w, &pool, &mut clock));
        }
        // Round 0: every client is first contact → a raw full resync of
        // 32·m payload bits each, zero broadcast error.
        assert_eq!(reports[0].resyncs, 5);
        assert_eq!(reports[0].downlink_bits, 5 * 32 * m);
        assert!(reports[0].downlink_bytes > 5 * 4 * m, "frames must add header overhead");
        assert_eq!(reports[0].broadcast_distortion, 0.0);
        // Round 1: everyone holds a fresh reference → compressed deltas
        // inside the 2 bits/entry budget, with nonzero quantization error.
        assert_eq!(reports[1].resyncs, 0);
        assert!(reports[1].downlink_bits <= 5 * 2 * m, "delta bits blew the budget");
        assert!(reports[1].downlink_bits > 0);
        assert!(reports[1].broadcast_distortion > 0.0, "a 2-bit broadcast must distort");
        assert_eq!(driver.broadcast_planner().tracked_clients(), 5);
        // Downlink-off rounds report all-zero downlink fields.
        let off = driver.run_round(&spec(2, &trainer, codec.as_ref()), &mut w, &pool, &mut clock);
        assert_eq!(
            (off.downlink_bytes, off.downlink_bits, off.resyncs, off.broadcast_distortion),
            (0, 0, 0, 0.0)
        );
    }

    #[test]
    fn shard_stats_partition_the_fold() {
        let (shards, trainer) = setup(9, 20);
        let pool = ShardPool::new(&shards);
        let codec = quantizer::make("uveqfed-l2").unwrap();
        let driver = FleetDriver::new(3, 2.0, 2, Scenario::full()).with_shards(4);
        let mut clock = VirtualClock::new();
        let mut w = trainer.init_params(2);
        let m = w.len();
        let rep = driver.run_round(&spec(0, &trainer, codec.as_ref()), &mut w, &pool, &mut clock);
        assert_eq!(rep.shards.len(), 4);
        for (i, s) in rep.shards.iter().enumerate() {
            assert_eq!(s.shard, i, "stats must come back in merge (shard) order");
            assert_eq!(s.entries, s.folds as u64 * m as u64);
        }
        // arrival i → shard i % 4: 9 arrivals land 3/2/2/2.
        let folds: Vec<usize> = rep.shards.iter().map(|s| s.folds).collect();
        assert_eq!(folds.iter().sum::<usize>(), rep.aggregated);
        assert_eq!(folds, vec![3, 2, 2, 2]);
    }

    #[test]
    fn dropout_one_freezes_the_model() {
        let (shards, trainer) = setup(4, 20);
        let pool = ShardPool::new(&shards);
        let codec = quantizer::make("qsgd").unwrap();
        let mut scenario = Scenario::sampled(4);
        scenario.faults.dropout = 1.0;
        let driver = FleetDriver::new(2, 2.0, 2, scenario);
        let mut clock = VirtualClock::new();
        let mut w = trainer.init_params(1);
        let w0 = w.clone();
        let rep = driver.run_round(&spec(0, &trainer, codec.as_ref()), &mut w, &pool, &mut clock);
        assert_eq!(rep.aggregated, 0);
        assert_eq!(rep.dropped, rep.selected);
        assert_eq!(rep.completion_rate, 0.0);
        assert_eq!(w, w0, "no arrivals must leave the model untouched");
    }

    #[test]
    fn rate_plan_assigns_distinct_budgets_and_respects_them() {
        use crate::coordinator::rate_control::CapacityProportional;
        let (shards, trainer) = setup(12, 25);
        let pool = ShardPool::new(&shards);
        let codec = quantizer::make("uveqfed-l2").unwrap();
        let plan = RatePlan::new(
            Channel::new(ChannelModel::by_name("tiers", 2.0).unwrap(), 5),
            Box::new(CapacityProportional),
        );
        let driver =
            FleetDriver::new(5, 2.0, 2, Scenario::full()).with_rate_plan(plan);
        let mut clock = VirtualClock::new();
        let mut w = trainer.init_params(3);
        let m = w.len();
        let rep = driver.run_round(&spec(0, &trainer, codec.as_ref()), &mut w, &pool, &mut clock);
        assert_eq!(rep.budget_violations, 0, "codec must fit every assigned budget");
        assert!(rep.channel.enabled);
        assert!(
            rep.channel.distinct_budgets >= 3,
            "tiers preset must yield ≥3 distinct budgets, got {}",
            rep.channel.distinct_budgets
        );
        assert!(rep.channel.min_rate < rep.channel.max_rate);
        assert!(rep.channel.assigned_mass <= rep.channel.capacity_mass + 1e-9);
        assert_eq!(rep.clients.len(), 12);
        for c in &rep.clients {
            assert!(c.assigned_rate <= c.capacity + 1e-9, "client {}: over capacity", c.user);
            assert!(
                c.achieved_bits <= (c.assigned_rate * m as f64).floor() as usize,
                "client {}: {} bits > ⌊{}·{m}⌋",
                c.user,
                c.achieved_bits,
                c.assigned_rate
            );
            // Everyone folded; a starved budget may legitimately fold the
            // empty zero message (0 bits).
            assert!(
                c.achieved_bits > 0 || c.assigned_rate * (m as f64) < 128.0,
                "client {} sent nothing at a workable budget",
                c.user
            );
        }
    }

    #[test]
    fn rate_plan_rounds_are_worker_count_independent() {
        use crate::coordinator::rate_control::TheoryGuided;
        let (shards, trainer) = setup(8, 20);
        let pool = ShardPool::new(&shards);
        let codec = quantizer::make("qsgd").unwrap();
        let run = |workers: usize, traced: bool| {
            let collector = if traced { Collector::for_cohort(5) } else { Collector::disabled() };
            let plan = RatePlan::new(
                Channel::new(
                    ChannelModel::Markov {
                        good: 4.0,
                        bad: 1.0,
                        p_good_to_bad: 0.3,
                        p_bad_to_good: 0.5,
                    },
                    9,
                ),
                Box::new(TheoryGuided),
            );
            let driver = FleetDriver::new(9, 2.0, workers, Scenario::sampled(5))
                .with_rate_plan(plan);
            let mut clock = VirtualClock::new();
            let mut w = trainer.init_params(1);
            for round in 0..3 {
                let s = spec(round, &trainer, codec.as_ref()).with_telemetry(&collector);
                driver.run_round(&s, &mut w, &pool, &mut clock);
            }
            w
        };
        let baseline = run(1, false);
        assert_eq!(baseline, run(4, false), "per-client rates must not depend on fold order");
        assert_eq!(baseline, run(4, true), "tracing must not perturb rate-planned rounds");
    }

    #[test]
    fn rate_override_rules_the_round_budget() {
        let (shards, trainer) = setup(3, 20);
        let pool = ShardPool::new(&shards);
        let codec = quantizer::make("uveqfed-l2").unwrap();
        let driver = FleetDriver::new(4, 1.0, 2, Scenario::full());
        let mut clock = VirtualClock::new();
        let mut w = trainer.init_params(2);
        let m = w.len();
        let spec_hi = spec(0, &trainer, codec.as_ref()).with_rate(6.0);
        let rep = driver.run_round(&spec_hi, &mut w, &pool, &mut clock);
        assert_eq!(rep.budget_violations, 0);
        // At R=6 the coded sizes may exceed the driver's base R=1 budget —
        // the override governs, and the extra rate is actually usable.
        for c in &rep.clients {
            assert_eq!(c.assigned_rate, 6.0);
            assert!(c.achieved_bits <= 6 * m, "{}", c.achieved_bits);
        }
        let rep_lo = driver.run_round(
            &spec(1, &trainer, codec.as_ref()).with_rate(1.0),
            &mut w,
            &pool,
            &mut clock,
        );
        assert!(
            rep_lo.uplink_bits < rep.uplink_bits,
            "R=1 round must code fewer bits than R=6 round"
        );
    }

    #[test]
    fn round_robin_pool_is_deterministic_and_weighted() {
        let ds = SynthMnist::new(3).dataset(40);
        let a = RoundRobinPool::synthetic(1000, vec![ds.clone()], 5);
        let b = RoundRobinPool::synthetic(1000, vec![ds], 5);
        assert_eq!(a.population(), 1000);
        for u in (0..1000).step_by(97) {
            assert_eq!(a.weight(u), b.weight(u));
            assert!((50.0..=150.0).contains(&a.weight(u)));
        }
    }
}
