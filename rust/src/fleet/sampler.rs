//! Per-round cohort selection over an arbitrarily large client population.
//!
//! Selection is a pure function of `(root seed, round)` through the same
//! splittable streams as every other source of randomness (assumption A3
//! plumbing): re-running a round, or running rounds out of order, always
//! selects the same cohort. Selected ids are returned in ascending order —
//! a canonical order that downstream fan-out relies on for reproducibility.

use crate::prng::{CommonRandomness, Rng, StreamKind, Xoshiro256pp};
use std::collections::HashSet;

/// Cohort selection policy.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SamplerKind {
    /// Every client, every round — the paper's (and the seed
    /// `RoundDriver`'s) degenerate preset.
    Full,
    /// `cohort` clients uniformly without replacement (Floyd's algorithm,
    /// O(cohort) time and memory — never O(population)).
    Uniform { cohort: usize },
    /// `cohort` clients without replacement, inclusion probability tilted
    /// by client weight (shard size): Efraimidis–Spirakis exponential
    /// keys, O(population log population) per round.
    Weighted { cohort: usize },
    /// A pinned roster (ablations / reproducing a specific trace).
    Fixed { members: Vec<usize> },
}

impl SamplerKind {
    /// Number of updates the server wants to aggregate per round.
    pub fn target(&self, population: usize) -> usize {
        match self {
            SamplerKind::Full => population,
            SamplerKind::Uniform { cohort } | SamplerKind::Weighted { cohort } => {
                (*cohort).min(population).max(1)
            }
            // Count distinct members — `select` dedups, and a quota above
            // the distinct roster size could never be met.
            SamplerKind::Fixed { members } => {
                let mut v = members.clone();
                v.sort_unstable();
                v.dedup();
                v.len()
            }
        }
    }
}

/// Deterministic cohort sampler: one selection stream per round, derived
/// from the shared root seed.
#[derive(Debug, Clone, Copy)]
pub struct CohortSampler {
    crand: CommonRandomness,
}

/// Sentinel "user" coordinate for the per-round selection stream (the
/// cohort is a server-side draw, not a per-client one).
const COHORT_STREAM_USER: u64 = u64::MAX;

impl CohortSampler {
    pub fn new(seed: u64) -> Self {
        Self { crand: CommonRandomness::new(seed) }
    }

    fn rng(&self, round: u64) -> Xoshiro256pp {
        self.crand.stream(COHORT_STREAM_USER, round, StreamKind::Cohort)
    }

    /// Select `count` distinct clients from `0..population` for `round`.
    /// `weight(u)` is consulted only by [`SamplerKind::Weighted`]. Ids are
    /// ascending; `count` is clamped to the population.
    pub fn select(
        &self,
        kind: &SamplerKind,
        population: usize,
        count: usize,
        weight: &dyn Fn(usize) -> f64,
        round: u64,
    ) -> Vec<usize> {
        assert!(population > 0, "empty client population");
        let count = count.min(population);
        match kind {
            SamplerKind::Full => (0..population).collect(),
            SamplerKind::Fixed { members } => {
                let mut v: Vec<usize> = members.clone();
                v.sort_unstable();
                v.dedup();
                assert!(
                    v.iter().all(|&u| u < population),
                    "fixed cohort member out of range"
                );
                v
            }
            SamplerKind::Uniform { .. } => {
                let mut rng = self.rng(round);
                floyd_sample(&mut rng, population, count)
            }
            SamplerKind::Weighted { .. } => {
                let mut rng = self.rng(round);
                weighted_sample(&mut rng, population, count, weight)
            }
        }
    }
}

/// Floyd's algorithm: `k` distinct uniform draws from `0..n` in O(k).
fn floyd_sample(rng: &mut impl Rng, n: usize, k: usize) -> Vec<usize> {
    debug_assert!(k <= n);
    let mut chosen: HashSet<usize> = HashSet::with_capacity(k);
    for j in (n - k)..n {
        let t = rng.gen_index(j + 1);
        if !chosen.insert(t) {
            chosen.insert(j);
        }
    }
    let mut v: Vec<usize> = chosen.into_iter().collect();
    v.sort_unstable();
    v
}

/// Efraimidis–Spirakis weighted sampling without replacement: draw
/// `u_i ~ U(0,1)` per client, keep the `k` largest keys `u_i^{1/w_i}`.
/// Ties (and zero weights) break on the client id, so the draw is fully
/// deterministic. O(n) per round via partition-select — no full sort of
/// the population.
fn weighted_sample(
    rng: &mut impl Rng,
    n: usize,
    k: usize,
    weight: &dyn Fn(usize) -> f64,
) -> Vec<usize> {
    debug_assert!(k <= n);
    if k == 0 {
        return Vec::new();
    }
    if k >= n {
        return (0..n).collect();
    }
    let mut keys: Vec<(f64, usize)> = (0..n)
        .map(|u| {
            let w = weight(u);
            let draw = rng.uniform();
            // ln(u)/w is a monotone transform of u^(1/w); avoids pow.
            let key = if w > 0.0 { draw.max(1e-300).ln() / w } else { f64::NEG_INFINITY };
            (key, u)
        })
        .collect();
    // Largest keys first; the id tie-break makes the order total, so the
    // top-k set is unique and the partition is deterministic.
    let desc = |a: &(f64, usize), b: &(f64, usize)| {
        b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
    };
    keys.select_nth_unstable_by(k - 1, desc);
    let mut v: Vec<usize> = keys[..k].iter().map(|&(_, u)| u).collect();
    v.sort_unstable();
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit_weight(_: usize) -> f64 {
        1.0
    }

    #[test]
    fn full_and_fixed() {
        let s = CohortSampler::new(1);
        assert_eq!(s.select(&SamplerKind::Full, 5, 5, &unit_weight, 0), vec![0, 1, 2, 3, 4]);
        let fixed = SamplerKind::Fixed { members: vec![4, 2, 2, 0] };
        assert_eq!(s.select(&fixed, 5, 3, &unit_weight, 9), vec![0, 2, 4]);
    }

    #[test]
    fn fixed_target_counts_distinct_members() {
        let kind = SamplerKind::Fixed { members: vec![2, 2, 3] };
        assert_eq!(kind.target(10), 2, "duplicate roster entries must not inflate the quota");
    }

    #[test]
    fn uniform_is_deterministic_per_round_and_distinct() {
        let s = CohortSampler::new(7);
        let kind = SamplerKind::Uniform { cohort: 50 };
        let a = s.select(&kind, 10_000, 50, &unit_weight, 3);
        let b = s.select(&kind, 10_000, 50, &unit_weight, 3);
        assert_eq!(a, b, "same (seed, round) must select the same cohort");
        assert_eq!(a.len(), 50);
        let mut d = a.clone();
        d.dedup();
        assert_eq!(d.len(), 50, "duplicate client selected");
        assert!(a.iter().all(|&u| u < 10_000));

        let c = s.select(&kind, 10_000, 50, &unit_weight, 4);
        assert_ne!(a, c, "different rounds should differ");
        let other = CohortSampler::new(8).select(&kind, 10_000, 50, &unit_weight, 3);
        assert_ne!(a, other, "different seeds should differ");
    }

    #[test]
    fn uniform_covers_population_over_rounds() {
        let s = CohortSampler::new(11);
        let kind = SamplerKind::Uniform { cohort: 8 };
        let mut seen = vec![false; 40];
        for round in 0..200 {
            for u in s.select(&kind, 40, 8, &unit_weight, round) {
                seen[u] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "some client was never sampled in 200 rounds");
    }

    #[test]
    fn weighted_prefers_heavy_clients() {
        let s = CohortSampler::new(13);
        let kind = SamplerKind::Weighted { cohort: 10 };
        // Client 0..10 carry 10× the weight of the rest.
        let w = |u: usize| if u < 10 { 10.0 } else { 1.0 };
        let mut heavy_hits = 0usize;
        let rounds = 300;
        for round in 0..rounds {
            heavy_hits +=
                s.select(&kind, 100, 10, &w, round).iter().filter(|&&u| u < 10).count();
        }
        // Heavy clients are 10% of the population with ~53% of the mass;
        // uniform sampling would hit them ~1/round.
        let per_round = heavy_hits as f64 / rounds as f64;
        assert!(per_round > 3.0, "weighted sampling ignored weights: {per_round}/round");
    }

    #[test]
    fn clamps_count_to_population() {
        let s = CohortSampler::new(3);
        let got = s.select(&SamplerKind::Uniform { cohort: 10 }, 4, 10, &unit_weight, 0);
        assert_eq!(got, vec![0, 1, 2, 3]);
    }
}
