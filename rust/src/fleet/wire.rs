//! Framed binary uplink messages.
//!
//! The seed runtime handed `quantizer::Encoded` structs to the server
//! in-memory, so the uplink metered an abstraction instead of bytes. The
//! fleet layer serializes every update into a self-describing frame and
//! meters the real serialized size; decode verifies integrity before any
//! payload bit reaches the aggregator.
//!
//! Frame layout (all integers little-endian):
//!
//! | offset | size | field |
//! |--------|------|-------|
//! | 0      | 4    | magic `0x4651_5655` (`"UVQF"`) |
//! | 4      | 1    | version (2) |
//! | 5      | 1    | codec id (`quantizer::codec_id`) |
//! | 6      | 1    | frame kind ([`FrameKind`]; 0 = uplink update) |
//! | 7      | 1    | reserved (0) |
//! | 8      | 8    | user id |
//! | 16     | 8    | round |
//! | 24     | 8    | exact payload bits |
//! | 32     | 4    | payload length in bytes |
//! | 36     | n    | payload (entropy-coded update) |
//! | 36+n   | 4    | CRC-32 (IEEE) over bytes `[0, 36+n)` |
//!
//! The exact bit count rides in the header so the uplink budget check
//! (`R·m` bits, headers included by the caller that meters `frame.len()`)
//! survives serialization: `bits ≤ 8·payload_len` is enforced on decode,
//! exactly like `UplinkChannel`'s phantom-bits check.
//!
//! Since the downlink subsystem (`fleet::downlink`) the same frame layout
//! carries server→client traffic: byte 6 — written as reserved-zero by
//! every historical encoder — is the **frame kind**. Kind 0 is the
//! original uplink update (all pre-existing frames decode unchanged),
//! kind 1 a compressed global-model-delta broadcast, kind 2 a full-model
//! resync. Unknown kinds are rejected with [`WireError::BadKind`].

use crate::quantizer::Encoded;
use std::fmt;

pub const MAGIC: u32 = 0x4651_5655; // "UVQF" as LE bytes
/// Frame version history:
/// * 1 — original framing; payloads entropy-coded with the bit-by-bit
///   adaptive range coder.
/// * 2 — identical frame layout, but range-coded payloads switched to the
///   table-driven symbol coder (`entropy::range::AdaptiveRangeCoder` v2);
///   version-1 payloads do not decode under v2 models, so decode rejects
///   them instead of folding garbage into the aggregate.
pub const VERSION: u8 = 2;
pub const HEADER_BYTES: usize = 36;
pub const TRAILER_BYTES: usize = 4;

/// Direction/semantics of a frame (header byte 6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    /// Client → server model update (the original, pre-downlink traffic;
    /// historical frames carry a zero here and decode as this kind).
    Update = 0,
    /// Server → client compressed global-model-delta broadcast.
    DownlinkDelta = 1,
    /// Server → client full-model resync (raw f32 little-endian model).
    DownlinkResync = 2,
}

impl FrameKind {
    fn from_byte(b: u8) -> Result<Self, WireError> {
        match b {
            0 => Ok(FrameKind::Update),
            1 => Ok(FrameKind::DownlinkDelta),
            2 => Ok(FrameKind::DownlinkResync),
            other => Err(WireError::BadKind(other)),
        }
    }
}

/// A decoded frame (uplink update or downlink broadcast — see `kind`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    pub user: u64,
    pub round: u64,
    pub codec: u8,
    pub kind: FrameKind,
    pub payload: Encoded,
}

/// Frame decode failures — every variant is observable fault-injection
/// surface for the fleet simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireError {
    /// Buffer shorter than a minimal frame, or shorter than its own
    /// declared payload length.
    Truncated { have: usize, need: usize },
    BadMagic(u32),
    BadVersion(u8),
    /// Frame kind byte (offset 6) outside the known [`FrameKind`] set.
    BadKind(u8),
    /// Buffer longer than header + payload + trailer.
    TrailingGarbage { extra: usize },
    /// Claimed exact bit count exceeds the physical payload.
    PhantomBits { bits: u64, capacity_bits: u64 },
    /// Checksum mismatch (corrupted in flight).
    Crc { expected: u32, actual: u32 },
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            WireError::Truncated { have, need } => {
                write!(f, "truncated frame: have {have} bytes, need {need}")
            }
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:#010x}"),
            WireError::BadVersion(v) => write!(f, "unsupported frame version {v}"),
            WireError::BadKind(k) => write!(f, "unknown frame kind {k}"),
            WireError::TrailingGarbage { extra } => {
                write!(f, "{extra} trailing bytes after frame")
            }
            WireError::PhantomBits { bits, capacity_bits } => {
                write!(f, "claimed {bits} bits exceeds physical payload of {capacity_bits} bits")
            }
            WireError::Crc { expected, actual } => {
                write!(f, "CRC mismatch: header says {expected:#010x}, payload hashes to {actual:#010x}")
            }
        }
    }
}

impl WireError {
    /// Short static label for quarantine accounting and telemetry span
    /// data (which must stay `Copy` — no formatted strings on that path).
    pub fn reason(self) -> &'static str {
        match self {
            WireError::Truncated { .. } => "truncated frame",
            WireError::BadMagic(_) => "bad frame magic",
            WireError::BadVersion(_) => "unsupported frame version",
            WireError::BadKind(_) => "unknown frame kind",
            WireError::TrailingGarbage { .. } => "trailing garbage",
            WireError::PhantomBits { .. } => "phantom bits header",
            WireError::Crc { .. } => "crc mismatch",
        }
    }
}

impl std::error::Error for WireError {}

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// CRC-32 (IEEE 802.3 polynomial, reflected).
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Total frame size for a payload of `payload_bytes`.
pub fn frame_len(payload_bytes: usize) -> usize {
    HEADER_BYTES + payload_bytes + TRAILER_BYTES
}

/// Serialize one encoded uplink update into a framed message
/// ([`FrameKind::Update`]; byte-identical to the pre-downlink framing).
pub fn encode_frame(user: u64, round: u64, codec: u8, enc: &Encoded) -> Vec<u8> {
    encode_frame_kind(user, round, codec, FrameKind::Update, enc)
}

/// Serialize one encoded payload into a framed message of `kind`.
pub fn encode_frame_kind(
    user: u64,
    round: u64,
    codec: u8,
    kind: FrameKind,
    enc: &Encoded,
) -> Vec<u8> {
    let mut buf = Vec::with_capacity(frame_len(enc.bytes.len()));
    buf.extend_from_slice(&MAGIC.to_le_bytes());
    buf.push(VERSION);
    buf.push(codec);
    buf.push(kind as u8);
    buf.push(0); // reserved
    buf.extend_from_slice(&user.to_le_bytes());
    buf.extend_from_slice(&round.to_le_bytes());
    buf.extend_from_slice(&(enc.bits as u64).to_le_bytes());
    buf.extend_from_slice(&(enc.bytes.len() as u32).to_le_bytes());
    buf.extend_from_slice(&enc.bytes);
    let crc = crc32(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());
    buf
}

fn le_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

fn le_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
}

/// Parse and verify one frame. The returned payload carries the exact bit
/// count, so `Encoded` round-trips losslessly through the wire.
pub fn decode_frame(buf: &[u8]) -> Result<Frame, WireError> {
    let min = HEADER_BYTES + TRAILER_BYTES;
    if buf.len() < min {
        return Err(WireError::Truncated { have: buf.len(), need: min });
    }
    let magic = le_u32(&buf[0..4]);
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    if buf[4] != VERSION {
        return Err(WireError::BadVersion(buf[4]));
    }
    let codec = buf[5];
    let kind = FrameKind::from_byte(buf[6])?;
    let user = le_u64(&buf[8..16]);
    let round = le_u64(&buf[16..24]);
    let bits = le_u64(&buf[24..32]);
    let len = le_u32(&buf[32..36]) as usize;
    let need = frame_len(len);
    if buf.len() < need {
        return Err(WireError::Truncated { have: buf.len(), need });
    }
    if buf.len() > need {
        return Err(WireError::TrailingGarbage { extra: buf.len() - need });
    }
    if bits > 8 * len as u64 {
        return Err(WireError::PhantomBits { bits, capacity_bits: 8 * len as u64 });
    }
    let body = HEADER_BYTES + len;
    let expected = le_u32(&buf[body..body + 4]);
    let actual = crc32(&buf[..body]);
    if expected != actual {
        return Err(WireError::Crc { expected, actual });
    }
    Ok(Frame {
        user,
        round,
        codec,
        kind,
        payload: Encoded { bytes: buf[HEADER_BYTES..body].to_vec(), bits: bits as usize },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enc(bytes: Vec<u8>, bits: usize) -> Encoded {
        Encoded { bytes, bits }
    }

    #[test]
    fn crc32_known_vector() {
        // Standard check value for "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_preserves_payload_and_exact_bits() {
        let e = enc(vec![0xAB, 0xCD, 0x0F], 21);
        let buf = encode_frame(42, 7, 3, &e);
        assert_eq!(buf.len(), frame_len(3));
        let f = decode_frame(&buf).unwrap();
        assert_eq!(f.user, 42);
        assert_eq!(f.round, 7);
        assert_eq!(f.codec, 3);
        assert_eq!(f.kind, FrameKind::Update);
        assert_eq!(f.payload.bytes, e.bytes);
        assert_eq!(f.payload.bits, 21);
    }

    #[test]
    fn downlink_kinds_roundtrip_and_uplink_bytes_are_unchanged() {
        let e = enc(vec![1, 2, 3], 20);
        for kind in [FrameKind::DownlinkDelta, FrameKind::DownlinkResync] {
            let buf = encode_frame_kind(11, 4, 2, kind, &e);
            assert_eq!(buf[6], kind as u8);
            let f = decode_frame(&buf).unwrap();
            assert_eq!(f.kind, kind);
            assert_eq!(f.payload.bytes, e.bytes);
        }
        // The uplink entry point must keep emitting kind-0 frames with the
        // historical reserved-zero bytes at offsets 6..8.
        let up = encode_frame(11, 4, 2, &e);
        assert_eq!(&up[6..8], &[0, 0]);
        assert_eq!(up, encode_frame_kind(11, 4, 2, FrameKind::Update, &e));
    }

    #[test]
    fn unknown_frame_kind_is_rejected() {
        let mut buf = encode_frame(1, 2, 3, &enc(vec![7], 8));
        buf[6] = 3; // first unassigned kind
        let body = HEADER_BYTES + 1;
        let crc = crc32(&buf[..body]);
        buf[body..body + 4].copy_from_slice(&crc.to_le_bytes());
        assert!(matches!(decode_frame(&buf), Err(WireError::BadKind(3))));
    }

    #[test]
    fn empty_payload_frames() {
        let e = enc(vec![], 0);
        let f = decode_frame(&encode_frame(0, 0, 0, &e)).unwrap();
        assert!(f.payload.bytes.is_empty());
        assert_eq!(f.payload.bits, 0);
    }

    #[test]
    fn corruption_anywhere_is_detected() {
        let e = enc((0..32).collect(), 32 * 8);
        let buf = encode_frame(9, 1, 5, &e);
        // Flip one bit in every byte position; every mutation must fail
        // decode (header fields fail structurally, payload fails CRC).
        for pos in 0..buf.len() {
            let mut bad = buf.clone();
            bad[pos] ^= 0x10;
            assert!(decode_frame(&bad).is_err(), "undetected corruption at byte {pos}");
        }
    }

    #[test]
    fn truncation_and_garbage_rejected() {
        let buf = encode_frame(1, 2, 3, &enc(vec![1, 2, 3, 4], 30));
        assert!(matches!(
            decode_frame(&buf[..buf.len() - 1]),
            Err(WireError::Truncated { .. })
        ));
        assert!(matches!(decode_frame(&buf[..10]), Err(WireError::Truncated { .. })));
        let mut long = buf.clone();
        long.push(0);
        assert!(matches!(
            decode_frame(&long),
            Err(WireError::TrailingGarbage { extra: 1 })
        ));
    }

    #[test]
    fn phantom_bits_rejected() {
        // Hand-build a frame whose bit count exceeds its payload.
        let mut buf = encode_frame(1, 2, 3, &enc(vec![0xFF], 8));
        buf[24..32].copy_from_slice(&9u64.to_le_bytes());
        let body = HEADER_BYTES + 1;
        let crc = crc32(&buf[..body]);
        buf[body..body + 4].copy_from_slice(&crc.to_le_bytes());
        assert!(matches!(
            decode_frame(&buf),
            Err(WireError::PhantomBits { bits: 9, capacity_bits: 8 })
        ));
    }
}
