//! Heterogeneous uplink capacity models.
//!
//! UVeQFed's premise is conveying model updates over *rate-constrained*
//! uplink channels (§V-A scales the lattice so codewords fit `R·m` bits)
//! — but a real fleet of millions of devices does not share one pipe:
//! capacities span orders of magnitude and drift over time (FedVQCS,
//! arXiv 2204.07692, and "Federated Learning With Quantized Global Model
//! Updates", arXiv 2006.10672, both evaluate exactly this regime). This
//! module models the per-client uplink capacity `C_u(t)` in **bits per
//! model entry** and the coordinator's rate controller
//! ([`crate::coordinator::rate_control`]) decides how much of each
//! client's capacity to actually spend.
//!
//! Every draw is a pure function of `(root seed, client, round)` through
//! the shared randomness streams ([`StreamKind::Channel`]) — capacities
//! are bit-reproducible and independent of cohort selection, worker
//! interleaving, or query order. The Markov fading chain is advanced by
//! the round clock: [`Channel::capacity`] walks each client's chain from
//! its last observed round (round 0 on first touch), so per-round
//! advancement is O(1) amortized and the state at round `t` never depends
//! on *which* rounds the client was sampled in.

use crate::prng::{CommonRandomness, Rng, StreamKind};
use std::collections::HashMap;
use std::sync::Mutex;

/// Per-client uplink capacity model (bits per model entry).
#[derive(Debug, Clone, PartialEq)]
pub enum ChannelModel {
    /// Every client, every round, the same capacity — the legacy
    /// "same pipe for everyone" degenerate preset.
    Fixed { rate: f64 },
    /// Static capacity classes: client `u` is pinned (by a seeded hash)
    /// to `rates[tier(u)]` for the whole run — device classes
    /// (wifi / LTE / constrained IoT).
    Tiers { rates: Vec<f64> },
    /// I.i.d. per-(client, round) log-normal bandwidth draws:
    /// `median · exp(σ·Z)` — heavy-tailed cell-edge variation.
    LogNormal { median: f64, sigma: f64 },
    /// Two-state Gilbert–Elliott fading per client: capacity `good` in
    /// the good state, `bad` in the bad state, with per-round transition
    /// probabilities. The chain starts from its stationary distribution
    /// and advances one step per round.
    Markov { good: f64, bad: f64, p_good_to_bad: f64, p_bad_to_good: f64 },
}

impl ChannelModel {
    /// Preset by CLI/config name, parameterized by the run's base rate
    /// `R` so presets stay meaningful at any budget scale.
    pub fn by_name(name: &str, base_rate: f64) -> crate::Result<Self> {
        crate::ensure!(
            base_rate.is_finite() && base_rate > 0.0,
            "channel presets need a positive base rate (got {base_rate})"
        );
        Ok(match name {
            "uniform" | "fixed" => ChannelModel::Fixed { rate: base_rate },
            // Three device classes around R: constrained, nominal, fast.
            "tiers" => ChannelModel::Tiers {
                rates: vec![0.5 * base_rate, base_rate, 2.0 * base_rate],
            },
            "lognormal" => ChannelModel::LogNormal { median: base_rate, sigma: 0.6 },
            "markov" => ChannelModel::Markov {
                good: 2.0 * base_rate,
                bad: 0.25 * base_rate,
                p_good_to_bad: 0.2,
                p_bad_to_good: 0.4,
            },
            other => crate::bail!(
                "unknown channel preset '{other}' (uniform|tiers|lognormal|markov)"
            ),
        })
    }

    /// Validate model parameters (config values arrive unchecked).
    pub fn validate(&self) -> crate::Result<()> {
        fn pos(v: f64, what: &str) -> crate::Result<()> {
            crate::ensure!(v.is_finite() && v > 0.0, "channel {what} must be > 0 (got {v})");
            Ok(())
        }
        match self {
            ChannelModel::Fixed { rate } => pos(*rate, "rate"),
            ChannelModel::Tiers { rates } => {
                crate::ensure!(!rates.is_empty(), "channel tiers must be non-empty");
                for &r in rates {
                    pos(r, "tier rate")?;
                }
                Ok(())
            }
            ChannelModel::LogNormal { median, sigma } => {
                pos(*median, "median")?;
                crate::ensure!(
                    sigma.is_finite() && *sigma >= 0.0,
                    "channel sigma must be ≥ 0 (got {sigma})"
                );
                Ok(())
            }
            ChannelModel::Markov { good, bad, p_good_to_bad, p_bad_to_good } => {
                pos(*good, "good-state rate")?;
                pos(*bad, "bad-state rate")?;
                for (p, what) in
                    [(*p_good_to_bad, "p_good_to_bad"), (*p_bad_to_good, "p_bad_to_good")]
                {
                    crate::ensure!(
                        (0.0..=1.0).contains(&p),
                        "channel {what} must be in [0, 1] (got {p})"
                    );
                }
                crate::ensure!(
                    *p_good_to_bad + *p_bad_to_good > 0.0,
                    "channel Markov chain must mix (both transition probabilities are 0)"
                );
                Ok(())
            }
        }
    }
}

/// Cached Markov fading state of one client.
#[derive(Debug, Clone, Copy)]
struct MarkovCell {
    /// Round the cached state applies to.
    round: u64,
    good: bool,
}

/// A seeded channel instance: the model plus the lazily-advanced Markov
/// state (other models are stateless functions of `(user, round)`).
#[derive(Debug)]
pub struct Channel {
    model: ChannelModel,
    crand: CommonRandomness,
    /// Per-client fading chains, advanced as the round clock moves. The
    /// mutex is touched once per (selected client, round) on the
    /// coordinator thread — never inside the worker fan-out.
    markov: Mutex<HashMap<u64, MarkovCell>>,
}

impl Channel {
    pub fn new(model: ChannelModel, seed: u64) -> Self {
        Self { model, crand: CommonRandomness::new(seed), markov: Mutex::new(HashMap::new()) }
    }

    pub fn model(&self) -> &ChannelModel {
        &self.model
    }

    /// Uniform draw for `(user, round)` from the channel stream.
    fn draw(&self, user: u64, round: u64) -> f64 {
        self.crand.stream(user, round, StreamKind::Channel).uniform()
    }

    /// Capacity of `user`'s uplink in `round`, bits per model entry.
    /// Deterministic in `(seed, user, round)` for every model.
    pub fn capacity(&self, user: u64, round: u64) -> f64 {
        match self.model {
            ChannelModel::Fixed { rate } => rate,
            ChannelModel::Tiers { ref rates } => {
                // Stable per-client class: seeded hash, constant over rounds.
                let tier =
                    self.crand.derive_seed(user, 0, StreamKind::Channel) as usize % rates.len();
                rates[tier]
            }
            ChannelModel::LogNormal { median, sigma } => {
                let z = self.crand.stream(user, round, StreamKind::Channel).normal();
                median * (sigma * z).exp()
            }
            ChannelModel::Markov { good, bad, p_good_to_bad, p_bad_to_good } => {
                let state = self.markov_state(user, round, p_good_to_bad, p_bad_to_good);
                if state {
                    good
                } else {
                    bad
                }
            }
        }
    }

    /// Markov state (true = good) of `user` at `round`: advance the
    /// cached chain forward, or replay from round 0 when queried behind
    /// the cache (pure function of `(seed, user, round)` either way).
    fn markov_state(&self, user: u64, round: u64, p_gb: f64, p_bg: f64) -> bool {
        let mut cells = self.markov.lock().unwrap();
        let mut cell = match cells.get(&user) {
            Some(&c) if c.round <= round => c,
            _ => {
                // Stationary start: P(good) = p_bg / (p_gb + p_bg).
                let pi_good = p_bg / (p_gb + p_bg);
                MarkovCell { round: 0, good: self.draw(user, 0) < pi_good }
            }
        };
        while cell.round < round {
            cell.round += 1;
            let u = self.draw(user, cell.round);
            cell.good = if cell.good { u >= p_gb } else { u < p_bg };
        }
        cells.insert(user, cell);
        cell.good
    }
}

/// Asymmetric link: independent uplink and downlink capacity models over
/// decorrelated randomness streams, opening the cheap-uplink vs
/// cheap-downlink scenario axis (`examples/downlink_asymmetry.rs`). The
/// downlink half is seeded with [`DOWNLINK_SEED_SALT`] so a client's
/// up and down draws are independent even under the same model.
///
/// [`DOWNLINK_SEED_SALT`]: crate::fleet::downlink::DOWNLINK_SEED_SALT
#[derive(Debug)]
pub struct AsymmetricChannel {
    up: Channel,
    down: Channel,
}

impl AsymmetricChannel {
    pub fn new(up: ChannelModel, down: ChannelModel, seed: u64) -> Self {
        Self {
            up: Channel::new(up, seed),
            down: Channel::new(down, seed ^ crate::fleet::downlink::DOWNLINK_SEED_SALT),
        }
    }

    pub fn up(&self) -> &Channel {
        &self.up
    }

    pub fn down(&self) -> &Channel {
        &self.down
    }

    /// Uplink capacity of `user` in `round`, bits per model entry.
    pub fn capacity_up(&self, user: u64, round: u64) -> f64 {
        self.up.capacity(user, round)
    }

    /// Downlink capacity of `user` in `round`, bits per model entry.
    pub fn capacity_down(&self, user: u64, round: u64) -> f64 {
        self.down.capacity(user, round)
    }

    /// Split into `(uplink, downlink)` halves — the uplink feeds
    /// [`crate::fleet::RatePlan`], the downlink feeds
    /// [`crate::coordinator::broadcast::BroadcastPlanner`].
    pub fn into_parts(self) -> (Channel, Channel) {
        (self.up, self.down)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_construct_and_validate() {
        for name in ["uniform", "tiers", "lognormal", "markov"] {
            let m = ChannelModel::by_name(name, 2.0).unwrap();
            m.validate().unwrap();
        }
        assert!(ChannelModel::by_name("nope", 2.0).is_err());
        assert!(ChannelModel::by_name("tiers", 0.0).is_err());
    }

    #[test]
    fn validation_rejects_bad_parameters() {
        assert!(ChannelModel::Fixed { rate: -1.0 }.validate().is_err());
        assert!(ChannelModel::Tiers { rates: vec![] }.validate().is_err());
        assert!(ChannelModel::Tiers { rates: vec![1.0, 0.0] }.validate().is_err());
        assert!(
            ChannelModel::LogNormal { median: 1.0, sigma: -0.1 }.validate().is_err()
        );
        assert!(ChannelModel::Markov {
            good: 2.0,
            bad: 1.0,
            p_good_to_bad: 1.5,
            p_bad_to_good: 0.5
        }
        .validate()
        .is_err());
        assert!(ChannelModel::Markov {
            good: 2.0,
            bad: 1.0,
            p_good_to_bad: 0.0,
            p_bad_to_good: 0.0
        }
        .validate()
        .is_err());
    }

    #[test]
    fn tiers_are_stable_per_client_and_cover_all_classes() {
        let ch = Channel::new(ChannelModel::by_name("tiers", 2.0).unwrap(), 7);
        let mut seen = std::collections::BTreeSet::new();
        for u in 0..300u64 {
            let c0 = ch.capacity(u, 0);
            assert_eq!(c0, ch.capacity(u, 5), "tier must not change across rounds");
            seen.insert(c0.to_bits());
        }
        assert_eq!(seen.len(), 3, "300 clients must cover all 3 tiers");
    }

    #[test]
    fn lognormal_is_deterministic_and_round_varying() {
        let model = ChannelModel::LogNormal { median: 2.0, sigma: 0.6 };
        let a = Channel::new(model.clone(), 9);
        let b = Channel::new(model, 9);
        assert_eq!(a.capacity(4, 2), b.capacity(4, 2));
        assert_ne!(a.capacity(4, 2), a.capacity(4, 3), "capacity must vary by round");
        // Median sanity over many draws.
        let mut v: Vec<f64> = (0..4001u64).map(|u| a.capacity(u, 0)).collect();
        v.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let med = v[v.len() / 2];
        assert!((med - 2.0).abs() < 0.15, "median {med}");
    }

    #[test]
    fn markov_state_is_query_order_independent() {
        let model = ChannelModel::Markov {
            good: 4.0,
            bad: 0.5,
            p_good_to_bad: 0.3,
            p_bad_to_good: 0.3,
        };
        // Forward walk…
        let fwd = Channel::new(model.clone(), 11);
        let forward: Vec<f64> = (0..40u64).map(|r| fwd.capacity(5, r)).collect();
        // …must equal arbitrary-order queries (each replays from 0 or
        // advances the cache).
        let rnd = Channel::new(model, 11);
        let order = [7u64, 0, 39, 12, 7, 3, 39, 20];
        for &r in &order {
            assert_eq!(rnd.capacity(5, r), forward[r as usize], "round {r}");
        }
    }

    #[test]
    fn asymmetric_halves_are_decorrelated_and_deterministic() {
        let model = ChannelModel::LogNormal { median: 2.0, sigma: 0.6 };
        let a = AsymmetricChannel::new(model.clone(), model.clone(), 17);
        let b = AsymmetricChannel::new(model.clone(), model, 17);
        assert_eq!(a.capacity_up(3, 1), b.capacity_up(3, 1));
        assert_eq!(a.capacity_down(3, 1), b.capacity_down(3, 1));
        // Same model both ways, yet the draws must not mirror each other.
        let mirrored = (0..200u64)
            .filter(|&u| a.capacity_up(u, 0).to_bits() == a.capacity_down(u, 0).to_bits())
            .count();
        assert_eq!(mirrored, 0, "{mirrored}/200 up/down draws coincide");
        let (up, down) = a.into_parts();
        assert_eq!(up.capacity(3, 1), b.capacity_up(3, 1));
        assert_eq!(down.capacity(3, 1), b.capacity_down(3, 1));
    }

    #[test]
    fn markov_visits_both_states() {
        let ch = Channel::new(ChannelModel::by_name("markov", 2.0).unwrap(), 13);
        let caps: Vec<f64> = (0..200u64).map(|r| ch.capacity(1, r)).collect();
        let goods = caps.iter().filter(|&&c| c > 2.0).count();
        assert!(goods > 20 && goods < 180, "chain stuck: {goods}/200 good rounds");
    }
}
