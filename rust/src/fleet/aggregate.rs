//! Streaming O(m) aggregation with order-independent determinism.
//!
//! The server folds each decoded update into a running `Σ α_k ĥ_k` as
//! frames arrive, so its memory is O(m) — independent of how many clients
//! report in a round. Floating-point addition is not associative, so a
//! naive f64 accumulator would make the aggregate depend on arrival order
//! and worker count. Instead every contribution `α_k·ĥ_k[i]` is rounded
//! **once** to a 2⁻⁴⁰ fixed-point grid and accumulated in `i128`; integer
//! addition is exactly associative and commutative, so any arrival order
//! and any parallelism produce the same bits.
//!
//! Precision: the per-contribution rounding error is ≤ 2⁻⁴¹ ≈ 4.5·10⁻¹³,
//! i.e. Σα_k·2⁻⁴¹ ≤ 2⁻⁴¹ total per entry for normalized weights — far
//! below every distortion this system measures. Contributions saturate at
//! |α·h| ≤ 2⁶³/2⁴⁰ ≈ 8.4·10⁶ per entry (a diverged run, not a real
//! update), which leaves ≥ 2⁶⁴ folds of headroom before an `i128` could
//! overflow.

use crate::quantizer::{DecodeError, DecodeStream};

/// Fractional bits of the accumulation grid.
pub const SCALE_BITS: u32 = 40;
const SCALE: f64 = (1u64 << SCALE_BITS) as f64;

/// Order-independent streaming accumulator for `Σ α_k x_k` over `m`-entry
/// vectors.
#[derive(Debug, Clone)]
pub struct StreamingAggregator {
    acc: Vec<i128>,
    folds: usize,
    alpha_sum: f64,
}

impl StreamingAggregator {
    pub fn new(m: usize) -> Self {
        Self { acc: vec![0i128; m], folds: 0, alpha_sum: 0.0 }
    }

    pub fn m(&self) -> usize {
        self.acc.len()
    }

    /// Number of updates folded so far.
    pub fn folds(&self) -> usize {
        self.folds
    }

    /// Σ of the `alpha` arguments folded so far (≈1 when the caller
    /// normalizes over the aggregating set).
    pub fn alpha_sum(&self) -> f64 {
        self.alpha_sum
    }

    /// Server-side state size in bytes — O(m), independent of client count.
    pub fn mem_bytes(&self) -> usize {
        self.acc.len() * std::mem::size_of::<i128>()
    }

    /// Fold one weighted update into the accumulator.
    pub fn fold(&mut self, alpha: f64, update: &[f32]) {
        assert_eq!(
            update.len(),
            self.acc.len(),
            "update length {} != aggregator m {}",
            update.len(),
            self.acc.len()
        );
        self.fold_chunk(0, alpha, update);
        self.commit(alpha);
    }

    /// Fold one chunk of a weighted update at `offset` — the streaming
    /// server path: decode-stream chunks land here directly, so the
    /// server never materializes a per-user vector. Per-entry arithmetic
    /// is identical to [`Self::fold`]; call [`Self::commit`] exactly once
    /// per update after its last chunk.
    pub fn fold_chunk(&mut self, offset: usize, alpha: f64, chunk: &[f32]) {
        let end = offset + chunk.len();
        assert!(
            end <= self.acc.len(),
            "chunk [{offset}, {end}) out of bounds for aggregator m {}",
            self.acc.len()
        );
        for (a, &v) in self.acc[offset..end].iter_mut().zip(chunk) {
            // f64→i64 casts saturate, bounding every contribution to i64
            // range; widening to i128 then leaves overflow unreachable.
            *a += (alpha * v as f64 * SCALE).round() as i64 as i128;
        }
    }

    /// Record one completed update (after its chunks were folded via
    /// [`Self::fold_chunk`]).
    pub fn commit(&mut self, alpha: f64) {
        self.folds += 1;
        self.alpha_sum += alpha;
    }

    /// Drain a codec [`DecodeStream`] straight into the accumulator —
    /// chunks fold as they are decoded, O(chunk) transient memory. The
    /// stream must yield exactly `m` entries.
    ///
    /// A mid-stream decode error (or a stream of the wrong length)
    /// returns `Err` **with the already-folded chunks left in the
    /// accumulator** — callers that need rejection semantics must stage
    /// the stream into a scratch vector first and fold only on success
    /// (see `fleet::shard`).
    pub fn fold_stream(
        &mut self,
        alpha: f64,
        stream: &mut dyn DecodeStream,
    ) -> Result<(), DecodeError> {
        let mut offset = 0;
        while let Some(chunk) = stream.next_chunk()? {
            let end = offset + chunk.len();
            if end > self.acc.len() {
                return Err(DecodeError::Length { got: end, want: self.acc.len() });
            }
            self.fold_chunk(offset, alpha, chunk);
            offset = end;
        }
        if offset != self.acc.len() {
            return Err(DecodeError::Length { got: offset, want: self.acc.len() });
        }
        self.commit(alpha);
        Ok(())
    }

    /// Merge another accumulator (sharded-server reduction). Exact: the
    /// merged state equals folding both fold-sequences in any order.
    pub fn merge(&mut self, other: &StreamingAggregator) {
        assert_eq!(self.acc.len(), other.acc.len(), "merge m mismatch");
        for (a, &b) in self.acc.iter_mut().zip(&other.acc) {
            *a += b;
        }
        self.folds += other.folds;
        self.alpha_sum += other.alpha_sum;
    }

    /// Current value of entry `i`.
    pub fn value(&self, i: usize) -> f64 {
        self.acc[i] as f64 / SCALE
    }

    /// Materialize the aggregate as f64.
    pub fn to_vec(&self) -> Vec<f64> {
        self.acc.iter().map(|&a| a as f64 / SCALE).collect()
    }

    /// Add the aggregate into `w` (the server apply step `w ← w + Σα·ĥ`).
    pub fn apply_to(&self, w: &mut [f32]) {
        assert_eq!(w.len(), self.acc.len(), "apply m mismatch");
        for (wv, &a) in w.iter_mut().zip(&self.acc) {
            *wv += (a as f64 / SCALE) as f32;
        }
    }

    /// Mean squared per-entry difference between two aggregates — the
    /// measured Theorem-2 quantity when `a` folds decoded updates and `b`
    /// folds the true ones. Exactly zero for a lossless codec.
    pub fn mean_sq_diff(a: &StreamingAggregator, b: &StreamingAggregator) -> f64 {
        assert_eq!(a.acc.len(), b.acc.len(), "diff m mismatch");
        if a.acc.is_empty() {
            return 0.0;
        }
        a.acc
            .iter()
            .zip(&b.acc)
            .map(|(&x, &y)| {
                let d = (x - y) as f64 / SCALE;
                d * d
            })
            .sum::<f64>()
            / a.acc.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Rng, Xoshiro256pp};

    fn random_update(seed: u64, m: usize) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..m).map(|_| rng.normal_f32() * 0.1).collect()
    }

    #[test]
    fn arrival_order_does_not_change_the_aggregate() {
        let m = 257;
        let updates: Vec<Vec<f32>> = (0..12).map(|u| random_update(u, m)).collect();
        let alphas: Vec<f64> = (0..12).map(|u| 1.0 / (u + 1) as f64).collect();

        let mut fwd = StreamingAggregator::new(m);
        for (u, up) in updates.iter().enumerate() {
            fwd.fold(alphas[u], up);
        }
        let mut rev = StreamingAggregator::new(m);
        for (u, up) in updates.iter().enumerate().rev() {
            rev.fold(alphas[u], up);
        }
        assert_eq!(fwd.acc, rev.acc);
        assert_eq!(fwd.to_vec(), rev.to_vec());
    }

    #[test]
    fn merge_equals_single_stream() {
        let m = 64;
        let updates: Vec<Vec<f32>> = (0..8).map(|u| random_update(100 + u, m)).collect();
        let mut whole = StreamingAggregator::new(m);
        let mut left = StreamingAggregator::new(m);
        let mut right = StreamingAggregator::new(m);
        for (u, up) in updates.iter().enumerate() {
            whole.fold(0.125, up);
            if u % 2 == 0 {
                left.fold(0.125, up);
            } else {
                right.fold(0.125, up);
            }
        }
        left.merge(&right);
        assert_eq!(left.acc, whole.acc);
        assert_eq!(left.folds(), whole.folds());
    }

    #[test]
    fn chunked_fold_is_bit_identical_to_whole_fold() {
        let m = 777;
        let updates: Vec<Vec<f32>> = (0..5).map(|u| random_update(20 + u, m)).collect();
        let mut whole = StreamingAggregator::new(m);
        let mut chunked = StreamingAggregator::new(m);
        for (u, up) in updates.iter().enumerate() {
            let alpha = 0.2 + u as f64 * 0.01;
            whole.fold(alpha, up);
            for (c, chunk) in up.chunks(53).enumerate() {
                chunked.fold_chunk(c * 53, alpha, chunk);
            }
            chunked.commit(alpha);
        }
        assert_eq!(whole.acc, chunked.acc);
        assert_eq!(whole.folds(), chunked.folds());
        assert_eq!(whole.alpha_sum(), chunked.alpha_sum());
    }

    #[test]
    fn fold_stream_matches_fold_of_materialized_decode() {
        use crate::quantizer::{self, CodecContext};
        let m = 1500;
        let up = random_update(9, m);
        let codec = quantizer::make("uveqfed-l2").unwrap();
        let ctx = CodecContext::new(3, 4, 11, 4.0);
        let enc = codec.encode(&up, &ctx);
        let mut via_stream = StreamingAggregator::new(m);
        let mut stream = codec.decoder(&enc, m, &ctx);
        via_stream.fold_stream(0.7, stream.as_mut()).unwrap();
        let mut via_vec = StreamingAggregator::new(m);
        via_vec.fold(0.7, &codec.decode(&enc, m, &ctx));
        assert_eq!(via_stream.acc, via_vec.acc);
        assert_eq!(via_stream.folds(), 1);
        assert!((via_stream.alpha_sum() - 0.7).abs() < 1e-15);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn fold_chunk_rejects_overflow_past_m() {
        let mut agg = StreamingAggregator::new(4);
        agg.fold_chunk(2, 1.0, &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn identical_streams_have_zero_diff() {
        let m = 100;
        let up = random_update(7, m);
        let mut a = StreamingAggregator::new(m);
        let mut b = StreamingAggregator::new(m);
        a.fold(0.5, &up);
        b.fold(0.5, &up);
        assert_eq!(StreamingAggregator::mean_sq_diff(&a, &b), 0.0);
    }

    #[test]
    fn value_approximates_weighted_sum() {
        let m = 16;
        let up = random_update(3, m);
        let mut agg = StreamingAggregator::new(m);
        agg.fold(0.25, &up);
        agg.fold(0.75, &up);
        for i in 0..m {
            let want = up[i] as f64;
            assert!((agg.value(i) - want).abs() < 1e-9, "{} vs {want}", agg.value(i));
        }
        assert!((agg.alpha_sum() - 1.0).abs() < 1e-12);
        assert_eq!(agg.folds(), 2);
    }

    #[test]
    fn apply_adds_in_place() {
        let m = 8;
        let mut agg = StreamingAggregator::new(m);
        let halves = vec![0.5f32; m];
        agg.fold(1.0, &halves);
        let mut w = vec![1.0f32; m];
        agg.apply_to(&mut w);
        for &v in &w {
            assert!((v - 1.5).abs() < 1e-6);
        }
    }

    #[test]
    fn memory_is_o_m_not_o_k() {
        let m = 1000;
        let mut agg = StreamingAggregator::new(m);
        let base = agg.mem_bytes();
        for u in 0..50 {
            agg.fold(0.02, &random_update(u, m));
        }
        assert_eq!(agg.mem_bytes(), base, "accumulator grew with client count");
        assert_eq!(base, m * 16);
    }
}
