//! Compressed global-model broadcast: the downlink half of the wire.
//!
//! UVeQFed's setting is a rate-constrained channel in *both* directions,
//! but until this module the simulation compressed only the uplink.
//! Following "Federated Learning With Quantized Global Model Updates"
//! (arXiv 2006.10672), the server broadcasts each cohort member a
//! **global-model delta** `w_t − w_ref(u)` coded against that client's
//! last-synced reference, with an **error-feedback accumulator** so the
//! quantization residue of round *t*'s broadcast is folded into round
//! *t+1*'s delta:
//!
//! ```text
//! d_t(u)  = w_t − ŵ_ref(u) + e_t(u)        (EF-compensated delta)
//! d̂_t(u)  = Q(d_t(u))                       (shared-dither codec)
//! ŵ_t(u)  = ŵ_ref(u) + d̂_t(u)              (client reconstruction)
//! e_{t+1}(u) = d_t(u) − d̂_t(u)             (residue carried forward)
//! ```
//!
//! The recursion telescopes — `ŵ_t = w_t + e_t − e_{t+1}` — so the
//! broadcast error stays bounded instead of compounding, which is exactly
//! the mechanism 2006.10672 shows preserves convergence.
//!
//! **Stale-model tracking.** A [`SyncTable`] keeps a compact per-client
//! record: the reference round, the model the client actually holds
//! (its previous reconstruction), and the EF residue. A client that
//! missed rounds gets its delta coded against that *stale* reference —
//! no resend of history — and a periodic full-model resync rule
//! (`resync_every`) bounds how stale a reference may get before the
//! server ships the raw model again. First contact is always a resync.
//!
//! **Lossless short-circuit.** A codec that is not rate-constrained
//! (`identity`) gains nothing from delta coding — the delta costs the
//! same 32 bits/entry as the model itself — so every broadcast takes the
//! resync path. That keeps the lossless downlink exactly transparent:
//! the client holds `w_t` bit-for-bit, and an identity-downlink run
//! reproduces an uplink-only run exactly.
//!
//! **Determinism.** Broadcasts run on the coordinator thread in
//! ascending arrival order, and the codec dither is drawn from
//! `CodecContext::new(user, round, seed ^ DOWNLINK_SEED_SALT, rate)` —
//! pure in its inputs and decorrelated from the uplink's dither stream.
//! Client reconstructions are therefore bit-identical for any worker or
//! shard count, traced or not. See `DESIGN.md` §12.

use crate::fleet::wire::{self, FrameKind};
use crate::quantizer::{self, CodecContext, Encoded, UpdateCodec, DEFAULT_CHUNK};
use std::collections::HashMap;

/// Seed salt decorrelating downlink dither from the uplink stream for
/// the same `(user, round)`: both sides of the link derive their common
/// randomness from the run seed, so without a salt the broadcast would
/// reuse the exact dither sequence of that client's uplink encode.
pub const DOWNLINK_SEED_SALT: u64 = 0x444F_574E_4C4E_4B21;

/// Per-round downlink configuration, carried on
/// [`crate::fleet::RoundSpec`] alongside `rate_override`/`telemetry`.
#[derive(Clone, Copy)]
pub struct DownlinkSpec<'a> {
    /// Broadcast codec: server-side encode and the simulated client
    /// decode share dither through the common-randomness contract (A3).
    pub codec: &'a dyn UpdateCodec,
    /// Downlink bit budget per model entry.
    pub rate: f64,
    /// Full-model resync when a client's reference is more than this
    /// many rounds stale (0 = resync only on first contact).
    pub resync_every: u64,
}

impl<'a> DownlinkSpec<'a> {
    /// Downlink at `rate` bits/entry with first-contact-only resyncs.
    pub fn new(codec: &'a dyn UpdateCodec, rate: f64) -> Self {
        Self { codec, rate, resync_every: 0 }
    }

    /// Set the periodic full-model resync staleness bound.
    pub fn with_resync_every(mut self, rounds: u64) -> Self {
        self.resync_every = rounds;
        self
    }
}

impl std::fmt::Debug for DownlinkSpec<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DownlinkSpec")
            .field("codec", &self.codec.name())
            .field("rate", &self.rate)
            .field("resync_every", &self.resync_every)
            .finish()
    }
}

/// What one broadcast did: the client's new model plus the accounting
/// the round report, telemetry spans, and tests reconcile against.
#[derive(Debug, Clone, PartialEq)]
pub struct BroadcastOutcome {
    /// The model the client holds after applying this broadcast.
    pub reconstruction: Vec<f32>,
    /// Serialized downlink frame bytes (header + payload + CRC).
    pub frame_bytes: usize,
    /// Exact coded payload bits.
    pub payload_bits: usize,
    /// Bit budget assigned (⌊rate·m⌋ for a delta, 32·m for a resync).
    pub assigned_bits: usize,
    /// True when this broadcast was a full-model resync.
    pub resync: bool,
    /// Rounds the client's reference lagged (`round + 1` on first
    /// contact: the client had never been synced).
    pub staleness: u64,
    /// Reference round the delta was coded against (`round` for resync).
    pub ref_round: u64,
    /// ‖d − d̂‖² of this broadcast (0 for a resync).
    pub sq_err: f64,
}

/// One tracked client: its reference round, the model it holds (the
/// previous reconstruction), and the error-feedback residue.
#[derive(Debug, Clone)]
struct ClientSync {
    ref_round: u64,
    w_ref: Vec<f32>,
    err: Vec<f32>,
}

/// Per-client stale-model table with error-feedback accumulators — the
/// server's compact record of what every contacted device holds.
#[derive(Debug, Default)]
pub struct SyncTable {
    clients: HashMap<u64, ClientSync>,
}

impl SyncTable {
    /// Number of clients with tracked state.
    pub fn len(&self) -> usize {
        self.clients.len()
    }

    /// True when no client has been broadcast to yet.
    pub fn is_empty(&self) -> bool {
        self.clients.is_empty()
    }

    /// The round `user`'s reference model was last synced at.
    pub fn ref_round(&self, user: u64) -> Option<u64> {
        self.clients.get(&user).map(|c| c.ref_round)
    }

    /// Rounds `user`'s reference lags behind `round` (`round + 1` when
    /// the client has never been contacted).
    pub fn staleness(&self, user: u64, round: u64) -> u64 {
        match self.clients.get(&user) {
            Some(c) => round.saturating_sub(c.ref_round),
            None => round.saturating_add(1),
        }
    }

    /// Encode one broadcast of the global model `w` to `user` and apply
    /// it to the table. Coordinator-thread only; deterministic in
    /// `(table state, codec, rate, resync_every, seed, round, user, w)`.
    #[allow(clippy::too_many_arguments)]
    pub fn broadcast(
        &mut self,
        codec: &dyn UpdateCodec,
        rate: f64,
        resync_every: u64,
        seed: u64,
        round: u64,
        user: u64,
        w: &[f32],
    ) -> BroadcastOutcome {
        let m = w.len();
        let staleness = self.staleness(user, round);
        let wire_codec =
            quantizer::codec_id(&codec.name()).unwrap_or(quantizer::CODEC_ID_UNREGISTERED);
        let full_sync = match self.clients.get(&user) {
            None => true,
            Some(c) => {
                c.w_ref.len() != m
                    || (resync_every > 0 && staleness > resync_every)
                    || !codec.rate_constrained()
            }
        };

        if full_sync {
            // Raw f32 little-endian model: the client now holds `w`
            // bit-for-bit, and the EF residue starts clean.
            let mut bytes = Vec::with_capacity(4 * m);
            for &x in w {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
            let enc = Encoded { bytes, bits: 32 * m };
            let frame =
                wire::encode_frame_kind(user, round, wire_codec, FrameKind::DownlinkResync, &enc);
            self.clients.insert(
                user,
                ClientSync { ref_round: round, w_ref: w.to_vec(), err: vec![0.0; m] },
            );
            return BroadcastOutcome {
                reconstruction: w.to_vec(),
                frame_bytes: frame.len(),
                payload_bits: enc.bits,
                assigned_bits: 32 * m,
                resync: true,
                staleness,
                ref_round: round,
                sq_err: 0.0,
            };
        }

        let entry = self.clients.get_mut(&user).expect("checked above");
        let ref_round = entry.ref_round;
        // EF-compensated delta against the client's actual (possibly
        // stale) reference.
        let mut d = Vec::with_capacity(m);
        for j in 0..m {
            d.push(w[j] - entry.w_ref[j] + entry.err[j]);
        }
        let ctx = CodecContext::new(user, round, seed ^ DOWNLINK_SEED_SALT, rate);
        let mut sink = codec.encoder(&ctx, m);
        for chunk in d.chunks(DEFAULT_CHUNK) {
            sink.push(chunk);
        }
        let enc = sink.finish();
        let frame =
            wire::encode_frame_kind(user, round, wire_codec, FrameKind::DownlinkDelta, &enc);
        // Simulated client decode: shared dither (A3) means this is
        // exactly what the device computes from the same frame.
        let d_hat = codec.decode(&enc, m, &ctx);
        let mut sq_err = 0.0f64;
        for j in 0..m {
            let residue = d[j] - d_hat[j];
            sq_err += residue as f64 * residue as f64;
            entry.err[j] = residue;
            entry.w_ref[j] += d_hat[j];
        }
        let reconstruction = entry.w_ref.clone();
        entry.ref_round = round;
        BroadcastOutcome {
            reconstruction,
            frame_bytes: frame.len(),
            payload_bits: enc.bits,
            assigned_bits: ctx.budget_bits(m),
            resync: false,
            staleness,
            ref_round,
            sq_err,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(m: usize, base: f32) -> Vec<f32> {
        (0..m).map(|j| base + 0.01 * j as f32).collect()
    }

    #[test]
    fn first_contact_is_an_exact_resync() {
        let codec = quantizer::make("uveqfed-l2").unwrap();
        let mut table = SyncTable::default();
        let w = model(96, 0.5);
        let out = table.broadcast(codec.as_ref(), 2.0, 0, 7, 3, 11, &w);
        assert!(out.resync);
        assert_eq!(out.staleness, 4, "never-synced staleness is round + 1");
        assert_eq!(out.ref_round, 3);
        assert_eq!(out.reconstruction, w);
        assert_eq!(out.payload_bits, 32 * 96);
        assert_eq!(out.frame_bytes, wire::frame_len(4 * 96));
        assert_eq!(out.sq_err, 0.0);
        assert_eq!(table.ref_round(11), Some(3));
        assert_eq!(table.len(), 1);
    }

    #[test]
    fn lossless_codec_short_circuits_to_resync_every_round() {
        let codec = quantizer::make("identity").unwrap();
        let mut table = SyncTable::default();
        for round in 0..4u64 {
            let w = model(32, round as f32);
            let out = table.broadcast(codec.as_ref(), 2.0, 0, 1, round, 5, &w);
            assert!(out.resync, "identity must resync at round {round}");
            assert_eq!(out.reconstruction, w, "lossless downlink must be transparent");
        }
    }

    #[test]
    fn error_feedback_residue_is_folded_into_the_next_delta() {
        let codec = quantizer::make("uveqfed-l2").unwrap();
        let mut table = SyncTable::default();
        let m = 128;
        table.broadcast(codec.as_ref(), 2.0, 0, 9, 0, 2, &model(m, 0.0));
        let w1 = model(m, 0.3);
        let out1 = table.broadcast(codec.as_ref(), 2.0, 0, 9, 1, 2, &w1);
        assert!(!out1.resync);
        assert!(out1.sq_err > 0.0, "a 2-bit broadcast must leave residue");
        // Manual replay of round 2 with the EF recursion: the table must
        // code w2 − ŵ1 + e2, not the plain delta.
        let e2: Vec<f32> = {
            let w0 = model(m, 0.0);
            let d1: Vec<f32> = (0..m).map(|j| w1[j] - w0[j]).collect();
            // Matches broadcast's internal CodecContext::new(user, round,
            // seed ^ SALT, rate) for the user = 2 / seed = 9 calls above.
            let ctx = CodecContext::new(2, 1, 9 ^ DOWNLINK_SEED_SALT, 2.0);
            let enc = codec.encode(&d1, &ctx);
            let d1_hat = codec.decode(&enc, m, &ctx);
            (0..m).map(|j| d1[j] - d1_hat[j]).collect()
        };
        let w2 = model(m, 0.7);
        let expect: Vec<f32> = {
            let ctx = CodecContext::new(2, 2, 9 ^ DOWNLINK_SEED_SALT, 2.0);
            let d2: Vec<f32> =
                (0..m).map(|j| w2[j] - out1.reconstruction[j] + e2[j]).collect();
            let enc = codec.encode(&d2, &ctx);
            let d2_hat = codec.decode(&enc, m, &ctx);
            (0..m).map(|j| out1.reconstruction[j] + d2_hat[j]).collect()
        };
        let out2 = table.broadcast(codec.as_ref(), 2.0, 0, 9, 2, 2, &w2);
        assert_eq!(out2.reconstruction, expect, "EF recursion mismatch");
    }

    #[test]
    fn fedvqcs_downlink_carries_error_feedback_through_the_solver() {
        // The pipeline codec must slot into the broadcast path unchanged:
        // the sketch + IHT reconstruction is deterministic in
        // (user, round, seed ^ SALT), so the simulated client decode is
        // exactly reproducible, and the (large — top-k keeps 10% of the
        // delta) quantization residue must ride the EF accumulator into
        // the next round's delta. Same manual-replay shape as the
        // uveqfed-l2 test above; shared-instance encodes are safe because
        // the terminal's warm-start hints are round-frozen.
        let spec = "fedvqcs:ratio=0.25,sparsity=0.1,solver_iters=10";
        let codec = quantizer::make(spec).unwrap();
        let mut table = SyncTable::default();
        let m = 128;
        table.broadcast(codec.as_ref(), 2.0, 0, 9, 0, 2, &model(m, 0.0));
        let w1 = model(m, 0.3);
        let out1 = table.broadcast(codec.as_ref(), 2.0, 0, 9, 1, 2, &w1);
        assert!(!out1.resync, "rate-constrained fedvqcs must take the delta path");
        assert!(out1.sq_err > 0.0, "a sketched 10%-sparse broadcast must leave residue");
        assert!(out1.payload_bits <= out1.assigned_bits, "fedvqcs delta over budget");
        // Replay contexts mirror `broadcast`'s own
        // `CodecContext::new(user, round, seed ^ DOWNLINK_SEED_SALT, rate)`
        // with the user = 2 / seed = 9 used above: the sketch matrix is
        // drawn from (user, round, seed), so any swap desynchronizes the
        // IHT solver from the table's simulated client decode.
        let e2: Vec<f32> = {
            let w0 = model(m, 0.0);
            let d1: Vec<f32> = (0..m).map(|j| w1[j] - w0[j]).collect();
            let ctx = CodecContext::new(2, 1, 9 ^ DOWNLINK_SEED_SALT, 2.0);
            let enc = codec.encode(&d1, &ctx);
            let d1_hat = codec.decode(&enc, m, &ctx);
            (0..m).map(|j| d1[j] - d1_hat[j]).collect()
        };
        assert!(e2.iter().any(|&v| v != 0.0), "residue must be non-trivial");
        let w2 = model(m, 0.7);
        let expect: Vec<f32> = {
            let ctx = CodecContext::new(2, 2, 9 ^ DOWNLINK_SEED_SALT, 2.0);
            let d2: Vec<f32> =
                (0..m).map(|j| w2[j] - out1.reconstruction[j] + e2[j]).collect();
            let enc = codec.encode(&d2, &ctx);
            let d2_hat = codec.decode(&enc, m, &ctx);
            (0..m).map(|j| out1.reconstruction[j] + d2_hat[j]).collect()
        };
        let out2 = table.broadcast(codec.as_ref(), 2.0, 0, 9, 2, 2, &w2);
        assert_eq!(out2.reconstruction, expect, "fedvqcs EF recursion mismatch");
    }

    #[test]
    fn stale_reference_is_used_until_the_resync_bound_trips() {
        let codec = quantizer::make("uveqfed-l2").unwrap();
        let mut table = SyncTable::default();
        table.broadcast(codec.as_ref(), 2.0, 3, 4, 0, 8, &model(64, 0.0));
        // Missing rounds 1..4: staleness 4 > resync_every 3 → resync.
        let out = table.broadcast(codec.as_ref(), 2.0, 3, 4, 4, 8, &model(64, 1.0));
        assert_eq!(out.staleness, 4);
        assert!(out.resync);
        // Staleness 3 ≤ 3 → delta against the stale reference.
        let out = table.broadcast(codec.as_ref(), 2.0, 3, 4, 7, 8, &model(64, 2.0));
        assert_eq!(out.staleness, 3);
        assert!(!out.resync);
        assert_eq!(out.ref_round, 4, "delta must be coded against the stale reference");
    }

    #[test]
    fn delta_broadcast_respects_the_bit_budget() {
        let codec = quantizer::make("uveqfed-l2").unwrap();
        let mut table = SyncTable::default();
        let m = 2048;
        table.broadcast(codec.as_ref(), 2.0, 0, 5, 0, 1, &model(m, 0.0));
        let out = table.broadcast(codec.as_ref(), 2.0, 0, 5, 1, 1, &model(m, 0.4));
        assert!(!out.resync);
        assert!(
            out.payload_bits <= out.assigned_bits,
            "coded {} bits over the {}-bit downlink budget",
            out.payload_bits,
            out.assigned_bits
        );
        let payload_bytes = out.frame_bytes - wire::HEADER_BYTES - wire::TRAILER_BYTES;
        assert!(out.payload_bits <= 8 * payload_bytes, "phantom bits on the downlink frame");
    }
}
