//! Virtual time: per-round wall/latency statistics without sleeping.
//!
//! The fleet simulates latency, so a 10k-client round with a 30 s deadline
//! completes in milliseconds of real time while still reporting when the
//! round *would* have closed. The clock advances by the modeled round
//! duration: the arrival time of the last aggregated update, or the full
//! deadline when the server waited it out short of its target.

use crate::util::stats::percentile;

/// Monotone virtual clock for a federated run.
#[derive(Debug, Clone, Copy, Default)]
pub struct VirtualClock {
    now: f64,
}

/// Latency statistics for one closed round (virtual seconds).
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundTiming {
    /// Virtual time at round start.
    pub start: f64,
    /// Modeled duration until the server closed the round.
    pub duration: f64,
    /// Median arrival latency over aggregated updates.
    pub p50_latency: f64,
    /// 95th-percentile arrival latency over aggregated updates.
    pub p95_latency: f64,
    /// Slowest aggregated arrival.
    pub max_latency: f64,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current virtual time.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Close a round given the latencies of the updates that were
    /// aggregated; `waited_deadline` is `Some(d)` when the server held the
    /// round open until the deadline (it fell short of its target count).
    pub fn close_round(
        &mut self,
        arrival_latencies: &[f64],
        waited_deadline: Option<f64>,
    ) -> RoundTiming {
        let start = self.now;
        let max_latency =
            arrival_latencies.iter().copied().fold(0.0f64, f64::max);
        let duration = waited_deadline.unwrap_or(max_latency).max(max_latency);
        let (p50, p95) = if arrival_latencies.is_empty() {
            (0.0, 0.0)
        } else {
            (percentile(arrival_latencies, 50.0), percentile(arrival_latencies, 95.0))
        };
        self.now += duration;
        RoundTiming {
            start,
            duration,
            p50_latency: p50,
            p95_latency: p95,
            max_latency,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_by_slowest_aggregated_arrival() {
        let mut clock = VirtualClock::new();
        let t = clock.close_round(&[0.5, 2.0, 1.0], None);
        assert_eq!(t.start, 0.0);
        assert_eq!(t.duration, 2.0);
        assert_eq!(t.max_latency, 2.0);
        assert_eq!(clock.now(), 2.0);
        let t2 = clock.close_round(&[1.0], None);
        assert_eq!(t2.start, 2.0);
        assert_eq!(clock.now(), 3.0);
    }

    #[test]
    fn waiting_out_a_deadline_costs_the_full_deadline() {
        let mut clock = VirtualClock::new();
        let t = clock.close_round(&[0.1, 0.2], Some(30.0));
        assert_eq!(t.duration, 30.0);
        assert_eq!(t.max_latency, 0.2);
        assert_eq!(clock.now(), 30.0);
    }

    #[test]
    fn empty_round_with_deadline_still_advances() {
        let mut clock = VirtualClock::new();
        let t = clock.close_round(&[], Some(5.0));
        assert_eq!(t.duration, 5.0);
        assert_eq!(t.p50_latency, 0.0);
        let t2 = clock.close_round(&[], None);
        assert_eq!(t2.duration, 0.0);
    }

    #[test]
    fn percentiles_are_ordered() {
        let mut clock = VirtualClock::new();
        let lats: Vec<f64> = (1..=100).map(|i| i as f64 / 100.0).collect();
        let t = clock.close_round(&lats, None);
        assert!(t.p50_latency <= t.p95_latency);
        assert!(t.p95_latency <= t.max_latency);
        assert!((t.p50_latency - 0.505).abs() < 0.02);
    }
}
