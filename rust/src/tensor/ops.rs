//! Activation functions and the small conv/pool kernels used by the native
//! CIFAR oracle.

use super::Matrix;

/// Elementwise sigmoid.
pub fn sigmoid(m: &Matrix) -> Matrix {
    m.map(|x| 1.0 / (1.0 + (-x).exp()))
}

/// Sigmoid derivative given the *activation* `a = σ(x)`.
pub fn sigmoid_grad(a: &Matrix) -> Matrix {
    a.map(|v| v * (1.0 - v))
}

pub fn relu(m: &Matrix) -> Matrix {
    m.map(|x| x.max(0.0))
}

/// ReLU derivative given the pre-activation (or activation — same mask).
pub fn relu_grad(a: &Matrix) -> Matrix {
    a.map(|x| if x > 0.0 { 1.0 } else { 0.0 })
}

/// Row-wise softmax (numerically stabilized).
pub fn softmax_rows(m: &Matrix) -> Matrix {
    let mut out = m.clone();
    let cols = m.cols();
    for r in 0..m.rows() {
        let row = &mut out.data_mut()[r * cols..(r + 1) * cols];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
    out
}

/// Valid 2-D convolution of a single-channel image with a single kernel.
/// `img` is HxW, `ker` is KhxKw; output (H−Kh+1)x(W−Kw+1).
pub fn conv2d_valid(img: &Matrix, ker: &Matrix) -> Matrix {
    let (h, w) = (img.rows(), img.cols());
    let (kh, kw) = (ker.rows(), ker.cols());
    assert!(h >= kh && w >= kw);
    let (oh, ow) = (h - kh + 1, w - kw + 1);
    let mut out = Matrix::zeros(oh, ow);
    for i in 0..oh {
        for j in 0..ow {
            let mut s = 0.0f32;
            for a in 0..kh {
                let irow = img.row(i + a);
                let krow = ker.row(a);
                for b in 0..kw {
                    s += irow[j + b] * krow[b];
                }
            }
            out.set(i, j, s);
        }
    }
    out
}

/// 2×2 max pooling with stride 2 (truncating odd edges).
pub fn max_pool2x2(img: &Matrix) -> Matrix {
    let (h, w) = (img.rows() / 2, img.cols() / 2);
    let mut out = Matrix::zeros(h, w);
    for i in 0..h {
        for j in 0..w {
            let v = img
                .get(2 * i, 2 * j)
                .max(img.get(2 * i, 2 * j + 1))
                .max(img.get(2 * i + 1, 2 * j))
                .max(img.get(2 * i + 1, 2 * j + 1));
            out.set(i, j, v);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_range_and_symmetry() {
        let m = Matrix::from_vec(1, 3, vec![-100.0, 0.0, 100.0]);
        let s = sigmoid(&m);
        assert!(s.data()[0] < 1e-6);
        assert!((s.data()[1] - 0.5).abs() < 1e-7);
        assert!(s.data()[2] > 1.0 - 1e-6);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., -1., 0., 1.]);
        let s = softmax_rows(&m);
        for r in 0..2 {
            let sum: f32 = s.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_stable_for_large_inputs() {
        let m = Matrix::from_vec(1, 2, vec![1000.0, 1001.0]);
        let s = softmax_rows(&m);
        assert!(s.data().iter().all(|v| v.is_finite()));
        assert!((s.data().iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn conv_known_values() {
        // 3x3 image, 2x2 kernel of ones → sliding window sums.
        let img = Matrix::from_vec(3, 3, vec![1., 2., 3., 4., 5., 6., 7., 8., 9.]);
        let ker = Matrix::from_vec(2, 2, vec![1., 1., 1., 1.]);
        let out = conv2d_valid(&img, &ker);
        assert_eq!(out.data(), &[12., 16., 24., 28.]);
    }

    #[test]
    fn pool_known_values() {
        let img = Matrix::from_vec(4, 4, (1..=16).map(|v| v as f32).collect());
        let out = max_pool2x2(&img);
        assert_eq!(out.data(), &[6., 8., 14., 16.]);
    }

    #[test]
    fn relu_masks_negative() {
        let m = Matrix::from_vec(1, 4, vec![-1.0, 0.0, 2.0, -3.0]);
        assert_eq!(relu(&m).data(), &[0.0, 0.0, 2.0, 0.0]);
        assert_eq!(relu_grad(&m).data(), &[0.0, 0.0, 1.0, 0.0]);
    }
}
