//! Row-major f32 matrix with the ops the native models need.

use crate::prng::{Normal, Rng};

#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    /// Glorot/Xavier-style init: N(0, 2/(fan_in+fan_out)).
    pub fn glorot<R: Rng + ?Sized>(rows: usize, cols: usize, rng: &mut R) -> Self {
        let std = (2.0 / (rows + cols) as f64).sqrt();
        let d = Normal::new(0.0, std);
        Self { rows, cols, data: d.vec_f32(rng, rows * cols) }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// `self · other` — ikj loop order for cache-friendliness; this is the
    /// native-path hot loop.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            let a_row = &self.data[i * k..(i + 1) * k];
            let o_row = &mut out.data[i * n..(i + 1) * n];
            for (kk, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let b_row = &other.data[kk * n..(kk + 1) * n];
                for (o, &b) in o_row.iter_mut().zip(b_row) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// `selfᵀ · other` without materializing the transpose.
    pub fn t_matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        let (m, k, n) = (self.cols, self.rows, other.cols);
        let mut out = Matrix::zeros(m, n);
        for kk in 0..k {
            let a_row = &self.data[kk * m..(kk + 1) * m];
            let b_row = &other.data[kk * n..(kk + 1) * n];
            for (i, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let o_row = &mut out.data[i * n..(i + 1) * n];
                for (o, &b) in o_row.iter_mut().zip(b_row) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// `self · otherᵀ`.
    pub fn matmul_t(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            let a_row = &self.data[i * k..(i + 1) * k];
            for j in 0..n {
                let b_row = &other.data[j * k..(j + 1) * k];
                let mut s = 0.0f32;
                for (a, b) in a_row.iter().zip(b_row) {
                    s += a * b;
                }
                out.data[i * n + j] = s;
            }
        }
        out
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Elementwise in-place map.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for v in self.data.iter_mut() {
            *v = f(*v);
        }
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        let mut m = self.clone();
        m.map_inplace(f);
        m
    }

    /// `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!(self.data.len(), other.data.len());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Elementwise product (Hadamard).
    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.data.len(), other.data.len());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a * b).collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    /// Broadcast-add a row vector to every row.
    pub fn add_row_vec(&mut self, v: &[f32]) {
        assert_eq!(v.len(), self.cols);
        for r in 0..self.rows {
            let row = &mut self.data[r * self.cols..(r + 1) * self.cols];
            for (x, b) in row.iter_mut().zip(v) {
                *x += b;
            }
        }
    }

    /// Column sums (gradient of a broadcast bias).
    pub fn col_sums(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            for (o, &x) in out.iter_mut().zip(self.row(r)) {
                *o += x;
            }
        }
        out
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Xoshiro256pp;

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let i = Matrix::from_vec(2, 2, vec![1., 0., 0., 1.]);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let mut rng = Xoshiro256pp::seed_from_u64(61);
        let a = Matrix::glorot(5, 7, &mut rng);
        let b = Matrix::glorot(5, 3, &mut rng);
        let fast = a.t_matmul(&b);
        let slow = a.transpose().matmul(&b);
        for (x, y) in fast.data().iter().zip(slow.data()) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn matmul_t_matches_explicit_transpose() {
        let mut rng = Xoshiro256pp::seed_from_u64(62);
        let a = Matrix::glorot(4, 6, &mut rng);
        let b = Matrix::glorot(3, 6, &mut rng);
        let fast = a.matmul_t(&b);
        let slow = a.matmul(&b.transpose());
        for (x, y) in fast.data().iter().zip(slow.data()) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn bias_and_colsums_are_adjoint() {
        let mut m = Matrix::zeros(3, 2);
        m.add_row_vec(&[1.0, -2.0]);
        assert_eq!(m.col_sums(), vec![3.0, -6.0]);
    }

    #[test]
    fn axpy_and_hadamard() {
        let mut a = Matrix::from_vec(1, 3, vec![1., 2., 3.]);
        let b = Matrix::from_vec(1, 3, vec![10., 20., 30.]);
        a.axpy(0.1, &b);
        assert_eq!(a.data(), &[2., 4., 6.]);
        let h = a.hadamard(&b);
        assert_eq!(h.data(), &[20., 80., 180.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
