//! Minimal dense-tensor substrate for the *native* model implementations
//! (the L3-side oracles and the strongly-convex theory experiments).
//!
//! The production training path runs through the AOT-compiled JAX graphs
//! (`runtime::` + `artifacts/*.hlo.txt`); this module exists so that
//! (i) convergence-theory experiments (logistic regression, Thm 3) can run
//! without the artifact toolchain, (ii) tests have an independent oracle
//! for the HLO path, and (iii) the benches can isolate coordinator cost
//! from XLA cost.
//!
//! Deliberately small: f32, row-major, 2-D matrices + vectors, with the
//! handful of ops the models need. The matmul microkernel is the one hot
//! loop and is written cache-friendly (i-k-j with row reuse).

mod matrix;
mod ops;

pub use matrix::Matrix;
pub use ops::{conv2d_valid, max_pool2x2, relu, relu_grad, sigmoid, sigmoid_grad, softmax_rows};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn module_surface_smoke() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.rows(), 2);
        assert_eq!(c.cols(), 2);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }
}
