//! # UVeQFed — Universal Vector Quantization for Federated Learning
//!
//! A production-grade reproduction of *Shlezinger, Chen, Eldar, Poor, Cui,
//! "UVeQFed: Universal Vector Quantization for Federated Learning"* (IEEE
//! TSP 2020) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the federated coordinator: round scheduling,
//!   client fan-out, the UVeQFed codec and every baseline behind the
//!   streaming session API (`quantizer::UpdateCodec::encoder` /
//!   `::decoder` — chunked encode sinks and decode streams that fold
//!   straight into the aggregator, with a fallible parameterized
//!   `CodecSpec` registry), the rate-constrained uplink, aggregation,
//!   metrics, and the `fleet::` simulator (cohort sampling, stragglers,
//!   wire framing, streaming O(m) aggregation, `RoundSpec`-driven
//!   rounds) for populations far beyond the paper's K ≤ 100;
//! * **L2 (python/compile/model.py)** — JAX forward/backward graphs for the
//!   paper's models, AOT-lowered to HLO text in `artifacts/`;
//! * **L1 (python/compile/kernels/)** — Pallas kernels (dithered lattice
//!   quantization, fused dense layer) called from L2.
//!
//! Python never runs on the request path: `runtime::` loads the HLO
//! artifacts once via PJRT and the rust binary is self-contained.
//!
//! See `DESIGN.md` for the full system inventory and experiment index, and
//! `examples/` for end-to-end drivers.

pub mod coordinator;
pub mod data;
pub mod entropy;
pub mod fl;
pub mod fleet;
pub mod lattice;
pub mod metrics;
pub mod models;
pub mod prng;
pub mod quantizer;
pub mod runtime;
pub mod telemetry;
pub mod tensor;
pub mod theory;
pub mod util;

pub mod bench;

/// Crate-wide result alias (see `util::error`; anyhow is not vendorable
/// in the offline image).
pub type Result<T> = util::error::Result<T>;
