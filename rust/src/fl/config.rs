//! Federated run configuration + learning-rate schedules.

use crate::data::Dataset;
use crate::fleet::{FaultPlan, LatencyModel, SamplerKind, Scenario};
use crate::util::config::Config;

/// Learning-rate schedule.
#[derive(Debug, Clone, Copy)]
pub enum LrSchedule {
    /// Constant η (the §V experiments).
    Const(f64),
    /// `η_t = β/(t+γ)` — the Theorem 3 schedule with `β = τ/ρ_c`,
    /// `γ = τ·max(1, 4ρ_s/ρ_c)`.
    InvT { beta: f64, gamma: f64 },
}

impl LrSchedule {
    pub fn at(&self, t: usize) -> f32 {
        match *self {
            LrSchedule::Const(eta) => eta as f32,
            LrSchedule::InvT { beta, gamma } => (beta / (t as f64 + gamma)) as f32,
        }
    }
}

/// Full federated experiment configuration (Table I fields + systems
/// knobs).
#[derive(Debug, Clone)]
pub struct FlConfig {
    /// Number of users K.
    pub users: usize,
    /// Aggregation rounds (each = τ local iterations).
    pub rounds: usize,
    /// τ — local steps between aggregations.
    pub local_steps: usize,
    /// Mini-batch size per local step (0 = full local dataset, i.e. GD).
    pub batch_size: usize,
    pub lr: LrSchedule,
    /// Quantization rate R (bits per model parameter).
    pub rate: f64,
    pub seed: u64,
    /// Client-fan-out worker threads.
    pub workers: usize,
    /// Evaluate every this many rounds.
    pub eval_every: usize,
    pub verbose: bool,
    /// Participation + fault scenario (`Scenario::full()` reproduces the
    /// seed's every-user-every-round behavior).
    pub fleet: Scenario,
}

impl FlConfig {
    /// Weighting coefficients α_k ∝ n_k (the federated-averaging default).
    pub fn alphas(&self, shards: &[Dataset]) -> Vec<f64> {
        let total: usize = shards.iter().map(|s| s.len()).sum();
        shards.iter().map(|s| s.len() as f64 / total as f64).collect()
    }

    /// Load from a `[fl]` section of a TOML config. Config mistakes (bad
    /// sampler name, missing cohort) are errors, not panics — the CLI
    /// surfaces them with the valid alternatives.
    pub fn from_config(c: &Config) -> crate::Result<Self> {
        Ok(Self {
            users: c.usize_or("fl.users", 10),
            rounds: c.usize_or("fl.rounds", 100),
            local_steps: c.usize_or("fl.local_steps", 1),
            batch_size: c.usize_or("fl.batch_size", 0),
            lr: LrSchedule::Const(c.f64_or("fl.step_size", 1e-2)),
            rate: c.f64_or("quantizer.rate", 2.0),
            seed: c.i64_or("fl.seed", 1) as u64,
            workers: c.usize_or("fl.workers", crate::util::threadpool::default_workers()),
            eval_every: c.usize_or("fl.eval_every", 5),
            verbose: c.bool_or("fl.verbose", false),
            fleet: Self::fleet_from_config(c)?,
        })
    }

    /// Parse the optional `[fleet]` section. Absent section = full
    /// participation (the paper configs keep working unchanged).
    fn fleet_from_config(c: &Config) -> crate::Result<Scenario> {
        let cohort = c.usize_or("fleet.cohort", 0);
        let sampler_name =
            c.str_or("fleet.sampler", if cohort == 0 { "full" } else { "uniform" });
        let sampler = match sampler_name.as_str() {
            "full" => SamplerKind::Full,
            "uniform" => SamplerKind::Uniform { cohort },
            "weighted" => SamplerKind::Weighted { cohort },
            other => crate::bail!(
                "unknown fleet.sampler '{other}' (valid: full, uniform, weighted)"
            ),
        };
        crate::ensure!(
            matches!(sampler, SamplerKind::Full) || cohort > 0,
            "fleet.sampler = \"{sampler_name}\" requires fleet.cohort > 0"
        );
        let median = c.f64_or("fleet.latency_median", 0.0);
        let latency = if median > 0.0 {
            LatencyModel::LogNormal { median, sigma: c.f64_or("fleet.latency_sigma", 0.8) }
        } else {
            LatencyModel::Fixed(0.0)
        };
        let deadline = c.f64_or("fleet.deadline", 0.0);
        Ok(Scenario {
            sampler,
            over_select: c.f64_or("fleet.over_select", 0.0),
            faults: FaultPlan {
                latency,
                dropout: c.f64_or("fleet.dropout", 0.0),
                deadline: (deadline > 0.0).then_some(deadline),
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules() {
        let c = LrSchedule::Const(0.1);
        assert_eq!(c.at(0), 0.1);
        assert_eq!(c.at(100), 0.1);
        let d = LrSchedule::InvT { beta: 10.0, gamma: 10.0 };
        assert_eq!(d.at(0), 1.0);
        assert!(d.at(90) <= 0.1 + 1e-9);
    }

    #[test]
    fn alphas_proportional_to_shard_size() {
        let mk = |n: usize| Dataset {
            x: vec![0.0; n],
            y: vec![0; n],
            features: 1,
            classes: 1,
        };
        let cfg = FlConfig {
            users: 2,
            rounds: 1,
            local_steps: 1,
            batch_size: 0,
            lr: LrSchedule::Const(0.1),
            rate: 2.0,
            seed: 1,
            workers: 1,
            eval_every: 1,
            verbose: false,
            fleet: Scenario::full(),
        };
        let a = cfg.alphas(&[mk(30), mk(10)]);
        assert!((a[0] - 0.75).abs() < 1e-12);
        assert!((a[1] - 0.25).abs() < 1e-12);
        assert!((a.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn from_config_defaults() {
        let c = Config::parse("[fl]\nusers = 3\nrounds = 7").unwrap();
        let f = FlConfig::from_config(&c).unwrap();
        assert_eq!(f.users, 3);
        assert_eq!(f.rounds, 7);
        assert_eq!(f.local_steps, 1);
        assert_eq!(f.fleet, Scenario::full(), "absent [fleet] = full participation");
    }

    #[test]
    fn fleet_section_parses() {
        let c = Config::parse(
            "[fleet]\ncohort = 64\nsampler = \"weighted\"\nover_select = 0.25\n\
             dropout = 0.05\ndeadline = 3.0\nlatency_median = 1.0\nlatency_sigma = 0.5",
        )
        .unwrap();
        let f = FlConfig::from_config(&c).unwrap();
        assert_eq!(f.fleet.sampler, SamplerKind::Weighted { cohort: 64 });
        assert_eq!(f.fleet.over_select, 0.25);
        assert_eq!(f.fleet.faults.dropout, 0.05);
        assert_eq!(f.fleet.faults.deadline, Some(3.0));
        assert_eq!(
            f.fleet.faults.latency,
            LatencyModel::LogNormal { median: 1.0, sigma: 0.5 }
        );
    }

    #[test]
    fn cohort_without_sampler_defaults_to_uniform() {
        let c = Config::parse("[fleet]\ncohort = 8").unwrap();
        let f = FlConfig::from_config(&c).unwrap();
        assert_eq!(f.fleet.sampler, SamplerKind::Uniform { cohort: 8 });
    }
}
