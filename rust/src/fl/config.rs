//! Federated run configuration + learning-rate schedules.

use crate::data::Dataset;
use crate::util::config::Config;

/// Learning-rate schedule.
#[derive(Debug, Clone, Copy)]
pub enum LrSchedule {
    /// Constant η (the §V experiments).
    Const(f64),
    /// `η_t = β/(t+γ)` — the Theorem 3 schedule with `β = τ/ρ_c`,
    /// `γ = τ·max(1, 4ρ_s/ρ_c)`.
    InvT { beta: f64, gamma: f64 },
}

impl LrSchedule {
    pub fn at(&self, t: usize) -> f32 {
        match *self {
            LrSchedule::Const(eta) => eta as f32,
            LrSchedule::InvT { beta, gamma } => (beta / (t as f64 + gamma)) as f32,
        }
    }
}

/// Full federated experiment configuration (Table I fields + systems
/// knobs).
#[derive(Debug, Clone)]
pub struct FlConfig {
    /// Number of users K.
    pub users: usize,
    /// Aggregation rounds (each = τ local iterations).
    pub rounds: usize,
    /// τ — local steps between aggregations.
    pub local_steps: usize,
    /// Mini-batch size per local step (0 = full local dataset, i.e. GD).
    pub batch_size: usize,
    pub lr: LrSchedule,
    /// Quantization rate R (bits per model parameter).
    pub rate: f64,
    pub seed: u64,
    /// Client-fan-out worker threads.
    pub workers: usize,
    /// Evaluate every this many rounds.
    pub eval_every: usize,
    pub verbose: bool,
}

impl FlConfig {
    /// Weighting coefficients α_k ∝ n_k (the federated-averaging default).
    pub fn alphas(&self, shards: &[Dataset]) -> Vec<f64> {
        let total: usize = shards.iter().map(|s| s.len()).sum();
        shards.iter().map(|s| s.len() as f64 / total as f64).collect()
    }

    /// Load from a `[fl]` section of a TOML config.
    pub fn from_config(c: &Config) -> Self {
        Self {
            users: c.usize_or("fl.users", 10),
            rounds: c.usize_or("fl.rounds", 100),
            local_steps: c.usize_or("fl.local_steps", 1),
            batch_size: c.usize_or("fl.batch_size", 0),
            lr: LrSchedule::Const(c.f64_or("fl.step_size", 1e-2)),
            rate: c.f64_or("quantizer.rate", 2.0),
            seed: c.i64_or("fl.seed", 1) as u64,
            workers: c.usize_or("fl.workers", crate::util::threadpool::default_workers()),
            eval_every: c.usize_or("fl.eval_every", 5),
            verbose: c.bool_or("fl.verbose", false),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules() {
        let c = LrSchedule::Const(0.1);
        assert_eq!(c.at(0), 0.1);
        assert_eq!(c.at(100), 0.1);
        let d = LrSchedule::InvT { beta: 10.0, gamma: 10.0 };
        assert_eq!(d.at(0), 1.0);
        assert!(d.at(90) <= 0.1 + 1e-9);
    }

    #[test]
    fn alphas_proportional_to_shard_size() {
        let mk = |n: usize| Dataset {
            x: vec![0.0; n],
            y: vec![0; n],
            features: 1,
            classes: 1,
        };
        let cfg = FlConfig {
            users: 2,
            rounds: 1,
            local_steps: 1,
            batch_size: 0,
            lr: LrSchedule::Const(0.1),
            rate: 2.0,
            seed: 1,
            workers: 1,
            eval_every: 1,
            verbose: false,
        };
        let a = cfg.alphas(&[mk(30), mk(10)]);
        assert!((a[0] - 0.75).abs() < 1e-12);
        assert!((a[1] - 0.25).abs() < 1e-12);
        assert!((a.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn from_config_defaults() {
        let c = Config::parse("[fl]\nusers = 3\nrounds = 7").unwrap();
        let f = FlConfig::from_config(&c);
        assert_eq!(f.users, 3);
        assert_eq!(f.rounds, 7);
        assert_eq!(f.local_steps, 1);
    }
}
