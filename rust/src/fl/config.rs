//! Federated run configuration + learning-rate schedules.

use crate::coordinator::rate_control::controller_by_name;
use crate::fleet::{
    Channel, ChannelModel, FaultPlan, LatencyModel, RatePlan, SamplerKind, Scenario, WirePlan,
};

use crate::data::Dataset;
use crate::util::config::Config;

/// Learning-rate schedule.
#[derive(Debug, Clone, Copy)]
pub enum LrSchedule {
    /// Constant η (the §V experiments).
    Const(f64),
    /// `η_t = β/(t+γ)` — the Theorem 3 schedule with `β = τ/ρ_c`,
    /// `γ = τ·max(1, 4ρ_s/ρ_c)`.
    InvT { beta: f64, gamma: f64 },
}

impl LrSchedule {
    pub fn at(&self, t: usize) -> f32 {
        match *self {
            LrSchedule::Const(eta) => eta as f32,
            LrSchedule::InvT { beta, gamma } => (beta / (t as f64 + gamma)) as f32,
        }
    }
}

/// Full federated experiment configuration (Table I fields + systems
/// knobs).
#[derive(Debug, Clone)]
pub struct FlConfig {
    /// Number of users K.
    pub users: usize,
    /// Aggregation rounds (each = τ local iterations).
    pub rounds: usize,
    /// τ — local steps between aggregations.
    pub local_steps: usize,
    /// Mini-batch size per local step (0 = full local dataset, i.e. GD).
    pub batch_size: usize,
    pub lr: LrSchedule,
    /// Quantization rate R (bits per model parameter).
    pub rate: f64,
    pub seed: u64,
    /// Client-fan-out worker threads.
    pub workers: usize,
    /// Server aggregation shards (≥ 1; bit-identical for any value).
    pub shards: usize,
    /// Evaluate every this many rounds.
    pub eval_every: usize,
    pub verbose: bool,
    /// Participation + fault scenario (`Scenario::full()` reproduces the
    /// seed's every-user-every-round behavior).
    pub fleet: Scenario,
    /// Heterogeneous uplink plan (`[channel]` config block); `None` keeps
    /// the legacy same-pipe-for-everyone uplink.
    pub channel: Option<ChannelPlanSpec>,
    /// Round-lifecycle tracing (`[telemetry]` config block); `None` runs
    /// untraced.
    pub telemetry: Option<TelemetrySpec>,
    /// Coded downlink broadcast (`[downlink]` config block); `None` keeps
    /// the classic perfect downlink (clients receive `w` verbatim).
    pub downlink: Option<DownlinkPlanSpec>,
}

/// Plain-data description of a coded downlink (`[downlink]` section):
/// the broadcast codec, its bit budget, and the stale-reference resync
/// bound. The live `DownlinkSpec` borrows the codec, so the boxed codec
/// is built once per run from this spec.
#[derive(Debug, Clone, PartialEq)]
pub struct DownlinkPlanSpec {
    /// Broadcast codec name (any `quantizer::make` name).
    pub codec: String,
    /// Downlink bits per model entry.
    pub rate: f64,
    /// Full-model resync when a reference is more than this many rounds
    /// stale (0 = first-contact resyncs only).
    pub resync_every: u64,
}

impl DownlinkPlanSpec {
    /// Instantiate the broadcast codec (names were validated at load).
    pub fn build(&self) -> crate::Result<Box<dyn crate::quantizer::UpdateCodec>> {
        crate::quantizer::make(&self.codec)
    }
}

/// Plain-data description of a tracing setup (`[telemetry]` section):
/// where the JSONL trace goes and how large the event ring should be.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TelemetrySpec {
    /// JSONL trace output path.
    pub trace: String,
    /// Event-ring capacity; 0 = auto-size from the per-round cohort.
    pub capacity: usize,
}

/// Plain-data description of a heterogeneous-uplink plan: the capacity
/// model plus the rate-control policy name. Separated from the live
/// [`RatePlan`] so `FlConfig` stays `Clone` and the Markov channel state
/// is created fresh per run.
#[derive(Debug, Clone, PartialEq)]
pub struct ChannelPlanSpec {
    pub model: ChannelModel,
    /// Rate-control policy: `uniform` | `proportional` | `theory`.
    pub policy: String,
}

impl ChannelPlanSpec {
    /// Instantiate the live plan for a run (validates the model and
    /// resolves the policy; both fail with named-alternative errors).
    pub fn build(&self, seed: u64) -> crate::Result<RatePlan> {
        self.model.validate()?;
        Ok(RatePlan::new(
            Channel::new(self.model.clone(), seed),
            controller_by_name(&self.policy)?,
        ))
    }
}

impl FlConfig {
    /// Weighting coefficients α_k ∝ n_k (the federated-averaging default).
    pub fn alphas(&self, shards: &[Dataset]) -> Vec<f64> {
        let total: usize = shards.iter().map(|s| s.len()).sum();
        shards.iter().map(|s| s.len() as f64 / total as f64).collect()
    }

    /// Load from a `[fl]` section of a TOML config. Config mistakes (bad
    /// sampler name, missing cohort) are errors, not panics — the CLI
    /// surfaces them with the valid alternatives.
    pub fn from_config(c: &Config) -> crate::Result<Self> {
        Ok(Self {
            users: c.usize_or("fl.users", 10),
            rounds: c.usize_or("fl.rounds", 100),
            local_steps: c.usize_or("fl.local_steps", 1),
            batch_size: c.usize_or("fl.batch_size", 0),
            lr: LrSchedule::Const(c.f64_or("fl.step_size", 1e-2)),
            rate: c.f64_or("quantizer.rate", 2.0),
            seed: c.i64_or("fl.seed", 1) as u64,
            workers: c.usize_or("fl.workers", crate::util::threadpool::default_workers()),
            shards: c.usize_or("fl.shards", 1),
            eval_every: c.usize_or("fl.eval_every", 5),
            verbose: c.bool_or("fl.verbose", false),
            fleet: Self::fleet_from_config(c)?,
            channel: Self::channel_from_config(c)?,
            telemetry: Self::telemetry_from_config(c)?,
            downlink: Self::downlink_from_config(c)?,
        })
    }

    /// Parse the optional `[downlink]` section. Grammar:
    ///
    /// ```toml
    /// [downlink]
    /// codec = "uveqfed-l2"  # required when the section is present
    /// rate = 2.0            # bits/entry; defaults to quantizer.rate
    /// resync_every = 0      # staleness bound; 0 = first contact only
    /// ```
    ///
    /// Absent section (no `downlink.codec` key) = perfect downlink.
    fn downlink_from_config(c: &Config) -> crate::Result<Option<DownlinkPlanSpec>> {
        let Some(codec) = c.get("downlink.codec").and_then(|v| v.as_str()) else {
            for orphan in ["downlink.rate", "downlink.resync_every"] {
                crate::ensure!(
                    c.get(orphan).is_none(),
                    "[downlink] has a {} but no codec — set downlink.codec",
                    orphan.trim_start_matches("downlink.")
                );
            }
            return Ok(None);
        };
        // Resolve now so config typos fail at load, not mid-run.
        crate::quantizer::make(codec)?;
        let rate = c.f64_or("downlink.rate", c.f64_or("quantizer.rate", 2.0));
        crate::ensure!(rate > 0.0, "downlink.rate must be > 0, got {rate}");
        Ok(Some(DownlinkPlanSpec {
            codec: codec.to_string(),
            rate,
            resync_every: c.i64_or("downlink.resync_every", 0) as u64,
        }))
    }

    /// Parse the optional `[telemetry]` section. Grammar:
    ///
    /// ```toml
    /// [telemetry]
    /// trace = "runs/trace.jsonl"  # required when the section is present
    /// capacity = 0                # event ring size; 0 = auto from cohort
    /// ```
    fn telemetry_from_config(c: &Config) -> crate::Result<Option<TelemetrySpec>> {
        let Some(trace) = c.get("telemetry.trace").and_then(|v| v.as_str()) else {
            crate::ensure!(
                c.get("telemetry.capacity").is_none(),
                "[telemetry] has a capacity but no trace path — set telemetry.trace"
            );
            return Ok(None);
        };
        crate::ensure!(!trace.is_empty(), "telemetry.trace must not be empty");
        Ok(Some(TelemetrySpec {
            trace: trace.to_string(),
            capacity: c.usize_or("telemetry.capacity", 0),
        }))
    }

    /// Parse the optional `[channel]` section. Grammar:
    ///
    /// ```toml
    /// [channel]
    /// model = "tiers"            # uniform | tiers | lognormal | markov
    /// policy = "theory"          # uniform | proportional | theory
    /// # model parameters (each defaults to its preset value, derived
    /// # from quantizer.rate):
    /// tiers = [1.0, 2.0, 4.0]    # tiers: capacity classes (bits/entry)
    /// median = 2.0               # lognormal: median capacity
    /// sigma = 0.6                # lognormal: log-std
    /// good = 4.0                 # markov: good-state capacity
    /// bad = 0.5                  # markov: bad-state capacity
    /// p_good_to_bad = 0.2        # markov: per-round transition
    /// p_bad_to_good = 0.4
    /// ```
    ///
    /// Absent section (no `channel.model` key) = homogeneous uplink.
    fn channel_from_config(c: &Config) -> crate::Result<Option<ChannelPlanSpec>> {
        let Some(model_name) = c.get("channel.model").and_then(|v| v.as_str()) else {
            crate::ensure!(
                c.get("channel.policy").is_none(),
                "[channel] has a policy but no model — set channel.model"
            );
            return Ok(None);
        };
        let base_rate = c.f64_or("quantizer.rate", 2.0);
        // Start from the preset at the run's base rate, then let explicit
        // keys override each parameter.
        let mut model = ChannelModel::by_name(model_name, base_rate)?;
        match &mut model {
            ChannelModel::Fixed { rate } => {
                *rate = c.f64_or("channel.rate", *rate);
            }
            ChannelModel::Tiers { rates } => {
                if let Some(arr) = c.get("channel.tiers").and_then(|v| v.as_array()) {
                    let parsed: Option<Vec<f64>> = arr.iter().map(|v| v.as_f64()).collect();
                    *rates = parsed
                        .ok_or_else(|| crate::format_err!("channel.tiers must be numeric"))?;
                }
            }
            ChannelModel::LogNormal { median, sigma } => {
                *median = c.f64_or("channel.median", *median);
                *sigma = c.f64_or("channel.sigma", *sigma);
            }
            ChannelModel::Markov { good, bad, p_good_to_bad, p_bad_to_good } => {
                *good = c.f64_or("channel.good", *good);
                *bad = c.f64_or("channel.bad", *bad);
                *p_good_to_bad = c.f64_or("channel.p_good_to_bad", *p_good_to_bad);
                *p_bad_to_good = c.f64_or("channel.p_bad_to_good", *p_bad_to_good);
            }
        }
        model.validate()?;
        let policy = c.str_or("channel.policy", "uniform");
        // Resolve now so config typos fail at load, not mid-run.
        controller_by_name(&policy)?;
        Ok(Some(ChannelPlanSpec { model, policy }))
    }

    /// Parse the optional `[fleet]` section. Absent section = full
    /// participation (the paper configs keep working unchanged).
    fn fleet_from_config(c: &Config) -> crate::Result<Scenario> {
        let cohort = c.usize_or("fleet.cohort", 0);
        let sampler_name =
            c.str_or("fleet.sampler", if cohort == 0 { "full" } else { "uniform" });
        let sampler = match sampler_name.as_str() {
            "full" => SamplerKind::Full,
            "uniform" => SamplerKind::Uniform { cohort },
            "weighted" => SamplerKind::Weighted { cohort },
            other => crate::bail!(
                "unknown fleet.sampler '{other}' (valid: full, uniform, weighted)"
            ),
        };
        crate::ensure!(
            matches!(sampler, SamplerKind::Full) || cohort > 0,
            "fleet.sampler = \"{sampler_name}\" requires fleet.cohort > 0"
        );
        let median = c.f64_or("fleet.latency_median", 0.0);
        let latency = if median > 0.0 {
            LatencyModel::LogNormal { median, sigma: c.f64_or("fleet.latency_sigma", 0.8) }
        } else {
            LatencyModel::Fixed(0.0)
        };
        let deadline = c.f64_or("fleet.deadline", 0.0);
        let corrupt = c.f64_or("fleet.corrupt", 0.0);
        crate::ensure!(
            (0.0..=1.0).contains(&corrupt),
            "fleet.corrupt = {corrupt} must be a probability in [0, 1]"
        );
        Ok(Scenario {
            sampler,
            over_select: c.f64_or("fleet.over_select", 0.0),
            faults: FaultPlan {
                latency,
                dropout: c.f64_or("fleet.dropout", 0.0),
                deadline: (deadline > 0.0).then_some(deadline),
                wire: WirePlan {
                    corrupt_prob: corrupt,
                    max_retries: c.usize_or("fleet.max_retries", 0) as u32,
                },
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules() {
        let c = LrSchedule::Const(0.1);
        assert_eq!(c.at(0), 0.1);
        assert_eq!(c.at(100), 0.1);
        let d = LrSchedule::InvT { beta: 10.0, gamma: 10.0 };
        assert_eq!(d.at(0), 1.0);
        assert!(d.at(90) <= 0.1 + 1e-9);
    }

    #[test]
    fn alphas_proportional_to_shard_size() {
        let mk = |n: usize| Dataset {
            x: vec![0.0; n],
            y: vec![0; n],
            features: 1,
            classes: 1,
        };
        let cfg = FlConfig {
            users: 2,
            rounds: 1,
            local_steps: 1,
            batch_size: 0,
            lr: LrSchedule::Const(0.1),
            rate: 2.0,
            seed: 1,
            workers: 1,
            shards: 1,
            eval_every: 1,
            verbose: false,
            fleet: Scenario::full(),
            channel: None,
            telemetry: None,
            downlink: None,
        };
        let a = cfg.alphas(&[mk(30), mk(10)]);
        assert!((a[0] - 0.75).abs() < 1e-12);
        assert!((a[1] - 0.25).abs() < 1e-12);
        assert!((a.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn from_config_defaults() {
        let c = Config::parse("[fl]\nusers = 3\nrounds = 7").unwrap();
        let f = FlConfig::from_config(&c).unwrap();
        assert_eq!(f.users, 3);
        assert_eq!(f.rounds, 7);
        assert_eq!(f.local_steps, 1);
        assert_eq!(f.shards, 1, "absent fl.shards = single-aggregator fold");
        assert_eq!(f.fleet, Scenario::full(), "absent [fleet] = full participation");
    }

    #[test]
    fn fleet_section_parses() {
        let c = Config::parse(
            "[fleet]\ncohort = 64\nsampler = \"weighted\"\nover_select = 0.25\n\
             dropout = 0.05\ndeadline = 3.0\nlatency_median = 1.0\nlatency_sigma = 0.5\n\
             corrupt = 0.1\nmax_retries = 2",
        )
        .unwrap();
        let f = FlConfig::from_config(&c).unwrap();
        assert_eq!(f.fleet.sampler, SamplerKind::Weighted { cohort: 64 });
        assert_eq!(f.fleet.over_select, 0.25);
        assert_eq!(f.fleet.faults.dropout, 0.05);
        assert_eq!(f.fleet.faults.deadline, Some(3.0));
        assert_eq!(
            f.fleet.faults.latency,
            LatencyModel::LogNormal { median: 1.0, sigma: 0.5 }
        );
        assert_eq!(f.fleet.faults.wire, WirePlan { corrupt_prob: 0.1, max_retries: 2 });
        assert!(f.fleet.faults.wire.active());
    }

    #[test]
    fn corrupt_probability_is_validated() {
        let c = Config::parse("[fleet]\ncorrupt = 1.5").unwrap();
        assert!(FlConfig::from_config(&c).is_err(), "corrupt > 1 must be rejected at load");
    }

    #[test]
    fn cohort_without_sampler_defaults_to_uniform() {
        let c = Config::parse("[fleet]\ncohort = 8").unwrap();
        let f = FlConfig::from_config(&c).unwrap();
        assert_eq!(f.fleet.sampler, SamplerKind::Uniform { cohort: 8 });
    }

    #[test]
    fn absent_channel_section_means_homogeneous_uplink() {
        let c = Config::parse("[fl]\nusers = 2").unwrap();
        assert_eq!(FlConfig::from_config(&c).unwrap().channel, None);
    }

    #[test]
    fn telemetry_section_parses() {
        let c = Config::parse("[fl]\nusers = 2").unwrap();
        assert_eq!(FlConfig::from_config(&c).unwrap().telemetry, None);

        let c = Config::parse("[telemetry]\ntrace = \"runs/t.jsonl\"\ncapacity = 4096").unwrap();
        assert_eq!(
            FlConfig::from_config(&c).unwrap().telemetry,
            Some(TelemetrySpec { trace: "runs/t.jsonl".to_string(), capacity: 4096 })
        );

        let c = Config::parse("[telemetry]\ntrace = \"t.jsonl\"").unwrap();
        assert_eq!(FlConfig::from_config(&c).unwrap().telemetry.unwrap().capacity, 0);

        for bad in ["[telemetry]\ncapacity = 64", "[telemetry]\ntrace = \"\""] {
            let c = Config::parse(bad).unwrap();
            assert!(FlConfig::from_config(&c).is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn channel_section_parses_presets_and_overrides() {
        let c = Config::parse(
            "[quantizer]\nrate = 2.0\n[channel]\nmodel = \"tiers\"\npolicy = \"theory\"\n\
             tiers = [0.5, 2.0, 8.0]",
        )
        .unwrap();
        let spec = FlConfig::from_config(&c).unwrap().channel.unwrap();
        assert_eq!(spec.model, ChannelModel::Tiers { rates: vec![0.5, 2.0, 8.0] });
        assert_eq!(spec.policy, "theory");
        spec.build(7).unwrap();

        // Preset parameters derive from quantizer.rate when not given.
        let c = Config::parse("[quantizer]\nrate = 4.0\n[channel]\nmodel = \"lognormal\"")
            .unwrap();
        let spec = FlConfig::from_config(&c).unwrap().channel.unwrap();
        assert_eq!(spec.model, ChannelModel::LogNormal { median: 4.0, sigma: 0.6 });
        assert_eq!(spec.policy, "uniform");

        let c = Config::parse(
            "[channel]\nmodel = \"markov\"\ngood = 6.0\nbad = 0.5\n\
             p_good_to_bad = 0.1\np_bad_to_good = 0.9\npolicy = \"proportional\"",
        )
        .unwrap();
        let spec = FlConfig::from_config(&c).unwrap().channel.unwrap();
        assert_eq!(
            spec.model,
            ChannelModel::Markov { good: 6.0, bad: 0.5, p_good_to_bad: 0.1, p_bad_to_good: 0.9 }
        );
    }

    #[test]
    fn downlink_section_parses() {
        let c = Config::parse("[fl]\nusers = 2").unwrap();
        assert_eq!(FlConfig::from_config(&c).unwrap().downlink, None);

        let c = Config::parse(
            "[downlink]\ncodec = \"uveqfed-l2\"\nrate = 1.5\nresync_every = 8",
        )
        .unwrap();
        let spec = FlConfig::from_config(&c).unwrap().downlink.unwrap();
        assert_eq!(
            spec,
            DownlinkPlanSpec { codec: "uveqfed-l2".into(), rate: 1.5, resync_every: 8 }
        );
        assert_eq!(spec.build().unwrap().name(), "uveqfed-l2");

        // Rate defaults to the uplink quantizer rate; resync_every to 0.
        let c = Config::parse("[quantizer]\nrate = 4.0\n[downlink]\ncodec = \"qsgd\"").unwrap();
        let spec = FlConfig::from_config(&c).unwrap().downlink.unwrap();
        assert_eq!(spec.rate, 4.0);
        assert_eq!(spec.resync_every, 0);
    }

    #[test]
    fn downlink_config_mistakes_are_errors() {
        for bad in [
            "[downlink]\ncodec = \"nope\"",
            "[downlink]\nrate = 2.0",         // rate without codec
            "[downlink]\nresync_every = 4",   // bound without codec
            "[downlink]\ncodec = \"qsgd\"\nrate = 0.0",
        ] {
            let c = Config::parse(bad).unwrap();
            assert!(FlConfig::from_config(&c).is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn channel_config_mistakes_are_errors() {
        for bad in [
            "[channel]\nmodel = \"nope\"",
            "[channel]\npolicy = \"theory\"", // policy without model
            "[channel]\nmodel = \"tiers\"\npolicy = \"nope\"",
            "[channel]\nmodel = \"tiers\"\ntiers = [\"a\"]",
            "[channel]\nmodel = \"lognormal\"\nsigma = -1.0",
            "[channel]\nmodel = \"markov\"\np_good_to_bad = 2.0",
        ] {
            let c = Config::parse(bad).unwrap();
            assert!(FlConfig::from_config(&c).is_err(), "{bad} should fail");
        }
    }
}
