//! Local-training abstraction: how a client turns `(w_t, shard)` into
//! `w̃_{t+τ}`. Two implementations exist — [`NativeTrainer`] (pure Rust
//! models, used for theory workloads and as an oracle) and
//! `runtime::HloTrainer` (the production path through the AOT-compiled JAX
//! graphs).

use crate::data::Dataset;
use crate::models::{EvalReport, Model};
use crate::prng::{Rng, SplitMix64, Xoshiro256pp};

/// Client-side local training + server-side evaluation interface.
pub trait Trainer: Send + Sync {
    fn num_params(&self) -> usize;

    fn init_params(&self, seed: u64) -> Vec<f32>;

    /// Run `tau` local SGD steps from `w0` on `shard`; `batch_size == 0`
    /// means full-batch gradient descent. `seed` derives the local
    /// mini-batch sampling stream (i_t^{(k)} in §IV-A).
    fn local_update(
        &self,
        w0: &[f32],
        shard: &Dataset,
        tau: usize,
        lr: f32,
        batch_size: usize,
        seed: u64,
    ) -> Vec<f32>;

    fn evaluate(&self, w: &[f32], ds: &Dataset) -> EvalReport;

    /// Upper bound on concurrent `local_update` calls (PJRT executables
    /// serialize; native models parallelize freely).
    fn max_workers(&self) -> usize {
        usize::MAX
    }
}

/// Pure-Rust trainer over any [`Model`].
pub struct NativeTrainer<M: Model> {
    model: M,
}

impl<M: Model> NativeTrainer<M> {
    pub fn new(model: M) -> Self {
        Self { model }
    }

    pub fn model(&self) -> &M {
        &self.model
    }
}

impl<M: Model> Trainer for NativeTrainer<M> {
    fn num_params(&self) -> usize {
        self.model.num_params()
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        self.model.init_params(seed)
    }

    fn local_update(
        &self,
        w0: &[f32],
        shard: &Dataset,
        tau: usize,
        lr: f32,
        batch_size: usize,
        seed: u64,
    ) -> Vec<f32> {
        let mut w = w0.to_vec();
        let mut grad = vec![0.0f32; w.len()];
        let mut rng = Xoshiro256pp::seed_from_u64(SplitMix64::new(seed).next());
        let full: Vec<usize> = (0..shard.len()).collect();
        for _ in 0..tau {
            let batch: Vec<usize> = if batch_size == 0 || batch_size >= shard.len() {
                full.clone()
            } else {
                (0..batch_size).map(|_| rng.gen_index(shard.len())).collect()
            };
            self.model.gradient(&w, shard, &batch, &mut grad);
            for (wv, &g) in w.iter_mut().zip(grad.iter()) {
                *wv -= lr * g;
            }
        }
        w
    }

    fn evaluate(&self, w: &[f32], ds: &Dataset) -> EvalReport {
        self.model.evaluate(w, ds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthMnist;
    use crate::models::LogReg;

    #[test]
    fn local_update_descends() {
        let ds = SynthMnist::new(21).dataset(100);
        let model = LogReg::new(ds.features, ds.classes, 1e-3);
        let tr = NativeTrainer::new(model);
        let w0 = tr.init_params(1);
        let l0 = tr.evaluate(&w0, &ds).loss;
        let w1 = tr.local_update(&w0, &ds, 10, 0.5, 0, 3);
        let l1 = tr.evaluate(&w1, &ds).loss;
        assert!(l1 < l0, "{l1} !< {l0}");
    }

    #[test]
    fn minibatch_path_deterministic_given_seed() {
        let ds = SynthMnist::new(21).dataset(60);
        let model = LogReg::new(ds.features, ds.classes, 1e-3);
        let tr = NativeTrainer::new(model);
        let w0 = tr.init_params(1);
        let a = tr.local_update(&w0, &ds, 5, 0.1, 8, 42);
        let b = tr.local_update(&w0, &ds, 5, 0.1, 8, 42);
        let c = tr.local_update(&w0, &ds, 5, 0.1, 8, 43);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn tau_zero_is_identity() {
        let ds = SynthMnist::new(21).dataset(30);
        let model = LogReg::new(ds.features, ds.classes, 1e-3);
        let tr = NativeTrainer::new(model);
        let w0 = tr.init_params(1);
        assert_eq!(tr.local_update(&w0, &ds, 0, 0.1, 0, 1), w0);
    }
}
