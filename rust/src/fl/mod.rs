//! Federated learning runtime: local-SGD federated averaging (§II-A /
//! §IV-A) over rate-constrained uplinks.
//!
//! `run_federated` drives the full loop of Fig. 1: broadcast → τ local
//! steps per user → encode update (any [`crate::quantizer::UpdateCodec`])
//! → metered uplink → decode + federated averaging → evaluate. The
//! systems pieces (fan-out, uplink accounting, aggregation) live in
//! [`crate::coordinator`]; this module owns the algorithmic schedule.

mod config;
mod trainer;

pub use config::{FlConfig, LrSchedule};
pub use trainer::{NativeTrainer, Trainer};

use crate::coordinator::{RoundDriver, RoundStats};
use crate::data::Dataset;
use crate::metrics::{CsvTable, Timer};
use crate::quantizer::UpdateCodec;

/// One evaluation point of a federated run.
#[derive(Debug, Clone, Copy)]
pub struct HistoryRow {
    pub round: usize,
    /// Global iteration index t = round·τ.
    pub t: usize,
    pub test_loss: f64,
    pub test_accuracy: f64,
    /// Cumulative uplink bits across all users.
    pub uplink_bits: f64,
    /// Per-round aggregate distortion ‖ĥ − Σα_k h_k‖² / m.
    pub aggregate_distortion: f64,
    pub wall_secs: f64,
}

/// Full run record; converts to CSV for the figure harnesses.
#[derive(Debug, Clone, Default)]
pub struct FlHistory {
    pub rows: Vec<HistoryRow>,
    pub final_weights: Vec<f32>,
}

impl FlHistory {
    pub fn to_table(&self) -> CsvTable {
        let mut t = CsvTable::new(&[
            "round",
            "t",
            "test_loss",
            "test_accuracy",
            "uplink_bits",
            "aggregate_distortion",
            "wall_secs",
        ]);
        for r in &self.rows {
            t.push(vec![
                r.round as f64,
                r.t as f64,
                r.test_loss,
                r.test_accuracy,
                r.uplink_bits,
                r.aggregate_distortion,
                r.wall_secs,
            ]);
        }
        t
    }

    pub fn final_accuracy(&self) -> f64 {
        self.rows.last().map(|r| r.test_accuracy).unwrap_or(0.0)
    }

    pub fn best_accuracy(&self) -> f64 {
        self.rows.iter().map(|r| r.test_accuracy).fold(0.0, f64::max)
    }
}

/// Execute a federated training run.
pub fn run_federated(
    cfg: &FlConfig,
    trainer: &dyn Trainer,
    shards: &[Dataset],
    test: &Dataset,
    codec: &dyn UpdateCodec,
) -> FlHistory {
    assert_eq!(shards.len(), cfg.users, "shard count != users");
    let alphas = cfg.alphas(shards);
    let mut w = trainer.init_params(cfg.seed);
    let driver = RoundDriver::new(cfg.seed, cfg.rate, cfg.workers.min(trainer.max_workers()));
    let mut history = FlHistory::default();
    let wall = Timer::start();
    let mut uplink_total = 0.0f64;

    for round in 0..cfg.rounds {
        let t = round * cfg.local_steps;
        let lr = cfg.lr.at(t);
        let stats: RoundStats = driver.run_round(
            round as u64,
            &mut w,
            shards,
            trainer,
            codec,
            &alphas,
            cfg.local_steps,
            lr,
            cfg.batch_size,
        );
        uplink_total += stats.uplink_bits as f64;

        if round % cfg.eval_every == 0 || round + 1 == cfg.rounds {
            let rep = trainer.evaluate(&w, test);
            history.rows.push(HistoryRow {
                round,
                t: t + cfg.local_steps,
                test_loss: rep.loss,
                test_accuracy: rep.accuracy,
                uplink_bits: uplink_total,
                aggregate_distortion: stats.aggregate_distortion,
                wall_secs: wall.elapsed_secs(),
            });
            if cfg.verbose {
                println!(
                    "round {round:>4}  loss {:.4}  acc {:.4}  bits {:.3e}  dist {:.3e}",
                    rep.loss, rep.accuracy, uplink_total, stats.aggregate_distortion
                );
            }
        }
    }
    history.final_weights = w;
    history
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{partition, PartitionScheme, SynthMnist};
    use crate::models::LogReg;
    use crate::quantizer;

    fn quick_cfg(users: usize, rounds: usize, rate: f64) -> FlConfig {
        FlConfig {
            users,
            rounds,
            local_steps: 1,
            batch_size: 0,
            lr: LrSchedule::Const(0.5),
            rate,
            seed: 7,
            workers: 4,
            eval_every: rounds.max(1),
            verbose: false,
        }
    }

    #[test]
    fn federated_logreg_learns_with_uveqfed() {
        let gen = SynthMnist::new(11);
        let ds = gen.dataset(300);
        let test = gen.test_dataset(100);
        let shards = partition(&ds, 5, 60, PartitionScheme::Iid, 3);
        let model = LogReg::new(ds.features, ds.classes, 1e-3);
        let trainer = NativeTrainer::new(model);
        let codec = quantizer::by_name("uveqfed-l2");
        let hist = run_federated(&quick_cfg(5, 25, 4.0), &trainer, &shards, &test, codec.as_ref());
        assert!(hist.final_accuracy() > 0.5, "acc {}", hist.final_accuracy());
        let bits = hist.rows.last().unwrap().uplink_bits;
        assert!(bits > 0.0);
    }

    #[test]
    fn quantized_tracks_unquantized() {
        let gen = SynthMnist::new(12);
        let ds = gen.dataset(300);
        let test = gen.test_dataset(100);
        let shards = partition(&ds, 5, 60, PartitionScheme::Iid, 3);
        let model = LogReg::new(ds.features, ds.classes, 1e-3);
        let trainer = NativeTrainer::new(model);
        let idc = quantizer::by_name("identity");
        let uvq = quantizer::by_name("uveqfed-l2");
        let h_id =
            run_federated(&quick_cfg(5, 20, 4.0), &trainer, &shards, &test, idc.as_ref());
        let h_uv =
            run_federated(&quick_cfg(5, 20, 4.0), &trainer, &shards, &test, uvq.as_ref());
        // At R=4 UVeQFed should be within a few points of unquantized.
        assert!(
            h_uv.final_accuracy() > h_id.final_accuracy() - 0.1,
            "uveqfed {} vs identity {}",
            h_uv.final_accuracy(),
            h_id.final_accuracy()
        );
    }

    #[test]
    fn history_table_shape() {
        let gen = SynthMnist::new(13);
        let ds = gen.dataset(100);
        let test = gen.test_dataset(50);
        let shards = partition(&ds, 2, 50, PartitionScheme::Iid, 3);
        let model = LogReg::new(ds.features, ds.classes, 1e-3);
        let trainer = NativeTrainer::new(model);
        let codec = quantizer::by_name("qsgd");
        let mut cfg = quick_cfg(2, 6, 2.0);
        cfg.eval_every = 2;
        let hist = run_federated(&cfg, &trainer, &shards, &test, codec.as_ref());
        let table = hist.to_table();
        assert_eq!(table.header.len(), 7);
        assert!(table.rows.len() >= 3);
        // uplink bits monotone
        for w in table.rows.windows(2) {
            assert!(w[1][4] >= w[0][4]);
        }
    }
}
