//! Federated learning runtime: local-SGD federated averaging (§II-A /
//! §IV-A) over rate-constrained uplinks.
//!
//! `run_federated` drives the full loop of Fig. 1: broadcast → τ local
//! steps per user → encode update (any [`crate::quantizer::UpdateCodec`])
//! → metered uplink → decode + federated averaging → evaluate. The
//! systems pieces (fan-out, uplink accounting, aggregation) live in
//! [`crate::coordinator`]; this module owns the algorithmic schedule.

mod config;
mod trainer;

pub use config::{ChannelPlanSpec, DownlinkPlanSpec, FlConfig, LrSchedule, TelemetrySpec};
pub use trainer::{NativeTrainer, Trainer};

use crate::data::Dataset;
use crate::fleet::{
    ClientRecords, DownlinkSpec, FleetDriver, FleetRoundReport, RoundSpec, ShardPool,
    VirtualClock,
};
use crate::metrics::{CsvTable, Timer};
use crate::quantizer::UpdateCodec;
use crate::telemetry::{summarize, Collector, TraceWriter};

/// One evaluation point of a federated run.
#[derive(Debug, Clone, Copy)]
pub struct HistoryRow {
    pub round: usize,
    /// Global iteration index t = round·τ.
    pub t: usize,
    pub test_loss: f64,
    pub test_accuracy: f64,
    /// Cumulative uplink bits across all users.
    pub uplink_bits: f64,
    /// Per-round aggregate distortion ‖ĥ − Σα_k h_k‖² / m.
    pub aggregate_distortion: f64,
    pub wall_secs: f64,
    /// Clients selected this round (cohort + over-selection).
    pub selected: usize,
    /// Updates aggregated this round (arrivals within deadline/quota).
    pub completed: usize,
    /// Fraction of the selected cohort's α weight that aggregated.
    pub alpha_mass: f64,
    /// Modeled (virtual) duration of this round, seconds.
    pub round_latency: f64,
    /// Cumulative serialized uplink bytes (frame headers included).
    pub wire_bytes: f64,
    /// Selected clients that missed the round deadline.
    pub deadline_misses: usize,
    /// Mean assigned rate over the round's aggregated clients
    /// (bits/entry); equals the configured rate on a homogeneous uplink.
    pub mean_assigned_rate: f64,
}

/// One column of the run history: CSV header name + value extractor.
pub type HistoryColumn = (&'static str, fn(&HistoryRow) -> f64);

/// Single source of truth for the history schema. [`FlHistory::to_table`]
/// derives both the CSV header and every row from this table, so adding
/// a metric is one entry here plus one field on [`HistoryRow`] — the
/// header, the push order and the column count can no longer drift apart.
pub const HISTORY_COLUMNS: &[HistoryColumn] = &[
    ("round", |r| r.round as f64),
    ("t", |r| r.t as f64),
    ("test_loss", |r| r.test_loss),
    ("test_accuracy", |r| r.test_accuracy),
    ("uplink_bits", |r| r.uplink_bits),
    ("aggregate_distortion", |r| r.aggregate_distortion),
    ("wall_secs", |r| r.wall_secs),
    ("selected", |r| r.selected as f64),
    ("completed", |r| r.completed as f64),
    ("alpha_mass", |r| r.alpha_mass),
    ("round_latency", |r| r.round_latency),
    ("wire_bytes", |r| r.wire_bytes),
    ("deadline_misses", |r| r.deadline_misses as f64),
    ("mean_assigned_rate", |r| r.mean_assigned_rate),
];

/// Full run record; converts to CSV for the figure harnesses.
#[derive(Debug, Clone, Default)]
pub struct FlHistory {
    pub rows: Vec<HistoryRow>,
    pub final_weights: Vec<f32>,
}

impl FlHistory {
    pub fn to_table(&self) -> CsvTable {
        let names: Vec<&str> = HISTORY_COLUMNS.iter().map(|&(name, _)| name).collect();
        let mut t = CsvTable::new(&names);
        for r in &self.rows {
            t.push(HISTORY_COLUMNS.iter().map(|&(_, extract)| extract(r)).collect());
        }
        t
    }

    pub fn final_accuracy(&self) -> f64 {
        self.rows.last().map(|r| r.test_accuracy).unwrap_or(0.0)
    }

    pub fn best_accuracy(&self) -> f64 {
        self.rows.iter().map(|r| r.test_accuracy).fold(0.0, f64::max)
    }
}

/// Execute a federated training run.
pub fn run_federated(
    cfg: &FlConfig,
    trainer: &dyn Trainer,
    shards: &[Dataset],
    test: &Dataset,
    codec: &dyn UpdateCodec,
) -> FlHistory {
    assert_eq!(shards.len(), cfg.users, "shard count != users");
    let alphas = cfg.alphas(shards);
    let pool = ShardPool::with_weights(shards, &alphas);
    let mut w = trainer.init_params(cfg.seed);
    let mut driver = FleetDriver::new(
        cfg.seed,
        cfg.rate,
        cfg.workers.min(trainer.max_workers()),
        cfg.fleet.clone(),
    )
    .with_shards(cfg.shards);
    if let Some(spec) = &cfg.channel {
        // Config-file paths validated this at load; programmatically
        // constructed FlConfigs surface the registry's own error here.
        driver = driver.with_rate_plan(
            spec.build(cfg.seed).unwrap_or_else(|e| panic!("invalid [channel] plan: {e}")),
        );
    }
    // Optional [telemetry] tracing: one collector for the run, drained to
    // JSONL after every round. File errors abort with context — a traced
    // experiment that silently loses its trace is worse than one that
    // stops.
    let (collector, mut tracer) = match &cfg.telemetry {
        Some(tspec) => {
            let collector = if tspec.capacity > 0 {
                Collector::new(tspec.capacity)
            } else {
                Collector::for_cohort(cfg.fleet.sampler.target(cfg.users))
            };
            let writer = TraceWriter::create(&tspec.trace)
                .unwrap_or_else(|e| panic!("telemetry.trace '{}': {e}", tspec.trace));
            (collector, Some(writer))
        }
        None => (Collector::disabled(), None),
    };
    // Optional [downlink] coded broadcast: the codec is built once for
    // the run (the per-round `DownlinkSpec` borrows it). Config-file
    // paths validated the name at load.
    let downlink: Option<(Box<dyn UpdateCodec>, f64, u64)> = cfg.downlink.as_ref().map(|d| {
        let codec = d.build().unwrap_or_else(|e| panic!("invalid [downlink] codec: {e}"));
        (codec, d.rate, d.resync_every)
    });
    let mut clock = VirtualClock::new();
    let mut history = FlHistory::default();
    let wall = Timer::start();
    let mut uplink_total = 0.0f64;
    let mut wire_total = 0.0f64;

    for round in 0..cfg.rounds {
        let t = round * cfg.local_steps;
        let spec = RoundSpec {
            round: round as u64,
            local_steps: cfg.local_steps,
            lr: cfg.lr.at(t),
            batch_size: cfg.batch_size,
            trainer,
            codec,
            rate_override: None,
            telemetry: Some(&collector),
            client_records: ClientRecords::Full,
            downlink: downlink.as_ref().map(|(dl_codec, rate, resync_every)| {
                DownlinkSpec::new(dl_codec.as_ref(), *rate).with_resync_every(*resync_every)
            }),
        };
        let rep: FleetRoundReport = driver.run_round(&spec, &mut w, &pool, &mut clock);
        if let Some(writer) = tracer.as_mut() {
            let events = collector.drain();
            let dropped = collector.take_dropped();
            writer.write_events(&events).expect("write trace spans");
            for (i, s) in summarize(&events).into_iter().enumerate() {
                writer
                    .write_round(&s, if i == 0 { dropped } else { 0 })
                    .expect("write trace round line");
            }
        }
        // Budget violations are codec bugs or a rate plan starving a
        // fixed-length codec — never injected faults (faults model
        // latency/dropout, not bit inflation). Abort loudly rather than
        // silently training on a shrunken cohort; callers that want to
        // observe violations drive `FleetDriver` directly.
        assert_eq!(
            rep.budget_violations, 0,
            "round {round}: {} uplink budget violation(s) — {}",
            rep.budget_violations,
            if cfg.channel.is_some() {
                "codec bug, or the [channel] plan starves a fixed-length codec \
                 (terngrad/signsgd cannot shrink below their floor; use a \
                 variable-rate codec or raise the bad-state capacity)"
            } else {
                "codec bug"
            }
        );
        uplink_total += rep.uplink_bits as f64;
        wire_total += rep.wire_bytes as f64;

        if round % cfg.eval_every == 0 || round + 1 == cfg.rounds {
            let eval = trainer.evaluate(&w, test);
            history.rows.push(HistoryRow {
                round,
                t: t + cfg.local_steps,
                test_loss: eval.loss,
                test_accuracy: eval.accuracy,
                uplink_bits: uplink_total,
                aggregate_distortion: rep.aggregate_distortion,
                wall_secs: wall.elapsed_secs(),
                selected: rep.selected,
                completed: rep.aggregated,
                alpha_mass: rep.alpha_mass,
                round_latency: rep.timing.duration,
                wire_bytes: wire_total,
                deadline_misses: rep.late,
                mean_assigned_rate: rep.channel.mean_rate,
            });
            if cfg.verbose {
                println!(
                    "round {round:>4}  loss {:.4}  acc {:.4}  bits {:.3e}  dist {:.3e}  \
                     cohort {}/{}  αmass {:.3}",
                    eval.loss,
                    eval.accuracy,
                    uplink_total,
                    rep.aggregate_distortion,
                    rep.aggregated,
                    rep.selected,
                    rep.alpha_mass
                );
            }
        }
    }
    if let Some(mut writer) = tracer {
        writer.flush().expect("flush trace");
    }
    history.final_weights = w;
    history
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{partition, PartitionScheme, SynthMnist};
    use crate::models::LogReg;
    use crate::quantizer;

    fn quick_cfg(users: usize, rounds: usize, rate: f64) -> FlConfig {
        FlConfig {
            users,
            rounds,
            local_steps: 1,
            batch_size: 0,
            lr: LrSchedule::Const(0.5),
            rate,
            seed: 7,
            workers: 4,
            shards: 1,
            eval_every: rounds.max(1),
            verbose: false,
            fleet: crate::fleet::Scenario::full(),
            channel: None,
            telemetry: None,
            downlink: None,
        }
    }

    #[test]
    fn federated_logreg_learns_with_uveqfed() {
        let gen = SynthMnist::new(11);
        let ds = gen.dataset(300);
        let test = gen.test_dataset(100);
        let shards = partition(&ds, 5, 60, PartitionScheme::Iid, 3);
        let model = LogReg::new(ds.features, ds.classes, 1e-3);
        let trainer = NativeTrainer::new(model);
        let codec = quantizer::make("uveqfed-l2").unwrap();
        let hist = run_federated(&quick_cfg(5, 25, 4.0), &trainer, &shards, &test, codec.as_ref());
        assert!(hist.final_accuracy() > 0.5, "acc {}", hist.final_accuracy());
        let bits = hist.rows.last().unwrap().uplink_bits;
        assert!(bits > 0.0);
    }

    #[test]
    fn quantized_tracks_unquantized() {
        let gen = SynthMnist::new(12);
        let ds = gen.dataset(300);
        let test = gen.test_dataset(100);
        let shards = partition(&ds, 5, 60, PartitionScheme::Iid, 3);
        let model = LogReg::new(ds.features, ds.classes, 1e-3);
        let trainer = NativeTrainer::new(model);
        let idc = quantizer::make("identity").unwrap();
        let uvq = quantizer::make("uveqfed-l2").unwrap();
        let h_id =
            run_federated(&quick_cfg(5, 20, 4.0), &trainer, &shards, &test, idc.as_ref());
        let h_uv =
            run_federated(&quick_cfg(5, 20, 4.0), &trainer, &shards, &test, uvq.as_ref());
        // At R=4 UVeQFed should be within a few points of unquantized.
        assert!(
            h_uv.final_accuracy() > h_id.final_accuracy() - 0.1,
            "uveqfed {} vs identity {}",
            h_uv.final_accuracy(),
            h_id.final_accuracy()
        );
    }

    #[test]
    fn history_table_shape() {
        let gen = SynthMnist::new(13);
        let ds = gen.dataset(100);
        let test = gen.test_dataset(50);
        let shards = partition(&ds, 2, 50, PartitionScheme::Iid, 3);
        let model = LogReg::new(ds.features, ds.classes, 1e-3);
        let trainer = NativeTrainer::new(model);
        let codec = quantizer::make("qsgd").unwrap();
        let mut cfg = quick_cfg(2, 6, 2.0);
        cfg.eval_every = 2;
        let hist = run_federated(&cfg, &trainer, &shards, &test, codec.as_ref());
        let table = hist.to_table();
        // Header and rows both derive from HISTORY_COLUMNS — no hardcoded
        // column count; verify the schema agrees with itself instead.
        assert_eq!(table.header.len(), HISTORY_COLUMNS.len());
        for (name, _) in HISTORY_COLUMNS {
            assert!(table.header.iter().any(|h| h == name), "missing column {name}");
        }
        assert!(table.rows.len() >= 3);
        // uplink bits monotone (look the column up by name, not position)
        let bits_col = table.header.iter().position(|h| h == "uplink_bits").unwrap();
        for w in table.rows.windows(2) {
            assert!(w[1][bits_col] >= w[0][bits_col]);
        }
    }

    #[test]
    fn heterogeneous_channel_run_learns_and_reports_rates() {
        let gen = SynthMnist::new(15);
        let ds = gen.dataset(300);
        let test = gen.test_dataset(100);
        let shards = partition(&ds, 6, 50, PartitionScheme::Iid, 3);
        let model = LogReg::new(ds.features, ds.classes, 1e-3);
        let trainer = NativeTrainer::new(model);
        let codec = quantizer::make("uveqfed-l2").unwrap();
        let mut cfg = quick_cfg(6, 15, 2.0);
        cfg.channel = Some(ChannelPlanSpec {
            model: crate::fleet::ChannelModel::Tiers { rates: vec![1.0, 2.0, 4.0] },
            policy: "theory".into(),
        });
        cfg.eval_every = 5;
        let hist = run_federated(&cfg, &trainer, &shards, &test, codec.as_ref());
        for r in &hist.rows {
            assert!(r.mean_assigned_rate > 0.0, "rate metrics must be surfaced");
        }
        assert!(hist.final_accuracy() > 0.4, "acc {}", hist.final_accuracy());
    }

    #[test]
    fn coded_downlink_run_learns_and_lossless_downlink_is_transparent() {
        let gen = SynthMnist::new(19);
        let ds = gen.dataset(300);
        let test = gen.test_dataset(100);
        let shards = partition(&ds, 5, 60, PartitionScheme::Iid, 3);
        let model = LogReg::new(ds.features, ds.classes, 1e-3);
        let trainer = NativeTrainer::new(model);
        let codec = quantizer::make("uveqfed-l2").unwrap();
        // An identity downlink ships the exact model every round, so the
        // run must reproduce the perfect-downlink weights bit-for-bit.
        let mut cfg = quick_cfg(5, 10, 4.0);
        let perfect = run_federated(&cfg, &trainer, &shards, &test, codec.as_ref());
        cfg.downlink =
            Some(DownlinkPlanSpec { codec: "identity".into(), rate: 4.0, resync_every: 0 });
        let lossless = run_federated(&cfg, &trainer, &shards, &test, codec.as_ref());
        assert_eq!(
            lossless.final_weights, perfect.final_weights,
            "identity downlink must be transparent"
        );
        // A coded downlink distorts the broadcast but still learns.
        cfg.downlink =
            Some(DownlinkPlanSpec { codec: "uveqfed-l2".into(), rate: 4.0, resync_every: 0 });
        cfg.rounds = 25;
        cfg.eval_every = 25;
        let coded = run_federated(&cfg, &trainer, &shards, &test, codec.as_ref());
        assert!(coded.final_accuracy() > 0.5, "acc {}", coded.final_accuracy());
    }

    #[test]
    fn traced_run_writes_jsonl_and_matches_untraced() {
        let gen = SynthMnist::new(21);
        let ds = gen.dataset(120);
        let test = gen.test_dataset(50);
        let shards = partition(&ds, 3, 40, PartitionScheme::Iid, 3);
        let model = LogReg::new(ds.features, ds.classes, 1e-3);
        let trainer = NativeTrainer::new(model);
        let codec = quantizer::make("qsgd").unwrap();
        let path = std::env::temp_dir()
            .join(format!("uveqfed_fl_trace_{}.jsonl", std::process::id()));
        let mut cfg = quick_cfg(3, 2, 2.0);
        cfg.telemetry =
            Some(TelemetrySpec { trace: path.to_string_lossy().into_owned(), capacity: 0 });
        let traced = run_federated(&cfg, &trainer, &shards, &test, codec.as_ref());
        cfg.telemetry = None;
        let untraced = run_federated(&cfg, &trainer, &shards, &test, codec.as_ref());
        assert_eq!(
            traced.final_weights, untraced.final_weights,
            "tracing must not perturb training"
        );
        let text = std::fs::read_to_string(&path).unwrap();
        let mut spans = 0usize;
        let mut rounds = 0usize;
        for (i, line) in text.lines().enumerate() {
            let j = crate::util::json::Json::parse(line).unwrap();
            let ty = j.get("type").and_then(crate::util::json::Json::as_str).unwrap();
            match ty {
                "meta" => assert_eq!(i, 0, "meta must be the first line"),
                "span" => spans += 1,
                "round" => rounds += 1,
                other => panic!("unexpected line type {other}"),
            }
        }
        // 2 rounds × (3 clients × 5 lifecycle spans + rate_alloc +
        // shard_fold for the single default shard).
        assert_eq!(spans, 2 * (3 * 5 + 2));
        assert_eq!(rounds, 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn partial_participation_reports_cohort_and_still_learns() {
        let gen = SynthMnist::new(14);
        let ds = gen.dataset(400);
        let test = gen.test_dataset(100);
        let shards = partition(&ds, 8, 50, PartitionScheme::Iid, 3);
        let model = LogReg::new(ds.features, ds.classes, 1e-3);
        let trainer = NativeTrainer::new(model);
        let codec = quantizer::make("uveqfed-l2").unwrap();
        let mut cfg = quick_cfg(8, 30, 4.0);
        cfg.fleet = crate::fleet::Scenario::sampled(3);
        cfg.eval_every = 5;
        let hist = run_federated(&cfg, &trainer, &shards, &test, codec.as_ref());
        for r in &hist.rows {
            assert_eq!(r.selected, 3);
            assert_eq!(r.completed, 3);
            assert!((r.alpha_mass - 1.0).abs() < 1e-12);
            assert!(r.wire_bytes > 0.0);
        }
        assert!(
            hist.final_accuracy() > 0.4,
            "cohort-sampled run failed to learn: {}",
            hist.final_accuracy()
        );
    }
}
