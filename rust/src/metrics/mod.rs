//! Metrics: wall-clock timers, counters, and CSV emission for experiment
//! curves (the plotting inputs for every reproduced figure).

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;
use std::time::Instant;

/// Simple scoped timer. **Wall-clock only** — in fleet code, which runs on
/// a simulated [`crate::fleet::VirtualClock`], pair measurements with the
/// virtual domain via [`DualTimer`] instead of mixing the two.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }
}

/// A timer spanning both clock domains: wall time from [`Timer`] and
/// simulated fleet time from a caller-supplied virtual clock reading.
///
/// The fleet's `VirtualClock` only advances at round close, so the caller
/// passes the current virtual reading at start and (optionally) at stop —
/// this type stays decoupled from `fleet::` and merely keeps the two
/// measurements together so span records can't mix domains by accident.
#[derive(Debug, Clone, Copy)]
pub struct DualTimer {
    wall_start: Instant,
    virt_start: f64,
}

impl DualTimer {
    /// Start both domains; `virt_now` is the current virtual-clock reading.
    pub fn start(virt_now: f64) -> Self {
        Self { wall_start: Instant::now(), virt_start: virt_now }
    }

    /// Wall seconds since start.
    pub fn wall_secs(&self) -> f64 {
        self.wall_start.elapsed().as_secs_f64()
    }

    /// Virtual-clock reading captured at start.
    pub fn virt_start(&self) -> f64 {
        self.virt_start
    }

    /// `(wall_elapsed, virt_elapsed)` given the current virtual reading.
    pub fn elapsed(&self, virt_now: f64) -> (f64, f64) {
        (self.wall_secs(), virt_now - self.virt_start)
    }
}

/// Accumulating named counters/gauges for a run; rendered as a summary or
/// merged into result JSON.
#[derive(Debug, Default, Clone)]
pub struct Counters {
    vals: BTreeMap<String, f64>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    /// Accumulate `v` into `key`. Allocates only on the first insert of a
    /// key; steady-state calls on a warmed key are allocation-free (the
    /// old `entry(key.to_string())` cloned the key on *every* call). For
    /// fleet hot paths prefer `telemetry::Collector::add_counter`, whose
    /// `&'static str` keys never allocate at all.
    pub fn add(&mut self, key: &str, v: f64) {
        match self.vals.get_mut(key) {
            Some(slot) => *slot += v,
            None => {
                self.vals.insert(key.to_string(), v);
            }
        }
    }

    pub fn set(&mut self, key: &str, v: f64) {
        self.vals.insert(key.to_string(), v);
    }

    pub fn get(&self, key: &str) -> f64 {
        self.vals.get(key).copied().unwrap_or(0.0)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, f64)> {
        self.vals.iter().map(|(k, &v)| (k.as_str(), v))
    }
}

/// CSV table writer with a fixed header, used for figure data.
#[derive(Debug, Clone)]
pub struct CsvTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<f64>>,
}

impl CsvTable {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn push(&mut self, row: Vec<f64>) {
        assert_eq!(row.len(), self.header.len(), "row width mismatch");
        self.rows.push(row);
    }

    pub fn to_csv(&self) -> String {
        let mut s = self.header.join(",");
        s.push('\n');
        for row in &self.rows {
            let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
            s.push_str(&cells.join(","));
            s.push('\n');
        }
        s
    }

    pub fn write_file(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }

    /// Render as an aligned text table for terminal output.
    pub fn to_pretty(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        let cells: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| r.iter().map(|v| format!("{v:.6}")).collect())
            .collect();
        for row in &cells {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut s = String::new();
        for (h, w) in self.header.iter().zip(&widths) {
            s.push_str(&format!("{h:>w$}  ", w = w));
        }
        s.push('\n');
        for row in &cells {
            for (c, w) in row.iter().zip(&widths) {
                s.push_str(&format!("{c:>w$}  ", w = w));
            }
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut c = Counters::new();
        c.add("bits", 10.0);
        c.add("bits", 5.0);
        c.set("rounds", 3.0);
        assert_eq!(c.get("bits"), 15.0);
        assert_eq!(c.get("rounds"), 3.0);
        assert_eq!(c.get("missing"), 0.0);
    }

    #[test]
    fn csv_rendering() {
        let mut t = CsvTable::new(&["round", "acc"]);
        t.push(vec![0.0, 0.1]);
        t.push(vec![1.0, 0.5]);
        assert_eq!(t.to_csv(), "round,acc\n0,0.1\n1,0.5\n");
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        let mut t = CsvTable::new(&["a"]);
        t.push(vec![1.0, 2.0]);
    }

    #[test]
    fn dual_timer_tracks_both_domains() {
        let t = DualTimer::start(12.5);
        assert_eq!(t.virt_start(), 12.5);
        std::thread::sleep(std::time::Duration::from_millis(2));
        let (wall, virt) = t.elapsed(20.0);
        assert!(wall > 0.0);
        assert_eq!(virt, 7.5);
        assert!(t.wall_secs() >= wall);
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
    }
}
