//! Property-testing mini-framework (proptest is not vendorable offline).
//!
//! A `Gen` produces random cases from a seeded RNG; `check` runs N cases
//! and on failure *shrinks* scalar inputs toward zero / smaller structures
//! before reporting, printing the seed so failures replay exactly.

use crate::prng::{Rng, Xoshiro256pp};

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_iters: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self { cases: 256, seed: 0x5EED_CAFE, max_shrink_iters: 400 }
    }
}

/// A generator of test cases with a shrinking strategy.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Xoshiro256pp) -> Self::Value;
    /// Candidate smaller versions of a failing value (simplest first).
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let _ = value;
        Vec::new()
    }
}

/// Run a property; panics with a minimal counterexample on failure.
pub fn check<G: Gen>(name: &str, gen: &G, cfg: PropConfig, prop: impl Fn(&G::Value) -> bool) {
    let mut rng = Xoshiro256pp::seed_from_u64(cfg.seed);
    for case in 0..cfg.cases {
        let value = gen.generate(&mut rng);
        if prop(&value) {
            continue;
        }
        // shrink
        let mut minimal = value.clone();
        let mut iters = 0;
        'outer: loop {
            if iters >= cfg.max_shrink_iters {
                break;
            }
            for cand in gen.shrink(&minimal) {
                iters += 1;
                if !prop(&cand) {
                    minimal = cand;
                    continue 'outer;
                }
                if iters >= cfg.max_shrink_iters {
                    break;
                }
            }
            break;
        }
        panic!(
            "property '{name}' failed at case {case} (seed {:#x}).\n  minimal counterexample: {minimal:?}",
            cfg.seed
        );
    }
}

/// Generator: f32 vectors with length in `[min_len, max_len]`, entries
/// N(0, scale).
pub struct VecF32Gen {
    pub min_len: usize,
    pub max_len: usize,
    pub scale: f64,
}

impl Gen for VecF32Gen {
    type Value = Vec<f32>;

    fn generate(&self, rng: &mut Xoshiro256pp) -> Vec<f32> {
        let n = self.min_len + rng.gen_index(self.max_len - self.min_len + 1);
        (0..n).map(|_| (rng.normal() * self.scale) as f32).collect()
    }

    fn shrink(&self, value: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        // halve the vector
        if value.len() > self.min_len {
            let half = value.len().max(2) / 2;
            if half >= self.min_len {
                out.push(value[..half].to_vec());
            }
            let mut drop_last = value.clone();
            drop_last.pop();
            if drop_last.len() >= self.min_len {
                out.push(drop_last);
            }
        }
        // zero-out entries
        if value.iter().any(|&v| v != 0.0) {
            out.push(value.iter().map(|_| 0.0).collect());
            out.push(value.iter().map(|&v| v / 2.0).collect());
        }
        out
    }
}

/// Generator: i64 vectors (lattice-index-like streams).
pub struct VecI64Gen {
    pub min_len: usize,
    pub max_len: usize,
    pub magnitude: i64,
}

impl Gen for VecI64Gen {
    type Value = Vec<i64>;

    fn generate(&self, rng: &mut Xoshiro256pp) -> Vec<i64> {
        let n = self.min_len + rng.gen_index(self.max_len - self.min_len + 1);
        (0..n)
            .map(|_| {
                let m = (2 * self.magnitude + 1) as usize;
                rng.gen_index(m) as i64 - self.magnitude
            })
            .collect()
    }

    fn shrink(&self, value: &Vec<i64>) -> Vec<Vec<i64>> {
        let mut out = Vec::new();
        if value.len() > self.min_len {
            out.push(value[..value.len() / 2].to_vec());
        }
        if value.iter().any(|&v| v != 0) {
            out.push(value.iter().map(|&v| v / 2).collect());
            out.push(vec![0; value.len()]);
        }
        out
    }
}

/// Generator: pair of (seed, scale) for parameterized properties.
pub struct SeedScaleGen {
    pub max_scale: f64,
}

impl Gen for SeedScaleGen {
    type Value = (u64, f64);

    fn generate(&self, rng: &mut Xoshiro256pp) -> (u64, f64) {
        (rng.next_u64(), rng.uniform() * self.max_scale + 1e-3)
    }

    fn shrink(&self, &(seed, scale): &(u64, f64)) -> Vec<(u64, f64)> {
        let mut out = Vec::new();
        if scale > 1e-3 {
            out.push((seed, scale / 2.0));
        }
        if seed != 0 {
            out.push((0, scale));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_quietly() {
        let g = VecF32Gen { min_len: 0, max_len: 32, scale: 1.0 };
        check("len-bounded", &g, PropConfig::default(), |v| v.len() <= 32);
    }

    #[test]
    fn failing_property_shrinks() {
        let g = VecI64Gen { min_len: 0, max_len: 64, magnitude: 100 };
        let result = std::panic::catch_unwind(|| {
            check("always-small", &g, PropConfig { cases: 64, ..Default::default() }, |v| {
                v.iter().all(|&x| x.abs() < 5)
            });
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("minimal counterexample"), "{msg}");
    }

    #[test]
    fn deterministic_given_seed() {
        let g = VecF32Gen { min_len: 1, max_len: 8, scale: 2.0 };
        let mut r1 = Xoshiro256pp::seed_from_u64(5);
        let mut r2 = Xoshiro256pp::seed_from_u64(5);
        assert_eq!(g.generate(&mut r1), g.generate(&mut r2));
    }
}
