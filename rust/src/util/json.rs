//! Minimal JSON *writer* for results files (serde is not vendorable
//! offline). Only serialization is needed — experiment outputs are JSON /
//! CSV consumed by plotting scripts or humans.

use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn push(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(fields) = self {
            fields.push((key.to_string(), val));
        } else {
            panic!("push on non-object Json");
        }
        self
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_nums(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&v| Json::Num(v)).collect())
    }

    fn escape(s: &str, out: &mut String) {
        out.push('"');
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\t' => out.push_str("\\t"),
                '\r' => out.push_str("\\r"),
                c if (c as u32) < 0x20 => {
                    let _ = write!(out, "\\u{:04x}", c as u32);
                }
                c => out.push(c),
            }
        }
        out.push('"');
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    let _ = write!(out, "{v}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => Self::escape(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Self::escape(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serializes_nested() {
        let mut o = Json::obj();
        o.push("name", Json::str("fig4"));
        o.push("rate", Json::num(2.0));
        o.push("curve", Json::arr_nums(&[1.0, 0.5, 0.25]));
        let mut inner = Json::obj();
        inner.push("ok", Json::Bool(true));
        o.push("meta", inner);
        assert_eq!(
            o.to_string(),
            r#"{"name":"fig4","rate":2,"curve":[1,0.5,0.25],"meta":{"ok":true}}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::str("a\"b\\c\nd");
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::num(f64::NAN).to_string(), "null");
    }
}
