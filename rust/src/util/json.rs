//! Minimal JSON writer + parser for results files (serde is not vendorable
//! offline). Serialization covers experiment outputs; the parser exists so
//! the bench baseline file (`BENCH_baseline.json`) can be read back and
//! merged across bench binaries and snapshots.

use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn push(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(fields) = self {
            fields.push((key.to_string(), val));
        } else {
            panic!("push on non-object Json");
        }
        self
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_nums(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&v| Json::Num(v)).collect())
    }

    fn escape(s: &str, out: &mut String) {
        out.push('"');
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\t' => out.push_str("\\t"),
                '\r' => out.push_str("\\r"),
                c if (c as u32) < 0x20 => {
                    let _ = write!(out, "\\u{:04x}", c as u32);
                }
                c => out.push(c),
            }
        }
        out.push('"');
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    let _ = write!(out, "{v}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => Self::escape(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Self::escape(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    // ── accessors (for parsed documents) ────────────────────────────

    /// Field lookup on an object (first match); `None` otherwise.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Parse a JSON document (strict enough for files this crate writes;
    /// rejects trailing garbage).
    pub fn parse(text: &str) -> crate::Result<Json> {
        let bytes = text.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            crate::bail!("trailing garbage at byte {} of JSON document", p.pos);
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> crate::Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            crate::bail!("expected '{}' at byte {} of JSON document", c as char, self.pos)
        }
    }

    fn eat_lit(&mut self, lit: &str) -> bool {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            true
        } else {
            false
        }
    }

    fn value(&mut self) -> crate::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') if self.eat_lit("null") => Ok(Json::Null),
            Some(b't') if self.eat_lit("true") => Ok(Json::Bool(true)),
            Some(b'f') if self.eat_lit("false") => Ok(Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => {
                self.pos += 1;
                let mut items = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    items.push(self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Json::Arr(items));
                        }
                        _ => crate::bail!("expected ',' or ']' at byte {}", self.pos),
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                let mut fields = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                loop {
                    self.skip_ws();
                    let key = self.string()?;
                    self.skip_ws();
                    self.expect(b':')?;
                    let val = self.value()?;
                    fields.push((key, val));
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(Json::Obj(fields));
                        }
                        _ => crate::bail!("expected ',' or '}}' at byte {}", self.pos),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => {
                let start = self.pos;
                self.pos += 1;
                while self
                    .peek()
                    .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
                    .unwrap_or(false)
                {
                    self.pos += 1;
                }
                let s = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii");
                s.parse::<f64>()
                    .map(Json::Num)
                    .map_err(|_| crate::util::error::Error::msg(format!("bad number '{s}'")))
            }
            _ => crate::bail!("unexpected character at byte {} of JSON document", self.pos),
        }
    }

    fn string(&mut self) -> crate::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else {
                crate::bail!("unterminated JSON string");
            };
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        crate::bail!("unterminated escape in JSON string");
                    };
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                crate::bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .ok()
                                    .and_then(|h| u32::from_str_radix(h, 16).ok())
                                    .and_then(char::from_u32);
                            self.pos += 4;
                            match hex {
                                Some(ch) => out.push(ch),
                                None => crate::bail!("bad \\u escape"),
                            }
                        }
                        other => crate::bail!("bad escape '\\{}'", other as char),
                    }
                }
                _ => {
                    // Copy the raw UTF-8 byte run starting here.
                    let start = self.pos - 1;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.pos += 1;
                    }
                    match std::str::from_utf8(&self.bytes[start..self.pos]) {
                        Ok(s) => out.push_str(s),
                        Err(_) => crate::bail!("invalid UTF-8 in JSON string"),
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serializes_nested() {
        let mut o = Json::obj();
        o.push("name", Json::str("fig4"));
        o.push("rate", Json::num(2.0));
        o.push("curve", Json::arr_nums(&[1.0, 0.5, 0.25]));
        let mut inner = Json::obj();
        inner.push("ok", Json::Bool(true));
        o.push("meta", inner);
        assert_eq!(
            o.to_string(),
            r#"{"name":"fig4","rate":2,"curve":[1,0.5,0.25],"meta":{"ok":true}}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::str("a\"b\\c\nd");
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn parse_roundtrips_writer_output() {
        let mut o = Json::obj();
        o.push("name", Json::str("base\"line\n"));
        o.push("n", Json::num(-12.5e-3));
        o.push("flag", Json::Bool(false));
        o.push("none", Json::Null);
        o.push("xs", Json::arr_nums(&[1.0, 2.0, 3.5]));
        let mut inner = Json::obj();
        inner.push("k", Json::num(7.0));
        o.push("meta", inner);
        let text = o.to_string();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed, o);
    }

    #[test]
    fn parse_accessors_and_whitespace() {
        let doc = Json::parse(
            "{\n  \"snapshots\": [ {\"label\": \"pre\", \"median\": 0.25} ],\n  \"schema\": 1\n}",
        )
        .unwrap();
        assert_eq!(doc.get("schema").and_then(Json::as_num), Some(1.0));
        let snaps = doc.get("snapshots").and_then(Json::as_arr).unwrap();
        assert_eq!(snaps.len(), 1);
        assert_eq!(snaps[0].get("label").and_then(Json::as_str), Some("pre"));
        assert_eq!(snaps[0].get("median").and_then(Json::as_num), Some(0.25));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1, 2,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("nul").is_err());
    }
}
