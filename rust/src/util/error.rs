//! Minimal dynamic error type (anyhow is not vendorable offline).
//!
//! Mirrors the slice of `anyhow` this codebase actually uses: a cheap
//! string-y error that any `std::error::Error` converts into via `?`, a
//! [`Context`] extension trait for `Result`/`Option`, and the
//! `format_err!` / `bail!` / `ensure!` macros. The crate-wide alias
//! `crate::Result<T>` resolves here.

use std::fmt;

/// Boxed error message with an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

/// Crate-local result alias (re-exported as `crate::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Self { msg: m.to_string(), source: None }
    }

    /// Wrap `self` with an outer message (the `context` chain).
    pub fn wrap(self, outer: impl fmt::Display) -> Self {
        Self { msg: format!("{outer}: {}", self.msg), source: self.source }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut src = self.source.as_deref().map(|s| s as &dyn std::error::Error);
        while let Some(s) = src {
            write!(f, "\n  caused by: {s}")?;
            src = s.source();
        }
        Ok(())
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`, so the
// blanket conversion below cannot collide with `impl From<T> for T` (the
// same trick anyhow uses).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// `anyhow::Context`-style extension for `Result` and `Option`.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! format_err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return an `Err(format_err!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::format_err!($($arg)*).into())
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
        // Debug output includes the io::Error source.
        assert!(format!("{e:?}").contains("caused by"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("outer").unwrap_err();
        assert!(e.to_string().starts_with("outer: "));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            crate::ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                crate::bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(3).unwrap_err().to_string().contains("three"));
        assert!(f(11).unwrap_err().to_string().contains("too big"));
        let e = crate::format_err!("code {}", 42).wrap("outer");
        assert_eq!(e.to_string(), "outer: code 42");
    }
}
