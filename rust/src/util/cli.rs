//! Tiny declarative CLI argument parser (clap is not vendorable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments
//! and subcommands, with typed getters and automatic `--help` text.

use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
}

#[derive(Debug, Default)]
pub struct Cli {
    pub program: String,
    pub about: &'static str,
    specs: Vec<ArgSpec>,
}

#[derive(Debug, Clone)]
pub struct Args {
    values: HashMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Cli {
    pub fn new(program: &str, about: &'static str) -> Self {
        Self { program: program.to_string(), about, specs: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: Some(default.to_string()), is_flag: false });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: None, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for spec in &self.specs {
            let kind = if spec.is_flag { "" } else { " <value>" };
            let def = spec
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_else(|| if spec.is_flag { String::new() } else { " (required)".into() });
            s.push_str(&format!("  --{}{kind}\t{}{def}\n", spec.name, spec.help));
        }
        s
    }

    /// Parse a raw argv (without the program name). Returns Err(usage) on
    /// `--help` or malformed/missing args.
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut values = HashMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    flags.push(key);
                } else {
                    let v = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("option --{key} needs a value"))?
                        }
                    };
                    values.insert(key, v);
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        // fill defaults / check required
        for spec in &self.specs {
            if spec.is_flag {
                continue;
            }
            if !values.contains_key(spec.name) {
                match &spec.default {
                    Some(d) => {
                        values.insert(spec.name.to_string(), d.clone());
                    }
                    None => return Err(format!("missing required --{}\n\n{}", spec.name, self.usage())),
                }
            }
        }
        Ok(Args { values, flags, positional })
    }

    /// Parse `std::env::args()`, exiting with usage on error.
    pub fn parse_env(&self) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&argv) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or_else(|| panic!("no option {name}"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} must be a number"))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("test", "test program")
            .opt("rate", "4", "bits per entry")
            .req("dataset", "dataset name")
            .flag("verbose", "chatty output")
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed_styles() {
        let a = cli().parse(&argv(&["--dataset", "mnist", "--rate=2", "--verbose", "pos1"])).unwrap();
        assert_eq!(a.get("dataset"), "mnist");
        assert_eq!(a.get_usize("rate"), 2);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults_applied() {
        let a = cli().parse(&argv(&["--dataset", "cifar"])).unwrap();
        assert_eq!(a.get_usize("rate"), 4);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(cli().parse(&argv(&["--rate", "2"])).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cli().parse(&argv(&["--dataset", "x", "--nope", "1"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = cli().parse(&argv(&["--help"])).unwrap_err();
        assert!(err.contains("bits per entry"));
    }
}
