//! Minimal TOML-subset config parser for the experiment configs in
//! `configs/*.toml`.
//!
//! Supported: `[section]` / `[section.sub]` headers, `key = value` with
//! string / integer / float / bool / homogeneous array values, `#`
//! comments. That covers every config this framework ships; anything
//! outside the subset is a hard parse error (config typos should never be
//! silently ignored in an experiment framework).

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "\"{s}\""),
            Value::Int(v) => write!(f, "{v}"),
            Value::Float(v) => write!(f, "{v}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Parsed config: keys are `section.key` (dotted paths).
#[derive(Debug, Clone, Default)]
pub struct Config {
    entries: BTreeMap<String, Value>,
}

#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

fn parse_scalar(tok: &str, line: usize) -> Result<Value, ParseError> {
    let t = tok.trim();
    if t.starts_with('"') && t.ends_with('"') && t.len() >= 2 {
        return Ok(Value::Str(t[1..t.len() - 1].to_string()));
    }
    if t == "true" {
        return Ok(Value::Bool(true));
    }
    if t == "false" {
        return Ok(Value::Bool(false));
    }
    if let Ok(v) = t.parse::<i64>() {
        return Ok(Value::Int(v));
    }
    if let Ok(v) = t.parse::<f64>() {
        return Ok(Value::Float(v));
    }
    Err(ParseError { line, msg: format!("cannot parse value '{t}'") })
}

/// Split a top-level array body on commas (no nested arrays needed).
fn parse_array(body: &str, line: usize) -> Result<Value, ParseError> {
    let inner = body.trim();
    if inner.is_empty() {
        return Ok(Value::Array(Vec::new()));
    }
    let mut items = Vec::new();
    for part in inner.split(',') {
        let p = part.trim();
        if p.is_empty() {
            continue;
        }
        items.push(parse_scalar(p, line)?);
    }
    Ok(Value::Array(items))
}

impl Config {
    pub fn parse(text: &str) -> Result<Self, ParseError> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (i, raw) in text.lines().enumerate() {
            let line_no = i + 1;
            // strip comments (naive: '#' outside quotes)
            let mut in_str = false;
            let mut cut = raw.len();
            for (pos, ch) in raw.char_indices() {
                match ch {
                    '"' => in_str = !in_str,
                    '#' if !in_str => {
                        cut = pos;
                        break;
                    }
                    _ => {}
                }
            }
            let line = raw[..cut].trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(ParseError { line: line_no, msg: "unterminated section header".into() });
                }
                section = line[1..line.len() - 1].trim().to_string();
                if section.is_empty() {
                    return Err(ParseError { line: line_no, msg: "empty section name".into() });
                }
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| ParseError { line: line_no, msg: "expected key = value".into() })?;
            let key = key.trim();
            if key.is_empty() {
                return Err(ParseError { line: line_no, msg: "empty key".into() });
            }
            let val = val.trim();
            let value = if val.starts_with('[') {
                if !val.ends_with(']') {
                    return Err(ParseError { line: line_no, msg: "unterminated array".into() });
                }
                parse_array(&val[1..val.len() - 1], line_no)?
            } else {
                parse_scalar(val, line_no)?
            };
            let full_key =
                if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            entries.insert(full_key, value);
        }
        Ok(Self { entries })
    }

    pub fn from_file(path: impl AsRef<Path>) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| crate::format_err!("reading {:?}: {e}", path.as_ref()))?;
        Ok(Self::parse(&text)?)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.i64_or(key, default as i64) as usize
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
name = "fig6"

[fl]
users = 100          # K
local_steps = 1
step_size = 1e-2
heterogeneous = false

[quantizer]
kind = "uveqfed"
rate = 2
lattice = "hex"
zeta_schedule = [2.4, 2.8, 3.2]
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("name", ""), "fig6");
        assert_eq!(c.usize_or("fl.users", 0), 100);
        assert_eq!(c.f64_or("fl.step_size", 0.0), 1e-2);
        assert!(!c.bool_or("fl.heterogeneous", true));
        assert_eq!(c.str_or("quantizer.kind", ""), "uveqfed");
        let arr = c.get("quantizer.zeta_schedule").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_f64(), Some(2.8));
    }

    #[test]
    fn comments_in_strings_preserved() {
        let c = Config::parse("k = \"a # b\"").unwrap();
        assert_eq!(c.str_or("k", ""), "a # b");
    }

    #[test]
    fn errors_on_garbage() {
        assert!(Config::parse("this is not toml").is_err());
        assert!(Config::parse("[unterminated").is_err());
        assert!(Config::parse("k = [1, 2").is_err());
        assert!(Config::parse("k = zzz").is_err());
    }

    #[test]
    fn defaults_for_missing_keys() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.usize_or("nope", 7), 7);
        assert_eq!(c.str_or("nope", "d"), "d");
    }
}
