//! Streaming statistics helpers used by metrics, benches and tests.

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Copy, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample variance (n−1).
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            (self.sample_variance() / self.n as f64).sqrt()
        }
    }
}

/// Percentile of a slice (linear interpolation); `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (p / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Mean squared difference between two equal-length slices, per entry.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// ℓ2 norm of an f32 slice, computed in f64.
pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert_eq!(w.count(), 5);
        assert!((w.mean() - 3.0).abs() < 1e-12);
        assert!((w.variance() - 2.0).abs() < 1e-12);
        assert!((w.sample_variance() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn mse_and_norm() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 4.0]), 2.0);
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }
}
