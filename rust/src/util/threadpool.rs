//! Scoped worker pool for the federated clients (tokio is not vendorable
//! offline; the workload — K independent local-training jobs per round —
//! is CPU-bound fan-out/fan-in, which scoped threads model exactly).
//!
//! Workers also get a typed **thread-local scratch registry**
//! ([`with_scratch`]): per-thread reusable arenas keyed by type, so the
//! per-client encode hot path (UVeQFed's buffers, lattice batch scratch)
//! allocates once per worker thread instead of once per client, and
//! `FleetDriver` rounds scale with cores instead of with the allocator.

use std::any::{Any, TypeId};
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

thread_local! {
    /// Per-thread arena registry: one instance of each scratch type per
    /// thread, created on first use and reused for the thread's lifetime.
    static SCRATCH: RefCell<HashMap<TypeId, Box<dyn Any>>> = RefCell::new(HashMap::new());
}

/// Borrow this thread's reusable instance of scratch type `T` (created via
/// `Default` on first use). The instance is *removed* from the registry for
/// the duration of `f`, so nested `with_scratch::<T>` calls see a fresh
/// value instead of aliasing — reuse simply doesn't compound across
/// recursion, which the hot paths never do.
pub fn with_scratch<T: Default + 'static, R>(f: impl FnOnce(&mut T) -> R) -> R {
    let mut boxed: Box<dyn Any> = SCRATCH
        .with(|c| c.borrow_mut().remove(&TypeId::of::<T>()))
        .unwrap_or_else(|| Box::<T>::default());
    let r = f(boxed.downcast_mut::<T>().expect("scratch registry type confusion"));
    SCRATCH.with(|c| c.borrow_mut().insert(TypeId::of::<T>(), boxed));
    r
}

/// Run `f(i)` for `i in 0..n` on up to `workers` OS threads, collecting
/// results in index order. Panics in jobs propagate.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(workers >= 1);
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.min(n);
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<T>>> =
        Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i);
                results.lock().unwrap()[i] = Some(out);
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("worker dropped a result"))
        .collect()
}

/// Run `f(i)` for `i in 0..n` on up to `workers` threads, folding each
/// result into `fold` on the caller's thread **in completion order** (not
/// index order). The channel is bounded at `2·workers`, so at most a
/// handful of results are ever in flight — the caller never buffers all
/// `n` outputs. This is the streaming fan-in under `fleet::`'s O(m)
/// aggregation: combined with an order-independent fold (fixed-point
/// accumulation) it is deterministic for any worker count.
pub fn parallel_map_fold<T, F, G>(n: usize, workers: usize, f: F, mut fold: G)
where
    T: Send,
    F: Fn(usize) -> T + Sync,
    G: FnMut(usize, T),
{
    assert!(workers >= 1);
    if n == 0 {
        return;
    }
    let workers = workers.min(n);
    if workers == 1 {
        for i in 0..n {
            let v = f(i);
            fold(i, v);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let (tx, rx) = std::sync::mpsc::sync_channel::<(usize, T)>(workers * 2);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                // The receiver only disappears on a fold panic; stop
                // quietly and let scope exit propagate that panic.
                if tx.send((i, v)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        for (i, v) in rx {
            fold(i, v);
        }
    });
}

/// Default worker count: physical-ish parallelism, capped.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scratch_is_reused_and_nesting_is_safe() {
        let cap = with_scratch::<Vec<u8>, _>(|v| {
            v.clear();
            v.reserve(128);
            v.capacity()
        });
        assert!(cap >= 128);
        let cap2 = with_scratch::<Vec<u8>, _>(|v| v.capacity());
        assert!(cap2 >= 128, "second borrow must see the reused buffer");
        // Nested borrow of the same type must get a fresh value, not alias.
        with_scratch::<Vec<u8>, _>(|outer| {
            outer.clear();
            outer.push(1);
            with_scratch::<Vec<u8>, _>(|inner| {
                assert!(inner.is_empty(), "nested scratch must not alias");
            });
            assert_eq!(outer.len(), 1);
        });
    }

    #[test]
    fn results_in_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_sequential() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_jobs() {
        let out = parallel_map(3, 16, |i| i);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn map_fold_sees_every_result_exactly_once() {
        for workers in [1, 2, 8] {
            let mut seen = vec![0u32; 100];
            let mut sum = 0usize;
            parallel_map_fold(100, workers, |i| i * 3, |i, v| {
                seen[i] += 1;
                sum += v;
            });
            assert!(seen.iter().all(|&c| c == 1), "workers={workers}");
            assert_eq!(sum, (0..100).map(|i| i * 3).sum::<usize>());
        }
    }

    #[test]
    fn map_fold_empty_and_oversubscribed() {
        let mut calls = 0;
        parallel_map_fold(0, 4, |i| i, |_, _| calls += 1);
        assert_eq!(calls, 0);
        parallel_map_fold(3, 64, |i| i, |_, _| calls += 1);
        assert_eq!(calls, 3);
    }

    #[test]
    #[should_panic]
    fn map_fold_worker_panic_propagates() {
        parallel_map_fold(
            8,
            2,
            |i| {
                if i == 5 {
                    panic!("boom");
                }
                i
            },
            |_, _| {},
        );
    }

    #[test]
    #[should_panic]
    fn job_panic_propagates() {
        let _ = parallel_map(4, 2, |i| {
            if i == 2 {
                panic!("boom");
            }
            i
        });
    }
}
