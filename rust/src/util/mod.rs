//! Framework utilities built from scratch (the offline image vendors only
//! the `xla` crate closure, so CLI parsing, config files, JSON output,
//! thread pools and property testing are all implemented here).

pub mod cli;
pub mod config;
pub mod error;
pub mod json;
pub mod prop;
pub mod stats;
pub mod threadpool;
