//! Artifact manifest: `artifacts/manifest.txt`, one line per compiled
//! graph, written by `python/compile/aot.py`:
//!
//! ```text
//! mnist_step_b500 kind=step model=mnist batch=500 features=784 classes=10 params=39760 file=mnist_step_b500.hlo.txt
//! ```

use crate::util::error::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub name: String,
    pub fields: BTreeMap<String, String>,
}

impl ManifestEntry {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.fields.get(key).map(|s| s.as_str())
    }

    pub fn usize_field(&self, key: &str) -> Result<usize> {
        self.get(key)
            .with_context(|| format!("manifest entry {} missing field {key}", self.name))?
            .parse()
            .with_context(|| format!("manifest {}: field {key} not an integer", self.name))
    }

    pub fn file(&self) -> Result<&str> {
        self.get("file").with_context(|| format!("manifest entry {} missing file", self.name))
    }
}

#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn parse(text: &str) -> Manifest {
        let mut entries = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let name = match parts.next() {
                Some(n) => n.to_string(),
                None => continue,
            };
            let mut fields = BTreeMap::new();
            for p in parts {
                if let Some((k, v)) = p.split_once('=') {
                    fields.insert(k.to_string(), v.to_string());
                }
            }
            entries.push(ManifestEntry { name, fields });
        }
        Manifest { entries }
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {path:?} (run `make artifacts`)"))?;
        Ok(Self::parse(&text))
    }

    pub fn find(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Find a step graph for (model, batch).
    pub fn find_step(&self, model: &str, batch: usize) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| {
            e.get("kind") == Some("step")
                && e.get("model") == Some(model)
                && e.get("batch").and_then(|b| b.parse::<usize>().ok()) == Some(batch)
        })
    }

    pub fn find_eval(&self, model: &str) -> Option<&ManifestEntry> {
        self.entries
            .iter()
            .find(|e| e.get("kind") == Some("eval") && e.get("model") == Some(model))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# artifacts
mnist_step_b500 kind=step model=mnist batch=500 features=784 classes=10 params=39760 file=mnist_step_b500.hlo.txt
mnist_eval kind=eval model=mnist batch=256 features=784 classes=10 params=39760 file=mnist_eval.hlo.txt
quantize_hex kind=kernel model=quantize file=quantize_hex.hlo.txt
";

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(SAMPLE);
        assert_eq!(m.entries.len(), 3);
        let e = m.find("mnist_step_b500").unwrap();
        assert_eq!(e.usize_field("batch").unwrap(), 500);
        assert_eq!(e.file().unwrap(), "mnist_step_b500.hlo.txt");
    }

    #[test]
    fn lookup_by_kind() {
        let m = Manifest::parse(SAMPLE);
        assert!(m.find_step("mnist", 500).is_some());
        assert!(m.find_step("mnist", 123).is_none());
        assert!(m.find_eval("mnist").is_some());
    }

    #[test]
    fn missing_fields_error() {
        let m = Manifest::parse("x file=y.hlo.txt");
        let e = m.find("x").unwrap();
        assert!(e.usize_field("batch").is_err());
    }
}
