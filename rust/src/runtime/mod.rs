//! PJRT runtime — loads the AOT-compiled JAX/Pallas graphs from
//! `artifacts/*.hlo.txt` and executes them on the L3 hot path.
//!
//! Interchange is **HLO text** (not serialized `HloModuleProto`): jax ≥0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids and round-trips cleanly (see
//! DESIGN.md and /opt/xla-example/README.md).
//!
//! Python runs only at `make artifacts` time; after that the Rust binary
//! is self-contained.

// The PJRT path needs the vendored `xla` crate, which only exists in the
// full offline image. Build with `RUSTFLAGS='--cfg uveqfed_xla'` (and the
// `xla` dependency added to Cargo.toml) to enable it; otherwise
// `HloTrainer` is a stub whose `load` returns a descriptive error, and the
// `model.backend = "hlo"` config path fails fast at startup.
#[cfg(uveqfed_xla)]
pub mod engine;
#[cfg(uveqfed_xla)]
mod hlo_trainer;
mod manifest;
#[cfg(not(uveqfed_xla))]
mod stub;

#[cfg(uveqfed_xla)]
pub use engine::{Engine, Graph};
#[cfg(uveqfed_xla)]
pub use hlo_trainer::HloTrainer;
#[cfg(not(uveqfed_xla))]
pub use stub::HloTrainer;
pub use manifest::{Manifest, ManifestEntry};

use std::path::{Path, PathBuf};

/// Default artifacts directory (override with `UVEQFED_ARTIFACTS`).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("UVEQFED_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// True when the AOT artifacts have been built (`make artifacts`).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.txt").exists()
}

/// Resolve an artifact path by file name.
pub fn artifact_path(file: &str) -> PathBuf {
    artifacts_dir().join(file)
}

/// Helper used by tests/examples to skip gracefully when artifacts are
/// missing (e.g. `cargo test` before `make artifacts`).
pub fn require_artifacts(what: &str) -> Option<PathBuf> {
    let dir = artifacts_dir();
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!(
            "[skip] {what}: artifacts not built (run `make artifacts`); looked in {:?}",
            dir
        );
        None
    }
}

/// Quick existence check for a specific artifact file.
pub fn artifact_exists(file: &str) -> bool {
    Path::new(&artifact_path(file)).exists()
}
