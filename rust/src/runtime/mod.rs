//! PJRT runtime — loads the AOT-compiled JAX/Pallas graphs from
//! `artifacts/*.hlo.txt` and executes them on the L3 hot path.
//!
//! Interchange is **HLO text** (not serialized `HloModuleProto`): jax ≥0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids and round-trips cleanly (see
//! DESIGN.md and /opt/xla-example/README.md).
//!
//! Python runs only at `make artifacts` time; after that the Rust binary
//! is self-contained.

pub mod engine;
mod hlo_trainer;
mod manifest;

pub use engine::{Engine, Graph};
pub use hlo_trainer::HloTrainer;
pub use manifest::{Manifest, ManifestEntry};

use std::path::{Path, PathBuf};

/// Default artifacts directory (override with `UVEQFED_ARTIFACTS`).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("UVEQFED_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// True when the AOT artifacts have been built (`make artifacts`).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.txt").exists()
}

/// Resolve an artifact path by file name.
pub fn artifact_path(file: &str) -> PathBuf {
    artifacts_dir().join(file)
}

/// Helper used by tests/examples to skip gracefully when artifacts are
/// missing (e.g. `cargo test` before `make artifacts`).
pub fn require_artifacts(what: &str) -> Option<PathBuf> {
    let dir = artifacts_dir();
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!(
            "[skip] {what}: artifacts not built (run `make artifacts`); looked in {:?}",
            dir
        );
        None
    }
}

/// Quick existence check for a specific artifact file.
pub fn artifact_exists(file: &str) -> bool {
    Path::new(&artifact_path(file)).exists()
}
