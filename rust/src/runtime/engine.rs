//! Thin typed wrapper over the `xla` crate's PJRT CPU client.

use crate::util::error::{Context, Result};

/// A PJRT client plus compile cache.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// Create a CPU PJRT engine.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| crate::format_err!("PJRT cpu: {e:?}"))?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO **text** artifact and compile it.
    pub fn load_hlo_text(&self, path: &std::path::Path) -> Result<Graph> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| crate::format_err!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe =
            self.client.compile(&comp).map_err(|e| crate::format_err!("compile {path:?}: {e:?}"))?;
        Ok(Graph { exe, name: path.display().to_string() })
    }
}

/// A compiled executable with convenience I/O.
pub struct Graph {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Graph {
    /// Execute with literal inputs; returns the flattened tuple outputs.
    /// (aot.py lowers everything with `return_tuple=True`.)
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let mut outs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| crate::format_err!("execute {}: {e:?}", self.name))?;
        let first = outs
            .pop()
            .and_then(|mut replicas| if replicas.is_empty() { None } else { Some(replicas.remove(0)) })
            .ok_or_else(|| crate::format_err!("no output buffers from {}", self.name))?;
        let mut lit = first
            .to_literal_sync()
            .map_err(|e| crate::format_err!("to_literal {}: {e:?}", self.name))?;
        let parts = lit
            .decompose_tuple()
            .map_err(|e| crate::format_err!("decompose {}: {e:?}", self.name))?;
        if parts.is_empty() {
            Ok(vec![lit])
        } else {
            Ok(parts)
        }
    }
}

/// Build an f32 literal of the given shape from a slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let numel: i64 = dims.iter().product();
    crate::ensure!(numel as usize == data.len(), "shape/data mismatch");
    let lit = xla::Literal::vec1(data);
    lit.reshape(dims).map_err(|e| crate::format_err!("reshape: {e:?}"))
}

/// Extract an f32 vector from a literal.
pub fn f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| crate::format_err!("to_vec: {e:?}"))
}

// The xla wrapper types hold raw pointers and are !Send/!Sync by default.
// The PJRT CPU client is internally synchronized for compilation and
// execution; we still serialize all calls through `HloTrainer`'s Mutex and
// cap `Trainer::max_workers` at 1, so cross-thread access never actually
// races. The impls below only allow moving the engine into the coordinator
// worker structure.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}
unsafe impl Send for Graph {}
unsafe impl Sync for Graph {}
