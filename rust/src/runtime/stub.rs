//! Stand-in `HloTrainer` for builds without the PJRT runtime.
//!
//! The vendored `xla` crate exists only in the full offline image; the
//! default build carries zero external dependencies. This stub keeps the
//! `runtime::HloTrainer` API (and everything that links against it)
//! compiling, while `load` fails with an actionable message instead of a
//! missing-symbol error at link time.

use crate::data::Dataset;
use crate::fl::Trainer;
use crate::models::EvalReport;
use crate::Result;

/// Unconstructible stand-in: `load` always errors, so no instance of this
/// type ever exists and the `Trainer` methods are unreachable.
#[derive(Debug)]
pub struct HloTrainer {
    _unconstructible: std::convert::Infallible,
}

impl HloTrainer {
    /// Always fails: the PJRT runtime is not compiled into this binary.
    pub fn load(model: &str, batch: usize) -> Result<Self> {
        Err(crate::format_err!(
            "HloTrainer::load({model:?}, batch={batch}): this binary was built without the \
             PJRT runtime. Rebuild with RUSTFLAGS='--cfg uveqfed_xla' and the vendored `xla` \
             crate (see DESIGN.md), or use model.backend = \"native\"."
        ))
    }

    pub fn platform(&self) -> String {
        match self._unconstructible {}
    }
}

impl Trainer for HloTrainer {
    fn num_params(&self) -> usize {
        match self._unconstructible {}
    }

    fn init_params(&self, _seed: u64) -> Vec<f32> {
        match self._unconstructible {}
    }

    fn local_update(
        &self,
        _w0: &[f32],
        _shard: &Dataset,
        _tau: usize,
        _lr: f32,
        _batch_size: usize,
        _seed: u64,
    ) -> Vec<f32> {
        match self._unconstructible {}
    }

    fn evaluate(&self, _w: &[f32], _ds: &Dataset) -> EvalReport {
        match self._unconstructible {}
    }

    fn max_workers(&self) -> usize {
        match self._unconstructible {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_runtime() {
        let e = HloTrainer::load("mnist", 500).unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("uveqfed_xla"), "unhelpful stub error: {msg}");
    }
}
