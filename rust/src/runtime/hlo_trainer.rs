//! `Trainer` implementation backed by the AOT-compiled JAX graphs — the
//! production L2/L1 path.
//!
//! Two graphs per model (see `python/compile/aot.py`):
//! * `kind=step`  — `(params[m], x[B,d], y_onehot[B,c], lr) → params'[m]`
//!   (one SGD step; τ local steps = τ calls);
//! * `kind=eval`  — `(params[m], x[B,d]) → logits[B,c]`.
//!
//! Batch shapes are baked in at AOT time (one executable per variant); the
//! trainer samples mini-batches of exactly the compiled size. All PJRT
//! calls serialize through a mutex (see `engine.rs` safety note) and
//! `max_workers() == 1` keeps the coordinator from fanning out.

use super::engine::{f32_vec, literal_f32, Engine, Graph};
use super::manifest::Manifest;
use crate::data::Dataset;
use crate::fl::Trainer;
use crate::models::EvalReport;
use crate::prng::{Rng, SplitMix64, Xoshiro256pp};
use crate::util::error::{Context, Result};
use std::sync::Mutex;

pub struct HloTrainer {
    engine: Engine,
    step: Mutex<Graph>,
    eval: Mutex<Graph>,
    pub model: String,
    pub params: usize,
    pub features: usize,
    pub classes: usize,
    /// Per-sample input dims (excluding batch), e.g. `[784]` or
    /// `[3, 32, 32]` — from the manifest `xdims` field.
    pub xdims: Vec<i64>,
    /// Batch size compiled into the step graph.
    pub step_batch: usize,
    /// Batch size compiled into the eval graph.
    pub eval_batch: usize,
    /// Initial parameters exported by aot.py (`<model>_init.f32` raw
    /// little-endian), so rust and python agree bit-exactly on w₀.
    init: Vec<f32>,
}

impl HloTrainer {
    /// Load a trainer for `model` with a `batch`-sized step graph from the
    /// artifacts directory.
    pub fn load(model: &str, batch: usize) -> Result<Self> {
        let dir = super::artifacts_dir();
        let manifest = Manifest::load(&dir)?;
        let step_e = manifest
            .find_step(model, batch)
            .with_context(|| format!("no step artifact for {model} batch={batch}"))?;
        let eval_e =
            manifest.find_eval(model).with_context(|| format!("no eval artifact for {model}"))?;
        let engine = Engine::cpu()?;
        let step = engine.load_hlo_text(&dir.join(step_e.file()?))?;
        let eval = engine.load_hlo_text(&dir.join(eval_e.file()?))?;
        let params = step_e.usize_field("params")?;
        let features = step_e.usize_field("features")?;
        let classes = step_e.usize_field("classes")?;
        let eval_batch = eval_e.usize_field("batch")?;
        let xdims: Vec<i64> = match step_e.get("xdims") {
            Some(s) => s
                .split(',')
                .map(|p| p.parse::<i64>().context("bad xdims"))
                .collect::<Result<_>>()?,
            None => vec![features as i64],
        };
        crate::ensure!(
            xdims.iter().product::<i64>() as usize == features,
            "xdims/features mismatch"
        );
        // init params blob
        let init_file = dir.join(format!("{model}_init.f32"));
        let raw = std::fs::read(&init_file)
            .with_context(|| format!("missing init blob {init_file:?}"))?;
        crate::ensure!(raw.len() == params * 4, "init blob size mismatch");
        let init: Vec<f32> = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        Ok(Self {
            engine,
            step: Mutex::new(step),
            eval: Mutex::new(eval),
            model: model.to_string(),
            params,
            features,
            classes,
            xdims,
            step_batch: batch,
            eval_batch,
            init,
        })
    }

    pub fn platform(&self) -> String {
        self.engine.platform()
    }

    fn batch_literals(
        &self,
        ds: &Dataset,
        idx: &[usize],
    ) -> Result<(xla::Literal, xla::Literal)> {
        let b = idx.len();
        let mut x = Vec::with_capacity(b * self.features);
        let mut y = vec![0.0f32; b * self.classes];
        for (r, &i) in idx.iter().enumerate() {
            let (xi, yi) = ds.sample(i);
            x.extend_from_slice(xi);
            y[r * self.classes + yi as usize] = 1.0;
        }
        let mut dims = vec![b as i64];
        dims.extend_from_slice(&self.xdims);
        Ok((
            literal_f32(&x, &dims)?,
            literal_f32(&y, &[b as i64, self.classes as i64])?,
        ))
    }
}

impl Trainer for HloTrainer {
    fn num_params(&self) -> usize {
        self.params
    }

    fn init_params(&self, _seed: u64) -> Vec<f32> {
        // The artifact's init blob is authoritative — the HLO graph and the
        // blob were produced by the same python invocation.
        self.init.clone()
    }

    fn local_update(
        &self,
        w0: &[f32],
        shard: &Dataset,
        tau: usize,
        lr: f32,
        batch_size: usize,
        seed: u64,
    ) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(SplitMix64::new(seed).next());
        let mut w = w0.to_vec();
        let b = self.step_batch;
        let _ = batch_size; // the compiled batch size governs
        for _ in 0..tau {
            let idx: Vec<usize> = if shard.len() == b {
                (0..b).collect()
            } else {
                (0..b).map(|_| rng.gen_index(shard.len())).collect()
            };
            let (x, y) = self.batch_literals(shard, &idx).expect("literal build");
            let wlit = literal_f32(&w, &[self.params as i64]).expect("params literal");
            let lr_lit = xla::Literal::scalar(lr);
            let outs = self
                .step
                .lock()
                .unwrap()
                .run(&[wlit, x, y, lr_lit])
                .expect("step graph execution");
            w = f32_vec(&outs[0]).expect("params output");
        }
        w
    }

    fn evaluate(&self, w: &[f32], ds: &Dataset) -> EvalReport {
        let b = self.eval_batch;
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        let n = ds.len();
        let mut i0 = 0;
        while i0 < n {
            let valid = (n - i0).min(b);
            // pad by repeating the first sample; padded rows are ignored.
            let idx: Vec<usize> =
                (0..b).map(|r| if r < valid { i0 + r } else { i0 }).collect();
            let (x, _) = self.batch_literals(ds, &idx).expect("literal build");
            let wlit = literal_f32(w, &[self.params as i64]).expect("params literal");
            let outs = self.eval.lock().unwrap().run(&[wlit, x]).expect("eval graph");
            let logits = f32_vec(&outs[0]).expect("logits output");
            for r in 0..valid {
                let row = &logits[r * self.classes..(r + 1) * self.classes];
                let yi = ds.y[i0 + r] as usize;
                let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let lse: f32 =
                    row.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
                loss += (lse - row[yi]) as f64;
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred == yi {
                    correct += 1;
                }
            }
            i0 += valid;
        }
        EvalReport { loss: loss / n as f64, accuracy: correct as f64 / n as f64 }
    }

    fn max_workers(&self) -> usize {
        1
    }
}
