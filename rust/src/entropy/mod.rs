//! Lossless entropy-coding substrate (UVeQFed steps **E4/D1**).
//!
//! UVeQFed compresses the discrete lattice indices with a lossless code;
//! QSGD uses Elias integer codes. This module provides, from scratch:
//!
//! * [`bitio`] — MSB-first bit-level writer/reader over byte buffers;
//! * [`elias`] — Elias γ/δ/ω universal integer codes + zig-zag mapping for
//!   signed integers;
//! * [`range`] — an adaptive binary range coder (arithmetic coding) with a
//!   simple order-0 context model, used as the default coder for lattice
//!   indices (adapts to the non-uniform index distribution the paper
//!   exploits);
//! * [`huffman`] — canonical Huffman for two-pass coding when the encoder
//!   may scan the data twice (used by the rate-targeting search, where the
//!   codebook cost must be accounted for exactly).
//!
//! All coders are exact-round-trip by construction and property-tested.

pub mod bitio;
pub mod elias;
pub mod huffman;
pub mod range;

pub use bitio::{BitReader, BitWriter};

/// Typed decode failure for the lossless coders. A corrupt or truncated
/// stream surfaces as `Err` — never a panic — so transport layers can
/// quarantine the payload and keep the round alive. Every variant is
/// `Copy` so errors can ride on zero-alloc telemetry spans.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CodeError {
    /// A length/magnitude prefix exceeds the 64-bit value range — the
    /// signature of a corrupt unary/recursive length code.
    IntOverflow { coder: &'static str },
    /// A code length outside the canonical table's admissible range.
    BadCodeLength { len: usize, max: usize },
    /// A declared count exceeds what the remaining stream can hold.
    BadCount { declared: usize, capacity: usize },
}

impl std::fmt::Display for CodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            CodeError::IntOverflow { coder } => {
                write!(f, "corrupt {coder} stream: length prefix exceeds 64 bits")
            }
            CodeError::BadCodeLength { len, max } => {
                write!(f, "corrupt code length {len} (admissible 1..={max})")
            }
            CodeError::BadCount { declared, capacity } => {
                write!(f, "declared count {declared} exceeds stream capacity {capacity}")
            }
        }
    }
}

impl std::error::Error for CodeError {}

/// Uniform interface so quantizer codecs can swap integer coders.
pub trait IntCoder {
    /// Append the encoding of `xs` (signed integers) to `w`.
    fn encode(&self, xs: &[i64], w: &mut BitWriter);
    /// Decode exactly `n` integers from `r`. Corrupt streams return a
    /// typed [`CodeError`]; they never panic.
    fn decode(&self, n: usize, r: &mut BitReader) -> Result<Vec<i64>, CodeError>;
    /// Human-readable name for logs/benches.
    fn name(&self) -> &'static str;
}

/// Map a signed integer to an unsigned one (zig-zag), preserving small
/// magnitudes — lattice coordinates concentrate near zero.
#[inline]
pub fn zigzag(x: i64) -> u64 {
    ((x.wrapping_shl(1)) ^ (x >> 63)) as u64
}

/// Inverse of [`zigzag`].
#[inline]
pub fn unzigzag(u: u64) -> i64 {
    ((u >> 1) as i64) ^ -((u & 1) as i64)
}

/// Empirical entropy (bits/symbol) of a symbol stream — used by the rate
/// controller to pick the lattice scale before actually encoding.
pub fn empirical_entropy(symbols: &[i64]) -> f64 {
    if symbols.is_empty() {
        return 0.0;
    }
    let mut counts = std::collections::HashMap::new();
    for &s in symbols {
        *counts.entry(s).or_insert(0usize) += 1;
    }
    let n = symbols.len() as f64;
    counts
        .values()
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.log2()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zigzag_roundtrip() {
        for x in [-1_000_000, -3, -1, 0, 1, 2, 5, 123456789, i64::MIN / 2] {
            assert_eq!(unzigzag(zigzag(x)), x);
        }
    }

    #[test]
    fn zigzag_orders_by_magnitude() {
        assert_eq!(zigzag(0), 0);
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
        assert_eq!(zigzag(-2), 3);
        assert_eq!(zigzag(2), 4);
    }

    #[test]
    fn entropy_uniform_and_degenerate() {
        let xs: Vec<i64> = (0..256).collect();
        let h = empirical_entropy(&xs);
        assert!((h - 8.0).abs() < 1e-9);
        let same = vec![7i64; 100];
        assert_eq!(empirical_entropy(&same), 0.0);
    }
}
