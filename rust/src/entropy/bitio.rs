//! MSB-first bit-level I/O over `Vec<u8>` buffers.
//!
//! This is the wire substrate for every codec in the repo: entropy coders,
//! codec headers, and the uplink bit accounting all measure through the
//! exact number of bits pushed here.

/// Bit-level writer; bits are packed MSB-first within each byte.
#[derive(Debug, Default, Clone)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Number of valid bits in the final partial byte (0..8); 0 means the
    /// buffer is byte-aligned.
    partial: u8,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bytes: usize) -> Self {
        Self { buf: Vec::with_capacity(bytes), partial: 0 }
    }

    /// Total number of bits written so far.
    pub fn bit_len(&self) -> usize {
        if self.partial == 0 {
            self.buf.len() * 8
        } else {
            (self.buf.len() - 1) * 8 + self.partial as usize
        }
    }

    /// Push a single bit.
    #[inline]
    pub fn push_bit(&mut self, bit: bool) {
        if self.partial == 0 {
            self.buf.push(0);
        }
        if bit {
            let last = self.buf.last_mut().unwrap();
            *last |= 1 << (7 - self.partial);
        }
        self.partial = (self.partial + 1) % 8;
    }

    /// Push the low `n` bits of `v`, MSB first. `n <= 64`.
    pub fn push_bits(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 64);
        for i in (0..n).rev() {
            self.push_bit((v >> i) & 1 == 1);
        }
    }

    /// Push a whole byte (fast path when aligned).
    pub fn push_byte(&mut self, b: u8) {
        if self.partial == 0 {
            self.buf.push(b);
        } else {
            self.push_bits(b as u64, 8);
        }
    }

    /// Push a little-endian u32 (headers).
    pub fn push_u32(&mut self, v: u32) {
        for b in v.to_le_bytes() {
            self.push_byte(b);
        }
    }

    /// Push a little-endian u64.
    pub fn push_u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.push_byte(b);
        }
    }

    /// Push an f32 bit pattern.
    pub fn push_f32(&mut self, v: f32) {
        self.push_u32(v.to_bits());
    }

    /// Append every bit of `other` (its exact `bit_len`, not its padded
    /// byte count) — used by streaming encode sinks that accumulate a
    /// side-buffer (e.g. sign bits) before the header is known.
    pub fn append(&mut self, other: &BitWriter) {
        let bits = other.bit_len();
        let full = bits / 8;
        for &b in &other.buf[..full] {
            self.push_byte(b);
        }
        let rem = (bits % 8) as u32;
        if rem > 0 {
            self.push_bits((other.buf[full] >> (8 - rem)) as u64, rem);
        }
    }

    /// Zero-pad to a byte boundary and return the buffer.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Borrow the current bytes (final byte may be partial, zero-padded).
    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }
}

/// Bit-level reader matching [`BitWriter`]'s layout.
#[derive(Debug, Clone)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// Global bit cursor.
    pos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bits consumed so far.
    pub fn bit_pos(&self) -> usize {
        self.pos
    }

    pub fn remaining_bits(&self) -> usize {
        self.buf.len() * 8 - self.pos
    }

    /// Read one bit; reading past the end yields `false` (zero padding),
    /// which matches the writer's implicit zero-fill and lets terminal
    /// range-coder flushes read cleanly.
    #[inline]
    pub fn read_bit(&mut self) -> bool {
        let byte = self.pos / 8;
        let bit = self.pos % 8;
        self.pos += 1;
        if byte >= self.buf.len() {
            return false;
        }
        (self.buf[byte] >> (7 - bit)) & 1 == 1
    }

    /// Read `n` bits MSB-first into the low bits of a u64.
    pub fn read_bits(&mut self, n: u32) -> u64 {
        debug_assert!(n <= 64);
        let mut v = 0u64;
        for _ in 0..n {
            v = (v << 1) | self.read_bit() as u64;
        }
        v
    }

    pub fn read_byte(&mut self) -> u8 {
        self.read_bits(8) as u8
    }

    pub fn read_u32(&mut self) -> u32 {
        let mut b = [0u8; 4];
        for x in &mut b {
            *x = self.read_byte();
        }
        u32::from_le_bytes(b)
    }

    pub fn read_u64(&mut self) -> u64 {
        let mut b = [0u8; 8];
        for x in &mut b {
            *x = self.read_byte();
        }
        u64::from_le_bytes(b)
    }

    pub fn read_f32(&mut self) -> f32 {
        f32::from_bits(self.read_u32())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_roundtrip() {
        let mut w = BitWriter::new();
        let pattern = [true, false, true, true, false, false, true, false, true];
        for &b in &pattern {
            w.push_bit(b);
        }
        assert_eq!(w.bit_len(), 9);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &b in &pattern {
            assert_eq!(r.read_bit(), b);
        }
    }

    #[test]
    fn bits_roundtrip_various_widths() {
        let mut w = BitWriter::new();
        let vals = [(0b1011u64, 4u32), (0xFFFF, 16), (0, 1), (1, 1), (0x1234_5678_9ABC, 48)];
        for &(v, n) in &vals {
            w.push_bits(v, n);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &(v, n) in &vals {
            assert_eq!(r.read_bits(n), v);
        }
    }

    #[test]
    fn numeric_helpers_roundtrip() {
        let mut w = BitWriter::new();
        w.push_bit(true); // force misalignment
        w.push_u32(0xDEADBEEF);
        w.push_u64(0x0123_4567_89AB_CDEF);
        w.push_f32(-1.5e-3);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert!(r.read_bit());
        assert_eq!(r.read_u32(), 0xDEADBEEF);
        assert_eq!(r.read_u64(), 0x0123_4567_89AB_CDEF);
        assert_eq!(r.read_f32(), -1.5e-3);
    }

    #[test]
    fn read_past_end_zero_fills() {
        let bytes = [0b1000_0000u8];
        let mut r = BitReader::new(&bytes);
        assert!(r.read_bit());
        for _ in 0..16 {
            assert!(!r.read_bit());
        }
    }

    #[test]
    fn append_copies_exact_bits() {
        // Misaligned destination, misaligned source: every bit must land.
        let mut side = BitWriter::new();
        let pattern = [true, true, false, true, false, false, true, false, true, true, false];
        for &b in &pattern {
            side.push_bit(b);
        }
        let mut w = BitWriter::new();
        w.push_f32(1.5);
        w.append(&side);
        assert_eq!(w.bit_len(), 32 + pattern.len());
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_f32(), 1.5);
        for &b in &pattern {
            assert_eq!(r.read_bit(), b);
        }

        // Aligned source (multiple of 8 bits) takes the byte fast path.
        let mut side8 = BitWriter::new();
        side8.push_byte(0xA5);
        side8.push_byte(0x3C);
        let mut w2 = BitWriter::new();
        w2.push_bit(true);
        w2.append(&side8);
        assert_eq!(w2.bit_len(), 17);
        let bytes = w2.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert!(r.read_bit());
        assert_eq!(r.read_bits(8), 0xA5);
        assert_eq!(r.read_bits(8), 0x3C);
    }

    #[test]
    fn append_empty_is_noop() {
        let mut w = BitWriter::new();
        w.push_bit(true);
        w.append(&BitWriter::new());
        assert_eq!(w.bit_len(), 1);
    }

    #[test]
    fn bit_len_counts_exactly() {
        let mut w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        w.push_bits(0, 13);
        assert_eq!(w.bit_len(), 13);
        w.push_byte(0xAB);
        assert_eq!(w.bit_len(), 21);
    }
}
