//! Canonical Huffman coding with an explicit transmitted codebook.
//!
//! Used by the two-pass path of UVeQFed's rate controller: when the encoder
//! may scan the index stream twice, a Huffman code gets within one bit per
//! symbol of entropy and the *exact* encoded size (codebook included) is
//! known before commit — which is what the "scale G such that the codeword
//! uses less than R·m bits" procedure in §V-A needs.
//!
//! The codebook is serialized as (symbol, code-length) pairs; canonical
//! code assignment means lengths alone reconstruct the code.

use super::{unzigzag, zigzag, BitReader, BitWriter, CodeError, IntCoder};
use std::collections::HashMap;

/// Maximum admissible code length; streams here have ≤ a few thousand
/// distinct symbols so 32 is far beyond the Kraft bound requirement.
const MAX_LEN: usize = 32;

/// Build Huffman code lengths from symbol counts (package-free heap
/// construction; ties broken deterministically by symbol for reproducible
/// artifacts).
fn code_lengths(counts: &[(i64, usize)]) -> Vec<(i64, u8)> {
    assert!(!counts.is_empty());
    if counts.len() == 1 {
        return vec![(counts[0].0, 1)];
    }
    // Node arena: (weight, tiebreak, children)
    #[derive(Clone)]
    struct Node {
        w: u64,
        tie: i64,
        kids: Option<(usize, usize)>,
        sym: Option<i64>,
    }
    let mut arena: Vec<Node> = counts
        .iter()
        .map(|&(s, c)| Node { w: c as u64, tie: s, kids: None, sym: Some(s) })
        .collect();
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let mut heap: BinaryHeap<Reverse<(u64, i64, usize)>> =
        arena.iter().enumerate().map(|(i, n)| Reverse((n.w, n.tie, i))).collect();
    while heap.len() > 1 {
        let Reverse((w1, _, i1)) = heap.pop().unwrap();
        let Reverse((w2, _, i2)) = heap.pop().unwrap();
        let tie = arena[i1].tie.min(arena[i2].tie);
        arena.push(Node { w: w1 + w2, tie, kids: Some((i1, i2)), sym: None });
        let id = arena.len() - 1;
        heap.push(Reverse((w1 + w2, tie, id)));
    }
    let root = heap.pop().unwrap().0 .2;
    // DFS to assign depths.
    let mut out = Vec::with_capacity(counts.len());
    let mut stack = vec![(root, 0u8)];
    while let Some((i, d)) = stack.pop() {
        if let Some((a, b)) = arena[i].kids {
            stack.push((a, d + 1));
            stack.push((b, d + 1));
        } else {
            out.push((arena[i].sym.unwrap(), d.max(1)));
        }
    }
    debug_assert!(out.iter().all(|&(_, l)| (l as usize) <= MAX_LEN));
    out
}

/// Canonical code assignment from (symbol, length) pairs. Lengths must be
/// in `1..=MAX_LEN` (the decoder validates wire lengths before calling);
/// the accumulator is u64 so even a maximal `MAX_LEN`-bit step cannot
/// overflow the shift.
fn canonical_codes(lengths: &[(i64, u8)]) -> Vec<(i64, u8, u32)> {
    let mut sorted: Vec<(i64, u8)> = lengths.to_vec();
    sorted.sort_by_key(|&(s, l)| (l, s));
    let mut codes = Vec::with_capacity(sorted.len());
    let mut code: u64 = 0;
    let mut prev_len: u8 = 0;
    for &(sym, len) in &sorted {
        if prev_len != 0 {
            code = (code + 1) << (len - prev_len);
        } else {
            code <<= len - prev_len;
        }
        codes.push((sym, len, code as u32));
        prev_len = len;
    }
    codes
}

/// Two-pass canonical Huffman coder. The codebook travels in-band.
#[derive(Debug, Clone, Copy, Default)]
pub struct HuffmanCoder;

impl HuffmanCoder {
    /// Exact encoded size in bits for a stream (codebook + payload),
    /// without materializing the encoding. Used by the rate controller.
    pub fn encoded_bits(xs: &[i64]) -> usize {
        if xs.is_empty() {
            return 32;
        }
        let mut counts: HashMap<i64, usize> = HashMap::new();
        for &x in xs {
            *counts.entry(x).or_insert(0) += 1;
        }
        let mut cv: Vec<(i64, usize)> = counts.into_iter().collect();
        cv.sort_unstable();
        let lens = code_lengths(&cv);
        let cmap: HashMap<i64, u8> = lens.iter().map(|&(s, l)| (s, l)).collect();
        let payload: usize = xs.iter().map(|x| cmap[x] as usize).sum();
        // Header: u32 n_symbols + per-symbol (varint zigzag symbol via
        // 16-bit cap here, we serialize as u32 + u8 len) — match encode().
        let header = 32 + lens.len() * (32 + 8);
        header + payload
    }
}

impl IntCoder for HuffmanCoder {
    fn encode(&self, xs: &[i64], w: &mut BitWriter) {
        let mut counts: HashMap<i64, usize> = HashMap::new();
        for &x in xs {
            *counts.entry(x).or_insert(0) += 1;
        }
        let mut cv: Vec<(i64, usize)> = counts.into_iter().collect();
        cv.sort_unstable();
        w.push_u32(cv.len() as u32);
        if cv.is_empty() {
            return;
        }
        let lens = code_lengths(&cv);
        let codes = canonical_codes(&lens);
        // Serialize codebook: (zigzag(symbol) as u32, len u8), in canonical
        // order so the decoder reconstructs codes by lengths alone.
        for &(sym, len, _) in &codes {
            w.push_u32(zigzag(sym) as u32);
            w.push_bits(len as u64, 8);
        }
        let cmap: HashMap<i64, (u8, u32)> =
            codes.iter().map(|&(s, l, c)| (s, (l, c))).collect();
        for x in xs {
            let (len, code) = cmap[x];
            w.push_bits(code as u64, len as u32);
        }
    }

    fn decode(&self, n: usize, r: &mut BitReader) -> Result<Vec<i64>, CodeError> {
        let n_sym = r.read_u32() as usize;
        if n_sym == 0 {
            if n != 0 {
                return Err(CodeError::BadCount { declared: 0, capacity: n });
            }
            return Ok(Vec::new());
        }
        // Each codebook entry costs 40 bits on the wire, so a declared
        // count the remaining stream cannot hold is corruption — reject
        // before allocating for it.
        let capacity = r.remaining_bits() / 40;
        if n_sym > capacity {
            return Err(CodeError::BadCount { declared: n_sym, capacity });
        }
        let mut entries: Vec<(i64, u8)> = Vec::with_capacity(n_sym);
        for _ in 0..n_sym {
            let sym = unzigzag(r.read_u32() as u64);
            let len = r.read_bits(8) as u8;
            if len == 0 || len as usize > MAX_LEN {
                return Err(CodeError::BadCodeLength { len: len as usize, max: MAX_LEN });
            }
            entries.push((sym, len));
        }
        let codes = canonical_codes(&entries);
        // Decode bit-by-bit against sorted canonical table (first-code per
        // length). Build length-indexed lookup.
        let mut by_len: Vec<Vec<(u32, i64)>> = vec![Vec::new(); MAX_LEN + 1];
        for &(sym, len, code) in &codes {
            by_len[len as usize].push((code, sym));
        }
        for v in by_len.iter_mut() {
            v.sort_unstable();
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let mut code: u32 = 0;
            let mut len = 0usize;
            loop {
                code = (code << 1) | r.read_bit() as u32;
                len += 1;
                if len > MAX_LEN {
                    return Err(CodeError::BadCodeLength { len, max: MAX_LEN });
                }
                if let Ok(i) = by_len[len].binary_search_by_key(&code, |&(c, _)| c) {
                    out.push(by_len[len][i].1);
                    break;
                }
            }
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "huffman"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Rng, Xoshiro256pp};

    #[test]
    fn roundtrip_basic() {
        let xs = vec![0i64, 0, 0, 1, -1, 2, 0, 0, 3, -3, 0];
        let c = HuffmanCoder;
        let mut w = BitWriter::new();
        c.encode(&xs, &mut w);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(c.decode(xs.len(), &mut r).unwrap(), xs);
    }

    #[test]
    fn roundtrip_single_symbol() {
        let xs = vec![42i64; 1000];
        let c = HuffmanCoder;
        let mut w = BitWriter::new();
        c.encode(&xs, &mut w);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(c.decode(xs.len(), &mut r).unwrap(), xs);
    }

    #[test]
    fn roundtrip_random() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let xs: Vec<i64> =
            (0..10_000).map(|_| (rng.normal() * 4.0).round() as i64).collect();
        let c = HuffmanCoder;
        let mut w = BitWriter::new();
        c.encode(&xs, &mut w);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(c.decode(xs.len(), &mut r).unwrap(), xs);
    }

    #[test]
    fn corrupt_codebooks_return_err_not_panic() {
        // Declared symbol count far beyond the stream's physical capacity.
        let mut w = BitWriter::new();
        w.push_u32(u32::MAX);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert!(matches!(
            HuffmanCoder.decode(4, &mut r),
            Err(CodeError::BadCount { .. })
        ));
        // Empty codebook but a nonzero symbol request.
        let mut w = BitWriter::new();
        w.push_u32(0);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert!(matches!(
            HuffmanCoder.decode(1, &mut r),
            Err(CodeError::BadCount { declared: 0, capacity: 1 })
        ));
        // Codebook entry with an inadmissible code length.
        for bad_len in [0u64, (MAX_LEN + 1) as u64] {
            let mut w = BitWriter::new();
            w.push_u32(1);
            w.push_u32(zigzag(3) as u32);
            w.push_bits(bad_len, 8);
            w.push_u32(0); // padding so the count check passes
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            assert!(matches!(
                HuffmanCoder.decode(1, &mut r),
                Err(CodeError::BadCodeLength { .. })
            ));
        }
        // Valid codebook, garbage payload that never matches a code: the
        // bit-by-bit walk must stop at MAX_LEN with an error. A single
        // 1-bit code for one symbol means a payload of zero bits decodes
        // that symbol forever — instead corrupt the codebook to two
        // entries of length 2 covering codes 00 and 01, then feed 1-bits.
        let mut w = BitWriter::new();
        w.push_u32(2);
        w.push_u32(zigzag(1) as u32);
        w.push_bits(2, 8);
        w.push_u32(zigzag(2) as u32);
        w.push_bits(2, 8);
        for _ in 0..8 {
            w.push_byte(0xFF); // payload bits that match neither code
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert!(matches!(
            HuffmanCoder.decode(1, &mut r),
            Err(CodeError::BadCodeLength { .. })
        ));
    }

    #[test]
    fn payload_within_one_bit_of_entropy() {
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let xs: Vec<i64> =
            (0..50_000).map(|_| (rng.normal() * 2.0).round() as i64).collect();
        let h = crate::entropy::empirical_entropy(&xs);
        let bits = HuffmanCoder::encoded_bits(&xs);
        // Subtract the (small) codebook header before comparing to entropy.
        let n_sym = {
            let mut s: Vec<i64> = xs.clone();
            s.sort_unstable();
            s.dedup();
            s.len()
        };
        let payload = bits - 32 - n_sym * 40;
        let bps = payload as f64 / xs.len() as f64;
        assert!(bps < h + 1.0, "bits/sym {bps} vs H {h}");
        assert!(bps + 1e-9 >= h, "Huffman cannot beat entropy: {bps} vs {h}");
    }

    #[test]
    fn encoded_bits_matches_actual_encoding() {
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let xs: Vec<i64> =
            (0..5_000).map(|_| (rng.normal() * 3.0).round() as i64).collect();
        let predicted = HuffmanCoder::encoded_bits(&xs);
        let mut w = BitWriter::new();
        HuffmanCoder.encode(&xs, &mut w);
        assert_eq!(predicted, w.bit_len());
    }
}
