//! Adaptive range coder (carry-less, 32-bit) — table-driven symbol coding.
//!
//! The default entropy coder for UVeQFed lattice indices. Since the hot-path
//! overhaul the primary path codes **whole symbols against per-context
//! frequency tables**: each context keeps adaptive counts for the 31 most
//! frequent zig-zagged values plus an escape slot, so a typical lattice
//! coordinate costs ONE range-coder narrowing instead of the 3–7 adaptive
//! binary decisions of the original bit-by-bit coder. Escaped (rare, large)
//! values fall back to the gamma-style adaptive bit models. This tracks the
//! empirical index distribution within a few % of entropy without a
//! two-pass codebook, which matters because model-update distributions
//! drift over FL rounds.
//!
//! The core is the classic Subbotin/LZMA-style range coder: 32-bit range,
//! renormalizing a byte at a time, with both binary (12-bit probability,
//! adaptation shift 5) and cumulative-frequency narrowing sharing one
//! low/range state so the escape path can interleave with table-coded
//! symbols.
//!
//! The original bit-by-bit coder survives as [`BitwiseRangeCoder`] — the
//! compatibility oracle the property suite fuzzes the table-driven path
//! against. The two produce different byte streams (the fleet frame version
//! was bumped accordingly) but must decode identical symbol sequences.

use super::{unzigzag, zigzag, BitReader, BitWriter, CodeError, IntCoder};

const PROB_BITS: u32 = 12;
const PROB_ONE: u16 = 1 << PROB_BITS;
const PROB_INIT: u16 = PROB_ONE / 2;
const ADAPT_SHIFT: u32 = 5;
const TOP: u32 = 1 << 24;

/// One adaptive binary probability state.
#[derive(Debug, Clone, Copy)]
struct BitModel(u16);

impl Default for BitModel {
    fn default() -> Self {
        Self(PROB_INIT)
    }
}

impl BitModel {
    /// `self.0` is the probability of bit == 0 (the `code < bound` side);
    /// observing a 0 must therefore *increase* it.
    #[inline]
    fn update(&mut self, bit: bool) {
        if bit {
            self.0 -= self.0 >> ADAPT_SHIFT;
        } else {
            self.0 += (PROB_ONE - self.0) >> ADAPT_SHIFT;
        }
    }
}

/// Range encoder writing bytes into a `Vec<u8>`.
///
/// Canonical LZMA-style carry handling: `cache` holds the last byte that
/// might still receive a carry, `cache_size` counts pending 0xFF bytes.
/// The first emitted byte is a spurious 0 (cache initial value); the
/// decoder skips it during init.
pub struct RangeEncoder {
    low: u64,
    range: u32,
    out: Vec<u8>,
    cache: u8,
    cache_size: u64,
}

impl Default for RangeEncoder {
    fn default() -> Self {
        Self::new()
    }
}

impl RangeEncoder {
    pub fn new() -> Self {
        Self { low: 0, range: u32::MAX, out: Vec::new(), cache: 0, cache_size: 1 }
    }

    #[inline]
    fn shift_low(&mut self) {
        if (self.low as u32) < 0xFF00_0000 || (self.low >> 32) != 0 {
            let carry = (self.low >> 32) as u8;
            let mut byte = self.cache;
            loop {
                self.out.push(byte.wrapping_add(carry));
                byte = 0xFF;
                self.cache_size -= 1;
                if self.cache_size == 0 {
                    break;
                }
            }
            self.cache = (self.low >> 24) as u8;
        }
        self.cache_size += 1;
        self.low = (self.low << 8) & 0xFFFF_FFFF;
    }

    #[inline]
    fn encode_bit_with(&mut self, model: &mut BitModel, bit: bool) {
        let bound = (self.range >> PROB_BITS) * model.0 as u32;
        if !bit {
            self.range = bound;
        } else {
            self.low += bound as u64;
            self.range -= bound;
        }
        model.update(bit);
        while self.range < TOP {
            self.range <<= 8;
            self.shift_low();
        }
    }

    /// Narrow to the sub-interval `[cum, cum+freq)` of a `total`-mass
    /// cumulative frequency table — one multi-bit symbol per call (the
    /// table-driven fast path). Requires `total ≤ 2^16` so the reduced
    /// range stays positive.
    #[inline]
    fn encode_freq(&mut self, cum: u32, freq: u32, total: u32) {
        debug_assert!(freq > 0 && cum + freq <= total);
        let r = self.range / total;
        self.low += (r as u64) * (cum as u64);
        self.range = r * freq;
        while self.range < TOP {
            self.range <<= 8;
            self.shift_low();
        }
    }

    pub fn finish(mut self) -> Vec<u8> {
        for _ in 0..5 {
            self.shift_low();
        }
        self.out
    }
}

/// Range decoder over a byte slice.
pub struct RangeDecoder<'a> {
    code: u32,
    range: u32,
    buf: &'a [u8],
    pos: usize,
}

impl<'a> RangeDecoder<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        let mut d = Self { code: 0, range: u32::MAX, buf, pos: 0 };
        // 5 init bytes: the first is the encoder's spurious cache byte and
        // shifts straight out of the 32-bit code register.
        for _ in 0..5 {
            d.code = (d.code << 8) | d.next_byte() as u32;
        }
        d
    }

    #[inline]
    fn next_byte(&mut self) -> u8 {
        let b = if self.pos < self.buf.len() { self.buf[self.pos] } else { 0 };
        self.pos += 1;
        b
    }

    #[inline]
    fn decode_bit_with(&mut self, model: &mut BitModel) -> bool {
        let bound = (self.range >> PROB_BITS) * model.0 as u32;
        let bit = if self.code < bound {
            self.range = bound;
            false
        } else {
            self.code -= bound;
            self.range -= bound;
            true
        };
        model.update(bit);
        while self.range < TOP {
            self.range <<= 8;
            self.code = (self.code << 8) | self.next_byte() as u32;
        }
        bit
    }

    /// Cumulative-frequency target for the next symbol: the caller scans
    /// its table for the slot `s` with `cum(s) ≤ target < cum(s)+freq(s)`
    /// and then commits with [`Self::decode_update`]. Clamped so corrupt
    /// or zero-padded streams yield in-range garbage instead of UB.
    #[inline]
    fn decode_target(&self, total: u32) -> u32 {
        let r = self.range / total;
        (self.code / r).min(total - 1)
    }

    /// Commit the symbol found from [`Self::decode_target`] — the decoder
    /// mirror of [`RangeEncoder::encode_freq`].
    #[inline]
    fn decode_update(&mut self, cum: u32, freq: u32, total: u32) {
        let r = self.range / total;
        self.code -= r * cum;
        self.range = r * freq;
        while self.range < TOP {
            self.range <<= 8;
            self.code = (self.code << 8) | self.next_byte() as u32;
        }
    }

    /// Bytes consumed (for accounting).
    pub fn bytes_read(&self) -> usize {
        self.pos
    }
}

/// Context model for integers coded bit-by-bit: a unary-ish binarization
/// where bit position k has its own adaptive state. MAX_CTX positions;
/// beyond that, a shared state. Used by the escape path of the table-driven
/// coder and by [`BitwiseRangeCoder`].
const MAX_CTX: usize = 48;

#[derive(Debug, Clone)]
struct IntModel {
    /// "continue" flags for unary length prefix of the Elias-gamma-style
    /// binarization.
    len_ctx: [BitModel; MAX_CTX],
    /// mantissa bits, indexed by (length, position) folded into one axis.
    bit_ctx: [BitModel; MAX_CTX],
}

impl Default for IntModel {
    fn default() -> Self {
        Self { len_ctx: [BitModel::default(); MAX_CTX], bit_ctx: [BitModel::default(); MAX_CTX] }
    }
}

impl IntModel {
    fn encode(&mut self, enc: &mut RangeEncoder, v: u64) {
        // v >= 0 (zig-zagged). Binarize as gamma: n = ilog2(v+1),
        // n "1" flags then a 0, then n mantissa bits of (v+1).
        // saturating_add guards v == u64::MAX (saturated casts upstream).
        let x = v.saturating_add(1).max(1);
        let n = (63 - x.leading_zeros()) as usize;
        for i in 0..n {
            enc.encode_bit_with(&mut self.len_ctx[i.min(MAX_CTX - 1)], true);
        }
        enc.encode_bit_with(&mut self.len_ctx[n.min(MAX_CTX - 1)], false);
        for i in (0..n).rev() {
            let bit = (x >> i) & 1 == 1;
            enc.encode_bit_with(&mut self.bit_ctx[i.min(MAX_CTX - 1)], bit);
        }
    }

    fn decode(&mut self, dec: &mut RangeDecoder) -> Result<u64, CodeError> {
        let mut n = 0usize;
        while dec.decode_bit_with(&mut self.len_ctx[n.min(MAX_CTX - 1)]) {
            n += 1;
            if n >= 64 {
                return Err(CodeError::IntOverflow { coder: "adaptive-range" });
            }
        }
        let mut x = 1u64;
        for i in (0..n).rev() {
            let bit = dec.decode_bit_with(&mut self.bit_ctx[i.min(MAX_CTX - 1)]);
            x = (x << 1) | bit as u64;
        }
        Ok(x - 1)
    }
}

/// Direct table slots: zig-zagged values `0..DIRECT_SYMS` (i.e. signed
/// values in `[-15, 15]`) code in a single narrowing; everything larger
/// escapes. Lattice coordinates at practical rates concentrate far inside
/// this window.
const DIRECT_SYMS: usize = 31;
/// Escape slot index.
const ESCAPE: usize = DIRECT_SYMS;
/// Table width (direct slots + escape).
const NSYM: usize = DIRECT_SYMS + 1;
/// Count added to a slot on each observation.
const FREQ_INC: u16 = 24;
/// Rescale threshold: keeps totals ≤ 2^13 (cheap division, u16 counts).
const FREQ_LIMIT: u32 = 1 << 13;

/// Adaptive cumulative-frequency table for one context.
#[derive(Debug, Clone)]
struct SymContext {
    freq: [u16; NSYM],
    total: u32,
}

impl Default for SymContext {
    fn default() -> Self {
        Self { freq: [1; NSYM], total: NSYM as u32 }
    }
}

impl SymContext {
    #[inline]
    fn cum(&self, s: usize) -> u32 {
        self.freq[..s].iter().map(|&f| f as u32).sum()
    }

    #[inline]
    fn update(&mut self, s: usize) {
        self.freq[s] += FREQ_INC;
        self.total += FREQ_INC as u32;
        if self.total > FREQ_LIMIT {
            let mut t = 0u32;
            for f in self.freq.iter_mut() {
                *f = (*f >> 1).max(1);
                t += *f as u32;
            }
            self.total = t;
        }
    }
}

/// One per-dimension symbol model: frequency table + escape bit models.
#[derive(Debug, Clone, Default)]
struct SymbolModel {
    ctx: SymContext,
    esc: IntModel,
}

impl SymbolModel {
    fn encode(&mut self, enc: &mut RangeEncoder, u: u64) {
        let s = if u < DIRECT_SYMS as u64 { u as usize } else { ESCAPE };
        enc.encode_freq(self.ctx.cum(s), self.ctx.freq[s] as u32, self.ctx.total);
        self.ctx.update(s);
        if s == ESCAPE {
            self.esc.encode(enc, u - DIRECT_SYMS as u64);
        }
    }

    fn decode(&mut self, dec: &mut RangeDecoder) -> Result<u64, CodeError> {
        let t = dec.decode_target(self.ctx.total);
        let mut cum = 0u32;
        let mut s = 0usize;
        while cum + self.ctx.freq[s] as u32 <= t {
            cum += self.ctx.freq[s] as u32;
            s += 1;
        }
        dec.decode_update(cum, self.ctx.freq[s] as u32, self.ctx.total);
        self.ctx.update(s);
        if s == ESCAPE {
            Ok(DIRECT_SYMS as u64 + self.esc.decode(dec)?)
        } else {
            Ok(s as u64)
        }
    }
}

/// Incremental, symbol-at-a-time counterpart of [`AdaptiveRangeCoder`]'s
/// batch [`IntCoder::decode`], over a *borrowed* range-coded payload (the
/// bytes after the u32 length prefix that the batch encoder emits).
///
/// This is what lets codec decode sessions run in O(chunk) memory: the
/// UVeQFed / QSGD / TernGrad streams hold one `SymbolDecoder` and pull
/// symbols per chunk instead of materializing all `m` integers. Symbol
/// `i` uses model `i % dims`, exactly like the batch decoder, so the two
/// paths are bit-identical. [`Self::decode_into`] is the batched pull the
/// session hot paths use (one call per lattice block / decoded chunk).
pub struct SymbolDecoder<'a> {
    dec: RangeDecoder<'a>,
    models: Vec<SymbolModel>,
    i: usize,
}

impl<'a> SymbolDecoder<'a> {
    pub fn new(payload: &'a [u8], dims: usize) -> Self {
        Self {
            dec: RangeDecoder::new(payload),
            models: vec![SymbolModel::default(); dims.max(1)],
            i: 0,
        }
    }

    /// Decoder for a range payload embedded in `bytes` at the position of
    /// `r`, which must sit (byte-aligned) on the u32 length prefix the
    /// batch encoder emits. Owns the embedded-payload framing in one
    /// place so the streaming codec decoders cannot drift from the batch
    /// path. Out-of-range lengths are clamped — the range decoder
    /// zero-fills past the end, matching the batch path's padded reads.
    pub fn from_embedded(bytes: &'a [u8], r: &mut BitReader, dims: usize) -> Self {
        let len = r.read_u32() as usize;
        debug_assert_eq!(r.bit_pos() % 8, 0, "range payload must start byte-aligned");
        let start = (r.bit_pos() / 8).min(bytes.len());
        let end = (start + len).min(bytes.len());
        Self::new(&bytes[start..end], dims)
    }

    /// Decode the next signed symbol. Corrupt escape codes surface as a
    /// typed error instead of a panic.
    pub fn next_symbol(&mut self) -> Result<i64, CodeError> {
        let d = self.i % self.models.len();
        self.i += 1;
        Ok(unzigzag(self.models[d].decode(&mut self.dec)?))
    }

    /// Batched decode: fill `out` with the next `out.len()` signed symbols
    /// (allocation-free; the session hot paths call this once per chunk).
    /// Stops at the first corrupt symbol and reports it — entries past the
    /// failure point are left untouched.
    pub fn decode_into(&mut self, out: &mut [i64]) -> Result<(), CodeError> {
        let dims = self.models.len();
        for o in out.iter_mut() {
            let d = self.i % dims;
            self.i += 1;
            *o = unzigzag(self.models[d].decode(&mut self.dec)?);
        }
        Ok(())
    }
}

/// Adaptive range coder exposed through the common [`IntCoder`] interface.
/// The byte payload is length-prefixed inside the bit stream so it can be
/// embedded in a larger message.
///
/// `dims > 1` maintains one adaptive model per position modulo `dims` —
/// for interleaved lattice coordinates whose per-dimension statistics
/// differ (e.g. D4/E8 coordinate systems).
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveRangeCoder {
    dims: usize,
}

impl Default for AdaptiveRangeCoder {
    fn default() -> Self {
        Self { dims: 1 }
    }
}

impl AdaptiveRangeCoder {
    pub fn with_dims(dims: usize) -> Self {
        Self { dims: dims.max(1) }
    }
}

impl IntCoder for AdaptiveRangeCoder {
    fn encode(&self, xs: &[i64], w: &mut BitWriter) {
        let mut enc = RangeEncoder::new();
        let mut models: Vec<SymbolModel> = vec![SymbolModel::default(); self.dims];
        let mut escapes = 0u64;
        for (i, &x) in xs.iter().enumerate() {
            let sym = zigzag(x);
            escapes += u64::from(sym >= DIRECT_SYMS as u64);
            models[i % self.dims].encode(&mut enc, sym);
        }
        crate::telemetry::probe::add_symbols(xs.len() as u64, escapes);
        let payload = enc.finish();
        w.push_u32(payload.len() as u32);
        for b in payload {
            w.push_byte(b);
        }
    }

    fn decode(&self, n: usize, r: &mut BitReader) -> Result<Vec<i64>, CodeError> {
        // Clamp the declared payload length to the physically remaining
        // bytes (mirrors `SymbolDecoder::from_embedded`): a corrupt length
        // prefix must not drive a huge allocation, and the range decoder
        // zero-fills past the end anyway.
        let len = (r.read_u32() as usize).min(r.remaining_bits() / 8);
        let bytes: Vec<u8> = (0..len).map(|_| r.read_byte()).collect();
        let mut sd = SymbolDecoder::new(&bytes, self.dims);
        let mut out = vec![0i64; n];
        sd.decode_into(&mut out)?;
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "adaptive-range"
    }
}

/// The original bit-by-bit adaptive coder (gamma binarization, one binary
/// range decision per bit). Kept as the compatibility oracle: the property
/// suite fuzzes [`AdaptiveRangeCoder`] against it (both must round-trip the
/// same symbol streams), and perf work can A/B the two paths. Wire format
/// differs from the table-driven coder.
#[derive(Debug, Clone, Copy)]
pub struct BitwiseRangeCoder {
    dims: usize,
}

impl Default for BitwiseRangeCoder {
    fn default() -> Self {
        Self { dims: 1 }
    }
}

impl BitwiseRangeCoder {
    pub fn with_dims(dims: usize) -> Self {
        Self { dims: dims.max(1) }
    }
}

impl IntCoder for BitwiseRangeCoder {
    fn encode(&self, xs: &[i64], w: &mut BitWriter) {
        let mut enc = RangeEncoder::new();
        let mut models: Vec<IntModel> =
            (0..self.dims).map(|_| IntModel::default()).collect();
        for (i, &x) in xs.iter().enumerate() {
            models[i % self.dims].encode(&mut enc, zigzag(x));
        }
        let payload = enc.finish();
        w.push_u32(payload.len() as u32);
        for b in payload {
            w.push_byte(b);
        }
    }

    fn decode(&self, n: usize, r: &mut BitReader) -> Result<Vec<i64>, CodeError> {
        let len = (r.read_u32() as usize).min(r.remaining_bits() / 8);
        let bytes: Vec<u8> = (0..len).map(|_| r.read_byte()).collect();
        let mut dec = RangeDecoder::new(&bytes);
        let mut models: Vec<IntModel> =
            (0..self.dims).map(|_| IntModel::default()).collect();
        (0..n)
            .map(|i| models[i % self.dims].decode(&mut dec).map(unzigzag))
            .collect()
    }

    fn name(&self) -> &'static str {
        "adaptive-range-bitwise"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Rng, Xoshiro256pp};

    #[test]
    fn roundtrip_small() {
        let xs: Vec<i64> = vec![0, 0, 1, -1, 2, -2, 0, 0, 0, 5, -7, 0];
        let coder = AdaptiveRangeCoder::default();
        let mut w = BitWriter::new();
        coder.encode(&xs, &mut w);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(coder.decode(xs.len(), &mut r).unwrap(), xs);
    }

    #[test]
    fn roundtrip_random_heavy_tail() {
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let xs: Vec<i64> = (0..20_000)
            .map(|_| {
                let g = rng.normal() * 3.0;
                g.round() as i64
            })
            .collect();
        let coder = AdaptiveRangeCoder::default();
        let mut w = BitWriter::new();
        coder.encode(&xs, &mut w);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(coder.decode(xs.len(), &mut r).unwrap(), xs);
    }

    #[test]
    fn roundtrip_escape_heavy_magnitudes() {
        // Force the escape path hard: values far outside the direct table.
        let mut rng = Xoshiro256pp::seed_from_u64(12);
        let xs: Vec<i64> = (0..5000)
            .map(|_| {
                let m = 1i64 << rng.gen_index(40);
                let v = rng.gen_index(m as usize + 1) as i64;
                if rng.next_u64() & 1 == 0 {
                    v
                } else {
                    -v
                }
            })
            .collect();
        for dims in [1usize, 2, 8] {
            let coder = AdaptiveRangeCoder::with_dims(dims);
            let mut w = BitWriter::new();
            coder.encode(&xs, &mut w);
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            assert_eq!(coder.decode(xs.len(), &mut r).unwrap(), xs, "dims={dims}");
        }
    }

    #[test]
    fn compresses_near_entropy_on_skewed_stream() {
        // Mostly zeros: entropy-ish coding should land well under 1 bit/sym.
        let mut rng = Xoshiro256pp::seed_from_u64(10);
        let xs: Vec<i64> = (0..50_000)
            .map(|_| if rng.uniform() < 0.95 { 0 } else { rng.gen_index(5) as i64 - 2 })
            .collect();
        let h = crate::entropy::empirical_entropy(&xs);
        let coder = AdaptiveRangeCoder::default();
        let mut w = BitWriter::new();
        coder.encode(&xs, &mut w);
        let bits_per_sym = w.bit_len() as f64 / xs.len() as f64;
        // within 20% of empirical entropy + tiny constant
        assert!(
            bits_per_sym < h * 1.2 + 0.05,
            "bits/sym={bits_per_sym:.4}, H={h:.4}"
        );
        // and must round-trip
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(coder.decode(xs.len(), &mut r).unwrap(), xs);
    }

    #[test]
    fn symbol_decoder_matches_batch_decode() {
        // The streaming codec decoders slice the payload directly out of
        // the message (skipping the u32 length prefix) — verify that
        // contract for dims 1 and 2, per-symbol and batched pulls.
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let xs: Vec<i64> = (0..5000).map(|_| (rng.normal() * 4.0).round() as i64).collect();
        for dims in [1usize, 2] {
            let coder = AdaptiveRangeCoder::with_dims(dims);
            let mut w = BitWriter::new();
            coder.encode(&xs, &mut w);
            let bytes = w.into_bytes();
            // batch path
            let mut r = BitReader::new(&bytes);
            let batch = coder.decode(xs.len(), &mut r).unwrap();
            assert_eq!(batch, xs);
            // streaming path over the raw payload slice (after u32 len)
            let len = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]) as usize;
            let mut sd = SymbolDecoder::new(&bytes[4..4 + len], dims);
            let streamed: Vec<i64> =
                (0..xs.len()).map(|_| sd.next_symbol().unwrap()).collect();
            assert_eq!(streamed, xs);
            // batched pulls in uneven chunks
            let mut sd = SymbolDecoder::new(&bytes[4..4 + len], dims);
            let mut chunked = vec![0i64; xs.len()];
            let mut pos = 0usize;
            for step in [1usize, 7, 64, 1000].iter().cycle() {
                if pos >= xs.len() {
                    break;
                }
                let n = (*step).min(xs.len() - pos);
                sd.decode_into(&mut chunked[pos..pos + n]).unwrap();
                pos += n;
            }
            assert_eq!(chunked, xs);
        }
    }

    #[test]
    fn concatenated_messages_independent() {
        // Two encodes into the same BitWriter must decode back-to-back.
        let a: Vec<i64> = vec![3, -4, 5, 0, 0, 1];
        let b: Vec<i64> = vec![-9, 9, 0, 2];
        let coder = AdaptiveRangeCoder::default();
        let mut w = BitWriter::new();
        coder.encode(&a, &mut w);
        coder.encode(&b, &mut w);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(coder.decode(a.len(), &mut r).unwrap(), a);
        assert_eq!(coder.decode(b.len(), &mut r).unwrap(), b);
    }

    #[test]
    fn corrupt_payloads_never_panic_and_bad_lengths_do_not_allocate() {
        // Bit-flip every byte of a real payload: decode must return either
        // in-range garbage or a typed error — never panic. (A flipped bit
        // can desynchronize the adaptive models arbitrarily.)
        let mut rng = Xoshiro256pp::seed_from_u64(77);
        let xs: Vec<i64> =
            (0..300).map(|_| (rng.normal() * 200.0).round() as i64).collect();
        for coder in
            [&AdaptiveRangeCoder::default() as &dyn IntCoder, &BitwiseRangeCoder::default()]
        {
            let mut w = BitWriter::new();
            coder.encode(&xs, &mut w);
            let bytes = w.into_bytes();
            for pos in 0..bytes.len() {
                let mut bad = bytes.clone();
                bad[pos] ^= 0x10;
                let mut r = BitReader::new(&bad);
                if let Ok(out) = coder.decode(xs.len(), &mut r) {
                    assert_eq!(out.len(), xs.len(), "{} at byte {pos}", coder.name());
                }
            }
            // A length prefix claiming ~4 GB of payload must be clamped to
            // the physically remaining bytes, not allocated.
            let mut w = BitWriter::new();
            w.push_u32(u32::MAX);
            w.push_byte(0xAB);
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            let _ = coder.decode(4, &mut r);
        }
    }

    #[test]
    fn bitwise_oracle_roundtrips_same_streams() {
        // The legacy coder must keep round-tripping; it is the oracle the
        // property suite checks the table-driven coder against.
        let mut rng = Xoshiro256pp::seed_from_u64(13);
        let xs: Vec<i64> = (0..8000).map(|_| (rng.normal() * 40.0).round() as i64).collect();
        for dims in [1usize, 2] {
            let coder = BitwiseRangeCoder::with_dims(dims);
            let mut w = BitWriter::new();
            coder.encode(&xs, &mut w);
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            assert_eq!(coder.decode(xs.len(), &mut r).unwrap(), xs, "dims={dims}");
        }
    }
}
