//! Elias universal integer codes (γ, δ, ω).
//!
//! QSGD [17] uses Elias(recursive) coding of the quantized levels; UVeQFed
//! can use them as a one-pass alternative to the adaptive range coder. All
//! codes here encode *positive* integers (≥ 1); signed lattice coordinates
//! go through zig-zag + 1.

use super::{unzigzag, zigzag, BitReader, BitWriter, CodeError, IntCoder};

/// Elias gamma: unary length prefix + binary remainder. Optimal for
/// P(x) ∝ 2^{-2 log x} style heavy-tail distributions.
#[derive(Debug, Clone, Copy, Default)]
pub struct EliasGamma;

/// Elias delta: gamma-coded length + binary remainder — asymptotically
/// shorter than gamma for large values.
#[derive(Debug, Clone, Copy, Default)]
pub struct EliasDelta;

/// Elias omega: recursive length encoding (the code QSGD references).
#[derive(Debug, Clone, Copy, Default)]
pub struct EliasOmega;

#[inline]
fn ilog2(x: u64) -> u32 {
    63 - x.leading_zeros()
}

impl EliasGamma {
    pub fn put(w: &mut BitWriter, x: u64) {
        assert!(x >= 1, "Elias codes encode integers >= 1");
        let n = ilog2(x);
        for _ in 0..n {
            w.push_bit(false);
        }
        w.push_bits(x, n + 1); // leading 1 + n remainder bits
    }

    pub fn get(r: &mut BitReader) -> Result<u64, CodeError> {
        let mut n = 0u32;
        while !r.read_bit() {
            n += 1;
            if n >= 64 {
                return Err(CodeError::IntOverflow { coder: "elias-gamma" });
            }
        }
        Ok((1u64 << n) | r.read_bits(n))
    }
}

impl EliasDelta {
    pub fn put(w: &mut BitWriter, x: u64) {
        assert!(x >= 1);
        let n = ilog2(x);
        EliasGamma::put(w, (n + 1) as u64);
        w.push_bits(x & !(1u64 << n), n); // remainder without leading 1
    }

    pub fn get(r: &mut BitReader) -> Result<u64, CodeError> {
        let len = EliasGamma::get(r)? as u32 - 1;
        if len >= 64 {
            return Err(CodeError::IntOverflow { coder: "elias-delta" });
        }
        Ok((1u64 << len) | r.read_bits(len))
    }
}

impl EliasOmega {
    pub fn put(w: &mut BitWriter, x: u64) {
        assert!(x >= 1);
        // Build groups back-to-front.
        let mut groups: Vec<(u64, u32)> = Vec::new();
        let mut k = x;
        while k > 1 {
            let n = ilog2(k);
            groups.push((k, n + 1));
            k = n as u64;
        }
        for &(v, bits) in groups.iter().rev() {
            w.push_bits(v, bits);
        }
        w.push_bit(false); // terminator
    }

    pub fn get(r: &mut BitReader) -> Result<u64, CodeError> {
        let mut n = 1u64;
        loop {
            if !r.read_bit() {
                return Ok(n);
            }
            if n >= 64 {
                return Err(CodeError::IntOverflow { coder: "elias-omega" });
            }
            // The bit we just read is the leading 1 of a (n+1)-bit group.
            let rest = r.read_bits(n as u32);
            n = (1u64 << n) | rest;
        }
    }
}

macro_rules! impl_int_coder {
    ($t:ty, $name:literal) => {
        impl IntCoder for $t {
            fn encode(&self, xs: &[i64], w: &mut BitWriter) {
                for &x in xs {
                    <$t>::put(w, zigzag(x) + 1);
                }
            }
            fn decode(&self, n: usize, r: &mut BitReader) -> Result<Vec<i64>, CodeError> {
                (0..n).map(|_| <$t>::get(r).map(|v| unzigzag(v - 1))).collect()
            }
            fn name(&self) -> &'static str {
                $name
            }
        }
    };
}

impl_int_coder!(EliasGamma, "elias-gamma");
impl_int_coder!(EliasDelta, "elias-delta");
impl_int_coder!(EliasOmega, "elias-omega");

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_one<F: Fn(&mut BitWriter, u64), G: Fn(&mut BitReader) -> u64>(
        put: F,
        get: G,
    ) {
        let vals: Vec<u64> = (1..200)
            .chain([255, 256, 257, 1023, 1024, 65535, 1 << 20, (1 << 40) + 17])
            .collect();
        let mut w = BitWriter::new();
        for &v in &vals {
            put(&mut w, v);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &v in &vals {
            assert_eq!(get(&mut r), v);
        }
    }

    #[test]
    fn gamma_roundtrip() {
        roundtrip_one(EliasGamma::put, |r| EliasGamma::get(r).unwrap());
    }

    #[test]
    fn delta_roundtrip() {
        roundtrip_one(EliasDelta::put, |r| EliasDelta::get(r).unwrap());
    }

    #[test]
    fn omega_roundtrip() {
        roundtrip_one(EliasOmega::put, |r| EliasOmega::get(r).unwrap());
    }

    #[test]
    fn corrupt_streams_return_err_not_panic() {
        // An empty buffer reads as an endless run of zero bits: the gamma
        // unary prefix never terminates and must surface as a typed error.
        let mut r = BitReader::new(&[]);
        assert_eq!(
            EliasGamma::get(&mut r),
            Err(CodeError::IntOverflow { coder: "elias-gamma" })
        );
        // Delta with a gamma-coded length claiming a >64-bit remainder.
        let mut w = BitWriter::new();
        EliasGamma::put(&mut w, 70); // delta len = 69 bits
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(
            EliasDelta::get(&mut r),
            Err(CodeError::IntOverflow { coder: "elias-delta" })
        );
        // Omega over all-ones bytes: the recursive groups double past 64.
        let ones = [0xFFu8; 32];
        let mut r = BitReader::new(&ones);
        assert_eq!(
            EliasOmega::get(&mut r),
            Err(CodeError::IntOverflow { coder: "elias-omega" })
        );
        // The IntCoder batch path propagates the same error.
        let mut r = BitReader::new(&[]);
        assert!(EliasGamma.decode(5, &mut r).is_err());
    }

    #[test]
    fn gamma_known_lengths() {
        // γ(1) = "1" (1 bit), γ(2) = "010" (3), γ(3)="011", γ(4)="00100" (5).
        for (v, bits) in [(1u64, 1usize), (2, 3), (3, 3), (4, 5), (7, 5), (8, 7)] {
            let mut w = BitWriter::new();
            EliasGamma::put(&mut w, v);
            assert_eq!(w.bit_len(), bits, "gamma({v})");
        }
    }

    #[test]
    fn signed_int_coder_roundtrip() {
        let xs: Vec<i64> = (-50..=50).chain([1000, -1000, 123456, -654321]).collect();
        for coder in [&EliasGamma as &dyn IntCoder, &EliasDelta, &EliasOmega] {
            let mut w = BitWriter::new();
            coder.encode(&xs, &mut w);
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            assert_eq!(coder.decode(xs.len(), &mut r).unwrap(), xs, "{}", coder.name());
        }
    }

    #[test]
    fn delta_beats_gamma_for_large_values() {
        let mut wg = BitWriter::new();
        let mut wd = BitWriter::new();
        for v in [100_000u64, 1 << 30, 1 << 45] {
            EliasGamma::put(&mut wg, v);
            EliasDelta::put(&mut wd, v);
        }
        assert!(wd.bit_len() < wg.bit_len());
    }
}
