//! Benchmark harness (criterion is not vendorable offline).
//!
//! `cargo bench` targets use [`run`] + [`BenchConfig`] for timing micro/meso benchmarks
//! with warmup, repetition, and robust statistics, and write figure data
//! through `metrics::CsvTable`. Output format is one line per benchmark:
//! `name  median  mean ± sem  (n iters)`.

use crate::metrics::Timer;
use crate::util::stats::{percentile, Welford};

#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub measure_iters: usize,
    /// Abort measurement early once this much wall time is spent.
    pub max_secs: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self { warmup_iters: 3, measure_iters: 15, max_secs: 20.0 }
    }
}

impl BenchConfig {
    /// Quick mode for CI-style smoke runs (env `BENCH_QUICK=1`).
    pub fn from_env() -> Self {
        if std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false) {
            Self { warmup_iters: 1, measure_iters: 3, max_secs: 5.0 }
        } else {
            Self::default()
        }
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub median_secs: f64,
    pub mean_secs: f64,
    pub sem_secs: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn throughput_per_sec(&self, items: f64) -> f64 {
        items / self.median_secs
    }
}

/// Run a benchmark closure.
pub fn run(name: &str, cfg: BenchConfig, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let wall = Timer::start();
    let mut samples = Vec::with_capacity(cfg.measure_iters);
    let mut acc = Welford::new();
    for _ in 0..cfg.measure_iters {
        let t = Timer::start();
        f();
        let dt = t.elapsed_secs();
        samples.push(dt);
        acc.push(dt);
        if wall.elapsed_secs() > cfg.max_secs {
            break;
        }
    }
    let res = BenchResult {
        name: name.to_string(),
        median_secs: percentile(&samples, 50.0),
        mean_secs: acc.mean(),
        sem_secs: acc.sem(),
        iters: samples.len(),
    };
    println!(
        "{:<44} median {:>10.4} ms   mean {:>10.4} ± {:>7.4} ms   ({} iters)",
        res.name,
        res.median_secs * 1e3,
        res.mean_secs * 1e3,
        res.sem_secs * 1e3,
        res.iters
    );
    res
}

/// Where figure CSVs land (`results/` by default, override with
/// `UVEQFED_RESULTS_DIR`).
pub fn results_dir() -> std::path::PathBuf {
    std::env::var("UVEQFED_RESULTS_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("results"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let cfg = BenchConfig { warmup_iters: 1, measure_iters: 5, max_secs: 5.0 };
        let r = run("noop-plus-sleep", cfg, || {
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        assert!(r.median_secs >= 0.001);
        assert!(r.iters >= 1);
        assert!(r.throughput_per_sec(100.0) > 0.0);
    }

    #[test]
    fn max_secs_caps_iterations() {
        let cfg = BenchConfig { warmup_iters: 0, measure_iters: 1000, max_secs: 0.02 };
        let r = run("capped", cfg, || {
            std::thread::sleep(std::time::Duration::from_millis(5));
        });
        assert!(r.iters < 1000);
    }
}
