//! Benchmark harness (criterion is not vendorable offline).
//!
//! `cargo bench` targets use [`run`] + [`BenchConfig`] for timing micro/meso benchmarks
//! with warmup, repetition, and robust statistics, and write figure data
//! through `metrics::CsvTable`. Output format is one line per benchmark:
//! `name  median  mean ± sem  (n iters)`.
//!
//! Perf-trajectory recording: every perf-relevant bench target also feeds
//! its results into a [`Recorder`], which merges a labelled snapshot into
//! the machine-readable baseline file `BENCH_baseline.json` (schema in
//! DESIGN.md §Performance). `--smoke` (or `BENCH_QUICK=1`) shrinks sizes
//! and iteration counts so CI can *execute* the bench binaries and keep
//! the JSON schema alive without paying full measurement cost.

use crate::metrics::Timer;
use crate::util::json::Json;
use crate::util::stats::{percentile, Welford};

#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub measure_iters: usize,
    /// Abort measurement early once this much wall time is spent.
    pub max_secs: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self { warmup_iters: 3, measure_iters: 15, max_secs: 20.0 }
    }
}

impl BenchConfig {
    /// Quick-run parameters for smoke mode.
    pub fn smoke() -> Self {
        Self { warmup_iters: 1, measure_iters: 3, max_secs: 5.0 }
    }

    /// Quick mode for CI-style smoke runs (`--smoke` argv flag or env
    /// `BENCH_QUICK=1`).
    pub fn from_env() -> Self {
        if smoke_mode() {
            Self::smoke()
        } else {
            Self::default()
        }
    }
}

/// True when the bench binary was invoked with `--smoke` (the CI smoke
/// step) or `BENCH_QUICK=1`: tiny sizes, few iterations — executes every
/// code path and the JSON emission without full measurement cost.
pub fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub median_secs: f64,
    pub mean_secs: f64,
    pub sem_secs: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn throughput_per_sec(&self, items: f64) -> f64 {
        items / self.median_secs
    }
}

/// Run a benchmark closure.
pub fn run(name: &str, cfg: BenchConfig, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let wall = Timer::start();
    let mut samples = Vec::with_capacity(cfg.measure_iters);
    let mut acc = Welford::new();
    for _ in 0..cfg.measure_iters {
        let t = Timer::start();
        f();
        let dt = t.elapsed_secs();
        samples.push(dt);
        acc.push(dt);
        if wall.elapsed_secs() > cfg.max_secs {
            break;
        }
    }
    let res = BenchResult {
        name: name.to_string(),
        median_secs: percentile(&samples, 50.0),
        mean_secs: acc.mean(),
        sem_secs: acc.sem(),
        iters: samples.len(),
    };
    println!(
        "{:<44} median {:>10.4} ms   mean {:>10.4} ± {:>7.4} ms   ({} iters)",
        res.name,
        res.median_secs * 1e3,
        res.mean_secs * 1e3,
        res.sem_secs * 1e3,
        res.iters
    );
    res
}

/// Where figure CSVs land (`results/` by default, override with
/// `UVEQFED_RESULTS_DIR`).
pub fn results_dir() -> std::path::PathBuf {
    std::env::var("UVEQFED_RESULTS_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("results"))
}

/// The machine-readable perf-baseline file (override with
/// `UVEQFED_BENCH_BASELINE`). Relative paths resolve against the bench
/// binary's working directory — the workspace root under `cargo bench`.
pub fn baseline_path() -> std::path::PathBuf {
    std::env::var("UVEQFED_BENCH_BASELINE")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("BENCH_baseline.json"))
}

#[derive(Debug, Clone)]
struct RecordEntry {
    name: String,
    median_secs: f64,
    mean_secs: f64,
    sem_secs: f64,
    iters: usize,
    items_per_sec: Option<f64>,
}

/// Collects [`BenchResult`]s and merges them into `BENCH_baseline.json`
/// as one labelled snapshot per `(label, bench)` pair.
///
/// Schema (`"schema": 1`, documented in DESIGN.md §Performance): the file
/// is `{"schema", "snapshots": [...]}`; each snapshot carries `label`
/// (env `UVEQFED_BENCH_LABEL`, default `"current"`), `bench` (the bench
/// target), `smoke`, `recorded_unix`, and `entries` — one object per
/// benchmark with `name`, `median_secs`, `mean_secs`, `sem_secs`,
/// `iters`, and optional `items_per_sec`. Re-running a bench under the
/// same label replaces only that `(label, bench)` snapshot, so a `pre` /
/// `post` perf comparison is two runs with different labels.
pub struct Recorder {
    bench: String,
    label: String,
    smoke: bool,
    entries: Vec<RecordEntry>,
}

impl Recorder {
    pub fn new(bench: &str) -> Self {
        let label =
            std::env::var("UVEQFED_BENCH_LABEL").unwrap_or_else(|_| "current".to_string());
        Self { bench: bench.to_string(), label, smoke: smoke_mode(), entries: Vec::new() }
    }

    /// Record one result.
    pub fn add(&mut self, r: &BenchResult) {
        self.push_entry(r, None);
    }

    /// Record one result plus a throughput figure derived from
    /// `items_per_iter` work items per timed iteration.
    pub fn add_with_items(&mut self, r: &BenchResult, items_per_iter: f64) {
        let t = r.throughput_per_sec(items_per_iter);
        self.push_entry(r, Some(t));
    }

    fn push_entry(&mut self, r: &BenchResult, items_per_sec: Option<f64>) {
        self.entries.push(RecordEntry {
            name: r.name.clone(),
            median_secs: r.median_secs,
            mean_secs: r.mean_secs,
            sem_secs: r.sem_secs,
            iters: r.iters,
            items_per_sec,
        });
    }

    /// Merge this snapshot into the baseline file and return its path.
    pub fn save(&self) -> crate::Result<std::path::PathBuf> {
        self.save_to(baseline_path())
    }

    /// [`Self::save`] against an explicit path (tests use this to stay
    /// hermetic — no process-global env mutation).
    fn save_to(&self, path: std::path::PathBuf) -> crate::Result<std::path::PathBuf> {
        let mut kept: Vec<Json> = Vec::new();
        // A smoke run must never clobber a real measurement under the
        // same (label, bench): smoke sizes/iteration counts are garbage
        // as a perf trajectory, and a careless `--smoke` rerun used to
        // silently poison the committed baseline (merge bug found while
        // writing the population procedure).
        let mut keep_existing = false;
        // Top-level fields other than schema/snapshots (e.g. a "note")
        // are preserved verbatim across merges.
        let mut extra: Vec<(String, Json)> = Vec::new();
        if let Ok(text) = std::fs::read_to_string(&path) {
            let doc = Json::parse(&text)
                .map_err(|e| e.wrap(format!("corrupt {}", path.display())))?;
            if let Json::Obj(fields) = &doc {
                for (k, v) in fields {
                    if k != "schema" && k != "snapshots" {
                        extra.push((k.clone(), v.clone()));
                    }
                }
            }
            if let Some(snaps) = doc.get("snapshots").and_then(Json::as_arr) {
                for s in snaps {
                    let same = s.get("label").and_then(Json::as_str)
                        == Some(self.label.as_str())
                        && s.get("bench").and_then(Json::as_str) == Some(self.bench.as_str());
                    if !same {
                        kept.push(s.clone());
                    } else if self.smoke
                        && s.get("smoke") != Some(&Json::Bool(true))
                    {
                        eprintln!(
                            "warning: not replacing real '{}'/'{}' baseline snapshot with a smoke run",
                            self.label, self.bench
                        );
                        kept.push(s.clone());
                        keep_existing = true;
                    }
                }
            }
        }
        let unix = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs() as f64)
            .unwrap_or(0.0);
        let mut snap = Json::obj();
        snap.push("label", Json::str(self.label.as_str()));
        snap.push("bench", Json::str(self.bench.as_str()));
        snap.push("smoke", Json::Bool(self.smoke));
        snap.push("recorded_unix", Json::num(unix));
        let mut arr = Vec::with_capacity(self.entries.len());
        for e in &self.entries {
            let mut o = Json::obj();
            o.push("name", Json::str(e.name.as_str()));
            o.push("median_secs", Json::num(e.median_secs));
            o.push("mean_secs", Json::num(e.mean_secs));
            o.push("sem_secs", Json::num(e.sem_secs));
            o.push("iters", Json::num(e.iters as f64));
            if let Some(t) = e.items_per_sec {
                o.push("items_per_sec", Json::num(t));
            }
            arr.push(o);
        }
        snap.push("entries", Json::Arr(arr));
        if !keep_existing {
            kept.push(snap);
        }
        let mut doc = Json::obj();
        doc.push("schema", Json::num(1.0));
        for (k, v) in extra {
            doc.push(&k, v);
        }
        doc.push("snapshots", Json::Arr(kept));
        // Crash-safe merge: write a sibling temp file, then rename over the
        // target — an interrupted bench run can't leave a truncated
        // baseline that poisons every later save.
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, doc.to_string() + "\n")?;
        std::fs::rename(&tmp, &path)?;
        Ok(path)
    }

    /// `save` + a one-line status print; failures warn instead of
    /// aborting the bench.
    pub fn save_or_warn(&self) {
        match self.save() {
            Ok(p) => println!("baseline snapshot '{}' -> {}", self.label, p.display()),
            Err(e) => eprintln!("warning: could not write bench baseline: {e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let cfg = BenchConfig { warmup_iters: 1, measure_iters: 5, max_secs: 5.0 };
        let r = run("noop-plus-sleep", cfg, || {
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        assert!(r.median_secs >= 0.001);
        assert!(r.iters >= 1);
        assert!(r.throughput_per_sec(100.0) > 0.0);
    }

    #[test]
    fn recorder_merges_snapshots_by_label_and_bench() {
        // Hermetic: saves through an explicit path — no env mutation (the
        // test harness is multi-threaded and setenv races are UB).
        let path = std::env::temp_dir()
            .join(format!("uveqfed-baseline-test-{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        // Pre-seed with an extra top-level field that merges must preserve.
        std::fs::write(&path, "{\"schema\":1,\"note\":\"keep me\",\"snapshots\":[]}")
            .unwrap();
        let res = BenchResult {
            name: "nearest/hex".into(),
            median_secs: 0.5,
            mean_secs: 0.5,
            sem_secs: 0.01,
            iters: 3,
        };
        let mut a = Recorder::new("lattice_micro");
        a.label = "pre".into();
        a.add_with_items(&res, 100.0);
        a.save_to(path.clone()).unwrap();
        let mut b = Recorder::new("lattice_micro");
        b.label = "post".into();
        b.add(&res);
        b.save_to(path.clone()).unwrap();
        // Re-saving an existing (label, bench) replaces, not duplicates.
        let mut c = Recorder::new("lattice_micro");
        c.label = "pre".into();
        c.add(&res);
        c.save_to(path.clone()).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("note").and_then(Json::as_str), Some("keep me"));
        let snaps = doc.get("snapshots").and_then(Json::as_arr).unwrap();
        assert_eq!(snaps.len(), 2, "one snapshot per (label, bench)");
        let pre = snaps
            .iter()
            .find(|s| s.get("label").and_then(Json::as_str) == Some("pre"))
            .unwrap();
        let entries = pre.get("entries").and_then(Json::as_arr).unwrap();
        assert_eq!(entries[0].get("name").and_then(Json::as_str), Some("nearest/hex"));
        assert_eq!(entries[0].get("median_secs").and_then(Json::as_num), Some(0.5));
        // The replacement dropped the throughput field of the first save.
        assert!(entries[0].get("items_per_sec").is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn smoke_run_cannot_clobber_a_real_snapshot() {
        let path = std::env::temp_dir()
            .join(format!("uveqfed-baseline-smoke-{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let res = BenchResult {
            name: "encode/uveqfed-l2/r2".into(),
            median_secs: 0.25,
            mean_secs: 0.25,
            sem_secs: 0.01,
            iters: 15,
        };
        // Real measurement lands first…
        let mut real = Recorder::new("codec_micro");
        real.label = "pre".into();
        real.smoke = false;
        real.add(&res);
        real.save_to(path.clone()).unwrap();
        // …then a smoke rerun under the same (label, bench) must NOT
        // replace it…
        let fast = BenchResult { median_secs: 1e-6, ..res.clone() };
        let mut smoke = Recorder::new("codec_micro");
        smoke.label = "pre".into();
        smoke.smoke = true;
        smoke.add(&fast);
        smoke.save_to(path.clone()).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let snaps = doc.get("snapshots").and_then(Json::as_arr).unwrap();
        assert_eq!(snaps.len(), 1);
        let entry = &snaps[0].get("entries").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(entry.get("median_secs").and_then(Json::as_num), Some(0.25));
        // …while smoke-over-smoke and real-over-anything still replace.
        let mut smoke2 = Recorder::new("lattice_micro");
        smoke2.label = "pre".into();
        smoke2.smoke = true;
        smoke2.add(&fast);
        smoke2.save_to(path.clone()).unwrap();
        let mut smoke3 = Recorder::new("lattice_micro");
        smoke3.label = "pre".into();
        smoke3.smoke = true;
        smoke3.add(&res);
        smoke3.save_to(path.clone()).unwrap();
        let mut real2 = Recorder::new("codec_micro");
        real2.label = "pre".into();
        real2.smoke = false;
        real2.add(&fast);
        real2.save_to(path.clone()).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let snaps = doc.get("snapshots").and_then(Json::as_arr).unwrap();
        assert_eq!(snaps.len(), 2, "one per bench");
        for s in snaps {
            let e = &s.get("entries").and_then(Json::as_arr).unwrap()[0];
            let want = if s.get("bench").and_then(Json::as_str) == Some("codec_micro") {
                1e-6 // real run replaced the real snapshot
            } else {
                0.25 // smoke replaced smoke
            };
            assert_eq!(e.get("median_secs").and_then(Json::as_num), Some(want));
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn max_secs_caps_iterations() {
        let cfg = BenchConfig { warmup_iters: 0, measure_iters: 1000, max_secs: 0.02 };
        let r = run("capped", cfg, || {
            std::thread::sleep(std::time::Duration::from_millis(5));
        });
        assert!(r.iters < 1000);
    }
}
