//! Generic-generator lattice with exact nearest-point search.
//!
//! Nearest-point search is NP-hard in general dimension, but for the small,
//! well-conditioned generators used here (L ≤ 4 in practice) Babai's
//! rounding followed by a bounded integer offset search is exact once the
//! search radius covers the basis' orthogonality defect. We compute a
//! conservative radius from `‖G‖·‖G⁻¹‖` at construction and verify
//! exactness against brute force in the test suite.

use super::{Lattice, Scratch};

#[derive(Debug, Clone)]
pub struct GenericLattice {
    dim: usize,
    /// Row-major `L×L` generator; lattice points are `G · l` with `l∈Z^L`
    /// (column-vector convention).
    g: Vec<f64>,
    /// Row-major inverse.
    g_inv: Vec<f64>,
    /// Reciprocals of the generator diagonal (diagonal fast path turns the
    /// per-coordinate division into a multiply).
    inv_diag: Vec<f64>,
    det_abs: f64,
    /// Offset search radius for exact NN (0 for diagonal generators,
    /// which decode by per-coordinate rounding).
    radius: i64,
    /// Diagonal fast path: per-coordinate rounding is exact.
    diagonal: bool,
    /// Flattened offset probe table, sorted by displacement norm: integer
    /// offsets (`n_offsets × L`) and their displacements `G·o`
    /// (`n_offsets × L`). Flat arrays keep the probe loop an indexed scan
    /// over contiguous memory (§Perf: no per-offset Vec chasing).
    offset_coords: Vec<i64>,
    offset_disps: Vec<f64>,
    name: &'static str,
    /// Cached second moment (computed lazily at construction via MC for
    /// dims > 1 unless a closed form applies).
    second_moment: f64,
    /// Row-major strictly-lower-triangular prediction coefficients for
    /// coordinate decorrelation: `pred_k = Σ_{j<k} a[k][j]·c_j` (empty for
    /// diagonal generators). Derived from Σ = G⁻¹·G⁻ᵀ, the coordinate
    /// covariance under white input.
    predictor: Vec<f64>,
}

fn mat_vec(a: &[f64], x: &[f64], n: usize) -> Vec<f64> {
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = 0.0;
        for j in 0..n {
            s += a[i * n + j] * x[j];
        }
        y[i] = s;
    }
    y
}

/// Gauss-Jordan inverse + determinant for small matrices.
fn invert(a: &[f64], n: usize) -> (Vec<f64>, f64) {
    let mut m = a.to_vec();
    let mut inv = vec![0.0; n * n];
    for i in 0..n {
        inv[i * n + i] = 1.0;
    }
    let mut det = 1.0;
    for col in 0..n {
        // partial pivot
        let mut piv = col;
        for r in (col + 1)..n {
            if m[r * n + col].abs() > m[piv * n + col].abs() {
                piv = r;
            }
        }
        assert!(m[piv * n + col].abs() > 1e-12, "singular generator matrix");
        if piv != col {
            for j in 0..n {
                m.swap(col * n + j, piv * n + j);
                inv.swap(col * n + j, piv * n + j);
            }
            det = -det;
        }
        let p = m[col * n + col];
        det *= p;
        for j in 0..n {
            m[col * n + j] /= p;
            inv[col * n + j] /= p;
        }
        for r in 0..n {
            if r != col {
                let f = m[r * n + col];
                if f != 0.0 {
                    for j in 0..n {
                        m[r * n + j] -= f * m[col * n + j];
                        inv[r * n + j] -= f * inv[col * n + j];
                    }
                }
            }
        }
    }
    (inv, det)
}

fn frobenius(a: &[f64]) -> f64 {
    a.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Sequential linear-MMSE predictor coefficients from the coordinate
/// covariance Σ = G⁻¹G⁻ᵀ (white input): `a_k = Σ_{<k,<k}⁻¹ Σ_{<k,k}`,
/// returned row-major strictly lower triangular. Shared by every lattice
/// that exposes coordinate decorrelation (generic, D_n, E8).
pub(crate) fn predictor_from_ginv(g_inv: &[f64], dim: usize) -> Vec<f64> {
    let mut sigma = vec![0.0; dim * dim];
    for i in 0..dim {
        for j in 0..dim {
            let mut s = 0.0;
            for t in 0..dim {
                s += g_inv[i * dim + t] * g_inv[j * dim + t];
            }
            sigma[i * dim + j] = s;
        }
    }
    let mut a = vec![0.0; dim * dim];
    for k in 1..dim {
        let mut sub = vec![0.0; k * k];
        for i in 0..k {
            for j in 0..k {
                sub[i * k + j] = sigma[i * dim + j];
            }
        }
        let (sub_inv, _) = invert(&sub, k);
        for i in 0..k {
            let mut s = 0.0;
            for j in 0..k {
                s += sub_inv[i * k + j] * sigma[j * dim + k];
            }
            a[k * dim + i] = s;
        }
    }
    a
}

/// Apply residual prediction back-to-front (shared decorrelate impl).
pub(crate) fn apply_decorrelate(pred: &[f64], c: &mut [i64], n: usize) {
    if pred.is_empty() {
        return;
    }
    for k in (1..n).rev() {
        let mut p = 0.0;
        for j in 0..k {
            p += pred[k * n + j] * c[j] as f64;
        }
        c[k] -= p.round() as i64;
    }
}

/// Inverse of [`apply_decorrelate`].
pub(crate) fn apply_recorrelate(pred: &[f64], c: &mut [i64], n: usize) {
    if pred.is_empty() {
        return;
    }
    for k in 1..n {
        let mut p = 0.0;
        for j in 0..k {
            p += pred[k * n + j] * c[j] as f64;
        }
        c[k] += p.round() as i64;
    }
}

impl GenericLattice {
    pub fn new(dim: usize, g_row_major: &[f64], name: &'static str) -> Self {
        assert_eq!(g_row_major.len(), dim * dim);
        let (g_inv, det) = invert(g_row_major, dim);
        let diagonal = (0..dim)
            .all(|i| (0..dim).all(|j| i == j || g_row_major[i * dim + j] == 0.0));
        // Conservative exactness radius: the Babai error in coordinate space
        // is bounded by ‖G⁻¹‖·(covering radius) and the covering radius by
        // (√L/2)·‖G‖ (diagonal of a fundamental box). Round up, clamp to a
        // sane maximum (search cost is (2r+1)^L). Diagonal generators skip
        // the search entirely (rounding is exact); non-diagonal generic
        // lattices are only supported in low dimension — higher-dimensional
        // structured lattices (D4/E8) have dedicated O(L) decoders.
        let radius = if diagonal {
            0
        } else {
            assert!(
                dim <= 4,
                "GenericLattice offset search is exponential in dim; use DnLattice/E8Lattice"
            );
            let cond = frobenius(g_row_major) * frobenius(&g_inv);
            ((cond * (dim as f64).sqrt() / 2.0).ceil() as i64).clamp(1, 2)
        };
        let predictor =
            if diagonal { Vec::new() } else { predictor_from_ginv(&g_inv, dim) };
        let inv_diag = if diagonal {
            (0..dim).map(|i| 1.0 / g_row_major[i * dim + i]).collect()
        } else {
            Vec::new()
        };
        let mut lat = Self {
            dim,
            g: g_row_major.to_vec(),
            g_inv,
            inv_diag,
            det_abs: det.abs(),
            radius,
            diagonal,
            offset_coords: Vec::new(),
            offset_disps: Vec::new(),
            name,
            second_moment: f64::NAN,
            predictor,
        };
        if !diagonal {
            lat.build_offsets();
        }
        lat.second_moment = if dim == 1 {
            // Δ·Z: cell is [−Δ/2, Δ/2), σ̄² = Δ²/12.
            lat.det_abs * lat.det_abs / 12.0
        } else if lat.is_diagonal() {
            // Δ·Z^L cube: σ̄² = L·Δ²/12 (Δ read off the diagonal; supports
            // unequal diagonals too).
            (0..dim).map(|i| lat.g[i * dim + i].powi(2) / 12.0).sum()
        } else {
            super::moment::monte_carlo_second_moment(&lat, 400_000, 0xD17E_5EED)
        };
        lat
    }

    fn is_diagonal(&self) -> bool {
        let n = self.dim;
        (0..n).all(|i| (0..n).all(|j| i == j || self.g[i * n + j] == 0.0))
    }

    fn build_offsets(&mut self) {
        let n = self.dim;
        let r = self.radius;
        let width = (2 * r + 1) as usize;
        let total = width.pow(n as u32);
        let mut table: Vec<(Vec<i64>, Vec<f64>)> = Vec::with_capacity(total);
        for idx in 0..total {
            let mut rem = idx;
            let mut o = vec![0i64; n];
            for d in 0..n {
                o[d] = (rem % width) as i64 - r;
                rem /= width;
            }
            let disp = {
                let of: Vec<f64> = o.iter().map(|&v| v as f64).collect();
                mat_vec(&self.g, &of, n)
            };
            table.push((o, disp));
        }
        // Sort by displacement norm so the common case (offset 0) is tried
        // first and the scan can early-exit in the squared-distance compare.
        table.sort_by(|a, b| {
            let na: f64 = a.1.iter().map(|x| x * x).sum();
            let nb: f64 = b.1.iter().map(|x| x * x).sum();
            na.partial_cmp(&nb).unwrap()
        });
        self.offset_coords = Vec::with_capacity(total * n);
        self.offset_disps = Vec::with_capacity(total * n);
        for (o, disp) in table {
            self.offset_coords.extend_from_slice(&o);
            self.offset_disps.extend_from_slice(&disp);
        }
    }

    /// Shared nearest-point core (scalar and batch paths both run exactly
    /// this code, so they are bit-identical by construction).
    #[inline]
    fn nearest_core(&self, x: &[f64], out: &mut [i64]) {
        let n = self.dim;
        if self.diagonal {
            // Per-coordinate rounding is exact for Δ·Z^L. Saturating cast
            // guards non-finite / extreme inputs.
            for i in 0..n {
                let v = x[i] * self.inv_diag[i];
                out[i] = if v.is_finite() { v.round() as i64 } else { 0 };
            }
            return;
        }
        // Babai rounding + residual, stack-allocated up to dim 4 (generic
        // non-diagonal lattices are constructor-capped at dim ≤ 4).
        let mut base = [0i64; 4];
        let mut res = [0.0f64; 4];
        for i in 0..n {
            let mut s = 0.0;
            for j in 0..n {
                s += self.g_inv[i * n + j] * x[j];
            }
            base[i] = if s.is_finite() { s.round() as i64 } else { 0 };
        }
        for i in 0..n {
            let mut p = 0.0;
            for j in 0..n {
                p += self.g[i * n + j] * base[j] as f64;
            }
            res[i] = x[i] - p;
        }
        let n_off = self.offset_disps.len() / n;
        let mut best_d = f64::INFINITY;
        let mut best_idx = 0usize;
        for k in 0..n_off {
            let disp = &self.offset_disps[k * n..k * n + n];
            let mut d = 0.0;
            for i in 0..n {
                let t = res[i] - disp[i];
                d += t * t;
                if d >= best_d {
                    break;
                }
            }
            if d < best_d {
                best_d = d;
                best_idx = k;
            }
        }
        let o = &self.offset_coords[best_idx * n..best_idx * n + n];
        for i in 0..n {
            out[i] = base[i] + o[i];
        }
    }

    /// Return the same lattice scaled by `s` (`s·Λ`).
    pub fn scaled(&self, s: f64) -> GenericLattice {
        assert!(s > 0.0);
        let g: Vec<f64> = self.g.iter().map(|x| x * s).collect();
        let mut lat = GenericLattice::new(self.dim, &g, self.name);
        // σ̄² scales as s²; reuse the (possibly MC) base value for exact
        // consistency between a lattice and its scalings.
        lat.second_moment = self.second_moment * s * s;
        lat
    }

    /// Babai rounding: `round(G⁻¹ x)` (kept for the brute-force tests).
    #[cfg(test)]
    fn babai(&self, x: &[f64]) -> Vec<i64> {
        mat_vec(&self.g_inv, x, self.dim)
            .into_iter()
            .map(|v| if v.is_finite() { v.round() as i64 } else { 0 })
            .collect()
    }
}

impl Lattice for GenericLattice {
    fn dim(&self) -> usize {
        self.dim
    }

    fn nearest_into(&self, x: &[f64], out: &mut [i64]) {
        debug_assert_eq!(x.len(), self.dim);
        self.nearest_core(x, out);
    }

    fn nearest_batch_into(&self, xs: &[f64], out: &mut [i64], _scratch: &mut Scratch) {
        let l = self.dim;
        debug_assert_eq!(xs.len() % l, 0);
        debug_assert_eq!(xs.len(), out.len());
        if self.diagonal && l == 1 {
            // Scalar lattice Δ·Z: a straight vectorizable loop.
            let inv = self.inv_diag[0];
            for (x, o) in xs.iter().zip(out.iter_mut()) {
                let v = x * inv;
                *o = if v.is_finite() { v.round() as i64 } else { 0 };
            }
            return;
        }
        for (x, o) in xs.chunks_exact(l).zip(out.chunks_exact_mut(l)) {
            self.nearest_core(x, o);
        }
    }

    fn point_into(&self, coords: &[i64], out: &mut [f64]) {
        let n = self.dim;
        debug_assert_eq!(coords.len(), n);
        debug_assert_eq!(out.len(), n);
        for i in 0..n {
            let mut s = 0.0;
            for j in 0..n {
                s += self.g[i * n + j] * coords[j] as f64;
            }
            out[i] = s;
        }
    }

    fn quantize_batch_into(&self, xs: &[f64], out: &mut [f64], _scratch: &mut Scratch) {
        let l = self.dim;
        debug_assert_eq!(xs.len() % l, 0);
        debug_assert_eq!(xs.len(), out.len());
        if self.diagonal {
            // Q(x) = round(x/Δ)·Δ per coordinate, any dimension. Routed
            // through the same i64 cast as `nearest_core` so extreme inputs
            // saturate identically on both paths. l == 1 (the scalar
            // lattice — every UVeQFed-L1 encode and dither fold) gets the
            // straight-line vectorizable loop.
            if l == 1 {
                let inv = self.inv_diag[0];
                let d = self.g[0];
                for (x, o) in xs.iter().zip(out.iter_mut()) {
                    let v = x * inv;
                    let c = if v.is_finite() { v.round() as i64 } else { 0 };
                    *o = c as f64 * d;
                }
            } else {
                for (xb, ob) in xs.chunks_exact(l).zip(out.chunks_exact_mut(l)) {
                    for j in 0..l {
                        let v = xb[j] * self.inv_diag[j];
                        let c = if v.is_finite() { v.round() as i64 } else { 0 };
                        ob[j] = c as f64 * self.g[j * l + j];
                    }
                }
            }
            return;
        }
        // Non-diagonal generators are constructor-capped at dim ≤ 4, so the
        // stack block below always fits (same invariant as `nearest_core`).
        debug_assert!(l <= 4);
        let mut c = [0i64; 4];
        for (x, o) in xs.chunks_exact(l).zip(out.chunks_exact_mut(l)) {
            self.nearest_core(x, &mut c[..l]);
            self.point_into(&c[..l], o);
        }
    }

    fn coords_real_into(&self, x: &[f64], out: &mut [f64]) {
        let n = self.dim;
        debug_assert_eq!(x.len(), n);
        debug_assert_eq!(out.len(), n);
        if self.diagonal {
            for i in 0..n {
                out[i] = x[i] * self.inv_diag[i];
            }
            return;
        }
        for i in 0..n {
            let mut s = 0.0;
            for j in 0..n {
                s += self.g_inv[i * n + j] * x[j];
            }
            out[i] = s;
        }
    }

    fn cell_volume(&self) -> f64 {
        self.det_abs
    }

    fn second_moment(&self) -> f64 {
        self.second_moment
    }

    fn generator(&self) -> &[f64] {
        &self.g
    }

    fn name(&self) -> String {
        self.name.to_string()
    }

    fn boxed_scaled(&self, s: f64) -> Box<dyn Lattice> {
        Box::new(self.scaled(s))
    }

    fn decorrelate(&self, c: &mut [i64]) {
        apply_decorrelate(&self.predictor, c, self.dim);
    }

    fn recorrelate(&self, c: &mut [i64]) {
        apply_recorrelate(&self.predictor, c, self.dim);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Rng, Xoshiro256pp};

    /// Brute-force NN over a generous coordinate window.
    fn brute_nearest(lat: &GenericLattice, x: &[f64], w: i64) -> Vec<i64> {
        let base = lat.babai(x);
        let n = lat.dim();
        let mut best = base.clone();
        let mut best_d = f64::INFINITY;
        let width = (2 * w + 1) as usize;
        for idx in 0..width.pow(n as u32) {
            let mut rem = idx;
            let mut c = base.clone();
            for d in 0..n {
                c[d] += (rem % width) as i64 - w;
                rem /= width;
            }
            let p = lat.point(&c);
            let d: f64 = x.iter().zip(&p).map(|(a, b)| (a - b) * (a - b)).sum();
            if d < best_d {
                best_d = d;
                best = c;
            }
        }
        best
    }

    #[test]
    fn nearest_matches_bruteforce_hex() {
        let lat = super::super::paper_hexagonal();
        let mut rng = Xoshiro256pp::seed_from_u64(21);
        for _ in 0..2000 {
            let x = [rng.uniform_range(-8.0, 8.0), rng.uniform_range(-8.0, 8.0)];
            let fast = lat.nearest(&x);
            let brute = brute_nearest(&lat, &x, 4);
            let pf = lat.point(&fast);
            let pb = lat.point(&brute);
            let df: f64 = x.iter().zip(&pf).map(|(a, b)| (a - b) * (a - b)).sum();
            let db: f64 = x.iter().zip(&pb).map(|(a, b)| (a - b) * (a - b)).sum();
            assert!(df <= db + 1e-12, "x={x:?} fast={fast:?} brute={brute:?}");
        }
    }

    #[test]
    fn nearest_matches_bruteforce_a2_scaled() {
        let lat = super::super::a2_hexagonal().scaled(0.37);
        let mut rng = Xoshiro256pp::seed_from_u64(22);
        for _ in 0..1000 {
            let x = [rng.uniform_range(-2.0, 2.0), rng.uniform_range(-2.0, 2.0)];
            let fast = lat.quantize(&x);
            let brute = lat.point(&brute_nearest(&lat, &x, 4));
            let df: f64 = x.iter().zip(&fast).map(|(a, b)| (a - b) * (a - b)).sum();
            let db: f64 = x.iter().zip(&brute).map(|(a, b)| (a - b) * (a - b)).sum();
            assert!(df <= db + 1e-12);
        }
    }

    #[test]
    fn scaled_lattice_scales_everything() {
        let base = super::super::paper_hexagonal();
        let s = base.scaled(2.5);
        assert!((s.cell_volume() - base.cell_volume() * 2.5 * 2.5).abs() < 1e-9);
        assert!(
            (s.second_moment() - base.second_moment() * 2.5 * 2.5).abs()
                / s.second_moment()
                < 1e-9
        );
        let p = s.point(&[1, -2]);
        let pb = base.point(&[1, -2]);
        assert!((p[0] - 2.5 * pb[0]).abs() < 1e-12);
        assert!((p[1] - 2.5 * pb[1]).abs() < 1e-12);
    }

    #[test]
    fn cubic_second_moment_closed_form() {
        let lat = super::super::cubic(3, 0.8);
        // σ̄² = L·Δ²/12 = 3·0.64/12 = 0.16
        assert!((lat.second_moment() - 0.16).abs() < 1e-12);
    }

    #[test]
    fn hex_second_moment_matches_known_constant() {
        // For any 2-D lattice, the dimensionless normalized second moment
        // is G(Λ) = σ̄²/(L·V). A2 hexagonal: G = 5/(36√3) ≈ 0.0801875.
        let lat = super::super::a2_hexagonal();
        let g = lat.second_moment() / (2.0 * lat.cell_volume());
        assert!((g - 5.0 / (36.0 * 3f64.sqrt())).abs() < 2e-3, "G={g}");
    }

    #[test]
    fn tie_breaking_is_deterministic() {
        let lat = super::super::scalar(1.0);
        let a = lat.nearest(&[0.5]);
        let b = lat.nearest(&[0.5]);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn singular_generator_rejected() {
        let _ = GenericLattice::new(2, &[1.0, 2.0, 2.0, 4.0], "bad");
    }
}
