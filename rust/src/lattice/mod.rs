//! Lattice quantization substrate (UVeQFed steps **E2–E3 / D2**).
//!
//! A lattice `Λ = {G·l : l ∈ Z^L}` induces the quantizer `Q_Λ(x)` mapping
//! `x` to its nearest lattice point, with Voronoi basic cell `P₀` (eq. (7)
//! in the paper). This module provides:
//!
//! * [`Lattice`] — the quantizer interface: exact nearest-point search,
//!   coordinate↔point maps, cell volume and the *normalized second moment*
//!   `σ̄²_Λ = ∫_{P₀}‖x‖²dx / ∫_{P₀}dx` (the constant in Theorems 1–3);
//! * [`GenericLattice`] — arbitrary generator matrix `G` (any `L`), exact
//!   NN via Babai rounding + bounded offset search (radius chosen from the
//!   basis conditioning, verified against brute force in tests). Covers the
//!   paper's scalar lattice `G = 1` and hexagonal `G = [2,0;1,1/√3]`;
//! * [`DnLattice`] / [`E8Lattice`] — the classic low-dimensional packings
//!   with O(L) closed-form decoders (extension beyond the paper's L ≤ 2,
//!   used in the ablation benches);
//! * [`dither`] — `Unif(P₀)` sampling via the mod-Λ fold of a uniform
//!   sample on the fundamental parallelepiped (exact for every lattice).
//!
//! All scales are explicit: `scaled(s)` returns the lattice `s·Λ`, which is
//! what the rate controller tunes to hit the bit budget.

mod generic;
mod dn;
mod e8;
pub mod dither;
pub mod moment;

pub use dn::DnLattice;
pub use e8::E8Lattice;
pub use generic::GenericLattice;

/// Caller-owned scratch for the batched, allocation-free lattice kernels
/// (`nearest_batch_into` / `quantize_batch_into` and the dither fill).
///
/// Buffers grow on first use and are reused afterwards; a `Scratch` may be
/// shared across lattices and batch sizes. Sessions own one `Scratch` per
/// encoder/decoder so steady-state hot-path calls perform zero heap
/// allocation (see DESIGN.md §Performance).
#[derive(Debug, Default, Clone)]
pub struct Scratch {
    /// f64 temp A (dither uniforms, per-block points).
    pub(crate) f1: Vec<f64>,
    /// f64 temp B (batch quantize output inside the dither fold).
    pub(crate) f2: Vec<f64>,
    /// i64 temp (batch coordinates inside default `quantize_batch_into`).
    pub(crate) i1: Vec<i64>,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// A (full-rank) lattice in `R^L` together with its nearest-point decoder.
pub trait Lattice: Send + Sync {
    /// Lattice dimension `L`.
    fn dim(&self) -> usize;

    /// Nearest-point integer coordinates: the `l ∈ Z^L` minimizing
    /// `‖x − G·l‖`. Ties broken deterministically.
    fn nearest(&self, x: &[f64]) -> Vec<i64> {
        let mut out = vec![0i64; self.dim()];
        self.nearest_into(x, &mut out);
        out
    }

    /// Allocation-free nearest-point search for a single `L`-dim block.
    /// The batched entry point [`Lattice::nearest_batch_into`] is the hot
    /// path; this remains as the single-block adapter.
    fn nearest_into(&self, x: &[f64], out: &mut [i64]);

    /// Batched nearest-point search over `xs.len()/L` contiguous blocks:
    /// writes integer coordinates for block `i` into `out[i*L..(i+1)*L]`.
    /// Must be bit-identical to per-block [`Lattice::nearest_into`]
    /// (property-tested); implementations hoist per-call setup out of the
    /// block loop and perform no heap allocation beyond `scratch` growth.
    fn nearest_batch_into(&self, xs: &[f64], out: &mut [i64], scratch: &mut Scratch) {
        let l = self.dim();
        debug_assert_eq!(xs.len() % l, 0, "batch length must be a multiple of L");
        debug_assert_eq!(xs.len(), out.len());
        let _ = scratch;
        for (x, o) in xs.chunks_exact(l).zip(out.chunks_exact_mut(l)) {
            self.nearest_into(x, o);
        }
    }

    /// Map integer coordinates to the lattice point `G·l`.
    fn point(&self, coords: &[i64]) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        self.point_into(coords, &mut out);
        out
    }

    /// Allocation-free `G·l` (decode hot path: one call per sub-vector).
    fn point_into(&self, coords: &[i64], out: &mut [f64]);

    /// `Q_Λ(x)` — the nearest lattice point itself.
    fn quantize(&self, x: &[f64]) -> Vec<f64> {
        self.point(&self.nearest(x))
    }

    /// Batched `Q_Λ` over contiguous blocks, allocation-free given
    /// `scratch`. Bit-identical to per-block [`Lattice::quantize`].
    fn quantize_batch_into(&self, xs: &[f64], out: &mut [f64], scratch: &mut Scratch) {
        let l = self.dim();
        debug_assert_eq!(xs.len() % l, 0);
        debug_assert_eq!(xs.len(), out.len());
        let mut coords = std::mem::take(&mut scratch.i1);
        coords.clear();
        coords.resize(xs.len(), 0);
        self.nearest_batch_into(xs, &mut coords, scratch);
        for (c, o) in coords.chunks_exact(l).zip(out.chunks_exact_mut(l)) {
            self.point_into(c, o);
        }
        scratch.i1 = coords;
    }

    /// Real-valued (Babai) coordinates `G⁻¹·x` of an ambient point —
    /// the cached quantity behind the encoder's single-pass scale search
    /// (rounding these approximates `nearest` and is exact for diagonal
    /// generators).
    fn coords_real_into(&self, x: &[f64], out: &mut [f64]);

    /// Volume of the basic cell, `|det G|`.
    fn cell_volume(&self) -> f64;

    /// Normalized second moment `σ̄²_Λ = E‖U‖²` for `U ~ Unif(P₀)` — the
    /// *unnormalized-per-dimension* version used by the paper's theorems.
    /// Implementations use exact closed forms where known and the
    /// deterministic Monte-Carlo estimator in [`moment`] otherwise.
    fn second_moment(&self) -> f64;

    /// Borrowed row-major generator matrix (`L×L`) — the allocation-free
    /// accessor the dither fill and batch kernels use.
    fn generator(&self) -> &[f64];

    /// The generator matrix in row-major order (`L×L`), for logging and
    /// for shipping to the Pallas kernel.
    fn generator_row_major(&self) -> Vec<f64> {
        self.generator().to_vec()
    }

    /// Short name for configs and logs.
    fn name(&self) -> String;

    /// The lattice scaled by `s` (`s·Λ`), boxed — what the rate controller
    /// tunes. Implementations must scale `second_moment` by `s²` *exactly*
    /// (no re-estimation) so the controller's search is monotone.
    fn boxed_scaled(&self, s: f64) -> Box<dyn Lattice>;

    /// Bijective integer decorrelation of a coordinate block (len = dim):
    /// replaces `c_k` by the residual against a rounded linear prediction
    /// from `c_1..c_{k−1}`. For non-orthogonal generators the coordinates
    /// `l = G⁻¹y` of i.i.d. inputs are correlated; coding residuals
    /// instead recovers the mutual information an order-0 entropy coder
    /// would otherwise waste. Default: identity (orthogonal generators).
    fn decorrelate(&self, _c: &mut [i64]) {}

    /// Inverse of [`Lattice::decorrelate`].
    fn recorrelate(&self, _c: &mut [i64]) {}
}

/// The paper's hexagonal lattice, `G = [2, 0; 1, 1/√3]` in §V-A's MATLAB
/// row-basis notation (basis (2,0), (1,1/√3) — a scaled hexagonal
/// lattice; reading the matrix column-wise instead gives a skewed lattice
/// with σ̄² ≈ 0.361, twice the hexagonal 0.185, which cannot be what the
/// paper benchmarked).
///
/// We generate the *same lattice* through its Lagrange-reduced basis
/// (1, 1/√3), (1, −1/√3) — a unimodular change of coordinates. Reduction
/// matters operationally: integer coordinates w.r.t. the reduced basis
/// have equal, minimal variances and mild correlation, which the order-0
/// entropy coder exploits (the unreduced coordinates cost ≈0.4 more
/// bits/sub-vector at equal distortion).
pub fn paper_hexagonal() -> GenericLattice {
    let s3 = 1.0 / 3f64.sqrt();
    GenericLattice::new(2, &[1.0, 1.0, s3, -s3], "hex-paper")
}

/// The canonical A2 hexagonal lattice (unit packing radius variant), used
/// in ablations: `G = [1, 1/2; 0, √3/2]`.
pub fn a2_hexagonal() -> GenericLattice {
    GenericLattice::new(2, &[1.0, 0.5, 0.0, 3f64.sqrt() / 2.0], "hex-a2")
}

/// Scalar lattice `Δ·Z` (the L=1 configuration; equals uniform scalar
/// quantization with step Δ).
pub fn scalar(delta: f64) -> GenericLattice {
    GenericLattice::new(1, &[delta], "scalar")
}

/// Cubic lattice `Δ·Z^L`.
pub fn cubic(dim: usize, delta: f64) -> GenericLattice {
    let mut g = vec![0.0; dim * dim];
    for i in 0..dim {
        g[i * dim + i] = delta;
    }
    GenericLattice::new(dim, &g, "cubic")
}

/// Construct a lattice by config name. Scale 1.0; callers apply
/// `GenericLattice::scaled` / codec-level scaling afterwards. Unknown
/// names are an error listing the valid lattices, not a panic.
pub fn by_name(name: &str) -> crate::Result<Box<dyn Lattice>> {
    Ok(match name {
        "scalar" => Box::new(scalar(1.0)),
        "hex" | "hex-paper" => Box::new(paper_hexagonal()),
        "hex-a2" => Box::new(a2_hexagonal()),
        "cubic2" => Box::new(cubic(2, 1.0)),
        "cubic4" => Box::new(cubic(4, 1.0)),
        "d4" => Box::new(DnLattice::new(4, 1.0)),
        "e8" => Box::new(E8Lattice::new(1.0)),
        other => crate::bail!(
            "unknown lattice '{other}' (valid: scalar, hex, hex-a2, cubic2, cubic4, d4, e8)"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_lattice_is_uniform_quantizer() {
        let lat = scalar(0.5);
        assert_eq!(lat.dim(), 1);
        assert_eq!(lat.nearest(&[0.74]), vec![1]); // 0.74/0.5 = 1.48 → 1
        assert_eq!(lat.nearest(&[0.76]), vec![2]);
        assert_eq!(lat.quantize(&[-0.74]), vec![-0.5]);
        assert!((lat.cell_volume() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn paper_hex_det() {
        let lat = paper_hexagonal();
        // det [2,0;1,1/√3] = 2/√3
        assert!((lat.cell_volume() - 2.0 / 3f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn quantize_lattice_points_is_identity() {
        let lat = paper_hexagonal();
        for l in [[0i64, 0], [1, 0], [0, 1], [-3, 2], [5, -4]] {
            let p = lat.point(&l);
            assert_eq!(lat.nearest(&p), l.to_vec(), "point {p:?}");
        }
    }

    #[test]
    fn by_name_constructs_all() {
        for n in ["scalar", "hex", "hex-a2", "cubic2", "cubic4", "d4", "e8"] {
            let lat = by_name(n).unwrap();
            let z = vec![0.3; lat.dim()];
            let q = lat.quantize(&z);
            assert_eq!(q.len(), lat.dim());
        }
    }

    #[test]
    fn by_name_unknown_is_an_error() {
        let err = by_name("nope").unwrap_err().to_string();
        assert!(err.contains("unknown lattice 'nope'"), "{err}");
        assert!(err.contains("e8"), "{err}");
    }
}
