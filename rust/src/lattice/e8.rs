//! The Gosset lattice `E8 = D8 ∪ (D8 + ½·1)` with the exact two-coset
//! decoder (Conway & Sloane SPLAG §20.3): decode in both cosets, keep the
//! closer. E8 has the best quantization constant of any known dimension-8
//! lattice (G ≈ 0.0717) — used by the ablation benches to show the paper's
//! "higher-dimensional lattices quantize better" claim keeps paying beyond
//! L = 2.

use super::{Lattice, Scratch};
use std::sync::OnceLock;

#[derive(Debug, Clone)]
pub struct E8Lattice {
    scale: f64,
    g: Vec<f64>,
    g_inv: Vec<f64>,
    base_moment: f64,
    /// Coordinate decorrelation predictor (see `generic::predictor_from_ginv`).
    predictor: Vec<f64>,
}

fn base_moment() -> f64 {
    static M: OnceLock<f64> = OnceLock::new();
    *M.get_or_init(|| {
        let probe = E8Lattice::new_unmeasured(1.0);
        super::moment::monte_carlo_second_moment(&probe, 400_000, 0xE8E8_0001)
    })
}

/// Nearest D8 point to `x` (unit scale), stack-only.
#[inline]
fn decode_d8(x: &[f64; 8]) -> [f64; 8] {
    let mut r = [0.0f64; 8];
    let mut sum = 0i64;
    let (mut worst, mut err) = (0usize, -1.0f64);
    for i in 0..8 {
        let v = x[i];
        let ri = v.round();
        sum += ri as i64;
        let e = (v - ri).abs();
        if e > err {
            err = e;
            worst = i;
        }
        r[i] = ri;
    }
    if sum.rem_euclid(2) != 0 {
        let v = x[worst];
        let ri = r[worst];
        r[worst] = if v >= ri { ri + 1.0 } else { ri - 1.0 };
    }
    r
}

impl E8Lattice {
    fn generator() -> Vec<f64> {
        // Standard E8 basis rows; stored transposed (columns = basis).
        let rows: [[f64; 8]; 8] = [
            [2., 0., 0., 0., 0., 0., 0., 0.],
            [-1., 1., 0., 0., 0., 0., 0., 0.],
            [0., -1., 1., 0., 0., 0., 0., 0.],
            [0., 0., -1., 1., 0., 0., 0., 0.],
            [0., 0., 0., -1., 1., 0., 0., 0.],
            [0., 0., 0., 0., -1., 1., 0., 0.],
            [0., 0., 0., 0., 0., -1., 1., 0.],
            [0.5, 0.5, 0.5, 0.5, 0.5, 0.5, 0.5, 0.5],
        ];
        let mut g = vec![0.0; 64];
        for (i, row) in rows.iter().enumerate() {
            for j in 0..8 {
                g[j * 8 + i] = row[j];
            }
        }
        g
    }

    fn new_unmeasured(scale: f64) -> Self {
        let mut g = Self::generator();
        for v in g.iter_mut() {
            *v *= scale;
        }
        let (g_inv, _) = invert(&g, 8);
        let predictor = super::generic::predictor_from_ginv(&g_inv, 8);
        Self { scale, g, g_inv, base_moment: f64::NAN, predictor }
    }

    pub fn new(scale: f64) -> Self {
        let mut lat = Self::new_unmeasured(scale);
        lat.base_moment = base_moment();
        lat
    }

    /// Exact two-coset decode written into `out` — stack-only shared core
    /// behind the scalar and batched paths (bit-identical by construction).
    fn decode_point_into(&self, x: &[f64], out: &mut [f64]) {
        debug_assert_eq!(x.len(), 8);
        debug_assert_eq!(out.len(), 8);
        let inv_s = 1.0 / self.scale;
        let mut xs = [0.0f64; 8];
        for i in 0..8 {
            xs[i] = x[i] * inv_s;
        }
        // Coset 0: D8.
        let a = decode_d8(&xs);
        // Coset ½: decode (x − ½) in D8, add ½ back.
        let mut shifted = [0.0f64; 8];
        for i in 0..8 {
            shifted[i] = xs[i] - 0.5;
        }
        let mut b = decode_d8(&shifted);
        for v in b.iter_mut() {
            *v += 0.5;
        }
        let mut da = 0.0;
        let mut db = 0.0;
        for i in 0..8 {
            da += (xs[i] - a[i]) * (xs[i] - a[i]);
            db += (xs[i] - b[i]) * (xs[i] - b[i]);
        }
        let best = if da <= db { &a } else { &b };
        for i in 0..8 {
            out[i] = best[i] * self.scale;
        }
    }

    /// Integer coordinates `l = G⁻¹p` of an ambient lattice point.
    #[inline]
    fn coords_of_point(&self, p: &[f64], out: &mut [i64]) {
        for i in 0..8 {
            let mut s = 0.0;
            for j in 0..8 {
                s += self.g_inv[i * 8 + j] * p[j];
            }
            out[i] = s.round() as i64;
        }
    }
}

fn invert(a: &[f64], n: usize) -> (Vec<f64>, f64) {
    let mut m = a.to_vec();
    let mut inv = vec![0.0; n * n];
    for i in 0..n {
        inv[i * n + i] = 1.0;
    }
    let mut det = 1.0;
    for col in 0..n {
        let mut piv = col;
        for r in (col + 1)..n {
            if m[r * n + col].abs() > m[piv * n + col].abs() {
                piv = r;
            }
        }
        assert!(m[piv * n + col].abs() > 1e-12, "singular");
        if piv != col {
            for j in 0..n {
                m.swap(col * n + j, piv * n + j);
                inv.swap(col * n + j, piv * n + j);
            }
            det = -det;
        }
        let p = m[col * n + col];
        det *= p;
        for j in 0..n {
            m[col * n + j] /= p;
            inv[col * n + j] /= p;
        }
        for r in 0..n {
            if r != col {
                let f = m[r * n + col];
                if f != 0.0 {
                    for j in 0..n {
                        m[r * n + j] -= f * m[col * n + j];
                        inv[r * n + j] -= f * inv[col * n + j];
                    }
                }
            }
        }
    }
    (inv, det)
}

impl Lattice for E8Lattice {
    fn dim(&self) -> usize {
        8
    }

    fn nearest_into(&self, x: &[f64], out: &mut [i64]) {
        let mut p = [0.0f64; 8];
        self.decode_point_into(x, &mut p);
        self.coords_of_point(&p, out);
    }

    fn nearest_batch_into(&self, xs: &[f64], out: &mut [i64], _scratch: &mut Scratch) {
        debug_assert_eq!(xs.len() % 8, 0);
        debug_assert_eq!(xs.len(), out.len());
        let mut p = [0.0f64; 8];
        for (x, o) in xs.chunks_exact(8).zip(out.chunks_exact_mut(8)) {
            self.decode_point_into(x, &mut p);
            self.coords_of_point(&p, o);
        }
    }

    fn point_into(&self, coords: &[i64], out: &mut [f64]) {
        debug_assert_eq!(coords.len(), 8);
        debug_assert_eq!(out.len(), 8);
        for i in 0..8 {
            let mut s = 0.0;
            for j in 0..8 {
                s += self.g[i * 8 + j] * coords[j] as f64;
            }
            out[i] = s;
        }
    }

    fn quantize(&self, x: &[f64]) -> Vec<f64> {
        let mut p = vec![0.0; 8];
        self.decode_point_into(x, &mut p);
        p
    }

    fn quantize_batch_into(&self, xs: &[f64], out: &mut [f64], _scratch: &mut Scratch) {
        debug_assert_eq!(xs.len() % 8, 0);
        debug_assert_eq!(xs.len(), out.len());
        for (x, o) in xs.chunks_exact(8).zip(out.chunks_exact_mut(8)) {
            self.decode_point_into(x, o);
        }
    }

    fn coords_real_into(&self, x: &[f64], out: &mut [f64]) {
        for i in 0..8 {
            let mut s = 0.0;
            for j in 0..8 {
                s += self.g_inv[i * 8 + j] * x[j];
            }
            out[i] = s;
        }
    }

    fn cell_volume(&self) -> f64 {
        // det E8 = 1, scaled.
        self.scale.powi(8)
    }

    fn second_moment(&self) -> f64 {
        self.base_moment * self.scale * self.scale
    }

    fn generator(&self) -> &[f64] {
        &self.g
    }

    fn name(&self) -> String {
        "e8".to_string()
    }

    fn boxed_scaled(&self, s: f64) -> Box<dyn Lattice> {
        let mut lat = E8Lattice::new_unmeasured(self.scale * s);
        lat.base_moment = self.base_moment;
        Box::new(lat)
    }

    fn decorrelate(&self, c: &mut [i64]) {
        super::generic::apply_decorrelate(&self.predictor, c, 8);
    }

    fn recorrelate(&self, c: &mut [i64]) {
        super::generic::apply_recorrelate(&self.predictor, c, 8);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Rng, Xoshiro256pp};

    #[test]
    fn points_are_valid_e8() {
        // E8 points: either all-integer with even sum, or all-half-integer
        // with coordinates ≡ ½ (mod 1) and sum even.
        let lat = E8Lattice::new(1.0);
        let mut rng = Xoshiro256pp::seed_from_u64(41);
        for _ in 0..500 {
            let x: Vec<f64> = (0..8).map(|_| rng.uniform_range(-3.0, 3.0)).collect();
            let q = lat.quantize(&x);
            let doubled: Vec<i64> = q.iter().map(|v| (2.0 * v).round() as i64).collect();
            for (v, &d) in q.iter().zip(&doubled) {
                assert!((2.0 * v - d as f64).abs() < 1e-9);
            }
            let all_int = doubled.iter().all(|d| d % 2 == 0);
            let all_half = doubled.iter().all(|d| d.rem_euclid(2) == 1);
            assert!(all_int || all_half, "q={q:?}");
            let sum2: i64 = doubled.iter().sum();
            assert_eq!(sum2.rem_euclid(4), 0, "sum of coords must be even: {q:?}");
        }
    }

    #[test]
    fn coords_roundtrip() {
        let lat = E8Lattice::new(0.9);
        let coords = vec![1i64, -2, 0, 3, -1, 2, 0, 1];
        let p = lat.point(&coords);
        assert_eq!(lat.nearest(&p), coords);
    }

    #[test]
    fn decoder_beats_cubic_rounding() {
        // E8's quantization error must on average beat Z^8 at equal cell
        // volume (that's the whole point of the lattice).
        let e8 = E8Lattice::new(1.0);
        let z8 = super::super::cubic(8, 1.0); // same cell volume = 1
        let mut rng = Xoshiro256pp::seed_from_u64(42);
        let (mut de, mut dz) = (0.0, 0.0);
        for _ in 0..5000 {
            let x: Vec<f64> = (0..8).map(|_| rng.uniform_range(-4.0, 4.0)).collect();
            let qe = e8.quantize(&x);
            let qz = z8.quantize(&x);
            de += x.iter().zip(&qe).map(|(a, b)| (a - b) * (a - b)).sum::<f64>();
            dz += x.iter().zip(&qz).map(|(a, b)| (a - b) * (a - b)).sum::<f64>();
        }
        assert!(de < dz, "E8 {de} vs Z8 {dz}");
    }

    #[test]
    fn e8_normalized_second_moment_near_known() {
        let lat = E8Lattice::new(1.0);
        let g = lat.second_moment() / 8.0; // V = 1 → G = σ̄²/(L·V^{2/L})
        assert!((g - 0.0716821).abs() < 2e-3, "G={g}");
    }
}
