//! The checkerboard lattice `D_n = {x ∈ Z^n : Σx_i even}`, with the O(n)
//! exact decoder of Conway & Sloane (SPLAG §20.2): round every coordinate;
//! if the rounded coordinate sum is odd, re-round the coordinate whose
//! rounding error was largest to its second-nearest integer.
//!
//! `D4` is the densest lattice packing in dimension 4 and a natural
//! extension point beyond the paper's L ≤ 2 experiments (the ablation
//! benches sweep L ∈ {1, 2, 4, 8}).

use super::{Lattice, Scratch};
use std::sync::OnceLock;

#[derive(Debug, Clone)]
pub struct DnLattice {
    n: usize,
    scale: f64,
    /// Row-major generator (transpose of the standard row-basis).
    g: Vec<f64>,
    g_inv: Vec<f64>,
    /// Base (scale=1) second moment, shared per dimension.
    base_moment: f64,
    /// Coordinate decorrelation predictor (see `generic::predictor_from_ginv`).
    predictor: Vec<f64>,
}

/// Cache of the scale-1 second moment per dimension (MC is deterministic,
/// so this is a pure function of n).
fn base_moment_for(n: usize) -> f64 {
    static CACHE: OnceLock<std::sync::Mutex<std::collections::HashMap<usize, f64>>> =
        OnceLock::new();
    let cache = CACHE.get_or_init(|| std::sync::Mutex::new(std::collections::HashMap::new()));
    let mut guard = cache.lock().unwrap();
    *guard.entry(n).or_insert_with(|| {
        let probe = DnLattice::new_unmeasured(n, 1.0);
        super::moment::monte_carlo_second_moment(&probe, 400_000, 0xD4D4_0000 + n as u64)
    })
}

impl DnLattice {
    fn generator(n: usize) -> Vec<f64> {
        // Standard basis rows (C&S): (−1,−1,0,…), (1,−1,0,…), (0,1,−1,…),…
        // We store points = G·l with *columns* as basis vectors, i.e. G is
        // the transpose of that row matrix.
        let mut rows = vec![vec![0.0; n]; n];
        rows[0][0] = -1.0;
        rows[0][1] = -1.0;
        for i in 1..n {
            rows[i][i - 1] = 1.0;
            rows[i][i] = -1.0;
        }
        let mut g = vec![0.0; n * n];
        for (i, row) in rows.iter().enumerate() {
            for j in 0..n {
                g[j * n + i] = row[j]; // transpose
            }
        }
        g
    }

    fn new_unmeasured(n: usize, scale: f64) -> Self {
        assert!(n >= 2);
        let mut g = Self::generator(n);
        for v in g.iter_mut() {
            *v *= scale;
        }
        let (g_inv, _) = invert(&g, n);
        let predictor = super::generic::predictor_from_ginv(&g_inv, n);
        Self { n, scale, g, g_inv, base_moment: f64::NAN, predictor }
    }

    pub fn new(n: usize, scale: f64) -> Self {
        let mut lat = Self::new_unmeasured(n, scale);
        lat.base_moment = base_moment_for(n);
        lat
    }

    /// Decode to the nearest D_n point (ambient coordinates), written into
    /// `out` with no heap allocation — the shared core behind the scalar
    /// and batched paths (Conway & Sloane's O(n) rule: round everything;
    /// on odd parity re-round the worst coordinate).
    fn decode_point_into(&self, x: &[f64], out: &mut [f64]) {
        let n = self.n;
        debug_assert_eq!(x.len(), n);
        debug_assert_eq!(out.len(), n);
        let inv_s = 1.0 / self.scale;
        let mut sum = 0i64;
        let (mut worst, mut err) = (0usize, -1.0f64);
        for i in 0..n {
            let v = x[i] * inv_s;
            let r = v.round();
            sum += r as i64;
            let e = (v - r).abs();
            if e > err {
                err = e;
                worst = i;
            }
            out[i] = r;
        }
        if sum.rem_euclid(2) != 0 {
            // flip the worst coordinate to its second-nearest integer
            let v = x[worst] * inv_s;
            let r = out[worst];
            out[worst] = if v >= r { r + 1.0 } else { r - 1.0 };
        }
        for o in out.iter_mut() {
            *o *= self.scale;
        }
    }

    /// Integer coordinates `l = G⁻¹p` of an ambient lattice point.
    #[inline]
    fn coords_of_point(&self, p: &[f64], out: &mut [i64]) {
        let n = self.n;
        for i in 0..n {
            let mut s = 0.0;
            for j in 0..n {
                s += self.g_inv[i * n + j] * p[j];
            }
            out[i] = s.round() as i64;
        }
    }
}

// Local copy of small-matrix inversion (kept private to avoid a pub dep
// on generic.rs internals).
fn invert(a: &[f64], n: usize) -> (Vec<f64>, f64) {
    let mut m = a.to_vec();
    let mut inv = vec![0.0; n * n];
    for i in 0..n {
        inv[i * n + i] = 1.0;
    }
    let mut det = 1.0;
    for col in 0..n {
        let mut piv = col;
        for r in (col + 1)..n {
            if m[r * n + col].abs() > m[piv * n + col].abs() {
                piv = r;
            }
        }
        assert!(m[piv * n + col].abs() > 1e-12, "singular");
        if piv != col {
            for j in 0..n {
                m.swap(col * n + j, piv * n + j);
                inv.swap(col * n + j, piv * n + j);
            }
            det = -det;
        }
        let p = m[col * n + col];
        det *= p;
        for j in 0..n {
            m[col * n + j] /= p;
            inv[col * n + j] /= p;
        }
        for r in 0..n {
            if r != col {
                let f = m[r * n + col];
                if f != 0.0 {
                    for j in 0..n {
                        m[r * n + j] -= f * m[col * n + j];
                        inv[r * n + j] -= f * inv[col * n + j];
                    }
                }
            }
        }
    }
    (inv, det)
}

impl Lattice for DnLattice {
    fn dim(&self) -> usize {
        self.n
    }

    fn nearest_into(&self, x: &[f64], out: &mut [i64]) {
        // Thin adapter over the batched kernel (single block).
        let mut p = vec![0.0; self.n];
        self.decode_point_into(x, &mut p);
        self.coords_of_point(&p, out);
    }

    fn nearest_batch_into(&self, xs: &[f64], out: &mut [i64], scratch: &mut Scratch) {
        let l = self.n;
        debug_assert_eq!(xs.len() % l, 0);
        debug_assert_eq!(xs.len(), out.len());
        let mut p = std::mem::take(&mut scratch.f1);
        p.clear();
        p.resize(l, 0.0);
        for (x, o) in xs.chunks_exact(l).zip(out.chunks_exact_mut(l)) {
            self.decode_point_into(x, &mut p);
            self.coords_of_point(&p, o);
        }
        scratch.f1 = p;
    }

    fn point_into(&self, coords: &[i64], out: &mut [f64]) {
        let n = self.n;
        debug_assert_eq!(coords.len(), n);
        debug_assert_eq!(out.len(), n);
        for i in 0..n {
            let mut s = 0.0;
            for j in 0..n {
                s += self.g[i * n + j] * coords[j] as f64;
            }
            out[i] = s;
        }
    }

    fn quantize(&self, x: &[f64]) -> Vec<f64> {
        let mut p = vec![0.0; self.n];
        self.decode_point_into(x, &mut p);
        p
    }

    fn quantize_batch_into(&self, xs: &[f64], out: &mut [f64], _scratch: &mut Scratch) {
        let l = self.n;
        debug_assert_eq!(xs.len() % l, 0);
        debug_assert_eq!(xs.len(), out.len());
        for (x, o) in xs.chunks_exact(l).zip(out.chunks_exact_mut(l)) {
            self.decode_point_into(x, o);
        }
    }

    fn coords_real_into(&self, x: &[f64], out: &mut [f64]) {
        let n = self.n;
        for i in 0..n {
            let mut s = 0.0;
            for j in 0..n {
                s += self.g_inv[i * n + j] * x[j];
            }
            out[i] = s;
        }
    }

    fn cell_volume(&self) -> f64 {
        // |det D_n| = 2, scaled by s^n.
        2.0 * self.scale.powi(self.n as i32)
    }

    fn second_moment(&self) -> f64 {
        self.base_moment * self.scale * self.scale
    }

    fn generator(&self) -> &[f64] {
        &self.g
    }

    fn name(&self) -> String {
        format!("d{}", self.n)
    }

    fn boxed_scaled(&self, s: f64) -> Box<dyn Lattice> {
        let mut lat = DnLattice::new_unmeasured(self.n, self.scale * s);
        lat.base_moment = self.base_moment;
        Box::new(lat)
    }

    fn decorrelate(&self, c: &mut [i64]) {
        super::generic::apply_decorrelate(&self.predictor, c, self.n);
    }

    fn recorrelate(&self, c: &mut [i64]) {
        super::generic::apply_recorrelate(&self.predictor, c, self.n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::{Rng, Xoshiro256pp};

    #[test]
    fn points_have_even_coordinate_sum() {
        let lat = DnLattice::new(4, 1.0);
        let mut rng = Xoshiro256pp::seed_from_u64(31);
        for _ in 0..500 {
            let x: Vec<f64> = (0..4).map(|_| rng.uniform_range(-5.0, 5.0)).collect();
            let q = lat.quantize(&x);
            let sum: i64 = q.iter().map(|v| v.round() as i64).sum();
            assert_eq!(sum.rem_euclid(2), 0, "q={q:?}");
            // every coordinate is an integer
            for v in &q {
                assert!((v - v.round()).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn decoder_is_nearest_vs_bruteforce() {
        let lat = DnLattice::new(4, 0.7);
        let mut rng = Xoshiro256pp::seed_from_u64(32);
        for _ in 0..300 {
            let x: Vec<f64> = (0..4).map(|_| rng.uniform_range(-2.0, 2.0)).collect();
            let q = lat.quantize(&x);
            let dq: f64 = x.iter().zip(&q).map(|(a, b)| (a - b) * (a - b)).sum();
            // brute force over all integer points with even sum in a window
            let mut best = f64::INFINITY;
            let c: Vec<i64> = x.iter().map(|v| (v / 0.7).round() as i64).collect();
            for d0 in -2..=2i64 {
                for d1 in -2..=2i64 {
                    for d2 in -2..=2i64 {
                        for d3 in -2..=2i64 {
                            let p = [c[0] + d0, c[1] + d1, c[2] + d2, c[3] + d3];
                            if p.iter().sum::<i64>().rem_euclid(2) != 0 {
                                continue;
                            }
                            let d: f64 = x
                                .iter()
                                .zip(p.iter())
                                .map(|(a, &b)| (a - b as f64 * 0.7).powi(2))
                                .sum();
                            best = best.min(d);
                        }
                    }
                }
            }
            assert!(dq <= best + 1e-9, "dq={dq} best={best}");
        }
    }

    #[test]
    fn coords_roundtrip() {
        let lat = DnLattice::new(4, 1.3);
        let coords = vec![2i64, -1, 3, 0];
        let p = lat.point(&coords);
        assert_eq!(lat.nearest(&p), coords);
    }

    #[test]
    fn d4_normalized_second_moment_near_known() {
        // G(D4) ≈ 0.076603. σ̄² = G·L·V^{2/L}; V=2 at scale 1, L=4.
        let lat = DnLattice::new(4, 1.0);
        let g = lat.second_moment() / (4.0 * 2f64.powf(2.0 / 4.0));
        assert!((g - 0.076603).abs() < 2e-3, "G={g}");
    }
}
