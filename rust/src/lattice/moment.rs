//! Second-moment estimation for Voronoi cells.
//!
//! `σ̄²_Λ = ∫_{P₀}‖x‖² dx / ∫_{P₀} dx` (the paper's normalization, eq. after
//! Thm 1) equals `E‖U‖²` for `U ~ Unif(P₀)`, which we estimate with the
//! exact mod-Λ dither sampler. The seed is fixed so the value is a pure
//! function of the lattice — important because σ̄² enters the theoretical
//! bounds reported in EXPERIMENTS.md.

use super::dither::fill_dither;
use super::{Lattice, Scratch};
use crate::prng::Xoshiro256pp;

/// Deterministic Monte-Carlo estimate of `E‖U‖²`, `U ~ Unif(P₀)`.
/// Runs through the batched dither fill in reused buffers — this executes
/// at lattice construction (400k samples for D4/E8/hex), so allocation
/// per sample would dominate.
pub fn monte_carlo_second_moment(lat: &dyn Lattice, samples: usize, seed: u64) -> f64 {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let l = lat.dim();
    let mut scratch = Scratch::new();
    let mut block = vec![0.0f64; 1024 * l];
    let mut acc = 0.0f64;
    let mut done = 0usize;
    while done < samples {
        let n = (samples - done).min(1024);
        let buf = &mut block[..n * l];
        fill_dither(lat, &mut rng, buf, &mut scratch);
        acc += buf.iter().map(|v| v * v).sum::<f64>();
        done += n;
    }
    acc / samples as f64
}

/// Dimensionless normalized second moment `G(Λ) = σ̄²/(L·V^{2/L})` — the
/// figure of merit tabulated by Conway & Sloane. Exposed for the ablation
/// report.
pub fn dimensionless_g(lat: &dyn Lattice) -> f64 {
    let l = lat.dim() as f64;
    lat.second_moment() / (l * lat.cell_volume().powf(2.0 / l))
}

#[cfg(test)]
mod tests {
    use crate::lattice;

    #[test]
    fn scalar_g_is_one_twelfth() {
        let lat = lattice::scalar(0.7);
        let g = super::dimensionless_g(&lat);
        assert!((g - 1.0 / 12.0).abs() < 1e-9, "G={g}");
    }

    #[test]
    fn g_ordering_improves_with_dimension() {
        // G(Z) > G(hex) > G(D4) > G(E8): the vector-quantization gain the
        // paper banks on.
        let gz = super::dimensionless_g(&lattice::scalar(1.0));
        let gh = super::dimensionless_g(&lattice::a2_hexagonal());
        let gd = super::dimensionless_g(&lattice::DnLattice::new(4, 1.0));
        let ge = super::dimensionless_g(&lattice::E8Lattice::new(1.0));
        assert!(gz > gh && gh > gd && gd > ge, "{gz} {gh} {gd} {ge}");
    }

    #[test]
    fn mc_is_deterministic() {
        let lat = lattice::paper_hexagonal();
        let a = super::monte_carlo_second_moment(&lat, 10_000, 7);
        let b = super::monte_carlo_second_moment(&lat, 10_000, 7);
        assert_eq!(a, b);
    }
}
