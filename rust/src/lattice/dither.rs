//! `Unif(P₀)` dither generation (UVeQFed step **E2**).
//!
//! The subtractive-dither machinery requires dither vectors uniform over
//! the basic (Voronoi) cell `P₀`. Direct rejection sampling against a
//! Voronoi cell is awkward for general lattices; instead we use the exact
//! *mod-Λ fold*: if `U` is uniform over any fundamental cell of Λ (we use
//! the parallelepiped `G·[0,1)^L`), then `U − Q_Λ(U)` is uniform over the
//! Voronoi region `P₀`. This is the standard construction behind dithered
//! lattice codes (Zamir & Feder) and works for every lattice we implement.

use super::{Lattice, Scratch};
use crate::prng::Rng;

/// Fill `out` (row-major `[M, L]`, `out.len()` a multiple of `L`) with
/// i.i.d. dither vectors `z ~ Unif(P₀)`, allocation-free given `scratch`.
///
/// This is the hot-path entry point: per-round dither for an entire update
/// (encoder) or one block at a time (streaming decoder) lands in a reused
/// caller-owned buffer. Draws exactly `L` uniforms per block in block
/// order, so encoder and decoder consume the shared stream identically
/// regardless of how many blocks they fill per call.
pub fn fill_dither<R: Rng + ?Sized>(
    lat: &dyn Lattice,
    rng: &mut R,
    out: &mut [f64],
    scratch: &mut Scratch,
) {
    let l = lat.dim();
    debug_assert_eq!(out.len() % l, 0, "dither buffer must hold whole blocks");
    let m = out.len() / l;
    let g = lat.generator();
    // u = G · v with v ~ Unif[0,1)^L (uniform over the fundamental
    // parallelepiped), written straight into `out`.
    let mut v = std::mem::take(&mut scratch.f1);
    v.clear();
    v.resize(l, 0.0);
    for b in 0..m {
        for vj in v.iter_mut() {
            *vj = rng.uniform();
        }
        let ub = &mut out[b * l..(b + 1) * l];
        for i in 0..l {
            let mut s = 0.0;
            for j in 0..l {
                s += g[i * l + j] * v[j];
            }
            ub[i] = s;
        }
    }
    scratch.f1 = v;
    // Mod-Λ fold: z = u − Q_Λ(u), batched.
    let mut q = std::mem::take(&mut scratch.f2);
    q.clear();
    q.resize(out.len(), 0.0);
    lat.quantize_batch_into(out, &mut q, scratch);
    for (o, qi) in out.iter_mut().zip(q.iter()) {
        *o -= qi;
    }
    scratch.f2 = q;
}

/// Draw one dither vector `z ~ Unif(P₀)` for `lat` (allocating adapter
/// over [`fill_dither`]).
pub fn sample_dither<R: Rng + ?Sized>(lat: &dyn Lattice, rng: &mut R) -> Vec<f64> {
    let mut out = vec![0.0; lat.dim()];
    let mut scratch = Scratch::new();
    fill_dither(lat, rng, &mut out, &mut scratch);
    out
}

/// Fill a `[M, L]` row-major buffer with i.i.d. dither vectors
/// (allocating adapter over [`fill_dither`]).
pub fn sample_dither_block<R: Rng + ?Sized>(
    lat: &dyn Lattice,
    rng: &mut R,
    m: usize,
) -> Vec<f64> {
    let mut out = vec![0.0; m * lat.dim()];
    let mut scratch = Scratch::new();
    fill_dither(lat, rng, &mut out, &mut scratch);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::{self, Lattice};
    use crate::prng::Xoshiro256pp;

    /// Dither samples must lie inside the Voronoi cell: each sample is at
    /// least as close to 0 as to any other lattice point.
    fn assert_in_voronoi(lat: &dyn Lattice, z: &[f64]) {
        let q = lat.quantize(z);
        let dz: f64 = z.iter().map(|v| v * v).sum();
        let dq: f64 = z.iter().zip(&q).map(|(a, b)| (a - b) * (a - b)).sum();
        // Nearest lattice point to z must be 0 (up to boundary ties).
        assert!(dq + 1e-9 >= dz || q.iter().all(|&v| v.abs() < 1e-9), "z={z:?} q={q:?}");
    }

    #[test]
    fn dither_in_cell_all_lattices() {
        let mut rng = Xoshiro256pp::seed_from_u64(51);
        for name in ["scalar", "hex", "d4", "e8"] {
            let lat = lattice::by_name(name).unwrap();
            for _ in 0..300 {
                let z = sample_dither(lat.as_ref(), &mut rng);
                assert_in_voronoi(lat.as_ref(), &z);
            }
        }
    }

    #[test]
    fn dither_second_moment_matches_lattice_constant() {
        // E‖z‖² must equal σ̄²_Λ (they are the same integral).
        let lat = lattice::paper_hexagonal();
        let mut rng = Xoshiro256pp::seed_from_u64(52);
        let n = 100_000;
        let mean_sq: f64 = (0..n)
            .map(|_| {
                let z = sample_dither(&lat, &mut rng);
                z.iter().map(|v| v * v).sum::<f64>()
            })
            .sum::<f64>()
            / n as f64;
        let rel = (mean_sq - lat.second_moment()).abs() / lat.second_moment();
        assert!(rel < 0.02, "MC={mean_sq} σ̄²={}", lat.second_moment());
    }

    #[test]
    fn dither_mean_is_zero() {
        // Voronoi cells are symmetric about the origin → zero-mean dither.
        let lat = lattice::paper_hexagonal();
        let mut rng = Xoshiro256pp::seed_from_u64(53);
        let n = 100_000;
        let mut mean = [0.0f64; 2];
        for _ in 0..n {
            let z = sample_dither(&lat, &mut rng);
            mean[0] += z[0];
            mean[1] += z[1];
        }
        let scale = lat.second_moment().sqrt();
        assert!((mean[0] / n as f64).abs() < 0.01 * scale);
        assert!((mean[1] / n as f64).abs() < 0.01 * scale);
    }

    #[test]
    fn block_layout() {
        let lat = lattice::paper_hexagonal();
        let mut rng = Xoshiro256pp::seed_from_u64(54);
        let block = sample_dither_block(&lat, &mut rng, 17);
        assert_eq!(block.len(), 34);
    }
}
