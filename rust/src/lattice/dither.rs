//! `Unif(P₀)` dither generation (UVeQFed step **E2**).
//!
//! The subtractive-dither machinery requires dither vectors uniform over
//! the basic (Voronoi) cell `P₀`. Direct rejection sampling against a
//! Voronoi cell is awkward for general lattices; instead we use the exact
//! *mod-Λ fold*: if `U` is uniform over any fundamental cell of Λ (we use
//! the parallelepiped `G·[0,1)^L`), then `U − Q_Λ(U)` is uniform over the
//! Voronoi region `P₀`. This is the standard construction behind dithered
//! lattice codes (Zamir & Feder) and works for every lattice we implement.

use super::Lattice;
use crate::prng::Rng;

/// Draw one dither vector `z ~ Unif(P₀)` for `lat`.
pub fn sample_dither<R: Rng + ?Sized>(lat: &dyn Lattice, rng: &mut R) -> Vec<f64> {
    let l = lat.dim();
    // u = G · v with v ~ Unif[0,1)^L  (uniform over the fundamental
    // parallelepiped).
    let v: Vec<f64> = (0..l).map(|_| rng.uniform()).collect();
    let g = lat.generator_row_major();
    let mut u = vec![0.0; l];
    for i in 0..l {
        let mut s = 0.0;
        for j in 0..l {
            s += g[i * l + j] * v[j];
        }
        u[i] = s;
    }
    let q = lat.quantize(&u);
    u.iter().zip(&q).map(|(a, b)| a - b).collect()
}

/// Fill a `[M, L]` row-major buffer with i.i.d. dither vectors.
pub fn sample_dither_block<R: Rng + ?Sized>(
    lat: &dyn Lattice,
    rng: &mut R,
    m: usize,
) -> Vec<f64> {
    let l = lat.dim();
    let mut out = Vec::with_capacity(m * l);
    for _ in 0..m {
        out.extend(sample_dither(lat, rng));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::{self, Lattice};
    use crate::prng::Xoshiro256pp;

    /// Dither samples must lie inside the Voronoi cell: each sample is at
    /// least as close to 0 as to any other lattice point.
    fn assert_in_voronoi(lat: &dyn Lattice, z: &[f64]) {
        let q = lat.quantize(z);
        let dz: f64 = z.iter().map(|v| v * v).sum();
        let dq: f64 = z.iter().zip(&q).map(|(a, b)| (a - b) * (a - b)).sum();
        // Nearest lattice point to z must be 0 (up to boundary ties).
        assert!(dq + 1e-9 >= dz || q.iter().all(|&v| v.abs() < 1e-9), "z={z:?} q={q:?}");
    }

    #[test]
    fn dither_in_cell_all_lattices() {
        let mut rng = Xoshiro256pp::seed_from_u64(51);
        for name in ["scalar", "hex", "d4", "e8"] {
            let lat = lattice::by_name(name).unwrap();
            for _ in 0..300 {
                let z = sample_dither(lat.as_ref(), &mut rng);
                assert_in_voronoi(lat.as_ref(), &z);
            }
        }
    }

    #[test]
    fn dither_second_moment_matches_lattice_constant() {
        // E‖z‖² must equal σ̄²_Λ (they are the same integral).
        let lat = lattice::paper_hexagonal();
        let mut rng = Xoshiro256pp::seed_from_u64(52);
        let n = 100_000;
        let mean_sq: f64 = (0..n)
            .map(|_| {
                let z = sample_dither(&lat, &mut rng);
                z.iter().map(|v| v * v).sum::<f64>()
            })
            .sum::<f64>()
            / n as f64;
        let rel = (mean_sq - lat.second_moment()).abs() / lat.second_moment();
        assert!(rel < 0.02, "MC={mean_sq} σ̄²={}", lat.second_moment());
    }

    #[test]
    fn dither_mean_is_zero() {
        // Voronoi cells are symmetric about the origin → zero-mean dither.
        let lat = lattice::paper_hexagonal();
        let mut rng = Xoshiro256pp::seed_from_u64(53);
        let n = 100_000;
        let mut mean = [0.0f64; 2];
        for _ in 0..n {
            let z = sample_dither(&lat, &mut rng);
            mean[0] += z[0];
            mean[1] += z[1];
        }
        let scale = lat.second_moment().sqrt();
        assert!((mean[0] / n as f64).abs() < 0.01 * scale);
        assert!((mean[1] / n as f64).abs() < 0.01 * scale);
    }

    #[test]
    fn block_layout() {
        let lat = lattice::paper_hexagonal();
        let mut rng = Xoshiro256pp::seed_from_u64(54);
        let block = sample_dither_block(&lat, &mut rng, 17);
        assert_eq!(block.len(), 34);
    }
}
