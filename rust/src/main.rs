//! `uveqfed` — launcher CLI for the federated runtime.
//!
//! Subcommands:
//! * `train`    — run a federated experiment from a TOML config
//! * `fleet`    — fleet-scale simulation: cohort sampling, stragglers,
//!   dropouts, framed uplink, streaming aggregation
//! * `distort`  — one-off codec distortion measurement
//! * `info`     — print lattice/codec/runtime diagnostics
//!
//! Examples: `uveqfed train --config configs/fig6_mnist_k100_r2.toml`,
//! `uveqfed fleet --population 100000 --cohort 256 --scenario stragglers`,
//! `uveqfed distort --codec uveqfed-l2:zeta=3.0 --rate 2`.
//!
//! Codec strings go through the fallible `quantizer::make` registry:
//! typos and bad parameters surface as errors listing the valid codecs,
//! never as panics.

use uveqfed::coordinator::rate_control::{
    controller_by_name, thm2_bound_for_allocation, RateController, UniformRate,
};
use uveqfed::data::{partition, PartitionScheme, SynthCifar, SynthMnist};
use uveqfed::fl::{run_federated, FlConfig, NativeTrainer, Trainer};
use uveqfed::fleet::{
    Channel, ChannelModel, ClientPool, ClientRecords, DownlinkSpec, FleetDriver, RatePlan,
    RoundRobinPool, RoundSpec, Scenario, VirtualClock, MAX_SHARDS,
};
use uveqfed::lattice;
use uveqfed::models::LogReg;
use uveqfed::models::{CnnLite, MlpMnist};
use uveqfed::quantizer;
use uveqfed::quantizer::DecodeBudget;
use uveqfed::runtime;
use uveqfed::telemetry::{summarize, Collector, TelemetryReport, TraceWriter};
use uveqfed::util::cli::{Args, Cli};
use uveqfed::util::config::Config;
use uveqfed::util::error::{Context, Error};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let sub = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    let result = match sub {
        "train" => cmd_train(rest),
        "fleet" => cmd_fleet(rest),
        "distort" => cmd_distort(rest),
        "info" => cmd_info(),
        _ => {
            println!(
                "uveqfed — Universal Vector Quantization for Federated Learning\n\n\
                 subcommands:\n  train   --config <file> [--codec SPEC] [--rate R] [--rounds N]\n  \
                 fleet   --population N --cohort K --scenario NAME [--rounds N] [--codec SPEC]\n          \
                 [--channel uniform|tiers|lognormal|markov --policy uniform|proportional|theory]\n          \
                 [--shards N] [--decode-budget N] [--trace FILE.jsonl --trace-report FILE.md]\n          \
                 [--corrupt P --max-retries N]\n          \
                 [--downlink-codec SPEC --downlink-rate R --downlink-resync N]\n  \
                 distort --codec SPEC --rate R [--size N]\n  info\n\n\
                 Codec SPEC grammar: name[:key=value,...] — e.g. uveqfed-l2, qsgd:max_levels=4096.\n\
                 See configs/*.toml for the paper's experiment setups."
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
}

fn parse_args(cli: &Cli, argv: &[String]) -> uveqfed::Result<Args> {
    cli.parse(argv).map_err(Error::msg)
}

fn cmd_train(argv: &[String]) -> uveqfed::Result<()> {
    let cli = Cli::new("uveqfed train", "run a federated experiment")
        .req("config", "TOML config file (see configs/)")
        .opt("codec", "", "override quantizer.kind (spec: name[:key=value,...])")
        .opt("rate", "", "override quantizer.rate")
        .opt("rounds", "", "override fl.rounds")
        .opt("out", "", "write history CSV here")
        .flag("verbose", "per-eval logging");
    let args = parse_args(&cli, argv)?;
    let conf = Config::from_file(args.get("config")).context("config load")?;
    let mut flc = FlConfig::from_config(&conf)?;
    flc.verbose = flc.verbose || args.has_flag("verbose");
    if !args.get("rate").is_empty() {
        flc.rate = args.get_f64("rate");
    }
    if !args.get("rounds").is_empty() {
        flc.rounds = args.get_usize("rounds");
    }
    let codec_name = if args.get("codec").is_empty() {
        conf.str_or("quantizer.kind", "uveqfed-l2")
    } else {
        args.get("codec").to_string()
    };
    let codec = quantizer::make(&codec_name)?;

    let dataset = conf.str_or("data.dataset", "mnist");
    let n_per_user = conf.usize_or("data.samples_per_user", 500);
    let scheme = match conf.str_or("data.partition", "iid").as_str() {
        "iid" => PartitionScheme::Iid,
        "sequential" => PartitionScheme::Sequential,
        "dominant" => PartitionScheme::DominantLabel {
            frac: conf.f64_or("data.dominant_frac", 0.25),
        },
        "dirichlet" => PartitionScheme::Dirichlet {
            alpha: conf.f64_or("data.dirichlet_alpha", 0.5),
        },
        other => uveqfed::bail!(
            "unknown data.partition '{other}' (iid|sequential|dominant|dirichlet)"
        ),
    };
    let seed = flc.seed;
    let test_n = conf.usize_or("data.test_samples", 1000);

    let (shards, test, trainer): (Vec<_>, _, Box<dyn Trainer>) = match dataset.as_str() {
        "mnist" => {
            let g = SynthMnist::new(seed);
            let ds = g.dataset(flc.users * n_per_user);
            let test = g.test_dataset(test_n);
            let shards = partition(&ds, flc.users, n_per_user, scheme, seed);
            let trainer: Box<dyn Trainer> = match conf.str_or("model.backend", "native").as_str()
            {
                "hlo" => Box::new(
                    runtime::HloTrainer::load("mnist", conf.usize_or("model.step_batch", n_per_user))
                        .context("load HLO trainer (run `make artifacts`)")?,
                ),
                _ => Box::new(NativeTrainer::new(MlpMnist::new(
                    conf.usize_or("model.hidden", 50),
                ))),
            };
            (shards, test, trainer)
        }
        "cifar" => {
            let g = SynthCifar::new(seed);
            let ds = g.dataset(flc.users * n_per_user);
            let test = g.test_dataset(test_n);
            let shards = partition(&ds, flc.users, n_per_user, scheme, seed);
            let trainer: Box<dyn Trainer> = match conf.str_or("model.backend", "native").as_str()
            {
                "hlo" => Box::new(
                    runtime::HloTrainer::load("cifar", conf.usize_or("model.step_batch", 60))
                        .context("load HLO trainer (run `make artifacts`)")?,
                ),
                _ => Box::new(NativeTrainer::new(CnnLite::cifar())),
            };
            (shards, test, trainer)
        }
        "logreg-mnist" => {
            let g = SynthMnist::new(seed);
            let ds = g.dataset(flc.users * n_per_user);
            let test = g.test_dataset(test_n);
            let shards = partition(&ds, flc.users, n_per_user, scheme, seed);
            let trainer: Box<dyn Trainer> = Box::new(NativeTrainer::new(LogReg::new(
                ds.features,
                ds.classes,
                conf.f64_or("model.lambda", 1e-2) as f32,
            )));
            (shards, test, trainer)
        }
        other => uveqfed::bail!("unknown data.dataset '{other}' (mnist|cifar|logreg-mnist)"),
    };

    println!(
        "train: dataset={dataset} users={} rounds={} codec={} rate={}",
        flc.users,
        flc.rounds,
        codec.name(),
        flc.rate
    );
    let hist = run_federated(&flc, trainer.as_ref(), &shards, &test, codec.as_ref());
    println!(
        "final accuracy {:.4} | best {:.4} | uplink {:.3e} bits",
        hist.final_accuracy(),
        hist.best_accuracy(),
        hist.rows.last().map(|r| r.uplink_bits).unwrap_or(0.0)
    );
    let out = args.get("out");
    if !out.is_empty() {
        hist.to_table().write_file(out).context("write history")?;
        println!("history → {out}");
    }
    Ok(())
}

fn cmd_fleet(argv: &[String]) -> uveqfed::Result<()> {
    let cli = Cli::new("uveqfed fleet", "fleet-scale federated simulation")
        .opt("population", "10000", "total client population")
        .opt("cohort", "64", "aggregation target per round")
        .opt("scenario", "stragglers", "full|sampled|weighted|stragglers|flaky")
        .opt("rounds", "10", "rounds to simulate")
        .opt("codec", "uveqfed-l2", "update codec (spec: name[:key=value,...])")
        .opt("rate", "2", "bits per model parameter")
        .opt("seed", "1", "root seed")
        .opt("workers", "0", "fan-out threads (0 = auto)")
        .opt("shards", "1", "server aggregation shards (bit-identical for any value)")
        .opt("decode-budget", "", "solver-iteration credit per decode (empty = unlimited)")
        .opt("deadline", "", "override round deadline (virtual seconds)")
        .opt("dropout", "", "override per-client dropout probability")
        .opt("corrupt", "", "per-attempt frame corruption probability")
        .opt("max-retries", "", "retransmit attempts after a corrupt frame")
        .opt("templates", "16", "distinct template shards backing the population")
        .opt("samples", "120", "samples per template shard")
        .opt("channel", "", "uplink capacity model: uniform|tiers|lognormal|markov")
        .opt("policy", "theory", "rate allocation: uniform|proportional|theory")
        .opt("downlink-codec", "", "broadcast codec for a coded downlink (off when empty)")
        .opt("downlink-rate", "", "downlink bits per model entry (default: --rate)")
        .opt("downlink-resync", "0", "resync when a reference is staler than this (0 = first contact only)")
        .opt("trace", "", "write round-lifecycle spans to this JSONL file")
        .opt("trace-report", "", "write the per-round telemetry Markdown table here");
    let args = parse_args(&cli, argv)?;
    let population = args.get_usize("population");
    let cohort = args.get_usize("cohort");
    let rounds = args.get_usize("rounds");
    let seed = args.get_usize("seed") as u64;
    let mut workers = args.get_usize("workers");
    if workers == 0 {
        workers = uveqfed::util::threadpool::default_workers();
    }
    let agg_shards = args.get_usize("shards");
    if !(1..=MAX_SHARDS).contains(&agg_shards) {
        return Err(Error::msg(format!(
            "--shards must be in 1..={MAX_SHARDS}, got {agg_shards}"
        )));
    }
    let mut scenario = Scenario::by_name(args.get("scenario"), cohort)?;
    if !args.get("deadline").is_empty() {
        scenario.faults.deadline = Some(args.get_f64("deadline"));
    }
    if !args.get("dropout").is_empty() {
        scenario.faults.dropout = args.get_f64("dropout");
    }
    if !args.get("corrupt").is_empty() {
        let p = args.get_f64("corrupt");
        if !(0.0..=1.0).contains(&p) {
            return Err(Error::msg(format!("--corrupt {p} must be a probability in [0, 1]")));
        }
        scenario.faults.wire.corrupt_prob = p;
    }
    if !args.get("max-retries").is_empty() {
        scenario.faults.wire.max_retries = args.get_usize("max-retries") as u32;
    }

    // Population backed by round-robin template shards: millions of
    // simulated clients without millions of datasets.
    let n_templates = args.get_usize("templates").max(1);
    let per = args.get_usize("samples").max(10);
    let gen = SynthMnist::new(seed);
    let ds = gen.dataset(n_templates * per);
    let test = gen.test_dataset(500);
    let templates = partition(&ds, n_templates, per, PartitionScheme::Iid, seed);
    let trainer = NativeTrainer::new(LogReg::new(ds.features, ds.classes, 1e-3));
    let pool = RoundRobinPool::synthetic(population, templates, seed);

    let codec = quantizer::make(args.get("codec"))?;
    let rate = args.get_f64("rate");
    // Coded downlink: broadcast the global model through its own codec
    // instead of handing clients `w` verbatim.
    let downlink_codec = match args.get("downlink-codec") {
        "" => None,
        spec => Some(quantizer::make(spec)?),
    };
    let downlink_rate = if args.get("downlink-rate").is_empty() {
        rate
    } else {
        args.get_f64("downlink-rate")
    };
    let downlink_resync = args.get_usize("downlink-resync") as u64;
    let mut driver =
        FleetDriver::new(seed, rate, workers, scenario.clone()).with_shards(agg_shards);
    if !args.get("decode-budget").is_empty() {
        let credit = args.get_usize("decode-budget") as u64;
        driver = driver.with_decode_budget(DecodeBudget::units(credit));
    }
    let channel_name = args.get("channel");
    let hetero = !channel_name.is_empty() && channel_name != "uniform";
    if !channel_name.is_empty() {
        let model = ChannelModel::by_name(channel_name, rate)?;
        let controller = controller_by_name(args.get("policy"))?;
        driver = driver.with_rate_plan(RatePlan::new(Channel::new(model, seed), controller));
    }
    let mut clock = VirtualClock::new();
    let mut w = trainer.init_params(seed);

    // Opt-in tracing: size the event ring for the per-round cohort so a
    // round's spans never overflow it, drain once per round.
    let trace_path = args.get("trace").to_string();
    let report_path = args.get("trace-report").to_string();
    let collector = if trace_path.is_empty() && report_path.is_empty() {
        Collector::disabled()
    } else {
        Collector::for_cohort(scenario.sampler.target(population))
    };
    let mut tracer = if trace_path.is_empty() {
        None
    } else {
        Some(TraceWriter::create(&trace_path).context("create trace file")?)
    };
    let mut telemetry_report = TelemetryReport::default();

    println!(
        "fleet: population={population} cohort={cohort} scenario={} codec={} rate={rate} rounds={rounds}{}",
        args.get("scenario"),
        codec.name(),
        if channel_name.is_empty() {
            String::new()
        } else {
            format!(" channel={channel_name} policy={}", args.get("policy"))
        },
    );
    if let Some(dl) = &downlink_codec {
        println!(
            "downlink: codec={} rate={downlink_rate} resync_every={downlink_resync}",
            dl.name()
        );
    }
    println!(
        "{:>5} {:>9} {:>9} {:>7} {:>6} {:>8} {:>9} {:>10} {:>9} {:>17}",
        "round", "selected", "done", "drop", "late", "compl", "αmass", "wireKB", "p95lat",
        "rate min/avg/max"
    );
    let mut wire_total = 0usize;
    let mut downlink_total = 0usize;
    let mut violations = 0usize;
    let mut rejected_total = 0usize;
    let mut retries_total = 0usize;
    for round in 0..rounds {
        let mut spec = RoundSpec {
            round: round as u64,
            local_steps: 1,
            lr: 0.5,
            batch_size: 0,
            trainer: &trainer,
            codec: codec.as_ref(),
            rate_override: None,
            telemetry: Some(&collector),
            client_records: ClientRecords::Full,
            downlink: None,
        };
        if let Some(dl) = &downlink_codec {
            spec = spec.with_downlink(
                DownlinkSpec::new(dl.as_ref(), downlink_rate).with_resync_every(downlink_resync),
            );
        }
        let rep = driver.run_round(&spec, &mut w, &pool, &mut clock);
        wire_total += rep.wire_bytes;
        downlink_total += rep.downlink_bytes;
        violations += rep.budget_violations;
        rejected_total += rep.rejected;
        retries_total += rep.retries;
        if collector.is_enabled() {
            let events = collector.drain();
            let dropped = collector.take_dropped();
            if let Some(t) = tracer.as_mut() {
                t.write_events(&events).context("write trace spans")?;
            }
            for (i, s) in summarize(&events).into_iter().enumerate() {
                if let Some(t) = tracer.as_mut() {
                    t.write_round(&s, if i == 0 { dropped } else { 0 })
                        .context("write trace round line")?;
                }
                telemetry_report.push(s);
            }
        }
        println!(
            "{:>5} {:>9} {:>9} {:>7} {:>6} {:>8.3} {:>9.3} {:>10.1} {:>9.3} {:>5.2}/{:>4.2}/{:>4.2}",
            round,
            rep.selected,
            rep.aggregated,
            rep.dropped,
            rep.late,
            rep.completion_rate,
            rep.alpha_mass,
            rep.wire_bytes as f64 / 1e3,
            rep.timing.p95_latency,
            rep.channel.min_rate,
            rep.channel.mean_rate,
            rep.channel.max_rate,
        );
        if scenario.faults.wire.active() {
            // Quarantine accounting under injected wire faults. Every
            // figure is a pure function of (seed, user, round), so CI
            // diffs this line across worker/shard topologies too.
            println!(
                "      faults: {:>4} rejected  {:>5} retries  {:>8} corrupt bytes  αΣ {:.3}",
                rep.rejected, rep.retries, rep.corrupt_wire_bytes, rep.alpha_sum,
            );
        }
        if downlink_codec.is_some() {
            // Broadcasts run sequentially on the coordinator, so every
            // figure here is bit-identical for any worker/shard count —
            // CI diffs this line across topologies.
            println!(
                "      downlink: {:>10.1} KB  {:>12} bits  {:>6} resyncs  bcast dist {:.3e}",
                rep.downlink_bytes as f64 / 1e3,
                rep.downlink_bits,
                rep.resyncs,
                rep.broadcast_distortion,
            );
        }
        if hetero && round == 0 {
            // Sanity surface for the heterogeneous preset: the allocation
            // must actually be rate-diverse and every coded message must
            // fit its own budget.
            let m = w.len();
            let over = rep
                .clients
                .iter()
                .filter(|c| c.achieved_bits > (c.assigned_rate * m as f64).floor() as usize)
                .count();
            println!(
                "      channel: {} distinct budgets, {} clients over-budget, \
                 capacity mass {:.1} b/entry, assigned {:.1}",
                rep.channel.distinct_budgets, over, rep.channel.capacity_mass,
                rep.channel.assigned_mass,
            );
            // Thm-2 bound of the active policy vs the uniform baseline at
            // equal total bits: uniform strands mass behind capacity caps,
            // so the fair comparison re-runs the active policy at the mass
            // uniform actually spent (same methodology as the tests).
            let folded: Vec<&uveqfed::fleet::ClientRoundRecord> =
                rep.clients.iter().filter(|c| c.achieved_bits > 0).collect();
            let caps: Vec<f64> = folded.iter().map(|c| c.capacity).collect();
            let alphas: Vec<f64> =
                folded.iter().map(|c| pool.weight(c.user as usize)).collect();
            let offered = rate * folded.len() as f64;
            let uni = UniformRate.allocate(&uveqfed::coordinator::AllocRequest {
                capacities: &caps,
                alphas: &alphas,
                total_rate: offered,
            });
            let spent_uni: f64 = uni.iter().sum();
            let plan = driver.rate_plan().expect("hetero implies a rate plan");
            let eq = plan.controller.allocate(&uveqfed::coordinator::AllocRequest {
                capacities: &caps,
                alphas: &alphas,
                total_rate: spent_uni,
            });
            let b_policy = thm2_bound_for_allocation(&eq, &alphas, m);
            let b_uniform = thm2_bound_for_allocation(&uni, &alphas, m);
            println!(
                "      thm2 aggregate bound: {} {:.3e} vs uniform {:.3e} at {:.1} b/entry total",
                args.get("policy"),
                b_policy,
                b_uniform,
                spent_uni
            );
        }
    }
    if let Some(mut t) = tracer {
        t.flush().context("flush trace")?;
        println!("trace → {trace_path}");
    }
    if !report_path.is_empty() {
        std::fs::write(&report_path, telemetry_report.to_markdown())
            .context("write trace report")?;
        println!("trace report → {report_path}");
    }
    let eval = trainer.evaluate(&w, &test);
    println!(
        "\nfinal: acc {:.4}  loss {:.4}  virtual time {:.2}s  wire {:.2} MB  budget violations {violations}{}{}",
        eval.accuracy,
        eval.loss,
        clock.now(),
        wire_total as f64 / 1e6,
        if scenario.faults.wire.active() {
            format!("  rejected {rejected_total}  retries {retries_total}")
        } else {
            String::new()
        },
        if downlink_codec.is_some() {
            format!("  downlink {:.2} MB", downlink_total as f64 / 1e6)
        } else {
            String::new()
        },
    );
    Ok(())
}

fn cmd_distort(argv: &[String]) -> uveqfed::Result<()> {
    let cli = Cli::new("uveqfed distort", "measure codec distortion on Gaussian data")
        .opt("codec", "uveqfed-l2", "codec spec (name[:key=value,...])")
        .opt("rate", "2", "bits per entry")
        .opt("size", "128", "matrix side (size×size entries)")
        .opt("trials", "10", "averaging trials")
        .flag("correlated", "use ΣHΣᵀ correlated data (Fig. 5)");
    let args = parse_args(&cli, argv)?;
    let codec = quantizer::make(args.get("codec"))?;
    let rate = args.get_f64("rate");
    let n = args.get_usize("size");
    let trials = args.get_usize("trials");
    let mut mse = 0.0;
    let mut bpe = 0.0;
    for t in 0..trials {
        let mut h = uveqfed::data::gaussian_matrix(n, 1000 + t as u64);
        if args.has_flag("correlated") {
            let sigma = uveqfed::data::exp_decay_sigma(n, 0.2);
            h = uveqfed::data::correlated_matrix(&h, &sigma, n);
        }
        let rep = quantizer::measure_distortion(codec.as_ref(), &h, rate, 7, t as u64);
        mse += rep.mse / trials as f64;
        bpe += rep.bits_per_entry / trials as f64;
    }
    println!(
        "codec={} rate={rate} size={n}x{n} trials={trials}\n  per-entry MSE {mse:.6e}\n  bits/entry  {bpe:.4}",
        codec.name()
    );
    Ok(())
}

fn cmd_info() -> uveqfed::Result<()> {
    println!("uveqfed info");
    println!("lattices:");
    for name in ["scalar", "hex", "hex-a2", "cubic2", "d4", "e8"] {
        let lat = lattice::by_name(name)?;
        println!(
            "  {name:<8} L={} det={:.4} σ̄²={:.6} G(Λ)={:.6}",
            lat.dim(),
            lat.cell_volume(),
            lat.second_moment(),
            lattice::moment::dimensionless_g(lat.as_ref()),
        );
    }
    println!(
        "codecs: uveqfed-l1/-l2/-l4/-l8, qsgd, rotation, subsample, terngrad, signsgd, topk, fedvqcs, identity"
    );
    println!("codec spec grammar: name[:key=value,...] — see `quantizer::CodecSpec`");
    print!("artifacts: ");
    if runtime::artifacts_available() {
        println!("available at {:?}", runtime::artifacts_dir());
    } else {
        println!("NOT built (run `make artifacts`)");
    }
    Ok(())
}
