//! Pseudo-randomness substrate.
//!
//! UVeQFed's assumption **A3** is that the server and each user share a
//! *source of common randomness* (a seed conveyed alongside the model).
//! Everything stochastic in this repository — dither generation, data
//! synthesis, SGD sample draws, baseline codec randomness — flows through
//! this module so that (i) the encoder and decoder can regenerate the exact
//! same dither stream from the shared seed, and (ii) every experiment is
//! bit-reproducible from its config.
//!
//! The generator is xoshiro256++ seeded via SplitMix64 (the reference
//! seeding procedure from Blackman & Vigna). No external crates: the image
//! is offline, and rolling our own keeps the dither stream specification
//! part of the wire format.

mod xoshiro;
mod common;
mod distributions;

pub use xoshiro::{SplitMix64, Xoshiro256pp};
pub use common::{CommonRandomness, StreamKind};
pub use distributions::Normal;

/// Minimal RNG interface used across the workspace.
pub trait Rng {
    /// Next raw 64 random bits.
    fn next_u64(&mut self) -> u64;

    /// Next 32 random bits.
    fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    fn uniform(&mut self) -> f64 {
        // Standard 53-bit mantissa trick.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    fn uniform_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Unbiased uniform integer in `[0, n)` via Lemire-style rejection.
    fn gen_index(&mut self, n: usize) -> usize {
        assert!(n > 0, "gen_index: empty range");
        let n = n as u64;
        // Rejection sampling on the top bits to avoid modulo bias.
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (cached second value not kept to stay
    /// object-safe; cost is acceptable off the hot path).
    fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Standard normal as f32.
    fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Random sign in {-1.0, +1.0}.
    fn sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_in_range() {
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_half() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| rng.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn gen_index_unbiased_small() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let mut counts = [0usize; 5];
        let n = 100_000;
        for _ in 0..n {
            counts[rng.gen_index(5)] += 1;
        }
        for c in counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.2).abs() < 0.01, "frac={frac}");
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let s = rng.sample_indices(100, 30);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
