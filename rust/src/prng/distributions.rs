//! Distribution helpers layered on [`Rng`].

use super::Rng;

/// Gaussian with configurable mean / std-dev.
#[derive(Debug, Clone, Copy)]
pub struct Normal {
    pub mean: f64,
    pub std: f64,
}

impl Normal {
    pub fn new(mean: f64, std: f64) -> Self {
        assert!(std >= 0.0);
        Self { mean, std }
    }

    pub fn sample<R: Rng + ?Sized>(&self, rng: &mut R) -> f64 {
        self.mean + self.std * rng.normal()
    }

    /// Fill a slice with i.i.d. samples (f32).
    pub fn fill_f32<R: Rng + ?Sized>(&self, rng: &mut R, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.sample(rng) as f32;
        }
    }

    pub fn vec_f32<R: Rng + ?Sized>(&self, rng: &mut R, n: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        self.fill_f32(rng, &mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Xoshiro256pp;

    #[test]
    fn normal_scaling() {
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let d = Normal::new(3.0, 2.0);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| d.sample(&mut rng)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05);
        assert!((var - 4.0).abs() < 0.1);
    }
}
