//! xoshiro256++ and SplitMix64 (Blackman & Vigna reference algorithms).

use super::Rng;

/// SplitMix64 — used to expand a single u64 seed into the xoshiro state and
/// to derive hierarchical sub-seeds (round/user streams).
#[derive(Debug, Clone, Copy)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

impl Rng for SplitMix64 {
    fn next_u64(&mut self) -> u64 {
        self.next()
    }
}

/// xoshiro256++ — fast, high-quality 256-bit-state generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 expansion (the recommended procedure).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next(), sm.next(), sm.next(), sm.next()];
        Self { s }
    }

    /// Construct from explicit state (must not be all-zero).
    pub fn from_state(s: [u64; 4]) -> Self {
        assert!(s.iter().any(|&x| x != 0), "xoshiro state must be non-zero");
        Self { s }
    }

    /// Jump function: equivalent to 2^128 `next()` calls. Used to derive
    /// non-overlapping parallel streams from one seed.
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] = [
            0x180EC6D33CFD0ABA,
            0xD5A61266F0C9392C,
            0xA9582618E03FC9AA,
            0x39ABDC4529B1661C,
        ];
        let mut t = [0u64; 4];
        for j in JUMP {
            for b in 0..64 {
                if (j >> b) & 1 == 1 {
                    t[0] ^= self.s[0];
                    t[1] ^= self.s[1];
                    t[2] ^= self.s[2];
                    t[3] ^= self.s[3];
                }
                self.next_u64();
            }
        }
        self.s = t;
    }
}

impl Rng for Xoshiro256pp {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference outputs for seed 1234567 (computed from the published
        // reference C implementation).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next();
        let b = sm.next();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(sm2.next(), a);
        assert_eq!(sm2.next(), b);
    }

    #[test]
    fn xoshiro_deterministic() {
        let mut a = Xoshiro256pp::seed_from_u64(42);
        let mut b = Xoshiro256pp::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xoshiro_differs_across_seeds() {
        let mut a = Xoshiro256pp::seed_from_u64(1);
        let mut b = Xoshiro256pp::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn jump_produces_disjoint_stream_prefix() {
        let mut a = Xoshiro256pp::seed_from_u64(7);
        let mut b = a.clone();
        b.jump();
        let eq = (0..1000).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(eq, 0);
    }

    #[test]
    #[should_panic]
    fn zero_state_rejected() {
        let _ = Xoshiro256pp::from_state([0; 4]);
    }
}
