//! The shared-seed *common randomness* of assumption A3.
//!
//! The server hands each user a distinct seed once at enrollment; from then
//! on both sides derive, per (round, tensor) pair, an identical dither
//! stream. The derivation is a pure function of `(root_seed, user, round,
//! stream)` so encoder and decoder never need to exchange randomness again
//! — exactly the "share a random seed along with the weights" protocol the
//! paper describes.

use super::{SplitMix64, Xoshiro256pp};

/// Factory for per-(user, round, stream) RNGs shared by server and client.
#[derive(Debug, Clone, Copy)]
pub struct CommonRandomness {
    root_seed: u64,
}

/// Identifies independent sub-streams within one (user, round) context.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamKind {
    /// Dither vectors for UVeQFed (E2/D2).
    Dither = 1,
    /// Probabilistic rounding randomness for QSGD-style codecs.
    Rounding = 2,
    /// Random rotation / Hadamard sign flips for the rotation codec.
    Rotation = 3,
    /// Subsampling mask selection.
    Mask = 4,
    /// Per-round cohort selection (`fleet::sampler`; user coordinate is a
    /// sentinel — one stream per round, shared by the whole population).
    Cohort = 5,
    /// Per-(client, round) simulated uplink latency (`fleet::faults`).
    Latency = 6,
    /// Per-(client, round) dropout draw (`fleet::faults`).
    Dropout = 7,
    /// Per-(client, round) uplink-capacity draw (`fleet::channel`): tier
    /// assignment, log-normal bandwidth, Markov fading transitions.
    Channel = 8,
    /// Per-(client, round) wire-corruption draws (`fleet::faults`): whether
    /// each transmit attempt corrupts, which corruption mode, and the
    /// affected bit/byte positions.
    WireFault = 9,
    /// Gaussian sketch matrix for the fedvqcs compressed-sensing codec:
    /// encoder and decoder regenerate the same projection `A` row by row
    /// from this stream, so `A` never travels on the wire.
    Sketch = 10,
}

impl CommonRandomness {
    pub fn new(root_seed: u64) -> Self {
        Self { root_seed }
    }

    pub fn root_seed(&self) -> u64 {
        self.root_seed
    }

    /// Derive the seed for `(user, round, stream)`. Mixing is done by
    /// feeding the coordinates through SplitMix64 sequentially; SplitMix64
    /// is a bijective avalanche mix, so distinct coordinate tuples yield
    /// (with overwhelming probability) distinct well-spread seeds.
    pub fn derive_seed(&self, user: u64, round: u64, stream: StreamKind) -> u64 {
        let mut sm = SplitMix64::new(self.root_seed ^ 0xA5A5_5A5A_0F0F_F0F0);
        let a = sm.next();
        let mut sm2 = SplitMix64::new(a ^ user.wrapping_mul(0x9E3779B97F4A7C15));
        let b = sm2.next();
        let mut sm3 = SplitMix64::new(b ^ round.wrapping_mul(0xC2B2AE3D27D4EB4F));
        let c = sm3.next();
        let mut sm4 = SplitMix64::new(c ^ (stream as u64).wrapping_mul(0x165667B19E3779F9));
        sm4.next()
    }

    /// RNG for a given `(user, round, stream)` — identical on both sides.
    pub fn stream(&self, user: u64, round: u64, stream: StreamKind) -> Xoshiro256pp {
        Xoshiro256pp::seed_from_u64(self.derive_seed(user, round, stream))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    #[test]
    fn server_and_client_agree() {
        let server = CommonRandomness::new(99);
        let client = CommonRandomness::new(99);
        let mut a = server.stream(3, 17, StreamKind::Dither);
        let mut b = client.stream(3, 17, StreamKind::Dither);
        for _ in 0..256 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ_by_user_round_kind() {
        let cr = CommonRandomness::new(5);
        let base = cr.derive_seed(1, 1, StreamKind::Dither);
        assert_ne!(base, cr.derive_seed(2, 1, StreamKind::Dither));
        assert_ne!(base, cr.derive_seed(1, 2, StreamKind::Dither));
        assert_ne!(base, cr.derive_seed(1, 1, StreamKind::Rounding));
    }

    #[test]
    fn derivation_spreads_over_adjacent_coordinates() {
        // Adjacent (user, round) tuples should give seeds whose streams are
        // decorrelated — check first outputs differ in ≥ 20 of 64 bits on
        // average (avalanche sanity, not a strict randomness test).
        let cr = CommonRandomness::new(123);
        let mut total = 0u32;
        let n = 64;
        for u in 0..n {
            let s1 = cr.derive_seed(u, 0, StreamKind::Dither);
            let s2 = cr.derive_seed(u + 1, 0, StreamKind::Dither);
            total += (s1 ^ s2).count_ones();
        }
        let avg = total as f64 / n as f64;
        assert!(avg > 20.0, "avg bit flips {avg}");
    }
}
