//! ℓ2-regularized multinomial logistic regression.
//!
//! With regularizer λ > 0 the objective is λ-strongly convex and
//! (λ + ¼·max‖x‖²)-smooth — it satisfies AS2–AS3 exactly, making it the
//! workload for the Theorem 3 convergence experiments. The constants
//! `rho_c()` / `rho_s()` feed the theoretical bound evaluator in
//! `theory::`.

use super::{EvalReport, Model};
use crate::data::Dataset;
use crate::prng::{Rng, Xoshiro256pp};

#[derive(Debug, Clone)]
pub struct LogReg {
    features: usize,
    classes: usize,
    /// ℓ2 regularization weight λ.
    pub lambda: f32,
}

impl LogReg {
    pub fn new(features: usize, classes: usize, lambda: f32) -> Self {
        assert!(lambda >= 0.0);
        Self { features, classes, lambda }
    }

    /// Strong-convexity constant ρ_c = λ.
    pub fn rho_c(&self) -> f64 {
        self.lambda as f64
    }

    /// Smoothness constant ρ_s ≤ λ + ¼·max_i‖x_i‖² (softmax Hessian bound).
    pub fn rho_s(&self, ds: &Dataset) -> f64 {
        let max_sq = (0..ds.len())
            .map(|i| {
                let (x, _) = ds.sample(i);
                x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
            })
            .fold(0.0f64, f64::max);
        self.lambda as f64 + 0.25 * max_sq
    }

    fn logits(&self, w: &[f32], x: &[f32], out: &mut [f32]) {
        let (d, c) = (self.features, self.classes);
        for j in 0..c {
            let wj = &w[j * d..(j + 1) * d];
            let b = w[c * d + j];
            let mut s = b;
            for (a, b) in x.iter().zip(wj) {
                s += a * b;
            }
            out[j] = s;
        }
    }
}

fn softmax_inplace(z: &mut [f32]) {
    let max = z.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in z.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    for v in z.iter_mut() {
        *v /= sum;
    }
}

impl Model for LogReg {
    fn num_params(&self) -> usize {
        self.classes * self.features + self.classes
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        (0..self.num_params()).map(|_| rng.normal_f32() * 0.01).collect()
    }

    fn gradient(&self, w: &[f32], ds: &Dataset, batch: &[usize], grad: &mut [f32]) {
        let (d, c) = (self.features, self.classes);
        grad.fill(0.0);
        let mut z = vec![0.0f32; c];
        let inv_n = 1.0 / batch.len() as f32;
        for &i in batch {
            let (x, y) = ds.sample(i);
            self.logits(w, x, &mut z);
            softmax_inplace(&mut z);
            for j in 0..c {
                let coef = (z[j] - if j == y as usize { 1.0 } else { 0.0 }) * inv_n;
                if coef == 0.0 {
                    continue;
                }
                let gj = &mut grad[j * d..(j + 1) * d];
                for (g, &xv) in gj.iter_mut().zip(x) {
                    *g += coef * xv;
                }
                grad[c * d + j] += coef;
            }
        }
        // ℓ2 term
        if self.lambda > 0.0 {
            for (g, &wv) in grad.iter_mut().zip(w) {
                *g += self.lambda * wv;
            }
        }
    }

    fn evaluate(&self, w: &[f32], ds: &Dataset) -> EvalReport {
        let c = self.classes;
        let mut z = vec![0.0f32; c];
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        for i in 0..ds.len() {
            let (x, y) = ds.sample(i);
            self.logits(w, x, &mut z);
            let max = z.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse: f32 = z.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
            loss += (lse - z[y as usize]) as f64;
            let pred = z
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == y as usize {
                correct += 1;
            }
        }
        loss /= ds.len() as f64;
        loss += 0.5 * self.lambda as f64 * w.iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
        EvalReport { loss, accuracy: correct as f64 / ds.len() as f64 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthMnist;
    use crate::models::finite_diff_check;

    #[test]
    fn gradient_matches_finite_difference() {
        let ds = SynthMnist::new(2).dataset(30);
        let m = LogReg::new(ds.features, ds.classes, 1e-2);
        let w = m.init_params(7);
        let probes: Vec<usize> = (0..m.num_params()).step_by(m.num_params() / 17).collect();
        finite_diff_check(&m, &ds, &w, &probes, 0.05);
    }

    #[test]
    fn gd_decreases_loss_and_learns() {
        let ds = SynthMnist::new(2).dataset(200);
        let m = LogReg::new(ds.features, ds.classes, 1e-3);
        let mut w = m.init_params(7);
        let batch: Vec<usize> = (0..ds.len()).collect();
        let mut grad = vec![0.0f32; m.num_params()];
        let l0 = m.evaluate(&w, &ds).loss;
        for _ in 0..60 {
            m.gradient(&w, &ds, &batch, &mut grad);
            for (wv, g) in w.iter_mut().zip(&grad) {
                *wv -= 0.5 * g;
            }
        }
        let rep = m.evaluate(&w, &ds);
        assert!(rep.loss < l0, "{} !< {l0}", rep.loss);
        assert!(rep.accuracy > 0.8, "train acc {}", rep.accuracy);
    }

    #[test]
    fn strong_convexity_constant_positive() {
        let ds = SynthMnist::new(2).dataset(10);
        let m = LogReg::new(ds.features, ds.classes, 0.05);
        assert!((m.rho_c() - 0.05).abs() < 1e-7);
        assert!(m.rho_s(&ds) > m.rho_c());
    }
}
