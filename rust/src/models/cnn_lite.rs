//! A compact convolutional classifier (conv5×5 → ReLU → maxpool2 → FC →
//! softmax) with hand-written backprop.
//!
//! Role: CPU-cheap conv-net oracle for tests and the artifact-free
//! fallback of the CIFAR benches. The full 5-layer architecture of §V-B
//! lives in `python/compile/model.py` (JAX autodiff) and runs through the
//! PJRT runtime.

use super::{EvalReport, Model};
use crate::data::Dataset;
use crate::prng::{Normal, Xoshiro256pp};

#[derive(Debug, Clone)]
pub struct CnnLite {
    pub side: usize,
    pub in_ch: usize,
    pub filters: usize,
    pub ksize: usize,
    pub classes: usize,
}

impl CnnLite {
    /// CIFAR-shaped default: 32×32×3 input, 8 filters of 5×5, 10 classes.
    pub fn cifar() -> Self {
        Self { side: 32, in_ch: 3, filters: 8, ksize: 5, classes: 10 }
    }

    fn conv_out(&self) -> usize {
        self.side - self.ksize + 1
    }

    fn pool_out(&self) -> usize {
        self.conv_out() / 2
    }

    fn flat_dim(&self) -> usize {
        self.pool_out() * self.pool_out() * self.filters
    }

    fn wk_len(&self) -> usize {
        self.filters * self.in_ch * self.ksize * self.ksize
    }

    /// Param layout: [conv W (F·C·k·k) | conv b (F) | fc W (flat·classes) |
    /// fc b (classes)].
    fn split<'a>(&self, w: &'a [f32]) -> (&'a [f32], &'a [f32], &'a [f32], &'a [f32]) {
        let wk = self.wk_len();
        let f = self.filters;
        let fc = self.flat_dim() * self.classes;
        (
            &w[0..wk],
            &w[wk..wk + f],
            &w[wk + f..wk + f + fc],
            &w[wk + f + fc..],
        )
    }

    /// Forward one sample. Returns (conv pre-activations, pooled+flattened
    /// activations with argmax indices for pool backprop, probs).
    fn forward_sample(
        &self,
        w: &[f32],
        x: &[f32],
    ) -> (Vec<f32>, Vec<f32>, Vec<usize>, Vec<f32>) {
        let (wc, bc, wf, bf) = self.split(w);
        let (s, c_in, f, k) = (self.side, self.in_ch, self.filters, self.ksize);
        let co = self.conv_out();
        let po = self.pool_out();

        // conv + ReLU
        let mut conv = vec![0.0f32; f * co * co];
        for fo in 0..f {
            for oy in 0..co {
                for ox in 0..co {
                    let mut acc = bc[fo];
                    for ci in 0..c_in {
                        let base_w = ((fo * c_in) + ci) * k * k;
                        let base_x = ci * s * s;
                        for ky in 0..k {
                            let xrow = base_x + (oy + ky) * s + ox;
                            let wrow = base_w + ky * k;
                            for kx in 0..k {
                                acc += x[xrow + kx] * wc[wrow + kx];
                            }
                        }
                    }
                    conv[fo * co * co + oy * co + ox] = acc;
                }
            }
        }
        // ReLU + 2×2 maxpool, remembering argmax for backprop
        let mut pooled = vec![0.0f32; f * po * po];
        let mut arg = vec![0usize; f * po * po];
        for fo in 0..f {
            for py in 0..po {
                for px in 0..po {
                    let mut best = f32::NEG_INFINITY;
                    let mut best_i = 0;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            let i = fo * co * co + (2 * py + dy) * co + (2 * px + dx);
                            let v = conv[i].max(0.0);
                            if v > best {
                                best = v;
                                best_i = i;
                            }
                        }
                    }
                    pooled[fo * po * po + py * po + px] = best;
                    arg[fo * po * po + py * po + px] = best_i;
                }
            }
        }
        // FC + softmax
        let mut z = vec![0.0f32; self.classes];
        for j in 0..self.classes {
            let mut acc = bf[j];
            for (i, &p) in pooled.iter().enumerate() {
                acc += p * wf[i * self.classes + j];
            }
            z[j] = acc;
        }
        let max = z.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in z.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in z.iter_mut() {
            *v /= sum;
        }
        (conv, pooled, arg, z)
    }
}

impl Model for CnnLite {
    fn num_params(&self) -> usize {
        self.wk_len() + self.filters + self.flat_dim() * self.classes + self.classes
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut w = Vec::with_capacity(self.num_params());
        let fan_in = self.in_ch * self.ksize * self.ksize;
        let gk = Normal::new(0.0, (2.0 / fan_in as f64).sqrt());
        w.extend(gk.vec_f32(&mut rng, self.wk_len()));
        w.extend(std::iter::repeat(0.0f32).take(self.filters));
        let gf = Normal::new(0.0, (2.0 / (self.flat_dim() + self.classes) as f64).sqrt());
        w.extend(gf.vec_f32(&mut rng, self.flat_dim() * self.classes));
        w.extend(std::iter::repeat(0.0f32).take(self.classes));
        w
    }

    fn gradient(&self, w: &[f32], ds: &Dataset, batch: &[usize], grad: &mut [f32]) {
        grad.fill(0.0);
        let (s, c_in, f, k) = (self.side, self.in_ch, self.filters, self.ksize);
        let co = self.conv_out();
        let flat = self.flat_dim();
        let (_, _, wf, _) = self.split(w);
        let wk = self.wk_len();
        let inv_n = 1.0 / batch.len() as f32;

        for &bi in batch {
            let (x, y) = ds.sample(bi);
            let (conv, pooled, arg, probs) = self.forward_sample(w, x);
            // dz (classes)
            let mut dz = probs;
            dz[y as usize] -= 1.0;
            for v in dz.iter_mut() {
                *v *= inv_n;
            }
            // FC grads + dpool
            let (gwf_off, gbf_off) = (wk + f, wk + f + flat * self.classes);
            let mut dpool = vec![0.0f32; flat];
            for (i, &p) in pooled.iter().enumerate() {
                let row = &mut grad[gwf_off + i * self.classes..gwf_off + (i + 1) * self.classes];
                let mut acc = 0.0f32;
                for j in 0..self.classes {
                    row[j] += p * dz[j];
                    acc += wf[i * self.classes + j] * dz[j];
                }
                dpool[i] = acc;
            }
            for j in 0..self.classes {
                grad[gbf_off + j] += dz[j];
            }
            // pool + ReLU backward → dconv (sparse at argmax)
            let mut dconv = vec![0.0f32; f * co * co];
            for (pi, &ci) in arg.iter().enumerate() {
                if conv[ci] > 0.0 {
                    dconv[ci] += dpool[pi];
                }
            }
            // conv backward: accumulate weight + bias grads
            for fo in 0..f {
                let mut gb = 0.0f32;
                for oy in 0..co {
                    for ox in 0..co {
                        let d = dconv[fo * co * co + oy * co + ox];
                        if d == 0.0 {
                            continue;
                        }
                        gb += d;
                        for ci in 0..c_in {
                            let base_w = ((fo * c_in) + ci) * k * k;
                            let base_x = ci * s * s;
                            for ky in 0..k {
                                let xrow = base_x + (oy + ky) * s + ox;
                                let wrow = base_w + ky * k;
                                for kx in 0..k {
                                    grad[wrow + kx] += d * x[xrow + kx];
                                }
                            }
                        }
                    }
                }
                grad[wk + fo] += gb;
            }
        }
    }

    fn evaluate(&self, w: &[f32], ds: &Dataset) -> EvalReport {
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        for i in 0..ds.len() {
            let (x, y) = ds.sample(i);
            let (_, _, _, probs) = self.forward_sample(w, x);
            let p = probs[y as usize].max(1e-12);
            loss += -(p as f64).ln();
            let pred = probs
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == y as usize {
                correct += 1;
            }
        }
        EvalReport {
            loss: loss / ds.len() as f64,
            accuracy: correct as f64 / ds.len() as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthCifar;
    use crate::models::finite_diff_check;
    use crate::prng::Rng;

    fn tiny() -> (CnnLite, Dataset) {
        // shrink everything for test speed
        let model = CnnLite { side: 12, in_ch: 1, filters: 3, ksize: 3, classes: 4 };
        // build a matching synthetic dataset: 12×12 single channel
        let mut x = Vec::new();
        let mut y = Vec::new();
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        for i in 0..24 {
            let cls = i % 4;
            for p in 0..144 {
                let v = if (p / 12 + p % 12 + cls * 3) % 7 < 2 { 0.9 } else { 0.05 };
                x.push(v + rng.normal_f32() * 0.05);
            }
            y.push(cls as u8);
        }
        (model, Dataset { x, y, features: 144, classes: 4 })
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let (m, ds) = tiny();
        let w = m.init_params(3);
        let probes: Vec<usize> =
            (0..m.num_params()).step_by((m.num_params() / 19).max(1)).collect();
        finite_diff_check(&m, &ds, &w, &probes, 0.12);
    }

    #[test]
    fn learns_the_tiny_task() {
        let (m, ds) = tiny();
        let mut w = m.init_params(3);
        let batch: Vec<usize> = (0..ds.len()).collect();
        let mut grad = vec![0.0f32; m.num_params()];
        let l0 = m.evaluate(&w, &ds).loss;
        for _ in 0..60 {
            m.gradient(&w, &ds, &batch, &mut grad);
            for (wv, g) in w.iter_mut().zip(&grad) {
                *wv -= 0.3 * g;
            }
        }
        assert!(m.evaluate(&w, &ds).loss < l0 * 0.8);
    }

    #[test]
    fn cifar_shape_params() {
        let m = CnnLite::cifar();
        // 8·3·25 + 8 + (14·14·8)·10 + 10 = 600+8+15680+10
        assert_eq!(m.num_params(), 16_298);
        let ds = SynthCifar::new(1).dataset(10);
        let w = m.init_params(1);
        let rep = m.evaluate(&w, &ds);
        assert!(rep.loss.is_finite());
    }
}
