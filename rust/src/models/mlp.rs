//! The §V-B MNIST architecture: fully-connected 784–H–10 with sigmoid
//! hidden activation and softmax cross-entropy output (H = 50 in the
//! paper). Native forward/backward; mirrors `python/compile/model.py`
//! exactly so the HLO path can be cross-validated against it.
//!
//! Parameter layout (flat vector): `[w1 (784·H) | b1 (H) | w2 (H·10) |
//! b2 (10)]`, matching the JAX side's `flatten_params` order.

use super::{EvalReport, Model};
use crate::data::Dataset;
use crate::prng::{Normal, Xoshiro256pp};
use crate::tensor::Matrix;

#[derive(Debug, Clone)]
pub struct MlpMnist {
    pub input: usize,
    pub hidden: usize,
    pub output: usize,
}

impl MlpMnist {
    pub fn new(hidden: usize) -> Self {
        Self { input: 784, hidden, output: 10 }
    }

    pub fn with_dims(input: usize, hidden: usize, output: usize) -> Self {
        Self { input, hidden, output }
    }

    fn split<'a>(&self, w: &'a [f32]) -> (&'a [f32], &'a [f32], &'a [f32], &'a [f32]) {
        let (i, h, o) = (self.input, self.hidden, self.output);
        let w1 = &w[0..i * h];
        let b1 = &w[i * h..i * h + h];
        let w2 = &w[i * h + h..i * h + h + h * o];
        let b2 = &w[i * h + h + h * o..];
        (w1, b1, w2, b2)
    }

    /// Forward pass for a batch: returns (hidden activations, probs).
    fn forward(&self, w: &[f32], x: &Matrix) -> (Matrix, Matrix) {
        let (i, h, o) = (self.input, self.hidden, self.output);
        let (w1, b1, w2, b2) = self.split(w);
        let w1m = Matrix::from_vec(i, h, w1.to_vec());
        let w2m = Matrix::from_vec(h, o, w2.to_vec());
        let mut a1 = x.matmul(&w1m);
        a1.add_row_vec(b1);
        let a1 = crate::tensor::sigmoid(&a1);
        let mut z2 = a1.matmul(&w2m);
        z2.add_row_vec(b2);
        let probs = crate::tensor::softmax_rows(&z2);
        (a1, probs)
    }

    fn batch_matrix(&self, ds: &Dataset, batch: &[usize]) -> (Matrix, Vec<u8>) {
        let mut x = Vec::with_capacity(batch.len() * ds.features);
        let mut y = Vec::with_capacity(batch.len());
        for &i in batch {
            let (xi, yi) = ds.sample(i);
            x.extend_from_slice(xi);
            y.push(yi);
        }
        (Matrix::from_vec(batch.len(), ds.features, x), y)
    }
}

impl Model for MlpMnist {
    fn num_params(&self) -> usize {
        let (i, h, o) = (self.input, self.hidden, self.output);
        i * h + h + h * o + o
    }

    fn init_params(&self, seed: u64) -> Vec<f32> {
        let (i, h, o) = (self.input, self.hidden, self.output);
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut w = Vec::with_capacity(self.num_params());
        // Glorot for each weight matrix, zeros for biases — the same init
        // aot.py bakes into the artifacts.
        let g1 = Normal::new(0.0, (2.0 / (i + h) as f64).sqrt());
        w.extend(g1.vec_f32(&mut rng, i * h));
        w.extend(std::iter::repeat(0.0f32).take(h));
        let g2 = Normal::new(0.0, (2.0 / (h + o) as f64).sqrt());
        w.extend(g2.vec_f32(&mut rng, h * o));
        w.extend(std::iter::repeat(0.0f32).take(o));
        w
    }

    fn gradient(&self, w: &[f32], ds: &Dataset, batch: &[usize], grad: &mut [f32]) {
        let (i, h, o) = (self.input, self.hidden, self.output);
        let n = batch.len();
        let (x, y) = self.batch_matrix(ds, batch);
        let (a1, probs) = self.forward(w, &x);
        // dz2 = (probs − onehot)/n
        let mut dz2 = probs;
        for (r, &yi) in y.iter().enumerate() {
            let v = dz2.get(r, yi as usize);
            dz2.set(r, yi as usize, v - 1.0);
        }
        dz2.map_inplace(|v| v / n as f32);
        let (_, _, w2, _) = self.split(w);
        let w2m = Matrix::from_vec(h, o, w2.to_vec());
        // grads
        let gw2 = a1.t_matmul(&dz2); // h×o
        let gb2 = dz2.col_sums();
        let da1 = dz2.matmul_t(&w2m); // n×h
        let dz1 = da1.hadamard(&crate::tensor::sigmoid_grad(&a1));
        let gw1 = x.t_matmul(&dz1); // i×h
        let gb1 = dz1.col_sums();

        grad[0..i * h].copy_from_slice(gw1.data());
        grad[i * h..i * h + h].copy_from_slice(&gb1);
        grad[i * h + h..i * h + h + h * o].copy_from_slice(gw2.data());
        grad[i * h + h + h * o..].copy_from_slice(&gb2);
    }

    fn evaluate(&self, w: &[f32], ds: &Dataset) -> EvalReport {
        let batch: Vec<usize> = (0..ds.len()).collect();
        // chunk to bound memory
        let mut loss = 0.0f64;
        let mut correct = 0usize;
        for chunk in batch.chunks(512) {
            let (x, y) = self.batch_matrix(ds, chunk);
            let (_, probs) = self.forward(w, &x);
            for (r, &yi) in y.iter().enumerate() {
                let p = probs.get(r, yi as usize).max(1e-12);
                loss += -(p as f64).ln();
                let pred = probs
                    .row(r)
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred == yi as usize {
                    correct += 1;
                }
            }
        }
        EvalReport {
            loss: loss / ds.len() as f64,
            accuracy: correct as f64 / ds.len() as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthMnist;
    use crate::models::finite_diff_check;

    #[test]
    fn param_count_matches_paper() {
        // 784·50 + 50 + 50·10 + 10 = 39,760 parameters.
        assert_eq!(MlpMnist::new(50).num_params(), 39_760);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let ds = SynthMnist::new(4).dataset(20);
        let m = MlpMnist::new(8); // small hidden for speed
        let w = m.init_params(9);
        let probes: Vec<usize> =
            (0..m.num_params()).step_by(m.num_params() / 23).collect();
        finite_diff_check(&m, &ds, &w, &probes, 0.08);
    }

    #[test]
    fn training_reduces_loss() {
        let ds = SynthMnist::new(4).dataset(200);
        let m = MlpMnist::new(16);
        let mut w = m.init_params(9);
        let batch: Vec<usize> = (0..ds.len()).collect();
        let mut grad = vec![0.0f32; m.num_params()];
        let l0 = m.evaluate(&w, &ds).loss;
        for _ in 0..80 {
            m.gradient(&w, &ds, &batch, &mut grad);
            for (wv, g) in w.iter_mut().zip(&grad) {
                *wv -= 0.5 * g;
            }
        }
        let rep = m.evaluate(&w, &ds);
        assert!(rep.loss < l0 * 0.8, "{} vs {l0}", rep.loss);
        assert!(rep.accuracy > 0.5, "acc {}", rep.accuracy);
    }

    #[test]
    fn init_is_deterministic() {
        let m = MlpMnist::new(50);
        assert_eq!(m.init_params(3), m.init_params(3));
        assert_ne!(m.init_params(3), m.init_params(4));
    }
}
