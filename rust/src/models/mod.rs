//! Native model implementations.
//!
//! The *production* training path executes the AOT-compiled JAX graphs
//! through `runtime::` (L2/L1 of the stack). The models here are pure-Rust
//! and serve three roles:
//!
//! 1. **Theory workloads** — [`LogReg`] is ρ_c-strongly convex + ρ_s-smooth
//!    (assumptions AS2–AS3), the setting where Theorem 3's O(1/t) bound
//!    applies verbatim;
//! 2. **Oracles** — [`MlpMnist`] mirrors the §V-B MNIST architecture
//!    (784–50–10, sigmoid) and cross-checks the HLO path numerics;
//! 3. **Fallbacks** — [`CnnLite`] is a small conv net used by tests and by
//!    the CIFAR benches when artifacts are unavailable.
//!
//! All models share the flat-parameter [`Model`] interface the federated
//! runtime consumes: weights are one `Vec<f32>`, gradients likewise — the
//! shape the update codecs quantize.

mod cnn_lite;
mod logreg;
mod mlp;

pub use cnn_lite::CnnLite;
pub use logreg::LogReg;
pub use mlp::MlpMnist;

use crate::data::Dataset;

/// Evaluation summary on a dataset.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalReport {
    pub loss: f64,
    pub accuracy: f64,
}

/// A differentiable classifier over flat parameter vectors.
pub trait Model: Send + Sync {
    fn num_params(&self) -> usize;

    /// Deterministic initialization.
    fn init_params(&self, seed: u64) -> Vec<f32>;

    /// Average gradient of the loss over `batch` (indices into `ds`),
    /// written into `grad` (len = num_params).
    fn gradient(&self, w: &[f32], ds: &Dataset, batch: &[usize], grad: &mut [f32]);

    /// Loss + accuracy over an entire dataset.
    fn evaluate(&self, w: &[f32], ds: &Dataset) -> EvalReport;
}

/// Finite-difference gradient check helper (tests only; exposed so the
/// integration suite can reuse it against any model).
pub fn finite_diff_check(
    model: &dyn Model,
    ds: &Dataset,
    w: &[f32],
    probe_coords: &[usize],
    tol: f64,
) {
    let batch: Vec<usize> = (0..ds.len()).collect();
    let mut grad = vec![0.0f32; model.num_params()];
    model.gradient(w, ds, &batch, &mut grad);
    let eps = 1e-3f32;
    for &i in probe_coords {
        let mut wp = w.to_vec();
        wp[i] += eps;
        let lp = model.evaluate(&wp, ds).loss;
        wp[i] -= 2.0 * eps;
        let lm = model.evaluate(&wp, ds).loss;
        let fd = (lp - lm) / (2.0 * eps as f64);
        let an = grad[i] as f64;
        // Floor the denominator at 1e-3: below that, f32 forward-pass noise
        // dominates the central difference and relative error is vacuous.
        let denom = fd.abs().max(an.abs()).max(1e-3);
        assert!(
            (fd - an).abs() / denom < tol,
            "coord {i}: finite-diff {fd} vs analytic {an}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthMnist;

    #[test]
    fn all_models_expose_consistent_shapes() {
        let ds = SynthMnist::new(1).dataset(20);
        let models: Vec<Box<dyn Model>> = vec![
            Box::new(LogReg::new(ds.features, ds.classes, 1e-2)),
            Box::new(MlpMnist::new(50)),
        ];
        for m in &models {
            let w = m.init_params(3);
            assert_eq!(w.len(), m.num_params());
            let mut g = vec![0.0; m.num_params()];
            m.gradient(&w, &ds, &[0, 1, 2], &mut g);
            assert!(g.iter().any(|&v| v != 0.0));
            let rep = m.evaluate(&w, &ds);
            assert!(rep.loss.is_finite());
            assert!((0.0..=1.0).contains(&rep.accuracy));
        }
    }
}
