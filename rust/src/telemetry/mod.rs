//! Round-lifecycle telemetry: structured tracing spans, log-scale
//! histograms, and static-key counters with a zero-allocation hot path.
//!
//! The fleet driver can only be trusted at 10k+ clients per round if
//! observing it costs nothing it can't afford: a [`Collector`]
//! preallocates a fixed ring of [`SpanEvent`]s at construction, the
//! histograms are fixed arrays of atomics, and counter keys are
//! `&'static str` — so recording a span, a histogram sample, or a counter
//! increment from the encode/decode/fold hot paths performs **zero** heap
//! allocations (enforced by the counting-allocator test
//! `tests/alloc_sessions.rs`). A `Collector::disabled()` collector makes
//! every record call a branch-and-return, so untraced rounds pay nothing.
//!
//! Every span carries **two clock domains**: real wall-clock seconds
//! (`wall_start_s`/`wall_dur_s`, measured from the collector's epoch) and
//! the fleet's simulated [`crate::fleet::VirtualClock`] time (`virt_s`),
//! so "how long did encoding actually take" and "when in simulated time
//! did this client's message land" stay coherent in one trace. See
//! `DESIGN.md` §10 for the event taxonomy and the JSONL schema emitted by
//! [`jsonl::TraceWriter`].

pub mod jsonl;
pub mod probe;
pub mod report;

pub use jsonl::TraceWriter;
pub use probe::EncodeProbe;
pub use report::{summarize, RoundSummary, TelemetryReport, CLIENT_LIFECYCLE};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Default event-ring capacity: comfortably holds the ~5 spans/client of
/// a 10k-client round plus the round-scoped spans.
pub const DEFAULT_EVENT_CAPACITY: usize = 1 << 16;

/// Static-key counter slots preallocated per collector.
const COUNTER_SLOTS: usize = 64;

/// The lifecycle stage a span instruments. Discriminant order is the
/// per-client lifecycle order; [`Collector::drain`] sorts on it so traces
/// are deterministic regardless of worker interleaving.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// Local SGD on one client (worker thread).
    ClientTrain,
    /// Session encode of one client update (worker thread).
    Encode,
    /// Uplink admission of one framed message (coordinator thread).
    Transmit,
    /// Decode-stream drain of one accepted message (shard thread).
    Decode,
    /// Fixed-point fold of one accepted message (shard thread).
    Fold,
    /// Per-round capacity draw + rate allocation (round-scoped).
    RateAlloc,
    /// One aggregation shard's whole-round fold summary (round-scoped,
    /// one span per shard per round, recorded in ascending shard order).
    ShardFold,
    /// Downlink broadcast of one client's compressed global-model delta
    /// (coordinator thread). Appended after `ShardFold` so the drain
    /// sort order of pre-downlink traces is unchanged.
    Broadcast,
    /// Full-model downlink resync for a stale or first-contact client
    /// (coordinator thread; a client gets `Broadcast` *or* `StaleSync`
    /// per downlink round, never both).
    StaleSync,
    /// One scheduled retransmission after a corrupt/unparseable frame
    /// (coordinator thread; up to `WirePlan::max_retries` per client per
    /// round). Appended after `StaleSync` so pre-existing traces keep
    /// their drain sort order.
    Retry,
    /// Terminal quarantine of one client's round contribution: wire
    /// corruption survived every retransmit, or a CRC-valid payload
    /// failed shard decode (coordinator thread).
    Reject,
}

impl SpanKind {
    /// Stable wire name (the JSONL `kind` field).
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::ClientTrain => "client_train",
            SpanKind::Encode => "encode",
            SpanKind::Transmit => "transmit",
            SpanKind::Decode => "decode",
            SpanKind::Fold => "fold",
            SpanKind::RateAlloc => "rate_alloc",
            SpanKind::ShardFold => "shard_fold",
            SpanKind::Broadcast => "broadcast",
            SpanKind::StaleSync => "stale_sync",
            SpanKind::Retry => "retry",
            SpanKind::Reject => "reject",
        }
    }
}

/// Stage-specific span payload. Kept `Copy` (no heap) so the event ring
/// can be preallocated and overwritten in place.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SpanData {
    /// Local training: τ and the model size.
    ClientTrain { local_steps: u32, m: u64 },
    /// Session encode: the budget the rate controller assigned
    /// (⌊R_u·m⌋), the exact coded bits achieved, chunks pushed through
    /// the sink, and the codec's internal work counters (scale-search
    /// probes, range-coder symbols/escapes) from [`probe`].
    Encode {
        assigned_bits: u64,
        achieved_bits: u64,
        chunks: u32,
        scale_probes_est: u32,
        scale_probes_exact: u32,
        symbols: u64,
        escapes: u64,
    },
    /// Uplink admission: serialized frame bytes, exact payload bits, and
    /// whether the budget check admitted the message.
    Transmit { wire_bytes: u64, payload_bits: u64, accepted: bool },
    /// Decode-stream drain: chunks yielded, entries produced, the
    /// aggregation shard that owned the stream, and iterations spent by
    /// budgeted reconstruction solvers (fedvqcs IHT; 0 for closed-form
    /// codecs) from [`probe`].
    Decode { chunks: u32, entries: u64, shard: u32, solver_iters: u64 },
    /// Aggregator fold: chunks folded, entries, the client's
    /// re-normalized weight α, and the owning aggregation shard.
    Fold { chunks: u32, entries: u64, alpha: f64, shard: u32 },
    /// Rate allocation over the round's arrivals: client count, Σ channel
    /// capacity and Σ assigned rate (bits/entry mass).
    RateAlloc { clients: u32, capacity_mass: f64, assigned_mass: f64 },
    /// One shard's round totals: streams folded, chunks, entries, and the
    /// decode/fold stage seconds (the per-client `decode`/`fold` spans of
    /// this round tagged with the same `shard` must sum to these counts —
    /// `scripts/validate_trace.py` reconciles them).
    ShardFold {
        shard: u32,
        folds: u32,
        chunks: u64,
        entries: u64,
        decode_secs: f64,
        fold_secs: f64,
    },
    /// Downlink delta broadcast: the budget assigned (⌊R_dl·m⌋), exact
    /// coded bits achieved, serialized frame bytes, and the reference
    /// round the delta was coded against.
    Broadcast { assigned_bits: u64, achieved_bits: u64, wire_bytes: u64, ref_round: u64 },
    /// Full-model downlink resync: how many rounds the client's
    /// reference lagged, raw payload bits (32·m), and frame bytes.
    StaleSync { staleness: u64, bits: u64, wire_bytes: u64 },
    /// One retransmission: which attempt just failed (1-based), the
    /// frame bytes it burned on the wire, and the decode failure that
    /// triggered the resend (`reason` is static — span data stays `Copy`).
    Retry { attempt: u32, wire_bytes: u64, reason: &'static str },
    /// Terminal rejection: total transmit attempts spent (1 + retries)
    /// and the failure that exhausted them.
    Reject { attempts: u32, reason: &'static str },
}

/// One recorded span. `user` is [`SpanEvent::ROUND_SCOPED`] for events
/// that belong to the round rather than a client.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpanEvent {
    pub kind: SpanKind,
    pub round: u64,
    pub user: u64,
    /// Wall-clock start, seconds since the collector's construction.
    pub wall_start_s: f64,
    /// Wall-clock duration in seconds (0 for instantaneous events).
    pub wall_dur_s: f64,
    /// Fleet [`crate::fleet::VirtualClock`] timestamp (simulated
    /// seconds): the round's virtual start for client-side spans, the
    /// message's virtual arrival for transmit/decode/fold.
    pub virt_s: f64,
    pub data: SpanData,
}

impl SpanEvent {
    /// Sentinel `user` id for round-scoped events (e.g. rate allocation).
    pub const ROUND_SCOPED: u64 = u64::MAX;
}

impl Default for SpanEvent {
    fn default() -> Self {
        Self {
            kind: SpanKind::ClientTrain,
            round: 0,
            user: Self::ROUND_SCOPED,
            wall_start_s: 0.0,
            wall_dur_s: 0.0,
            virt_s: 0.0,
            data: SpanData::ClientTrain { local_steps: 0, m: 0 },
        }
    }
}

/// Metrics with a fixed log₂-bucket histogram on the collector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HistMetric {
    /// Per-client session-encode latency, nanoseconds.
    EncodeNanos = 0,
    /// Per-client serialized frame size, bytes.
    MessageBytes = 1,
    /// Per-chunk aggregator fold time, nanoseconds.
    FoldChunkNanos = 2,
    /// Per-client wall nanoseconds inside pipeline transform stages
    /// (forward on encode; zero for non-pipeline codecs).
    TransformNanos = 3,
}

impl HistMetric {
    /// Number of distinct metrics (histogram array length).
    pub const COUNT: usize = 4;

    /// All metrics, in index order.
    pub const ALL: [HistMetric; Self::COUNT] = [
        HistMetric::EncodeNanos,
        HistMetric::MessageBytes,
        HistMetric::FoldChunkNanos,
        HistMetric::TransformNanos,
    ];

    /// Stable name for reports.
    pub fn name(self) -> &'static str {
        match self {
            HistMetric::EncodeNanos => "encode_nanos",
            HistMetric::MessageBytes => "message_bytes",
            HistMetric::FoldChunkNanos => "fold_chunk_nanos",
            HistMetric::TransformNanos => "transform_nanos",
        }
    }
}

/// Fixed log₂-bucket histogram: value `v` lands in bucket
/// `⌊log₂ v⌋ + 1` (0 holds `v = 0`), so 64 buckets cover the full `u64`
/// range. All-atomic — recording never locks or allocates.
#[derive(Debug)]
pub struct LogHistogram {
    buckets: [AtomicU64; 64],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

impl LogHistogram {
    /// Bucket index for a value.
    pub fn bucket_of(value: u64) -> usize {
        (64 - value.leading_zeros() as usize).min(63)
    }

    /// Lower bound of a bucket (inclusive): 0, 1, 2, 4, 8, …
    pub fn bucket_floor(bucket: usize) -> u64 {
        if bucket == 0 {
            0
        } else {
            1u64 << (bucket - 1)
        }
    }

    /// Record one sample.
    pub fn record(&self, value: u64) {
        self.buckets[Self::bucket_of(value)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    /// Snapshot of the 64 bucket counts.
    pub fn buckets(&self) -> [u64; 64] {
        let mut out = [0u64; 64];
        for (o, b) in out.iter_mut().zip(&self.buckets) {
            *o = b.load(Ordering::Relaxed);
        }
        out
    }

    /// Approximate percentile (bucket-floor resolution): the lower bound
    /// of the bucket containing the `p`-quantile sample, `p` in [0, 100].
    pub fn percentile(&self, p: f64) -> u64 {
        let counts = self.buckets();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_floor(i);
            }
        }
        Self::bucket_floor(63)
    }
}

/// Preallocated span storage: a fixed ring that overwrites its oldest
/// event (and counts the overwrite) when full.
#[derive(Debug)]
struct EventRing {
    buf: Vec<SpanEvent>,
    start: usize,
    len: usize,
    dropped: u64,
}

impl EventRing {
    fn with_capacity(capacity: usize) -> Self {
        Self { buf: vec![SpanEvent::default(); capacity], start: 0, len: 0, dropped: 0 }
    }

    fn push(&mut self, ev: SpanEvent) {
        let cap = self.buf.len();
        if cap == 0 {
            self.dropped += 1;
            return;
        }
        if self.len < cap {
            self.buf[(self.start + self.len) % cap] = ev;
            self.len += 1;
        } else {
            self.buf[self.start] = ev;
            self.start = (self.start + 1) % cap;
            self.dropped += 1;
        }
    }
}

/// Preallocated `&'static str`-keyed counters: linear-scan lookup, first
/// use of a key claims a free slot (no allocation — the slot vector's
/// capacity is reserved at construction).
#[derive(Debug)]
struct CounterBank {
    slots: Vec<(&'static str, f64)>,
    overflowed: u64,
}

impl CounterBank {
    fn add(&mut self, key: &'static str, v: f64) {
        for slot in self.slots.iter_mut() {
            if std::ptr::eq(slot.0, key) || slot.0 == key {
                slot.1 += v;
                return;
            }
        }
        if self.slots.len() < self.slots.capacity() {
            self.slots.push((key, v));
        } else {
            self.overflowed += 1;
        }
    }
}

/// Thread-safe telemetry sink for one run: span ring + histograms +
/// counters. `&Collector` is `Sync`, so fleet workers record through the
/// same shared reference the coordinator drains.
#[derive(Debug)]
pub struct Collector {
    enabled: bool,
    epoch: Instant,
    ring: Mutex<EventRing>,
    hists: [LogHistogram; HistMetric::COUNT],
    counters: Mutex<CounterBank>,
}

impl Collector {
    /// Active collector holding up to `capacity` events between drains.
    /// All steady-state storage is allocated here, up front.
    pub fn new(capacity: usize) -> Self {
        Self {
            enabled: true,
            epoch: Instant::now(),
            ring: Mutex::new(EventRing::with_capacity(capacity)),
            hists: Default::default(),
            counters: Mutex::new(CounterBank {
                slots: Vec::with_capacity(COUNTER_SLOTS),
                overflowed: 0,
            }),
        }
    }

    /// Active collector with [`DEFAULT_EVENT_CAPACITY`].
    pub fn with_default_capacity() -> Self {
        Self::new(DEFAULT_EVENT_CAPACITY)
    }

    /// Capacity sized for per-round drains over cohorts of `n` clients:
    /// ≈5 uplink spans plus one downlink `broadcast`/`stale_sync` span
    /// each, headroom for wire-fault `retry`/`reject` spans (each retry
    /// adds one extra `transmit` + one `retry` span), one `shard_fold`
    /// span per aggregation shard (≤ `fleet::MAX_SHARDS`), plus
    /// round-scoped headroom — a traced bidirectional round at any legal
    /// shard count fits without dropping events.
    pub fn for_cohort(n: usize) -> Self {
        Self::new(
            n.saturating_mul(12).saturating_add(crate::fleet::MAX_SHARDS).saturating_add(64),
        )
    }

    /// No-op collector: every record call returns after one branch, no
    /// storage is allocated. The near-zero-overhead "tracing off" state.
    pub fn disabled() -> Self {
        Self {
            enabled: false,
            epoch: Instant::now(),
            ring: Mutex::new(EventRing::with_capacity(0)),
            hists: Default::default(),
            counters: Mutex::new(CounterBank { slots: Vec::new(), overflowed: 0 }),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Wall-clock seconds since this collector was constructed (the
    /// `wall_start_s` domain of every span it records).
    pub fn wall_now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Record a span. Zero-allocation; oldest event is overwritten (and
    /// counted dropped) if the ring is full.
    pub fn record(&self, ev: SpanEvent) {
        if !self.enabled {
            return;
        }
        // Observability must never turn one contained panic into a
        // cascade: a recorder that panicked while holding this lock can
        // at worst have torn its own event slot, so recover the lock and
        // keep tracing (DESIGN.md §13 poisoning policy).
        self.ring.lock().unwrap_or_else(|p| p.into_inner()).push(ev);
    }

    /// Record one histogram sample. Zero-allocation, lock-free.
    pub fn record_hist(&self, metric: HistMetric, value: u64) {
        if !self.enabled {
            return;
        }
        self.hists[metric as usize].record(value);
    }

    /// Add to a static-key counter. Zero-allocation (slots preallocated).
    pub fn add_counter(&self, key: &'static str, v: f64) {
        if !self.enabled {
            return;
        }
        self.counters.lock().unwrap_or_else(|p| p.into_inner()).add(key, v);
    }

    /// Take all buffered events, emptying the ring. Events are sorted by
    /// `(round, user, kind)` so the trace is deterministic for any worker
    /// count (the recording order is completion order, which is not).
    /// Off the hot path — allocation here is fine.
    pub fn drain(&self) -> Vec<SpanEvent> {
        let mut ring = self.ring.lock().unwrap_or_else(|p| p.into_inner());
        let cap = ring.buf.len();
        let mut out = Vec::with_capacity(ring.len);
        for k in 0..ring.len {
            out.push(ring.buf[(ring.start + k) % cap]);
        }
        ring.start = 0;
        ring.len = 0;
        drop(ring);
        out.sort_by_key(|e| (e.round, e.user, e.kind));
        out
    }

    /// Events lost to ring overflow since the last call; resets to zero.
    pub fn take_dropped(&self) -> u64 {
        let mut ring = self.ring.lock().unwrap_or_else(|p| p.into_inner());
        std::mem::take(&mut ring.dropped)
    }

    /// The histogram for `metric`.
    pub fn histogram(&self, metric: HistMetric) -> &LogHistogram {
        &self.hists[metric as usize]
    }

    /// Snapshot of all counters (key, value), in first-use order, plus
    /// the number of adds lost to slot exhaustion.
    pub fn counters_snapshot(&self) -> (Vec<(&'static str, f64)>, u64) {
        let bank = self.counters.lock().unwrap_or_else(|p| p.into_inner());
        (bank.slots.clone(), bank.overflowed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(round: u64, user: u64, kind: SpanKind) -> SpanEvent {
        SpanEvent { kind, round, user, ..SpanEvent::default() }
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let col = Collector::new(4);
        for u in 0..7u64 {
            col.record(ev(0, u, SpanKind::Encode));
        }
        let events = col.drain();
        assert_eq!(events.len(), 4);
        let users: Vec<u64> = events.iter().map(|e| e.user).collect();
        assert_eq!(users, vec![3, 4, 5, 6], "oldest three must be overwritten");
        assert_eq!(col.take_dropped(), 3);
        assert_eq!(col.take_dropped(), 0, "dropped counter must reset");
        assert!(col.drain().is_empty(), "drain must empty the ring");
    }

    #[test]
    fn drain_sorts_by_round_user_kind() {
        let col = Collector::new(16);
        col.record(ev(1, 2, SpanKind::Fold));
        col.record(ev(0, 5, SpanKind::Encode));
        col.record(ev(1, 2, SpanKind::ClientTrain));
        col.record(ev(0, SpanEvent::ROUND_SCOPED, SpanKind::RateAlloc));
        col.record(ev(0, 5, SpanKind::ClientTrain));
        let events = col.drain();
        let keys: Vec<(u64, u64, SpanKind)> =
            events.iter().map(|e| (e.round, e.user, e.kind)).collect();
        assert_eq!(
            keys,
            vec![
                (0, 5, SpanKind::ClientTrain),
                (0, 5, SpanKind::Encode),
                (0, SpanEvent::ROUND_SCOPED, SpanKind::RateAlloc),
                (1, 2, SpanKind::ClientTrain),
                (1, 2, SpanKind::Fold),
            ]
        );
    }

    #[test]
    fn disabled_collector_is_a_no_op() {
        let col = Collector::disabled();
        assert!(!col.is_enabled());
        col.record(ev(0, 1, SpanKind::Encode));
        col.record_hist(HistMetric::EncodeNanos, 500);
        col.add_counter("x", 1.0);
        assert!(col.drain().is_empty());
        assert_eq!(col.histogram(HistMetric::EncodeNanos).count(), 0);
        assert_eq!(col.counters_snapshot().0.len(), 0);
        assert_eq!(col.take_dropped(), 0, "disabled record must not count drops");
    }

    #[test]
    fn histogram_buckets_and_stats() {
        assert_eq!(LogHistogram::bucket_of(0), 0);
        assert_eq!(LogHistogram::bucket_of(1), 1);
        assert_eq!(LogHistogram::bucket_of(2), 2);
        assert_eq!(LogHistogram::bucket_of(3), 2);
        assert_eq!(LogHistogram::bucket_of(4), 3);
        assert_eq!(LogHistogram::bucket_of(u64::MAX), 63);
        assert_eq!(LogHistogram::bucket_floor(0), 0);
        assert_eq!(LogHistogram::bucket_floor(1), 1);
        assert_eq!(LogHistogram::bucket_floor(3), 4);

        let h = LogHistogram::default();
        for v in [0u64, 1, 3, 8, 8, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.sum(), 1020);
        assert!((h.mean() - 170.0).abs() < 1e-9);
        let b = h.buckets();
        assert_eq!(b[0], 1); // 0
        assert_eq!(b[1], 1); // 1
        assert_eq!(b[2], 1); // 3
        assert_eq!(b[4], 2); // 8, 8
        assert_eq!(b[10], 1); // 1000 ∈ [512, 1024)
        assert_eq!(h.percentile(0.0), 0);
        assert_eq!(h.percentile(100.0), 512);
        assert!(h.percentile(50.0) <= h.percentile(95.0));
    }

    #[test]
    fn counters_accumulate_under_static_keys() {
        let col = Collector::new(4);
        col.add_counter("bits", 10.0);
        col.add_counter("bits", 5.0);
        col.add_counter("chunks", 1.0);
        let (snap, overflowed) = col.counters_snapshot();
        assert_eq!(overflowed, 0);
        assert_eq!(snap, vec![("bits", 15.0), ("chunks", 1.0)]);
    }

    #[test]
    fn collector_is_sync_and_workers_can_record() {
        let col = Collector::new(1024);
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let col = &col;
                s.spawn(move || {
                    for u in 0..50u64 {
                        col.record(ev(0, t * 100 + u, SpanKind::Encode));
                        col.record_hist(HistMetric::MessageBytes, u + 1);
                    }
                });
            }
        });
        assert_eq!(col.drain().len(), 200);
        assert_eq!(col.histogram(HistMetric::MessageBytes).count(), 200);
    }
}
